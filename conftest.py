"""Repo-root pytest shim: make `python/` importable so the suites run both
as `cd python && pytest tests/` (Makefile) and `pytest python/tests/`
(repo root)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
