"""L1 — Pallas kernel for the dense domination operator (paper Remark 9).

A vertex ``u`` is dominated by ``v`` iff ``N[u] ⊆ N[v]`` where ``N[·]`` is
the *closed* neighbourhood. With ``B = A + I`` over {0,1}:

    viol[u, v] = Σ_w  B[u, w] · (1 − B[v, w])

``u`` is dominated by ``v`` ⟺ ``viol[u, v] == 0 ∧ u ≠ v`` (closed
neighbourhoods make adjacency implied: ``w = u`` contributes ``1`` unless
``B[v, u] = 1``). The PrunIT sublevel condition ``f(u) ≥ f(v)`` (Thm 7) is
fused into the epilogue; superlevel (Rmk 8) is obtained by negating ``f``
on the caller side. The diagonal needs no explicit mask: the fused
``adj > 0`` test kills it because adjacency matrices carry a zero diagonal.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the violation count is a
single ``B · (1 − B)ᵀ`` matmul → MXU systolic array. The grid tiles the
*output* into (T, T) blocks; each program streams the two (T, K) operand
panels through VMEM, runs one ``dot_general`` MXU pass, and fuses the
masking epilogue so no N×N intermediate ever round-trips through HBM.

NOTE on the contraction axis: a production TPU kernel would add a third
grid axis over K with a VMEM scratch accumulator (`pl.when(k == 0)` zero +
`pl.when(k == nk-1)` epilogue). This environment's jax (0.8.2) cannot
lower `program_id` through the *CPU HLO interpreter* used for AOT export,
so the exported artifact keeps K whole-panel — which is also the correct
choice for every exported bucket: at N = 512, one f32 operand panel is
128·512·4 B = 256 KiB, far under the ~16 MiB VMEM budget, so K-splitting
would only add loop overhead. ``interpret=True`` everywhere — the CPU PJRT
plugin cannot execute Mosaic custom-calls.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dom_kernel(b_u_ref, b_v_ref, adj_ref, f_u_ref, f_v_ref, out_ref):
    """One (T, T) output tile of the dominated-pair mask.

    Grid axes: 0 → output row tile (u), 1 → output col tile (v).
    Operand panels are (T, K) row slabs of ``B = A + I``.
    """
    b_u = b_u_ref[...]          # (T, K) rows of B for the u tile
    b_v = b_v_ref[...]          # (T, K) rows of B for the v tile
    # (T, K) @ (K, T) MXU pass: |N[u] \ N[v]| violation counts.
    viol = jax.lax.dot_general(
        b_u,
        (1.0 - b_v).T,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # Fused epilogue: domination ∧ adjacency (kills the diagonal) ∧ the
    # Theorem 7 filtration admissibility f(u) ≥ f(v).
    adjacent = adj_ref[...] > 0.0
    f_ok = f_u_ref[...] >= f_v_ref[...]
    dominated = (viol == 0.0) & adjacent & f_ok
    out_ref[...] = dominated.astype(jnp.float32)


@partial(jax.jit, static_argnames=("block",))
def dominated_pairs_kernel(adj, f, block=None):
    """Dense dominated-pair mask via the Pallas kernel.

    Args:
      adj: (N, N) symmetric 0/1 float32 adjacency matrix, zero diagonal.
      f:   (N,) float32 filtering values (sublevel; negate for superlevel).
      block: output tile edge; must divide N. Defaults to min(N, 128).

    Returns:
      (N, N) float32 mask; ``mask[u, v] = 1`` iff v dominates u and
      ``f(u) ≥ f(v)``.
    """
    n = adj.shape[0]
    if block is None:
        block = min(n, 128)
    assert n % block == 0, f"N={n} must be a multiple of block={block}"
    b = adj + jnp.eye(n, dtype=adj.dtype)
    f_col = f.reshape(n, 1)
    f_row = f.reshape(1, n)
    grid = (n // block, n // block)
    return pl.pallas_call(
        _dom_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, n), lambda i, j: (i, 0)),  # B panel (u rows)
            pl.BlockSpec((block, n), lambda i, j: (j, 0)),  # B panel (v rows)
            pl.BlockSpec((block, block), lambda i, j: (i, j)),  # adj tile
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),      # f(u)
            pl.BlockSpec((1, block), lambda i, j: (0, j)),      # f(v)
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(b, b, adj, f_col, f_row)
