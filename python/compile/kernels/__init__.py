# L1: Pallas kernel(s) for the paper's compute hot-spot.
from .domination import dominated_pairs_kernel
from .ref import dominated_any_ref, dominated_pairs_ref

__all__ = ["dominated_pairs_kernel", "dominated_pairs_ref", "dominated_any_ref"]
