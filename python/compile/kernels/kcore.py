"""L1 — Pallas kernel for bulk-synchronous dense k-core peeling.

CoralTDA (paper Thm 2) needs the (k+1)-core. The sparse CPU path uses
Batagelj–Zaveršnik; this kernel is the dense/TPU formulation: one peeling
round is a masked degree count

    deg[u] = Σ_v A[u, v] · alive[v]        (an (N,N)·(N,1) MXU matvec)

followed by `alive' = alive ∧ (deg ≥ k)`. The L2 graph iterates rounds to
a fixed point with `lax.while_loop` — the whole loop lowers into a single
HLO `while`, so the Rust runtime executes the full decomposition in one
artifact call.

TPU mapping: grid over row tiles; each program streams a (T, N) adjacency
panel and the (N, 1) alive column through VMEM for one matvec, fusing the
mask-and-threshold epilogue. `interpret=True` as everywhere (CPU PJRT
cannot run Mosaic custom-calls).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _peel_kernel(adj_ref, alive_col_ref, alive_tile_ref, k_ref, out_ref):
    """One peeling round for a (T,) tile of vertices."""
    adj = adj_ref[...]              # (T, N) rows of A
    alive_col = alive_col_ref[...]  # (N, 1) current alive column
    deg = jax.lax.dot_general(
        adj,
        alive_col,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (T, 1) masked degrees
    k = k_ref[...]                  # (1, 1)
    my_alive = alive_tile_ref[...]  # (T, 1) — this tile's current state
    out_ref[...] = my_alive * (deg >= k).astype(jnp.float32)


@partial(jax.jit, static_argnames=("block",))
def peel_round_kernel(adj, alive, k, block=None):
    """One bulk-synchronous peeling round.

    Args:
      adj:   (N, N) 0/1 f32 adjacency.
      alive: (N, 1) 0/1 f32 alive column.
      k:     (1, 1) f32 threshold.
      block: row-tile edge; must divide N.

    Returns:
      (N, 1) f32 new alive column.
    """
    n = adj.shape[0]
    if block is None:
        block = min(n, 128)
    assert n % block == 0
    grid = (n // block,)
    return pl.pallas_call(
        _peel_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, n), lambda i: (i, 0)),   # adjacency panel
            pl.BlockSpec((n, 1), lambda i: (0, 0)),       # alive column
            pl.BlockSpec((block, 1), lambda i: (i, 0)),   # this tile's alive
            pl.BlockSpec((1, 1), lambda i: (0, 0)),       # k scalar
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(adj, alive, alive, k)
