"""Pure-jnp oracle for the domination kernel (the L1 correctness signal).

Implements paper Definition 4 + the Theorem 7 filtration condition with no
Pallas machinery: the pytest/hypothesis suites assert the kernel matches
this reference bit-for-bit (the computation is exact integer counting in
f32, so ``==`` comparisons are legitimate).
"""

import jax.numpy as jnp


def dominated_pairs_ref(adj, f):
    """(N, N) mask; mask[u, v] = 1 iff v dominates u and f(u) ≥ f(v).

    Closed-neighbourhood domination: ``N[u] ⊆ N[v]`` with
    ``N[x] = {x} ∪ neighbours(x)``.
    """
    n = adj.shape[0]
    b = adj + jnp.eye(n, dtype=adj.dtype)
    # viol[u, v] = |N[u] \ N[v]| — number of witnesses against domination.
    viol = b @ (1.0 - b).T
    not_diag = ~jnp.eye(n, dtype=bool)
    adjacent = adj > 0.0
    f_ok = f[:, None] >= f[None, :]
    return ((viol == 0.0) & not_diag & adjacent & f_ok).astype(jnp.float32)


def dominated_any_ref(adj, f):
    """(N,) flag: vertex u is dominated by at least one admissible v."""
    return jnp.max(dominated_pairs_ref(adj, f), axis=1)


def kcore_mask_ref(adj, k):
    """(N,) 0/1 k-core membership by iterative peeling (pure jnp)."""
    import numpy as np

    a = np.asarray(adj)
    n = a.shape[0]
    alive = np.ones(n, dtype=np.float32)
    while True:
        deg = a @ alive * alive
        new_alive = alive * (deg >= k).astype(np.float32)
        # vertices with alive=0 have deg 0 < k (for k >= 1), handled above
        if np.array_equal(new_alive, alive):
            return jnp.asarray(alive)
        alive = new_alive
