"""L2 — the JAX compute graph exported to the Rust runtime.

The paper's contribution is a pre-processing reduction, so the dense
hot-spot we accelerate is one **PrunIT domination sweep** (Remark 9 + the
Theorem 7 admissibility condition): given a padded adjacency matrix and
filtering values, emit the dominated-pair mask and per-vertex dominated
flags. The Rust coordinator (L3) performs the sequential greedy selection
(removing both members of a mutually-dominating twin pair is unsound) and
re-invokes the artifact until a fixed point.

Padding contract (mirrored by ``rust/src/runtime/pad.rs``): graphs are
padded to a size bucket with **isolated** vertices carrying
``f = PAD_SENTINEL``. An isolated pad vertex is adjacent to nothing, so it
can neither dominate nor be dominated (adjacency is required); the real
block of the output is therefore unchanged and the pad block is all-zero.
``python/tests/test_model.py`` proves this inertness property.
"""

import jax
import jax.numpy as jnp

from .kernels.domination import dominated_pairs_kernel
from .kernels.kcore import peel_round_kernel

#: f-value assigned to padding vertices; any finite f compares against it
#: safely. Kept finite so the HLO stays NaN/Inf-free end to end.
PAD_SENTINEL = 3.0e38

#: Size buckets exported by aot.py; rust/src/runtime/pad.rs must agree.
BUCKETS = (32, 64, 128, 256, 512)


def domination_sweep(adj, f):
    """One PrunIT sweep over a (bucket-padded) dense graph.

    Args:
      adj: (N, N) symmetric 0/1 f32 adjacency, zero diagonal.
      f:   (N,) f32 sublevel filtering values (negate for superlevel).

    Returns:
      tuple of
        mask:      (N, N) f32; mask[u, v] = 1 iff v dominates u, f(u) ≥ f(v).
        dominated: (N,) f32; 1 iff u has at least one admissible dominator.
    """
    mask = dominated_pairs_kernel(adj, f)
    dominated = jnp.max(mask, axis=1)
    return (mask, dominated)


def kcore_mask(adj, k):
    """Dense k-core membership mask via bulk-synchronous peeling.

    The paper's CoralTDA substrate (Thm 2 needs the (k+1)-core). The
    peeling loop runs to a fixed point inside a single `lax.while_loop`,
    so the exported HLO contains the full decomposition — one artifact
    call per core query on the Rust side.

    Args:
      adj: (N, N) symmetric 0/1 f32 adjacency, zero diagonal (padding
           vertices are isolated: degree 0 < k, peeled in round one —
           inert for any k ≥ 1).
      k:   (1, 1) f32 core order.

    Returns:
      (N,) f32 0/1 membership mask of the k-core.
    """
    n = adj.shape[0]
    alive0 = jnp.ones((n, 1), jnp.float32)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        alive, _ = state
        new_alive = peel_round_kernel(adj, alive, k)
        changed = jnp.any(new_alive != alive)
        return (new_alive, changed)

    alive, _ = jax.lax.while_loop(cond, body, (alive0, jnp.bool_(True)))
    return (alive.reshape(n),)


def lower_kcore(bucket):
    """AOT-lower ``kcore_mask`` for one size bucket."""
    spec_adj = jax.ShapeDtypeStruct((bucket, bucket), jnp.float32)
    spec_k = jax.ShapeDtypeStruct((1, 1), jnp.float32)
    return jax.jit(kcore_mask).lower(spec_adj, spec_k)


def pad_inputs(adj, f, bucket):
    """Pad (adj, f) up to ``bucket`` with inert isolated vertices."""
    n = adj.shape[0]
    assert n <= bucket, f"graph order {n} exceeds bucket {bucket}"
    pad = bucket - n
    adj_p = jnp.pad(adj, ((0, pad), (0, pad)))
    f_p = jnp.pad(f, (0, pad), constant_values=PAD_SENTINEL)
    return adj_p, f_p


def pick_bucket(n):
    """Smallest exported bucket holding an order-n graph (None if too big)."""
    for b in BUCKETS:
        if n <= b:
            return b
    return None


def lower_domination(bucket):
    """AOT-lower ``domination_sweep`` for one size bucket."""
    spec_adj = jax.ShapeDtypeStruct((bucket, bucket), jnp.float32)
    spec_f = jax.ShapeDtypeStruct((bucket,), jnp.float32)
    return jax.jit(domination_sweep).lower(spec_adj, spec_f)
