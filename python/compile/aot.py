"""AOT entrypoint: lower the L2 graph to HLO **text** artifacts.

HLO text — not ``lowered.compile().serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser on the Rust side reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Python runs ONLY here (and in pytest); never on the Rust request path.
"""

import argparse
import hashlib
import os

from jax._src.lib import xla_client as xc

from .model import BUCKETS, lower_domination, lower_kcore


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for Rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifacts(out_dir: str) -> list:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    kernels = [("domination", lower_domination), ("kcore", lower_kcore)]
    for kernel_name, lower in kernels:
        for bucket in BUCKETS:
            text = to_hlo_text(lower(bucket))
            name = f"{kernel_name}_{bucket}.hlo.txt"
            path = os.path.join(out_dir, name)
            with open(path, "w") as fh:
                fh.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest.append((name, kernel_name, bucket, len(text), digest))
            print(f"wrote {path}: bucket={bucket} chars={len(text)} sha256[:16]={digest}")
    # Manifest lets the Rust runtime discover kernels/buckets without
    # hardcoding.
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as fh:
        fh.write("artifact\tkernel\tbucket\tchars\tsha256_16\n")
        for row in manifest:
            fh.write("\t".join(str(x) for x in row) + "\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file marker path")
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    build_artifacts(out_dir or args.out_dir)
    if args.out:
        # Makefile stamp target: emit the marker the dependency rule expects.
        with open(args.out, "w") as fh:
            fh.write("see domination_<bucket>.hlo.txt artifacts\n")


if __name__ == "__main__":
    main()
