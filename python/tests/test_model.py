"""L2 contract tests: padding inertness, bucket selection, sweep outputs."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.model import (
    BUCKETS,
    PAD_SENTINEL,
    domination_sweep,
    pad_inputs,
    pick_bucket,
)
from compile.kernels.ref import dominated_pairs_ref

from .test_kernel import random_graph


class TestBuckets:
    def test_bucket_selection(self):
        assert pick_bucket(1) == 32
        assert pick_bucket(32) == 32
        assert pick_bucket(33) == 64
        assert pick_bucket(512) == 512
        assert pick_bucket(513) is None

    def test_buckets_sorted_and_block_aligned(self):
        assert list(BUCKETS) == sorted(BUCKETS)
        for b in BUCKETS:
            assert b % 32 == 0


class TestPaddingInertness:
    """The runtime padding contract: pad vertices cannot perturb the mask."""

    @pytest.mark.parametrize("n", [3, 17, 30])
    def test_padded_equals_unpadded(self, n):
        adj, f = random_graph(n, 0.35, seed=n)
        adj_p, f_p = pad_inputs(adj, f, 32)
        mask_p, dom_p = domination_sweep(adj_p, f_p)
        mask_p = np.asarray(mask_p)
        want = np.asarray(dominated_pairs_ref(adj, f))
        np.testing.assert_array_equal(mask_p[:n, :n], want)
        assert mask_p[n:, :].sum() == 0.0, "pad rows must be inert"
        assert mask_p[:, n:].sum() == 0.0, "pad cols must be inert"
        np.testing.assert_array_equal(
            np.asarray(dom_p)[:n], want.max(axis=1)
        )
        assert np.asarray(dom_p)[n:].sum() == 0.0

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=31),
        seed=st.integers(min_value=0, max_value=10**6),
    )
    def test_padding_inert_hypothesis(self, n, seed):
        adj, f = random_graph(n, 0.4, seed)
        adj_p, f_p = pad_inputs(adj, f, 32)
        mask_p, _ = domination_sweep(adj_p, f_p)
        want = np.asarray(dominated_pairs_ref(adj, f))
        np.testing.assert_array_equal(np.asarray(mask_p)[:n, :n], want)

    def test_sentinel_is_finite_and_dominant(self):
        assert np.isfinite(PAD_SENTINEL)
        assert PAD_SENTINEL > 1e30

    def test_pad_rejects_oversize(self):
        adj, f = random_graph(40, 0.2, seed=1)
        with pytest.raises(AssertionError):
            pad_inputs(adj, f, 32)


class TestSweepOutputs:
    def test_outputs_are_tuple_of_two(self):
        adj, f = random_graph(32, 0.3, seed=2)
        out = domination_sweep(adj, f)
        assert len(out) == 2
        assert out[0].shape == (32, 32)
        assert out[1].shape == (32,)

    def test_sweep_matches_ref(self):
        adj, f = random_graph(64, 0.25, seed=9)
        mask, dom = domination_sweep(adj, f)
        want = np.asarray(dominated_pairs_ref(adj, f))
        np.testing.assert_array_equal(np.asarray(mask), want)
        np.testing.assert_array_equal(np.asarray(dom), want.max(axis=1))

    def test_dtype_is_f32(self):
        adj, f = random_graph(32, 0.3, seed=4)
        mask, dom = domination_sweep(adj, f)
        assert mask.dtype == jnp.float32 and dom.dtype == jnp.float32
