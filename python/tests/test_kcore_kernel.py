"""L1/L2 correctness for the dense k-core peeling kernel."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.kcore import peel_round_kernel
from compile.kernels.ref import kcore_mask_ref
from compile.model import kcore_mask

from .test_kernel import random_graph


def full_peel_via_kernel(adj, k, block=None):
    """Iterate the Pallas peel round to a fixed point (test-side loop)."""
    n = adj.shape[0]
    alive = jnp.ones((n, 1), jnp.float32)
    k_arr = jnp.full((1, 1), float(k), jnp.float32)
    while True:
        new_alive = peel_round_kernel(adj, alive, k_arr, block=block)
        if bool(jnp.all(new_alive == alive)):
            return np.asarray(alive).reshape(n)
        alive = new_alive


class TestPeelRound:
    def test_star_peels_leaves_at_k2(self):
        n = 8
        adj = np.zeros((n, n), np.float32)
        for leaf in range(1, n):
            adj[0, leaf] = adj[leaf, 0] = 1.0
        alive = full_peel_via_kernel(jnp.asarray(adj), 2)
        assert alive.sum() == 0.0, "star has empty 2-core"

    def test_cycle_survives_k2_dies_k3(self):
        n = 8
        adj = np.zeros((n, n), np.float32)
        for i in range(n):
            adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1.0
        assert full_peel_via_kernel(jnp.asarray(adj), 2).sum() == n
        assert full_peel_via_kernel(jnp.asarray(adj), 3).sum() == 0.0

    def test_cascade_needs_multiple_rounds(self):
        # path graph: peeling cascades from the ends inward
        n = 16
        adj = np.zeros((n, n), np.float32)
        for i in range(n - 1):
            adj[i, i + 1] = adj[i + 1, i] = 1.0
        alive = full_peel_via_kernel(jnp.asarray(adj), 2)
        assert alive.sum() == 0.0, "paths have empty 2-core (via cascade)"

    @pytest.mark.parametrize("k", [0, 1, 2, 3, 5])
    def test_matches_ref_random(self, k):
        adj, _ = random_graph(32, 0.15, seed=k * 7 + 1)
        got = full_peel_via_kernel(adj, k)
        want = np.asarray(kcore_mask_ref(adj, k))
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=30, deadline=None)
    @given(
        n=st.sampled_from([8, 16, 24, 32]),
        p=st.floats(min_value=0.0, max_value=0.5),
        k=st.integers(min_value=1, max_value=6),
        seed=st.integers(min_value=0, max_value=10**6),
    )
    def test_hypothesis_vs_ref(self, n, p, k, seed):
        adj, _ = random_graph(n, p, seed)
        got = full_peel_via_kernel(adj, k, block=8)
        want = np.asarray(kcore_mask_ref(adj, k))
        np.testing.assert_array_equal(got, want)


class TestWhileLoopModel:
    def test_model_matches_ref(self):
        adj, _ = random_graph(32, 0.2, seed=3)
        for k in [1, 2, 3, 4]:
            (mask,) = kcore_mask(adj, jnp.full((1, 1), float(k), jnp.float32))
            want = np.asarray(kcore_mask_ref(adj, k))
            np.testing.assert_array_equal(np.asarray(mask), want)

    def test_padding_inert_for_kcore(self):
        adj, _ = random_graph(20, 0.3, seed=9)
        pad = jnp.zeros((32, 32), jnp.float32)
        pad = pad.at[:20, :20].set(adj)
        (mask_p,) = kcore_mask(pad, jnp.full((1, 1), 2.0, jnp.float32))
        (mask,) = kcore_mask(adj, jnp.full((1, 1), 2.0, jnp.float32))
        np.testing.assert_array_equal(np.asarray(mask_p)[:20], np.asarray(mask))
        assert np.asarray(mask_p)[20:].sum() == 0.0, "isolated pads peel at k>=1"
