"""AOT path tests: lowering succeeds, HLO text is loader-compatible."""

import re

import pytest

from compile.aot import to_hlo_text
from compile.model import BUCKETS, lower_domination, lower_kcore


@pytest.fixture(scope="module")
def hlo_small():
    return to_hlo_text(lower_domination(32))


@pytest.fixture(scope="module")
def hlo_kcore():
    return to_hlo_text(lower_kcore(32))


class TestHloText:
    def test_nonempty_and_textual(self, hlo_small):
        assert len(hlo_small) > 1000
        assert "HloModule" in hlo_small

    def test_entry_signature(self, hlo_small):
        """Two parameters (adj NxN, f N) and a tuple root — the contract
        rust/src/runtime/artifact.rs relies on."""
        assert re.search(r"f32\[32,32\]", hlo_small)
        assert re.search(r"f32\[32\]", hlo_small)
        assert "ROOT" in hlo_small
        # return_tuple=True → root is a tuple of (mask, dominated)
        assert re.search(r"ROOT\s+\S+\s*=\s*\(f32\[32,32\]", hlo_small)

    def test_no_custom_calls(self, hlo_small):
        """interpret=True must lower pallas to plain HLO — a Mosaic
        custom-call would be unloadable by the CPU PJRT client."""
        assert "custom-call" not in hlo_small.lower()

    def test_all_buckets_lower(self):
        # Lowering (not compiling) is cheap enough to check every bucket.
        for bucket in BUCKETS:
            assert lower_domination(bucket) is not None
            assert lower_kcore(bucket) is not None

    def test_deterministic_lowering(self):
        a = to_hlo_text(lower_domination(32))
        b = to_hlo_text(lower_domination(32))
        assert a == b


class TestKcoreHlo:
    def test_contains_while_loop(self, hlo_kcore):
        """The full peeling fix-point must be inside the artifact."""
        assert "while(" in hlo_kcore or "while (" in hlo_kcore

    def test_no_custom_calls(self, hlo_kcore):
        assert "custom-call" not in hlo_kcore.lower()

    def test_signature(self, hlo_kcore):
        assert re.search(r"f32\[32,32\]", hlo_kcore)
        assert re.search(r"f32\[1,1\]", hlo_kcore)
        assert re.search(r"ROOT\s+\S+\s*=\s*\(f32\[32\]", hlo_kcore)
