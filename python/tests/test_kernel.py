"""L1 correctness: Pallas domination kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer — exact equality
(the computation is integer counting in f32), plus hypothesis sweeps over
graph order, density, block size and filtering values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.domination import dominated_pairs_kernel
from compile.kernels.ref import dominated_any_ref, dominated_pairs_ref


def random_graph(n, p, seed, weights="uniform"):
    """Symmetric 0/1 adjacency + filtering values, deterministic in seed."""
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, 1)
    adj = (adj | adj.T).astype(np.float32)
    if weights == "degree":
        f = adj.sum(axis=1).astype(np.float32)
    elif weights == "ties":
        f = rng.integers(0, 3, size=n).astype(np.float32)
    else:
        f = rng.random(n).astype(np.float32)
    return jnp.asarray(adj), jnp.asarray(f)


def pad_to(adj, f, n_target, sentinel=3.0e38):
    n = adj.shape[0]
    adj_p = jnp.pad(adj, ((0, n_target - n), (0, n_target - n)))
    f_p = jnp.pad(f, (0, n_target - n), constant_values=sentinel)
    return adj_p, f_p


class TestKnownCases:
    def test_figure3_star_of_triangles(self):
        """Paper Figure 3: vertex 3 dominates vertices 1 and 2 (0-indexed:
        vertex 2 dominates 0 and 1). Graph: edges 1-3, 2-3, 1-2? — Fig 3 has
        vertices 1,2 adjacent to 3 and 4 adjacent to 3; N(1)={1,3}⊂N(3)."""
        # 0-indexed: v0-v2, v1-v2, v2-v3  (v2 is paper's vertex 3)
        n = 4
        adj = np.zeros((n, n), np.float32)
        for a, b in [(0, 2), (1, 2), (2, 3)]:
            adj[a, b] = adj[b, a] = 1.0
        f = jnp.zeros(n, jnp.float32)  # equal f: every domination admissible
        mask = np.asarray(dominated_pairs_kernel(jnp.asarray(adj), f))
        # v2 dominates v0, v1, v3 (all closed nbhds ⊆ N[2])
        assert mask[0, 2] == 1.0
        assert mask[1, 2] == 1.0
        assert mask[3, 2] == 1.0
        # v2 itself is dominated by nobody (its nbhd is strictly largest)
        assert mask[2].sum() == 0.0

    def test_triangle_mutual_domination(self):
        """In K3 every vertex dominates every other (twin symmetry)."""
        adj = jnp.asarray(
            np.array([[0, 1, 1], [1, 0, 1], [1, 1, 0]], np.float32)
        )
        f = jnp.zeros(3, jnp.float32)
        mask = np.asarray(dominated_pairs_kernel(adj, f))
        assert mask.sum() == 6.0  # all off-diagonal pairs

    def test_path_endpoints_dominated(self):
        """Path a-b-c: endpoints dominated by the middle, middle by nobody."""
        adj = jnp.asarray(
            np.array([[0, 1, 0], [1, 0, 1], [0, 1, 0]], np.float32)
        )
        f = jnp.zeros(3, jnp.float32)
        mask = np.asarray(dominated_pairs_kernel(adj, f))
        assert mask[0, 1] == 1.0 and mask[2, 1] == 1.0
        assert mask[1].sum() == 0.0
        assert mask[0, 2] == 0.0  # non-adjacent: closed nbhd not contained

    def test_filtration_condition_blocks_removal(self):
        """f(u) < f(v) must veto the (u dominated-by v) pair (Thm 7)."""
        adj = jnp.asarray(
            np.array([[0, 1, 0], [1, 0, 1], [0, 1, 0]], np.float32)
        )
        f = jnp.asarray(np.array([0.0, 1.0, 2.0], np.float32))
        mask = np.asarray(dominated_pairs_kernel(adj, f))
        assert mask[0, 1] == 0.0  # f(0)=0 < f(1)=1 → not admissible
        assert mask[2, 1] == 1.0  # f(2)=2 ≥ f(1)=1 → admissible

    def test_isolated_vertex_inert(self):
        adj = jnp.zeros((4, 4), jnp.float32)
        f = jnp.zeros(4, jnp.float32)
        mask = np.asarray(dominated_pairs_kernel(adj, f))
        assert mask.sum() == 0.0

    def test_empty_f_ties_superlevel_negation(self):
        """Superlevel admissibility f(u) ≤ f(v) == sublevel on -f."""
        adj, f = random_graph(16, 0.3, 7)
        sub_on_neg = np.asarray(dominated_pairs_kernel(adj, -f))
        ref = np.asarray(dominated_pairs_ref(adj, -f))
        np.testing.assert_array_equal(sub_on_neg, ref)


class TestKernelVsRef:
    @pytest.mark.parametrize("n", [4, 8, 16, 32, 64])
    @pytest.mark.parametrize("p", [0.0, 0.1, 0.4, 0.9, 1.0])
    def test_grid(self, n, p):
        adj, f = random_graph(n, p, seed=n * 100 + int(p * 10))
        got = np.asarray(dominated_pairs_kernel(adj, f, block=min(n, 32)))
        want = np.asarray(dominated_pairs_ref(adj, f))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("block", [8, 16, 32, 64])
    def test_block_invariance(self, block):
        """Tiling must not change the result."""
        adj, f = random_graph(64, 0.25, seed=3)
        got = np.asarray(dominated_pairs_kernel(adj, f, block=block))
        want = np.asarray(dominated_pairs_ref(adj, f))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("weights", ["uniform", "degree", "ties"])
    def test_weight_families(self, weights):
        adj, f = random_graph(32, 0.3, seed=11, weights=weights)
        got = np.asarray(dominated_pairs_kernel(adj, f))
        want = np.asarray(dominated_pairs_ref(adj, f))
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=60, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=32),
        p=st.floats(min_value=0.0, max_value=1.0),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        ties=st.booleans(),
    )
    def test_hypothesis_sweep(self, n, p, seed, ties):
        adj, f = random_graph(n, p, seed, weights="ties" if ties else "uniform")
        # pad to the smallest block-aligned size
        n_pad = ((n + 7) // 8) * 8
        adj_p, f_p = pad_to(adj, f, n_pad)
        got = np.asarray(dominated_pairs_kernel(adj_p, f_p, block=8))
        want = np.asarray(dominated_pairs_ref(adj_p, f_p))
        np.testing.assert_array_equal(got, want)


class TestDominationSemantics:
    """Sanity properties of the *reference* semantics (shared contract)."""

    def test_domination_implies_adjacency(self):
        adj, f = random_graph(32, 0.3, seed=5)
        mask = np.asarray(dominated_pairs_ref(adj, jnp.zeros_like(f)))
        a = np.asarray(adj)
        assert np.all(mask <= a), "closed-nbhd domination must imply adjacency"

    def test_domination_implies_degree_order(self):
        adj, f = random_graph(32, 0.3, seed=6)
        mask = np.asarray(dominated_pairs_ref(adj, jnp.zeros_like(f)))
        deg = np.asarray(adj).sum(1)
        us, vs = np.nonzero(mask)
        assert np.all(deg[us] <= deg[vs])

    def test_any_flag_matches_pairs(self):
        adj, f = random_graph(24, 0.4, seed=8)
        pairs = np.asarray(dominated_pairs_ref(adj, f))
        anyf = np.asarray(dominated_any_ref(adj, f))
        np.testing.assert_array_equal(anyf, pairs.max(axis=1))
