//! **§Perf — hot-path microbenches** (EXPERIMENTS.md §Perf): the
//! measurement harness for the optimization pass. One row per hot path;
//! re-run after each change and record deltas.

use coral_prunit::bench::json::{write_records, JsonRecord};
use coral_prunit::bench::{bench_auto, sink};
use coral_prunit::complex::{CliqueComplex, Filtration, FlatComplex};
use coral_prunit::graph::gen;
use coral_prunit::homology::legacy;
use coral_prunit::homology::reduction::{
    diagrams_of_complex, diagrams_of_complex_with, Algorithm, PhConfig,
};
use coral_prunit::homology::{pd0, persistence_diagrams, Diagram};
use coral_prunit::kcore::coreness;
use coral_prunit::prune::prunit;
use coral_prunit::util::Table;

/// Every `f64` bit-equal in every dimension — the chunked rows time an
/// engine that must be indistinguishable from twist.
fn assert_diagrams_bit_eq(a: &[Diagram], b: &[Diagram], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: diagram count");
    for (da, db) in a.iter().zip(b) {
        assert_eq!(da.all_pairs().len(), db.all_pairs().len(), "{ctx}: pair count");
        for (x, y) in da.all_pairs().iter().zip(db.all_pairs()) {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: birth");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: death");
        }
    }
}

fn main() {
    let mut t = Table::new(
        "§Perf — hot paths (median ± MAD)",
        &["path", "workload", "time"],
    );

    // 1. k-core decomposition (Batagelj–Zaveršnik)
    let big = gen::barabasi_albert(100_000, 4, 1);
    let m = bench_auto(|| sink(coreness(&big)));
    t.row(&["kcore/bz".into(), format!("BA n=100k m={}", big.m()), m.fmt_ms()]);

    // 2. PrunIT sparse fixed point (materializing reference path)
    let social = coral_prunit::datasets::recipes::social(50_000, 2, 0.45, 2);
    let f_social = Filtration::degree_superlevel(&social);
    let m = bench_auto(|| sink(prunit(&social, &f_social).unwrap().removed));
    t.row(&["prunit/sparse".into(), format!("social n=50k m={}", social.m()), m.fmt_ms()]);

    // 2b. zero-copy reduction planner on the same workload: in-place
    //     prunit+coral and its fixed-point alternation, one compaction —
    //     rows also land in BENCH_hotpaths.json (same schema as the
    //     planner_scaling driver's BENCH_planner.json; distinct file so
    //     a full `cargo bench` run cannot clobber either)
    let mut planner_records: Vec<JsonRecord> = Vec::new();
    {
        use coral_prunit::reduce::{combined_with_ws, Reduction, ReductionWorkspace};
        let mut ws = ReductionWorkspace::new();
        for which in [Reduction::Combined, Reduction::FixedPoint] {
            let red = combined_with_ws(&mut ws, &social, &f_social, 1, which).unwrap();
            let m = bench_auto(|| {
                sink(combined_with_ws(&mut ws, &social, &f_social, 1, which).unwrap().graph.n())
            });
            t.row(&[
                format!("reduce/planner {}", which.name()),
                format!("social n=50k m={}", social.m()),
                m.fmt_ms(),
            ]);
            planner_records.push(JsonRecord {
                bench: "perf_hotpaths".into(),
                graph: format!("social({},{})", social.n(), social.m()),
                pipeline: "in-place".into(),
                reduction: which.name().into(),
                stage: "reduce".into(),
                kernel: "auto".into(),
                wall_secs: m.median_secs,
                removed_per_round: red
                    .report
                    .rounds
                    .iter()
                    .map(|r| r.prunit_removed + r.core_removed)
                    .collect(),
                vertices_after: red.graph.n(),
            });
        }
    }

    // 2b'. team-vs-scope dispatch: the multi-round FixedPoint alternation
    //     at 4 prune threads under the persistent thread team vs the
    //     spawn-per-round scoped reference — same residue (asserted),
    //     only the dispatch mechanism differs, so the row pair isolates
    //     thread-standup cost on the hot path.
    {
        use coral_prunit::reduce::{
            combined_with_ws, ParallelBackend, Reduction, ReductionWorkspace,
        };
        let mut reference: Option<coral_prunit::reduce::Reduced> = None;
        for (tag, backend) in [
            ("team-t4", ParallelBackend::Team),
            ("scoped-t4", ParallelBackend::Scoped),
        ] {
            let mut ws = ReductionWorkspace::with_prune_threads(4);
            ws.set_parallel_backend(backend);
            let red = combined_with_ws(&mut ws, &social, &f_social, 1, Reduction::FixedPoint)
                .unwrap();
            if let Some(r) = &reference {
                assert_eq!(red.graph, r.graph, "dispatch must not change the residue");
                assert_eq!(red.kept_old_ids, r.kept_old_ids);
            }
            let mut samples: Vec<f64> = (0..9)
                .map(|_| {
                    let r =
                        combined_with_ws(&mut ws, &social, &f_social, 1, Reduction::FixedPoint)
                            .unwrap();
                    sink(r.graph.n());
                    r.report.prunit_secs
                })
                .collect();
            samples.sort_by(|a, b| a.total_cmp(b));
            let median = samples[samples.len() / 2];
            t.row(&[
                "prunit/team-vs-scope".into(),
                format!("social n=50k {tag}"),
                format!("{:.3}ms", median * 1e3),
            ]);
            planner_records.push(JsonRecord {
                bench: "perf_hotpaths".into(),
                graph: format!("social({},{})", social.n(), social.m()),
                pipeline: tag.into(),
                reduction: "fixed-point".into(),
                stage: "prunit".into(),
                kernel: "auto".into(),
                wall_secs: median,
                removed_per_round: red
                    .report
                    .rounds
                    .iter()
                    .map(|r| r.prunit_removed + r.core_removed)
                    .collect(),
                vertices_after: red.graph.n(),
            });
            reference = Some(red);
        }
    }

    // 2c. domination-kernel matrix: the in-place PrunIT stage pinned to
    //     each kernel, on the sparse social workload (merge territory)
    //     and a dense ER core (bitset territory). Each pinned run is
    //     asserted bit-identical to the merge reference before timing.
    {
        use coral_prunit::prune::DominationKernel;
        use coral_prunit::reduce::{combined_with_ws, Reduction, ReductionWorkspace};
        let dense = gen::erdos_renyi(1_200, 0.15, 6);
        let f_dense = Filtration::degree_superlevel(&dense);
        for (wl, g, f) in [
            ("social n=50k", &social, &f_social),
            ("ER(1200,0.15)", &dense, &f_dense),
        ] {
            let mut mws = ReductionWorkspace::new();
            mws.set_domination_kernel(DominationKernel::Merge);
            let reference = combined_with_ws(&mut mws, g, f, 1, Reduction::Prunit).unwrap();
            for kern in [
                DominationKernel::Merge,
                DominationKernel::Bitset,
                DominationKernel::Auto,
            ] {
                let mut kws = ReductionWorkspace::new();
                kws.set_domination_kernel(kern);
                let red = combined_with_ws(&mut kws, g, f, 1, Reduction::Prunit).unwrap();
                assert_eq!(
                    red.graph,
                    reference.graph,
                    "prunit residue must be bit-identical under the {} kernel",
                    kern.name()
                );
                let mut samples: Vec<f64> = (0..9)
                    .map(|_| {
                        let r = combined_with_ws(&mut kws, g, f, 1, Reduction::Prunit).unwrap();
                        sink(r.graph.n());
                        r.report.prunit_secs
                    })
                    .collect();
                samples.sort_by(|a, b| a.total_cmp(b));
                let median = samples[samples.len() / 2];
                t.row(&[
                    format!("prunit/kernel-{}", kern.name()),
                    wl.into(),
                    format!("{:.3}ms", median * 1e3),
                ]);
                planner_records.push(JsonRecord {
                    bench: "perf_hotpaths".into(),
                    graph: wl.into(),
                    pipeline: "in-place".into(),
                    reduction: "prunit".into(),
                    stage: "prunit".into(),
                    kernel: kern.name().into(),
                    wall_secs: median,
                    removed_per_round: red
                        .report
                        .rounds
                        .iter()
                        .map(|r| r.prunit_removed + r.core_removed)
                        .collect(),
                    vertices_after: red.graph.n(),
                });
            }
        }
    }

    // 3. clique enumeration (complex build) on a clustered graph:
    //    columnar production path vs the retained AoS reference. Note the
    //    flat build also resolves every boundary column, which the legacy
    //    build defers to its separate HashMap matrix pass (measured on the
    //    row-4 reduction workload in row 5 — a different graph, so don't
    //    difference rows 3 and 5 directly; `flat_complex` is the
    //    like-for-like layout bench).
    let plc = gen::powerlaw_cluster(2_000, 6, 0.7, 3);
    let f_plc = Filtration::degree(&plc);
    let m = bench_auto(|| sink(FlatComplex::build(&plc, &f_plc, 3).len()));
    t.row(&["complex/build-flat(dim≤3)".into(), format!("PLC n=2k m={}", plc.m()), m.fmt_ms()]);
    let m = bench_auto(|| sink(CliqueComplex::build(&plc, &f_plc, 3).len()));
    t.row(&["complex/build-legacy(dim≤3)".into(), format!("PLC n=2k m={}", plc.m()), m.fmt_ms()]);

    // 4. boundary-matrix reduction: standard vs twist (columnar engine)
    let er = gen::erdos_renyi(300, 0.1, 4);
    let f_er = Filtration::degree(&er);
    let complex = FlatComplex::build(&er, &f_er, 3);
    println!("reduction workload: {} simplices", complex.len());
    let m_std = bench_auto(|| sink(diagrams_of_complex(&complex, 2, Algorithm::Standard).len()));
    t.row(&["homology/standard".into(), format!("{} simplices", complex.len()), m_std.fmt_ms()]);
    let m_tw = bench_auto(|| sink(diagrams_of_complex(&complex, 2, Algorithm::Twist).len()));
    t.row(&["homology/twist".into(), format!("{} simplices", complex.len()), m_tw.fmt_ms()]);

    // 4b. chunked persistence engine vs twist: the small row-4 workload
    //     plus the dense ER(1200,0.15) hotpath, where the apparent-pair
    //     prepass and the chunk-parallel local phase have real column
    //     volume. Diagrams are asserted bit-identical to twist before
    //     anything is timed; rows land in BENCH_hotpaths.json as stage
    //     `ph` (pipeline `twist` vs `chunked-t{T}`).
    {
        use coral_prunit::util::{CancelToken, TeamSlot};
        let dense = gen::erdos_renyi(1_200, 0.15, 6);
        let f_dense = Filtration::degree_superlevel(&dense);
        let dense_complex = FlatComplex::build(&dense, &f_dense, 2);
        let cancel = CancelToken::none();
        for (wl, c, max_k) in [
            (format!("ER(300,0.1) {} simplices", complex.len()), &complex, 2usize),
            (
                format!("ER(1200,0.15) {} simplices", dense_complex.len()),
                &dense_complex,
                1,
            ),
        ] {
            let mut team = TeamSlot::default();
            let twist_cfg = PhConfig { algorithm: Algorithm::Twist, ..PhConfig::default() };
            let (want, _) =
                diagrams_of_complex_with(c, max_k, &twist_cfg, &mut team, &cancel).unwrap();
            let m_tw = bench_auto(|| {
                sink(
                    diagrams_of_complex_with(c, max_k, &twist_cfg, &mut team, &cancel)
                        .unwrap()
                        .0
                        .len(),
                )
            });
            t.row(&["reduce/twist".into(), wl.clone(), m_tw.fmt_ms()]);
            planner_records.push(JsonRecord {
                bench: "perf_hotpaths".into(),
                graph: wl.clone(),
                pipeline: "twist".into(),
                reduction: "none".into(),
                stage: "ph".into(),
                kernel: "auto".into(),
                wall_secs: m_tw.median_secs,
                removed_per_round: Vec::new(),
                vertices_after: c.len(),
            });
            for threads in [1usize, 4] {
                let cfg = PhConfig { algorithm: Algorithm::Chunked, threads, chunk_cols: 0 };
                let (got, stats) =
                    diagrams_of_complex_with(c, max_k, &cfg, &mut team, &cancel).unwrap();
                assert_diagrams_bit_eq(&got, &want, &wl);
                let m = bench_auto(|| {
                    sink(
                        diagrams_of_complex_with(c, max_k, &cfg, &mut team, &cancel)
                            .unwrap()
                            .0
                            .len(),
                    )
                });
                t.row(&[
                    format!("reduce/chunked-t{threads}"),
                    format!(
                        "{wl} ({} apparent / {} reduced)",
                        stats.apparent_pairs, stats.reduced_pairs
                    ),
                    m.fmt_ms(),
                ]);
                planner_records.push(JsonRecord {
                    bench: "perf_hotpaths".into(),
                    graph: wl.clone(),
                    pipeline: format!("chunked-t{threads}"),
                    reduction: "none".into(),
                    stage: "ph".into(),
                    kernel: "auto".into(),
                    wall_secs: m.median_secs,
                    removed_per_round: Vec::new(),
                    vertices_after: c.len(),
                });
            }
        }
    }

    // 5. legacy HashMap boundary-matrix build on the row-4 workload — the
    //    pass the flat layout folds into construction
    let legacy_complex = CliqueComplex::build(&er, &f_er, 3);
    let m = bench_auto(|| {
        sink(
            legacy::BoundaryMatrix::build(&legacy_complex)
                .expect("clique complex is face-closed")
                .columns
                .len(),
        )
    });
    t.row(&["homology/matrix-build-legacy".into(), format!("{} simplices", legacy_complex.len()), m.fmt_ms()]);

    // 6. PD_0 union-find on a large sparse graph
    let cite = coral_prunit::datasets::recipes::citation(200_000, 600_000, 5);
    let f_cite = Filtration::degree_superlevel(&cite);
    let m = bench_auto(|| sink(pd0(&cite, &f_cite).len()));
    t.row(&["homology/pd0-uf".into(), format!("citation n=200k m={}", cite.m()), m.fmt_ms()]);

    // 7. end-to-end PD_1 with combined reduction (the product workload)
    let reddit = coral_prunit::datasets::find("REDDIT-BINARY").unwrap().make(7, 0);
    let f_r = Filtration::degree_superlevel(&reddit);
    let m_none = bench_auto(|| sink(persistence_diagrams(&reddit, &f_r, 1).len()));
    t.row(&["e2e/pd1 no-reduction".into(), format!("REDDIT n={}", reddit.n()), m_none.fmt_ms()]);
    let m_red = bench_auto(|| {
        let r = coral_prunit::reduce::combined(&reddit, &f_r, 1).unwrap();
        sink(persistence_diagrams(&r.graph, &r.filtration, 1).len())
    });
    t.row(&["e2e/pd1 prunit+coral".into(), format!("REDDIT n={}", reddit.n()), m_red.fmt_ms()]);

    // 8. XLA dense domination sweep per bucket (runtime layer)
    match coral_prunit::runtime::XlaRuntime::from_default() {
        Ok(rt) => {
            for n in [32usize, 128, 512] {
                let g = gen::powerlaw_cluster(n, 4, 0.6, 9);
                let f = Filtration::degree_superlevel(&g);
                let m = bench_auto(|| sink(rt.domination_sweep(&g, &f).unwrap().bucket));
                t.row(&["runtime/xla-sweep".into(), format!("bucket {n}"), m.fmt_ms()]);
            }
        }
        Err(e) => println!("xla runtime unavailable ({e}); skipping sweep rows"),
    }

    t.emit(Some("bench_results.tsv"));
    write_records("BENCH_hotpaths.json", &planner_records).expect("write BENCH_hotpaths.json");
    println!("wrote BENCH_hotpaths.json ({} records)", planner_records.len());
}
