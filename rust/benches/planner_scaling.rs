//! §Planner — materializing vs in-place reduction pipelines
//! (EXPERIMENTS.md §Perf): the acceptance bench for the zero-copy
//! planner. Both sides run the identical reduction (differential-tested
//! equal in `rust/tests/fixed_point.rs`); only the execution strategy
//! differs — per-stage `Graph` materialization vs tombstone masks on the
//! original CSR with a single compaction.
//!
//! Workloads: ER(20000, 5/n) and BA(20000, 3) (pass `--quick` for a
//! 2000-vertex CI profile), reductions Combined and FixedPoint, plus a
//! **PrunIT thread sweep**: the frontier check phase at 1/2/4/8 threads
//! (or the single count given by `--prune-threads T` — CI runs a
//! 1-vs-adaptive matrix and uploads one artifact per setting; `T = 0`
//! runs the adaptive per-round ramp and labels its rows
//! `in-place-adaptive`). Residues are asserted bit-identical across the
//! sweep before anything is timed. Emits the wall-time table plus
//! machine-readable `BENCH_planner.json` (graph, stage, wall seconds,
//! vertices removed per round) for the cross-PR perf trajectory; sweep
//! rows carry stage `prunit` and pipeline `in-place-t{T}`.
//!
//! A **team-vs-scoped FixedPoint sweep** times the multi-round
//! PrunIT⇄core alternation under the persistent thread team against the
//! spawn-per-round `ParallelBackend::Scoped` reference (rows
//! `in-place-scoped-t{T}`) — the acceptance comparison for the
//! persistent-team dispatch.
//!
//! A **domination-kernel sweep** mirrors the thread sweep: the prunit
//! stage pinned to each kernel (`--domination-kernel K` restricts to
//! one — CI runs a merge-vs-bitset matrix), rows carry pipeline
//! `in-place-k{K}`, and every pinned run is asserted bit-identical to
//! the sequential merge-kernel reference before it is timed.
//!
//! A **persistence-engine sweep** times the boundary-matrix reduction
//! itself on the dense ER(1200,0.15) hotpath: `twist` vs the
//! apparent-pair + chunk-parallel `chunked` engine (`--ph-threads T`
//! pins one chunked thread count — CI runs a t1-vs-t4 matrix and
//! uploads one artifact per setting). Diagrams are asserted
//! bit-identical to twist before anything is timed; rows carry stage
//! `ph` and pipeline `twist` / `chunked-t{T}`.

use coral_prunit::bench::json::{write_records, JsonRecord};
use coral_prunit::bench::{bench_auto, sink};
use coral_prunit::complex::Filtration;
use coral_prunit::graph::gen;
use coral_prunit::prune::DominationKernel;
use coral_prunit::reduce::{
    combined_with_materializing, combined_with_ws, ParallelBackend, Reduction,
    ReductionWorkspace,
};
use coral_prunit::util::Table;

/// Median of the prunit-stage seconds over `runs` fresh plans of `which`.
fn prunit_stage_median(
    ws: &mut ReductionWorkspace,
    g: &coral_prunit::graph::Graph,
    f: &Filtration,
    runs: usize,
    which: Reduction,
) -> f64 {
    let mut samples: Vec<f64> = (0..runs)
        .map(|_| {
            let r = combined_with_ws(ws, g, f, 1, which).unwrap();
            sink(r.graph.n());
            r.report.prunit_secs
        })
        .collect();
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[samples.len() / 2]
}

/// Sweep row label: `in-place-adaptive` for the ramp, `in-place-t{T}`
/// for a pinned thread count.
fn pipeline_label(threads: usize) -> String {
    if threads == 0 {
        "in-place-adaptive".into()
    } else {
        format!("in-place-t{threads}")
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let fixed_threads: Option<usize> = argv.iter().position(|a| a == "--prune-threads").map(|i| {
        argv.get(i + 1)
            .expect("--prune-threads: missing value")
            .parse()
            .expect("--prune-threads: expected integer")
    });
    let sweep: Vec<usize> = match fixed_threads {
        Some(t) => vec![t],
        None => vec![1, 2, 4, 8],
    };
    let fixed_kernel: Option<DominationKernel> =
        argv.iter().position(|a| a == "--domination-kernel").map(|i| {
            DominationKernel::parse(argv.get(i + 1).expect("--domination-kernel: missing value"))
                .expect("--domination-kernel: auto|merge|bitset")
        });
    let fixed_ph_threads: Option<usize> = argv.iter().position(|a| a == "--ph-threads").map(|i| {
        argv.get(i + 1)
            .expect("--ph-threads: missing value")
            .parse()
            .expect("--ph-threads: expected integer")
    });
    let ph_sweep: Vec<usize> = match fixed_ph_threads {
        Some(t) => vec![t],
        None => vec![1, 4],
    };
    let requested = fixed_kernel.unwrap_or_default();
    let kernel_sweep: Vec<DominationKernel> = match fixed_kernel {
        Some(k) => vec![k],
        None => vec![
            DominationKernel::Merge,
            DominationKernel::Bitset,
            DominationKernel::Auto,
        ],
    };
    let n: usize = if quick { 2_000 } else { 20_000 };
    let graphs = [
        (
            format!("ER({n},5/n)"),
            gen::erdos_renyi(n, 5.0 / n as f64, 11),
        ),
        (format!("BA({n},3)"), gen::barabasi_albert(n, 3, 11)),
    ];
    let mut t = Table::new(
        "§Planner — reduce wall-time, materializing vs in-place (median ± MAD)",
        &["graph", "reduction", "pipeline", "|V'|", "rounds", "time"],
    );
    let mut records: Vec<JsonRecord> = Vec::new();
    let mut ws = ReductionWorkspace::new();
    ws.set_domination_kernel(requested);
    for (label, g) in &graphs {
        let f = Filtration::degree_superlevel(g);
        for which in [Reduction::Combined, Reduction::FixedPoint] {
            // one reference run for the telemetry the JSON rows carry
            let mat = combined_with_materializing(g, &f, 1, which).unwrap();
            let inp = combined_with_ws(&mut ws, g, &f, 1, which).unwrap();
            assert_eq!(
                mat.graph, inp.graph,
                "materializing and in-place pipelines must agree"
            );
            let removed_per_round: Vec<usize> = inp
                .report
                .rounds
                .iter()
                .map(|r| r.prunit_removed + r.core_removed)
                .collect();

            let m_mat = bench_auto(|| {
                sink(combined_with_materializing(g, &f, 1, which).unwrap().graph.n())
            });
            let m_inp =
                bench_auto(|| sink(combined_with_ws(&mut ws, g, &f, 1, which).unwrap().graph.n()));

            for (pipeline, m, red) in [
                ("materializing", m_mat, &mat),
                ("in-place", m_inp, &inp),
            ] {
                t.row(&[
                    label.clone(),
                    which.name().into(),
                    pipeline.into(),
                    red.graph.n().to_string(),
                    red.report.rounds_run().to_string(),
                    m.fmt_ms(),
                ]);
                records.push(JsonRecord {
                    bench: "planner_scaling".into(),
                    graph: label.clone(),
                    pipeline: pipeline.into(),
                    reduction: which.name().into(),
                    stage: "reduce".into(),
                    // the materializing reference runs the sequential
                    // merge-walk prunit; the planner honours the flag
                    kernel: if pipeline == "materializing" {
                        "merge".into()
                    } else {
                        requested.name().into()
                    },
                    wall_secs: m.median_secs,
                    removed_per_round: removed_per_round.clone(),
                    vertices_after: red.graph.n(),
                });
            }
        }

        // PrunIT frontier thread sweep: identical residue, stage wall time
        // per configured thread count. The reference pins the sequential
        // merge kernel so every sweep row below is asserted against an
        // independent kernel/thread configuration.
        let mut seq_ws = ReductionWorkspace::with_prune_threads(1);
        seq_ws.set_domination_kernel(DominationKernel::Merge);
        let reference = combined_with_ws(&mut seq_ws, g, &f, 1, Reduction::Prunit).unwrap();
        let removed_per_round: Vec<usize> = reference
            .report
            .rounds
            .iter()
            .map(|r| r.prunit_removed + r.core_removed)
            .collect();
        for &threads in &sweep {
            let mut tws = ReductionWorkspace::with_prune_threads(threads);
            tws.set_domination_kernel(requested);
            let check = combined_with_ws(&mut tws, g, &f, 1, Reduction::Prunit).unwrap();
            assert_eq!(
                check.graph, reference.graph,
                "prunit residue must be bit-identical at {threads} threads"
            );
            assert_eq!(check.kept_old_ids, reference.kept_old_ids);
            let runs = if quick { 7 } else { 9 };
            let median = prunit_stage_median(&mut tws, g, &f, runs, Reduction::Prunit);
            t.row(&[
                label.clone(),
                "prunit".into(),
                pipeline_label(threads),
                reference.graph.n().to_string(),
                reference.report.prunit_rounds.to_string(),
                format!("{:.3}ms", median * 1e3),
            ]);
            records.push(JsonRecord {
                bench: "planner_scaling".into(),
                graph: label.clone(),
                pipeline: pipeline_label(threads),
                reduction: "prunit".into(),
                stage: "prunit".into(),
                kernel: requested.name().into(),
                wall_secs: median,
                removed_per_round: removed_per_round.clone(),
                vertices_after: reference.graph.n(),
            });
        }

        // Team-vs-scoped FixedPoint sweep: the multi-round PrunIT⇄core
        // alternation is where dispatch overhead accumulates — the
        // persistent team is measured against the spawn-per-round scoped
        // reference at the same thread count, residues asserted
        // bit-identical to the sequential run first.
        let mut fp_seq = ReductionWorkspace::with_prune_threads(1);
        fp_seq.set_domination_kernel(DominationKernel::Merge);
        let fp_ref = combined_with_ws(&mut fp_seq, g, &f, 1, Reduction::FixedPoint).unwrap();
        let fp_removed: Vec<usize> = fp_ref
            .report
            .rounds
            .iter()
            .map(|r| r.prunit_removed + r.core_removed)
            .collect();
        for &threads in &sweep {
            let mut configs: Vec<(String, ParallelBackend)> =
                vec![(pipeline_label(threads), ParallelBackend::Team)];
            if threads > 1 {
                configs.push((
                    format!("in-place-scoped-t{threads}"),
                    ParallelBackend::Scoped,
                ));
            }
            for (pipeline, backend) in configs {
                let mut bws = ReductionWorkspace::with_prune_threads(threads);
                bws.set_domination_kernel(requested);
                bws.set_parallel_backend(backend);
                let check = combined_with_ws(&mut bws, g, &f, 1, Reduction::FixedPoint).unwrap();
                assert_eq!(
                    check.graph, fp_ref.graph,
                    "fixed-point residue must be bit-identical ({pipeline})"
                );
                assert_eq!(check.kept_old_ids, fp_ref.kept_old_ids);
                let runs = if quick { 7 } else { 9 };
                let median = prunit_stage_median(&mut bws, g, &f, runs, Reduction::FixedPoint);
                t.row(&[
                    label.clone(),
                    "fixed-point".into(),
                    pipeline.clone(),
                    fp_ref.graph.n().to_string(),
                    fp_ref.report.rounds_run().to_string(),
                    format!("{:.3}ms", median * 1e3),
                ]);
                records.push(JsonRecord {
                    bench: "planner_scaling".into(),
                    graph: label.clone(),
                    pipeline,
                    reduction: "fixed-point".into(),
                    stage: "prunit".into(),
                    kernel: requested.name().into(),
                    wall_secs: median,
                    removed_per_round: fp_removed.clone(),
                    vertices_after: fp_ref.graph.n(),
                });
            }
        }

        // Domination-kernel sweep: the same prunit stage pinned to each
        // kernel, asserted bit-identical to the merge reference above.
        for &kern in &kernel_sweep {
            let mut kws = ReductionWorkspace::with_prune_threads(1);
            kws.set_domination_kernel(kern);
            let check = combined_with_ws(&mut kws, g, &f, 1, Reduction::Prunit).unwrap();
            assert_eq!(
                check.graph,
                reference.graph,
                "prunit residue must be bit-identical under the {} kernel",
                kern.name()
            );
            assert_eq!(check.kept_old_ids, reference.kept_old_ids);
            assert_eq!(check.report.prunit_rounds, reference.report.prunit_rounds);
            let runs = if quick { 7 } else { 9 };
            let median = prunit_stage_median(&mut kws, g, &f, runs, Reduction::Prunit);
            t.row(&[
                label.clone(),
                "prunit".into(),
                format!("in-place-k{}", kern.name()),
                reference.graph.n().to_string(),
                reference.report.prunit_rounds.to_string(),
                format!("{:.3}ms", median * 1e3),
            ]);
            records.push(JsonRecord {
                bench: "planner_scaling".into(),
                graph: label.clone(),
                pipeline: format!("in-place-k{}", kern.name()),
                reduction: "prunit".into(),
                stage: "prunit".into(),
                kernel: kern.name().into(),
                wall_secs: median,
                removed_per_round: removed_per_round.clone(),
                vertices_after: reference.graph.n(),
            });
        }
    }

    // Persistence-engine sweep: twist vs chunked on the dense
    // ER(1200,0.15) clique complex (dim ≤ 2 — enough column volume that
    // the apparent-pair prepass and the parallel local phase are both
    // exercised; the sparse planner workloads above have near-empty
    // higher skeletons). The same graph in both profiles: the quick CI
    // artifact and the full run record the same hotpath row.
    {
        use coral_prunit::complex::FlatComplex;
        use coral_prunit::homology::{diagrams_of_complex_with, Algorithm, Diagram, PhConfig};
        use coral_prunit::util::{CancelToken, TeamSlot};

        fn assert_bit_eq(a: &[Diagram], b: &[Diagram], ctx: &str) {
            assert_eq!(a.len(), b.len(), "{ctx}: diagram count");
            for (da, db) in a.iter().zip(b) {
                assert_eq!(da.all_pairs().len(), db.all_pairs().len(), "{ctx}: pair count");
                for (x, y) in da.all_pairs().iter().zip(db.all_pairs()) {
                    assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: birth");
                    assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: death");
                }
            }
        }

        let dense = gen::erdos_renyi(1_200, 0.15, 6);
        let f_dense = Filtration::degree_superlevel(&dense);
        let complex = FlatComplex::build(&dense, &f_dense, 2);
        let graph_label = format!("ER(1200,0.15) [{} simplices]", complex.len());
        let cancel = CancelToken::none();
        let mut team = TeamSlot::default();
        let twist_cfg = PhConfig { algorithm: Algorithm::Twist, ..PhConfig::default() };
        let (want, _) =
            diagrams_of_complex_with(&complex, 1, &twist_cfg, &mut team, &cancel).unwrap();
        let m_tw = bench_auto(|| {
            sink(
                diagrams_of_complex_with(&complex, 1, &twist_cfg, &mut team, &cancel)
                    .unwrap()
                    .0
                    .len(),
            )
        });
        t.row(&[
            graph_label.clone(),
            "none".into(),
            "twist".into(),
            dense.n().to_string(),
            "-".into(),
            m_tw.fmt_ms(),
        ]);
        records.push(JsonRecord {
            bench: "planner_scaling".into(),
            graph: graph_label.clone(),
            pipeline: "twist".into(),
            reduction: "none".into(),
            stage: "ph".into(),
            kernel: "auto".into(),
            wall_secs: m_tw.median_secs,
            removed_per_round: Vec::new(),
            vertices_after: dense.n(),
        });
        for &threads in &ph_sweep {
            let cfg = PhConfig { algorithm: Algorithm::Chunked, threads, chunk_cols: 0 };
            let (got, stats) =
                diagrams_of_complex_with(&complex, 1, &cfg, &mut team, &cancel).unwrap();
            assert_bit_eq(&got, &want, &format!("chunked-t{threads}"));
            let m = bench_auto(|| {
                sink(
                    diagrams_of_complex_with(&complex, 1, &cfg, &mut team, &cancel)
                        .unwrap()
                        .0
                        .len(),
                )
            });
            t.row(&[
                graph_label.clone(),
                format!("{} apparent / {} reduced", stats.apparent_pairs, stats.reduced_pairs),
                format!("chunked-t{threads}"),
                dense.n().to_string(),
                "-".into(),
                m.fmt_ms(),
            ]);
            records.push(JsonRecord {
                bench: "planner_scaling".into(),
                graph: graph_label.clone(),
                pipeline: format!("chunked-t{threads}"),
                reduction: "none".into(),
                stage: "ph".into(),
                kernel: "auto".into(),
                wall_secs: m.median_secs,
                removed_per_round: Vec::new(),
                vertices_after: dense.n(),
            });
        }
    }
    t.emit(Some("bench_results.tsv"));
    write_records("BENCH_planner.json", &records).expect("write BENCH_planner.json");
    println!("wrote BENCH_planner.json ({} records)", records.len());
}
