//! §Planner — materializing vs in-place reduction pipelines
//! (EXPERIMENTS.md §Perf): the acceptance bench for the zero-copy
//! planner. Both sides run the identical reduction (differential-tested
//! equal in `rust/tests/fixed_point.rs`); only the execution strategy
//! differs — per-stage `Graph` materialization vs tombstone masks on the
//! original CSR with a single compaction.
//!
//! Workloads: ER(20000, 5/n) and BA(20000, 3) (pass `--quick` for a
//! 2000-vertex CI profile), reductions Combined and FixedPoint. Emits
//! the wall-time table plus machine-readable `BENCH_planner.json`
//! (graph, stage, wall seconds, vertices removed per round) for the
//! cross-PR perf trajectory.

use coral_prunit::bench::json::{write_records, JsonRecord};
use coral_prunit::bench::{bench_auto, sink};
use coral_prunit::complex::Filtration;
use coral_prunit::graph::gen;
use coral_prunit::reduce::{
    combined_with_materializing, combined_with_ws, Reduction, ReductionWorkspace,
};
use coral_prunit::util::Table;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n: usize = if quick { 2_000 } else { 20_000 };
    let graphs = [
        (
            format!("ER({n},5/n)"),
            gen::erdos_renyi(n, 5.0 / n as f64, 11),
        ),
        (format!("BA({n},3)"), gen::barabasi_albert(n, 3, 11)),
    ];
    let mut t = Table::new(
        "§Planner — reduce wall-time, materializing vs in-place (median ± MAD)",
        &["graph", "reduction", "pipeline", "|V'|", "rounds", "time"],
    );
    let mut records: Vec<JsonRecord> = Vec::new();
    let mut ws = ReductionWorkspace::new();
    for (label, g) in &graphs {
        let f = Filtration::degree_superlevel(g);
        for which in [Reduction::Combined, Reduction::FixedPoint] {
            // one reference run for the telemetry the JSON rows carry
            let mat = combined_with_materializing(g, &f, 1, which).unwrap();
            let inp = combined_with_ws(&mut ws, g, &f, 1, which).unwrap();
            assert_eq!(
                mat.graph, inp.graph,
                "materializing and in-place pipelines must agree"
            );
            let removed_per_round: Vec<usize> = inp
                .report
                .rounds
                .iter()
                .map(|r| r.prunit_removed + r.core_removed)
                .collect();

            let m_mat = bench_auto(|| {
                sink(combined_with_materializing(g, &f, 1, which).unwrap().graph.n())
            });
            let m_inp =
                bench_auto(|| sink(combined_with_ws(&mut ws, g, &f, 1, which).unwrap().graph.n()));

            for (pipeline, m, red) in [
                ("materializing", m_mat, &mat),
                ("in-place", m_inp, &inp),
            ] {
                t.row(&[
                    label.clone(),
                    which.name().into(),
                    pipeline.into(),
                    red.graph.n().to_string(),
                    red.report.rounds_run().to_string(),
                    m.fmt_ms(),
                ]);
                records.push(JsonRecord {
                    bench: "planner_scaling".into(),
                    graph: label.clone(),
                    pipeline: pipeline.into(),
                    reduction: which.name().into(),
                    stage: "reduce".into(),
                    wall_secs: m.median_secs,
                    removed_per_round: removed_per_round.clone(),
                    vertices_after: red.graph.n(),
                });
            }
        }
    }
    t.emit(Some("bench_results.tsv"));
    write_records("BENCH_planner.json", &records).expect("write BENCH_planner.json");
    println!("wrote BENCH_planner.json ({} records)", records.len());
}
