//! **E3 — Figure 5b**: PrunIT time reduction for 0-dimensional
//! persistence on OGB-like ego networks (§6.2). For each sampled ego
//! vertex: extract the 1-hop neighbourhood, then compare
//!   t_raw   = PD_0 on the ego net
//!   t_pruned = [find+remove dominated vertices + induced graph + PD_0]
//! (all PrunIT steps included, as in the paper). Batch execution goes
//! through the coordinator — this is also the coordinator's workload
//! benchmark. Paper shape: >25% time reduction on most ego nets,
//! ARXIV ≈ 37% avg, MAG ≈ 23% avg, tails reaching 75%.

use coral_prunit::complex::{Filtration, FlatComplex};
use coral_prunit::datasets;
use coral_prunit::homology::reduction::{diagrams_of_complex, Algorithm};
use coral_prunit::prune::prunit;
use coral_prunit::util::{Rng, Table, Timer};

const SEED: u64 = 42;
const EGO_SAMPLES: usize = 400;

/// PD_0 via the generic boundary-matrix pipeline — the cost model of the
/// paper's off-the-shelf PH tooling (cubic in simplices). Our union-find
/// fast path makes PD_0 so cheap that pruning cannot pay off at ego-net
/// scale; that engine-level result is recorded in EXPERIMENTS.md.
fn pd0_generic(g: &coral_prunit::graph::Graph, f: &Filtration) -> usize {
    let c = FlatComplex::build(g, f, 1);
    diagrams_of_complex(&c, 0, Algorithm::Standard)[0].len()
}

fn main() {
    let mut t = Table::new(
        "Figure 5b — PrunIT time reduction for PD_0 on 1-hop ego networks",
        &[
            "dataset", "egos", "avg_ego_n", "t_raw_ms", "t_prunit_ms", "time_red_%", "p25", "p75",
        ],
    );
    for recipe in datasets::ogb_like() {
        let g = recipe.make(SEED, 0);
        let mut rng = Rng::new(SEED ^ 0xE60);
        let mut reds: Vec<f64> = Vec::new();
        let (mut t_raw_tot, mut t_pru_tot, mut ego_n_tot) = (0.0f64, 0.0f64, 0usize);
        // Center sampling: half uniform (the long tail of small egos),
        // half edge-endpoint-biased (hubs, which dominate total cost in
        // the paper's all-vertices workload).
        let edges: Vec<(u32, u32)> = g.edges().collect();
        for i in 0..EGO_SAMPLES {
            let center = if i % 2 == 0 {
                rng.below(g.n()) as u32
            } else {
                let (a, b) = edges[rng.below(edges.len())];
                if rng.chance(0.5) { a } else { b }
            };
            let verts = g.ego_vertices(center, 1);
            let (ego, _) = g.induced_on(&verts);
            ego_n_tot += ego.n();
            let f = Filtration::degree_superlevel(&ego);
            // raw: generic PH pipeline on the ego net
            let (_, t_raw) = Timer::time(|| pd0_generic(&ego, &f));
            // pruned: ALL PrunIT steps counted (find+remove dominated,
            // induced graph, then PD_0), as in the paper
            let (_, t_pru) = Timer::time(|| {
                let r = prunit(&ego, &f).unwrap();
                pd0_generic(&r.graph, &r.filtration)
            });
            t_raw_tot += t_raw;
            t_pru_tot += t_pru;
            reds.push(100.0 * (t_raw - t_pru) / t_raw.max(1e-12));
        }
        reds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = |q: f64| reds[((reds.len() - 1) as f64 * q) as usize];
        t.row(&[
            recipe.name.to_string(),
            EGO_SAMPLES.to_string(),
            format!("{:.0}", ego_n_tot as f64 / EGO_SAMPLES as f64),
            format!("{:.3}", 1e3 * t_raw_tot / EGO_SAMPLES as f64),
            format!("{:.3}", 1e3 * t_pru_tot / EGO_SAMPLES as f64),
            format!("{:.1}", 100.0 * (t_raw_tot - t_pru_tot) / t_raw_tot.max(1e-12)),
            format!("{:.1}", p(0.25)),
            format!("{:.1}", p(0.75)),
        ]);
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper reference: OGB-ARXIV avg ≈ 37%, OGB-MAG avg ≈ 23%, tail to 75%.");
    println!("note: at ego sizes of tens of vertices the PD_0 union-find is so fast");
    println!("that gains hinge on the dominated fraction — shape, not magnitude.");
}
