//! **E15 — Appendix D.2 conjecture**: for k ≥ 2 there exist
//! `0 < α_k < β_k < 1` such that `CC(G) < α_k` or `CC(G) > β_k` implies
//! `PD_k(G) = ∅` with high probability. We evaluate the band (α₂, β₂) =
//! (0.15, 0.75) as a *skip predictor* for β₂ computations across the
//! graph datasets, and measure the early-stopped CC approximation the
//! appendix proposes as the cheap gate.

use coral_prunit::datasets;
use coral_prunit::graph::clustering;
use coral_prunit::homology::betti_numbers;
use coral_prunit::kcore::kcore_subgraph;
use coral_prunit::util::{Table, Timer};

const SEED: u64 = 42;
const ALPHA2: f64 = 0.15;
const BETA2: f64 = 0.75;

fn main() {
    let mut t = Table::new(
        "Appendix D.2 — CC-band conjecture as a β₂ skip predictor (α=0.15, β=0.75)",
        &[
            "dataset", "graphs", "skip_predicted", "false_skips", "cc_exact_ms", "cc_approx_ms",
            "approx_err",
        ],
    );
    let mut total_skips = 0usize;
    let mut total_false = 0usize;
    for recipe in datasets::kernel_datasets() {
        let graphs = recipe.make_all(SEED);
        let mut skips = 0usize;
        let mut false_skips = 0usize;
        let (mut t_exact, mut t_approx, mut err_acc) = (0.0f64, 0.0f64, 0.0f64);
        for g in &graphs {
            let (cc, secs_e) = Timer::time(|| clustering::average(g));
            let ((cc_a, _), secs_a) = Timer::time(|| clustering::approximate_average(g, 0.02, 7));
            t_exact += secs_e;
            t_approx += secs_a;
            err_acc += (cc - cc_a).abs();
            let predicted_trivial = clustering::conjecture_predicts_trivial(cc, ALPHA2, BETA2);
            if predicted_trivial {
                skips += 1;
                // ground truth via the CoralTDA shortcut (β₂ in the 3-core)
                let (core3, _) = kcore_subgraph(g, 3);
                let b2 = if core3.n() == 0 || core3.n() > 400 {
                    0
                } else {
                    betti_numbers(&core3, 2)[2]
                };
                if b2 > 0 {
                    false_skips += 1;
                }
            }
        }
        total_skips += skips;
        total_false += false_skips;
        t.row(&[
            recipe.name.to_string(),
            graphs.len().to_string(),
            skips.to_string(),
            false_skips.to_string(),
            format!("{:.2}", 1e3 * t_exact / graphs.len() as f64),
            format!("{:.2}", 1e3 * t_approx / graphs.len() as f64),
            format!("{:.3}", err_acc / graphs.len() as f64),
        ]);
    }
    t.emit(Some("bench_results.tsv"));
    println!(
        "conjecture precision: {total_false} false skips out of {total_skips} predicted-trivial \
         graphs ({:.1}% safe)",
        100.0 * (1.0 - total_false as f64 / total_skips.max(1) as f64)
    );
    println!("paper shape check: CC outside the band ⇒ trivial PD_2 with high prob.");
}
