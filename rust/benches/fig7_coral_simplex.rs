//! **E9 — Figure 7**: CoralTDA clique/simplex-count reduction. For the
//! target dimension k the PH computation consumes cliques up to size
//! k + 2 ((k+1)-simplices kill k-classes); we report the reduction in that
//! total clique count between `G` and `G^{k+1}`.

use coral_prunit::complex::clique::count_cliques;
use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::reduce::coral_reduce;
use coral_prunit::util::table::reduction_pct;
use coral_prunit::util::Table;

const SEED: u64 = 42;

fn main() {
    let mut t = Table::new(
        "Figure 7 — CoralTDA clique-count reduction % (avg over instances)",
        &["dataset", "k=1", "k=2", "k=3"],
    );
    let recipes: Vec<_> = datasets::kernel_datasets()
        .into_iter()
        .chain(datasets::node_datasets())
        .collect();
    for recipe in recipes {
        let graphs = recipe.make_all(SEED);
        let mut row = vec![recipe.name.to_string()];
        for k in 1..=3usize {
            let mut acc = 0.0;
            for g in &graphs {
                let f = Filtration::degree(g);
                let before: usize = count_cliques(g, k + 2).iter().sum();
                let r = coral_reduce(g, &f, k).unwrap();
                let after: usize = count_cliques(&r.graph, k + 2).iter().sum();
                acc += reduction_pct(before, after);
            }
            row.push(format!("{:.1}", acc / graphs.len() as f64));
        }
        t.row(&row);
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: simplex reduction resembles Figure 4 but amplified,");
    println!("since peeling low-core vertices removes super-linearly many cliques.");
}
