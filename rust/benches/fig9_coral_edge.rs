//! **E11 — Figure 9**: CoralTDA edge reduction
//! `100·(|E| − |E^k|)/|E|` on the same datasets as Figure 4.

use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::reduce::coral_reduce;
use coral_prunit::util::table::reduction_pct;
use coral_prunit::util::Table;

const SEED: u64 = 42;

fn main() {
    let mut t = Table::new(
        "Figure 9 — CoralTDA edge reduction % (avg over instances)",
        &["dataset", "k=1", "k=2", "k=3", "k=4", "k=5"],
    );
    let recipes: Vec<_> = datasets::kernel_datasets()
        .into_iter()
        .chain(datasets::node_datasets())
        .collect();
    for recipe in recipes {
        let graphs = recipe.make_all(SEED);
        let mut row = vec![recipe.name.to_string()];
        for k in 1..=5usize {
            let mut acc = 0.0;
            for g in &graphs {
                let f = Filtration::degree(g);
                let r = coral_reduce(g, &f, k).unwrap();
                acc += reduction_pct(g.m(), r.graph.m());
            }
            row.push(format!("{:.1}", acc / graphs.len() as f64));
        }
        t.row(&row);
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: edge reduction tracks Figure 4's vertex reduction.");
}
