//! **E4 — Table 1**: PrunIT vertex and edge reductions on the 11 large
//! networks (synthetic stand-ins, scaled ~5–20×; see DESIGN.md §4). The
//! paper's columns are printed alongside for direct shape comparison:
//! who reduces most (emailEuAll ≈ 95%), who least (soc-Epinions1 /
//! p2pGnutella edges ≈ 14–20%), average vertex reduction ≈ 62%.

use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::prune::prunit;
use coral_prunit::util::table::reduction_pct;
use coral_prunit::util::{Table, Timer};

const SEED: u64 = 42;

/// Paper Table 1 reference values: (dataset, |V|, V-red %, |E|, E-red %).
const PAPER: [(&str, usize, f64, usize, f64); 11] = [
    ("com-youtube", 1_134_890, 59.0, 2_987_624, 25.0),
    ("com-amazon", 334_863, 37.0, 925_872, 40.0),
    ("com-dblp", 317_080, 72.0, 1_049_866, 65.0),
    ("web-Stanford", 281_903, 67.0, 1_992_636, 76.0),
    ("emailEuAll", 265_214, 95.0, 364_481, 94.0),
    ("soc-Epinions1", 75_879, 57.0, 405_740, 14.0),
    ("p2pGnutella31", 62_586, 46.0, 147_892, 20.0),
    ("Brightkite_edges", 58_228, 48.0, 214_078, 21.0),
    ("Email-Enron", 36_692, 76.0, 183_831, 38.0),
    ("CA-CondMat", 23_133, 69.0, 93_439, 65.0),
    ("oregon1_010526", 11_174, 62.0, 23_409, 48.0),
];

fn main() {
    let mut t = Table::new(
        "Table 1 — PrunIT reductions on large networks (ours vs paper)",
        &[
            "dataset", "|V|", "V_red_%", "paper_V%", "|E|", "E_red_%", "paper_E%", "secs",
        ],
    );
    let mut v_red_sum = 0.0;
    for recipe in datasets::large_networks() {
        let g = recipe.make(SEED, 0);
        let f = Filtration::degree_superlevel(&g);
        let (r, secs) = Timer::time(|| prunit(&g, &f).unwrap());
        let v_red = reduction_pct(g.n(), r.graph.n());
        let e_red = reduction_pct(g.m(), r.graph.m());
        v_red_sum += v_red;
        let paper = PAPER.iter().find(|p| p.0 == recipe.name).unwrap();
        t.row(&[
            recipe.name.to_string(),
            g.n().to_string(),
            format!("{v_red:.1}"),
            format!("{:.0}", paper.2),
            g.m().to_string(),
            format!("{e_red:.1}"),
            format!("{:.0}", paper.4),
            format!("{secs:.3}"),
        ]);
    }
    t.emit(Some("bench_results.tsv"));
    println!(
        "average vertex reduction: {:.1}% (paper: ≈62%)",
        v_red_sum / PAPER.len() as f64
    );
    println!("shape check: emailEuAll highest; p2p/Epinions lowest edge reduction.");
}
