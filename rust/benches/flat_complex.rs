//! **§Perf — columnar FlatComplex vs legacy AoS layout** (the tentpole
//! measurement for the flat-complex refactor): construction + reduction
//! wall-time for both layouts on ER(n=2000, p=0.01) and BA(n=2000, m=3),
//! degree-superlevel filtration, max_dim = 2 (the PD_1 workload).
//!
//! Columns:
//! * `build`  — complex construction. The flat build *includes* boundary
//!   resolution; the legacy build does not (its HashMap boundary pass is
//!   charged to `pd`), so `build` understates the legacy total — the
//!   honest comparison is `total`.
//! * `pd`     — everything from the built complex to diagrams: for the
//!   legacy engine `BoundaryMatrix::build` (HashMap face lookups) + the
//!   cloning reduction; for the flat engine the clone-free reduction
//!   straight off the boundary CSR.
//! * `total`  — build + pd medians.
//!
//! The two engines' diagrams are asserted equal before timing, so every
//! row measures the same answer. Results append to `bench_results.tsv`.

use coral_prunit::bench::{bench_auto, sink};
use coral_prunit::complex::{CliqueComplex, Filtration, FlatComplex};
use coral_prunit::graph::gen;
use coral_prunit::homology::legacy;
use coral_prunit::homology::reduction::{diagrams_of_complex, Algorithm};
use coral_prunit::util::Table;

const MAX_DIM: usize = 2; // PD_1 workload
const MAX_K: usize = 1;

fn main() {
    let mut t = Table::new(
        "FlatComplex vs legacy AoS — construction + reduction (PD_1, degree-superlevel)",
        &["graph", "layout", "simplices", "build", "pd", "total_ms"],
    );

    let cases = [
        ("ER(2000,0.01)", gen::erdos_renyi(2000, 0.01, 42)),
        ("BA(2000,3)", gen::barabasi_albert(2000, 3, 42)),
    ];

    for (name, g) in cases {
        let f = Filtration::degree_superlevel(&g);

        // correctness gate: both engines must produce identical diagrams
        let legacy_c = CliqueComplex::build(&g, &f, MAX_DIM);
        let flat_c = FlatComplex::build(&g, &f, MAX_DIM);
        let pd_legacy = legacy::diagrams_of_complex(&legacy_c, MAX_K, Algorithm::Twist)
            .expect("clique complex is face-closed");
        let pd_flat = diagrams_of_complex(&flat_c, MAX_K, Algorithm::Twist);
        for k in 0..=MAX_K {
            assert!(
                pd_legacy[k].same_as(&pd_flat[k], 0.0),
                "{name}: engines disagree on PD_{k}"
            );
        }

        // legacy layout: AoS build, then HashMap matrix + cloning reduce
        let m_build = bench_auto(|| sink(CliqueComplex::build(&g, &f, MAX_DIM).len()));
        let m_pd = bench_auto(|| {
            sink(
                legacy::diagrams_of_complex(&legacy_c, MAX_K, Algorithm::Twist)
                    .expect("clique complex is face-closed")
                    .len(),
            )
        });
        t.row(&[
            name.into(),
            "legacy-aos".into(),
            legacy_c.len().to_string(),
            m_build.fmt_ms(),
            m_pd.fmt_ms(),
            format!("{:.2}", m_build.median_ms() + m_pd.median_ms()),
        ]);

        // flat layout: columnar build (boundary included), clone-free reduce
        let m_build = bench_auto(|| sink(FlatComplex::build(&g, &f, MAX_DIM).len()));
        let m_pd = bench_auto(|| sink(diagrams_of_complex(&flat_c, MAX_K, Algorithm::Twist).len()));
        t.row(&[
            name.into(),
            "flat-columnar".into(),
            flat_c.len().to_string(),
            m_build.fmt_ms(),
            m_pd.fmt_ms(),
            format!("{:.2}", m_build.median_ms() + m_pd.median_ms()),
        ]);
    }

    t.emit(Some("bench_results.tsv"));
    println!("layout check: identical diagrams from both engines on every graph ✓");
}
