//! **E5 — Figure 6**: vertex reduction after applying PrunIT *then*
//! CoralTDA on the 11 large networks, for core orders 2..6 (the paper
//! plots cores 2 and 3 averaging ≈78%, with emailEuAll the outlier).

use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::kcore::kcore_subgraph;
use coral_prunit::prune::prunit;
use coral_prunit::util::table::reduction_pct;
use coral_prunit::util::Table;

const SEED: u64 = 42;
const CORES: [usize; 5] = [2, 3, 4, 5, 6];

fn main() {
    let mut t = Table::new(
        "Figure 6 — PrunIT + CoralTDA vertex reduction % on large networks",
        &["dataset", "core=2", "core=3", "core=4", "core=5", "core=6"],
    );
    let mut sums = [0.0f64; CORES.len()];
    let mut count = 0usize;
    for recipe in datasets::large_networks() {
        let g = recipe.make(SEED, 0);
        let f = Filtration::degree_superlevel(&g);
        let pruned = prunit(&g, &f).unwrap();
        let mut row = vec![recipe.name.to_string()];
        for (i, &c) in CORES.iter().enumerate() {
            let (core, _) = kcore_subgraph(&pruned.graph, c);
            let red = reduction_pct(g.n(), core.n());
            sums[i] += red;
            row.push(format!("{red:.1}"));
        }
        count += 1;
        t.row(&row);
    }
    let mut avg_row = vec!["AVERAGE".to_string()];
    for s in sums {
        avg_row.push(format!("{:.1}", s / count as f64));
    }
    t.row(&avg_row);
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: cores 2–3 already average ≈78% combined reduction;");
    println!("emailEuAll is the low outlier at cores 2–3 (its fringe IS the graph).");
}
