//! **E10 — Figure 8**: CoralTDA *time* reduction: wall time of
//! PD_k(G) vs [core decomposition + PD_k(G^{k+1})], averaged over
//! instances. The paper's qualitative result: big positive gains on
//! sparse kernel datasets, bounded gains on OHSU (≤25%, small graphs but
//! high coreness), and NEGATIVE gains on FACEBOOK/TWITTER (nothing peels,
//! so the decomposition is pure overhead).

use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::homology::persistence_diagrams;
use coral_prunit::reduce::coral_reduce;
use coral_prunit::util::{Table, Timer};

const SEED: u64 = 42;

/// Dense ego datasets are capped to k=1 (their higher clique tiers are
/// enormous and identical before/after — the paper's point exactly).
fn max_k_for(name: &str) -> usize {
    match name {
        "TWITTER" | "FACEBOOK" | "FIRSTMM" => 1,
        _ => 2,
    }
}

fn main() {
    let mut t = Table::new(
        "Figure 8 — CoralTDA time reduction % (avg; negative = overhead)",
        &["dataset", "k", "t_orig_ms", "t_coral_ms", "time_red_%"],
    );
    let recipes: Vec<_> = datasets::kernel_datasets()
        .into_iter()
        .chain(datasets::node_datasets())
        .collect();
    for recipe in recipes {
        let graphs = recipe.make_all(SEED);
        for k in 1..=max_k_for(recipe.name) {
            let (mut t_orig, mut t_red) = (0.0f64, 0.0f64);
            for g in &graphs {
                let f = Filtration::degree(g);
                let (_, secs_orig) = Timer::time(|| persistence_diagrams(g, &f, k));
                let (_, secs_red) = Timer::time(|| {
                    let r = coral_reduce(g, &f, k).unwrap();
                    persistence_diagrams(&r.graph, &r.filtration, k)
                });
                t_orig += secs_orig;
                t_red += secs_red;
            }
            let n = graphs.len() as f64;
            t.row(&[
                recipe.name.to_string(),
                k.to_string(),
                format!("{:.2}", 1e3 * t_orig / n),
                format!("{:.2}", 1e3 * t_red / n),
                format!("{:.1}", 100.0 * (t_orig - t_red) / t_orig.max(1e-12)),
            ]);
        }
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: negative/≈0 gains expected on TWITTER/FACEBOOK");
    println!("(high cores peel nothing); large gains on tree-like kernel sets.");
}
