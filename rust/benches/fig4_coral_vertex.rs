//! **E1 — Figure 4**: CoralTDA vertex reduction on graph- and
//! node-classification datasets, k = 1..5 (higher is better). Reduction
//! values are averages over a dataset's graph instances; CORA/CITESEER are
//! single graphs. The paper's headline shapes: FACEBOOK/TWITTER stay
//! ≈20% for k > 4 (strong cores); most kernel datasets hit 100% by
//! k = 4..5 (trivial higher PDs).

use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::reduce::coral_reduce;
use coral_prunit::util::table::reduction_pct;
use coral_prunit::util::Table;

const SEED: u64 = 42;
const KS: [usize; 5] = [1, 2, 3, 4, 5];

fn main() {
    let mut t = Table::new(
        "Figure 4 — CoralTDA vertex reduction % (avg over instances)",
        &["dataset", "k=1", "k=2", "k=3", "k=4", "k=5"],
    );
    let recipes: Vec<_> = datasets::kernel_datasets()
        .into_iter()
        .chain(datasets::node_datasets())
        .collect();
    for recipe in recipes {
        let graphs = recipe.make_all(SEED);
        let mut row = vec![recipe.name.to_string()];
        for &k in &KS {
            let mut acc = 0.0;
            for g in &graphs {
                let f = Filtration::degree(g);
                let r = coral_reduce(g, &f, k).unwrap();
                acc += reduction_pct(g.n(), r.graph.n());
            }
            row.push(format!("{:.1}", acc / graphs.len() as f64));
        }
        t.row(&row);
    }
    t.emit(Some("bench_results.tsv"));
    println!(
        "paper shape check: dense ego sets (TWITTER/FACEBOOK) should stay low \
         (strong cores); sparse kernel sets should approach 100 by k=4..5."
    );
}
