//! **E6 — Table 3 (Remark 13)**: PrunIT vs Strong Collapse on the
//! Email-Enron stand-in with degree filtering, threshold step sizes
//! δ ∈ {4, 12}. PrunIT detects dominated vertices ONCE on the graph;
//! Strong Collapse must collapse every flag complex in the filtration
//! sequence. Reported: dominated-vertex-elimination time and total
//! simplex count feeding PH (paper: PrunIT ≈5× faster, ≈40% fewer
//! simplices).

use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::prune::strong_collapse::{prunit_sweep, strong_collapse_sweep};
use coral_prunit::util::Table;

const SEED: u64 = 42;
const MAX_CLIQUE: usize = 3; // simplices up to triangles, as for PD_1

/// Paper Table 3: (step, prunit secs, SC secs, prunit Msimp, SC Msimp).
const PAPER: [(f64, f64, f64, f64, f64); 2] =
    [(4.0, 1412.0, 7014.0, 270.2, 465.2), (12.0, 513.0, 2520.0, 90.7, 155.8)];

fn main() {
    let recipe = datasets::find("Email-Enron").unwrap();
    let g = recipe.make(SEED, 0);
    let f = Filtration::degree_superlevel(&g);
    println!(
        "Email-Enron stand-in: n={} m={} (paper: 36,692 / 183,831; {}x scale)",
        g.n(),
        g.m(),
        recipe.scale_down
    );
    let mut t = Table::new(
        "Table 3 — PrunIT vs Strong Collapse (Email-Enron stand-in)",
        &[
            "step", "prunit_s", "sc_s", "speedup", "paper_speedup", "prunit_simplices",
            "sc_simplices", "simp_ratio", "paper_ratio",
        ],
    );
    for (step, p_s, sc_s, p_m, sc_m) in PAPER {
        let pi = prunit_sweep(&g, &f, step, MAX_CLIQUE);
        let sc = strong_collapse_sweep(&g, &f, step, MAX_CLIQUE);
        t.row(&[
            format!("{step}"),
            format!("{:.3}", pi.collapse_secs),
            format!("{:.3}", sc.collapse_secs),
            format!("{:.1}x", sc.collapse_secs / pi.collapse_secs.max(1e-9)),
            format!("{:.1}x", sc_s / p_s),
            pi.simplex_count.to_string(),
            sc.simplex_count.to_string(),
            format!("{:.2}", sc.simplex_count as f64 / pi.simplex_count.max(1) as f64),
            format!("{:.2}", sc_m / p_m),
        ]);
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: PrunIT ≈5x faster dominated-vertex elimination at");
    println!("both step sizes, and the PH input carries ≈1.7x fewer simplices than");
    println!("under Strong Collapse (paper Table 3 ratios printed alongside).");
}
