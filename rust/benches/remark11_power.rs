//! **E13 — Remark 11**: CoralTDA does NOT extend to power filtrations.
//! Cycles Cₙ have empty 3-cores, yet (Adamaszek) clique complexes of
//! cycle powers realise arbitrarily high-dimensional topology — for
//! n ≥ 2k + 3 the power filtration has nontrivial PD_k. If CoralTDA held
//! for power filtrations, PD_k(Cₙ) would be trivial for all k ≥ 2.

use coral_prunit::complex::power::power_complex;
use coral_prunit::graph::gen;
use coral_prunit::homology::reduction::{diagrams_of_complex, Algorithm};
use coral_prunit::util::Table;

fn main() {
    let mut t = Table::new(
        "Remark 11 — cycles: empty 3-core vs nontrivial power-filtration PDs",
        &["n", "3-core", "PD1 points", "PD2 points", "coral would predict"],
    );
    for n in [7usize, 9, 11, 13] {
        let g = gen::cycle(n);
        let (core3, _) = coral_prunit::kcore::kcore_subgraph(&g, 3);
        let max_power = n / 2;
        let c = power_complex(&g, 3, max_power);
        let pds = diagrams_of_complex(&c, 2, Algorithm::Twist);
        t.row(&[
            n.to_string(),
            format!("{} vertices", core3.n()),
            pds[1].points().len().to_string(),
            pds[2].points().len().to_string(),
            "trivial PD_2 (WRONG)".to_string(),
        ]);
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: 3-cores are empty, yet power filtrations produce");
    println!("nontrivial higher diagrams (C7 already has a PD_2 point: its square's");
    println!("clique complex is a 2-sphere by Adamaszek's classification).");
}
