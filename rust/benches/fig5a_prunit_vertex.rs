//! **E2 — Figure 5a**: PrunIT vertex reduction in the superlevel
//! filtration (degree function — Remark 8 makes every dominated vertex
//! admissible). Paper shapes: FIRSTMM and SYNNEW reduce < 10% (strong
//! cores: point-cloud proximity / synthetic ER); the other datasets
//! reduce ≥ 35%.

use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::prune::prunit;
use coral_prunit::util::table::reduction_pct;
use coral_prunit::util::Table;

const SEED: u64 = 42;

fn main() {
    let mut t = Table::new(
        "Figure 5a — PrunIT vertex reduction % (superlevel, degree)",
        &["dataset", "avg_n", "avg_removed", "vertex_red_%"],
    );
    for recipe in datasets::kernel_datasets() {
        let graphs = recipe.make_all(SEED);
        let mut acc = 0.0;
        let mut n_acc = 0usize;
        let mut rem_acc = 0usize;
        for g in &graphs {
            let f = Filtration::degree_superlevel(g);
            let r = prunit(g, &f).unwrap();
            acc += reduction_pct(g.n(), r.graph.n());
            n_acc += g.n();
            rem_acc += r.removed;
        }
        let count = graphs.len();
        t.row(&[
            recipe.name.to_string(),
            format!("{:.0}", n_acc as f64 / count as f64),
            format!("{:.0}", rem_acc as f64 / count as f64),
            format!("{:.1}", acc / count as f64),
        ]);
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: FIRSTMM & SYNNEW < 10-20%; most others ≥ 35%.");
}
