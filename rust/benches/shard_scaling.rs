//! **Shard scaling**: component-sharded PH vs the monolithic engine.
//!
//! The boundary-matrix reduction is cubic in total simplices, so a graph
//! of `c` equal components costs the monolith `O((c·n)³)` but the sharded
//! pipeline `c·O(n³)` — before parallelism even starts. Two workloads:
//!
//! * multi-component Erdős–Rényi unions (c ∈ {2, 4, 8} pieces), and
//! * post-coral graphs: decorated-cycle networks whose 2-core shatters
//!   into many small components (the regime CoralTDA produces).
//!
//! Reported: monolithic wall-time vs sharded wall-time at 1/2/4 workers.

use coral_prunit::bench::{bench, sink};
use coral_prunit::complex::Filtration;
use coral_prunit::graph::decompose::{decompose_filtered, disjoint_union};
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::{persistence_diagrams, persistence_diagrams_sharded};
use coral_prunit::reduce::{coral_reduce, pd_sharded, pd_with_reduction, Reduction};
use coral_prunit::util::Table;

const SEED: u64 = 42;
const K: usize = 1;

fn er_union(pieces: usize, n: usize, p: f64) -> Graph {
    let parts: Vec<Graph> = (0..pieces)
        .map(|i| gen::erdos_renyi(n, p, SEED ^ (i as u64 + 1)))
        .collect();
    disjoint_union(&parts)
}

/// A network whose 2-core shatters: `pieces` cycles, each decorated with
/// pendant trees that coral peels away.
fn shattering_graph(pieces: usize) -> Graph {
    let parts: Vec<Graph> = (0..pieces)
        .map(|i| {
            let cyc = gen::cycle(24 + i);
            let n = cyc.n() as u32;
            let mut edges: Vec<(u32, u32)> = cyc.edges().collect();
            // a pendant path of 6 vertices off vertex 0
            for j in 0..6u32 {
                let a = if j == 0 { 0 } else { n + j - 1 };
                edges.push((a, n + j));
            }
            Graph::from_edges(n as usize + 6, &edges)
        })
        .collect();
    disjoint_union(&parts)
}

fn main() {
    let mut t = Table::new(
        "shard scaling — monolithic vs component-sharded PD_0..PD_1 wall-time",
        &[
            "workload", "n", "m", "shards", "mono_ms", "shard_w1_ms", "shard_w2_ms",
            "shard_w4_ms", "speedup_w2",
        ],
    );

    // --- multi-component ER unions -------------------------------------
    for pieces in [2usize, 4, 8] {
        let g = er_union(pieces, 70, 0.12);
        let f = Filtration::degree_superlevel(&g);
        let shards = decompose_filtered(&g, &f).len();
        let mono = bench(1, 3, || sink(persistence_diagrams(&g, &f, K).len()));
        let w1 = bench(1, 3, || {
            sink(persistence_diagrams_sharded(&g, &f, K, 1).len())
        });
        let w2 = bench(1, 3, || {
            sink(persistence_diagrams_sharded(&g, &f, K, 2).len())
        });
        let w4 = bench(1, 3, || {
            sink(persistence_diagrams_sharded(&g, &f, K, 4).len())
        });
        t.row(&[
            format!("ER x{pieces}"),
            g.n().to_string(),
            g.m().to_string(),
            shards.to_string(),
            format!("{:.2}", mono.median_ms()),
            format!("{:.2}", w1.median_ms()),
            format!("{:.2}", w2.median_ms()),
            format!("{:.2}", w4.median_ms()),
            format!("{:.2}x", mono.median_secs / w2.median_secs.max(1e-12)),
        ]);
    }

    // --- post-coral shattering -----------------------------------------
    for pieces in [4usize, 12] {
        let g = shattering_graph(pieces);
        let f = Filtration::degree_superlevel(&g);
        // monolithic: coral-reduce then one big PH call
        let mono = bench(1, 3, || {
            let r = coral_reduce(&g, &f, K).unwrap();
            sink(persistence_diagrams(&r.graph, &r.filtration, K).len())
        });
        // sharded: the pd_sharded entry point (reduce + split + parallel PH)
        let time_sharded = |workers: usize| {
            bench(1, 3, || {
                sink(pd_sharded(&g, &f, K, Reduction::Coral, workers).unwrap().0.len())
            })
        };
        let w1 = time_sharded(1);
        let w2 = time_sharded(2);
        let w4 = time_sharded(4);
        let (_, report) = pd_sharded(&g, &f, K, Reduction::Coral, 2).unwrap();
        t.row(&[
            format!("coral-shatter x{pieces}"),
            g.n().to_string(),
            g.m().to_string(),
            report.shard_count().to_string(),
            format!("{:.2}", mono.median_ms()),
            format!("{:.2}", w1.median_ms()),
            format!("{:.2}", w2.median_ms()),
            format!("{:.2}", w4.median_ms()),
            format!("{:.2}x", mono.median_secs / w2.median_secs.max(1e-12)),
        ]);
    }

    t.emit(Some("bench_results.tsv"));

    // Exactness spot-check alongside the timing claim.
    let g = er_union(4, 70, 0.12);
    let f = Filtration::degree_superlevel(&g);
    let (mono, _) = pd_with_reduction(&g, &f, K, Reduction::None).unwrap();
    let sharded = persistence_diagrams_sharded(&g, &f, K, 2);
    for k in 0..=K {
        assert!(
            mono[k].same_as(&sharded[k], 1e-12),
            "sharded PD_{k} diverged from monolithic"
        );
    }
    println!("exactness verified: sharded == monolithic on the ER x4 union ✓");
    println!("expected shape: sharded beats monolithic already at 1 worker (Σnᵢ³ < (Σnᵢ)³),");
    println!("and scales further with workers while the largest shard bounds the critical path.");
}
