//! **E7 — Figure 2**: clustering coefficient vs number of higher
//! topological features (β₁, β₂) on FACEBOOK/TWITTER-like ego networks.
//! Each data point is one graph instance; the paper observes hundreds of
//! higher features in these dense social graphs.

use coral_prunit::datasets;
use coral_prunit::graph::clustering;
use coral_prunit::homology::betti_numbers;
use coral_prunit::util::Table;

const SEED: u64 = 42;

fn main() {
    let mut t = Table::new(
        "Figure 2 — clustering coefficient vs topological features",
        &["dataset", "instance", "n", "m", "CC", "beta1", "beta2"],
    );
    for name in ["TWITTER", "FACEBOOK"] {
        let recipe = datasets::find(name).unwrap();
        for (i, g) in recipe.make_all(SEED).into_iter().enumerate() {
            let cc = clustering::average(&g);
            let betti = betti_numbers(&g, 2);
            t.row(&[
                name.to_string(),
                i.to_string(),
                g.n().to_string(),
                g.m().to_string(),
                format!("{cc:.3}"),
                betti[1].to_string(),
                betti[2].to_string(),
            ]);
        }
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: dense ego networks carry nontrivial β1 and β2 at");
    println!("clustering coefficients in the middle band (≈0.4–0.7) — neither the");
    println!("sparse (CC→0) nor the near-clique (CC→1) regime produces features.");
}
