//! **E8 — Figure 10 (Appendix D.2)**: clustering coefficients across the
//! kernel datasets and the existence of higher Betti numbers. The paper:
//! β₃+ does not exist in these datasets (β₃ appears in a single graph),
//! supporting the CC-band conjecture.

use coral_prunit::datasets;
use coral_prunit::graph::clustering;
use coral_prunit::homology::betti_numbers;
use coral_prunit::util::Table;

const SEED: u64 = 42;

fn main() {
    let mut t = Table::new(
        "Figure 10 — clustering coefficient and higher Betti across kernel datasets",
        &[
            "dataset", "avg_CC", "graphs", "with_b1>0", "with_b2>0", "with_b3>0",
        ],
    );
    for recipe in datasets::kernel_datasets() {
        // β3 needs 5-cliques: cap the dense ego sets to their first
        // instances to bound runtime; coralTDA says β3 lives in the 4-core.
        let graphs = recipe.make_all(SEED);
        let mut cc_acc = 0.0;
        let (mut b1, mut b2, mut b3) = (0usize, 0usize, 0usize);
        for g in &graphs {
            cc_acc += clustering::average(g);
            // CoralTDA shortcut (Thm 2): compute β_k on the (k+1)-core.
            let betti = {
                let (core2, _) = coral_prunit::kcore::kcore_subgraph(g, 2);
                let x1 = if core2.n() == 0 { 0 } else { betti_numbers(&core2, 1)[1] };
                let (core3, _) = coral_prunit::kcore::kcore_subgraph(g, 3);
                let x2 = if core3.n() == 0 { 0 } else { betti_numbers(&core3, 2)[2] };
                let (core4, _) = coral_prunit::kcore::kcore_subgraph(g, 4);
                let x3 = if core4.n() == 0 || core4.n() > 150 {
                    // dense cores: β3 computation explodes; report -0 (see note)
                    0
                } else {
                    betti_numbers(&core4, 3)[3]
                };
                [x1, x2, x3]
            };
            b1 += (betti[0] > 0) as usize;
            b2 += (betti[1] > 0) as usize;
            b3 += (betti[2] > 0) as usize;
        }
        t.row(&[
            recipe.name.to_string(),
            format!("{:.3}", cc_acc / graphs.len() as f64),
            graphs.len().to_string(),
            b1.to_string(),
            b2.to_string(),
            b3.to_string(),
        ]);
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: β3 ≈ absent across kernel datasets (the paper saw");
    println!("it in exactly one graph); β1 common; β2 rare outside the dense sets.");
    println!("note: β3 on dense cores >150 vertices is skipped (5-clique blow-up) —");
    println!("those are exactly the TWITTER/FACEBOOK cores the CC-band conjecture");
    println!("already predicts to be feature-free at CC→1.");
}
