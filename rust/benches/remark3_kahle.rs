//! **E12 — Remark 3 (Kahle threshold)**: in G(n, p), nontrivial H_k of
//! the clique complex needs average degree between n^{(k-1)/k} and
//! n^{k/(k+1)} — e.g. for k=2 and n=1000 an average degree of 31..100.
//! Real-life-like networks carry higher Betti at FAR lower average
//! degree. We sweep ER average degree at n=300 (Kahle window for k=2:
//! n^{1/2}=17.3 .. n^{2/3}=44.8) and compare against clustered social
//! graphs of the same size and much lower degree.

use coral_prunit::graph::{clustering, gen};
use coral_prunit::homology::betti_numbers;
use coral_prunit::kcore::kcore_subgraph;
use coral_prunit::util::Table;

const N: usize = 300;
const TRIALS: usize = 3;

fn beta2_via_core(g: &coral_prunit::graph::Graph) -> usize {
    // Thm 2: β2 lives in the 3-core.
    let (core, _) = kcore_subgraph(g, 3);
    if core.n() == 0 {
        return 0;
    }
    betti_numbers(&core, 2)[2]
}

fn main() {
    let mut t = Table::new(
        "Remark 3 — ER Kahle window vs real-like sparse graphs (n=300, k=2)",
        &["family", "avg_deg", "CC", "beta2>0 (of trials)", "avg_beta2"],
    );
    // ER sweep across the window (n^1/2 ≈ 17.3, n^2/3 ≈ 44.8)
    for avg_deg in [4.0, 10.0, 17.0, 25.0, 35.0, 45.0, 60.0] {
        let p = avg_deg / (N as f64 - 1.0);
        let (mut nonzero, mut total) = (0usize, 0usize);
        let mut cc_acc = 0.0;
        for trial in 0..TRIALS {
            let g = gen::erdos_renyi(N, p, 1000 + trial as u64);
            cc_acc += clustering::average(&g);
            let b2 = beta2_via_core(&g);
            nonzero += (b2 > 0) as usize;
            total += b2;
        }
        t.row(&[
            format!("ER p={p:.4}"),
            format!("{avg_deg:.0}"),
            format!("{:.3}", cc_acc / TRIALS as f64),
            format!("{nonzero}/{TRIALS}"),
            format!("{:.1}", total as f64 / TRIALS as f64),
        ]);
    }
    // Real-like: clustered social graphs at low average degree
    let families: [(&str, fn(u64) -> coral_prunit::graph::Graph); 3] = [
        ("PLC m=4 pt=0.9", |s| gen::powerlaw_cluster(N, 4, 0.9, s)),
        ("WS k=8 beta=0.1", |s| gen::watts_strogatz(N, 8, 0.1, s)),
        ("RGG r=0.12", |s| gen::random_geometric(N, 0.12, s)),
    ];
    for (name, make) in families {
        let (mut nonzero, mut total) = (0usize, 0usize);
        let mut cc_acc = 0.0;
        let mut deg_acc = 0.0;
        for trial in 0..TRIALS {
            let g = make(2000 + trial as u64);
            cc_acc += clustering::average(&g);
            deg_acc += 2.0 * g.m() as f64 / g.n() as f64;
            let b2 = beta2_via_core(&g);
            nonzero += (b2 > 0) as usize;
            total += b2;
        }
        t.row(&[
            name.to_string(),
            format!("{:.0}", deg_acc / TRIALS as f64),
            format!("{:.3}", cc_acc / TRIALS as f64),
            format!("{nonzero}/{TRIALS}"),
            format!("{:.1}", total as f64 / TRIALS as f64),
        ]);
    }
    t.emit(Some("bench_results.tsv"));
    println!("paper shape check: ER needs degree inside the Kahle window (≈17–45 at");
    println!("n=300) for β2 > 0; clustered graphs reach β2 > 0 at degree ≈8–14.");
}
