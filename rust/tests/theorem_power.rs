//! **Theorem 10** (PrunIT for power filtration) and **Remark 11**
//! (CoralTDA does *not* extend to power filtration — the cyclic-graph
//! counterexample via Adamaszek's classification).

use coral_prunit::complex::power::power_complex;
use coral_prunit::complex::Filtration;
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::reduction::{diagrams_of_complex, Algorithm};
use coral_prunit::homology::Diagram;
use coral_prunit::prune::dominates;
use coral_prunit::testutil::{forall, random_graph_case};

fn power_pds(g: &Graph, max_k: usize, max_power: usize) -> Vec<Diagram> {
    let c = power_complex(g, max_k + 1, max_power);
    diagrams_of_complex(&c, max_k, Algorithm::Twist)
}

/// Theorem 10: removing a dominated vertex preserves power-filtration
/// PD_k for k ≥ 1 on connected graphs.
#[test]
fn theorem10_dominated_removal_preserves_power_pds() {
    forall("power-theorem10", 40, 0x70, |rng| {
        let case = random_graph_case(rng, 12);
        let g = &case.graph;
        if !g.is_connected() || g.n() < 3 {
            return Ok(()); // theorem assumes connected
        }
        // find a dominated vertex (no f condition in Thm 10)
        let mut target = None;
        'outer: for u in 0..g.n() as u32 {
            for &v in g.neighbors(u) {
                if dominates(g, u, v) {
                    target = Some(u);
                    break 'outer;
                }
            }
        }
        let Some(u) = target else { return Ok(()) };
        let keep: Vec<bool> = (0..g.n() as u32).map(|v| v != u).collect();
        let (h, _) = g.induced(&keep);
        let max_power = 3;
        let before = power_pds(g, 2, max_power);
        let after = power_pds(&h, 2, max_power);
        for k in 1..=2 {
            if !before[k].same_as(&after[k], 1e-9) {
                return Err(format!(
                    "{}: power PD_{k} changed after removing dominated {u}: {} vs {}",
                    case.desc, before[k], after[k]
                ));
            }
        }
        Ok(())
    });
}

/// Remark 11 counterexample, concrete instance: C₇ has an empty 3-core,
/// yet its power filtration carries a nontrivial PD_1 point and C₉'s
/// square (power 2) already has interesting higher structure. If CoralTDA
/// were valid for power filtrations, PD_k(Cₙ) would be trivial for k ≥ 2
/// — verify the premise (empty 3-core) and the nontrivial power PDs.
#[test]
fn remark11_cycles_power_filtration_counterexample() {
    for n in [7usize, 9, 11] {
        let g = gen::cycle(n);
        // 3-core of any cycle is empty
        let (core3, _) = coral_prunit::kcore::kcore_subgraph(&g, 3);
        assert_eq!(core3.n(), 0, "C{n} must have empty 3-core");
        // power filtration has a 1-dimensional feature (the circle persists
        // until the power fills it)
        let pds = power_pds(&g, 1, (n - 1) / 2);
        assert!(
            !pds[1].is_trivial(),
            "C{n} power filtration should carry PD_1 points, got {}",
            pds[1]
        );
    }
}

/// Adamaszek (Rmk 11): clique complexes of cycle powers are spheres or
/// wedges — for C₅, power 2 gives K₅ (contractible complex via full
/// simplex); cross-check a few closed forms the power engine must hit.
#[test]
fn cycle_power_closed_forms() {
    // C4 at power 1: the square → β1 = 1; at power 2: K4 → contractible.
    let g = gen::cycle(4);
    let p1 = power_pds(&g, 1, 1);
    assert_eq!(p1[1].betti(), 1);
    let p2 = power_pds(&g, 1, 2);
    // the essential loop from power 1 must DIE at power 2 (diagonals fill)
    assert_eq!(p2[1].betti(), 0);
    let pts = p2[1].points();
    assert!(
        pts.iter().any(|&(b, d)| b == 1.0 && d == 2.0),
        "loop born at 1 should die at 2, got {:?}",
        pts
    );
}

/// PD_0 of the power filtration of a connected graph: everything merges
/// at power 1 (the paper notes dimension 0 is trivial for power
/// filtrations of connected graphs).
#[test]
fn power_pd0_trivial_for_connected() {
    forall("power-pd0", 20, 0xF0, |rng| {
        let case = random_graph_case(rng, 12);
        let g = &case.graph;
        if !g.is_connected() || g.n() < 2 {
            return Ok(());
        }
        let pds = power_pds(g, 0, 2);
        let pts = pds[0].points();
        // one essential class born at 0; all other components die at 1
        let essential = pts.iter().filter(|p| p.1.is_infinite()).count();
        if essential != 1 {
            return Err(format!("{}: {} essential components", case.desc, essential));
        }
        if pts.iter().any(|&(b, d)| d.is_finite() && (b, d) != (0.0, 1.0)) {
            return Err(format!("{}: finite PD_0 point not (0,1): {:?}", case.desc, pts));
        }
        Ok(())
    });
}
