//! Property suite for the component-sharded persistence pipeline:
//! sharded diagrams equal monolithic diagrams — exactly, in every
//! dimension k ≤ 2 — on random graphs with forced multiple components
//! (disjoint unions of ER / BA / cycle / star pieces plus isolates).

use coral_prunit::complex::Filtration;
use coral_prunit::graph::decompose::{decompose_filtered, disjoint_union};
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::{persistence_diagrams, persistence_diagrams_sharded};
use coral_prunit::reduce::{pd_sharded, pd_with_reduction, Reduction};
use coral_prunit::testutil::{forall, random_filtration};
use coral_prunit::util::Rng;

/// A random multi-component graph: 2–5 pieces from a family mix, with an
/// occasional batch of isolated vertices.
fn multi_component_graph(rng: &mut Rng) -> (Graph, String) {
    let pieces = rng.range(2, 5);
    let mut parts = Vec::new();
    let mut desc = String::new();
    for _ in 0..pieces {
        let n = rng.range(3, 14);
        let (part, tag) = match rng.below(4) {
            0 => (
                gen::erdos_renyi(n, 0.35, rng.next_u64()),
                format!("ER{n}"),
            ),
            1 => (
                gen::barabasi_albert(n, 2, rng.next_u64()),
                format!("BA{n}"),
            ),
            2 => (gen::cycle(n), format!("C{n}")),
            _ => (gen::star(n), format!("S{n}")),
        };
        parts.push(part);
        desc.push_str(&tag);
        desc.push('+');
    }
    if rng.chance(0.4) {
        let iso = rng.range(1, 4);
        parts.push(Graph::empty(iso));
        desc.push_str(&format!("iso{iso}"));
    }
    (disjoint_union(&parts), desc)
}

#[test]
fn sharded_equals_monolithic_all_dimensions() {
    forall("sharded-vs-monolithic", 30, 0x5AAD, |rng| {
        let (g, desc) = multi_component_graph(rng);
        let f = random_filtration(rng, &g);
        let mono = persistence_diagrams(&g, &f, 2);
        for workers in [1usize, 3] {
            let sharded = persistence_diagrams_sharded(&g, &f, 2, workers);
            for k in 0..=2 {
                if !mono[k].same_as(&sharded[k], 1e-12) {
                    return Err(format!(
                        "{desc} (workers={workers}): PD_{k} mismatch: {} vs {}",
                        mono[k], sharded[k]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_reduction_pipeline_equals_monolithic() {
    forall("pd-sharded-vs-pipeline", 25, 0x5AAE, |rng| {
        let (g, desc) = multi_component_graph(rng);
        let f = Filtration::degree_superlevel(&g);
        for which in [
            Reduction::None,
            Reduction::Prunit,
            Reduction::Combined,
            Reduction::FixedPoint,
        ] {
            let (mono, _) = pd_with_reduction(&g, &f, 1, which).map_err(|e| e.to_string())?;
            let (sharded, report) = pd_sharded(&g, &f, 1, which, 2).map_err(|e| e.to_string())?;
            for k in 0..=1 {
                // For Combined/Coral/FixedPoint only PD_k (k=1) is
                // guaranteed; for None/Prunit both dimensions must match.
                // Either way the sharded result must equal the monolithic
                // result on the SAME reduced graph — sharding itself is
                // always exact.
                if !mono[k].same_as(&sharded[k], 1e-12) {
                    return Err(format!(
                        "{desc} via {}: PD_{k} mismatch: {} vs {}",
                        which.name(),
                        mono[k],
                        sharded[k]
                    ));
                }
            }
            let census: usize = report.shard_sizes.iter().sum();
            if census != report.vertices_after {
                return Err(format!(
                    "{desc} via {}: shard census {census} != residue order {}",
                    which.name(),
                    report.vertices_after
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn shard_census_partitions_the_graph() {
    forall("shard-census", 25, 0x5AAF, |rng| {
        let (g, desc) = multi_component_graph(rng);
        let f = random_filtration(rng, &g);
        let shards = decompose_filtered(&g, &f);
        let n_sum: usize = shards.iter().map(|s| s.graph.n()).sum();
        let m_sum: usize = shards.iter().map(|s| s.graph.m()).sum();
        if n_sum != g.n() || m_sum != g.m() {
            return Err(format!(
                "{desc}: shard census n={n_sum}/{} m={m_sum}/{}",
                g.n(),
                g.m()
            ));
        }
        for s in &shards {
            if !s.graph.is_connected() {
                return Err(format!("{desc}: disconnected shard of order {}", s.graph.n()));
            }
            if s.filtration.len() != s.graph.n() {
                return Err(format!("{desc}: filtration/shard size mismatch"));
            }
            for (new, &old) in s.kept_old_ids.iter().enumerate() {
                if s.filtration.value(new as u32) != f.value(old) {
                    return Err(format!("{desc}: restricted f lost original values"));
                }
            }
        }
        Ok(())
    });
}
