//! Property suite for the PrunIT⇄CoralTDA **fixed-point alternation**
//! (`Reduction::FixedPoint`) on the zero-copy planner:
//!
//! * exactness — diagrams equal the `Reduction::None` baseline for every
//!   `j ≥ k` (each PrunIT stage preserves all PDs, each core stage
//!   preserves PD_j for j ≥ k, so any finite alternation does);
//! * dominance — never removes fewer vertices than `Reduction::Combined`
//!   (round 1 of the alternation IS Combined);
//! * termination — the round count is bounded by the number of vertices
//!   removed (every round but the last removes at least one vertex);
//! * differential — the in-place planner and the materializing reference
//!   pipeline produce the identical reduced instance.
//!
//! Graph families per the issue: seeded ER, BA, and cycles-with-tails.

use coral_prunit::complex::Filtration;
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::persistence_diagrams;
use coral_prunit::reduce::{
    combined_with, combined_with_materializing, combined_with_ws, Reduction, ReductionWorkspace,
};
use coral_prunit::util::Rng;

/// A cycle with a pendant path ("tail") — coral food with PD_1 content.
fn cycle_with_tail(cycle_n: usize, tail: usize) -> Graph {
    let c = cycle_n as u32;
    let mut edges: Vec<(u32, u32)> = (0..c).map(|i| (i, (i + 1) % c)).collect();
    for t in 0..tail as u32 {
        let a = if t == 0 { 0 } else { c + t - 1 };
        edges.push((a, c + t));
    }
    Graph::from_edges(cycle_n + tail, &edges)
}

/// The issue's seeded family mix.
fn family_graph(rng: &mut Rng) -> (Graph, String) {
    match rng.below(3) {
        0 => {
            let n = rng.range(6, 40);
            (
                gen::erdos_renyi(n, 0.25, rng.next_u64()),
                format!("ER({n},0.25)"),
            )
        }
        1 => {
            let n = rng.range(6, 40);
            (
                gen::barabasi_albert(n, 2, rng.next_u64()),
                format!("BA({n},2)"),
            )
        }
        _ => {
            let c = rng.range(4, 12);
            let t = rng.range(1, 6);
            (cycle_with_tail(c, t), format!("C{c}+tail{t}"))
        }
    }
}

#[test]
fn fixed_point_diagrams_equal_baseline_above_k() {
    let mut rng = Rng::new(0xF1DE);
    for trial in 0..40 {
        let (g, desc) = family_graph(&mut rng);
        let f = if rng.chance(0.5) {
            Filtration::degree_superlevel(&g)
        } else {
            Filtration::degree(&g)
        };
        let max_j = 2usize;
        let base = persistence_diagrams(&g, &f, max_j);
        for k in 1..=max_j {
            let red = combined_with(&g, &f, k, Reduction::FixedPoint).unwrap();
            let after = persistence_diagrams(&red.graph, &red.filtration, max_j);
            for j in k..=max_j {
                assert!(
                    base[j].same_as(&after[j], 1e-9),
                    "trial {trial} {desc} k={k}: PD_{j} {} vs {}",
                    base[j],
                    after[j]
                );
            }
        }
    }
}

#[test]
fn fixed_point_removes_at_least_as_many_as_combined() {
    // (`fixed_point_beats_combined_on_a_crafted_cascade` below shows the
    // inequality is strict on at least one instance, so this property is
    // not vacuous.)
    let mut rng = Rng::new(0xF1DF);
    for trial in 0..60 {
        let (g, desc) = family_graph(&mut rng);
        let f = Filtration::degree_superlevel(&g);
        let c = combined_with(&g, &f, 1, Reduction::Combined).unwrap();
        let fp = combined_with(&g, &f, 1, Reduction::FixedPoint).unwrap();
        assert!(
            fp.report.removed() >= c.report.removed(),
            "trial {trial} {desc}: fixed-point removed {} < combined {}",
            fp.report.removed(),
            c.report.removed()
        );
        // the fixed-point residue must be contained in Combined's residue
        // (round 1 of the alternation IS Combined, removal is monotone)
        assert!(
            fp.kept_old_ids
                .iter()
                .all(|v| c.kept_old_ids.binary_search(v).is_ok()),
            "trial {trial} {desc}: fixed-point residue not nested in combined residue"
        );
    }
}

#[test]
fn fixed_point_terminates_with_rounds_bounded_by_removals() {
    let mut rng = Rng::new(0xF1E0);
    for trial in 0..60 {
        let (g, desc) = family_graph(&mut rng);
        let f = Filtration::degree_superlevel(&g);
        let red = combined_with(&g, &f, 1, Reduction::FixedPoint).unwrap();
        let rounds = red.report.rounds_run();
        assert!(
            rounds <= red.report.removed() + 1,
            "trial {trial} {desc}: {rounds} rounds for {} removals",
            red.report.removed()
        );
        // the last round is the terminating all-zero round
        let last = red.report.rounds.last().unwrap();
        assert_eq!(
            last.prunit_removed + last.core_removed,
            0,
            "trial {trial} {desc}: plan stopped mid-round"
        );
        // per-round counts sum to the total removal
        let by_rounds: usize = red
            .report
            .rounds
            .iter()
            .map(|r| r.prunit_removed + r.core_removed)
            .sum();
        assert_eq!(by_rounds, red.report.removed(), "trial {trial} {desc}");
    }
}

#[test]
fn planner_and_materializing_pipelines_are_identical() {
    let mut rng = Rng::new(0xF1E1);
    let mut ws = ReductionWorkspace::new();
    for trial in 0..40 {
        let (g, desc) = family_graph(&mut rng);
        let f = if rng.chance(0.5) {
            Filtration::degree_superlevel(&g)
        } else {
            Filtration::degree(&g)
        };
        for which in [
            Reduction::None,
            Reduction::Coral,
            Reduction::Prunit,
            Reduction::Combined,
            Reduction::FixedPoint,
        ] {
            let a = combined_with_ws(&mut ws, &g, &f, 1, which).unwrap();
            let b = combined_with_materializing(&g, &f, 1, which).unwrap();
            assert_eq!(
                a.graph,
                b.graph,
                "trial {trial} {desc} {}: graphs differ",
                which.name()
            );
            assert_eq!(a.kept_old_ids, b.kept_old_ids, "trial {trial} {desc}");
            assert_eq!(a.filtration, b.filtration, "trial {trial} {desc}");
        }
    }
}

#[test]
fn fixed_point_beats_combined_on_a_crafted_cascade() {
    // Triangle 0-1-2 with pendant leaves 3 (on 0) and 4 (on 1), sublevel
    // f = [2, 3, 1, 0, 0]:
    //
    // * PrunIT round 1 removes nothing — every domination is vetoed by f
    //   (the leaves sit below their hubs; 2 sits below 0 and 1; 0 and 1
    //   each own a private leaf the other lacks).
    // * The 2-core peel removes the leaves 3 and 4.
    // * PrunIT round 2 now sees 2 dominating 0 (the leaf witness is
    //   gone) with f(0) = 2 ≥ f(2) = 1 — removes 0, then 1; the core
    //   peel clears the remaining isolated vertex.
    //
    // Combined stops after the first core pass (triangle, 3 vertices);
    // the alternation genuinely needs round 2 and empties the graph.
    let g = Graph::from_edges(5, &[(0, 1), (1, 2), (0, 2), (0, 3), (1, 4)]);
    let f = Filtration::sublevel(vec![2.0, 3.0, 1.0, 0.0, 0.0]);
    let c = combined_with(&g, &f, 1, Reduction::Combined).unwrap();
    let fp = combined_with(&g, &f, 1, Reduction::FixedPoint).unwrap();
    assert_eq!(c.graph.n(), 3, "Combined stops at the triangle");
    assert_eq!(fp.graph.n(), 0, "alternation cascades to the empty graph");
    assert!(fp.report.rounds_run() >= 3, "needs a genuine second round");
    // and PD_1 is still exact (the triangle is a filled 2-simplex)
    let base = persistence_diagrams(&g, &f, 1);
    let after = persistence_diagrams(&fp.graph, &fp.filtration, 1);
    assert!(base[1].same_as(&after[1], 1e-12));
}
