//! Service-mode integration tests: overload accounting (every request
//! reaches exactly one terminal state while `/healthz` keeps answering),
//! fault-injected failures landing in the journal, and the SIGTERM
//! drain contract driven against the real `repro serve` binary.

#![cfg(unix)]

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::process::{Command, Stdio};
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use coral_prunit::config::{CoordinatorConfig, ServiceConfig};
use coral_prunit::coordinator::serve::serve;
use coral_prunit::coordinator::{JournalReplay, ServeOptions, ServeReport};

/// Blocking reader fed line-batches over a channel; EOF when the sender
/// drops. Lets a test hold the service's stdin open and pace requests.
struct ChanReader {
    rx: Receiver<Vec<u8>>,
    buf: Vec<u8>,
    pos: usize,
}

impl Read for ChanReader {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.buf.len() {
            match self.rx.recv() {
                Ok(b) => {
                    self.buf = b;
                    self.pos = 0;
                }
                Err(_) => return Ok(0),
            }
        }
        let n = (self.buf.len() - self.pos).min(out.len());
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

fn base_options() -> ServeOptions {
    ServeOptions {
        coordinator: CoordinatorConfig {
            workers: 2,
            max_k: 1,
            reduction: "combined".into(),
            seed: 42,
            prune_threads: 1,
            ..CoordinatorConfig::default()
        },
        service: ServiceConfig {
            http_addr: String::new(),
            idle_evict_secs: 0.0,
            stuck_job_secs: 0.0,
            ..ServiceConfig::default()
        },
        ..ServeOptions::default()
    }
}

/// Spawn `serve` on a thread; returns the request sender, the response
/// receiver, and the join handle yielding the final report.
#[allow(clippy::type_complexity)]
fn spawn_serve(
    opts: ServeOptions,
) -> (
    std::sync::mpsc::Sender<Vec<u8>>,
    Receiver<String>,
    std::thread::JoinHandle<ServeReport>,
) {
    let (in_tx, in_rx) = channel::<Vec<u8>>();
    let (out_tx, out_rx) = channel::<String>();
    let handle = std::thread::spawn(move || {
        let input = ChanReader { rx: in_rx, buf: Vec::new(), pos: 0 };
        let reader = std::io::BufReader::new(input);
        serve(reader, opts, move |line| {
            let _ = out_tx.send(line);
        })
        .expect("serve must drain cleanly")
    });
    (in_tx, out_rx, handle)
}

fn wait_for(rx: &Receiver<String>, needle: &str) -> String {
    loop {
        let line = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|_| panic!("timed out waiting for {needle:?}"));
        if line.contains(needle) {
            return line;
        }
    }
}

fn http_get(addr: &str, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect endpoint");
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut body = String::new();
    s.read_to_string(&mut body).unwrap();
    body
}

/// Overload run: a deliberately tiny admission window sheds most of a
/// burst, yet every request reaches exactly one terminal state and the
/// health endpoint keeps answering throughout.
#[test]
fn overloaded_burst_accounts_for_every_request_and_healthz_answers() {
    let mut opts = base_options();
    opts.coordinator.workers = 1;
    opts.service.http_addr = "127.0.0.1:0".into();
    opts.service.max_pending = 2;
    opts.service.shed_pending = 1;
    let (in_tx, out_rx, handle) = spawn_serve(opts);
    let http_line = wait_for(&out_rx, "serve: http listening on ");
    let addr = http_line.rsplit(' ').next().unwrap().to_string();

    let health = http_get(&addr, "/healthz");
    assert!(health.starts_with("HTTP/1.1 200"), "{health}");

    const BURST: usize = 24;
    let mut lines = String::new();
    for i in 0..BURST {
        lines.push_str(&format!("id={i} dataset=DHFR instance={}\n", i % 8));
    }
    in_tx.send(lines.into_bytes()).unwrap();

    // the endpoint must stay responsive while the burst is in flight
    let metrics = http_get(&addr, "/metrics");
    assert!(metrics.contains("repro_jobs_submitted"), "{metrics}");
    assert!(metrics.contains("repro_cache_hits"), "{metrics}");

    drop(in_tx); // EOF → drain
    let report = handle.join().unwrap();
    let terminal = report.completed
        + report.failed
        + report.shed
        + report.cache_hits
        + report.already_done
        + report.bad_lines;
    assert_eq!(terminal, BURST, "every request needs exactly one terminal state");
    assert!(report.completed >= 1, "at least the first admit must complete");
    assert_eq!(report.failed, 0);
    assert_eq!(report.bad_lines, 0);
}

/// Cache hits, shed responses, and plain successes coexist in one
/// session; resubmitting a finished graph is answered from cache with a
/// bit-identical digest.
#[test]
fn mixed_session_cache_hit_digest_matches_cold_compute() {
    let (in_tx, out_rx, handle) = spawn_serve(base_options());
    in_tx.send(b"id=0 dataset=DHFR instance=3\n".to_vec()).unwrap();
    let cold = wait_for(&out_rx, "id=0 status=ok");
    in_tx.send(b"id=1 dataset=DHFR instance=3\n".to_vec()).unwrap();
    let hit = wait_for(&out_rx, "id=1 status=cached");
    drop(in_tx);
    let report = handle.join().unwrap();
    assert_eq!(report.cache_hits, 1);
    assert_eq!(report.completed, 1);
    let digest = |l: &str| l.split("pd=").nth(1).unwrap().to_string();
    assert_eq!(digest(&cold), digest(&hit), "cache answered different diagrams");
}

/// Fault-injected chaos: a job scripted to panic on every attempt must
/// surface as a journaled failure — not a hang, not a lost id — while
/// the rest of the stream completes.
#[cfg(feature = "faults")]
#[test]
fn scripted_panic_becomes_a_journaled_failure_not_a_loss() {
    use coral_prunit::coordinator::FaultPlan;
    let mut journal = std::env::temp_dir();
    journal.push(format!("coral-serve-chaos-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&journal);
    let mut opts = base_options();
    opts.coordinator.retry_backoff_ms = 1;
    opts.journal_path = Some(journal.clone());
    opts.faults = Some(FaultPlan::new().panic_always(1));
    let (in_tx, out_rx, handle) = spawn_serve(opts);
    for line in [
        "id=0 dataset=DHFR instance=0\n",
        "id=1 dataset=DHFR instance=1\n",
        "id=2 dataset=DHFR instance=2\n",
    ] {
        in_tx.send(line.as_bytes().to_vec()).unwrap();
    }
    let failure = wait_for(&out_rx, "failed id=1");
    assert!(failure.contains("attempts="), "{failure}");
    drop(in_tx);
    let report = handle.join().unwrap();
    assert_eq!(report.completed, 2);
    assert_eq!(report.failed, 1);
    let replay = JournalReplay::load(&journal).unwrap();
    assert!(replay.failed.contains(&1), "failure must reach the journal");
    assert_eq!(replay.completed.len(), 2);
    assert!(replay.orphaned().is_empty(), "no id may be left in limbo");
    let _ = std::fs::remove_file(&journal);
}

/// Journal location for the SIGTERM test: CI pins it via
/// `SERVE_JOURNAL_PATH` and uploads it as an artifact.
fn serve_journal_path() -> std::path::PathBuf {
    let p = match std::env::var_os("SERVE_JOURNAL_PATH") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            let mut p = std::env::temp_dir();
            p.push(format!("coral-serve-sigterm-{}.jsonl", std::process::id()));
            p
        }
    };
    let _ = std::fs::remove_file(&p);
    p
}

fn completed_counts(path: &std::path::Path) -> BTreeMap<u64, usize> {
    let text = std::fs::read_to_string(path).unwrap_or_default();
    let mut counts = BTreeMap::new();
    for line in text.lines() {
        if !line.contains("\"event\":\"completed\"") {
            continue;
        }
        if let Some(rest) = line.split("\"id\":").nth(1) {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(id) = digits.parse::<u64>() {
                *counts.entry(id).or_insert(0) += 1;
            }
        }
    }
    counts
}

/// SIGTERM the real `repro serve` binary mid-stream, assert it exits 0
/// after draining, then resume with the same journal and prove no job
/// was lost or run twice.
#[test]
fn sigterm_drains_exits_zero_and_resume_loses_nothing() {
    const JOBS: u64 = 8;
    let journal = serve_journal_path();
    let serve_cmd = |j: &std::path::Path| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_repro"));
        cmd.args(["serve", "--workers", "1", "--journal"])
            .arg(j)
            .stdin(Stdio::piped())
            .stdout(Stdio::null())
            .stderr(Stdio::null());
        cmd
    };
    let wait_exit = |child: &mut std::process::Child| {
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            if let Some(status) = child.try_wait().expect("poll child") {
                return status;
            }
            assert!(Instant::now() < deadline, "serve did not exit within 120s");
            std::thread::sleep(Duration::from_millis(20));
        }
    };

    // Incarnation 1: feed the stream, SIGTERM once progress is visible.
    let mut child = serve_cmd(&journal).spawn().expect("spawn repro serve");
    let mut stdin = child.stdin.take().expect("piped stdin");
    for i in 0..JOBS {
        writeln!(stdin, "id={i} dataset=DD instance={i}").expect("write request");
    }
    stdin.flush().unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let done = JournalReplay::load(&journal)
            .map(|r| r.completed.len())
            .unwrap_or(0);
        if done >= 1 {
            break;
        }
        assert!(
            child.try_wait().expect("poll child").is_none(),
            "serve exited before SIGTERM with stdin still open"
        );
        assert!(Instant::now() < deadline, "no journal progress within 120s");
        std::thread::sleep(Duration::from_millis(10));
    }
    let term = Command::new("kill")
        .arg("-TERM")
        .arg(child.id().to_string())
        .status()
        .expect("send SIGTERM");
    assert!(term.success());
    let status = wait_exit(&mut child);
    assert!(status.success(), "SIGTERM drain must exit 0, got {status:?}");
    drop(stdin);

    let replay = JournalReplay::load(&journal).unwrap();
    assert!(
        replay.orphaned().is_empty(),
        "drain left in-flight ids orphaned: {:?}",
        replay.orphaned()
    );
    for (id, count) in completed_counts(&journal) {
        assert_eq!(count, 1, "job {id} completed {count} times before resume");
    }

    // Incarnation 2: same journal, same ids — completed ones are skipped
    // (`already-done`), shed/unsubmitted ones run now; everything lands.
    let mut child = serve_cmd(&journal).spawn().expect("resume repro serve");
    let mut stdin = child.stdin.take().expect("piped stdin");
    for i in 0..JOBS {
        writeln!(stdin, "id={i} dataset=DD instance={i}").expect("write request");
    }
    drop(stdin); // EOF → drain → exit
    let status = wait_exit(&mut child);
    assert!(status.success(), "resume must exit 0, got {status:?}");

    let replay = JournalReplay::load(&journal).unwrap();
    let expected: Vec<u64> = (0..JOBS).collect();
    let completed: Vec<u64> = replay.completed.iter().copied().collect();
    assert_eq!(completed, expected, "lost or extra job ids after resume");
    assert!(replay.orphaned().is_empty());
    assert!(replay.failed.is_empty());
    for (id, count) in completed_counts(&journal) {
        assert_eq!(count, 1, "job {id} completed {count} times (duplicate run)");
    }
    if std::env::var_os("SERVE_JOURNAL_PATH").is_none() {
        let _ = std::fs::remove_file(&journal);
    }
}
