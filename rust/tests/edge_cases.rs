//! Edge cases and failure injection across the stack: degenerate graphs,
//! disconnected inputs, worker panics, config round-trips, and diagram
//! invariants that must hold at the boundaries.

use coral_prunit::complex::{Filtration, FlatComplex};
use coral_prunit::config::{Config, CoordinatorConfig};
use coral_prunit::coordinator::{Coordinator, Job, JobSpec};
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::{betti_numbers, persistence_diagrams, bottleneck, wasserstein1};
use coral_prunit::prune::prunit;
use coral_prunit::reduce::{combined_with, coral_reduce, Reduction};
use coral_prunit::testutil::forall;

// ---------- degenerate graphs ----------

#[test]
fn empty_graph_full_pipeline() {
    let g = Graph::empty(0);
    let f = Filtration::constant(0);
    let pds = persistence_diagrams(&g, &f, 2);
    assert!(pds.iter().all(|d| d.is_empty()));
    let r = combined_with(&g, &f, 1, Reduction::Combined).unwrap();
    assert_eq!(r.graph.n(), 0);
    assert_eq!(r.vertex_reduction_pct(), 0.0);
}

#[test]
fn single_vertex_pipeline() {
    let g = Graph::empty(1);
    let f = Filtration::sublevel(vec![7.0]);
    let pds = persistence_diagrams(&g, &f, 1);
    assert_eq!(pds[0].betti(), 1);
    assert_eq!(pds[0].essential(), vec![7.0]);
    assert!(pds[1].is_empty());
    // nothing dominates in a K1
    assert_eq!(prunit(&g, &f).unwrap().removed, 0);
}

#[test]
fn all_isolated_vertices() {
    let g = Graph::empty(5);
    let f = Filtration::sublevel(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    let pds = persistence_diagrams(&g, &f, 1);
    assert_eq!(pds[0].betti(), 5, "five essential components");
    let r = coral_reduce(&g, &f, 1).unwrap();
    assert_eq!(r.graph.n(), 0, "isolated vertices have coreness 0");
    // and CoralTDA still preserves PD_1 (both trivial)
    let after = persistence_diagrams(&r.graph, &r.filtration, 1);
    assert!(pds[1].same_as(&after[1], 1e-12));
}

#[test]
fn two_vertices_one_edge() {
    let g = Graph::from_edges(2, &[(0, 1)]);
    let f = Filtration::sublevel(vec![0.0, 1.0]);
    let pds = persistence_diagrams(&g, &f, 1);
    assert_eq!(pds[0].betti(), 1);
    let pts = pds[0].points();
    assert_eq!(pts, vec![(0.0, f64::INFINITY)]);
    // vertex 1 is dominated by 0 and admissible (f(1) ≥ f(0))
    let r = prunit(&g, &f).unwrap();
    assert_eq!(r.graph.n(), 1);
    assert_eq!(r.kept_old_ids, vec![0]);
}

#[test]
fn disconnected_components_are_independent() {
    // triangle ⊔ path ⊔ isolate, constant filtration
    let g = Graph::from_edges(7, &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5)]);
    assert_eq!(betti_numbers(&g, 1), vec![3, 0]);
    // prunit collapses the triangle and path but can't merge components
    let f = Filtration::degree_superlevel(&g);
    let r = prunit(&g, &f).unwrap();
    let after = persistence_diagrams(&r.graph, &r.filtration, 1);
    assert_eq!(after[0].betti(), 3, "component count is a homotopy invariant");
}

#[test]
fn filtration_with_equal_values_everywhere() {
    // heavy tie-breaking stress: all f equal → every order is valid and
    // every dominated vertex admissible in both directions.
    forall("all-ties", 20, 0x71e, |rng| {
        let n = rng.range(3, 16);
        let g = gen::erdos_renyi(n, 0.4, rng.next_u64());
        let f = Filtration::constant(n);
        let base = persistence_diagrams(&g, &f, 1);
        let r = prunit(&g, &f).unwrap();
        let after = persistence_diagrams(&r.graph, &r.filtration, 1);
        for k in 0..=1 {
            if !base[k].same_as(&after[k], 1e-12) {
                return Err(format!("tie-breaking broke PD_{k}"));
            }
        }
        Ok(())
    });
}

#[test]
fn negative_and_huge_filtration_values() {
    let g = gen::cycle(5);
    let f = Filtration::sublevel(vec![-1e12, 3.5, -2.0, 1e12, 0.0]);
    let pds = persistence_diagrams(&g, &f, 1);
    assert_eq!(pds[1].betti(), 1);
    assert_eq!(pds[1].essential(), vec![1e12], "loop completes at max f");
}

// ---------- complex construction boundaries ----------

#[test]
fn max_dim_zero_complex_is_vertices_only() {
    let g = gen::complete(5);
    let c = FlatComplex::build(&g, &Filtration::constant(5), 0);
    assert_eq!(c.counts_by_dim(), vec![5]);
}

#[test]
fn requesting_k_above_degeneracy_gives_trivial_diagrams() {
    let g = gen::cycle(6); // degeneracy 2, complex dim 1
    let f = Filtration::degree(&g);
    let pds = persistence_diagrams(&g, &f, 4);
    assert_eq!(pds.len(), 5);
    for k in 2..=4 {
        assert!(pds[k].is_empty(), "PD_{k} of a cycle must be empty");
    }
}

// ---------- distances at the boundaries ----------

#[test]
fn distances_on_empty_diagrams() {
    let a = coral_prunit::homology::Diagram::new(1, vec![]);
    let b = coral_prunit::homology::Diagram::new(1, vec![]);
    assert_eq!(bottleneck(&a, &b), 0.0);
    assert_eq!(wasserstein1(&a, &b), 0.0);
}

#[test]
fn distance_is_zero_between_reduced_and_unreduced() {
    forall("distance-zero", 15, 0xd15, |rng| {
        let n = rng.range(4, 18);
        let g = gen::erdos_renyi(n, 0.35, rng.next_u64());
        let f = Filtration::degree_superlevel(&g);
        let base = persistence_diagrams(&g, &f, 1);
        let r = prunit(&g, &f).unwrap();
        let red = persistence_diagrams(&r.graph, &r.filtration, 1);
        let db = bottleneck(&base[1], &red[1]);
        let dw = wasserstein1(&base[1], &red[1]);
        if db > 1e-9 || dw > 1e-9 {
            return Err(format!("distances nonzero: bottleneck={db}, W1={dw}"));
        }
        Ok(())
    });
}

// ---------- coordinator failure injection ----------

#[test]
fn worker_panic_surfaces_as_coordinator_error() {
    // A filtration/graph mismatch used to panic inside the worker; the
    // planner now surfaces it as a typed error, and the coordinator must
    // report it as the batch error, not hang or crash the test.
    let cfg = CoordinatorConfig {
        workers: 2,
        queue_depth: 2,
        max_k: 1,
        reduction: "prunit".into(),
        seed: 1,
        prune_threads: 1,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::new(cfg);
    let bad = Job::new(
        0,
        gen::cycle(5),
        Filtration::constant(3), // wrong length → panic in worker
        JobSpec::default(),
    );
    let result = coord.run(vec![bad]);
    assert!(result.is_err(), "panicking worker must surface an error");
}

#[test]
fn coordinator_survives_mixed_good_and_tiny_jobs() {
    let cfg = CoordinatorConfig {
        workers: 3,
        queue_depth: 1,
        max_k: 1,
        reduction: "prunit+coral".into(),
        seed: 2,
        prune_threads: 2,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::new(cfg);
    let jobs: Vec<Job> = vec![
        Job::degree_superlevel(0, Graph::empty(0), JobSpec::default()),
        Job::degree_superlevel(1, Graph::empty(1), JobSpec::default()),
        Job::degree_superlevel(2, gen::complete(12), JobSpec::default()),
        Job::degree_superlevel(3, gen::cycle(40), JobSpec::default()),
    ];
    let out = coord.run(jobs).unwrap();
    assert_eq!(out.len(), 4);
    assert_eq!(out[3].diagrams[1].betti(), 1, "C40 keeps its loop");
}

// ---------- config + CLI robustness ----------

#[test]
fn config_file_round_trip_from_disk() {
    let dir = std::env::temp_dir().join("coral_prunit_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("coordinator.toml");
    std::fs::write(
        &path,
        "[coordinator]\nworkers = 5\nqueue_depth = 9\nmax_k = 2\nreduction = \"coral\"\nseed = 77\n",
    )
    .unwrap();
    let cfg = CoordinatorConfig::from_config(&Config::load(&path).unwrap()).unwrap();
    assert_eq!(cfg.workers, 5);
    assert_eq!(cfg.queue_depth, 9);
    assert_eq!(cfg.max_k, 2);
    assert_eq!(cfg.reduction, "coral");
    assert_eq!(cfg.seed, 77);
}

#[test]
fn config_missing_file_is_io_error() {
    assert!(Config::load("/definitely/not/here.toml").is_err());
}

// ---------- reduction bookkeeping invariants ----------

#[test]
fn kept_old_ids_always_strictly_ascending() {
    forall("ids-ascending", 25, 0xa5c, |rng| {
        let n = rng.range(3, 30);
        let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
        let f = Filtration::degree_superlevel(&g);
        for which in [
            Reduction::Coral,
            Reduction::Prunit,
            Reduction::Combined,
            Reduction::FixedPoint,
        ] {
            let r = combined_with(&g, &f, 1, which).unwrap();
            if !r.kept_old_ids.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("{}: ids not ascending", which.name()));
            }
            if r.kept_old_ids.len() != r.graph.n() {
                return Err(format!("{}: id/graph size mismatch", which.name()));
            }
            if r.filtration.len() != r.graph.n() {
                return Err(format!("{}: filtration size mismatch", which.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn reduced_graph_is_induced_subgraph() {
    forall("induced-subgraph", 20, 0x1d5, |rng| {
        let n = rng.range(4, 25);
        let g = gen::erdos_renyi(n, 0.3, rng.next_u64());
        let f = Filtration::degree_superlevel(&g);
        let r = combined_with(&g, &f, 1, Reduction::Combined).unwrap();
        for (a_new, &a_old) in r.kept_old_ids.iter().enumerate() {
            for (b_new, &b_old) in r.kept_old_ids.iter().enumerate() {
                let has_new = r.graph.has_edge(a_new as u32, b_new as u32);
                let has_old = g.has_edge(a_old, b_old);
                if has_new != has_old {
                    return Err(format!(
                        "edge mismatch: new ({a_new},{b_new})={has_new} old ({a_old},{b_old})={has_old}"
                    ));
                }
            }
        }
        Ok(())
    });
}
