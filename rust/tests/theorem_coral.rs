//! Property suite for **Theorem 2 (CoralTDA)**:
//! `PD_j(G, f) = PD_j(G^{k+1}, f)` for all `j ≥ k`, with `f` restricted
//! (not recomputed) to the core.

use coral_prunit::complex::Filtration;
use coral_prunit::graph::gen;
use coral_prunit::homology::persistence_diagrams;
use coral_prunit::kcore::{degeneracy, kcore_subgraph};
use coral_prunit::reduce::{coral_reduce, Reduction};
use coral_prunit::testutil::{forall, random_filtration, random_graph_case};

/// The theorem, quantified over random graphs, filtrations, and k.
#[test]
fn theorem2_pd_equality_above_k() {
    forall("coral-theorem2", 60, 0xC07A1, |rng| {
        let case = random_graph_case(rng, 22);
        let g = &case.graph;
        let f = random_filtration(rng, g);
        let max_j = 2usize;
        let before = persistence_diagrams(g, &f, max_j);
        for k in 1..=max_j {
            let r = coral_reduce(g, &f, k).unwrap();
            let after = persistence_diagrams(&r.graph, &r.filtration, max_j);
            for j in k..=max_j {
                if !before[j].same_as(&after[j], 1e-9) {
                    return Err(format!(
                        "{}: PD_{j} differs on the {}-core: {} vs {}",
                        case.desc,
                        k + 1,
                        before[j],
                        after[j]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Below the guarantee (j < k) the diagrams genuinely may differ — make
/// sure the suite would notice a violation (sanity of the test itself):
/// PD_0 of the 2-core drops the tree periphery's components.
#[test]
fn below_k_equality_fails_as_expected() {
    // star: 2-core is empty; PD_0 is decidedly nonempty.
    let g = gen::star(6);
    let f = Filtration::degree(&g);
    let r = coral_reduce(&g, &f, 1).unwrap();
    assert_eq!(r.graph.n(), 0);
    let before = persistence_diagrams(&g, &f, 1);
    assert!(before[0].betti() > 0);
}

/// Deterministic families across several k.
#[test]
fn theorem2_on_deterministic_families() {
    for g in [
        gen::cycle(12),
        gen::complete(7),
        gen::octahedron(),
        gen::grid(4, 4),
        gen::star(9),
        gen::path(8),
    ] {
        let f = Filtration::degree(&g);
        let before = persistence_diagrams(&g, &f, 2);
        for k in 1..=2 {
            let r = coral_reduce(&g, &f, k).unwrap();
            let after = persistence_diagrams(&r.graph, &r.filtration, 2);
            for j in k..=2 {
                assert!(
                    before[j].same_as(&after[j], 1e-9),
                    "PD_{j} via {}-core on n={}: {} vs {}",
                    k + 1,
                    g.n(),
                    before[j],
                    after[j]
                );
            }
        }
    }
}

/// Superlevel variant of the theorem (the filtration direction is
/// irrelevant to the core argument).
#[test]
fn theorem2_superlevel() {
    forall("coral-superlevel", 25, 99, |rng| {
        let case = random_graph_case(rng, 18);
        let g = &case.graph;
        let f = Filtration::degree_superlevel(g);
        let before = persistence_diagrams(g, &f, 2);
        let r = coral_reduce(g, &f, 1).unwrap();
        let after = persistence_diagrams(&r.graph, &r.filtration, 2);
        for j in 1..=2 {
            if !before[j].same_as(&after[j], 1e-9) {
                return Err(format!("{}: PD_{j} {} vs {}", case.desc, before[j], after[j]));
            }
        }
        Ok(())
    });
}

/// Theorem 2 extended to the PrunIT⇄core alternation
/// (`Reduction::FixedPoint`): every core stage preserves `PD_j` for
/// `j ≥ k` and every PrunIT stage preserves all diagrams, so the full
/// alternation keeps `PD_j` for `j ≥ k` — quantified over random graphs,
/// random filtrations, and k, like the single-core statement above. Also
/// checks the residue really is inside the (k+1)-core (min degree) and
/// that the alternation never does worse than one coral pass.
#[test]
fn theorem2_alternation_pd_equality_above_k() {
    forall("coral-alternation", 40, 0xC07A2, |rng| {
        let case = random_graph_case(rng, 22);
        let g = &case.graph;
        let f = random_filtration(rng, g);
        let max_j = 2usize;
        let before = persistence_diagrams(g, &f, max_j);
        for k in 1..=max_j {
            let red = coral_prunit::reduce::combined_with(g, &f, k, Reduction::FixedPoint)
                .map_err(|e| e.to_string())?;
            let coral = coral_reduce(g, &f, k).unwrap();
            if red.graph.n() > coral.graph.n() {
                return Err(format!(
                    "{}: alternation kept {} > single core {}",
                    case.desc,
                    red.graph.n(),
                    coral.graph.n()
                ));
            }
            for u in 0..red.graph.n() as u32 {
                if red.graph.degree(u) < k + 1 {
                    return Err(format!(
                        "{}: residue vertex {u} has degree {} < {}",
                        case.desc,
                        red.graph.degree(u),
                        k + 1
                    ));
                }
            }
            let after = persistence_diagrams(&red.graph, &red.filtration, max_j);
            for j in k..=max_j {
                if !before[j].same_as(&after[j], 1e-9) {
                    return Err(format!(
                        "{}: PD_{j} differs after alternation at k={k}: {} vs {}",
                        case.desc, before[j], after[j]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The §4.1 structural fact: the clique complex has a (k−1)-simplex iff
/// the k-core is non-empty (degeneracy bounds the complex dimension).
#[test]
fn degeneracy_bounds_complex_dimension() {
    forall("degeneracy-dimension", 30, 7, |rng| {
        let case = random_graph_case(rng, 20);
        let g = &case.graph;
        let d = degeneracy(g);
        let complex = coral_prunit::complex::FlatComplex::build(
            g,
            &Filtration::constant(g.n()),
            d + 2,
        );
        if g.n() == 0 {
            return Ok(());
        }
        if complex.dim() > d {
            return Err(format!(
                "{}: complex dim {} exceeds degeneracy {d}",
                case.desc,
                complex.dim()
            ));
        }
        // conversely the d-core is non-empty by definition of degeneracy
        let (core, _) = kcore_subgraph(g, d);
        if core.n() == 0 {
            return Err(format!("{}: {d}-core empty at degeneracy", case.desc));
        }
        Ok(())
    });
}

/// Reduction percentages are monotone in k: higher-dimensional targets
/// peel at least as much (cores are nested).
#[test]
fn coral_reduction_monotone_in_k() {
    forall("coral-monotone", 30, 13, |rng| {
        let case = random_graph_case(rng, 40);
        let g = &case.graph;
        let f = Filtration::degree(g);
        let mut prev = usize::MAX;
        for k in 0..5 {
            let r = coral_reduce(g, &f, k).unwrap();
            if r.graph.n() > prev {
                return Err(format!("{}: core sizes not nested at k={k}", case.desc));
            }
            prev = r.graph.n();
        }
        Ok(())
    });
}
