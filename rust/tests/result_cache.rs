//! Content-addressed result cache: a cache hit must be bit-identical to
//! the cold compute across the whole reduction matrix, keys must be
//! sensitive to every input component, and the byte budget must be
//! enforced in LRU order.

use coral_prunit::complex::Filtration;
use coral_prunit::coordinator::serve::diagram_digest;
use coral_prunit::coordinator::{job_key, CachedResult, Coordinator, Job, JobSpec, ResultCache};
use coral_prunit::datasets;
use coral_prunit::homology::Diagram;
use coral_prunit::reduce::Reduction;

const REDUCTIONS: [Reduction; 5] = [
    Reduction::None,
    Reduction::Coral,
    Reduction::Prunit,
    Reduction::Combined,
    Reduction::FixedPoint,
];

fn cold(idx: usize, reduction: Reduction) -> (Job, coral_prunit::coordinator::JobResult) {
    let g = datasets::find("DHFR").unwrap().make(42, idx);
    let f = Filtration::degree_superlevel(&g);
    let job = Job::new(
        idx as u64,
        g,
        f,
        JobSpec { max_k: 1, reduction, sharded: false, ..JobSpec::default() },
    );
    let result = Coordinator::execute(&job, 0).unwrap();
    (job, result)
}

/// Property: for every reduction in the matrix, inserting a cold result
/// and reading it back yields diagrams whose every `f64` is bit-equal —
/// and an independent recompute digests identically (the pipeline is
/// deterministic, so the cache can never be observed to change answers).
#[test]
fn cache_hits_are_bit_identical_to_cold_compute_across_reduction_matrix() {
    let cache = ResultCache::new(64 << 20);
    for reduction in REDUCTIONS {
        for idx in 0..3 {
            let (job, first) = cold(idx, reduction);
            let key = job_key(&job.graph, &job.filtration, reduction, job.spec.max_k);
            cache.insert(
                key,
                CachedResult {
                    diagrams: first.diagrams.clone(),
                    reduction: first.reduction.clone(),
                },
            );
            let hit = cache.get(&key).expect("inserted key must hit");
            assert_eq!(hit.diagrams.len(), first.diagrams.len());
            for (a, b) in hit.diagrams.iter().zip(&first.diagrams) {
                assert_eq!(a.all_pairs().len(), b.all_pairs().len());
                for (&(b1, d1), &(b2, d2)) in a.all_pairs().iter().zip(b.all_pairs()) {
                    assert_eq!(b1.to_bits(), b2.to_bits(), "{reduction:?} birth bits");
                    assert_eq!(d1.to_bits(), d2.to_bits(), "{reduction:?} death bits");
                }
            }
            // a second cold compute agrees bit-for-bit with what was cached
            let (_, second) = cold(idx, reduction);
            assert_eq!(
                diagram_digest(&second.diagrams),
                diagram_digest(&hit.diagrams),
                "{reduction:?} instance {idx}: recompute differs from cached result"
            );
        }
    }
    assert_eq!(cache.stats().evictions, 0, "64 MiB budget must not evict here");
}

#[test]
fn keys_separate_graph_filtration_reduction_and_dimension() {
    let recipe = datasets::find("DHFR").unwrap();
    let g0 = recipe.make(42, 0);
    let g1 = recipe.make(42, 1);
    let f0 = Filtration::degree_superlevel(&g0);
    let f1 = Filtration::degree_superlevel(&g1);
    let base = job_key(&g0, &f0, Reduction::Combined, 1);
    assert_eq!(base, job_key(&g0, &f0, Reduction::Combined, 1), "deterministic");
    assert_ne!(base, job_key(&g1, &f1, Reduction::Combined, 1), "graph");
    assert_ne!(base, job_key(&g0, &f0, Reduction::Prunit, 1), "reduction");
    assert_ne!(base, job_key(&g0, &f0, Reduction::Combined, 2), "max_k");
}

/// Fill a small cache past its byte budget and check the LRU contract:
/// bytes stay under budget, the eviction counter advances, the oldest
/// entry is gone, and a recently-touched entry survives.
#[test]
fn eviction_honours_byte_budget_in_lru_order() {
    // one real report to clone into synthetic entries
    let (_, seed) = cold(0, Reduction::None);
    let entry = |tag: u64| CachedResult {
        // 64 pairs ≈ 1 KiB per entry after overheads
        diagrams: vec![Diagram::new(0, (0..64).map(|i| (tag as f64, i as f64)).collect())],
        reduction: seed.reduction.clone(),
    };
    let one_size = entry(0).byte_size();
    let budget = one_size * 3 + one_size / 2; // fits 3, not 4
    let cache = ResultCache::new(budget);
    let keys: Vec<_> = (0..4u64)
        .map(|i| coral_prunit::coordinator::CacheKey(i as u128 + 1))
        .collect();
    for (i, k) in keys.iter().enumerate().take(3) {
        cache.insert(*k, entry(i as u64));
    }
    assert_eq!(cache.stats().entries, 3);
    // touch key 0 so key 1 becomes the LRU victim
    assert!(cache.get(&keys[0]).is_some());
    cache.insert(keys[3], entry(3));
    let stats = cache.stats();
    assert!(stats.bytes <= budget, "cache holds {} bytes over the {budget} budget", stats.bytes);
    assert!(stats.evictions >= 1, "inserting past budget must evict");
    assert!(cache.get(&keys[1]).is_none(), "LRU entry must be evicted");
    assert!(cache.get(&keys[0]).is_some(), "recently-used entry must survive");
    assert!(cache.get(&keys[3]).is_some(), "newest entry must survive");
}
