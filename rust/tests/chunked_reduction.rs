//! Differential suite for the chunked persistence engine: `Chunked` must
//! produce diagrams bit-identical to `Twist` at every thread count and
//! chunk size — the apparent-pair prepass and the parallel local phase
//! are pure wall-time optimisations, never answer changes. Also pins the
//! PD₀-only fast route (union-find elder rule) to the matrix engines.

use coral_prunit::complex::{ComplexWorkspace, Filtration, FlatComplex};
use coral_prunit::graph::{disjoint_union, gen, Graph};
use coral_prunit::homology::{
    diagrams_of_complex_with, pd0, persistence_diagrams_ph, persistence_diagrams_sharded_with,
    reduce_with, Algorithm, Diagram, PhConfig,
};
use coral_prunit::reduce::ReductionWorkspace;
use coral_prunit::util::{CancelToken, TeamSlot};

const THREADS: [usize; 4] = [1, 2, 4, 8];
const CHUNKS: [usize; 4] = [1, 7, 64, 0]; // 0 = auto sizing

/// Seeded corpus spanning the shapes the reduction sees in practice:
/// sparse/dense random, preferential attachment, and structured graphs
/// with known homology.
fn corpus() -> Vec<(&'static str, Graph)> {
    vec![
        ("er_sparse", gen::erdos_renyi(60, 0.08, 11)),
        ("er_dense", gen::erdos_renyi(40, 0.3, 12)),
        ("ba", gen::barabasi_albert(70, 3, 13)),
        ("ws", gen::watts_strogatz(50, 4, 0.2, 14)),
        ("cycle", gen::cycle(9)),
        ("octahedron", gen::octahedron()),
        ("grid", gen::grid(4, 4)),
    ]
}

fn degenerates() -> Vec<(&'static str, Graph)> {
    vec![
        ("empty", Graph::empty(0)),
        ("single_vertex", Graph::empty(1)),
        ("isolated_vertices", Graph::empty(5)),
        (
            "forest",
            disjoint_union(&[gen::path(4), gen::star(5), Graph::empty(3), gen::path(2)]),
        ),
    ]
}

fn filtrations(g: &Graph) -> Vec<(&'static str, Filtration)> {
    vec![
        ("degree_superlevel", Filtration::degree_superlevel(g)),
        ("degree_sublevel", Filtration::degree(g)),
    ]
}

/// Every `f64` in every dimension bit-equal — stricter than `same_as`.
fn assert_bit_identical(a: &[Diagram], b: &[Diagram], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: diagram count");
    for (k, (da, db)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            da.all_pairs().len(),
            db.all_pairs().len(),
            "{ctx}: PD{k} pair count"
        );
        for (i, (&(b1, d1), &(b2, d2))) in da.all_pairs().iter().zip(db.all_pairs()).enumerate() {
            assert_eq!(b1.to_bits(), b2.to_bits(), "{ctx}: PD{k} pair {i} birth");
            assert_eq!(d1.to_bits(), d2.to_bits(), "{ctx}: PD{k} pair {i} death");
        }
    }
}

fn twist(c: &FlatComplex, max_k: usize) -> Vec<Diagram> {
    let ph = PhConfig {
        algorithm: Algorithm::Twist,
        ..PhConfig::default()
    };
    diagrams_of_complex_with(c, max_k, &ph, &mut TeamSlot::default(), &CancelToken::none())
        .expect("twist with a none token cannot fail")
        .0
}

fn chunked(
    c: &FlatComplex,
    max_k: usize,
    threads: usize,
    chunk_cols: usize,
    team: &mut TeamSlot,
) -> Vec<Diagram> {
    let ph = PhConfig {
        algorithm: Algorithm::Chunked,
        threads,
        chunk_cols,
    };
    diagrams_of_complex_with(c, max_k, &ph, team, &CancelToken::none())
        .expect("chunked with a none token cannot fail")
        .0
}

/// The tentpole guarantee: the full threads × chunk-size grid reproduces
/// Twist bit-for-bit on every corpus graph under both filtration
/// directions.
#[test]
fn chunked_is_bit_identical_to_twist_across_threads_and_chunk_sizes() {
    let max_k = 2;
    let mut team = TeamSlot::default();
    for (gname, g) in corpus() {
        for (fname, f) in filtrations(&g) {
            let c = FlatComplex::build(&g, &f, max_k + 1);
            let want = twist(&c, max_k);
            for threads in THREADS {
                for chunk_cols in CHUNKS {
                    let got = chunked(&c, max_k, threads, chunk_cols, &mut team);
                    assert_bit_identical(
                        &got,
                        &want,
                        &format!("{gname}/{fname} t={threads} chunk={chunk_cols}"),
                    );
                }
            }
        }
    }
}

/// Degenerate inputs — empty graph, single vertex, pure forests — must
/// flow through the prepass and the chunk loop without panicking and
/// still match Twist exactly.
#[test]
fn chunked_matches_twist_on_degenerate_inputs() {
    let max_k = 2;
    let mut team = TeamSlot::default();
    for (gname, g) in degenerates() {
        let f = Filtration::degree_superlevel(&g);
        let c = FlatComplex::build(&g, &f, max_k + 1);
        let want = twist(&c, max_k);
        for threads in [1, 4] {
            for chunk_cols in [1, 0] {
                let got = chunked(&c, max_k, threads, chunk_cols, &mut team);
                assert_bit_identical(
                    &got,
                    &want,
                    &format!("{gname} t={threads} chunk={chunk_cols}"),
                );
            }
        }
    }
}

/// All three algorithms are legal reductions of the same matrix, so the
/// extracted pairs — not just the diagrams — must coincide, and the
/// chunked stats must account for every pair exactly once.
#[test]
fn pair_indices_and_stats_agree_across_algorithms() {
    let g = gen::erdos_renyi(40, 0.3, 21);
    let f = Filtration::degree_superlevel(&g);
    let c = FlatComplex::build(&g, &f, 3);
    let cancel = CancelToken::none();
    let run = |algorithm, threads, chunk_cols| {
        let ph = PhConfig {
            algorithm,
            threads,
            chunk_cols,
        };
        reduce_with(&c, &ph, &mut TeamSlot::default(), &cancel).unwrap()
    };
    let std_red = run(Algorithm::Standard, 1, 0);
    let twist_red = run(Algorithm::Twist, 1, 0);
    assert_eq!(std_red.pairs, twist_red.pairs);
    assert_eq!(std_red.essential, twist_red.essential);
    assert_eq!(twist_red.stats.apparent_pairs, 0, "twist takes no shortcut");
    for threads in THREADS {
        let chunk_red = run(Algorithm::Chunked, threads, 16);
        assert_eq!(chunk_red.pairs, twist_red.pairs, "t={threads} pairs");
        assert_eq!(chunk_red.essential, twist_red.essential, "t={threads} essential");
        assert_eq!(
            chunk_red.stats.apparent_pairs + chunk_red.stats.reduced_pairs,
            chunk_red.pairs.len(),
            "t={threads}: every pair is either apparent or reduced"
        );
        assert!(
            chunk_red.stats.apparent_pairs > 0,
            "a dense ER clique complex must expose some apparent pairs"
        );
    }
}

/// Satellite 3 parity: PD₀-only requests take the union-find elder-rule
/// route (no boundary matrix), and the answer is bit-identical to the
/// Twist matrix engine's PD₀ in both entry points.
#[test]
fn pd0_fast_route_matches_twist_everywhere() {
    for (gname, g) in corpus().into_iter().chain(degenerates()) {
        let f = Filtration::degree_superlevel(&g);
        let c = FlatComplex::build(&g, &f, 1);
        let want = twist(&c, 0);

        // direct union-find
        assert_bit_identical(&[pd0(&g, &f)], &want, &format!("{gname} pd0"));

        // persistence_diagrams_ph at max_k = 0 (the serve/worker route)
        let (got, stats) = persistence_diagrams_ph(
            &mut ComplexWorkspace::new(),
            &g,
            &f,
            0,
            &PhConfig::default(),
            &mut TeamSlot::default(),
            &CancelToken::none(),
        )
        .unwrap();
        assert_bit_identical(&got, &want, &format!("{gname} ph entry"));
        assert_eq!(stats.apparent_pairs + stats.reduced_pairs, 0, "no matrix built");

        // planner entry point used by pd_sharded
        if g.n() > 0 {
            let got =
                persistence_diagrams_sharded_with(&mut ReductionWorkspace::new(), &g, &f, 0, 4)
                    .unwrap();
            assert_bit_identical(&got, &want, &format!("{gname} sharded entry"));
        }
    }
}

/// A workspace-held team survives many graphs and mixed thread counts —
/// the slot grows monotonically and never corrupts state between runs.
#[test]
fn one_team_slot_serves_the_whole_corpus() {
    let mut team = TeamSlot::default();
    let max_k = 2;
    for (i, (gname, g)) in corpus().into_iter().enumerate() {
        let f = Filtration::degree_superlevel(&g);
        let c = FlatComplex::build(&g, &f, max_k + 1);
        let want = twist(&c, max_k);
        // alternate thread counts so the slot grows and then re-clamps
        let threads = [2, 8, 1, 4][i % 4];
        let got = chunked(&c, max_k, threads, 0, &mut team);
        assert_bit_identical(&got, &want, &format!("{gname} shared-team t={threads}"));
    }
}
