//! Chaos differential suite: every scripted fault must resolve to one of
//! three observable outcomes — a retried success, a degraded success
//! whose diagrams are IDENTICAL in every guaranteed dimension (PD_j,
//! j ≥ max_k; escalated reductions stay exact there by Thms 2 & 7), or a
//! journaled failure with identity — and never a hang, a lost job, or a
//! wrong diagram.
//!
//! Runs only with `cargo test --features faults` (the fault hooks are
//! compiled out of default builds; integration tests link the library
//! without `cfg(test)`).

#![cfg(feature = "faults")]

use std::time::Duration;

use coral_prunit::config::CoordinatorConfig;
use coral_prunit::coordinator::{Coordinator, FaultPlan, Job, JobSpec, JournalReplay};
use coral_prunit::error::Error;
use coral_prunit::graph::gen;

fn cfg(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        queue_depth: 4,
        max_retries: 2,
        retry_backoff_ms: 0,
        ..CoordinatorConfig::default()
    }
}

fn jobs(n: usize) -> Vec<Job> {
    (0..n)
        .map(|i| {
            Job::degree_superlevel(
                i as u64,
                gen::barabasi_albert(40 + i, 2, i as u64),
                JobSpec::default(),
            )
        })
        .collect()
}

fn tmp(tag: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("coraltda-chaos-{tag}-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Reference run with no faults: the ground truth every chaos outcome is
/// compared against.
fn clean_results(n: usize) -> Vec<coral_prunit::coordinator::JobResult> {
    Coordinator::new(cfg(2)).run(jobs(n)).unwrap()
}

#[test]
fn chaos_every_fault_resolves_and_diagrams_match_clean_run() {
    let n = 10;
    let clean = clean_results(n);
    // one of each fault kind, all recoverable within the retry budget
    let plan = FaultPlan::new()
        .panic_on(1, 0) // first attempt panics
        .error_on(3, 0) // first attempt errors
        .error_on(3, 1) // ...and so does the second
        .panic_on(6, 0)
        .error_on(6, 1) // mixed panic-then-error
        .delay_rounds(8, Duration::from_millis(1)); // slow but no deadline
    let mut c = Coordinator::new(cfg(3));
    c.set_fault_plan(plan);
    let out = c.run_with_failures(jobs(n), None).unwrap();
    assert_eq!(out.results.len(), n, "every fault must resolve to success");
    assert!(out.failures.is_empty());
    let m = c.metrics();
    assert_eq!(m.completed() as usize, n);
    assert_eq!(m.workers_panicked(), 0, "panics stay inside the harness");
    assert!(m.jobs_retried() >= 4, "retries={}", m.jobs_retried());
    // the differential core: faulted jobs produce exactly the diagrams
    // the clean run produced — in every dimension when the route didn't
    // change, and in every guaranteed dimension (PD_j, j ≥ max_k) when a
    // retry escalated the reduction (a stronger core is still exact
    // there; below max_k it is best-effort by design)
    for (a, b) in clean.iter().zip(&out.results) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.diagrams.len(), b.diagrams.len());
        let first_guaranteed = if b.outcome.is_degraded() {
            JobSpec::default().max_k
        } else {
            0
        };
        for k in first_guaranteed..a.diagrams.len() {
            assert!(
                a.diagrams[k].same_as(&b.diagrams[k], 1e-9),
                "job {} PD_{k} changed under fault injection",
                a.id
            );
        }
    }
    // degraded outcomes are flagged as such
    for id in [1u64, 3, 6] {
        let r = out.results.iter().find(|r| r.id == id).unwrap();
        assert!(r.attempts > 1, "job {id} must have retried");
        assert!(r.outcome.is_degraded());
    }
}

#[test]
fn chaos_unrecoverable_job_fails_alone_with_identity() {
    let n = 8;
    let mut c = Coordinator::new(cfg(2));
    c.set_fault_plan(FaultPlan::new().panic_always(4));
    let path = tmp("lone-failure");
    let (out, resume) = c.run_resumable(jobs(n), &path).unwrap();
    assert_eq!(resume.skipped, 0);
    assert!(resume.orphaned.is_empty());
    assert_eq!(out.results.len(), n - 1, "only the doomed job fails");
    assert_eq!(out.failures.len(), 1);
    assert_eq!(out.failures[0].id, 4);
    assert_eq!(out.failures[0].attempts, 3);
    assert!(matches!(out.failures[0].error, Error::JobPanicked(_)));
    // the journal recorded the failure with identity
    let replay = JournalReplay::load(&path).unwrap();
    assert_eq!(replay.completed.len(), n - 1);
    assert!(replay.failed.contains(&4));
    assert!(replay.orphaned().is_empty(), "no job may vanish");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn chaos_deadline_misses_degrade_or_fail_but_never_hang() {
    // job 0's rounds each sleep 40ms against a 5ms deadline: every
    // attempt times out, so it must fail cleanly (not hang) while the
    // rest of the batch completes
    let mut config = cfg(2);
    config.job_deadline_secs = 0.005;
    config.max_retries = 1;
    let mut c = Coordinator::new(config);
    c.set_fault_plan(FaultPlan::new().delay_rounds(0, Duration::from_millis(40)));
    let out = c.run_with_failures(jobs(6), None).unwrap();
    assert_eq!(out.results.len(), 5);
    assert_eq!(out.failures.len(), 1);
    assert_eq!(out.failures[0].id, 0);
    assert!(matches!(
        out.failures[0].error,
        Error::DeadlineExceeded { .. }
    ));
    let m = c.metrics();
    assert!(m.deadline_misses() >= 2);
    assert!(m.summary().contains("deadline_misses="), "{}", m.summary());
}

#[test]
fn chaos_faulted_batch_journal_resumes_to_full_completion() {
    let n = 8;
    let path = tmp("resume");
    // incarnation 1: job 5 always fails
    {
        let mut c = Coordinator::new(cfg(2));
        c.set_fault_plan(FaultPlan::new().error_always(5));
        let (out, _) = c.run_resumable(jobs(n), &path).unwrap();
        assert_eq!(out.failures.len(), 1);
    }
    // incarnation 2: fault gone — only job 5 re-runs, ids never duplicate
    {
        let c = Coordinator::new(cfg(2));
        let (out, resume) = c.run_resumable(jobs(n), &path).unwrap();
        assert_eq!(resume.skipped, n - 1);
        assert!(resume.orphaned.is_empty(), "the failure was terminal, not orphaned");
        assert_eq!(out.results.len(), 1);
        assert_eq!(out.results[0].id, 5);
    }
    let replay = JournalReplay::load(&path).unwrap();
    assert_eq!(replay.completed.len(), n, "all ids completed exactly once");
    assert!(replay.failed.is_empty());
    let _ = std::fs::remove_file(&path);
}
