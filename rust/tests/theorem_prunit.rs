//! Property suite for **Theorem 7 (PrunIT)** and **Remark 8**:
//! removing a dominated vertex `u` with admissible `f` preserves every
//! persistence diagram, for sublevel and superlevel filtrations — both
//! for a single removal and for the full fixed-point algorithm.

use coral_prunit::complex::Filtration;
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::persistence_diagrams;
use coral_prunit::prune::{find_dominator, prunit, strong_collapse_core};
use coral_prunit::reduce::Reduction;
use coral_prunit::testutil::{forall, random_filtration, random_graph_case};

/// Single-removal form of Theorem 7: find any admissible dominated vertex,
/// remove exactly it, compare all PDs.
#[test]
fn theorem7_single_removal() {
    forall("prunit-single", 60, 0x9147, |rng| {
        let case = random_graph_case(rng, 20);
        let g = &case.graph;
        let f = random_filtration(rng, g);
        // find the first admissible dominated vertex, if any
        let target = (0..g.n() as u32).find(|&u| find_dominator(g, &f, u).is_some());
        let Some(u) = target else { return Ok(()) };
        let keep: Vec<bool> = (0..g.n() as u32).map(|v| v != u).collect();
        let (h, ids) = g.induced(&keep);
        let fh = f.restrict(&ids);
        let before = persistence_diagrams(g, &f, 2);
        let after = persistence_diagrams(&h, &fh, 2);
        for k in 0..=2 {
            if !before[k].same_as(&after[k], 1e-9) {
                return Err(format!(
                    "{}: removing dominated {u} changed PD_{k}: {} vs {}",
                    case.desc, before[k], after[k]
                ));
            }
        }
        Ok(())
    });
}

/// Fixed-point form: the full PrunIT output has all the original PDs.
#[test]
fn theorem7_fixed_point_all_dimensions() {
    forall("prunit-fixedpoint", 50, 0x517, |rng| {
        let case = random_graph_case(rng, 20);
        let g = &case.graph;
        let f = random_filtration(rng, g);
        let r = prunit(g, &f).unwrap();
        let before = persistence_diagrams(g, &f, 2);
        let after = persistence_diagrams(&r.graph, &r.filtration, 2);
        for k in 0..=2 {
            if !before[k].same_as(&after[k], 1e-9) {
                return Err(format!(
                    "{}: PrunIT (removed {}) changed PD_{k}: {} vs {}",
                    case.desc, r.removed, before[k], after[k]
                ));
            }
        }
        Ok(())
    });
}

/// Remark 8: with `f = degree` under superlevel, every vertex dominated
/// *in the original graph* is admissible (`deg(u) ≤ deg(v)` whenever v
/// dominates u). Note this holds only while f's degree values match the
/// graph being inspected — after removals the restricted f keeps ORIGINAL
/// degrees (Remark 1), so later sweeps may legitimately be blocked. The
/// unconditional Strong Collapse is therefore a lower bound on size.
#[test]
fn remark8_degree_superlevel_first_pass_vacuous() {
    forall("remark8", 40, 0x88, |rng| {
        let case = random_graph_case(rng, 30);
        let g = &case.graph;
        let f = Filtration::degree_superlevel(g);
        // (a) in the original graph, domination ⇒ admissibility
        for u in 0..g.n() as u32 {
            for &v in g.neighbors(u) {
                if coral_prunit::prune::dominates(g, u, v) && !f.admissible_removal(u, v) {
                    return Err(format!(
                        "{}: {u} dominated by {v} but inadmissible under degree-superlevel",
                        case.desc
                    ));
                }
            }
        }
        // (b) PrunIT with the condition can never beat the unconditional
        //     collapse, and must remove every originally-dominated vertex
        //     class at least once (removed ≥ 1 whenever SC removes).
        let r = prunit(g, &f).unwrap();
        let (sc, _, sc_removed) = strong_collapse_core(g);
        if r.graph.n() < sc.n() {
            return Err(format!(
                "{}: prunit kept {} < unconditional collapse {}",
                case.desc,
                r.graph.n(),
                sc.n()
            ));
        }
        if sc_removed > 0 && r.removed == 0 {
            return Err(format!(
                "{}: SC removed {sc_removed} but PrunIT removed none despite Rmk 8",
                case.desc
            ));
        }
        Ok(())
    });
}

/// An *inadmissible* removal genuinely breaks diagrams — the test suite
/// can detect violations (negative control for the property above).
#[test]
fn inadmissible_removal_breaks_pd0() {
    // path 0-1-2, sublevel f = [1, 0, 1]: vertex 0 dominated by 1 and
    // admissible... choose f = [0, 1, 0]: vertex 0 dominated by 1 but
    // f(0) < f(1) — removing it anyway changes PD_0.
    let g = gen::path(3);
    let f = Filtration::sublevel(vec![0.0, 1.0, 0.0]);
    let keep = vec![false, true, true];
    let (h, ids) = g.induced(&keep);
    let fh = f.restrict(&ids);
    let before = persistence_diagrams(&g, &f, 0);
    let after = persistence_diagrams(&h, &fh, 0);
    // Before: components born at 0 (two of them; one dies at 1), essential
    // min birth 0. After: births at 0 and 1 → the (0, 1) point vanishes.
    assert!(
        !before[0].same_as(&after[0], 1e-9),
        "negative control failed: {} vs {}",
        before[0],
        after[0]
    );
}

/// Figure 3 worked example from the paper.
#[test]
fn paper_figure3_prunes_dominated_vertices() {
    // 0-indexed Fig 3: vertices 0,1 dominated by 2 (all their neighbours
    // are neighbours of 2).
    let g = Graph::from_edges(5, &[(0, 1), (0, 2), (1, 2), (2, 3), (2, 4), (3, 4)]);
    let f = Filtration::degree_superlevel(&g);
    let r = prunit(&g, &f).unwrap();
    let before = persistence_diagrams(&g, &f, 2);
    let after = persistence_diagrams(&r.graph, &r.filtration, 2);
    for k in 0..=2 {
        assert!(before[k].same_as(&after[k], 1e-9));
    }
    assert!(r.removed >= 2, "both triangles collapse into the hub");
}

/// PrunIT never removes vertices from domination-free graphs.
#[test]
fn irreducible_graphs_are_untouched() {
    for g in [gen::cycle(9), gen::grid(3, 4), gen::octahedron()] {
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(r.removed, 0, "n={} should be irreducible", g.n());
    }
}

/// Idempotence: running PrunIT twice changes nothing the second time.
#[test]
fn prunit_is_idempotent() {
    forall("prunit-idempotent", 30, 0x1de, |rng| {
        let case = random_graph_case(rng, 30);
        let f = random_filtration(rng, &case.graph);
        let r1 = prunit(&case.graph, &f).unwrap();
        let r2 = prunit(&r1.graph, &r1.filtration).unwrap();
        if r2.removed != 0 {
            return Err(format!(
                "{}: second pass removed {} more vertices",
                case.desc, r2.removed
            ));
        }
        Ok(())
    });
}

/// Lemma 5 at the *diagram* level: under the constant filtration every
/// domination is admissible (ties admit both ways), so the unconditional
/// Strong Collapse coincides with a valid Theorem 7 removal chain and
/// must preserve PD_k exactly for k ≤ 2 against the unreduced diagrams.
#[test]
fn strong_collapse_preserves_constant_filtration_diagrams() {
    forall("sc-preserves-pd", 40, 0x5C11, |rng| {
        let case = random_graph_case(rng, 18);
        let g = &case.graph;
        let (h, ids, removed) = strong_collapse_core(g);
        let before = persistence_diagrams(g, &Filtration::constant(g.n()), 2);
        let after = persistence_diagrams(&h, &Filtration::constant(h.n()), 2);
        for k in 0..=2 {
            if !before[k].same_as(&after[k], 1e-12) {
                return Err(format!(
                    "{} (removed {removed}): collapse changed PD_{k}: {} vs {}",
                    case.desc, before[k], after[k]
                ));
            }
        }
        if ids.len() != h.n() {
            return Err(format!("{}: id map size mismatch", case.desc));
        }
        Ok(())
    });
}

/// Cross-check against PrunIT: with a constant filtration the
/// admissibility condition is vacuous, so PrunIT performs the same kind
/// of unconditional collapse (possibly in a different order) and must
/// equally preserve every diagram of the constant filtration.
#[test]
fn constant_filtration_prunit_also_preserves_all_diagrams() {
    forall("const-prunit-pd", 25, 0x5C12, |rng| {
        let case = random_graph_case(rng, 18);
        let g = &case.graph;
        let f = Filtration::constant(g.n());
        let r = prunit(g, &f).unwrap();
        let before = persistence_diagrams(g, &f, 2);
        let after = persistence_diagrams(&r.graph, &r.filtration, 2);
        for k in 0..=2 {
            if !before[k].same_as(&after[k], 1e-12) {
                return Err(format!(
                    "{}: constant-f PrunIT changed PD_{k}",
                    case.desc
                ));
            }
        }
        Ok(())
    });
}

/// Theorem 7 under the PrunIT⇄core alternation: every PrunIT stage of
/// `Reduction::FixedPoint` preserves ALL diagrams, so with k = 1 the
/// only losses the alternation may incur below dimension 1 come from the
/// core stages. Running the alternation with the core threshold disabled
/// is exactly iterated PrunIT — and PrunIT is already a fixed point after
/// one pass (idempotence above) — so the stronger end-to-end statement
/// worth testing here is: FixedPoint's PD_j equals the baseline for all
/// j ≥ k, under arbitrary random filtrations, on the same graph families
/// the single-pass suite uses.
#[test]
fn theorem7_alternation_preserves_pd_above_k() {
    forall("prunit-alternation", 40, 0x517a, |rng| {
        let case = random_graph_case(rng, 20);
        let g = &case.graph;
        let f = random_filtration(rng, g);
        let before = persistence_diagrams(g, &f, 2);
        for k in 1..=2usize {
            let red = coral_prunit::reduce::combined_with(g, &f, k, Reduction::FixedPoint)
                .map_err(|e| e.to_string())?;
            let after = persistence_diagrams(&red.graph, &red.filtration, 2);
            for j in k..=2 {
                if !before[j].same_as(&after[j], 1e-9) {
                    return Err(format!(
                        "{}: alternation (k={k}, {} rounds, removed {}) changed PD_{j}: {} vs {}",
                        case.desc,
                        red.report.rounds_run(),
                        red.report.removed(),
                        before[j],
                        after[j]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Lemma 5 (homotopy equivalence) at the Betti level for the
/// unconditional collapse.
#[test]
fn lemma5_collapse_preserves_betti() {
    forall("lemma5", 40, 0x1e5, |rng| {
        let case = random_graph_case(rng, 18);
        let g = &case.graph;
        let (h, _, _) = strong_collapse_core(g);
        let b_g = coral_prunit::homology::betti_numbers(g, 2);
        let b_h = coral_prunit::homology::betti_numbers(&h, 2);
        if b_g != b_h {
            return Err(format!("{}: betti {b_g:?} vs {b_h:?}", case.desc));
        }
        Ok(())
    });
}
