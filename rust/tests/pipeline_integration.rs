//! Integration tests across the full stack: combined reduction identity,
//! coordinator batches, the XLA dense path against the sparse path, and
//! engine-level cross-validation (standard vs twist vs union-find).

use coral_prunit::complex::{Filtration, FlatComplex};
use coral_prunit::coordinator::{Coordinator, Job, JobSpec};
use coral_prunit::config::CoordinatorConfig;
use coral_prunit::graph::gen;
use coral_prunit::homology::reduction::{diagrams_of_complex, Algorithm};
use coral_prunit::homology::{pd0, persistence_diagrams};
use coral_prunit::reduce::{combined_with, pd_with_reduction, Reduction};
use coral_prunit::runtime::{prunit_dense, try_runtime};
use coral_prunit::testutil::{forall, random_filtration, random_graph_case};

/// §5 composition: `PD_k(G) = PD_k((G')^{k+1})` with all four reduction
/// modes agreeing on PD_k.
#[test]
fn all_reduction_modes_agree_on_pd_k() {
    forall("modes-agree", 40, 0xABCD, |rng| {
        let case = random_graph_case(rng, 20);
        let g = &case.graph;
        let f = random_filtration(rng, g);
        let k = 1usize;
        let (base, _) = pd_with_reduction(g, &f, k, Reduction::None).unwrap();
        for which in [
            Reduction::Coral,
            Reduction::Prunit,
            Reduction::Combined,
            Reduction::FixedPoint,
        ] {
            let (red, report) = pd_with_reduction(g, &f, k, which).unwrap();
            if !base[k].same_as(&red[k], 1e-9) {
                return Err(format!(
                    "{}: PD_{k} via {} ({}→{} vertices): {} vs {}",
                    case.desc,
                    which.name(),
                    report.vertices_before,
                    report.vertices_after,
                    base[k],
                    red[k]
                ));
            }
        }
        // PrunIT additionally preserves PD_0
        let (p, _) = pd_with_reduction(g, &f, k, Reduction::Prunit).unwrap();
        if !base[0].same_as(&p[0], 1e-9) {
            return Err(format!("{}: PrunIT broke PD_0", case.desc));
        }
        Ok(())
    });
}

/// Combined reduces at least as much as either standalone algorithm.
#[test]
fn combined_dominates_either_alone() {
    forall("combined-dominates", 30, 0xBEE, |rng| {
        let case = random_graph_case(rng, 40);
        let g = &case.graph;
        let f = Filtration::degree_superlevel(g);
        let coral = combined_with(g, &f, 1, Reduction::Coral).unwrap();
        let pru = combined_with(g, &f, 1, Reduction::Prunit).unwrap();
        let both = combined_with(g, &f, 1, Reduction::Combined).unwrap();
        if both.graph.n() > coral.graph.n() || both.graph.n() > pru.graph.n() {
            return Err(format!(
                "{}: combined kept {} vs coral {} / prunit {}",
                case.desc,
                both.graph.n(),
                coral.graph.n(),
                pru.graph.n()
            ));
        }
        Ok(())
    });
}

/// Coordinator on a realistic batch reproduces inline results and its
/// metrics add up.
#[test]
fn coordinator_batch_end_to_end() {
    let recipe = coral_prunit::datasets::find("DHFR").unwrap();
    let jobs: Vec<Job> = (0..recipe.instances)
        .map(|i| Job::degree_superlevel(i as u64, recipe.make(7, i), JobSpec::default()))
        .collect();
    let expected: Vec<_> = jobs.iter().map(|j| Coordinator::execute(j, 0).unwrap()).collect();
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 4,
        queue_depth: 2,
        max_k: 1,
        reduction: "prunit+coral".into(),
        seed: 7,
        prune_threads: 1,
        ..CoordinatorConfig::default()
    });
    let got = coord.run(jobs).unwrap();
    assert_eq!(got.len(), expected.len());
    for (a, b) in expected.iter().zip(&got) {
        assert_eq!(a.id, b.id);
        for k in 0..a.diagrams.len() {
            assert!(a.diagrams[k].same_as(&b.diagrams[k], 1e-12));
        }
        assert_eq!(a.reduction.vertices_after, b.reduction.vertices_after);
    }
    assert_eq!(coord.metrics().completed() as usize, got.len());
    assert!(coord.metrics().vertex_reduction_pct() > 0.0);
}

/// Dense (XLA Pallas artifact) and sparse PrunIT both preserve every PD;
/// their fixed-point sizes agree under degree-superlevel (Remark 8 makes
/// admissibility vacuous, so both peel maximally).
#[test]
fn xla_dense_path_equivalent_to_sparse() {
    let Some(rt) = try_runtime() else {
        eprintln!(
            "skipping xla_dense_path_equivalent_to_sparse: dense backend unavailable \
             (build with `--features xla` and run `make artifacts`)"
        );
        return;
    };
    forall("dense-vs-sparse", 12, 0xD0D0, |rng| {
        let case = random_graph_case(rng, 50);
        let g = &case.graph;
        if g.n() > rt.max_order() {
            return Ok(());
        }
        let f = Filtration::degree_superlevel(g);
        let dense = prunit_dense(&rt, g, &f).map_err(|e| e.to_string())?;
        let sparse = coral_prunit::prune::prunit(g, &f).unwrap();
        if dense.graph.n() != sparse.graph.n() {
            return Err(format!(
                "{}: dense kept {} vs sparse {}",
                case.desc,
                dense.graph.n(),
                sparse.graph.n()
            ));
        }
        let base = persistence_diagrams(g, &f, 1);
        let dd = persistence_diagrams(&dense.graph, &dense.filtration, 1);
        for k in 0..=1 {
            if !base[k].same_as(&dd[k], 1e-9) {
                return Err(format!("{}: dense path broke PD_{k}", case.desc));
            }
        }
        Ok(())
    });
}

/// Engine cross-validation: standard reduction, twist reduction, and the
/// union-find PD_0 fast path agree everywhere.
#[test]
fn engine_three_way_agreement() {
    forall("engine-agreement", 40, 0xE4, |rng| {
        let case = random_graph_case(rng, 22);
        let g = &case.graph;
        let f = random_filtration(rng, g);
        let c = FlatComplex::build(g, &f, 3);
        let std_pds = diagrams_of_complex(&c, 2, Algorithm::Standard);
        let twist_pds = diagrams_of_complex(&c, 2, Algorithm::Twist);
        for k in 0..=2 {
            if !std_pds[k].same_as(&twist_pds[k], 1e-12) {
                return Err(format!("{}: standard vs twist PD_{k}", case.desc));
            }
        }
        let uf = pd0(g, &f);
        if !uf.same_as(&std_pds[0], 1e-12) {
            return Err(format!(
                "{}: union-find vs matrix PD_0: {} vs {}",
                case.desc, uf, std_pds[0]
            ));
        }
        Ok(())
    });
}

/// Euler characteristic consistency: Σ (−1)^k · #k-simplices =
/// Σ (−1)^k · β_k for the full clique complex (a deep global check on the
/// clique enumeration AND the reduction together).
#[test]
fn euler_characteristic_matches_betti_alternating_sum() {
    forall("euler", 25, 0xEC, |rng| {
        let case = random_graph_case(rng, 16);
        let g = &case.graph;
        if g.n() == 0 {
            return Ok(());
        }
        // full clique complex: cap by degeneracy+1 (max clique size)
        let d = coral_prunit::kcore::degeneracy(g);
        let max_dim = d + 1;
        let c = FlatComplex::build(g, &Filtration::constant(g.n()), max_dim + 1);
        let counts = c.counts_by_dim();
        let chi_simplices: i64 = counts
            .iter()
            .enumerate()
            .map(|(k, &c)| if k % 2 == 0 { c as i64 } else { -(c as i64) })
            .sum();
        let betti = coral_prunit::homology::betti_numbers(g, max_dim);
        let chi_betti: i64 = betti
            .iter()
            .enumerate()
            .map(|(k, &b)| if k % 2 == 0 { b as i64 } else { -(b as i64) })
            .sum();
        if chi_simplices != chi_betti {
            return Err(format!(
                "{}: χ(simplices)={chi_simplices} vs χ(betti)={chi_betti} (counts {counts:?}, betti {betti:?})",
                case.desc
            ));
        }
        Ok(())
    });
}

/// Ego-network workload slice (§6.2): PD_0 on 1-hop neighbourhoods with
/// and without PrunIT agrees for every ego vertex of a citation graph.
#[test]
fn ego_network_pd0_with_prunit() {
    let g = coral_prunit::datasets::recipes::citation(400, 800, 3);
    let mut rng = coral_prunit::util::Rng::new(9);
    for _ in 0..25 {
        let center = rng.below(g.n()) as u32;
        let verts = g.ego_vertices(center, 1);
        let (ego, _) = g.induced_on(&verts);
        let f = Filtration::degree_superlevel(&ego);
        let base = pd0(&ego, &f);
        let r = coral_prunit::prune::prunit(&ego, &f).unwrap();
        let red = pd0(&r.graph, &r.filtration);
        assert!(
            base.same_as(&red, 1e-9),
            "ego {center}: {base} vs {red} after pruning {} vertices",
            r.removed
        );
    }
}
