//! Differential suite for the unified residue-domination kernel
//! (ISSUE 6 tentpole): every kernel policy (merge walk, chunked-u64
//! bitset, per-round auto) at every thread count must produce the
//! **bit-identical** residue, frontier-round count, and check count as
//! the sequential reference `prune::prunit` — on a corpus spanning
//! sparse fringes (merge territory), dense cores and hubs (bitset
//! territory), and graphs large enough to engage the scoped-thread
//! frontier sweep. Persistence diagrams and the per-round kernel census
//! recorded in `RoundStats` are checked on top.

use coral_prunit::complex::Filtration;
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::persistence_diagrams;
use coral_prunit::prune::{prunit, DominationKernel, KernelChoice};
use coral_prunit::reduce::{combined_with_ws, Reduction, ReductionWorkspace, PAR_FRONTIER_MIN};

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

const KERNELS: [DominationKernel; 3] = [
    DominationKernel::Merge,
    DominationKernel::Bitset,
    DominationKernel::Auto,
];

/// The corpus: (description, graph). Spans both sides of the auto
/// crossover — sparse ER/BA fringes resolve to the merge walk, dense
/// blocks and cliques to the bitset — plus structured cases (stars,
/// twins) where domination cascades.
fn corpus() -> Vec<(String, Graph)> {
    let mut out: Vec<(String, Graph)> = Vec::new();
    for (n, p, seed) in [
        (30usize, 0.3f64, 1u64),
        (120, 0.08, 2),
        (700, 0.15, 3),
        (2048, 0.003, 4),
        (3000, 5.0 / 3000.0, 5),
    ] {
        out.push((format!("ER({n},{p})"), gen::erdos_renyi(n, p, seed)));
    }
    for (n, m, seed) in [(100usize, 2usize, 6u64), (3000, 3, 7)] {
        out.push((format!("BA({n},{m})"), gen::barabasi_albert(n, m, seed)));
    }
    let mut edges: Vec<(u32, u32)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
    edges.push((0, 6));
    edges.push((6, 7));
    out.push(("cycle+tail".into(), Graph::from_edges(8, &edges)));
    out.push(("star(80)".into(), gen::star(80)));
    out.push(("complete(24)".into(), gen::complete(24)));
    out
}

#[test]
fn every_kernel_and_thread_count_matches_the_sequential_reference() {
    for (desc, g) in corpus() {
        let f = Filtration::degree_superlevel(&g);
        let reference = prunit(&g, &f).unwrap();
        for kernel in KERNELS {
            for threads in THREAD_SWEEP {
                let mut ws = ReductionWorkspace::with_prune_threads(threads);
                ws.set_domination_kernel(kernel);
                ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
                let kept: Vec<u32> = (0..g.n() as u32)
                    .filter(|&v| ws.alive()[v as usize])
                    .collect();
                let tag = format!("{desc} kernel={} threads={threads}", kernel.name());
                assert_eq!(kept, reference.kept_old_ids, "{tag}: alive set");
                assert_eq!(ws.frontier_rounds(), reference.rounds, "{tag}: rounds");
                assert_eq!(ws.checks(), reference.checks, "{tag}: checks");
            }
        }
    }
}

#[test]
fn corpus_engages_both_kernels_and_the_parallel_sweep() {
    let graphs = corpus();
    let big = graphs.iter().filter(|(_, g)| g.n() >= PAR_FRONTIER_MIN).count();
    assert!(big >= 3, "corpus must keep several super-threshold graphs");
    // under Auto, at least one corpus member must resolve some round to
    // each kernel — otherwise the differential covers only one code path
    let mut merge = 0usize;
    let mut bitset = 0usize;
    for (_, g) in &graphs {
        let f = Filtration::degree_superlevel(g);
        let mut ws = ReductionWorkspace::new();
        ws.plan(g, &f, 1, Reduction::Prunit).unwrap();
        merge += ws
            .kernel_rounds()
            .iter()
            .filter(|&&k| k == KernelChoice::Merge)
            .count();
        bitset += ws
            .kernel_rounds()
            .iter()
            .filter(|&&k| k == KernelChoice::Bitset)
            .count();
    }
    assert!(merge > 0, "auto never picked the merge walk on the corpus");
    assert!(bitset > 0, "auto never picked the bitset kernel on the corpus");
}

#[test]
fn diagrams_are_identical_across_kernels() {
    for (desc, g) in corpus().into_iter().filter(|(_, g)| g.n() <= 150) {
        let f = Filtration::degree_superlevel(&g);
        let before = persistence_diagrams(&g, &f, 1);
        let mut reduced: Vec<Vec<coral_prunit::homology::Diagram>> = Vec::new();
        for kernel in KERNELS {
            let mut ws = ReductionWorkspace::with_domination_kernel(kernel);
            let red = combined_with_ws(&mut ws, &g, &f, 1, Reduction::Prunit).unwrap();
            let after = persistence_diagrams(&red.graph, &red.filtration, 1);
            for k in 0..=1 {
                assert!(
                    before[k].same_as(&after[k], 1e-9),
                    "{desc} kernel={} PD_{k}",
                    kernel.name()
                );
            }
            reduced.push(after);
        }
        // across kernels the reduced diagrams must agree exactly, not
        // merely within tolerance of the unreduced ones
        for pds in &reduced[1..] {
            for k in 0..=1 {
                assert!(reduced[0][k].same_as(&pds[k], 0.0), "{desc} PD_{k} drifted");
            }
        }
    }
}

#[test]
fn round_stats_record_the_kernel_census() {
    let g = gen::complete(30);
    let f = Filtration::degree_superlevel(&g);
    let mut ws = ReductionWorkspace::new();
    let red = combined_with_ws(&mut ws, &g, &f, 1, Reduction::Prunit).unwrap();
    let merge = red.report.merge_kernel_rounds();
    let bitset = red.report.bitset_kernel_rounds();
    assert_eq!(
        merge + bitset,
        ws.frontier_rounds(),
        "every frontier round must be attributed to exactly one kernel"
    );
    assert_eq!(ws.kernel_rounds().len(), ws.frontier_rounds());
    assert!(bitset > 0, "K30 rounds are dense; auto must pick the bitset");

    // pinned runs report a one-sided census
    let mut mws = ReductionWorkspace::with_domination_kernel(DominationKernel::Merge);
    let mred = combined_with_ws(&mut mws, &g, &f, 1, Reduction::Prunit).unwrap();
    assert_eq!(mred.report.bitset_kernel_rounds(), 0);
    assert_eq!(mred.report.merge_kernel_rounds(), mws.frontier_rounds());
    let mut bws = ReductionWorkspace::with_domination_kernel(DominationKernel::Bitset);
    let bred = combined_with_ws(&mut bws, &g, &f, 1, Reduction::Prunit).unwrap();
    assert_eq!(bred.report.merge_kernel_rounds(), 0);
    assert_eq!(bred.report.bitset_kernel_rounds(), bws.frontier_rounds());
    // and the pinned residues agree with each other
    assert_eq!(mred.graph, bred.graph);
    assert_eq!(mred.kept_old_ids, bred.kept_old_ids);
}

#[test]
fn fixed_point_alternation_is_kernel_invariant() {
    let g = gen::barabasi_albert(2500, 3, 13);
    let f = Filtration::degree_superlevel(&g);
    let mut mws = ReductionWorkspace::with_domination_kernel(DominationKernel::Merge);
    let reference = combined_with_ws(&mut mws, &g, &f, 1, Reduction::FixedPoint).unwrap();
    for kernel in [DominationKernel::Bitset, DominationKernel::Auto] {
        for threads in [1usize, 4] {
            let mut ws = ReductionWorkspace::with_prune_threads(threads);
            ws.set_domination_kernel(kernel);
            let red = combined_with_ws(&mut ws, &g, &f, 1, Reduction::FixedPoint).unwrap();
            let tag = format!("kernel={} threads={threads}", kernel.name());
            assert_eq!(red.graph, reference.graph, "{tag}");
            assert_eq!(red.kept_old_ids, reference.kept_old_ids, "{tag}");
            assert_eq!(
                red.report.prunit_rounds, reference.report.prunit_rounds,
                "{tag}: frontier schedule"
            );
        }
    }
}

#[test]
fn kernel_reconfiguration_between_plans_is_stateless() {
    let g = gen::erdos_renyi(700, 0.15, 3);
    let f = Filtration::degree_superlevel(&g);
    let reference = prunit(&g, &f).unwrap();
    let mut ws = ReductionWorkspace::new();
    for kernel in [
        DominationKernel::Bitset,
        DominationKernel::Merge,
        DominationKernel::Auto,
        DominationKernel::Bitset,
        DominationKernel::Merge,
    ] {
        ws.set_domination_kernel(kernel);
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        let kept: Vec<u32> = (0..g.n() as u32)
            .filter(|&v| ws.alive()[v as usize])
            .collect();
        assert_eq!(kept, reference.kept_old_ids, "kernel={}", kernel.name());
        assert_eq!(ws.checks(), reference.checks, "kernel={}", kernel.name());
    }
}
