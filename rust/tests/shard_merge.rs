//! Exact-merge edge cases for the component-sharded persistence pipeline:
//! degenerate graphs, the single-component identity, and known spaces
//! whose Betti numbers must add component-wise.

use coral_prunit::complex::Filtration;
use coral_prunit::graph::decompose::{decompose_filtered, disjoint_union};
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::{persistence_diagrams, persistence_diagrams_sharded};
use coral_prunit::reduce::{pd_sharded, pd_with_reduction, Reduction};

fn assert_same(a: &[coral_prunit::homology::Diagram], b: &[coral_prunit::homology::Diagram]) {
    assert_eq!(a.len(), b.len());
    for k in 0..a.len() {
        assert!(
            a[k].same_as(&b[k], 1e-12),
            "PD_{k} mismatch: {} vs {}",
            a[k],
            b[k]
        );
    }
}

// ---------- degenerate inputs ----------

#[test]
fn empty_graph_all_paths_empty() {
    let g = Graph::empty(0);
    let f = Filtration::constant(0);
    let mono = persistence_diagrams(&g, &f, 2);
    let shard = persistence_diagrams_sharded(&g, &f, 2, 4);
    assert_same(&mono, &shard);
    assert!(shard.iter().all(|d| d.is_empty()));
    let (pds, report) = pd_sharded(&g, &f, 2, Reduction::Combined, 4).unwrap();
    assert_eq!(report.shard_count(), 0);
    assert!(pds.iter().all(|d| d.is_empty()));
}

#[test]
fn all_isolated_vertices_one_essential_class_each() {
    let g = Graph::empty(6);
    let f = Filtration::sublevel(vec![3.0, 1.0, 4.0, 1.0, 5.0, 9.0]);
    let mono = persistence_diagrams(&g, &f, 1);
    let shard = persistence_diagrams_sharded(&g, &f, 1, 3);
    assert_same(&mono, &shard);
    assert_eq!(shard[0].betti(), 6, "one essential class per shard");
    assert!(shard[1].is_empty());
    // every shard is a singleton
    let shards = decompose_filtered(&g, &f);
    assert_eq!(shards.len(), 6);
    assert!(shards.iter().all(|s| s.graph.n() == 1));
}

#[test]
fn single_component_shard_path_is_identity() {
    for g in [
        gen::cycle(9),
        gen::complete(6),
        gen::octahedron(),
        gen::powerlaw_cluster(40, 3, 0.6, 5),
    ] {
        let f = Filtration::degree_superlevel(&g);
        let mono = persistence_diagrams(&g, &f, 2);
        for workers in [1usize, 2, 4] {
            let shard = persistence_diagrams_sharded(&g, &f, 2, workers);
            assert_same(&mono, &shard);
        }
        let shards = decompose_filtered(&g, &f);
        assert_eq!(shards.len(), 1, "connected graph is one shard");
        assert_eq!(shards[0].graph, g);
    }
}

// ---------- known spaces: Betti numbers add component-wise ----------

#[test]
fn octahedron_cycle_complete_betti_add() {
    // β(octahedron) = (1, 0, 1); β(C8) = (1, 1, 0); β(K5) = (1, 0, 0)
    // → union: β0 = 3, β1 = 1, β2 = 1.
    let g = disjoint_union(&[gen::octahedron(), gen::cycle(8), gen::complete(5)]);
    let f = Filtration::constant(g.n());
    let shard = persistence_diagrams_sharded(&g, &f, 2, 3);
    assert_eq!(shard[0].betti(), 3);
    assert_eq!(shard[1].betti(), 1);
    assert_eq!(shard[2].betti(), 1);
    // and the merged diagrams equal the monolithic engine's
    let mono = persistence_diagrams(&g, &f, 2);
    assert_same(&mono, &shard);
}

#[test]
fn merged_diagram_points_carry_per_component_values() {
    // Two cycles with distinct filtration plateaus: the merged PD_1 must
    // contain one essential loop born at each plateau's key.
    let g = disjoint_union(&[gen::cycle(4), gen::cycle(5)]);
    let mut vals = vec![2.0; 4];
    vals.extend(vec![7.0; 5]);
    let f = Filtration::sublevel(vals);
    let shard = persistence_diagrams_sharded(&g, &f, 1, 2);
    assert_eq!(shard[1].betti(), 2);
    assert_eq!(shard[1].essential(), vec![2.0, 7.0]);
    let mono = persistence_diagrams(&g, &f, 1);
    assert_same(&mono, &shard);
}

// ---------- sharded reduction pipeline ----------

#[test]
fn pd_sharded_agrees_with_monolithic_for_every_reduction() {
    let g = disjoint_union(&[
        gen::barabasi_albert(25, 2, 1),
        gen::cycle(7),
        gen::erdos_renyi(18, 0.3, 2),
        Graph::empty(3),
    ]);
    let f = Filtration::degree_superlevel(&g);
    for which in [
        Reduction::None,
        Reduction::Coral,
        Reduction::Prunit,
        Reduction::Combined,
        Reduction::FixedPoint,
    ] {
        let (mono, mono_report) = pd_with_reduction(&g, &f, 1, which).unwrap();
        let (shard, report) = pd_sharded(&g, &f, 1, which, 2).unwrap();
        assert_same(&mono, &shard);
        // shard census covers the reduced residue exactly, and matches
        // the component count of the monolithically-compacted graph
        assert_eq!(report.vertices_after, mono_report.vertices_after);
        assert_eq!(
            report.shard_sizes.iter().sum::<usize>(),
            report.vertices_after,
            "{}: shard census must cover the reduced graph",
            which.name()
        );
        let mono_red = coral_prunit::reduce::combined_with(&g, &f, 1, which).unwrap();
        assert_eq!(report.shard_count(), mono_red.graph.components());
    }
}

#[test]
fn coral_shatters_then_shards_exactly() {
    // A graph designed to shatter under the 2-core: several cycles, each
    // with tree decorations that coral peels away, leaving 4 components.
    let mut parts = Vec::new();
    for seed in 0..4u64 {
        let cycle = gen::cycle(6 + seed as usize);
        let mut edges: Vec<(u32, u32)> = cycle.edges().collect();
        let n = cycle.n() as u32;
        // pendant path hanging off vertex 0
        edges.push((0, n));
        edges.push((n, n + 1));
        parts.push(Graph::from_edges(n as usize + 2, &edges));
    }
    let g = disjoint_union(&parts);
    let f = Filtration::degree_superlevel(&g);
    let (mono, _) = pd_with_reduction(&g, &f, 1, Reduction::Coral).unwrap();
    let (shard, report) = pd_sharded(&g, &f, 1, Reduction::Coral, 2).unwrap();
    assert_eq!(report.shard_count(), 4, "2-core = the four bare cycles");
    assert!(report.largest_shard() <= 9);
    assert_same(&mono, &shard);
    assert_eq!(shard[1].betti(), 4, "one essential loop per cycle");
}
