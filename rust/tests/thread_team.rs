//! Differential suite for the persistent thread-team check phase (ISSUE 8
//! tentpole): at every thread count, the team-dispatched parallel PrunIT
//! must produce the **bit-identical** residue, frontier-round count, and
//! check count as both the scoped-thread reference backend
//! (`ParallelBackend::Scoped`, the pre-team spawn-per-round path) and the
//! sequential reference `prune::prunit` — and the adaptive ramp
//! (`prune_threads = 0`) must be wall-time-only: same residues, same
//! schedule, run after run.

use coral_prunit::complex::Filtration;
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::persistence_diagrams;
use coral_prunit::prune::prunit;
use coral_prunit::reduce::{
    combined_with_ws, ParallelBackend, Reduction, ReductionWorkspace, PAR_FRONTIER_MIN,
};

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// Corpus mixing inline-sized graphs with graphs whose round-1 frontier
/// clears `PAR_FRONTIER_MIN`, so the team dispatch path actually engages.
fn corpus() -> Vec<(String, Graph)> {
    let mut out: Vec<(String, Graph)> = Vec::new();
    for (n, p, seed) in [
        (120usize, 0.08f64, 2u64),
        (800, 0.01, 3),
        (3000, 5.0 / 3000.0, 5),
    ] {
        out.push((format!("ER({n},{p})"), gen::erdos_renyi(n, p, seed)));
    }
    out.push(("BA(3000,3)".into(), gen::barabasi_albert(3000, 3, 7)));
    out.push(("star(50)".into(), gen::star(50)));
    // cycle with a pendant tail: PD_1 must survive the collapse
    let mut edges: Vec<(u32, u32)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
    edges.push((0, 6));
    edges.push((6, 7));
    out.push(("cycle+tail".into(), Graph::from_edges(8, &edges)));
    out
}

fn workspace(threads: usize, backend: ParallelBackend) -> ReductionWorkspace {
    let mut ws = ReductionWorkspace::with_prune_threads(threads);
    ws.set_parallel_backend(backend);
    ws
}

fn kept(ws: &ReductionWorkspace, n: usize) -> Vec<u32> {
    (0..n as u32).filter(|&v| ws.alive()[v as usize]).collect()
}

#[test]
fn corpus_engages_the_team_dispatch_path() {
    let big = corpus()
        .into_iter()
        .filter(|(_, g)| g.n() >= PAR_FRONTIER_MIN)
        .count();
    assert!(big >= 2, "corpus must keep several super-threshold graphs");
}

#[test]
fn team_matches_scoped_and_sequential_residues() {
    for (desc, g) in corpus() {
        let f = Filtration::degree_superlevel(&g);
        let reference = prunit(&g, &f).unwrap();
        for threads in THREAD_SWEEP {
            for backend in [ParallelBackend::Team, ParallelBackend::Scoped] {
                let mut ws = workspace(threads, backend);
                ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
                assert_eq!(
                    kept(&ws, g.n()),
                    reference.kept_old_ids,
                    "{desc} threads={threads} {backend:?}: alive set"
                );
                assert_eq!(
                    ws.frontier_rounds(),
                    reference.rounds,
                    "{desc} threads={threads} {backend:?}: rounds"
                );
                assert_eq!(
                    ws.checks(),
                    reference.checks,
                    "{desc} threads={threads} {backend:?}: checks"
                );
            }
        }
    }
}

#[test]
fn team_fixed_point_matches_scoped_backend_exactly() {
    // multi-round FixedPoint is where the persistent team pays off: many
    // short dispatches per plan. Both backends must agree on everything
    // observable, including how many rounds went parallel.
    for (desc, g) in corpus().into_iter().filter(|(_, g)| g.n() >= 500) {
        let f = Filtration::degree_superlevel(&g);
        for threads in [2usize, 4, 8] {
            let mut team_ws = workspace(threads, ParallelBackend::Team);
            let team = combined_with_ws(&mut team_ws, &g, &f, 1, Reduction::FixedPoint).unwrap();
            let mut scoped_ws = workspace(threads, ParallelBackend::Scoped);
            let scoped =
                combined_with_ws(&mut scoped_ws, &g, &f, 1, Reduction::FixedPoint).unwrap();
            assert_eq!(team.graph, scoped.graph, "{desc} threads={threads}");
            assert_eq!(team.kept_old_ids, scoped.kept_old_ids, "{desc} threads={threads}");
            assert_eq!(
                team.report.prunit_rounds, scoped.report.prunit_rounds,
                "{desc} threads={threads}: frontier schedule"
            );
            assert_eq!(
                team_ws.par_frontier_rounds(),
                scoped_ws.par_frontier_rounds(),
                "{desc} threads={threads}: parallel-round count"
            );
            // the dispatch path really engaged, and only the team backend
            // spawned workers (at most threads-1: the leader takes part 0,
            // and the chunk floor can cap fan-out below the request)
            assert!(team_ws.par_frontier_rounds() > 0, "{desc} threads={threads}");
            let w = team_ws.team_workers();
            assert!(
                (1..threads).contains(&w),
                "{desc} threads={threads}: team_workers={w}"
            );
            assert_eq!(scoped_ws.team_workers(), 0, "{desc}: scoped never spawns a team");
        }
    }
}

#[test]
fn team_preserves_diagrams_on_small_corpus() {
    // Theorem 7 end-to-end through the team path (PD computation bounds
    // this to the small corpus members)
    for (desc, g) in corpus().into_iter().filter(|(_, g)| g.n() <= 150) {
        let f = Filtration::degree_superlevel(&g);
        let before = persistence_diagrams(&g, &f, 1);
        for threads in THREAD_SWEEP {
            let mut ws = workspace(threads, ParallelBackend::Team);
            let red = combined_with_ws(&mut ws, &g, &f, 1, Reduction::Prunit).unwrap();
            let after = persistence_diagrams(&red.graph, &red.filtration, 1);
            for k in 0..=1 {
                assert!(
                    before[k].same_as(&after[k], 1e-9),
                    "{desc} threads={threads} PD_{k}: {} vs {}",
                    before[k],
                    after[k]
                );
            }
        }
    }
}

#[test]
fn adaptive_mode_is_deterministic_and_residue_invariant() {
    // prune_threads = 0: the per-round thread count comes from a measured
    // cost model, so it may differ run to run — everything the caller can
    // observe besides wall time must not
    let g = gen::erdos_renyi(3000, 5.0 / 3000.0, 5);
    let f = Filtration::degree_superlevel(&g);
    let mut seq = ReductionWorkspace::with_prune_threads(1);
    let reference = combined_with_ws(&mut seq, &g, &f, 1, Reduction::FixedPoint).unwrap();
    for trial in 0..3 {
        let mut ws = ReductionWorkspace::with_prune_threads(0);
        let red = combined_with_ws(&mut ws, &g, &f, 1, Reduction::FixedPoint).unwrap();
        assert_eq!(red.graph, reference.graph, "trial={trial}");
        assert_eq!(red.kept_old_ids, reference.kept_old_ids, "trial={trial}");
        assert_eq!(
            red.report.prunit_rounds, reference.report.prunit_rounds,
            "trial={trial}: the frontier schedule is thread-invariant"
        );
        assert_eq!(ws.checks(), seq.checks(), "trial={trial}: check count");
        // telemetry self-consistency: one log entry per frontier round,
        // parallel rounds are exactly the entries that fanned out
        assert_eq!(ws.round_thread_log().len(), ws.frontier_rounds(), "trial={trial}");
        assert_eq!(
            ws.par_frontier_rounds(),
            ws.round_thread_log().iter().filter(|&&t| t > 1).count(),
            "trial={trial}"
        );
    }
}

#[test]
fn one_team_serves_the_whole_corpus() {
    // a single workspace (one team) planning every corpus member must
    // match fresh sequential runs each time — persistent workers carry no
    // state between rounds or plans
    let mut ws = workspace(4, ParallelBackend::Team);
    for (desc, g) in corpus() {
        let f = Filtration::degree_superlevel(&g);
        let reference = prunit(&g, &f).unwrap();
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        assert_eq!(kept(&ws, g.n()), reference.kept_old_ids, "{desc}");
        assert_eq!(ws.frontier_rounds(), reference.rounds, "{desc}");
    }
    assert_eq!(
        ws.team_workers(),
        3,
        "the team spawned once and survived the corpus"
    );
}
