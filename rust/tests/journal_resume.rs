//! Kill-and-resume smoke test: SIGKILL a journaled `repro batch` mid
//! flight, re-run it with the same journal, and prove the batch
//! converges with no duplicated and no missing job ids.
//!
//! Drives the real binary (`CARGO_BIN_EXE_repro`) so the whole stack is
//! exercised: CLI flag parsing, journal replay, scheduler, and the
//! per-record flush discipline that makes a SIGKILL survivable.

#![cfg(unix)]

use std::collections::BTreeMap;
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use coral_prunit::coordinator::JournalReplay;

const DATASET: &str = "DD"; // 12 instances of the largest kernel graphs
const INSTANCES: u64 = 12;

/// Journal location: `JOURNAL_RESUME_PATH` when set (CI points it into
/// the workspace and uploads the file as an artifact), a tempdir path
/// otherwise.
fn journal_path() -> std::path::PathBuf {
    let p = match std::env::var_os("JOURNAL_RESUME_PATH") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            let mut p = std::env::temp_dir();
            p.push(format!("coraltda-kill-resume-{}.jsonl", std::process::id()));
            p
        }
    };
    let _ = std::fs::remove_file(&p);
    p
}

fn batch_cmd(journal: &std::path::Path) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_repro"));
    cmd.args([
        "batch",
        "--dataset",
        DATASET,
        "--workers",
        "1",
        "--journal",
    ])
    .arg(journal)
    .stdout(Stdio::null())
    .stderr(Stdio::null());
    cmd
}

/// Count `completed` records per id from the raw journal, to catch
/// double execution that the replayed set view would hide.
fn completed_counts(path: &std::path::Path) -> BTreeMap<u64, usize> {
    let text = std::fs::read_to_string(path).unwrap_or_default();
    let mut counts = BTreeMap::new();
    for line in text.lines() {
        if !line.contains("\"event\":\"completed\"") {
            continue;
        }
        if let Some(rest) = line.split("\"id\":").nth(1) {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(id) = digits.parse::<u64>() {
                *counts.entry(id).or_insert(0) += 1;
            }
        }
    }
    counts
}

#[test]
fn sigkill_mid_batch_then_resume_converges_without_duplicates() {
    let journal = journal_path();

    // Incarnation 1: kill -9 as soon as at least one job has completed
    // (so the journal is non-trivial) — mid-batch when the machine is
    // slow enough, post-batch otherwise; both must resume cleanly.
    let mut child = batch_cmd(&journal).spawn().expect("spawn repro batch");
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let done = JournalReplay::load(&journal)
            .map(|r| r.completed.len())
            .unwrap_or(0);
        if done >= 1 {
            break;
        }
        if child.try_wait().expect("poll child").is_some() {
            break; // finished before we saw a record — still fine
        }
        assert!(Instant::now() < deadline, "no progress within 120s");
        std::thread::sleep(Duration::from_millis(10));
    }
    let _ = child.kill(); // SIGKILL on unix — no cleanup handlers run
    let _ = child.wait();

    let after_kill = JournalReplay::load(&journal).unwrap();
    assert!(
        !after_kill.completed.is_empty(),
        "the flushed journal must have survived the kill"
    );

    // Incarnation 2: same command, same journal — replays and finishes.
    let status = batch_cmd(&journal).status().expect("resume repro batch");
    assert!(status.success(), "resumed batch failed: {status:?}");

    // Convergence: every id completed, none orphaned, none run twice.
    let replay = JournalReplay::load(&journal).unwrap();
    let expected: Vec<u64> = (0..INSTANCES).collect();
    let completed: Vec<u64> = replay.completed.iter().copied().collect();
    assert_eq!(completed, expected, "missing or extra job ids");
    assert!(replay.orphaned().is_empty(), "orphans after resume");
    assert!(replay.failed.is_empty());
    for (id, count) in completed_counts(&journal) {
        assert_eq!(count, 1, "job {id} completed {count} times (duplicate run)");
    }

    // keep the journal when CI pinned its location (artifact upload)
    if std::env::var_os("JOURNAL_RESUME_PATH").is_none() {
        let _ = std::fs::remove_file(&journal);
    }
}
