//! Differential suite for the round-synchronous parallel PrunIT frontier
//! (ISSUE 5 tentpole): at every thread count the planner must produce the
//! **bit-identical** residue, frontier-round count, and check count as
//! the sequential reference `prune::prunit`, on a seeded ER/BA/structured
//! corpus — including graphs large enough that the scoped-thread check
//! phase actually engages (round-1 frontier ≥ `PAR_FRONTIER_MIN`) and
//! crafted adjacent-domination conflict cases where naive simultaneous
//! removal would destroy homology.

use coral_prunit::complex::Filtration;
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::persistence_diagrams;
use coral_prunit::prune::prunit;
use coral_prunit::reduce::{
    combined_with_materializing, combined_with_ws, pd_sharded_with, Reduction,
    ReductionWorkspace, PAR_FRONTIER_MIN,
};

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// The corpus: (description, graph). Mixes small graphs (inline sweep),
/// large sparse graphs (parallel sweep), hubs (bitset domination path),
/// and conflict-heavy structures (twin classes).
fn corpus() -> Vec<(String, Graph)> {
    let mut out: Vec<(String, Graph)> = Vec::new();
    for (n, p, seed) in [
        (30usize, 0.3f64, 1u64),
        (120, 0.08, 2),
        (800, 0.01, 3),
        (2048, 0.003, 4),
        (3000, 5.0 / 3000.0, 5),
    ] {
        out.push((format!("ER({n},{p})"), gen::erdos_renyi(n, p, seed)));
    }
    for (n, m, seed) in [(100usize, 2usize, 6u64), (3000, 3, 7)] {
        out.push((format!("BA({n},{m})"), gen::barabasi_albert(n, m, seed)));
    }
    // cycle with a pendant tail: coring fodder with PD_1 that must survive
    let mut edges: Vec<(u32, u32)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
    edges.push((0, 6));
    edges.push((6, 7));
    out.push(("cycle+tail".into(), Graph::from_edges(8, &edges)));
    out.push(("star(50)".into(), gen::star(50)));
    out.push(("complete(12)".into(), gen::complete(12)));
    out
}

/// A crafted adjacent-domination conflict graph: two twin pairs wired so
/// round 1 is all conflicts and the resolution cascades. {0,1} are
/// adjacent twins, {2,3} are adjacent twins, every twin sees both members
/// of the other pair — so all four vertices are dominated candidates in
/// round 1 and witness deaths force deferrals.
fn conflict_graph() -> Graph {
    Graph::from_edges(
        5,
        &[
            (0, 1), // twins A
            (2, 3), // twins B
            (0, 2),
            (0, 3),
            (1, 2),
            (1, 3),
            (2, 4),
            (3, 4), // 4 hangs off pair B, dominated by either
        ],
    )
}

#[test]
fn parallel_alive_sets_rounds_and_checks_match_sequential() {
    for (desc, g) in corpus() {
        let f = Filtration::degree_superlevel(&g);
        let reference = prunit(&g, &f).unwrap();
        for threads in THREAD_SWEEP {
            let mut ws = ReductionWorkspace::with_prune_threads(threads);
            ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
            let kept: Vec<u32> = (0..g.n() as u32)
                .filter(|&v| ws.alive()[v as usize])
                .collect();
            assert_eq!(
                kept, reference.kept_old_ids,
                "{desc} threads={threads}: alive set"
            );
            assert_eq!(
                ws.frontier_rounds(),
                reference.rounds,
                "{desc} threads={threads}: rounds"
            );
            assert_eq!(
                ws.checks(),
                reference.checks,
                "{desc} threads={threads}: checks"
            );
        }
    }
}

#[test]
fn corpus_includes_genuinely_parallel_rounds() {
    // the differential result is only meaningful if some corpus members
    // take the scoped-thread path: their round-1 frontier is all of V
    let big = corpus()
        .into_iter()
        .filter(|(_, g)| g.n() >= PAR_FRONTIER_MIN)
        .count();
    assert!(big >= 3, "corpus must keep several super-threshold graphs");
}

#[test]
fn fixed_point_alternation_is_thread_invariant() {
    for (desc, g) in corpus() {
        let f = Filtration::degree_superlevel(&g);
        let reference = combined_with_materializing(&g, &f, 1, Reduction::FixedPoint).unwrap();
        for threads in THREAD_SWEEP {
            let mut ws = ReductionWorkspace::with_prune_threads(threads);
            let red = combined_with_ws(&mut ws, &g, &f, 1, Reduction::FixedPoint).unwrap();
            assert_eq!(red.graph, reference.graph, "{desc} threads={threads}");
            assert_eq!(red.kept_old_ids, reference.kept_old_ids, "{desc} threads={threads}");
            assert_eq!(
                red.report.prunit_rounds, reference.report.prunit_rounds,
                "{desc} threads={threads}: frontier schedule"
            );
        }
    }
}

#[test]
fn parallel_prunit_preserves_diagrams_on_small_corpus() {
    // Theorem 7 end-to-end at every thread count (PD computation bounds
    // this to the small corpus members)
    for (desc, g) in corpus().into_iter().filter(|(_, g)| g.n() <= 150) {
        let f = Filtration::degree_superlevel(&g);
        let before = persistence_diagrams(&g, &f, 1);
        for threads in THREAD_SWEEP {
            let mut ws = ReductionWorkspace::with_prune_threads(threads);
            let red = combined_with_ws(&mut ws, &g, &f, 1, Reduction::Prunit).unwrap();
            let after = persistence_diagrams(&red.graph, &red.filtration, 1);
            for k in 0..=1 {
                assert!(
                    before[k].same_as(&after[k], 1e-9),
                    "{desc} threads={threads} PD_{k}: {} vs {}",
                    before[k],
                    after[k]
                );
            }
        }
    }
}

#[test]
fn crafted_conflict_case_resolves_deterministically() {
    let g = conflict_graph();
    for f in [
        Filtration::constant(g.n()),
        Filtration::degree_superlevel(&g),
    ] {
        let reference = prunit(&g, &f).unwrap();
        // the collapse must not delete whole twin classes: the graph is
        // connected and contractible-ish, one component must survive
        assert!(!reference.kept_old_ids.is_empty());
        let before = persistence_diagrams(&g, &f, 1);
        let after = persistence_diagrams(&reference.graph, &reference.filtration, 1);
        assert!(before[0].same_as(&after[0], 1e-12), "conflict case PD_0");
        assert!(before[1].same_as(&after[1], 1e-12), "conflict case PD_1");
        for threads in THREAD_SWEEP {
            let mut ws = ReductionWorkspace::with_prune_threads(threads);
            ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
            let kept: Vec<u32> = (0..g.n() as u32)
                .filter(|&v| ws.alive()[v as usize])
                .collect();
            assert_eq!(kept, reference.kept_old_ids, "threads={threads}");
            assert_eq!(ws.frontier_rounds(), reference.rounds, "threads={threads}");
        }
    }
}

#[test]
fn adjacent_twins_lowest_index_dominated_by_alive_wins() {
    // pure twin pair: both candidates in round 1 with each other as
    // witness. The rule removes 0 (witness 1 alive) and defers 1 (witness
    // 0 dead); 1 survives the re-check. Removing both would change PD_0.
    let g = Graph::from_edges(2, &[(0, 1)]);
    let f = Filtration::constant(2);
    for threads in THREAD_SWEEP {
        let mut ws = ReductionWorkspace::with_prune_threads(threads);
        ws.plan(&g, &f, 0, Reduction::Prunit).unwrap();
        assert_eq!(ws.alive(), &[false, true], "threads={threads}");
    }
}

#[test]
fn sharded_pipeline_is_thread_invariant_end_to_end() {
    let g = gen::erdos_renyi(600, 0.004, 9);
    let f = Filtration::degree_superlevel(&g);
    let mut seq = ReductionWorkspace::with_prune_threads(1);
    let (pds_seq, rep_seq) = pd_sharded_with(&mut seq, &g, &f, 1, Reduction::FixedPoint, 2).unwrap();
    for threads in [2usize, 4, 8] {
        let mut par = ReductionWorkspace::with_prune_threads(threads);
        let (pds_par, rep_par) =
            pd_sharded_with(&mut par, &g, &f, 1, Reduction::FixedPoint, 2).unwrap();
        assert_eq!(rep_par.shard_sizes, rep_seq.shard_sizes, "threads={threads}");
        assert_eq!(rep_par.prunit_rounds, rep_seq.prunit_rounds);
        for k in 0..=1 {
            assert!(
                pds_seq[k].same_as(&pds_par[k], 0.0),
                "threads={threads} PD_{k}"
            );
        }
    }
}

#[test]
fn workspace_thread_reconfiguration_is_stateless() {
    // one workspace, thread count flipped between plans: every plan must
    // match a fresh sequential run
    let g = gen::barabasi_albert(2500, 3, 13);
    let f = Filtration::degree_superlevel(&g);
    let reference = prunit(&g, &f).unwrap();
    let mut ws = ReductionWorkspace::new();
    for &threads in &[4usize, 1, 8, 2, 1, 4] {
        ws.set_prune_threads(threads);
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        let kept: Vec<u32> = (0..g.n() as u32)
            .filter(|&v| ws.alive()[v as usize])
            .collect();
        assert_eq!(kept, reference.kept_old_ids, "threads={threads}");
    }
}
