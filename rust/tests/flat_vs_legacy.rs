//! Differential properties of the columnar `FlatComplex` engine against
//! the legacy AoS `CliqueComplex` path, on seeded random graphs: simplex
//! order, counts by dimension, boundary structure, and persistence
//! diagrams (Standard + Twist) must all coincide. This suite is the
//! contract that lets the legacy type be deleted later without losing
//! the reference semantics.

use coral_prunit::complex::{CliqueComplex, Filtration, FlatComplex};
use coral_prunit::graph::gen;
use coral_prunit::homology::legacy;
use coral_prunit::homology::reduction::{diagrams_of_complex, Algorithm};
use coral_prunit::util::Rng;

const MAX_DIM: usize = 3;
const MAX_K: usize = 2;

fn case_graph(case: usize, rng: &mut Rng) -> coral_prunit::graph::Graph {
    let n = rng.range(4, 28);
    match case % 4 {
        0 | 1 => gen::erdos_renyi(n, 0.15 + rng.below(30) as f64 / 100.0, rng.next_u64()),
        2 => gen::barabasi_albert(n.max(5), 2, rng.next_u64()),
        _ => gen::powerlaw_cluster(n.max(6), 2, 0.5, rng.next_u64()),
    }
}

fn case_filtration(case: usize, rng: &mut Rng, g: &coral_prunit::graph::Graph) -> Filtration {
    match case % 3 {
        0 => Filtration::sublevel((0..g.n()).map(|_| rng.below(6) as f64).collect()),
        1 => Filtration::degree(g),
        _ => Filtration::degree_superlevel(g),
    }
}

#[test]
fn flat_matches_legacy_on_seeded_random_graphs() {
    let mut rng = Rng::new(0xF1A7);
    for case in 0..24 {
        let g = case_graph(case, &mut rng);
        let f = case_filtration(case, &mut rng, &g);

        let legacy_c = CliqueComplex::build(&g, &f, MAX_DIM);
        let flat = FlatComplex::build(&g, &f, MAX_DIM);

        // identical simplex order: same tuples, same keys, position by position
        assert_eq!(flat.len(), legacy_c.len(), "case {case}: simplex count");
        assert_eq!(
            flat.counts_by_dim(),
            legacy_c.counts_by_dim(),
            "case {case}: counts by dim"
        );
        for (i, s) in legacy_c.simplices.iter().enumerate() {
            assert_eq!(
                flat.vertices_of(i),
                s.simplex.vertices(),
                "case {case}: order diverged at position {i}"
            );
            assert_eq!(
                flat.key_of(i),
                s.key,
                "case {case}: key diverged at position {i}"
            );
            assert_eq!(flat.dim_of(i), s.simplex.dim());
        }

        // boundary structure: faces strictly precede cofaces
        for i in 0..flat.len() {
            let col = flat.boundary_of(i);
            if flat.dim_of(i) == 0 {
                assert!(col.is_empty());
            } else {
                assert_eq!(col.len(), flat.dim_of(i) + 1);
            }
            for &r in col {
                assert!((r as usize) < i, "case {case}: face after coface");
            }
        }

        // diagrams through both engines, both algorithms
        for alg in [Algorithm::Standard, Algorithm::Twist] {
            let a = legacy::diagrams_of_complex(&legacy_c, MAX_K, alg).unwrap();
            let b = diagrams_of_complex(&flat, MAX_K, alg);
            for k in 0..=MAX_K {
                assert!(
                    a[k].same_as(&b[k], 0.0),
                    "case {case} ({alg:?}): PD_{k} {} vs {}",
                    a[k],
                    b[k]
                );
            }
        }
    }
}

#[test]
fn flat_matches_legacy_on_structured_graphs() {
    let f_of = |g: &coral_prunit::graph::Graph| Filtration::degree(g);
    for (name, g) in [
        ("cycle8", gen::cycle(8)),
        ("complete6", gen::complete(6)),
        ("octahedron", gen::octahedron()),
        ("star7", gen::star(7)),
        ("grid3x4", gen::grid(3, 4)),
        ("path5", gen::path(5)),
        ("empty", coral_prunit::graph::Graph::empty(4)),
    ] {
        let f = f_of(&g);
        let legacy_c = CliqueComplex::build(&g, &f, MAX_DIM);
        let flat = FlatComplex::build(&g, &f, MAX_DIM);
        assert_eq!(flat.len(), legacy_c.len(), "{name}");
        for (i, s) in legacy_c.simplices.iter().enumerate() {
            assert_eq!(flat.vertices_of(i), s.simplex.vertices(), "{name} at {i}");
            assert_eq!(flat.key_of(i), s.key, "{name} at {i}");
        }
        let a = legacy::diagrams_of_complex(&legacy_c, MAX_K, Algorithm::Twist).unwrap();
        let b = diagrams_of_complex(&flat, MAX_K, Algorithm::Twist);
        for k in 0..=MAX_K {
            assert!(a[k].same_as(&b[k], 0.0), "{name}: PD_{k}");
        }
    }
}
