//! **E14 — the end-to-end driver** (EXPERIMENTS.md headline run): the
//! full system on a real workload. A graph-classification dataset is
//! pushed through the batch coordinator twice — without reduction and
//! with PrunIT+CoralTDA — computing PD_0 and PD_1 for every instance,
//! verifying the diagrams agree pointwise (the paper's exactness claim),
//! and reporting the throughput gain. The XLA dense path is cross-checked
//! on the instances that fit its buckets, proving all three layers
//! compose: Pallas kernel → AOT HLO → Rust PJRT → coordinator.
//!
//! ```bash
//! cargo run --release --example end_to_end [dataset]
//! ```

use coral_prunit::complex::Filtration;
use coral_prunit::config::CoordinatorConfig;
use coral_prunit::coordinator::{Coordinator, Job, JobSpec};
use coral_prunit::datasets;
use coral_prunit::reduce::Reduction;
use coral_prunit::runtime::{prunit_dense, XlaRuntime};
use coral_prunit::util::{Table, Timer};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("PROTEINS");
    let recipe = datasets::find(name).expect("unknown dataset; see `repro info`");
    let graphs = recipe.make_all(42);
    println!(
        "dataset {name}: {} instances, avg n = {:.0}",
        graphs.len(),
        graphs.iter().map(|g| g.n()).sum::<usize>() as f64 / graphs.len() as f64
    );

    let cfg = CoordinatorConfig {
        workers: std::thread::available_parallelism().map(|c| c.get()).unwrap_or(2),
        queue_depth: 32,
        max_k: 1,
        reduction: "prunit+coral".into(),
        seed: 42,
        prune_threads: 1,
        ..CoordinatorConfig::default()
    };

    let run = |reduction: Reduction| {
        let coordinator = Coordinator::new(cfg.clone());
        let jobs: Vec<Job> = graphs
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, g)| {
                Job::degree_superlevel(
                    i as u64,
                    g,
                    JobSpec { max_k: 1, reduction, sharded: false, ..JobSpec::default() },
                )
            })
            .collect();
        let t = Timer::start();
        let results = coordinator.run(jobs).unwrap();
        let secs = t.elapsed().as_secs_f64();
        (results, secs, coordinator.metrics().summary())
    };

    let (base, base_secs, base_metrics) = run(Reduction::None);
    let (red, red_secs, red_metrics) = run(Reduction::Combined);

    // Exactness: PD_1 agrees on every instance (Thm 2+7); PrunIT-only
    // would also give PD_0 — with coral in the chain PD_0 may differ, so
    // the guarantee we assert is PD_1.
    for (a, b) in base.iter().zip(&red) {
        assert!(
            a.diagrams[1].same_as(&b.diagrams[1], 1e-9),
            "instance {}: PD_1 mismatch — theorem violation!",
            a.id
        );
    }
    println!("exactness verified: PD_1 identical on all {} instances ✓", base.len());

    let mut t = Table::new(
        "end-to-end: full-batch PD_0..PD_1 throughput",
        &["pipeline", "wall_s", "jobs/s", "metrics"],
    );
    t.row(&[
        "no reduction".into(),
        format!("{base_secs:.3}"),
        format!("{:.1}", base.len() as f64 / base_secs),
        base_metrics,
    ]);
    t.row(&[
        "prunit+coral".into(),
        format!("{red_secs:.3}"),
        format!("{:.1}", red.len() as f64 / red_secs),
        red_metrics,
    ]);
    t.emit(None);
    println!(
        "speedup: {:.2}x end-to-end",
        base_secs / red_secs.max(1e-12)
    );

    // Layer-composition proof: run the same pruning through the AOT
    // Pallas artifact on PJRT and confirm diagram equality.
    match XlaRuntime::from_default() {
        Ok(rt) => {
            let mut checked = 0usize;
            for g in graphs.iter().filter(|g| g.n() <= rt.max_order()).take(3) {
                let f = Filtration::degree_superlevel(g);
                let dense = prunit_dense(&rt, g, &f).unwrap();
                let a = coral_prunit::homology::persistence_diagrams(g, &f, 1);
                let b = coral_prunit::homology::persistence_diagrams(
                    &dense.graph,
                    &dense.filtration,
                    1,
                );
                assert!(a[0].same_as(&b[0], 1e-9) && a[1].same_as(&b[1], 1e-9));
                checked += 1;
            }
            println!(
                "XLA dense path (Pallas kernel → HLO → PJRT): {checked} instances \
                 cross-checked ✓ (platform={})",
                rt.platform()
            );
        }
        Err(e) => println!("XLA runtime unavailable: {e} (run `make artifacts`)"),
    }
}
