//! Scenario (the paper's §1 motivation): graph classification with
//! topological features. Two structurally distinct classes are embedded,
//! per-graph features are PD vectorizations (stats + Betti curves)
//! computed on **reduced** graphs — exactness (Thms 2+7) guarantees the
//! features are identical to the unreduced ones, so accuracy is free of
//! reduction artifacts while the feature-extraction pass runs faster.
//!
//! ```bash
//! cargo run --release --example graph_classification
//! ```

use coral_prunit::complex::Filtration;
use coral_prunit::graph::{gen, Graph};
use coral_prunit::homology::vectorize::feature_vector;
use coral_prunit::homology::persistence_diagrams;
use coral_prunit::reduce::{combined_with, Reduction};
use coral_prunit::util::{Rng, Timer};

const PER_CLASS: usize = 40;

/// Class 0: molecule-like (tree + few rings). Class 1: clustered social.
fn make_dataset(seed: u64) -> Vec<(Graph, usize)> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..PER_CLASS {
        let n = rng.range(30, 60);
        out.push((
            coral_prunit::datasets::recipes::molecule(n, 4, rng.next_u64()),
            0,
        ));
        out.push((gen::powerlaw_cluster(n, 3, 0.8, rng.next_u64()), 1));
    }
    out
}

fn features(g: &Graph, reduction: Reduction) -> Vec<f64> {
    let f = Filtration::degree_superlevel(g);
    let r = combined_with(g, &f, 1, reduction).unwrap();
    let pds = persistence_diagrams(&r.graph, &r.filtration, 1);
    // PD_1 features only: exactness holds for k ≥ 1 under Combined.
    feature_vector(&pds[1..], -30.0, 0.0, 24)
}

/// Nearest-centroid classifier with leave-one-out evaluation.
fn loo_accuracy(feats: &[Vec<f64>], labels: &[usize]) -> f64 {
    let dim = feats[0].len();
    let mut correct = 0usize;
    for hold in 0..feats.len() {
        let mut centroids = vec![vec![0.0; dim]; 2];
        let mut counts = [0usize; 2];
        for i in 0..feats.len() {
            if i == hold {
                continue;
            }
            counts[labels[i]] += 1;
            for (c, x) in centroids[labels[i]].iter_mut().zip(&feats[i]) {
                *c += x;
            }
        }
        for (cls, centroid) in centroids.iter_mut().enumerate() {
            for c in centroid.iter_mut() {
                *c /= counts[cls].max(1) as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let pred = if dist(&feats[hold], &centroids[0]) <= dist(&feats[hold], &centroids[1]) {
            0
        } else {
            1
        };
        correct += (pred == labels[hold]) as usize;
    }
    correct as f64 / feats.len() as f64
}

fn main() {
    let data = make_dataset(42);
    let labels: Vec<usize> = data.iter().map(|(_, l)| *l).collect();
    println!("dataset: {} graphs, 2 classes", data.len());

    for reduction in [Reduction::None, Reduction::Combined] {
        let (feats, secs) = Timer::time(|| {
            data.iter()
                .map(|(g, _)| features(g, reduction))
                .collect::<Vec<_>>()
        });
        let acc = loo_accuracy(&feats, &labels);
        println!(
            "{:>13}: feature extraction {:.3}s, LOO nearest-centroid accuracy {:.1}%",
            reduction.name(),
            secs,
            100.0 * acc
        );
    }
    println!("exactness ⇒ identical features ⇒ identical accuracy; only time differs.");

    // Prove the claim: feature vectors must match elementwise.
    for (g, _) in data.iter().take(10) {
        let a = features(g, Reduction::None);
        let b = features(g, Reduction::Combined);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-9, "feature drift — theorem violation");
        }
    }
    println!("feature equality verified on 10 spot-checked graphs ✓");
}
