//! Scenario (paper §6.2): node classification needs PD_0 of *every
//! vertex's* 1-hop ego network in a large citation graph. The batch
//! coordinator fans the jobs across workers with bounded-queue
//! backpressure; PrunIT shrinks each ego net first.
//!
//! ```bash
//! cargo run --release --example ego_pipeline
//! ```

use coral_prunit::complex::Filtration;
use coral_prunit::config::CoordinatorConfig;
use coral_prunit::coordinator::{Coordinator, Job, JobSpec};
use coral_prunit::datasets;
use coral_prunit::reduce::Reduction;
use coral_prunit::util::Timer;

const EGO_COUNT: usize = 2_000;

fn main() {
    let recipe = datasets::find("OGB-ARXIV").unwrap();
    let g = recipe.make(42, 0);
    println!(
        "OGB-ARXIV stand-in: n={} m={} ({}x scale-down; paper: 169,343 vertices)",
        g.n(),
        g.m(),
        recipe.scale_down
    );

    let cfg = CoordinatorConfig {
        workers: std::thread::available_parallelism().map(|c| c.get()).unwrap_or(2),
        queue_depth: 128,
        max_k: 0,
        reduction: "prunit".into(),
        seed: 42,
        prune_threads: 1,
        ..CoordinatorConfig::default()
    };
    let coordinator = Coordinator::new(cfg.clone());

    // Stream ego-network jobs straight off the big graph — the bounded
    // queue means we never materialise all 2000 subgraphs at once.
    let spec =
        JobSpec { max_k: 0, reduction: Reduction::Prunit, sharded: false, ..JobSpec::default() };
    let graph = &g;
    let jobs = (0..EGO_COUNT as u64).map(move |i| {
        let center = (i as usize * 7919) % graph.n(); // deterministic spread
        let verts = graph.ego_vertices(center as u32, 1);
        let (ego, _) = graph.induced_on(&verts);
        let f = Filtration::degree_superlevel(&ego);
        Job::new(i, ego, f, spec.clone())
    });

    let t = Timer::start();
    let mut betti0_hist = std::collections::BTreeMap::<usize, usize>::new();
    let n_done = coordinator
        .run_streaming(jobs, |res| {
            *betti0_hist.entry(res.diagrams[0].betti()).or_default() += 1;
        })
        .unwrap();
    let secs = t.elapsed().as_secs_f64();

    println!(
        "computed PD_0 for {n_done} ego networks in {secs:.2}s \
         ({:.0} jobs/s on {} workers)",
        n_done as f64 / secs,
        cfg.workers
    );
    println!("coordinator metrics: {}", coordinator.metrics().summary());
    println!("β0 histogram (feature used for node classification):");
    for (betti, count) in betti0_hist.iter().take(8) {
        println!("  β0={betti}: {count} vertices");
    }
}
