//! Scenario (paper §6.3): reduce a large network for PD computation,
//! checkpointing the reduced graph to disk in SNAP edge-list format.
//! Demonstrates PrunIT → CoralTDA composition plus graph IO.
//!
//! ```bash
//! cargo run --release --example large_network_reduction [dataset] [k]
//! ```

use coral_prunit::complex::Filtration;
use coral_prunit::datasets;
use coral_prunit::graph::io;
use coral_prunit::kcore::kcore_subgraph;
use coral_prunit::prune::prunit;
use coral_prunit::util::{table::reduction_pct, Timer};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("com-dblp");
    let k: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let recipe = datasets::find(name).expect("unknown dataset; see `repro info`");
    let g = recipe.make(42, 0);
    println!(
        "{name} stand-in: n={} m={} ({}x scale-down)",
        g.n(),
        g.m(),
        recipe.scale_down
    );

    // Stage 1: PrunIT (valid in every dimension).
    let f = Filtration::degree_superlevel(&g);
    let (pruned, p_secs) = Timer::time(|| prunit(&g, &f).unwrap());
    println!(
        "PrunIT: removed {} vertices in {:.3}s → n={} ({:.1}%), m={} ({:.1}%)",
        pruned.removed,
        p_secs,
        pruned.graph.n(),
        reduction_pct(g.n(), pruned.graph.n()),
        pruned.graph.m(),
        reduction_pct(g.m(), pruned.graph.m()),
    );

    // Stage 2: CoralTDA (k+1)-core for the target dimension.
    let ((core, _ids), c_secs) = Timer::time(|| kcore_subgraph(&pruned.graph, k + 1));
    println!(
        "CoralTDA (core {}): {:.3}s → n={} ({:.1}% total vertex reduction)",
        k + 1,
        c_secs,
        core.n(),
        reduction_pct(g.n(), core.n()),
    );

    // Checkpoint the reduced instance.
    let out = std::env::temp_dir().join(format!("{name}_reduced_k{k}.txt"));
    io::write_edge_list(
        &core,
        &out,
        &format!("{name} after PrunIT + {}-core; PD_{k}-exact per Thms 2+7", k + 1),
    )
    .unwrap();
    println!("checkpoint written: {}", out.display());

    // Round-trip sanity.
    let back = io::read_edge_list(&out).unwrap();
    assert_eq!(back.m(), core.m());
    println!("round-trip verified ({} edges)", back.m());
}
