//! Quickstart: the 60-second tour of the coral-prunit API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use coral_prunit::prelude::*;

fn main() {
    // 1. A graph. Generators are seeded and deterministic.
    let g = gen::barabasi_albert(500, 2, 42);
    println!("graph: n={} m={}", g.n(), g.m());

    // 2. A filtering function. Degree + superlevel is the paper's Fig 5a
    //    setting; under it every dominated vertex is removable (Remark 8).
    let f = Filtration::degree_superlevel(&g);

    // 3. The baseline: persistence diagrams PD_0, PD_1 of (G, f).
    let base = homology::persistence_diagrams(&g, &f, 1);
    println!("PD_0: {} points | PD_1: {} points", base[0].points().len(), base[1].points().len());

    // 4. Reduce first — exactly, per the paper's theorems.
    //    PrunIT (Thm 7) preserves every PD; CoralTDA (Thm 2) preserves
    //    PD_j for j ≥ k; combined: PD_k(G) = PD_k((G')^{k+1}).
    let r = reduce::combined(&g, &f, 1).unwrap();
    println!(
        "reduced: {} -> {} vertices ({:.1}%), {} -> {} edges ({:.1}%) in {:.1} ms",
        r.report.vertices_before,
        r.graph.n(),
        r.vertex_reduction_pct(),
        r.report.edges_before,
        r.graph.m(),
        r.edge_reduction_pct(),
        r.report.reduce_secs * 1e3,
    );

    // 5. Same diagram, much smaller input.
    let reduced = homology::persistence_diagrams(&r.graph, &r.filtration, 1);
    assert!(base[1].same_as(&reduced[1], 1e-9), "Theorem 2 + 7 guarantee this");
    println!(
        "PD_1 identical after reduction ✓  ({} points, {} essential loops)",
        reduced[1].points().len(),
        reduced[1].betti()
    );

    // 6. k-core facts (the CoralTDA substrate).
    println!("degeneracy: {}", kcore::degeneracy(&g));

    // 7. Dominated-vertex counts (the PrunIT substrate).
    let dominated = (0..g.n() as u32)
        .filter(|&u| prune::find_dominator(&g, &f, u).is_some())
        .count();
    println!("{dominated} of {} vertices are admissibly dominated", g.n());
}
