//! Deterministic, dependency-free RNG (xoshiro256++ seeded via splitmix64).
//!
//! Every generator, dataset recipe and property test in the crate is seeded
//! through this type, so all experiments are bit-reproducible across runs —
//! a requirement for the EXPERIMENTS.md paper-vs-measured records.

/// xoshiro256++ PRNG. Not cryptographic; statistical quality is more than
/// sufficient for graph generation and property-test case generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby integer seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, bound) via Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in the inclusive range [lo, hi].
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct items from 0..n (k ≤ n), ascending order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected.
        let mut chosen = std::collections::BTreeSet::new();
        for j in n - k..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fork a child stream (for per-graph seeds inside dataset recipes).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.below(8)] += 1;
        }
        let expect = n / 8;
        for c in counts {
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(6);
        for _ in 0..100 {
            let s = r.sample_indices(50, 10);
            assert_eq!(s.len(), 10);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
