//! Small shared utilities: deterministic RNG, timing, table formatting,
//! and the persistent worker team behind parallel PrunIT.

pub mod cancel;
pub mod rng;
pub mod table;
pub mod team;
pub mod timer;

pub use cancel::CancelToken;
pub use rng::Rng;
pub use table::Table;
pub use team::{TeamSlot, ThreadTeam};
pub use timer::Timer;

/// Order-preserving f64 → u64 bit transform (total order, NaN-free
/// inputs assumed): integer sort keys beat `partial_cmp` in hot sorts.
#[inline]
pub fn sortable_f64(x: f64) -> u64 {
    let bits = x.to_bits();
    if bits >> 63 == 0 {
        bits | (1 << 63)
    } else {
        !bits
    }
}

#[cfg(test)]
mod sortable_tests {
    use super::sortable_f64;

    #[test]
    fn preserves_order() {
        let xs = [-1e30, -2.5, -0.0, 0.0, 1e-9, 3.0, 1e30, f64::INFINITY];
        for w in xs.windows(2) {
            assert!(sortable_f64(w[0]) <= sortable_f64(w[1]), "{} vs {}", w[0], w[1]);
        }
        assert!(sortable_f64(-1.0) < sortable_f64(1.0));
    }
}
