//! Minimal wall-clock timing helpers shared by benches and the coordinator.

use std::time::{Duration, Instant};

/// A simple start/stop timer with accumulated laps.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// Time a closure; return (result, seconds).
    pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
        let t = Instant::now();
        let out = f();
        (out, t.elapsed().as_secs_f64())
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_nonnegative() {
        let (v, secs) = Timer::time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn elapsed_monotone() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
    }
}
