//! Persistent parking worker team for round-synchronous fan-out.
//!
//! The parallel PrunIT check phase used to spawn fresh scoped threads
//! every frontier round; on the small rounds that dominate late
//! convergence a spawn costs more than the sweep, and a multi-round
//! FixedPoint job pays that latency dozens of times. [`ThreadTeam`]
//! amortises it: the workers are spawned once, park on a condvar
//! between rounds, and each [`ThreadTeam::run`] wakes exactly the
//! workers a round needs with one epoch-stamped dispatch.
//!
//! Design points:
//!
//! * **Epoch-stamped rounds.** The leader publishes a type-erased task
//!   pointer plus a bumped epoch under the team mutex and notifies the
//!   work condvar. A worker runs a round iff the epoch moved past the
//!   one it last served *and* its index is below the round's `parts`
//!   budget; everyone else keeps parking, so a 2-way round on an 8-way
//!   team wakes two threads, not eight.
//! * **Borrowed closures, no allocation.** `run` erases `&dyn Fn(usize)`
//!   to a raw pointer for the dispatch. That is sound because `run`
//!   never returns (or unwinds) before every participating worker has
//!   finished the epoch — the borrow provably outlives all uses.
//! * **Panic-safe.** Each worker executes its part under `catch_unwind`
//!   (the crate's job-isolation convention): a panicking part is
//!   counted, the round still completes, and the count is returned to
//!   the leader, which escalates. A leader-side panic in part 0 is
//!   caught, the barrier is still honoured, and the payload is rethrown
//!   only after the team is idle — workers never race a stack that is
//!   unwinding away beneath them.
//! * **Shutdown on drop.** Dropping the team flips a shutdown flag,
//!   wakes everyone, and joins the handles.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Type-erased borrowed task: `call(data, part)` invokes the leader's
/// `&dyn Fn(usize)` for one part index. Only valid for the epoch it was
/// published under; [`ThreadTeam::run`] keeps the referent alive until
/// every participant finished that epoch.
#[derive(Clone, Copy)]
struct Task {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: the pointer is only dereferenced by workers between dispatch
// and the leader's barrier, while the leader provably keeps the closure
// (and everything it borrows) alive on its own stack.
unsafe impl Send for Task {}

struct State {
    /// monotone round stamp; workers run a round once per epoch advance
    epoch: u64,
    /// worker threads participating in the current epoch (indices
    /// `0..active` run parts `1..=active`; the leader runs part 0)
    active: usize,
    /// participants that have not yet finished the current epoch
    remaining: usize,
    /// participants whose part panicked during the current epoch
    panicked: usize,
    task: Option<Task>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// workers park here between rounds
    work: Condvar,
    /// the leader parks here while a round is in flight
    done: Condvar,
}

fn lock(shared: &Shared) -> MutexGuard<'_, State> {
    // the critical sections below never panic, but recover anyway: a
    // poisoned team must still shut down cleanly
    shared.state.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a>(cv: &Condvar, guard: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

fn worker_loop(shared: &Shared, index: usize) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = lock(shared);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if index < st.active {
                        break;
                    }
                    // not in this round's budget: wait for the next one
                }
                st = wait(&shared.work, st);
            }
            st.task.expect("a dispatched epoch always carries a task")
        };
        // part 0 is the leader's; worker `index` owns part `index + 1`
        let ok = catch_unwind(AssertUnwindSafe(|| unsafe {
            (task.call)(task.data, index + 1)
        }))
        .is_ok();
        let mut st = lock(shared);
        if !ok {
            st.panicked += 1;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_all();
        }
    }
}

unsafe fn invoke_erased(data: *const (), part: usize) {
    let f = *data.cast::<&(dyn Fn(usize) + Sync)>();
    f(part);
}

/// A persistent team of parked worker threads; see module docs.
pub struct ThreadTeam {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadTeam {
    /// Spawn a team of `workers` parked threads. Together with the
    /// calling (leader) thread this supports rounds of up to
    /// `workers + 1` parts.
    pub fn new(workers: usize) -> ThreadTeam {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                active: 0,
                remaining: 0,
                panicked: 0,
                task: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("prunit-team-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn PrunIT team worker")
            })
            .collect();
        ThreadTeam { shared, handles }
    }

    /// Number of worker threads (the leader is extra).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run one round: `body(part)` for every `part < parts`, part 0 on
    /// the calling thread and parts `1..parts` on team workers. Blocks
    /// until every part finished (the round barrier that makes borrowed
    /// dispatch sound). Returns the number of *worker* parts that
    /// panicked; a panic in part 0 is rethrown here after the barrier.
    ///
    /// `parts` is clamped to `workers() + 1`; callers size their rounds
    /// to the team.
    pub fn run(&self, parts: usize, body: &(dyn Fn(usize) + Sync)) -> usize {
        let dispatch = parts.saturating_sub(1).min(self.workers());
        if dispatch == 0 {
            body(0);
            return 0;
        }
        {
            let mut st = lock(&self.shared);
            debug_assert_eq!(st.remaining, 0, "round dispatched while one is in flight");
            st.epoch += 1;
            st.active = dispatch;
            st.remaining = dispatch;
            st.panicked = 0;
            st.task = Some(Task {
                data: (&body as *const &(dyn Fn(usize) + Sync)).cast(),
                call: invoke_erased,
            });
            self.shared.work.notify_all();
        }
        let leader = catch_unwind(AssertUnwindSafe(|| body(0)));
        let worker_panics = {
            let mut st = lock(&self.shared);
            while st.remaining > 0 {
                st = wait(&self.shared.done, st);
            }
            st.task = None;
            st.panicked
        };
        if let Err(payload) = leader {
            resume_unwind(payload);
        }
        worker_panics
    }
}

impl Drop for ThreadTeam {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared);
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ThreadTeam {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadTeam")
            .field("workers", &self.handles.len())
            .finish()
    }
}

/// A lazily-spawned [`ThreadTeam`] slot designed to live inside
/// cloneable state (the planner's [`ReductionWorkspace`] derives
/// `Clone`): cloning yields an empty slot — threads are not clonable —
/// and the clone respawns its own team on first parallel round. The
/// slot grows the team when a round needs more workers and never
/// shrinks it; parked threads are cheap.
///
/// [`ReductionWorkspace`]: crate::reduce::ReductionWorkspace
#[derive(Default)]
pub struct TeamSlot {
    team: Option<ThreadTeam>,
}

impl TeamSlot {
    /// The slot's team, spawned (or grown) to at least `workers` worker
    /// threads.
    pub fn get(&mut self, workers: usize) -> &ThreadTeam {
        let respawn = match &self.team {
            Some(t) => t.workers() < workers,
            None => true,
        };
        if respawn {
            // the old team (if any) drops first: shutdown + join before
            // the replacement spawns
            self.team = Some(ThreadTeam::new(workers));
        }
        self.team.as_ref().expect("just spawned")
    }

    /// Worker threads currently spawned (0 until the first parallel
    /// round).
    pub fn workers(&self) -> usize {
        self.team.as_ref().map_or(0, ThreadTeam::workers)
    }

    /// Shut down and join the team (a fresh one respawns on next use).
    pub fn clear(&mut self) {
        self.team = None;
    }
}

impl Clone for TeamSlot {
    fn clone(&self) -> TeamSlot {
        TeamSlot::default()
    }
}

impl std::fmt::Debug for TeamSlot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TeamSlot").field("workers", &self.workers()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_part_runs_exactly_once_per_round() {
        let team = ThreadTeam::new(3);
        for round in 1..=50usize {
            let parts = 1 + round % 4;
            let hits: Vec<AtomicUsize> = (0..parts).map(|_| AtomicUsize::new(0)).collect();
            let panics = team.run(parts, &|p| {
                hits[p].fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(panics, 0);
            for (p, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "round {round} part {p}");
            }
        }
    }

    #[test]
    fn leader_runs_part_zero_inline() {
        let team = ThreadTeam::new(2);
        let me = std::thread::current().id();
        let leader_part_thread = std::sync::Mutex::new(None);
        team.run(3, &|p| {
            if p == 0 {
                *leader_part_thread.lock().unwrap() = Some(std::thread::current().id());
            }
        });
        assert_eq!(*leader_part_thread.lock().unwrap(), Some(me));
    }

    #[test]
    fn parts_beyond_team_capacity_are_clamped() {
        let team = ThreadTeam::new(2);
        let hits = AtomicUsize::new(0);
        let panics = team.run(100, &|_p| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(panics, 0);
        // leader + 2 workers
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn worker_panic_is_counted_and_team_survives() {
        let team = ThreadTeam::new(2);
        let panics = team.run(3, &|p| {
            if p == 2 {
                panic!("scripted part failure");
            }
        });
        assert_eq!(panics, 1);
        // the team is still serviceable after a panicked round
        let hits = AtomicUsize::new(0);
        assert_eq!(
            team.run(3, &|_p| {
                hits.fetch_add(1, Ordering::Relaxed);
            }),
            0
        );
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn leader_panic_is_rethrown_after_the_barrier() {
        let team = ThreadTeam::new(2);
        let worker_done = AtomicUsize::new(0);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            team.run(3, &|p| {
                if p == 0 {
                    panic!("leader part failure");
                }
                worker_done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(caught.is_err());
        // the barrier held: both worker parts finished before the rethrow
        assert_eq!(worker_done.load(Ordering::Relaxed), 2);
        // and the team still works
        assert_eq!(team.run(2, &|_p| {}), 0);
    }

    #[test]
    fn slot_spawns_lazily_grows_and_clones_empty() {
        let mut slot = TeamSlot::default();
        assert_eq!(slot.workers(), 0);
        slot.get(2);
        assert_eq!(slot.workers(), 2);
        slot.get(1); // never shrinks
        assert_eq!(slot.workers(), 2);
        slot.get(5);
        assert_eq!(slot.workers(), 5);
        let cloned = slot.clone();
        assert_eq!(cloned.workers(), 0, "threads must not be cloned");
        slot.clear();
        assert_eq!(slot.workers(), 0);
    }
}
