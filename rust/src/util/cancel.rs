//! Cooperative cancellation token for long-running reductions.
//!
//! A [`CancelToken`] is threaded from the coordinator's per-job deadline
//! through `ReductionWorkspace` into the PrunIT round loop and the Z/2
//! column reduction, which poll it at round boundaries / every N columns.
//! The token is cooperative: nothing is interrupted, the hot loops simply
//! observe expiry at their next checkpoint and unwind with a typed error,
//! so a pathological graph can time out without wedging its worker.
//!
//! `CancelToken::none()` is the default everywhere and its `check()` is a
//! single `Option` test — the hot paths pay nothing when no deadline is
//! configured.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

#[derive(Debug)]
struct Inner {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
    limit_secs: f64,
}

/// Shared cancellation + deadline flag. Cloning shares the flag.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    inner: Option<Arc<Inner>>,
}

impl CancelToken {
    /// A token that never fires; `check()` is free.
    pub fn none() -> CancelToken {
        CancelToken { inner: None }
    }

    /// A cancellable token with no deadline (fires only via `cancel()`).
    pub fn cancellable() -> CancelToken {
        CancelToken {
            inner: Some(Arc::new(Inner {
                cancelled: AtomicBool::new(false),
                deadline: None,
                limit_secs: 0.0,
            })),
        }
    }

    /// A token that expires `limit` from now (and can also be cancelled).
    pub fn with_deadline(limit: Duration) -> CancelToken {
        CancelToken {
            inner: Some(Arc::new(Inner {
                cancelled: AtomicBool::new(false),
                deadline: Some(Instant::now() + limit),
                limit_secs: limit.as_secs_f64(),
            })),
        }
    }

    /// Build from a config-style seconds value; `secs <= 0` means no
    /// deadline (a plain cancellable token is still returned so explicit
    /// `cancel()` works).
    pub fn from_secs(secs: f64) -> CancelToken {
        if secs > 0.0 {
            CancelToken::with_deadline(Duration::from_secs_f64(secs))
        } else {
            CancelToken::none()
        }
    }

    /// Trip the flag; every clone observes it at its next `check()`.
    pub fn cancel(&self) {
        if let Some(inner) = &self.inner {
            inner.cancelled.store(true, Ordering::Release);
        }
    }

    /// True once cancelled or past the deadline.
    pub fn is_expired(&self) -> bool {
        match &self.inner {
            None => false,
            Some(inner) => {
                inner.cancelled.load(Ordering::Acquire)
                    || inner.deadline.is_some_and(|d| Instant::now() >= d)
            }
        }
    }

    /// Checkpoint: `Ok(())` while live, a typed error once expired.
    #[inline]
    pub fn check(&self) -> Result<()> {
        let Some(inner) = &self.inner else {
            return Ok(());
        };
        if inner.cancelled.load(Ordering::Acquire) {
            return Err(Error::Cancelled);
        }
        if let Some(deadline) = inner.deadline {
            if Instant::now() >= deadline {
                return Err(Error::DeadlineExceeded {
                    limit_secs: inner.limit_secs,
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fires() {
        let t = CancelToken::none();
        assert!(t.check().is_ok());
        t.cancel(); // no-op on the none token
        assert!(t.check().is_ok());
        assert!(!t.is_expired());
    }

    #[test]
    fn default_is_none() {
        assert!(CancelToken::default().check().is_ok());
    }

    #[test]
    fn cancel_is_observed_by_clones() {
        let t = CancelToken::cancellable();
        let clone = t.clone();
        assert!(clone.check().is_ok());
        t.cancel();
        assert!(matches!(clone.check(), Err(Error::Cancelled)));
        assert!(clone.is_expired());
    }

    #[test]
    fn deadline_fires_with_limit_in_error() {
        let t = CancelToken::with_deadline(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(5));
        match t.check() {
            Err(Error::DeadlineExceeded { limit_secs }) => {
                assert!((limit_secs - 0.001).abs() < 1e-9);
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn generous_deadline_does_not_fire() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(t.check().is_ok());
        assert!(!t.is_expired());
    }

    #[test]
    fn from_secs_zero_is_none() {
        let t = CancelToken::from_secs(0.0);
        assert!(t.check().is_ok());
        let t = CancelToken::from_secs(-1.0);
        assert!(t.check().is_ok());
    }
}
