//! Aligned plain-text table rendering for bench drivers — the benches print
//! the same rows/series the paper's tables and figures report, plus a TSV
//! dump for post-processing. (criterion is unavailable offline; see
//! DESIGN.md §4.)

use std::fmt::Write as _;

/// Column-aligned table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity must match header"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                let _ = write!(line, "{:w$}", cells[i], w = widths[i]);
                if i + 1 < ncol {
                    line.push_str("  ");
                }
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Tab-separated dump (machine-readable companion to `render`).
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# {}", self.title);
        let _ = writeln!(out, "{}", self.header.join("\t"));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join("\t"));
        }
        out
    }

    /// Print to stdout and append the TSV to `path` (best-effort).
    pub fn emit(&self, tsv_path: Option<&str>) {
        print!("{}", self.render());
        println!();
        if let Some(path) = tsv_path {
            use std::io::Write;
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
            {
                let _ = f.write_all(self.to_tsv().as_bytes());
                let _ = f.write_all(b"\n");
            }
        }
    }
}

/// Format a fraction as a percentage string like "62.3%".
pub fn pct(numer: f64, denom: f64) -> String {
    if denom == 0.0 {
        "n/a".to_string()
    } else {
        format!("{:.1}%", 100.0 * numer / denom)
    }
}

/// Reduction percentage `100·(before − after)/before` (paper §6 definition).
pub fn reduction_pct(before: usize, after: usize) -> f64 {
    if before == 0 {
        0.0
    } else {
        100.0 * (before as f64 - after as f64) / before as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(&["x".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("a  bbbb"));
    }

    #[test]
    fn tsv_roundtrip_columns() {
        let mut t = Table::new("demo", &["c1", "c2"]);
        t.row(&["1".into(), "2".into()]);
        let tsv = t.to_tsv();
        assert!(tsv.lines().any(|l| l == "1\t2"));
    }

    #[test]
    fn reduction_pct_matches_paper_definition() {
        assert!((reduction_pct(100, 41) - 59.0).abs() < 1e-12);
        assert_eq!(reduction_pct(0, 0), 0.0);
        assert_eq!(reduction_pct(10, 10), 0.0);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
