//! Bucket padding — mirrors `python/compile/model.py::pad_inputs`.
//!
//! Padding vertices are isolated and carry `f = PAD_SENTINEL`; the kernel's
//! adjacency mask makes them inert (proved by `python/tests/test_model.py`
//! and re-checked here against the live artifact in `client` tests).

use crate::complex::Filtration;
use crate::graph::Graph;

/// Must match `python/compile/model.py::PAD_SENTINEL`.
pub const PAD_SENTINEL: f32 = 3.0e38;

/// Dense, padded inputs for the domination artifact: row-major (bucket ×
/// bucket) f32 adjacency and bucket-length f32 sublevel keys.
pub fn pad_dense(g: &Graph, f: &Filtration, bucket: usize) -> (Vec<f32>, Vec<f32>) {
    let n = g.n();
    assert!(n <= bucket, "graph order {n} exceeds bucket {bucket}");
    let mut adj = vec![0.0f32; bucket * bucket];
    for (u, v) in g.edges() {
        adj[u as usize * bucket + v as usize] = 1.0;
        adj[v as usize * bucket + u as usize] = 1.0;
    }
    let mut keys = vec![PAD_SENTINEL; bucket];
    for (v, k) in f.keys_f32().into_iter().enumerate() {
        keys[v] = k;
    }
    (adj, keys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn pad_layout_matches_graph() {
        let g = gen::cycle(4);
        let f = Filtration::degree(&g);
        let (adj, keys) = pad_dense(&g, &f, 8);
        assert_eq!(adj.len(), 64);
        assert_eq!(keys.len(), 8);
        assert_eq!(adj[0 * 8 + 1], 1.0);
        assert_eq!(adj[1 * 8 + 0], 1.0);
        assert_eq!(adj[0 * 8 + 2], 0.0);
        // pad rows empty
        for i in 4..8 {
            for j in 0..8 {
                assert_eq!(adj[i * 8 + j], 0.0);
            }
        }
        assert_eq!(keys[0], 2.0);
        assert_eq!(keys[5], PAD_SENTINEL);
    }

    #[test]
    fn superlevel_keys_negated() {
        let g = gen::star(3);
        let f = Filtration::degree_superlevel(&g);
        let (_, keys) = pad_dense(&g, &f, 4);
        assert_eq!(keys[0], -2.0);
        assert_eq!(keys[1], -1.0);
    }

    #[test]
    #[should_panic(expected = "exceeds bucket")]
    fn oversize_panics() {
        let g = gen::cycle(10);
        let f = Filtration::degree(&g);
        pad_dense(&g, &f, 8);
    }
}
