//! Dense PrunIT via the AOT Pallas kernel: sweep → greedy-ascending
//! removal → re-sweep on the reduced graph, to a fixed point.
//!
//! Soundness of the per-sweep greedy rule (process `u` ascending; remove
//! `u` if some admissible dominator `v` is not already removed this
//! sweep): each removal is justified in the graph state at its own moment
//! — removing *other* vertices preserves domination among survivors — so
//! the sequence of removals is a valid Theorem 7 chain. Twin classes
//! (mutual domination cycles) keep exactly their first-surviving member.
//!
//! The dense path is exact but O(bucket³) per sweep, so it targets the
//! small/dense graphs of the paper's graph-classification datasets; the
//! sparse CPU path (`prune::prunit`) covers large networks. Both paths
//! are cross-checked for PD equality in `rust/tests/`.
//!
//! The host-side greedy resolution shares the sparse planner's u64-block
//! layout: device mask rows are packed into block vectors and the
//! live-dominator test is `prune::kernel::blocks_subset` — one
//! representation for both execution paths (ROADMAP item 4).
//!
//! `PruneResult::checks` counts what the sparse path counts: domination
//! checks per candidate. The device kernel evaluates every current
//! vertex each sweep, so the dense path charges the sweep's vertex count
//! — previously it misreported the sweep count itself, making `checks`
//! mean different things per backend.

use crate::complex::Filtration;
use crate::error::Result;
use crate::graph::Graph;
use crate::prune::kernel;
use crate::prune::PruneResult;

use super::client::XlaRuntime;

/// CoralTDA on the device: the (k+1)-core via the k-core peeling artifact
/// (Thm 2 → exact for PD_j, j ≥ k), filtration restricted per Remark 1.
pub fn coral_dense(
    rt: &XlaRuntime,
    g: &Graph,
    f: &Filtration,
    k: usize,
) -> Result<(Graph, Vec<u32>, Filtration)> {
    f.check(g)?;
    let alive = rt.kcore_mask(g, k + 1)?;
    let (core, ids) = g.induced(&alive);
    let rf = f.restrict(&ids);
    Ok((core, ids, rf))
}

/// The combined dense pipeline (§5 end): PrunIT then CoralTDA, both
/// executing the AOT Pallas artifacts — `PD_k(G) = PD_k((G')^{k+1})`.
pub fn combined_dense(
    rt: &XlaRuntime,
    g: &Graph,
    f: &Filtration,
    k: usize,
) -> Result<(Graph, Vec<u32>, Filtration)> {
    let pruned = prunit_dense(rt, g, f)?;
    let (core, ids, rf) = coral_dense(rt, &pruned.graph, &pruned.filtration, k)?;
    let orig_ids: Vec<u32> = ids
        .iter()
        .map(|&mid| pruned.kept_old_ids[mid as usize])
        .collect();
    Ok((core, orig_ids, rf))
}

/// PrunIT to a fixed point using the XLA domination artifact.
pub fn prunit_dense(rt: &XlaRuntime, g: &Graph, f: &Filtration) -> Result<PruneResult> {
    f.check(g)?;
    // alive mask over ORIGINAL ids
    let mut alive = vec![true; g.n()];
    let mut cur = g.clone();
    let mut cur_f = f.clone();
    let mut cur_ids: Vec<u32> = (0..g.n() as u32).collect();
    let mut removed_total = 0usize;
    let mut sweeps = 0usize;
    let mut checks = 0usize;

    loop {
        sweeps += 1;
        // the device kernel checks every current vertex against every
        // candidate dominator — charge one check per vertex, the same
        // accounting unit as the sparse frontier sweep
        checks += cur.n();
        let out = rt.domination_sweep(&cur, &cur_f)?;
        let n = cur.n();
        let (removed_now, any) = greedy_select(&out.mask, &out.dominated);
        if !any {
            break;
        }
        for u in 0..n {
            if removed_now[u] {
                alive[cur_ids[u] as usize] = false;
                removed_total += 1;
            }
        }
        let keep: Vec<bool> = removed_now.iter().map(|&r| !r).collect();
        let (next, new_to_cur) = cur.induced(&keep);
        cur_ids = new_to_cur.iter().map(|&m| cur_ids[m as usize]).collect();
        cur_f = cur_f.restrict(&new_to_cur);
        cur = next;
        if cur.n() == 0 {
            break;
        }
    }

    let (graph, kept_old_ids) = g.induced(&alive);
    let filtration = f.restrict(&kept_old_ids);
    Ok(PruneResult {
        graph,
        kept_old_ids,
        filtration,
        removed: removed_total,
        checks,
        rounds: sweeps,
    })
}

/// One sweep's greedy-ascending selection over the device's dominated
/// mask: vertex `u` is removed iff it is dominated and some dominator
/// survives the removals made earlier in this sweep. Each row is packed
/// into u64 blocks so the survivor test is the shared block primitive —
/// a live dominator exists ⟺ the row is NOT a subset of the removed set
/// (`!blocks_subset`) — instead of the old O(n) bool scan per vertex.
///
/// Soundness is unchanged from the scan (see module docs): each removal
/// is justified against the removals made strictly before it.
fn greedy_select(mask: &[Vec<bool>], dominated: &[bool]) -> (Vec<bool>, bool) {
    let n = dominated.len();
    let words = n.div_ceil(64).max(1);
    let mut removed_bits = vec![0u64; words];
    let mut row = vec![0u64; words];
    let mut removed_now = vec![false; n];
    let mut any = false;
    for u in 0..n {
        if !dominated[u] {
            continue;
        }
        row.iter_mut().for_each(|w| *w = 0);
        for (v, &m) in mask[u].iter().enumerate() {
            if m {
                kernel::set_block_bit(&mut row, v);
            }
        }
        if !kernel::blocks_subset(&row, &removed_bits) {
            removed_now[u] = true;
            kernel::set_block_bit(&mut removed_bits, u);
            any = true;
        }
    }
    (removed_now, any)
}

// greedy_select is pure host code: test it without the xla feature, as a
// differential against the per-vertex scan it replaced.
#[cfg(test)]
mod select_tests {
    use super::greedy_select;
    use crate::util::Rng;

    fn reference(mask: &[Vec<bool>], dominated: &[bool]) -> (Vec<bool>, bool) {
        let n = dominated.len();
        let mut removed_now = vec![false; n];
        let mut any = false;
        for u in 0..n {
            if !dominated[u] {
                continue;
            }
            let has_live_dominator = (0..n).any(|v| mask[u][v] && !removed_now[v]);
            if has_live_dominator {
                removed_now[u] = true;
                any = true;
            }
        }
        (removed_now, any)
    }

    #[test]
    fn packed_selection_matches_scan_reference() {
        let mut rng = Rng::new(321);
        for n in [0usize, 1, 5, 17, 63, 64, 65, 130] {
            for density in [0.02f64, 0.15, 0.6] {
                let mask: Vec<Vec<bool>> = (0..n)
                    .map(|_| (0..n).map(|_| rng.chance(density)).collect())
                    .collect();
                let dominated: Vec<bool> = mask.iter().map(|row| row.iter().any(|&m| m)).collect();
                let got = greedy_select(&mask, &dominated);
                let want = reference(&mask, &dominated);
                assert_eq!(got, want, "n={n} density={density}");
            }
        }
    }

    #[test]
    fn twin_cycle_keeps_first_survivor() {
        // 0 and 1 mutually dominate: greedy ascending removes 0 (1 still
        // live), then 1 survives (its only dominator is now removed)
        let mask = vec![vec![false, true], vec![true, false]];
        let dominated = vec![true, true];
        let (removed, any) = greedy_select(&mask, &dominated);
        assert!(any);
        assert_eq!(removed, vec![true, false]);
    }
}

// These tests exercise the live PJRT path: they need the `xla` feature
// AND the AOT artifacts on disk (`make artifacts`).
#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::homology::persistence_diagrams;
    use crate::prune::prunit;

    fn runtime() -> XlaRuntime {
        XlaRuntime::from_default().expect("run `make artifacts` first")
    }

    #[test]
    fn star_collapses_like_sparse() {
        let rt = runtime();
        let g = gen::star(12);
        let f = Filtration::degree_superlevel(&g);
        let dense = prunit_dense(&rt, &g, &f).unwrap();
        let sparse = prunit(&g, &f).unwrap();
        assert_eq!(dense.graph.n(), sparse.graph.n());
        assert!(dense.graph.n() <= 2);
        // checks are per-candidate on both backends: at least one full
        // pass over the original vertices each (schedules differ, so
        // exact equality is not expected)
        assert!(dense.checks >= g.n(), "dense checks undercounted");
        assert!(sparse.checks >= g.n());
    }

    #[test]
    fn dense_and_sparse_preserve_the_same_diagrams() {
        // Fixed points may differ vertex-wise (twin choices), but both must
        // preserve every PD of the original graph (Theorem 7).
        let rt = runtime();
        let mut rng = crate::util::Rng::new(2024);
        for _ in 0..5 {
            let n = rng.range(5, 40);
            let g = gen::erdos_renyi(n, 0.3, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let base = persistence_diagrams(&g, &f, 1);
            let dense = prunit_dense(&rt, &g, &f).unwrap();
            let sparse = prunit(&g, &f).unwrap();
            // same accounting unit on both backends (per-candidate checks)
            assert!(dense.checks >= g.n() && sparse.checks >= g.n());
            let dd = persistence_diagrams(&dense.graph, &dense.filtration, 1);
            for k in 0..=1 {
                assert!(
                    base[k].same_as(&dd[k], 1e-9),
                    "dense PD_{k}: {} vs {} (n={n})",
                    base[k],
                    dd[k]
                );
            }
        }
    }

    #[test]
    fn dense_reaches_a_fixed_point() {
        let rt = runtime();
        let g = gen::barabasi_albert(50, 2, 6);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit_dense(&rt, &g, &f).unwrap();
        // no admissible dominated vertex remains
        for u in 0..r.graph.n() as u32 {
            assert!(
                crate::prune::find_dominator(&r.graph, &r.filtration, u).is_none(),
                "vertex {u} still prunable after dense fixed point"
            );
        }
        // every sweep charges the vertex count it evaluated, so the total
        // is at least the original order (first sweep checks everything)
        assert!(r.checks >= g.n(), "checks must count per-vertex work");
        assert!(r.rounds >= 1, "at least one sweep");
    }

    #[test]
    fn coral_dense_matches_sparse_core() {
        let rt = runtime();
        let mut rng = crate::util::Rng::new(31);
        for _ in 0..4 {
            let n = rng.range(6, 60);
            let g = gen::erdos_renyi(n, 0.2, rng.next_u64());
            let f = Filtration::degree(&g);
            for k in 1..=2usize {
                let (core_d, ids_d, _) = coral_dense(&rt, &g, &f, k).unwrap();
                let r = crate::reduce::coral_reduce(&g, &f, k).unwrap();
                assert_eq!(core_d, r.graph, "n={n} k={k}");
                assert_eq!(ids_d, r.kept_old_ids);
            }
        }
    }

    #[test]
    fn combined_dense_preserves_pd_k() {
        let rt = runtime();
        let mut rng = crate::util::Rng::new(57);
        for _ in 0..4 {
            let n = rng.range(8, 50);
            let g = gen::erdos_renyi(n, 0.3, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let base = persistence_diagrams(&g, &f, 1);
            let (core, _, rf) = combined_dense(&rt, &g, &f, 1).unwrap();
            let red = persistence_diagrams(&core, &rf, 1);
            assert!(
                base[1].same_as(&red[1], 1e-9),
                "combined dense PD_1: {} vs {} (n={n})",
                base[1],
                red[1]
            );
        }
    }

    #[test]
    fn cycle_untouched() {
        let rt = runtime();
        let g = gen::cycle(10);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit_dense(&rt, &g, &f).unwrap();
        assert_eq!(r.removed, 0);
        assert_eq!(r.graph.n(), 10);
    }
}
