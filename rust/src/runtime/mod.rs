//! XLA/PJRT runtime (S13): loads the AOT-compiled Pallas domination
//! artifacts (`artifacts/domination_<bucket>.hlo.txt`, produced once by
//! `make artifacts`) and executes them from the Rust hot path. Python is
//! never involved at runtime.

pub mod artifact;
pub mod client;
pub mod dense_prune;
pub mod pad;

pub use artifact::{default_artifacts_dir, Manifest};
pub use client::{backend_compiled, try_runtime, SweepOutput, XlaRuntime};
pub use dense_prune::{combined_dense, coral_dense, prunit_dense};
pub use pad::{pad_dense, PAD_SENTINEL};
