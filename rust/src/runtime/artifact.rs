//! Artifact discovery: `artifacts/manifest.tsv` (written by
//! `python -m compile.aot`) lists one HLO-text artifact per
//! (kernel, size bucket) pair.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One artifact entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub file: String,
    pub kernel: String,
    pub bucket: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .map_err(|_| Error::ArtifactMissing(path.display().to_string()))?;
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() < 3 {
                return Err(Error::Parse(format!("manifest line {}: {line:?}", i + 1)));
            }
            let bucket: usize = cols[2]
                .parse()
                .map_err(|_| Error::Parse(format!("manifest bucket {:?}", cols[2])))?;
            entries.push(ArtifactEntry {
                file: cols[0].to_string(),
                kernel: cols[1].to_string(),
                bucket,
            });
        }
        entries.sort_by(|a, b| a.kernel.cmp(&b.kernel).then(a.bucket.cmp(&b.bucket)));
        if entries.is_empty() {
            return Err(Error::ArtifactMissing(format!(
                "{}: manifest has no entries",
                path.display()
            )));
        }
        Ok(Manifest { dir, entries })
    }

    /// Kernel names available.
    pub fn kernels(&self) -> Vec<String> {
        let mut k: Vec<String> = self.entries.iter().map(|e| e.kernel.clone()).collect();
        k.dedup();
        k
    }

    /// Buckets for one kernel, ascending.
    pub fn buckets(&self, kernel: &str) -> Vec<usize> {
        self.entries
            .iter()
            .filter(|e| e.kernel == kernel)
            .map(|e| e.bucket)
            .collect()
    }

    /// Smallest bucket of `kernel` that fits a graph of order `n`.
    pub fn pick_bucket(&self, kernel: &str, n: usize) -> Result<usize> {
        let buckets = self.buckets(kernel);
        if buckets.is_empty() {
            return Err(Error::ArtifactMissing(format!("kernel {kernel:?}")));
        }
        buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or(Error::NoBucket {
                order: n,
                largest: buckets.last().copied().unwrap_or(0),
            })
    }

    /// Absolute path of the artifact for `(kernel, bucket)`.
    pub fn path_for(&self, kernel: &str, bucket: usize) -> Result<PathBuf> {
        self.entries
            .iter()
            .find(|e| e.kernel == kernel && e.bucket == bucket)
            .map(|e| self.dir.join(&e.file))
            .ok_or_else(|| Error::ArtifactMissing(format!("{kernel} bucket {bucket}")))
    }
}

/// Default artifacts directory: `$CORAL_PRUNIT_ARTIFACTS` or
/// `<manifest dir>/artifacts` (works for `cargo test`/`cargo bench` runs
/// from the workspace).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CORAL_PRUNIT_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The on-disk artifacts are produced by `python -m compile.aot`
    /// (`make artifacts`) and are not checked in; skip the live-manifest
    /// tests gracefully when they have not been built.
    fn manifest_or_skip(test: &str) -> Option<Manifest> {
        match Manifest::load(default_artifacts_dir()) {
            Ok(m) => Some(m),
            Err(_) => {
                eprintln!("skipping {test}: artifacts not built (run `make artifacts`)");
                None
            }
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest_or_skip("loads_real_manifest") else {
            return;
        };
        assert!(m.kernels().contains(&"domination".to_string()));
        assert!(m.kernels().contains(&"kcore".to_string()));
        for k in m.kernels() {
            assert!(m.buckets(&k).contains(&32));
            assert!(m.buckets(&k).windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn pick_bucket_rounds_up() {
        let Some(m) = manifest_or_skip("pick_bucket_rounds_up") else {
            return;
        };
        assert_eq!(m.pick_bucket("domination", 1).unwrap(), 32);
        assert_eq!(m.pick_bucket("domination", 32).unwrap(), 32);
        assert_eq!(m.pick_bucket("kcore", 33).unwrap(), 64);
        assert!(m.pick_bucket("domination", 100_000).is_err());
        assert!(m.pick_bucket("nonexistent", 4).is_err());
    }

    #[test]
    fn paths_exist_on_disk() {
        let Some(m) = manifest_or_skip("paths_exist_on_disk") else {
            return;
        };
        for k in m.kernels() {
            for b in m.buckets(&k) {
                assert!(m.path_for(&k, b).unwrap().exists(), "{k} bucket {b}");
            }
        }
    }

    #[test]
    fn missing_dir_is_artifact_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(matches!(err, Error::ArtifactMissing(_)));
    }

    #[test]
    fn synthetic_manifest_parses_and_picks() {
        // Exercise the parse/pick logic without on-disk artifacts.
        let dir = std::env::temp_dir().join("coral_prunit_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "file\tkernel\tbucket\ndom_32.hlo.txt\tdomination\t32\ndom_64.hlo.txt\tdomination\t64\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.kernels(), vec!["domination".to_string()]);
        assert_eq!(m.buckets("domination"), vec![32, 64]);
        assert_eq!(m.pick_bucket("domination", 33).unwrap(), 64);
        assert!(m.pick_bucket("domination", 65).is_err());
        assert!(m
            .path_for("domination", 32)
            .unwrap()
            .ends_with("dom_32.hlo.txt"));
    }
}
