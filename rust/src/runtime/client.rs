//! PJRT client wrapper: compile-once-per-bucket executable cache and the
//! typed `domination_sweep` entrypoint (HLO *text* interchange; see
//! README.md §XLA backend).
//!
//! The live implementation needs the vendored `xla` crate, which is not
//! available in every build environment — it is gated behind the `xla`
//! cargo feature. Without the feature a stub with the identical surface
//! is compiled whose constructors return [`Error::Xla`]; every caller in
//! the crate (CLI `info`/`dense-check`, benches, examples, tests)
//! already handles that path, so default builds stay green.

/// Output of one dense domination sweep on the device.
#[derive(Clone, Debug)]
pub struct SweepOutput {
    /// mask[u][v] = 1 iff v dominates u and key(u) ≥ key(v); n × n,
    /// already un-padded.
    pub mask: Vec<Vec<bool>>,
    /// per-vertex dominated flag.
    pub dominated: Vec<bool>,
    /// bucket actually used.
    pub bucket: usize,
}

#[cfg(feature = "xla")]
mod live {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use crate::complex::Filtration;
    use crate::error::{Error, Result};
    use crate::graph::Graph;
    use crate::runtime::artifact::{default_artifacts_dir, Manifest};
    use crate::runtime::pad::pad_dense;

    use super::SweepOutput;

    /// PJRT CPU runtime with per-(kernel, bucket) compiled executables.
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: Mutex<HashMap<(String, usize), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl XlaRuntime {
        /// Load from an artifacts dir (see [`default_artifacts_dir`]).
        pub fn new(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| Error::Xla(e.to_string()))?;
            Ok(XlaRuntime {
                client,
                manifest,
                cache: Mutex::new(HashMap::new()),
            })
        }

        /// Construct from the default artifacts location.
        pub fn from_default() -> Result<XlaRuntime> {
            XlaRuntime::new(default_artifacts_dir())
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn buckets(&self) -> Vec<usize> {
            self.manifest.buckets("domination")
        }

        /// Largest graph order the runtime can process densely.
        pub fn max_order(&self) -> usize {
            self.buckets().last().copied().unwrap_or(0)
        }

        fn executable(
            &self,
            kernel: &str,
            bucket: usize,
        ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            let key = (kernel.to_string(), bucket);
            // recover a poisoned cache lock: the map only ever holds
            // finished Arc'd executables, so it is valid whatever the
            // panicking holder was doing
            if let Some(exe) = self
                .cache
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .get(&key)
            {
                return Ok(std::sync::Arc::clone(exe));
            }
            let path = self.manifest.path_for(kernel, bucket)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::Xla(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Xla(format!("compile {kernel} bucket {bucket}: {e}")))?;
            let exe = std::sync::Arc::new(exe);
            self.cache
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .insert(key, std::sync::Arc::clone(&exe));
            Ok(exe)
        }

        /// Run the dense k-core membership kernel (bulk-synchronous peeling;
        /// the full fix-point runs inside one HLO `while`). Returns the alive
        /// mask over `g`'s vertices.
        pub fn kcore_mask(&self, g: &Graph, k: usize) -> Result<Vec<bool>> {
            let n = g.n();
            let bucket = self.manifest.pick_bucket("kcore", n)?;
            let exe = self.executable("kcore", bucket)?;
            // isolated pad vertices peel in round one for k ≥ 1 — inert.
            let f = Filtration::constant(n);
            let (adj, _) = pad_dense(g, &f, bucket);
            let adj_lit = xla::Literal::vec1(&adj)
                .reshape(&[bucket as i64, bucket as i64])
                .map_err(|e| Error::Xla(e.to_string()))?;
            let k_lit = xla::Literal::vec1(&[k as f32])
                .reshape(&[1, 1])
                .map_err(|e| Error::Xla(e.to_string()))?;
            let result = exe
                .execute::<xla::Literal>(&[adj_lit, k_lit])
                .map_err(|e| Error::Xla(format!("execute kcore bucket {bucket}: {e}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Xla(e.to_string()))?;
            let mask_lit = result
                .to_tuple1()
                .map_err(|e| Error::Xla(format!("expected 1-tuple output: {e}")))?;
            let flat: Vec<f32> = mask_lit.to_vec().map_err(|e| Error::Xla(e.to_string()))?;
            debug_assert_eq!(flat.len(), bucket);
            Ok(flat[..n].iter().map(|&x| x != 0.0).collect())
        }

        /// Run one domination sweep (Pallas kernel semantics) for `(g, f)`.
        pub fn domination_sweep(&self, g: &Graph, f: &Filtration) -> Result<SweepOutput> {
            f.check(g)?;
            let n = g.n();
            let bucket = self.manifest.pick_bucket("domination", n)?;
            let exe = self.executable("domination", bucket)?;
            let (adj, keys) = pad_dense(g, f, bucket);

            let adj_lit = xla::Literal::vec1(&adj)
                .reshape(&[bucket as i64, bucket as i64])
                .map_err(|e| Error::Xla(e.to_string()))?;
            let key_lit = xla::Literal::vec1(&keys);

            let result = exe
                .execute::<xla::Literal>(&[adj_lit, key_lit])
                .map_err(|e| Error::Xla(format!("execute bucket {bucket}: {e}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Xla(e.to_string()))?;
            let (mask_lit, dom_lit) = result
                .to_tuple2()
                .map_err(|e| Error::Xla(format!("expected 2-tuple output: {e}")))?;
            let mask_flat: Vec<f32> = mask_lit.to_vec().map_err(|e| Error::Xla(e.to_string()))?;
            let dom_flat: Vec<f32> = dom_lit.to_vec().map_err(|e| Error::Xla(e.to_string()))?;
            debug_assert_eq!(mask_flat.len(), bucket * bucket);
            debug_assert_eq!(dom_flat.len(), bucket);

            // Un-pad; assert the inertness contract in debug builds.
            #[cfg(debug_assertions)]
            {
                for u in n..bucket {
                    debug_assert_eq!(dom_flat[u], 0.0, "pad vertex {u} flagged dominated");
                }
            }
            let mask = (0..n)
                .map(|u| (0..n).map(|v| mask_flat[u * bucket + v] != 0.0).collect())
                .collect();
            let dominated = (0..n).map(|u| dom_flat[u] != 0.0).collect();
            Ok(SweepOutput {
                mask,
                dominated,
                bucket,
            })
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::graph::gen;
        use crate::prune::domination::dominated_pairs_dense;

        fn runtime() -> XlaRuntime {
            XlaRuntime::from_default().expect("run `make artifacts` first")
        }

        #[test]
        fn platform_is_cpu_pjrt() {
            let rt = runtime();
            assert!(!rt.platform().is_empty());
            assert_eq!(rt.max_order(), 512);
        }

        #[test]
        fn sweep_matches_sparse_reference_star() {
            let rt = runtime();
            let g = gen::star(9);
            let f = Filtration::degree_superlevel(&g);
            let out = rt.domination_sweep(&g, &f).unwrap();
            assert_eq!(out.bucket, 32);
            let want = dominated_pairs_dense(&g, &f);
            assert_eq!(out.mask, want);
            for leaf in 1..9 {
                assert!(out.dominated[leaf], "leaf {leaf} dominated by hub");
            }
            assert!(!out.dominated[0]);
        }

        #[test]
        fn sweep_matches_sparse_reference_random() {
            let rt = runtime();
            let mut rng = crate::util::Rng::new(4242);
            for _ in 0..6 {
                let n = rng.range(5, 60);
                let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
                let f = crate::testutil::random_filtration(&mut rng, &g);
                let out = rt.domination_sweep(&g, &f).unwrap();
                let want = dominated_pairs_dense(&g, &f);
                assert_eq!(out.mask, want, "n={n}");
                for u in 0..n {
                    assert_eq!(out.dominated[u], want[u].iter().any(|&b| b));
                }
            }
        }

        #[test]
        fn kcore_mask_matches_bz() {
            let rt = runtime();
            let mut rng = crate::util::Rng::new(777);
            for _ in 0..6 {
                let n = rng.range(4, 70);
                let g = gen::erdos_renyi(n, 0.15, rng.next_u64());
                for k in 1..=4usize {
                    let got = rt.kcore_mask(&g, k).unwrap();
                    let core = crate::kcore::coreness(&g);
                    let want: Vec<bool> = core.iter().map(|&c| c >= k).collect();
                    assert_eq!(got, want, "n={n} k={k}");
                }
            }
        }

        #[test]
        fn kcore_mask_cycle_and_star() {
            let rt = runtime();
            let cyc = gen::cycle(10);
            assert!(rt.kcore_mask(&cyc, 2).unwrap().iter().all(|&a| a));
            assert!(rt.kcore_mask(&cyc, 3).unwrap().iter().all(|&a| !a));
            let star = gen::star(9);
            assert!(rt.kcore_mask(&star, 2).unwrap().iter().all(|&a| !a));
        }

        #[test]
        fn bucket_rounding_and_cache_reuse() {
            let rt = runtime();
            let g1 = gen::cycle(33); // → bucket 64
            let f1 = Filtration::degree(&g1);
            let o1 = rt.domination_sweep(&g1, &f1).unwrap();
            assert_eq!(o1.bucket, 64);
            // second call hits the compiled-executable cache
            let o2 = rt.domination_sweep(&g1, &f1).unwrap();
            assert_eq!(o2.mask, o1.mask);
        }

        #[test]
        fn oversize_graph_is_a_typed_error() {
            let rt = runtime();
            let g = gen::path(1000);
            let f = Filtration::degree(&g);
            match rt.domination_sweep(&g, &f) {
                Err(Error::NoBucket { order, largest }) => {
                    assert_eq!(order, 1000);
                    assert_eq!(largest, 512);
                }
                other => panic!("expected NoBucket, got {other:?}"),
            }
        }
    }
}

#[cfg(feature = "xla")]
pub use live::XlaRuntime;

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use crate::complex::Filtration;
    use crate::error::{Error, Result};
    use crate::graph::Graph;

    use super::SweepOutput;

    /// Stub runtime compiled when the `xla` feature is off: the surface
    /// of the live client with constructors that fail with a typed error.
    pub struct XlaRuntime {
        _private: (),
    }

    impl XlaRuntime {
        fn unavailable<T>() -> Result<T> {
            Err(Error::Xla(
                "crate built without the `xla` feature; dense backend unavailable".into(),
            ))
        }

        pub fn new(_dir: impl AsRef<Path>) -> Result<XlaRuntime> {
            Self::unavailable()
        }

        pub fn from_default() -> Result<XlaRuntime> {
            Self::unavailable()
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn buckets(&self) -> Vec<usize> {
            Vec::new()
        }

        pub fn max_order(&self) -> usize {
            0
        }

        pub fn kcore_mask(&self, _g: &Graph, _k: usize) -> Result<Vec<bool>> {
            Self::unavailable()
        }

        pub fn domination_sweep(&self, _g: &Graph, _f: &Filtration) -> Result<SweepOutput> {
            Self::unavailable()
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_constructors_fail_with_typed_error() {
            for err in [
                XlaRuntime::from_default().err().unwrap(),
                XlaRuntime::new("/tmp").err().unwrap(),
            ] {
                assert!(matches!(err, Error::Xla(_)), "got {err:?}");
            }
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaRuntime;

/// True when the dense XLA backend was compiled in (the `xla` feature).
pub fn backend_compiled() -> bool {
    cfg!(feature = "xla")
}

/// Convenience: the runtime if it can be constructed, `None` otherwise
/// (feature off, or artifacts missing). Callers that want to *optionally*
/// cross-check the dense path use this instead of matching on errors.
pub fn try_runtime() -> Option<XlaRuntime> {
    XlaRuntime::from_default().ok()
}

#[cfg(test)]
mod shared_tests {
    use super::*;

    #[test]
    fn sweep_output_is_plain_data() {
        let out = SweepOutput {
            mask: vec![vec![false]],
            dominated: vec![false],
            bucket: 32,
        };
        let copy = out.clone();
        assert_eq!(copy.bucket, 32);
        assert_eq!(copy.mask.len(), 1);
    }

    #[test]
    fn try_runtime_never_panics() {
        // With the feature off (or artifacts missing) this is None; with a
        // fully built backend it is Some. Either way: no panic, and a live
        // runtime implies the backend was compiled in.
        let rt = try_runtime();
        assert!(rt.is_none() || backend_compiled());
    }
}
