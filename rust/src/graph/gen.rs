//! Seeded graph generators (S2) — the synthetic stand-ins for the paper's
//! datasets (no network access in this environment; see DESIGN.md §4).
//!
//! Families:
//! * `erdos_renyi`       — G(n, p), the Remark 3 / Kahle-threshold baseline.
//! * `barabasi_albert`   — preferential attachment; heavy-tailed citation /
//!                         web-like degree sequences, many dominated leaves.
//! * `powerlaw_cluster`  — Holme–Kim: BA + triad closure; social-network-like
//!                         clustering (FACEBOOK/TWITTER ego stand-ins).
//! * `watts_strogatz`    — small-world ring; low-core lattice-like graphs.
//! * `random_geometric`  — unit-square proximity graph (FIRSTMM-like "3d
//!                         point cloud" structure: dense local communities).
//! * `planted_partition` — community structure (DBLP/Amazon-like).
//! * deterministic families: `cycle`, `complete`, `star`, `path`, `grid`,
//!   `octahedron` (the S² witness for PH-engine tests).

use super::{Graph, GraphBuilder};
use crate::util::Rng;

/// Erdős–Rényi G(n, p) via geometric edge skipping — O(n + m) expected.
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    if n >= 2 && p > 0.0 {
        if p >= 1.0 {
            return complete(n);
        }
        let logq = (1.0 - p).ln();
        // Iterate over the upper triangle with geometric jumps.
        let total: u64 = (n as u64) * (n as u64 - 1) / 2;
        let mut idx: i64 = -1;
        loop {
            let r = rng.f64().max(1e-300);
            let skip = (r.ln() / logq).floor() as i64 + 1;
            idx += skip.max(1);
            if idx as u64 >= total {
                break;
            }
            let (u, v) = unrank_pair(idx as u64, n as u64);
            edges.push((u as u32, v as u32));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Map a linear index in [0, n·(n−1)/2) to the (u < v) pair, row-major.
fn unrank_pair(idx: u64, n: u64) -> (u64, u64) {
    // Row u starts at offset u·n − u·(u+1)/2 − u ... solve by scanning rows
    // arithmetically: row u has (n − 1 − u) entries.
    let mut u = 0u64;
    let mut off = idx;
    loop {
        let row = n - 1 - u;
        if off < row {
            return (u, u + 1 + off);
        }
        off -= row;
        u += 1;
    }
}

/// Barabási–Albert preferential attachment: each new vertex attaches to
/// `m` distinct existing vertices chosen ∝ degree.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> Graph {
    assert!(m >= 1, "BA needs m >= 1");
    let m = m.min(n.saturating_sub(1)).max(1);
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    // Repeated-endpoint list: sampling uniformly from it = degree-biased.
    let mut chips: Vec<u32> = Vec::new();
    // Seed clique of m+1 vertices keeps early attachment well-defined.
    let seed_n = (m + 1).min(n);
    for a in 0..seed_n {
        for b in (a + 1)..seed_n {
            edges.push((a as u32, b as u32));
            chips.push(a as u32);
            chips.push(b as u32);
        }
    }
    for v in seed_n..n {
        let mut targets = std::collections::BTreeSet::new();
        while targets.len() < m {
            let t = chips[rng.below(chips.len())];
            targets.insert(t);
        }
        for &t in &targets {
            edges.push((v as u32, t));
            chips.push(v as u32);
            chips.push(t);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Holme–Kim power-law cluster model: BA attachment where each subsequent
/// link closes a triangle with probability `pt` — tunable clustering.
pub fn powerlaw_cluster(n: usize, m: usize, pt: f64, seed: u64) -> Graph {
    assert!(m >= 1);
    let m = m.min(n.saturating_sub(1)).max(1);
    let mut rng = Rng::new(seed);
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut chips: Vec<u32> = Vec::new();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let seed_n = (m + 1).min(n);
    let add = |edges: &mut Vec<(u32, u32)>,
                   adj: &mut Vec<Vec<u32>>,
                   chips: &mut Vec<u32>,
                   a: u32,
                   b: u32| {
        edges.push((a, b));
        adj[a as usize].push(b);
        adj[b as usize].push(a);
        chips.push(a);
        chips.push(b);
    };
    for a in 0..seed_n {
        for b in (a + 1)..seed_n {
            add(&mut edges, &mut adj, &mut chips, a as u32, b as u32);
        }
    }
    for v in seed_n..n {
        let mut targets: Vec<u32> = Vec::with_capacity(m);
        // First link: pure preferential attachment.
        let mut first = chips[rng.below(chips.len())];
        while first == v as u32 {
            first = chips[rng.below(chips.len())];
        }
        targets.push(first);
        while targets.len() < m {
            let last = *targets.last().unwrap();
            let candidate = if rng.chance(pt) && !adj[last as usize].is_empty() {
                // triad closure: neighbour of the previous target
                adj[last as usize][rng.below(adj[last as usize].len())]
            } else {
                chips[rng.below(chips.len())]
            };
            if candidate != v as u32 && !targets.contains(&candidate) {
                targets.push(candidate);
            } else {
                // fall back to PA to guarantee progress
                let c = chips[rng.below(chips.len())];
                if c != v as u32 && !targets.contains(&c) {
                    targets.push(c);
                }
            }
        }
        for t in targets {
            add(&mut edges, &mut adj, &mut chips, v as u32, t);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Watts–Strogatz small world: ring lattice with k/2 neighbours each side,
/// rewired with probability `beta`.
pub fn watts_strogatz(n: usize, k: usize, beta: f64, seed: u64) -> Graph {
    assert!(k % 2 == 0 && k < n, "WS needs even k < n");
    let mut rng = Rng::new(seed);
    let mut edge_set: std::collections::BTreeSet<(u32, u32)> = (0..n)
        .flat_map(|i| {
            (1..=k / 2).map(move |d| {
                let j = (i + d) % n;
                (i.min(j) as u32, i.max(j) as u32)
            })
        })
        .collect();
    let originals: Vec<(u32, u32)> = edge_set.iter().copied().collect();
    for (a, b) in originals {
        if rng.chance(beta) {
            // rewire b-end to a uniform non-neighbour of a
            for _ in 0..16 {
                let c = rng.below(n) as u32;
                let key = (a.min(c), a.max(c));
                if c != a && !edge_set.contains(&key) {
                    edge_set.remove(&(a, b));
                    edge_set.insert(key);
                    break;
                }
            }
        }
    }
    Graph::from_edges(n, &edge_set.into_iter().collect::<Vec<_>>())
}

/// Random geometric graph on the unit square with connection radius `r`.
pub fn random_geometric(n: usize, r: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64(), rng.f64())).collect();
    // Grid bucketing for near-linear neighbour search.
    let cell = r.max(1e-9);
    let cells = (1.0 / cell).ceil() as i64 + 1;
    let mut grid: std::collections::HashMap<(i64, i64), Vec<u32>> =
        std::collections::HashMap::new();
    for (i, &(x, y)) in pts.iter().enumerate() {
        grid.entry(((x / cell) as i64, (y / cell) as i64))
            .or_default()
            .push(i as u32);
    }
    let mut edges = Vec::new();
    let r2 = r * r;
    for (i, &(x, y)) in pts.iter().enumerate() {
        let (cx, cy) = ((x / cell) as i64, (y / cell) as i64);
        for dx in -1..=1 {
            for dy in -1..=1 {
                let (gx, gy) = (cx + dx, cy + dy);
                if gx < 0 || gy < 0 || gx > cells || gy > cells {
                    continue;
                }
                if let Some(bucket) = grid.get(&(gx, gy)) {
                    for &j in bucket {
                        if (j as usize) > i {
                            let (px, py) = pts[j as usize];
                            let (ddx, ddy) = (px - x, py - y);
                            if ddx * ddx + ddy * ddy <= r2 {
                                edges.push((i as u32, j));
                            }
                        }
                    }
                }
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Planted-partition community model: `c` communities of (roughly) equal
/// size; intra-community edges w.p. `p_in`, inter w.p. `p_out`.
pub fn planted_partition(n: usize, c: usize, p_in: f64, p_out: f64, seed: u64) -> Graph {
    assert!(c >= 1);
    let mut rng = Rng::new(seed);
    let comm: Vec<usize> = (0..n).map(|i| i % c).collect();
    let mut edges = Vec::new();
    for a in 0..n {
        for b in (a + 1)..n {
            let p = if comm[a] == comm[b] { p_in } else { p_out };
            if rng.chance(p) {
                edges.push((a as u32, b as u32));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Sparse planted-partition for large n: skip-sampling within and across
/// blocks (O(m) expected instead of O(n²)).
pub fn planted_partition_sparse(
    n: usize,
    c: usize,
    deg_in: f64,
    deg_out: f64,
    seed: u64,
) -> Graph {
    // Convert expected intra/inter degrees to probabilities.
    let size = (n / c.max(1)).max(1);
    let p_in = (deg_in / size as f64).min(1.0);
    let p_out = if n > size {
        (deg_out / (n - size) as f64).min(1.0)
    } else {
        0.0
    };
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    // Intra-community: ER per block.
    for blk in 0..c {
        let members: Vec<u32> = (0..n).filter(|i| i % c == blk).map(|i| i as u32).collect();
        let g = erdos_renyi(members.len(), p_in, rng.next_u64());
        for (a, b) in g.edges() {
            edges.push((members[a as usize], members[b as usize]));
        }
    }
    // Inter-community: global ER thinned to cross-block pairs.
    if p_out > 0.0 {
        let g = erdos_renyi(n, p_out, rng.next_u64());
        for (a, b) in g.edges() {
            if (a as usize) % c != (b as usize) % c {
                edges.push((a, b));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Cycle graph Cₙ (the Remark 11 counterexample family).
pub fn cycle(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (0..n)
        .map(|i| (i as u32, ((i + 1) % n) as u32))
        .collect();
    Graph::from_edges(n, &edges)
}

/// Complete graph Kₙ.
pub fn complete(n: usize) -> Graph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for a in 0..n {
        for b in (a + 1)..n {
            edges.push((a as u32, b as u32));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Star K₁,ₙ₋₁ (hub = 0).
pub fn star(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (1..n).map(|i| (0, i as u32)).collect();
    Graph::from_edges(n, &edges)
}

/// Path Pₙ.
pub fn path(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (1..n).map(|i| ((i - 1) as u32, i as u32)).collect();
    Graph::from_edges(n, &edges)
}

/// w×h grid lattice.
pub fn grid(w: usize, h: usize) -> Graph {
    let mut b = GraphBuilder::new(w * h);
    let id = |x: usize, y: usize| (y * w + x) as u32;
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w {
                b.add_edge(id(x, y), id(x + 1, y));
            }
            if y + 1 < h {
                b.add_edge(id(x, y), id(x, y + 1));
            }
        }
    }
    b.build()
}

/// Octahedron = boundary of the 3-dim cross-polytope ≅ S²: the canonical
/// witness for β₂ = 1 in the PH-engine tests (K₄-free, so its clique
/// complex is exactly the 2-sphere).
pub fn octahedron() -> Graph {
    // vertices 0..6; antipodal pairs (0,1), (2,3), (4,5) are NOT adjacent.
    let mut edges = Vec::new();
    for a in 0..6u32 {
        for b in (a + 1)..6u32 {
            let antipodal = (a / 2 == b / 2) && (a % 2 != b % 2);
            if !antipodal {
                edges.push((a, b));
            }
        }
    }
    Graph::from_edges(6, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::clustering;

    #[test]
    fn er_edge_count_near_expectation() {
        let n = 400;
        let p = 0.05;
        let g = erdos_renyi(n, p, 1);
        let expect = p * (n * (n - 1) / 2) as f64;
        let got = g.m() as f64;
        assert!(
            (got - expect).abs() < 4.0 * expect.sqrt() + 10.0,
            "m={got} expect≈{expect}"
        );
    }

    #[test]
    fn er_extremes() {
        assert_eq!(erdos_renyi(50, 0.0, 2).m(), 0);
        assert_eq!(erdos_renyi(10, 1.0, 3).m(), 45);
    }

    #[test]
    fn er_deterministic_in_seed() {
        let a = erdos_renyi(100, 0.1, 9);
        let b = erdos_renyi(100, 0.1, 9);
        assert_eq!(a, b);
        let c = erdos_renyi(100, 0.1, 10);
        assert_ne!(a, c);
    }

    #[test]
    fn unrank_pair_covers_triangle() {
        let n = 7u64;
        let mut seen = std::collections::BTreeSet::new();
        for idx in 0..(n * (n - 1) / 2) {
            let (u, v) = unrank_pair(idx, n);
            assert!(u < v && v < n);
            assert!(seen.insert((u, v)));
        }
        assert_eq!(seen.len() as u64, n * (n - 1) / 2);
    }

    #[test]
    fn ba_sizes_and_connectivity() {
        let g = barabasi_albert(200, 3, 5);
        assert_eq!(g.n(), 200);
        assert!(g.is_connected());
        // m edges per new vertex beyond the seed clique
        let expect = 3 * (200 - 4) + 6;
        assert_eq!(g.m(), expect);
    }

    #[test]
    fn ba_has_heavy_tail() {
        let g = barabasi_albert(500, 2, 6);
        let max_d = g.max_degree();
        let avg_d = 2.0 * g.m() as f64 / g.n() as f64;
        assert!(max_d as f64 > 4.0 * avg_d, "hub degree {max_d} vs avg {avg_d}");
    }

    #[test]
    fn powerlaw_cluster_raises_clustering() {
        let plain = barabasi_albert(300, 3, 7);
        let clustered = powerlaw_cluster(300, 3, 0.9, 7);
        assert!(
            clustering::average(&clustered) > clustering::average(&plain) + 0.05,
            "triad closure should raise CC: {} vs {}",
            clustering::average(&clustered),
            clustering::average(&plain)
        );
    }

    #[test]
    fn ws_ring_unrewired() {
        let g = watts_strogatz(20, 4, 0.0, 1);
        assert_eq!(g.m(), 40);
        assert!(g.is_connected());
        for v in 0..20u32 {
            assert_eq!(g.degree(v), 4);
        }
    }

    #[test]
    fn ws_rewiring_preserves_edge_count() {
        let g = watts_strogatz(50, 6, 0.3, 2);
        assert_eq!(g.m(), 150);
    }

    #[test]
    fn geometric_radius_monotone() {
        let small = random_geometric(200, 0.05, 3);
        let large = random_geometric(200, 0.2, 3);
        assert!(large.m() > small.m());
    }

    #[test]
    fn planted_partition_is_assortative() {
        let g = planted_partition(120, 4, 0.4, 0.01, 4);
        let mut intra = 0;
        let mut inter = 0;
        for (a, b) in g.edges() {
            if (a as usize) % 4 == (b as usize) % 4 {
                intra += 1;
            } else {
                inter += 1;
            }
        }
        assert!(intra > inter * 2, "intra={intra} inter={inter}");
    }

    #[test]
    fn sparse_partition_scales() {
        let g = planted_partition_sparse(5000, 10, 8.0, 2.0, 5);
        let avg_deg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!((avg_deg - 10.0).abs() < 2.0, "avg degree {avg_deg}");
    }

    #[test]
    fn deterministic_families() {
        assert_eq!(cycle(5).m(), 5);
        assert_eq!(complete(6).m(), 15);
        assert_eq!(star(7).m(), 6);
        assert_eq!(path(4).m(), 3);
        assert_eq!(grid(3, 3).m(), 12);
        let oct = octahedron();
        assert_eq!(oct.n(), 6);
        assert_eq!(oct.m(), 12);
        for v in 0..6u32 {
            assert_eq!(oct.degree(v), 4);
        }
        // no K4: every triangle's vertex trio misses its antipode
        assert!(!oct.has_edge(0, 1));
        assert!(!oct.has_edge(2, 3));
        assert!(!oct.has_edge(4, 5));
    }
}
