//! Connected-component decomposition — the sharding substrate of the
//! component-sharded persistence pipeline (see `homology::sharded`).
//!
//! Persistence diagrams are additive over disjoint unions: the boundary
//! matrix of `G = G₁ ⊔ … ⊔ G_c` is block-diagonal in any filtration
//! order, so column reduction never mixes blocks and every persistence
//! pair lives inside one component. Splitting before PH therefore turns
//! the `O((Σnᵢ)³)` monolithic reduction into `Σ O(nᵢ³)` independent jobs
//! — an *exact* reduction in the same spirit as Theorems 2 and 7, and
//! CoralTDA's (k+1)-core typically shatters a network into many small
//! components, which is precisely when sharding pays off.

use crate::complex::Filtration;
use crate::graph::Graph;

/// One connected component of a graph, as an induced subgraph.
#[derive(Clone, Debug)]
pub struct Component {
    /// The component with vertices compacted to `0..nᵢ`.
    pub graph: Graph,
    /// `new id -> old id` (ascending), as in every reduction in the crate.
    pub kept_old_ids: Vec<u32>,
}

/// One shard of a sharded PH job: a component plus its restricted
/// filtration (original values, per Remark 1 — never recomputed).
#[derive(Clone, Debug)]
pub struct Shard {
    pub graph: Graph,
    pub filtration: Filtration,
    pub kept_old_ids: Vec<u32>,
}

/// Label every vertex with its component id (`0..count`, in order of the
/// smallest vertex of each component) and return the component count.
/// This is the labelled extension of [`Graph::components`].
pub fn component_labels(g: &Graph) -> (Vec<u32>, usize) {
    let n = g.n();
    let mut label = vec![u32::MAX; n];
    let mut stack: Vec<u32> = Vec::new();
    let mut count: u32 = 0;
    for s in 0..n as u32 {
        if label[s as usize] != u32::MAX {
            continue;
        }
        label[s as usize] = count;
        stack.push(s);
        while let Some(v) = stack.pop() {
            for &w in g.neighbors(v) {
                if label[w as usize] == u32::MAX {
                    label[w as usize] = count;
                    stack.push(w);
                }
            }
        }
        count += 1;
    }
    (label, count as usize)
}

/// Split a graph into its connected components in O(n + m) total — one
/// labelling pass plus one CSR re-assembly pass per component (no O(n)
/// mask per component, so a graph of many isolates stays linear).
pub fn decompose(g: &Graph) -> Vec<Component> {
    let (labels, count) = component_labels(g);
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); count];
    for v in 0..g.n() as u32 {
        members[labels[v as usize] as usize].push(v);
    }
    // Global old -> new map; within a component the assignment is
    // monotone, so mapped neighbour lists stay sorted.
    let mut new_id = vec![0u32; g.n()];
    for part in &members {
        for (i, &v) in part.iter().enumerate() {
            new_id[v as usize] = i as u32;
        }
    }
    members
        .into_iter()
        .map(|old_ids| {
            let mut offsets = Vec::with_capacity(old_ids.len() + 1);
            let mut neighbors = Vec::new();
            offsets.push(0);
            for &v in &old_ids {
                neighbors.extend(g.neighbors(v).iter().map(|&w| new_id[w as usize]));
                offsets.push(neighbors.len());
            }
            Component {
                graph: Graph::from_csr_parts(offsets, neighbors),
                kept_old_ids: old_ids,
            }
        })
        .collect()
}

/// Split `(G, f)` into per-component shards, restricting the filtration
/// to each component (original values; Remark 1).
pub fn decompose_filtered(g: &Graph, f: &Filtration) -> Vec<Shard> {
    f.check(g).expect("filtration must match graph");
    decompose(g)
        .into_iter()
        .map(|c| {
            let filtration = f.restrict(&c.kept_old_ids);
            Shard {
                graph: c.graph,
                filtration,
                kept_old_ids: c.kept_old_ids,
            }
        })
        .collect()
}

/// Disjoint union `G₁ ⊔ … ⊔ G_c` with vertex ids offset in input order —
/// the inverse operation of [`decompose`], used by the shard tests and
/// the multi-component bench generators.
pub fn disjoint_union(parts: &[Graph]) -> Graph {
    let total: usize = parts.iter().map(|g| g.n()).sum();
    let mut offsets = Vec::with_capacity(total + 1);
    let mut neighbors = Vec::new();
    offsets.push(0);
    let mut base: u32 = 0;
    for g in parts {
        for v in 0..g.n() as u32 {
            neighbors.extend(g.neighbors(v).iter().map(|&w| w + base));
            offsets.push(neighbors.len());
        }
        base += g.n() as u32;
    }
    Graph::from_csr_parts(offsets, neighbors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn labels_match_component_count() {
        let g = Graph::from_edges(5, &[(0, 1), (2, 3)]);
        let (labels, count) = component_labels(&g);
        assert_eq!(count, 3);
        assert_eq!(count, g.components());
        assert_eq!(labels, vec![0, 0, 1, 1, 2]);
    }

    #[test]
    fn decompose_partitions_vertices_and_edges() {
        let g = disjoint_union(&[gen::cycle(5), gen::complete(4), Graph::empty(3)]);
        let parts = decompose(&g);
        assert_eq!(parts.len(), 5); // cycle, K4, three isolates
        let n_sum: usize = parts.iter().map(|p| p.graph.n()).sum();
        let m_sum: usize = parts.iter().map(|p| p.graph.m()).sum();
        assert_eq!(n_sum, g.n());
        assert_eq!(m_sum, g.m());
        // kept_old_ids are ascending and jointly cover 0..n exactly once
        let mut all: Vec<u32> = Vec::new();
        for p in &parts {
            assert!(p.kept_old_ids.windows(2).all(|w| w[0] < w[1]));
            assert_eq!(p.kept_old_ids.len(), p.graph.n());
            all.extend_from_slice(&p.kept_old_ids);
        }
        all.sort_unstable();
        assert_eq!(all, (0..g.n() as u32).collect::<Vec<_>>());
    }

    #[test]
    fn components_are_induced_subgraphs() {
        let g = disjoint_union(&[gen::erdos_renyi(12, 0.3, 7), gen::path(6), gen::star(5)]);
        for p in decompose(&g) {
            for a in 0..p.graph.n() as u32 {
                for b in 0..p.graph.n() as u32 {
                    assert_eq!(
                        p.graph.has_edge(a, b),
                        g.has_edge(p.kept_old_ids[a as usize], p.kept_old_ids[b as usize])
                    );
                }
            }
            assert!(p.graph.is_connected());
        }
    }

    #[test]
    fn connected_graph_is_one_component() {
        let g = gen::barabasi_albert(30, 2, 3);
        let parts = decompose(&g);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].graph, g);
        assert_eq!(parts[0].kept_old_ids, (0..30).collect::<Vec<u32>>());
    }

    #[test]
    fn empty_graph_decomposes_to_nothing() {
        assert!(decompose(&Graph::empty(0)).is_empty());
        assert_eq!(disjoint_union(&[]).n(), 0);
    }

    #[test]
    fn filtered_shards_keep_original_values() {
        let g = disjoint_union(&[gen::cycle(4), gen::star(4)]);
        let f = Filtration::superlevel((0..8).map(|v| v as f64).collect());
        for s in decompose_filtered(&g, &f) {
            for (new, &old) in s.kept_old_ids.iter().enumerate() {
                assert_eq!(s.filtration.value(new as u32), f.value(old));
            }
            assert_eq!(s.filtration.direction(), f.direction());
        }
    }

    #[test]
    fn union_then_decompose_roundtrips_sizes() {
        let parts = [gen::cycle(6), gen::complete(5), gen::grid(3, 3)];
        let g = disjoint_union(&parts);
        let back = decompose(&g);
        assert_eq!(back.len(), parts.len());
        let mut got: Vec<usize> = back.iter().map(|p| p.graph.n()).collect();
        let mut want: Vec<usize> = parts.iter().map(|p| p.n()).collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
