//! Immutable CSR graph — the substrate every algorithm in the crate runs on.
//!
//! Undirected, simple (no self-loops, no multi-edges), vertices are
//! `0..n` as `u32`. Neighbour lists are sorted, enabling O(log d) edge
//! queries and O(d₁+d₂) sorted intersections (the hot operation in both
//! clique enumeration and domination checks).

use crate::error::{Error, Result};

/// Compressed-sparse-row undirected graph.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Graph {
    offsets: Vec<usize>,
    neighbors: Vec<u32>,
}

impl Graph {
    /// Build from an edge list; duplicates and self-loops are dropped.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Graph {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(a, b) in edges {
            let (a, b) = (a as usize, b as usize);
            assert!(a < n && b < n, "edge ({a},{b}) out of range for n={n}");
            if a == b {
                continue;
            }
            adj[a].push(b as u32);
            adj[b].push(a as u32);
        }
        let mut offsets = Vec::with_capacity(n + 1);
        let mut neighbors = Vec::new();
        offsets.push(0);
        for list in adj.iter_mut() {
            list.sort_unstable();
            list.dedup();
            neighbors.extend_from_slice(list);
            offsets.push(neighbors.len());
        }
        Graph { offsets, neighbors }
    }

    /// Assemble from raw CSR parts (crate-internal, used by
    /// [`crate::graph::decompose`] and friends to skip the per-edge
    /// rebuild). Callers guarantee per-vertex neighbour lists are sorted
    /// and symmetric.
    pub(crate) fn from_csr_parts(offsets: Vec<usize>, neighbors: Vec<u32>) -> Graph {
        debug_assert_eq!(offsets.first(), Some(&0));
        debug_assert_eq!(offsets.last().copied(), Some(neighbors.len()));
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]));
        Graph { offsets, neighbors }
    }

    /// Graph with `n` vertices and no edges.
    pub fn empty(n: usize) -> Graph {
        Graph {
            offsets: vec![0; n + 1],
            neighbors: Vec::new(),
        }
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        let v = v as usize;
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Sorted neighbour slice of `v`.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let v = v as usize;
        &self.neighbors[self.offsets[v]..self.offsets[v + 1]]
    }

    /// Edge query via binary search: O(log deg(u)).
    #[inline]
    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        if u == v {
            return false;
        }
        // Search the smaller list.
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        self.neighbors(a).binary_search(&b).is_ok()
    }

    /// All degrees.
    pub fn degrees(&self) -> Vec<usize> {
        (0..self.n() as u32).map(|v| self.degree(v)).collect()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n() as u32)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Iterate undirected edges with `u < v`.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.n() as u32).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .copied()
                .filter(move |&v| u < v)
                .map(move |v| (u, v))
        })
    }

    /// Induced subgraph on vertices where `keep[v]` is true.
    ///
    /// Returns the subgraph plus the mapping `new id -> old id`
    /// (ascending). Edge set = edges with both endpoints kept.
    pub fn induced(&self, keep: &[bool]) -> (Graph, Vec<u32>) {
        assert_eq!(keep.len(), self.n());
        let old_ids: Vec<u32> = (0..self.n() as u32)
            .filter(|&v| keep[v as usize])
            .collect();
        let mut new_id = vec![u32::MAX; self.n()];
        for (new, &old) in old_ids.iter().enumerate() {
            new_id[old as usize] = new as u32;
        }
        // degree-sum upper bound on surviving half-edges: one reservation
        // instead of repeated doubling reallocations. Exact when the kept
        // set is neighbourhood-closed; otherwise an overestimate (sparse
        // kept sets over hubs reserve more than they fill)
        let cap: usize = old_ids.iter().map(|&v| self.degree(v)).sum();
        let mut offsets = Vec::with_capacity(old_ids.len() + 1);
        let mut neighbors = Vec::with_capacity(cap);
        offsets.push(0);
        for &old in &old_ids {
            for &w in self.neighbors(old) {
                if keep[w as usize] {
                    neighbors.push(new_id[w as usize]);
                }
            }
            offsets.push(neighbors.len());
        }
        (Graph { offsets, neighbors }, old_ids)
    }

    /// Induced subgraph on an explicit (sorted or unsorted) vertex set.
    ///
    /// A strictly-ascending vertex set (the common case: ego extractions
    /// and every `kept_old_ids` mapping in the crate) takes an O(s log s)
    /// path that maps neighbours by binary search into the set itself —
    /// no O(n) `keep` mask, so extracting a small subgraph from a huge
    /// graph costs only the subgraph.
    pub fn induced_on(&self, vertices: &[u32]) -> (Graph, Vec<u32>) {
        let sorted = vertices.windows(2).all(|w| w[0] < w[1]);
        if !sorted {
            let mut keep = vec![false; self.n()];
            for &v in vertices {
                keep[v as usize] = true;
            }
            return self.induced(&keep);
        }
        let old_ids = vertices.to_vec();
        let cap: usize = old_ids.iter().map(|&v| self.degree(v)).sum();
        let mut offsets = Vec::with_capacity(old_ids.len() + 1);
        let mut neighbors = Vec::with_capacity(cap);
        offsets.push(0);
        for &old in &old_ids {
            for &w in self.neighbors(old) {
                if let Ok(new) = vertices.binary_search(&w) {
                    neighbors.push(new as u32);
                }
            }
            offsets.push(neighbors.len());
        }
        (Graph { offsets, neighbors }, old_ids)
    }

    /// Number of connected components (isolated vertices count).
    /// The labelled variant lives in [`crate::graph::decompose`], which
    /// also materialises the per-component subgraphs for shard execution.
    pub fn components(&self) -> usize {
        crate::graph::decompose::component_labels(self).1
    }

    pub fn is_connected(&self) -> bool {
        self.n() <= 1 || self.components() == 1
    }

    /// BFS distances from `src`; `usize::MAX` for unreachable.
    pub fn bfs_distances(&self, src: u32) -> Vec<usize> {
        let mut dist = vec![usize::MAX; self.n()];
        let mut queue = std::collections::VecDeque::new();
        dist[src as usize] = 0;
        queue.push_back(src);
        while let Some(v) = queue.pop_front() {
            let dv = dist[v as usize];
            for &w in self.neighbors(v) {
                if dist[w as usize] == usize::MAX {
                    dist[w as usize] = dv + 1;
                    queue.push_back(w);
                }
            }
        }
        dist
    }

    /// Vertices within `hops` of `center` (the paper's §6.2 ego-network
    /// extraction), including the center.
    pub fn ego_vertices(&self, center: u32, hops: usize) -> Vec<u32> {
        let mut dist = vec![usize::MAX; self.n()];
        let mut queue = std::collections::VecDeque::new();
        dist[center as usize] = 0;
        queue.push_back(center);
        let mut out = vec![center];
        while let Some(v) = queue.pop_front() {
            let dv = dist[v as usize];
            if dv == hops {
                continue;
            }
            for &w in self.neighbors(v) {
                if dist[w as usize] == usize::MAX {
                    dist[w as usize] = dv + 1;
                    out.push(w);
                    queue.push_back(w);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Dense f32 adjacency (row-major), the marshalling format for the XLA
    /// domination artifact.
    pub fn to_dense_f32(&self) -> Vec<f32> {
        let n = self.n();
        let mut a = vec![0.0f32; n * n];
        for (u, v) in self.edges() {
            a[u as usize * n + v as usize] = 1.0;
            a[v as usize * n + u as usize] = 1.0;
        }
        a
    }

    /// Validate a vertex id.
    pub fn check_vertex(&self, v: usize) -> Result<()> {
        if v < self.n() {
            Ok(())
        } else {
            Err(Error::VertexOutOfRange {
                vertex: v,
                order: self.n(),
            })
        }
    }

    /// Sorted intersection size of two neighbour lists (shared triangles).
    pub fn common_neighbors(&self, u: u32, v: u32) -> usize {
        sorted_intersection_count(self.neighbors(u), self.neighbors(v))
    }
}

/// Count |a ∩ b| for sorted slices via merge walk.
#[inline]
pub fn sorted_intersection_count(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut c) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

/// Materialise |a ∩ b| for sorted slices into `out` (cleared first).
#[inline]
pub fn sorted_intersection_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
}

/// Is sorted `a` a subset of sorted `b`?
#[inline]
pub fn sorted_is_subset(a: &[u32], b: &[u32]) -> bool {
    if a.len() > b.len() {
        return false;
    }
    let mut j = 0;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j == b.len() || b[j] != x {
            return false;
        }
        j += 1;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_tail() -> Graph {
        // 0-1-2 triangle, 2-3 tail
        Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)])
    }

    #[test]
    fn basic_counts() {
        let g = triangle_plus_tail();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.max_degree(), 3);
    }

    #[test]
    fn from_edges_dedups_and_drops_loops() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)]);
        assert_eq!(g.m(), 1);
        assert_eq!(g.degree(2), 0);
    }

    #[test]
    fn neighbors_sorted() {
        let g = Graph::from_edges(5, &[(2, 4), (2, 0), (2, 3), (2, 1)]);
        assert_eq!(g.neighbors(2), &[0, 1, 3, 4]);
    }

    #[test]
    fn has_edge_symmetric() {
        let g = triangle_plus_tail();
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(0, 3));
        assert!(!g.has_edge(1, 1));
    }

    #[test]
    fn edges_iterator_ordered_unique() {
        let g = triangle_plus_tail();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 2), (2, 3)]);
    }

    #[test]
    fn induced_subgraph_remaps() {
        let g = triangle_plus_tail();
        let keep = vec![true, false, true, true];
        let (h, ids) = g.induced(&keep);
        assert_eq!(ids, vec![0, 2, 3]);
        assert_eq!(h.n(), 3);
        // surviving edges: 0-2 and 2-3 → new ids (0,1), (1,2)
        assert_eq!(h.edges().collect::<Vec<_>>(), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn induced_on_sorted_and_unsorted_agree() {
        let g = Graph::from_edges(
            8,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7), (0, 7), (2, 6)],
        );
        let sorted = vec![1u32, 2, 3, 6];
        let unsorted = vec![6u32, 2, 1, 3];
        let (hs, ids_s) = g.induced_on(&sorted);
        let (hu, ids_u) = g.induced_on(&unsorted);
        assert_eq!(ids_s, vec![1, 2, 3, 6]);
        assert_eq!(ids_s, ids_u);
        assert_eq!(hs, hu);
        assert_eq!(hs.edges().collect::<Vec<_>>(), vec![(0, 1), (1, 2), (1, 3)]);
    }

    #[test]
    fn induced_on_duplicate_input_falls_back_to_mask_path() {
        // duplicates are not strictly ascending → the keep-mask path
        // dedups them, same as before
        let g = triangle_plus_tail();
        let (h, ids) = g.induced_on(&[2, 2, 3]);
        assert_eq!(ids, vec![2, 3]);
        assert_eq!(h.m(), 1);
    }

    #[test]
    fn components_and_connectivity() {
        let g = Graph::from_edges(5, &[(0, 1), (2, 3)]);
        assert_eq!(g.components(), 3); // {0,1},{2,3},{4}
        assert!(!g.is_connected());
        assert!(triangle_plus_tail().is_connected());
        assert!(Graph::empty(0).is_connected());
        assert!(Graph::empty(1).is_connected());
    }

    #[test]
    fn bfs_distances_path() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(g.bfs_distances(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn ego_vertices_one_hop() {
        let g = triangle_plus_tail();
        assert_eq!(g.ego_vertices(0, 1), vec![0, 1, 2]);
        assert_eq!(g.ego_vertices(3, 1), vec![2, 3]);
        assert_eq!(g.ego_vertices(3, 2), vec![0, 1, 2, 3]);
    }

    #[test]
    fn dense_roundtrip() {
        let g = triangle_plus_tail();
        let a = g.to_dense_f32();
        let n = g.n();
        for u in 0..n {
            assert_eq!(a[u * n + u], 0.0);
            for v in 0..n {
                let want = if g.has_edge(u as u32, v as u32) { 1.0 } else { 0.0 };
                assert_eq!(a[u * n + v], want);
            }
        }
    }

    #[test]
    fn sorted_set_helpers() {
        assert_eq!(sorted_intersection_count(&[1, 3, 5], &[2, 3, 5, 7]), 2);
        assert!(sorted_is_subset(&[2, 5], &[1, 2, 3, 5]));
        assert!(!sorted_is_subset(&[2, 6], &[1, 2, 3, 5]));
        assert!(sorted_is_subset(&[], &[1]));
        let mut out = Vec::new();
        sorted_intersection_into(&[1, 2, 9], &[2, 9, 10], &mut out);
        assert_eq!(out, vec![2, 9]);
    }

    #[test]
    fn check_vertex_bounds() {
        let g = triangle_plus_tail();
        assert!(g.check_vertex(3).is_ok());
        assert!(g.check_vertex(4).is_err());
    }
}
