//! Incremental graph builder (mutable edge accumulation → immutable CSR).

use super::Graph;

/// Accumulates edges, then freezes into a [`Graph`].
#[derive(Debug, Clone, Default)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(u32, u32)>,
}

impl GraphBuilder {
    pub fn new(n: usize) -> Self {
        GraphBuilder { n, edges: Vec::new() }
    }

    /// Add an undirected edge; self-loops are ignored at build time.
    pub fn add_edge(&mut self, a: u32, b: u32) -> &mut Self {
        let hi = a.max(b) as usize;
        if hi >= self.n {
            self.n = hi + 1;
        }
        self.edges.push((a, b));
        self
    }

    /// Ensure the graph has at least `n` vertices (for trailing isolates).
    pub fn ensure_vertices(&mut self, n: usize) -> &mut Self {
        self.n = self.n.max(n);
        self
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    pub fn build(&self) -> Graph {
        Graph::from_edges(self.n, &self.edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_to_fit() {
        let mut b = GraphBuilder::new(0);
        b.add_edge(0, 5);
        let g = b.build();
        assert_eq!(g.n(), 6);
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn isolates_preserved() {
        let mut b = GraphBuilder::new(0);
        b.add_edge(0, 1).ensure_vertices(10);
        let g = b.build();
        assert_eq!(g.n(), 10);
        assert_eq!(g.degree(9), 0);
    }

    #[test]
    fn chained_building() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1).add_edge(1, 2);
        assert_eq!(b.edge_count(), 2);
        assert_eq!(b.build().m(), 2);
    }
}
