//! Edge-list IO in the SNAP plain-text format (`# comments`, one
//! whitespace-separated `u v` pair per line). The large-network benches
//! read/write this format so runs can be checkpointed and inspected.

use std::io::{Read, Write};
use std::path::Path;

use super::Graph;
use crate::error::{Error, Result};

/// Parse an edge list from a string. Vertex ids may be arbitrary u32s;
/// they are compacted to `0..n` preserving order of first appearance? No —
/// ids are used verbatim, with `n = max id + 1`, matching SNAP semantics.
pub fn parse_edge_list(text: &str) -> Result<Graph> {
    let mut edges = Vec::new();
    let mut max_id: u32 = 0;
    let mut any = false;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let a = it
            .next()
            .ok_or_else(|| Error::Parse(format!("line {}: missing source", lineno + 1)))?;
        let b = it
            .next()
            .ok_or_else(|| Error::Parse(format!("line {}: missing target", lineno + 1)))?;
        let a: u32 = a
            .parse()
            .map_err(|_| Error::Parse(format!("line {}: bad vertex id {a:?}", lineno + 1)))?;
        let b: u32 = b
            .parse()
            .map_err(|_| Error::Parse(format!("line {}: bad vertex id {b:?}", lineno + 1)))?;
        max_id = max_id.max(a).max(b);
        edges.push((a, b));
        any = true;
    }
    let n = if any { max_id as usize + 1 } else { 0 };
    Ok(Graph::from_edges(n, &edges))
}

/// Read an edge-list file.
pub fn read_edge_list(path: impl AsRef<Path>) -> Result<Graph> {
    let mut f = std::fs::File::open(path.as_ref())
        .map_err(|e| Error::Io(format!("{}: {e}", path.as_ref().display())))?;
    let mut text = String::new();
    f.read_to_string(&mut text)
        .map_err(|e| Error::Io(e.to_string()))?;
    parse_edge_list(&text)
}

/// Write a graph as an edge list with a provenance header.
pub fn write_edge_list(g: &Graph, path: impl AsRef<Path>, comment: &str) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .map_err(|e| Error::Io(format!("{}: {e}", path.as_ref().display())))?;
    writeln!(f, "# {comment}")?;
    writeln!(f, "# n={} m={}", g.n(), g.m())?;
    for (u, v) in g.edges() {
        writeln!(f, "{u}\t{v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let g = parse_edge_list("# header\n0 1\n1 2\n\n% alt comment\n2 0\n").unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
    }

    #[test]
    fn parse_tabs_and_gaps() {
        let g = parse_edge_list("0\t5\n3   4").unwrap();
        assert_eq!(g.n(), 6);
        assert!(g.has_edge(0, 5));
        assert!(g.has_edge(3, 4));
    }

    #[test]
    fn parse_errors_are_located() {
        let err = parse_edge_list("0 1\nbogus").unwrap_err();
        assert!(err.to_string().contains("line 2"));
        let err = parse_edge_list("7").unwrap_err();
        assert!(err.to_string().contains("missing target"));
    }

    #[test]
    fn empty_input_is_empty_graph() {
        let g = parse_edge_list("# nothing\n").unwrap();
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn roundtrip_through_file() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let dir = std::env::temp_dir().join("coral_prunit_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c4.txt");
        write_edge_list(&g, &path, "C4 test").unwrap();
        let h = read_edge_list(&path).unwrap();
        assert_eq!(g, h);
    }
}
