//! Edge-list IO in the SNAP plain-text format (`# comments`, one
//! whitespace-separated `u v` pair per line). The large-network benches
//! read/write this format so runs can be checkpointed and inspected.

use std::io::{Read, Write};
use std::path::Path;

use super::Graph;
use crate::error::{Error, Result};

/// Parse an edge list from a string. Vertex ids are used verbatim, with
/// `n = max id + 1`, matching SNAP semantics (no compaction). Undirected
/// edges are normalised to `(min, max)` and deduplicated, so a file that
/// lists both `u v` and `v u` (or repeats a pair) yields each edge once.
/// Self-loops and ids of `u32::MAX` (which would overflow `n`) are
/// rejected with a located [`Error::Parse`].
pub fn parse_edge_list(text: &str) -> Result<Graph> {
    let mut edges = Vec::new();
    let mut max_id: u32 = 0;
    let mut any = false;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let a = it
            .next()
            .ok_or_else(|| Error::Parse(format!("line {}: missing source", lineno + 1)))?;
        let b = it
            .next()
            .ok_or_else(|| Error::Parse(format!("line {}: missing target", lineno + 1)))?;
        let a: u32 = a
            .parse()
            .map_err(|_| Error::Parse(format!("line {}: bad vertex id {a:?}", lineno + 1)))?;
        let b: u32 = b
            .parse()
            .map_err(|_| Error::Parse(format!("line {}: bad vertex id {b:?}", lineno + 1)))?;
        if a == u32::MAX || b == u32::MAX {
            return Err(Error::Parse(format!(
                "line {}: vertex id {} overflows the u32 order (max id is {})",
                lineno + 1,
                u32::MAX,
                u32::MAX - 1
            )));
        }
        if a == b {
            return Err(Error::Parse(format!(
                "line {}: self-loop {a} {b} (simple graphs only)",
                lineno + 1
            )));
        }
        max_id = max_id.max(a).max(b);
        edges.push((a.min(b), a.max(b)));
        any = true;
    }
    edges.sort_unstable();
    edges.dedup();
    let n = if any { max_id as usize + 1 } else { 0 };
    Ok(Graph::from_edges(n, &edges))
}

/// Read an edge-list file.
pub fn read_edge_list(path: impl AsRef<Path>) -> Result<Graph> {
    let mut f = std::fs::File::open(path.as_ref())
        .map_err(|e| Error::Io(format!("{}: {e}", path.as_ref().display())))?;
    let mut text = String::new();
    f.read_to_string(&mut text)
        .map_err(|e| Error::Io(e.to_string()))?;
    parse_edge_list(&text)
}

/// Write a graph as an edge list with a provenance header.
pub fn write_edge_list(g: &Graph, path: impl AsRef<Path>, comment: &str) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .map_err(|e| Error::Io(format!("{}: {e}", path.as_ref().display())))?;
    writeln!(f, "# {comment}")?;
    writeln!(f, "# n={} m={}", g.n(), g.m())?;
    for (u, v) in g.edges() {
        writeln!(f, "{u}\t{v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let g = parse_edge_list("# header\n0 1\n1 2\n\n% alt comment\n2 0\n").unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
    }

    #[test]
    fn parse_tabs_and_gaps() {
        let g = parse_edge_list("0\t5\n3   4").unwrap();
        assert_eq!(g.n(), 6);
        assert!(g.has_edge(0, 5));
        assert!(g.has_edge(3, 4));
    }

    #[test]
    fn parse_errors_are_located() {
        let err = parse_edge_list("0 1\nbogus").unwrap_err();
        assert!(err.to_string().contains("line 2"));
        let err = parse_edge_list("7").unwrap_err();
        assert!(err.to_string().contains("missing target"));
    }

    #[test]
    fn max_u32_vertex_id_is_rejected() {
        let err = parse_edge_list(&format!("0 {}\n", u32::MAX)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 1"), "{msg}");
        assert!(msg.contains("overflow"), "{msg}");
        // rejected on either endpoint
        assert!(parse_edge_list(&format!("{} 3\n", u32::MAX)).is_err());
    }

    #[test]
    fn self_loops_are_rejected_with_location() {
        let err = parse_edge_list("0 1\n2 2\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("self-loop"), "{msg}");
    }

    #[test]
    fn duplicate_and_reversed_edges_dedup() {
        let g = parse_edge_list("0 1\n1 0\n0 1\n1 2\n2 1\n").unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 2));
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn empty_input_is_empty_graph() {
        let g = parse_edge_list("# nothing\n").unwrap();
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn roundtrip_through_file() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let dir = std::env::temp_dir().join("coral_prunit_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c4.txt");
        write_edge_list(&g, &path, "C4 test").unwrap();
        let h = read_edge_list(&path).unwrap();
        assert_eq!(g, h);
    }
}
