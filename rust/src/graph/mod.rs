//! Graph substrate: compact CSR graphs, builders, IO, generators, and
//! clustering-coefficient analysis (S1/S2/S10 in DESIGN.md).

pub mod builder;
pub mod clustering;
pub mod core;
pub mod gen;
pub mod io;

pub use builder::GraphBuilder;
pub use core::Graph;
