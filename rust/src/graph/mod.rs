//! Graph substrate: compact CSR graphs, builders, IO, generators,
//! clustering-coefficient analysis, and connected-component
//! decomposition (the sharding substrate; see README.md).

pub mod builder;
pub mod clustering;
pub mod core;
pub mod decompose;
pub mod gen;
pub mod io;

pub use builder::GraphBuilder;
pub use core::Graph;
pub use decompose::{decompose, decompose_filtered, disjoint_union, Component, Shard};
