//! Clustering coefficients (S10) — the statistic behind the paper's
//! Figure 2 / Figure 10 analysis and the Appendix D.2 conjecture relating
//! `CC(G)` to (non)trivial higher persistence diagrams.

use super::core::sorted_intersection_count;
use super::Graph;

/// Local clustering coefficient of `v`: triangles through `v` divided by
/// `deg(v)·(deg(v)−1)/2`; zero when `deg(v) < 2`.
pub fn local(g: &Graph, v: u32) -> f64 {
    let d = g.degree(v);
    if d < 2 {
        return 0.0;
    }
    let nbrs = g.neighbors(v);
    // Count edges among neighbours via sorted intersections.
    let mut tri = 0usize;
    for (i, &u) in nbrs.iter().enumerate() {
        // only count pairs once: neighbours after u in v's list
        let rest = &nbrs[i + 1..];
        tri += sorted_intersection_count(g.neighbors(u), rest);
    }
    2.0 * tri as f64 / (d * (d - 1)) as f64
}

/// Average clustering coefficient (mean of local CCs over all vertices) —
/// the "clustering coefficient" reported in the paper's figures.
pub fn average(g: &Graph) -> f64 {
    if g.n() == 0 {
        return 0.0;
    }
    (0..g.n() as u32).map(|v| local(g, v)).sum::<f64>() / g.n() as f64
}

/// Global transitivity: 3·triangles / connected triples.
pub fn transitivity(g: &Graph) -> f64 {
    let mut tri3 = 0usize; // each triangle counted 3 times
    let mut triples = 0usize;
    for v in 0..g.n() as u32 {
        let d = g.degree(v);
        triples += d * d.saturating_sub(1) / 2;
        let nbrs = g.neighbors(v);
        for (i, &u) in nbrs.iter().enumerate() {
            tri3 += sorted_intersection_count(g.neighbors(u), &nbrs[i + 1..]);
        }
    }
    if triples == 0 {
        0.0
    } else {
        // tri3 already counts each triangle once per apex vertex = 3 total
        tri3 as f64 / triples as f64
    }
}

/// Sequentially-sampled approximation of the average clustering
/// coefficient with early stopping (paper Appendix D.2: "a stopping
/// condition can be applied to terminate early when the coefficient can
/// be approximated"). Samples vertex CCs until the standard error drops
/// below `tol` (or all vertices are used); returns (estimate, samples).
pub fn approximate_average(g: &Graph, tol: f64, seed: u64) -> (f64, usize) {
    let n = g.n();
    if n == 0 {
        return (0.0, 0);
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut rng = crate::util::Rng::new(seed);
    rng.shuffle(&mut order);
    let mut sum = 0.0;
    let mut sum_sq = 0.0;
    let min_samples = 32.min(n);
    for (i, &v) in order.iter().enumerate() {
        let c = local(g, v);
        sum += c;
        sum_sq += c * c;
        let k = i + 1;
        if k >= min_samples {
            let mean = sum / k as f64;
            let var = (sum_sq / k as f64 - mean * mean).max(0.0);
            let stderr = (var / k as f64).sqrt();
            if stderr < tol {
                return (mean, k);
            }
        }
    }
    (sum / n as f64, n)
}

/// The Appendix D.2 conjecture as a predictor: for k ≥ 2 there are bands
/// `(alpha_k, beta_k)` such that `CC(G)` outside the band predicts a
/// trivial `PD_k(G)` with high probability. Returns `true` when the
/// conjecture predicts **trivial** higher diagrams (CC too low or too
/// high), i.e. the expensive β_k computation can be skipped.
pub fn conjecture_predicts_trivial(cc: f64, alpha_k: f64, beta_k: f64) -> bool {
    debug_assert!(alpha_k < beta_k);
    cc < alpha_k || cc > beta_k
}

/// Total triangle count of the graph.
pub fn triangle_count(g: &Graph) -> usize {
    let mut tri = 0usize;
    for v in 0..g.n() as u32 {
        let nbrs = g.neighbors(v);
        for (i, &u) in nbrs.iter().enumerate() {
            if u < v {
                continue; // apex ordering: count each triangle at min vertex
            }
            // pairs (u, w) with v < u < w all adjacent to v and u~w
            tri += sorted_intersection_count(
                g.neighbors(u),
                &nbrs[i + 1..],
            );
        }
    }
    // Each triangle {a<b<c} is counted once at apex a with pair (b, c)?
    // At apex v=a we iterate u=b and intersect nbrs(b) with a's neighbours
    // after b → counts c once. Larger apexes skip via the u < v guard.
    tri
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangle_has_cc_one() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]);
        assert_eq!(average(&g), 1.0);
        assert_eq!(transitivity(&g), 1.0);
        assert_eq!(triangle_count(&g), 1);
    }

    #[test]
    fn path_has_cc_zero() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(average(&g), 0.0);
        assert_eq!(triangle_count(&g), 0);
    }

    #[test]
    fn paw_graph_values() {
        // triangle 0-1-2 plus pendant 3 attached to 2.
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        assert!((local(&g, 0) - 1.0).abs() < 1e-12);
        assert!((local(&g, 2) - (1.0 / 3.0)).abs() < 1e-12);
        assert_eq!(local(&g, 3), 0.0);
        let avg = (1.0 + 1.0 + 1.0 / 3.0 + 0.0) / 4.0;
        assert!((average(&g) - avg).abs() < 1e-12);
    }

    #[test]
    fn complete_graph_counts() {
        let n = 6u32;
        let mut edges = Vec::new();
        for a in 0..n {
            for b in a + 1..n {
                edges.push((a, b));
            }
        }
        let g = Graph::from_edges(n as usize, &edges);
        assert_eq!(average(&g), 1.0);
        // C(6,3) = 20 triangles
        assert_eq!(triangle_count(&g), 20);
    }

    #[test]
    fn transitivity_of_star_is_zero() {
        let g = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        assert_eq!(transitivity(&g), 0.0);
    }

    #[test]
    fn approximate_average_converges() {
        let g = crate::graph::gen::powerlaw_cluster(2000, 4, 0.7, 3);
        let exact = average(&g);
        let (approx, samples) = approximate_average(&g, 0.01, 7);
        assert!(
            (approx - exact).abs() < 0.05,
            "approx {approx:.3} vs exact {exact:.3} ({samples} samples)"
        );
        assert!(samples < g.n(), "early stopping should kick in");
    }

    #[test]
    fn approximate_average_exact_when_uniform() {
        // all-equal local CCs → variance 0 → stops at min_samples
        let g = crate::graph::gen::complete(40);
        let (approx, samples) = approximate_average(&g, 0.01, 1);
        assert_eq!(approx, 1.0);
        assert!(samples <= 40);
    }

    #[test]
    fn conjecture_band_logic() {
        assert!(conjecture_predicts_trivial(0.01, 0.05, 0.9));
        assert!(conjecture_predicts_trivial(0.95, 0.05, 0.9));
        assert!(!conjecture_predicts_trivial(0.5, 0.05, 0.9));
    }
}
