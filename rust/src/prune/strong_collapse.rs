//! Strong Collapse baseline (paper Remark 13 / Table 3): the
//! Boissonnat–Pritam method reduces *each flag complex in the filtration
//! sequence* by collapsing dominated vertices, whereas PrunIT prunes the
//! graph once, before filtration. This module implements both sides of
//! the paper's comparison.

use crate::complex::clique::count_cliques;
use crate::complex::Filtration;
use crate::graph::Graph;
use crate::util::Timer;

use super::prunit::{collapse_with, prunit};

/// Collapse a single graph's flag complex by removing *any* dominated
/// vertex (no filtration condition — within one fixed complex every
/// dominated vertex is collapsible) until none remain. This is the
/// per-step primitive of Strong Collapse.
pub fn strong_collapse_core(g: &Graph) -> (Graph, Vec<u32>, usize) {
    let out = collapse_with(g, |_, _| true);
    let (h, ids) = g.induced(&out.alive);
    (h, ids, out.removed)
}

/// Stats from a filtration sweep (the Table 3 measurement).
#[derive(Clone, Debug, Default)]
pub struct StrongCollapseStats {
    /// seconds spent finding/removing dominated vertices
    pub collapse_secs: f64,
    /// total simplices (cliques up to `max_clique`) summed over all steps
    pub simplex_count: usize,
    /// number of filtration steps processed
    pub steps: usize,
    /// vertices removed summed over steps
    pub removed: usize,
}

/// Threshold sequence: min key → max key in `step` increments (paper's
/// Table 3 "threshold step sizes" δ ∈ {4, 12} on degree values).
pub fn thresholds(f: &Filtration, step: f64) -> Vec<f64> {
    assert!(step > 0.0);
    let keys: Vec<f64> = (0..f.len() as u32).map(|v| f.key(v)).collect();
    let lo = keys.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = keys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !lo.is_finite() {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut a = lo;
    while a < hi {
        out.push(a);
        a += step;
    }
    out.push(hi);
    out
}

/// Strong Collapse sweep: for every threshold, build the sublevel
/// subgraph, collapse its flag complex, and count simplices.
///
/// Simplex accounting (paper Table 3 semantics): Strong Collapse operates
/// *inside* the filtration sequence — each flag complex `Ĝ_i` must be
/// materialised before it can be collapsed, so the pipeline's simplex
/// count is that of the **pre-collapse** complexes. PrunIT, by contrast,
/// shrinks the graph before any complex is built (see [`prunit_sweep`]).
pub fn strong_collapse_sweep(
    g: &Graph,
    f: &Filtration,
    step: f64,
    max_clique: usize,
) -> StrongCollapseStats {
    let mut stats = StrongCollapseStats::default();
    for alpha in thresholds(f, step) {
        let keep: Vec<bool> = (0..g.n() as u32).map(|v| f.key(v) <= alpha).collect();
        let (gi, _) = g.induced(&keep);
        stats.simplex_count += count_cliques(&gi, max_clique).iter().sum::<usize>();
        let ((_hi, _, removed), secs) = Timer::time(|| strong_collapse_core(&gi));
        stats.collapse_secs += secs;
        stats.removed += removed;
        stats.steps += 1;
    }
    stats
}

/// PrunIT sweep for the same measurement: prune the *graph* once (timed),
/// then count simplices of the pruned graph's sublevel subgraphs.
pub fn prunit_sweep(
    g: &Graph,
    f: &Filtration,
    step: f64,
    max_clique: usize,
) -> StrongCollapseStats {
    let mut stats = StrongCollapseStats::default();
    let (r, secs) =
        Timer::time(|| prunit(g, f).expect("prunit_sweep: filtration must match graph"));
    stats.collapse_secs = secs;
    stats.removed = r.removed;
    for alpha in thresholds(f, step) {
        let keep: Vec<bool> = (0..r.graph.n() as u32)
            .map(|v| r.filtration.key(v) <= alpha)
            .collect();
        let (gi, _) = r.graph.induced(&keep);
        stats.simplex_count += count_cliques(&gi, max_clique).iter().sum::<usize>();
        stats.steps += 1;
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::homology::betti_numbers;

    #[test]
    fn collapse_preserves_homotopy_type() {
        // Lemma 5: collapsed complex is homotopy equivalent → same Betti.
        let mut rng = crate::util::Rng::new(21);
        for _ in 0..10 {
            let n = rng.range(4, 20);
            let g = gen::erdos_renyi(n, 0.3, rng.next_u64());
            let (h, _, _) = strong_collapse_core(&g);
            assert_eq!(betti_numbers(&g, 2), betti_numbers(&h, 2), "n={n}");
        }
    }

    #[test]
    fn complete_collapses_to_a_point() {
        let (h, _, removed) = strong_collapse_core(&gen::complete(7));
        assert_eq!(h.n(), 1);
        assert_eq!(removed, 6);
    }

    #[test]
    fn cycle_cannot_collapse() {
        let (h, _, removed) = strong_collapse_core(&gen::cycle(9));
        assert_eq!(h.n(), 9);
        assert_eq!(removed, 0);
    }

    #[test]
    fn thresholds_cover_range() {
        let f = Filtration::sublevel(vec![1.0, 3.0, 9.0]);
        let t = thresholds(&f, 4.0);
        assert_eq!(t, vec![1.0, 5.0, 9.0]);
    }

    #[test]
    fn sweeps_count_fewer_simplices_than_raw() {
        let g = gen::powerlaw_cluster(60, 3, 0.6, 2);
        let f = Filtration::degree_superlevel(&g);
        let sc = strong_collapse_sweep(&g, &f, 2.0, 3);
        // raw simplex count per sweep for comparison
        let mut raw = 0usize;
        for alpha in thresholds(&f, 2.0) {
            let keep: Vec<bool> = (0..g.n() as u32).map(|v| f.key(v) <= alpha).collect();
            let (gi, _) = g.induced(&keep);
            raw += count_cliques(&gi, 3).iter().sum::<usize>();
        }
        assert!(sc.simplex_count <= raw);
        assert!(sc.steps > 0);
        let pi = prunit_sweep(&g, &f, 2.0, 3);
        assert!(pi.simplex_count <= raw);
        assert_eq!(pi.steps, sc.steps);
    }

    #[test]
    fn prunit_sweep_faster_collapse_work() {
        // PrunIT does its domination work once; Strong Collapse per step.
        // On any graph with enough steps the removed-counts differ in
        // structure: SC's `removed` sums per-step removals.
        let g = gen::barabasi_albert(120, 2, 4);
        let f = Filtration::degree_superlevel(&g);
        let sc = strong_collapse_sweep(&g, &f, 1.0, 3);
        let pi = prunit_sweep(&g, &f, 1.0, 3);
        assert!(pi.removed <= g.n());
        assert!(sc.removed >= pi.removed, "SC re-removes across steps");
    }
}
