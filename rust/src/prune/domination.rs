//! Domination queries (paper Definition 4 + Remark 9).
//!
//! `u` is dominated by `v` iff `N[u] ⊆ N[v]` (closed neighbourhoods) —
//! which forces `u ~ v`. The sparse path walks sorted adjacency lists;
//! the dense reference mirrors the XLA/Pallas kernel's matrix semantics
//! and is the cross-check for `runtime::dense_prune`.

use crate::complex::Filtration;
use crate::graph::Graph;

/// Original-CSR degree above which the planner's domination checks switch
/// from the sorted-merge walk to the [`HubBitset`] membership path. A merge
/// pays `O(deg(u) + deg(v))` per check — quadratic in the hub degree when a
/// hub's many low-degree neighbours each probe it — while the bitset pays
/// `O(deg(v)/64)` once per hub and `O(deg(u))` per check thereafter.
pub const HUB_DEGREE: usize = 64;

/// Reusable one-vertex neighbourhood bitset (`n` bits in u64 blocks) for
/// domination checks against hubs. Loading vertex `v` clears the previous
/// owner's bits neighbour-by-neighbour (O(deg) — never a full O(n/64)
/// rescan), so repeated probes against the same hub are near-free.
///
/// The bits always encode the ORIGINAL adjacency of the owner; callers
/// that operate on a tombstoned residue (the reduction planner) must skip
/// dead vertices themselves before testing membership.
#[derive(Clone, Debug)]
pub struct HubBitset {
    bits: Vec<u64>,
    owner: u32,
}

impl Default for HubBitset {
    fn default() -> HubBitset {
        HubBitset::new()
    }
}

impl HubBitset {
    pub fn new() -> HubBitset {
        HubBitset {
            bits: Vec::new(),
            owner: u32::MAX,
        }
    }

    /// Forget the cached owner and zero every block. Required when the
    /// workspace is re-targeted at a different graph: the stale owner id
    /// is meaningless there and must not be used to clear bits.
    pub fn invalidate(&mut self) {
        self.bits.iter_mut().for_each(|b| *b = 0);
        self.owner = u32::MAX;
    }

    /// Make the bitset hold `N(v)` of `g`, reusing the allocation.
    pub fn load(&mut self, g: &Graph, v: u32) {
        let words = g.n().div_ceil(64);
        if self.bits.len() != words {
            self.bits.clear();
            self.bits.resize(words, 0);
            self.owner = u32::MAX;
        }
        if self.owner == v {
            return;
        }
        if self.owner != u32::MAX {
            for &w in g.neighbors(self.owner) {
                self.bits[w as usize / 64] &= !(1u64 << (w % 64));
            }
        }
        for &w in g.neighbors(v) {
            self.bits[w as usize / 64] |= 1u64 << (w % 64);
        }
        self.owner = v;
    }

    /// Is `x` a neighbour of the loaded owner?
    #[inline]
    pub fn contains(&self, x: u32) -> bool {
        self.bits[x as usize / 64] & (1u64 << (x % 64)) != 0
    }
}

/// Does alive `v` dominate alive `u` in the residue selected by `alive`,
/// i.e. is `N[u] ∩ alive ⊆ N[v] ∩ alive`? The caller guarantees `u ~ v`
/// in `g`, that both are alive, and (as a cheap pre-filter) that the
/// residual degree of `u` does not exceed `v`'s.
///
/// This is the hybrid check shared by the sequential planner pass and the
/// parallel frontier workers: low-degree dominator candidates walk both
/// sorted adjacency lists; hubs (original degree ≥ [`HUB_DEGREE`]) load
/// their neighbourhood into the caller's [`HubBitset`] once and answer
/// each probe in `O(deg(u))`. Read-only on `g`/`alive`, so any number of
/// workers can run it concurrently against the same residue, each with
/// its own bitset.
pub fn residue_dominates(g: &Graph, alive: &[bool], u: u32, v: u32, hub: &mut HubBitset) -> bool {
    if g.degree(v) >= HUB_DEGREE {
        hub.load(g, v);
        for &x in g.neighbors(u) {
            if x == v || !alive[x as usize] {
                continue;
            }
            if !hub.contains(x) {
                return false;
            }
        }
        true
    } else {
        let nv = g.neighbors(v);
        let mut j = 0usize;
        for &x in g.neighbors(u) {
            if x == v || !alive[x as usize] {
                continue;
            }
            while j < nv.len() && nv[j] < x {
                j += 1;
            }
            if j == nv.len() || nv[j] != x {
                return false;
            }
            j += 1;
        }
        true
    }
}

/// Does `v` dominate `u` in `g`? (Checked on immutable CSR.)
pub fn dominates(g: &Graph, u: u32, v: u32) -> bool {
    if u == v || !g.has_edge(u, v) {
        return false;
    }
    // N[u] ⊆ N[v] ⟺ every x ∈ N(u), x == v or x ∈ N(v) (u ∈ N(v) holds by
    // adjacency).
    let nv = g.neighbors(v);
    let mut j = 0usize;
    for &x in g.neighbors(u) {
        if x == v {
            continue;
        }
        while j < nv.len() && nv[j] < x {
            j += 1;
        }
        if j == nv.len() || nv[j] != x {
            return false;
        }
    }
    true
}

/// Find an admissible dominator of `u` under filtration `f` (Thm 7 /
/// Rmk 8 condition), or None. Deterministic: smallest qualifying v.
pub fn find_dominator(g: &Graph, f: &Filtration, u: u32) -> Option<u32> {
    g.neighbors(u)
        .iter()
        .copied()
        .find(|&v| g.degree(v) >= g.degree(u) && f.admissible_removal(u, v) && dominates(g, u, v))
}

/// Dense dominated-pair mask with filtration admissibility — the exact
/// semantics of the AOT Pallas kernel (`python/compile/kernels/ref.py`),
/// used to validate the XLA execution path bit-for-bit.
pub fn dominated_pairs_dense(g: &Graph, f: &Filtration) -> Vec<Vec<bool>> {
    let n = g.n();
    let mut mask = vec![vec![false; n]; n];
    for u in 0..n as u32 {
        for &v in g.neighbors(u) {
            if f.admissible_removal(u, v) && dominates(g, u, v) {
                mask[u as usize][v as usize] = true;
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn figure3_graph() -> Graph {
        // Paper Fig 3 (0-indexed): vertices 0,1 and 3 all adjacent to 2;
        // plus edge 0-1 so N[0] = {0,1,2} ⊆ N[2].
        Graph::from_edges(4, &[(0, 2), (1, 2), (0, 1), (2, 3)])
    }

    #[test]
    fn figure3_dominations() {
        let g = figure3_graph();
        assert!(dominates(&g, 0, 2), "2 dominates 0");
        assert!(dominates(&g, 1, 2), "2 dominates 1");
        assert!(dominates(&g, 3, 2), "2 dominates 3");
        assert!(!dominates(&g, 2, 0));
        // 0 and 1 are twins: they dominate each other.
        assert!(dominates(&g, 0, 1) && dominates(&g, 1, 0));
    }

    #[test]
    fn domination_requires_adjacency() {
        let g = gen::path(3); // 0-1-2
        assert!(dominates(&g, 0, 1));
        assert!(!dominates(&g, 0, 2), "non-adjacent cannot dominate");
    }

    #[test]
    fn self_domination_excluded() {
        let g = gen::complete(3);
        assert!(!dominates(&g, 1, 1));
    }

    #[test]
    fn complete_graph_all_mutually_dominate() {
        let g = gen::complete(4);
        for u in 0..4u32 {
            for v in 0..4u32 {
                assert_eq!(dominates(&g, u, v), u != v);
            }
        }
    }

    #[test]
    fn cycle_has_no_dominated_vertices() {
        let g = gen::cycle(5);
        let f = Filtration::degree(&g);
        for u in 0..5u32 {
            assert_eq!(find_dominator(&g, &f, u), None);
        }
    }

    #[test]
    fn filtration_vetoes_dominator() {
        let g = gen::path(3); // 1 dominates 0 and 2
        // sublevel needs f(u) >= f(v)
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0]);
        assert_eq!(find_dominator(&g, &f, 0), None, "f(0) < f(1)");
        assert_eq!(find_dominator(&g, &f, 2), Some(1));
    }

    #[test]
    fn degree_superlevel_always_admits(){
        let g = figure3_graph();
        let f = Filtration::degree_superlevel(&g);
        assert_eq!(find_dominator(&g, &f, 3), Some(2));
        assert!(find_dominator(&g, &f, 0).is_some());
    }

    #[test]
    fn hub_bitset_tracks_neighbourhoods_across_loads() {
        let g = gen::erdos_renyi(130, 0.1, 3);
        let mut bits = HubBitset::new();
        for v in [0u32, 7, 7, 99, 0] {
            bits.load(&g, v);
            for x in 0..g.n() as u32 {
                assert_eq!(bits.contains(x), g.has_edge(v, x), "owner {v} bit {x}");
            }
        }
        bits.invalidate();
        // retarget to a different graph with the same word count
        let h = gen::star(70);
        bits.load(&h, 0);
        for x in 0..h.n() as u32 {
            assert_eq!(bits.contains(x), h.has_edge(0, x));
        }
    }

    #[test]
    fn residue_domination_matches_induced_subgraph() {
        // killing vertices and re-checking on the mask must agree with
        // materializing the induced residue and running the plain check
        let g = gen::erdos_renyi(40, 0.25, 11);
        let mut rng = crate::util::Rng::new(11);
        let alive: Vec<bool> = (0..g.n()).map(|_| rng.chance(0.7)).collect();
        let (h, ids) = g.induced(&alive);
        let mut hub = HubBitset::new();
        for (hu, &gu) in ids.iter().enumerate() {
            for (hv, &gv) in ids.iter().enumerate() {
                if hu == hv || !g.has_edge(gu, gv) {
                    continue;
                }
                assert_eq!(
                    residue_dominates(&g, &alive, gu, gv, &mut hub),
                    dominates(&h, hu as u32, hv as u32),
                    "residue pair ({gu},{gv})"
                );
            }
        }
    }

    #[test]
    fn residue_domination_hub_path_matches_merge_path() {
        // a 150-leaf star forces the bitset branch for the hub dominator
        let mut edges: Vec<(u32, u32)> = (1..=150).map(|v| (0u32, v)).collect();
        edges.push((1, 2));
        let g = Graph::from_edges(151, &edges);
        assert!(g.degree(0) >= HUB_DEGREE);
        let mut alive = vec![true; g.n()];
        alive[3] = false;
        let mut hub = HubBitset::new();
        // every leaf is dominated by the hub in the residue
        assert!(residue_dominates(&g, &alive, 5, 0, &mut hub));
        assert!(residue_dominates(&g, &alive, 1, 0, &mut hub));
        // the hub is not dominated by a leaf
        assert!(!residue_dominates(&g, &alive, 0, 1, &mut hub));
    }

    #[test]
    fn dense_mask_matches_pointwise() {
        let g = gen::erdos_renyi(30, 0.25, 5);
        let f = Filtration::degree(&g);
        let mask = dominated_pairs_dense(&g, &f);
        for u in 0..30u32 {
            for v in 0..30u32 {
                let want = u != v
                    && g.has_edge(u, v)
                    && f.admissible_removal(u, v)
                    && dominates(&g, u, v);
                assert_eq!(mask[u as usize][v as usize], want);
            }
        }
    }
}
