//! Domination queries (paper Definition 4 + Remark 9).
//!
//! `u` is dominated by `v` iff `N[u] ⊆ N[v]` (closed neighbourhoods) —
//! which forces `u ~ v`. This module holds the plain immutable-graph
//! queries; the residue-aware checks (tombstone masks, hub bitsets, the
//! u64-block kernel) live in [`crate::prune::kernel`]. The dense
//! reference here mirrors the XLA/Pallas kernel's matrix semantics and
//! is the cross-check for `runtime::dense_prune`.

use crate::complex::Filtration;
use crate::graph::Graph;

/// Does `v` dominate `u` in `g`? (Checked on immutable CSR.)
pub fn dominates(g: &Graph, u: u32, v: u32) -> bool {
    if u == v || !g.has_edge(u, v) {
        return false;
    }
    // N[u] ⊆ N[v] ⟺ every x ∈ N(u), x == v or x ∈ N(v) (u ∈ N(v) holds by
    // adjacency).
    let nv = g.neighbors(v);
    let mut j = 0usize;
    for &x in g.neighbors(u) {
        if x == v {
            continue;
        }
        while j < nv.len() && nv[j] < x {
            j += 1;
        }
        if j == nv.len() || nv[j] != x {
            return false;
        }
    }
    true
}

/// Find an admissible dominator of `u` under filtration `f` (Thm 7 /
/// Rmk 8 condition), or None. Deterministic: smallest qualifying v.
pub fn find_dominator(g: &Graph, f: &Filtration, u: u32) -> Option<u32> {
    g.neighbors(u)
        .iter()
        .copied()
        .find(|&v| g.degree(v) >= g.degree(u) && f.admissible_removal(u, v) && dominates(g, u, v))
}

/// Dense dominated-pair mask with filtration admissibility — the exact
/// semantics of the AOT Pallas kernel (`python/compile/kernels/ref.py`),
/// used to validate the XLA execution path bit-for-bit.
pub fn dominated_pairs_dense(g: &Graph, f: &Filtration) -> Vec<Vec<bool>> {
    let n = g.n();
    let mut mask = vec![vec![false; n]; n];
    for u in 0..n as u32 {
        for &v in g.neighbors(u) {
            if f.admissible_removal(u, v) && dominates(g, u, v) {
                mask[u as usize][v as usize] = true;
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn figure3_graph() -> Graph {
        // Paper Fig 3 (0-indexed): vertices 0,1 and 3 all adjacent to 2;
        // plus edge 0-1 so N[0] = {0,1,2} ⊆ N[2].
        Graph::from_edges(4, &[(0, 2), (1, 2), (0, 1), (2, 3)])
    }

    #[test]
    fn figure3_dominations() {
        let g = figure3_graph();
        assert!(dominates(&g, 0, 2), "2 dominates 0");
        assert!(dominates(&g, 1, 2), "2 dominates 1");
        assert!(dominates(&g, 3, 2), "2 dominates 3");
        assert!(!dominates(&g, 2, 0));
        // 0 and 1 are twins: they dominate each other.
        assert!(dominates(&g, 0, 1) && dominates(&g, 1, 0));
    }

    #[test]
    fn domination_requires_adjacency() {
        let g = gen::path(3); // 0-1-2
        assert!(dominates(&g, 0, 1));
        assert!(!dominates(&g, 0, 2), "non-adjacent cannot dominate");
    }

    #[test]
    fn self_domination_excluded() {
        let g = gen::complete(3);
        assert!(!dominates(&g, 1, 1));
    }

    #[test]
    fn complete_graph_all_mutually_dominate() {
        let g = gen::complete(4);
        for u in 0..4u32 {
            for v in 0..4u32 {
                assert_eq!(dominates(&g, u, v), u != v);
            }
        }
    }

    #[test]
    fn cycle_has_no_dominated_vertices() {
        let g = gen::cycle(5);
        let f = Filtration::degree(&g);
        for u in 0..5u32 {
            assert_eq!(find_dominator(&g, &f, u), None);
        }
    }

    #[test]
    fn filtration_vetoes_dominator() {
        let g = gen::path(3); // 1 dominates 0 and 2
        // sublevel needs f(u) >= f(v)
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0]);
        assert_eq!(find_dominator(&g, &f, 0), None, "f(0) < f(1)");
        assert_eq!(find_dominator(&g, &f, 2), Some(1));
    }

    #[test]
    fn degree_superlevel_always_admits() {
        let g = figure3_graph();
        let f = Filtration::degree_superlevel(&g);
        assert_eq!(find_dominator(&g, &f, 3), Some(2));
        assert!(find_dominator(&g, &f, 0).is_some());
    }

    #[test]
    fn dense_mask_matches_pointwise() {
        let g = gen::erdos_renyi(30, 0.25, 5);
        let f = Filtration::degree(&g);
        let mask = dominated_pairs_dense(&g, &f);
        for u in 0..30u32 {
            for v in 0..30u32 {
                let want = u != v
                    && g.has_edge(u, v)
                    && f.admissible_removal(u, v)
                    && dominates(&g, u, v);
                assert_eq!(mask[u as usize][v as usize], want);
            }
        }
    }
}
