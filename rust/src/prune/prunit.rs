//! PrunIT (paper Algorithm 2 + Theorem 7): iteratively remove dominated
//! vertices whose filtration value admits removal, to a fixed point.
//!
//! Soundness of sequential removal: domination is preserved under removal
//! of *other* vertices (`N[u]\{w} ⊆ N[v]\{w}`), and the admissibility
//! condition only references `f`, which never changes — so each removal
//! is individually justified by Theorem 7 in the current graph, and the
//! final graph has all the original persistence diagrams.
//!
//! The worklist keeps the pass near-linear in practice: removing `u` can
//! only create new dominations for pairs `(x, y)` whose violation witness
//! was `u`, i.e. `x ∈ N(u)` — only former neighbours of `u` are re-queued.

use crate::complex::Filtration;
use crate::error::Result;
use crate::graph::Graph;

/// Result of a pruning pass.
#[derive(Clone, Debug)]
pub struct PruneResult {
    /// The pruned graph (vertices compacted).
    pub graph: Graph,
    /// `new id -> old id` of surviving vertices (ascending).
    pub kept_old_ids: Vec<u32>,
    /// The filtration restricted to survivors (original values; Rmk 1).
    pub filtration: Filtration,
    /// Number of vertices removed.
    pub removed: usize,
    /// Worklist pops — a proxy for work done (perf metric).
    pub checks: usize,
}

/// Mutable adjacency view used during pruning.
struct View {
    adj: Vec<Vec<u32>>,
    alive: Vec<bool>,
}

impl View {
    fn new(g: &Graph) -> View {
        View {
            adj: (0..g.n() as u32).map(|v| g.neighbors(v).to_vec()).collect(),
            alive: vec![true; g.n()],
        }
    }

    /// Does alive neighbour `v` dominate alive `u` in the current graph?
    fn dominates(&self, u: u32, v: u32) -> bool {
        let nu = &self.adj[u as usize];
        let nv = &self.adj[v as usize];
        if nu.len() > nv.len() {
            return false;
        }
        let mut j = 0usize;
        for &x in nu {
            if x == v {
                continue;
            }
            while j < nv.len() && nv[j] < x {
                j += 1;
            }
            if j == nv.len() || nv[j] != x {
                return false;
            }
            j += 1;
        }
        true
    }

    /// Remove vertex u, updating neighbour lists exactly. The removed
    /// vertex's list is left in place so callers can re-queue its former
    /// neighbours.
    fn remove(&mut self, u: u32) {
        self.alive[u as usize] = false;
        let nbrs = std::mem::take(&mut self.adj[u as usize]);
        for &w in &nbrs {
            let list = &mut self.adj[w as usize];
            if let Ok(pos) = list.binary_search(&u) {
                list.remove(pos);
            }
        }
        self.adj[u as usize] = nbrs;
    }
}

/// Core worklist collapse: remove vertices `u` that have a current-graph
/// dominator `v` with `admissible(u, v)`, until a fixed point.
/// Returns (alive mask, removed count, worklist pops).
pub(crate) fn collapse_with<F: Fn(u32, u32) -> bool>(
    g: &Graph,
    admissible: F,
) -> (Vec<bool>, usize, usize) {
    let n = g.n();
    let mut view = View::new(g);
    let mut in_queue = vec![true; n];
    let mut queue: std::collections::VecDeque<u32> = (0..n as u32).collect();
    let mut removed = 0usize;
    let mut checks = 0usize;

    while let Some(u) = queue.pop_front() {
        in_queue[u as usize] = false;
        if !view.alive[u as usize] {
            continue;
        }
        checks += 1;
        let dominator = view.adj[u as usize]
            .iter()
            .copied()
            .find(|&v| admissible(u, v) && view.dominates(u, v));
        if dominator.is_some() {
            view.remove(u);
            removed += 1;
            for &w in &view.adj[u as usize] {
                if view.alive[w as usize] && !in_queue[w as usize] {
                    in_queue[w as usize] = true;
                    queue.push_back(w);
                }
            }
        }
    }
    (view.alive, removed, checks)
}

/// Run PrunIT to a fixed point. Deterministic: the worklist is processed
/// in FIFO order seeded with ascending vertex ids.
///
/// Errors with [`crate::error::Error::FiltrationMismatch`] when `f` does
/// not match `g`'s order (the pre-planner `expect` panic is gone).
pub fn prunit(g: &Graph, f: &Filtration) -> Result<PruneResult> {
    f.check(g)?;
    let (alive, removed, checks) = collapse_with(g, |u, v| f.admissible_removal(u, v));
    let (graph, kept_old_ids) = g.induced(&alive);
    let filtration = f.restrict(&kept_old_ids);
    Ok(PruneResult {
        graph,
        kept_old_ids,
        filtration,
        removed,
        checks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::homology::persistence_diagrams;

    #[test]
    fn star_prunes_to_near_nothing() {
        // superlevel + degree: all leaves admissible (Rmk 8).
        let g = gen::star(8);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert!(r.graph.n() <= 2, "star should collapse, got n={}", r.graph.n());
        assert_eq!(r.removed, 8 - r.graph.n());
    }

    #[test]
    fn complete_graph_collapses_to_point() {
        let g = gen::complete(6);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(r.graph.n(), 1);
    }

    #[test]
    fn cycle_is_irreducible() {
        let g = gen::cycle(6);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(r.graph.n(), 6);
        assert_eq!(r.removed, 0);
    }

    #[test]
    fn sublevel_condition_blocks_removals() {
        // path 0-1-2 with f = [0,1,2] sublevel: vertex 0 is dominated by 1
        // but f(0) < f(1) vetoes it. Vertex 2 is removable (f(2) ≥ f(1));
        // afterwards 1 becomes dominated by 0 with f(1) ≥ f(0) → removed.
        let g = gen::path(3);
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0]);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(r.removed, 2);
        assert_eq!(r.kept_old_ids, vec![0]);
    }

    #[test]
    fn sublevel_veto_is_strict_when_no_cascade() {
        // star with hub f below the leaves: leaves removable; plus a
        // configuration where the veto genuinely blocks: two leaves with
        // f strictly below the hub survive.
        let g = gen::star(4); // hub 0, leaves 1..3
        let f = Filtration::sublevel(vec![5.0, 1.0, 1.0, 9.0]);
        let r = prunit(&g, &f).unwrap();
        // leaf 3 (f=9 ≥ 5) is removable; leaves 1,2 (f=1 < 5) are vetoed;
        // hub dominated by nobody (leaves have smaller nbhds).
        assert!(!r.kept_old_ids.contains(&3));
        assert!(r.kept_old_ids.contains(&1) && r.kept_old_ids.contains(&2));
    }

    #[test]
    fn restricted_filtration_keeps_original_values() {
        let g = gen::star(5);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        for (new, &old) in r.kept_old_ids.iter().enumerate() {
            assert_eq!(r.filtration.value(new as u32), f.value(old));
        }
    }

    #[test]
    fn theorem7_pd_preserved_small_random() {
        // The headline property (exhaustive version lives in rust/tests/).
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..10 {
            let n = rng.range(4, 18);
            let g = gen::erdos_renyi(n, 0.35, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let r = prunit(&g, &f).unwrap();
            let before = persistence_diagrams(&g, &f, 1);
            let after = persistence_diagrams(&r.graph, &r.filtration, 1);
            for k in 0..=1 {
                assert!(
                    before[k].same_as(&after[k], 1e-9),
                    "PD_{k}: {} vs {} (n={n})",
                    before[k],
                    after[k]
                );
            }
        }
    }

    #[test]
    fn fixed_point_no_admissible_dominated_left() {
        let g = gen::barabasi_albert(80, 2, 9);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        for u in 0..r.graph.n() as u32 {
            assert!(
                super::super::domination::find_dominator(&r.graph, &r.filtration, u).is_none(),
                "vertex {u} still prunable"
            );
        }
    }

    #[test]
    fn twins_collapse_preserves_homology() {
        // K4 minus one edge: 2 and 3 are twins adjacent to {0, 1}.
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (1, 2), (0, 3), (1, 3)]);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert!(r.graph.n() >= 1);
        let before = persistence_diagrams(&g, &f, 1);
        let after = persistence_diagrams(&r.graph, &r.filtration, 1);
        assert!(before[0].same_as(&after[0], 1e-9));
        assert!(before[1].same_as(&after[1], 1e-9));
    }

    #[test]
    fn checks_bounded_reasonably() {
        let g = gen::barabasi_albert(300, 2, 3);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        // worklist discipline: far fewer pops than n * rounds of full sweeps
        assert!(r.checks < 20 * g.n(), "checks={} n={}", r.checks, g.n());
        assert!(r.removed > 0, "BA graphs have dominated leaves");
    }
}
