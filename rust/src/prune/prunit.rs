//! PrunIT (paper Algorithm 2 + Theorem 7): iteratively remove dominated
//! vertices whose filtration value admits removal, to a fixed point.
//!
//! The schedule is a **round-synchronous frontier sweep** (the strong-
//! collapse formulation of Boissonnat–Pritam): each round checks every
//! frontier vertex against the *round-start* residue, collects dominated
//! candidates with their witness dominator, then resolves in ascending
//! vertex order — a candidate is removed iff its witness is still alive
//! ("lowest index dominated-by-alive wins"); candidates whose witness
//! died this round are deferred to the next frontier for a re-check. The
//! next frontier is the deferred set plus the alive former neighbours of
//! everything removed.
//!
//! Soundness: domination of `u` by `v` survives the removal of any third
//! vertex (`N[u]\{w} ⊆ N[v]\{w}`), and admissibility only references
//! `f`, which never changes — so every removal in the resolution pass is
//! individually justified by Theorem 7 in the graph state at its own
//! moment, making the whole round a valid removal chain. Termination: a
//! deferral requires a same-round witness death, so a round that removes
//! nothing had no candidates at all and the frontier empties.
//!
//! The round-start snapshot makes the candidate checks of one round
//! mutually independent — this is exactly what lets the planner
//! (`reduce::planner`) partition the frontier across threads while
//! staying bit-identical to this sequential reference.
//!
//! This module deliberately stays on the naive sorted-merge residue
//! check: it is the independent reference the fast kernels in
//! [`crate::prune::kernel`] (merge walk, chunked u64 bitset) are
//! differentially tested against.

use crate::complex::Filtration;
use crate::error::Result;
use crate::graph::Graph;
use crate::util::CancelToken;

/// Result of a pruning pass.
#[derive(Clone, Debug)]
pub struct PruneResult {
    /// The pruned graph (vertices compacted).
    pub graph: Graph,
    /// `new id -> old id` of surviving vertices (ascending).
    pub kept_old_ids: Vec<u32>,
    /// The filtration restricted to survivors (original values; Rmk 1).
    pub filtration: Filtration,
    /// Number of vertices removed.
    pub removed: usize,
    /// Frontier vertices checked — a proxy for work done (perf metric).
    pub checks: usize,
    /// Frontier sweep rounds until the fixed point.
    pub rounds: usize,
}

/// Everything `collapse_with` reports about one collapse to fixed point.
pub(crate) struct CollapseOutcome {
    /// Survivor mask over original vertex ids.
    pub alive: Vec<bool>,
    /// Vertices removed.
    pub removed: usize,
    /// Frontier vertices checked across all rounds.
    pub checks: usize,
    /// Frontier rounds executed.
    pub rounds: usize,
}

/// Mutable adjacency view used during pruning.
struct View {
    adj: Vec<Vec<u32>>,
    alive: Vec<bool>,
}

impl View {
    fn new(g: &Graph) -> View {
        View {
            adj: (0..g.n() as u32).map(|v| g.neighbors(v).to_vec()).collect(),
            alive: vec![true; g.n()],
        }
    }

    /// Does alive neighbour `v` dominate alive `u` in the current graph?
    fn dominates(&self, u: u32, v: u32) -> bool {
        let nu = &self.adj[u as usize];
        let nv = &self.adj[v as usize];
        if nu.len() > nv.len() {
            return false;
        }
        let mut j = 0usize;
        for &x in nu {
            if x == v {
                continue;
            }
            while j < nv.len() && nv[j] < x {
                j += 1;
            }
            if j == nv.len() || nv[j] != x {
                return false;
            }
            j += 1;
        }
        true
    }

    /// Remove vertex u, updating neighbour lists exactly. The removed
    /// vertex's list is left in place so callers can walk its former
    /// neighbours.
    fn remove(&mut self, u: u32) {
        self.alive[u as usize] = false;
        let nbrs = std::mem::take(&mut self.adj[u as usize]);
        for &w in &nbrs {
            let list = &mut self.adj[w as usize];
            if let Ok(pos) = list.binary_search(&u) {
                list.remove(pos);
            }
        }
        self.adj[u as usize] = nbrs;
    }
}

/// Core round-synchronous collapse: remove vertices `u` that have a
/// round-start dominator `v` with `admissible(u, v)`, resolving conflicts
/// in ascending vertex order, until a fixed point.
///
/// Deterministic: the initial frontier is all vertices ascending, each
/// round's candidate set is a pure function of the round-start residue,
/// and resolution order is ascending — the exact schedule
/// `reduce::planner::ReductionWorkspace` reproduces (at any thread
/// count) on its tombstone masks.
pub(crate) fn collapse_with<F: Fn(u32, u32) -> bool>(g: &Graph, admissible: F) -> CollapseOutcome {
    collapse_with_cancel(g, admissible, &CancelToken::none())
        .expect("collapse with a none token cannot be cancelled")
}

/// [`collapse_with`] with cooperative cancellation, polled once per
/// frontier round — the same checkpoint cadence as the planner's
/// `prunit_pass`, so reference and planner observe a shared deadline at
/// equivalent points.
pub(crate) fn collapse_with_cancel<F: Fn(u32, u32) -> bool>(
    g: &Graph,
    admissible: F,
    cancel: &CancelToken,
) -> Result<CollapseOutcome> {
    let n = g.n();
    let mut view = View::new(g);
    let mut frontier: Vec<u32> = (0..n as u32).collect();
    let mut next_frontier: Vec<u32> = Vec::new();
    let mut in_next = vec![false; n];
    let mut cands: Vec<(u32, u32)> = Vec::new();
    let mut removed = 0usize;
    let mut checks = 0usize;
    let mut rounds = 0usize;

    while !frontier.is_empty() {
        cancel.check()?;
        rounds += 1;
        // Check phase: every alive frontier vertex against the round-start
        // residue. The witness is the first admissible dominator in
        // ascending (sorted alive-neighbour) order.
        cands.clear();
        for &u in &frontier {
            if !view.alive[u as usize] {
                continue;
            }
            checks += 1;
            let witness = view.adj[u as usize]
                .iter()
                .copied()
                .find(|&v| admissible(u, v) && view.dominates(u, v));
            if let Some(v) = witness {
                cands.push((u, v));
            }
        }
        // Resolution phase, ascending: remove a candidate iff its witness
        // is still alive; defer it otherwise. Neighbour lists are edited
        // as removals land, so the next round's checks see the new
        // residue.
        next_frontier.clear();
        for &(u, w) in &cands {
            if view.alive[w as usize] {
                view.remove(u);
                removed += 1;
                for &x in &view.adj[u as usize] {
                    if view.alive[x as usize] && !in_next[x as usize] {
                        in_next[x as usize] = true;
                        next_frontier.push(x);
                    }
                }
            } else if !in_next[u as usize] {
                in_next[u as usize] = true;
                next_frontier.push(u);
            }
        }
        next_frontier.sort_unstable();
        for &x in &next_frontier {
            in_next[x as usize] = false;
        }
        std::mem::swap(&mut frontier, &mut next_frontier);
    }
    Ok(CollapseOutcome {
        alive: view.alive,
        removed,
        checks,
        rounds,
    })
}

/// Run PrunIT to a fixed point on the round-synchronous schedule.
///
/// Errors with [`crate::error::Error::FiltrationMismatch`] when `f` does
/// not match `g`'s order (the pre-planner `expect` panic is gone).
pub fn prunit(g: &Graph, f: &Filtration) -> Result<PruneResult> {
    prunit_cancellable(g, f, &CancelToken::none())
}

/// [`prunit`] with cooperative cancellation polled at frontier-round
/// boundaries. Additionally errors with `Error::DeadlineExceeded` /
/// `Error::Cancelled` once the token expires.
pub fn prunit_cancellable(g: &Graph, f: &Filtration, cancel: &CancelToken) -> Result<PruneResult> {
    f.check(g)?;
    let out = collapse_with_cancel(g, |u, v| f.admissible_removal(u, v), cancel)?;
    let (graph, kept_old_ids) = g.induced(&out.alive);
    let filtration = f.restrict(&kept_old_ids);
    Ok(PruneResult {
        graph,
        kept_old_ids,
        filtration,
        removed: out.removed,
        checks: out.checks,
        rounds: out.rounds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::homology::persistence_diagrams;

    #[test]
    fn star_prunes_to_near_nothing() {
        // superlevel + degree: all leaves admissible (Rmk 8).
        let g = gen::star(8);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert!(r.graph.n() <= 2, "star should collapse, got n={}", r.graph.n());
        assert_eq!(r.removed, 8 - r.graph.n());
    }

    #[test]
    fn complete_graph_collapses_to_point() {
        let g = gen::complete(6);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(r.graph.n(), 1);
        // mutual-domination conflicts resolve one per round: 0 removed in
        // round 1 (witness 1 alive), everyone else defers to its witness 0
        assert_eq!(r.rounds, 6, "K6 defers a twin chain");
    }

    #[test]
    fn cycle_is_irreducible() {
        let g = gen::cycle(6);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(r.graph.n(), 6);
        assert_eq!(r.removed, 0);
        assert_eq!(r.rounds, 1, "one sweep finds no candidates");
    }

    #[test]
    fn sublevel_condition_blocks_removals() {
        // path 0-1-2 with f = [0,1,2] sublevel: vertex 0 is dominated by 1
        // but f(0) < f(1) vetoes it. Vertex 2 is removable (f(2) ≥ f(1));
        // afterwards 1 becomes dominated by 0 with f(1) ≥ f(0) → removed.
        let g = gen::path(3);
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0]);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(r.removed, 2);
        assert_eq!(r.kept_old_ids, vec![0]);
    }

    #[test]
    fn sublevel_veto_is_strict_when_no_cascade() {
        // star with hub f below the leaves: leaves removable; plus a
        // configuration where the veto genuinely blocks: two leaves with
        // f strictly below the hub survive.
        let g = gen::star(4); // hub 0, leaves 1..3
        let f = Filtration::sublevel(vec![5.0, 1.0, 1.0, 9.0]);
        let r = prunit(&g, &f).unwrap();
        // leaf 3 (f=9 ≥ 5) is removable; leaves 1,2 (f=1 < 5) are vetoed;
        // hub dominated by nobody (leaves have smaller nbhds).
        assert!(!r.kept_old_ids.contains(&3));
        assert!(r.kept_old_ids.contains(&1) && r.kept_old_ids.contains(&2));
    }

    #[test]
    fn restricted_filtration_keeps_original_values() {
        let g = gen::star(5);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        for (new, &old) in r.kept_old_ids.iter().enumerate() {
            assert_eq!(r.filtration.value(new as u32), f.value(old));
        }
    }

    #[test]
    fn theorem7_pd_preserved_small_random() {
        // The headline property (exhaustive version lives in rust/tests/).
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..10 {
            let n = rng.range(4, 18);
            let g = gen::erdos_renyi(n, 0.35, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let r = prunit(&g, &f).unwrap();
            let before = persistence_diagrams(&g, &f, 1);
            let after = persistence_diagrams(&r.graph, &r.filtration, 1);
            for k in 0..=1 {
                assert!(
                    before[k].same_as(&after[k], 1e-9),
                    "PD_{k}: {} vs {} (n={n})",
                    before[k],
                    after[k]
                );
            }
        }
    }

    #[test]
    fn fixed_point_no_admissible_dominated_left() {
        let g = gen::barabasi_albert(80, 2, 9);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        for u in 0..r.graph.n() as u32 {
            assert!(
                super::super::domination::find_dominator(&r.graph, &r.filtration, u).is_none(),
                "vertex {u} still prunable"
            );
        }
    }

    #[test]
    fn twins_collapse_preserves_homology() {
        // K4 minus one edge: 2 and 3 are twins adjacent to {0, 1}.
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (1, 2), (0, 3), (1, 3)]);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        assert!(r.graph.n() >= 1);
        let before = persistence_diagrams(&g, &f, 1);
        let after = persistence_diagrams(&r.graph, &r.filtration, 1);
        assert!(before[0].same_as(&after[0], 1e-9));
        assert!(before[1].same_as(&after[1], 1e-9));
    }

    #[test]
    fn adjacent_twin_conflict_keeps_exactly_one() {
        // 0 and 1 are adjacent twins (each dominates the other): the
        // resolution rule removes 0 (lowest index, witness 1 alive) and
        // must then DEFER 1 (its witness 0 died this round) rather than
        // remove both — killing both would delete the K2 component.
        let g = Graph::from_edges(2, &[(0, 1)]);
        let f = Filtration::constant(2);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(r.kept_old_ids, vec![1]);
        assert_eq!(r.removed, 1);
        assert_eq!(r.rounds, 2, "round 1 removes 0, round 2 re-checks 1");
    }

    #[test]
    fn checks_bounded_reasonably() {
        let g = gen::barabasi_albert(300, 2, 3);
        let f = Filtration::degree_superlevel(&g);
        let r = prunit(&g, &f).unwrap();
        // frontier discipline: far fewer checks than n * rounds full sweeps
        assert!(r.checks < 20 * g.n(), "checks={} n={}", r.checks, g.n());
        assert!(r.removed > 0, "BA graphs have dominated leaves");
        assert!(r.rounds >= 1 && r.rounds <= r.removed + 1);
    }
}
