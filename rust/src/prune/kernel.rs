//! The unified residue-domination kernel (ROADMAP item 4): one module
//! answering "does alive `v` dominate alive `u` in the residue selected
//! by `alive`?" for both execution paths — the sparse planner's frontier
//! sweep (`reduce::planner`, sequential or scoped-thread) and the dense
//! XLA path's greedy resolution (`runtime::dense_prune`), which shares
//! the same u64-block row layout via [`blocks_subset`].
//!
//! Two kernels compute the identical predicate:
//!
//! * **merge** — the sorted-merge walk over both adjacency lists, with a
//!   [`HubBitset`] membership fast path for hub dominators (original
//!   degree ≥ [`HUB_DEGREE`]). `O(deg(u) + deg(v))` per check;
//!   unbeatable on sparse fringes.
//! * **bitset** — u64-block loops: the candidate's alive-filtered
//!   neighbourhood and the dominator's neighbourhood live in n-bit block
//!   vectors, and the subset test is a fixed-width chunked AND-NOT
//!   reduction ([`blocks_subset`]) that LLVM auto-vectorizes. `O(n/64)`
//!   words per check regardless of degree; wins on dense residues
//!   (high-degree cores) where the merge walk degenerates.
//!
//! [`choose`] resolves [`DominationKernel::Auto`] **per round** from the
//! measured residue density (average residual degree vs block-loop
//! length) — not from the static per-vertex [`HUB_DEGREE`] cut — so a
//! plan that cores down to a dense nucleus flips to the bitset kernel
//! exactly when merges start to degenerate. Both kernels compute the
//! same predicate, so residues are bit-identical whatever the policy
//! picks: the `#[cfg(test)]` suite below and
//! `rust/tests/domination_kernels.rs` check this differentially
//! (kernel vs kernel vs materialized-subgraph reference), independent of
//! how the block loops actually compile.
//!
//! The sequential reference `prune::prunit` deliberately does NOT share
//! this module: it keeps an independent adjacency-list implementation so
//! the differential suites compare two genuinely different computations.

use crate::error::{Error, Result};
use crate::graph::Graph;

/// Original-CSR degree above which the merge kernel's checks switch from
/// the sorted-merge walk to the [`HubBitset`] membership path. A merge
/// pays `O(deg(u) + deg(v))` per check — quadratic in the hub degree when
/// a hub's many low-degree neighbours each probe it — while the bitset
/// pays `O(deg(v)/64)` once per hub and `O(deg(u))` per check thereafter.
pub const HUB_DEGREE: usize = 64;

/// Fixed block-loop width of the u64 kernels: the AND-NOT reduction runs
/// over `chunks_exact(BLOCK_CHUNK)` with independent accumulators, a
/// shape LLVM reliably turns into vector ops.
const BLOCK_CHUNK: usize = 4;

/// Requested domination-kernel policy (`--domination-kernel`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DominationKernel {
    /// Pick per round from measured residue density (the default).
    #[default]
    Auto,
    /// Always the sorted-merge walk (+ hub membership fast path).
    Merge,
    /// Always the u64-block subset test.
    Bitset,
}

impl DominationKernel {
    /// Parse a `--domination-kernel` / config value.
    pub fn parse(s: &str) -> Result<DominationKernel> {
        match s {
            "auto" => Ok(DominationKernel::Auto),
            "merge" => Ok(DominationKernel::Merge),
            "bitset" => Ok(DominationKernel::Bitset),
            other => Err(Error::Parse(format!(
                "--domination-kernel must be auto|merge|bitset, got {other:?}"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DominationKernel::Auto => "auto",
            DominationKernel::Merge => "merge",
            DominationKernel::Bitset => "bitset",
        }
    }
}

/// The kernel a round actually runs ([`DominationKernel::Auto`] resolved
/// by [`choose`]). Recorded per frontier round by the planner and
/// aggregated into `RoundStats`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    Merge,
    Bitset,
}

impl KernelChoice {
    pub fn name(&self) -> &'static str {
        match self {
            KernelChoice::Merge => "merge",
            KernelChoice::Bitset => "bitset",
        }
    }
}

/// Density crossover of the adaptive policy: the bitset kernel runs when
/// `residual_degree_sum × DENSITY_CROSSOVER ≥ words × alive_count`, i.e.
/// when the average residual degree reaches `1/DENSITY_CROSSOVER` of the
/// block-loop length (`n/64` words). Above that point one merge walk
/// touches as much memory as the whole block loop, with branches instead
/// of straight-line vector ops.
pub const DENSITY_CROSSOVER: usize = 8;

/// Resolve the kernel for one round: pinned policies resolve immediately;
/// `Auto` applies the [`DENSITY_CROSSOVER`] rule to the round-start
/// residue (`alive_count` alive vertices with `residual_degree_sum` total
/// residual degree in a graph of original order `n`). Thread-count
/// independent by construction — it reads only round-start aggregates.
pub fn choose(
    requested: DominationKernel,
    n: usize,
    alive_count: usize,
    residual_degree_sum: usize,
) -> KernelChoice {
    match requested {
        DominationKernel::Merge => KernelChoice::Merge,
        DominationKernel::Bitset => KernelChoice::Bitset,
        DominationKernel::Auto => {
            if alive_count == 0 {
                return KernelChoice::Merge;
            }
            let words = n.div_ceil(64).max(1);
            let dense = residual_degree_sum.saturating_mul(DENSITY_CROSSOVER)
                >= words.saturating_mul(alive_count);
            if dense {
                KernelChoice::Bitset
            } else {
                KernelChoice::Merge
            }
        }
    }
}

/// Reusable one-vertex neighbourhood bitset (`n` bits in u64 blocks) for
/// domination checks against hubs and for the bitset kernel's dominator
/// side. Loading vertex `v` clears the previous owner's bits
/// neighbour-by-neighbour (O(deg) — never a full O(n/64) rescan), so
/// repeated probes against the same dominator are near-free.
///
/// The bits always encode the ORIGINAL adjacency of the owner; callers
/// that operate on a tombstoned residue (the reduction planner) must skip
/// dead vertices themselves before testing membership.
#[derive(Clone, Debug)]
pub struct HubBitset {
    bits: Vec<u64>,
    owner: u32,
}

impl Default for HubBitset {
    fn default() -> HubBitset {
        HubBitset::new()
    }
}

impl HubBitset {
    pub fn new() -> HubBitset {
        HubBitset {
            bits: Vec::new(),
            owner: u32::MAX,
        }
    }

    /// Forget the cached owner and zero every block. Required when the
    /// workspace is re-targeted at a different graph: the stale owner id
    /// is meaningless there and must not be used to clear bits.
    pub fn invalidate(&mut self) {
        self.bits.iter_mut().for_each(|b| *b = 0);
        self.owner = u32::MAX;
    }

    /// Make the bitset hold `N(v)` of `g`, reusing the allocation.
    pub fn load(&mut self, g: &Graph, v: u32) {
        let words = g.n().div_ceil(64);
        if self.bits.len() != words {
            self.bits.clear();
            self.bits.resize(words, 0);
            self.owner = u32::MAX;
        }
        if self.owner == v {
            return;
        }
        if self.owner != u32::MAX {
            for &w in g.neighbors(self.owner) {
                self.bits[w as usize / 64] &= !(1u64 << (w % 64));
            }
        }
        for &w in g.neighbors(v) {
            self.bits[w as usize / 64] |= 1u64 << (w % 64);
        }
        self.owner = v;
    }

    /// Is `x` a neighbour of the loaded owner?
    #[inline]
    pub fn contains(&self, x: u32) -> bool {
        self.bits[x as usize / 64] & (1u64 << (x % 64)) != 0
    }

    /// The raw u64 blocks (block-kernel side of the subset test).
    #[inline]
    fn words(&self) -> &[u64] {
        &self.bits
    }
}

/// Candidate-side residue bits: `N(u) ∩ alive` of the last
/// [`KernelState::load_candidate`]. Unlike [`HubBitset`] there is no
/// same-owner shortcut — `alive` changes between rounds, so a re-checked
/// vertex must always be reloaded; the previous owner is still tracked so
/// clearing walks `N(prev)` (a superset of whatever bits were set)
/// instead of rescanning every block.
#[derive(Clone, Debug)]
struct CandidateBitset {
    bits: Vec<u64>,
    owner: u32,
}

impl Default for CandidateBitset {
    fn default() -> CandidateBitset {
        CandidateBitset {
            bits: Vec::new(),
            owner: u32::MAX,
        }
    }
}

impl CandidateBitset {
    fn invalidate(&mut self) {
        self.bits.iter_mut().for_each(|b| *b = 0);
        self.owner = u32::MAX;
    }

    fn load(&mut self, g: &Graph, alive: &[bool], u: u32) {
        let words = g.n().div_ceil(64);
        if self.bits.len() != words {
            self.bits.clear();
            self.bits.resize(words, 0);
            self.owner = u32::MAX;
        }
        if self.owner != u32::MAX {
            for &w in g.neighbors(self.owner) {
                self.bits[w as usize / 64] &= !(1u64 << (w % 64));
            }
        }
        for &w in g.neighbors(u) {
            if alive[w as usize] {
                self.bits[w as usize / 64] |= 1u64 << (w % 64);
            }
        }
        self.owner = u;
    }

    #[inline]
    fn clear_bit(&mut self, x: u32) {
        self.bits[x as usize / 64] &= !(1u64 << (x % 64));
    }

    #[inline]
    fn set_bit(&mut self, x: u32) {
        self.bits[x as usize / 64] |= 1u64 << (x % 64);
    }

    #[inline]
    fn words(&self) -> &[u64] {
        &self.bits
    }
}

/// Per-worker kernel scratch: one dominator-side [`HubBitset`] (shared by
/// the merge kernel's hub path and the bitset kernel) plus one
/// candidate-side residue bitset. Each frontier worker owns its own state
/// — the bitsets cache one loaded neighbourhood each, so sharing across
/// threads would both race and thrash.
#[derive(Clone, Debug, Default)]
pub struct KernelState {
    hub: HubBitset,
    cand: CandidateBitset,
}

impl KernelState {
    pub fn new() -> KernelState {
        KernelState::default()
    }

    /// Forget all cached owners — required when re-targeting at a
    /// different graph (see [`HubBitset::invalidate`]).
    pub fn invalidate(&mut self) {
        self.hub.invalidate();
        self.cand.invalidate();
    }

    /// Load the candidate-side bits for `u` (`N(u) ∩ alive`). Must be
    /// called before probing dominators of `u` under
    /// [`KernelChoice::Bitset`]; a merge round never needs it.
    pub fn load_candidate(&mut self, g: &Graph, alive: &[bool], u: u32) {
        self.cand.load(g, alive, u);
    }

    /// Does alive `v` dominate alive `u` in the residue, under `choice`?
    /// Same contract as [`residue_dominates`]; under
    /// [`KernelChoice::Bitset`] the caller must have loaded `u` via
    /// [`KernelState::load_candidate`] (once per frontier vertex — every
    /// dominator probe for that vertex then reuses the bits).
    pub fn residue_dominates(
        &mut self,
        g: &Graph,
        alive: &[bool],
        u: u32,
        v: u32,
        choice: KernelChoice,
    ) -> bool {
        match choice {
            KernelChoice::Merge => residue_dominates(g, alive, u, v, &mut self.hub),
            KernelChoice::Bitset => {
                debug_assert_eq!(self.cand.owner, u, "load_candidate(u) before bitset checks");
                self.hub.load(g, v);
                // drop v itself from N(u) ∩ alive (closed-neighbourhood
                // subset: v ∈ N[v] trivially), test, restore
                self.cand.clear_bit(v);
                let dominated = blocks_subset(self.cand.words(), self.hub.words());
                self.cand.set_bit(v);
                dominated
            }
        }
    }
}

/// Does alive `v` dominate alive `u` in the residue selected by `alive`,
/// i.e. is `N[u] ∩ alive ⊆ N[v] ∩ alive`? The caller guarantees `u ~ v`
/// in `g`, that both are alive, and (as a cheap pre-filter) that the
/// residual degree of `u` does not exceed `v`'s.
///
/// This is the merge kernel: low-degree dominator candidates walk both
/// sorted adjacency lists; hubs (original degree ≥ [`HUB_DEGREE`]) load
/// their neighbourhood into the caller's [`HubBitset`] once and answer
/// each probe in `O(deg(u))`. Read-only on `g`/`alive`, so any number of
/// workers can run it concurrently against the same residue, each with
/// its own bitset.
pub fn residue_dominates(g: &Graph, alive: &[bool], u: u32, v: u32, hub: &mut HubBitset) -> bool {
    if g.degree(v) >= HUB_DEGREE {
        hub.load(g, v);
        for &x in g.neighbors(u) {
            if x == v || !alive[x as usize] {
                continue;
            }
            if !hub.contains(x) {
                return false;
            }
        }
        true
    } else {
        let nv = g.neighbors(v);
        let mut j = 0usize;
        for &x in g.neighbors(u) {
            if x == v || !alive[x as usize] {
                continue;
            }
            while j < nv.len() && nv[j] < x {
                j += 1;
            }
            if j == nv.len() || nv[j] != x {
                return false;
            }
            j += 1;
        }
        true
    }
}

/// `a ⊆ b` over equal-length u64 blocks: no bit of `a` is missing from
/// `b`. The shared block primitive of both residue paths — the sparse
/// bitset kernel tests candidate-vs-dominator neighbourhoods with it, and
/// the dense path tests dominator-row-vs-removed masks with its negation.
/// Written as a fixed-width chunked AND-NOT reduction with independent
/// accumulators so LLVM auto-vectorizes it; correctness is asserted
/// against a scalar per-bit reference, independent of codegen.
pub fn blocks_subset(a: &[u64], b: &[u64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(BLOCK_CHUNK);
    let cb = b.chunks_exact(BLOCK_CHUNK);
    let mut tail = 0u64;
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        tail |= x & !y;
    }
    let mut acc = [0u64; BLOCK_CHUNK];
    for (x, y) in ca.zip(cb) {
        for ((s, &xv), &yv) in acc.iter_mut().zip(x).zip(y) {
            *s |= xv & !yv;
        }
    }
    acc.iter().fold(tail, |s, &w| s | w) == 0
}

/// Set bit `i` of a u64-block row (dense-path row packing).
#[inline]
pub fn set_block_bit(words: &mut [u64], i: usize) {
    words[i / 64] |= 1u64 << (i % 64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::prune::dominates;
    use crate::util::Rng;

    #[test]
    fn kernel_policy_parse_and_names() {
        assert_eq!(DominationKernel::parse("auto").unwrap(), DominationKernel::Auto);
        assert_eq!(DominationKernel::parse("merge").unwrap(), DominationKernel::Merge);
        assert_eq!(DominationKernel::parse("bitset").unwrap(), DominationKernel::Bitset);
        assert!(DominationKernel::parse("simd").is_err());
        assert_eq!(DominationKernel::default().name(), "auto");
        assert_eq!(KernelChoice::Bitset.name(), "bitset");
    }

    #[test]
    fn choose_respects_pins_and_density() {
        // pinned: density is irrelevant
        assert_eq!(choose(DominationKernel::Merge, 10, 0, 0), KernelChoice::Merge);
        assert_eq!(choose(DominationKernel::Bitset, 10, 0, 0), KernelChoice::Bitset);
        // auto: a complete residue is dense, a 5-regular 20k residue is not
        assert_eq!(choose(DominationKernel::Auto, 12, 12, 132), KernelChoice::Bitset);
        assert_eq!(
            choose(DominationKernel::Auto, 20_000, 20_000, 100_000),
            KernelChoice::Merge
        );
        // a dense core inside a big graph flips to bitset
        assert_eq!(
            choose(DominationKernel::Auto, 20_000, 500, 25_000),
            KernelChoice::Bitset
        );
        // empty residue: nothing to check, merge by convention
        assert_eq!(choose(DominationKernel::Auto, 100, 0, 0), KernelChoice::Merge);
    }

    #[test]
    fn blocks_subset_matches_scalar_bit_reference() {
        // disassembly-independent differential: whatever the chunked loop
        // compiles to, it must equal the per-bit definition
        let mut rng = Rng::new(91);
        for len in [0usize, 1, 3, 4, 5, 8, 11, 16, 33] {
            for _ in 0..40 {
                let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
                // bias a toward subsets so both outcomes are exercised
                let a: Vec<u64> = b
                    .iter()
                    .map(|&w| {
                        let masked = w & rng.next_u64();
                        if rng.chance(0.3) {
                            masked | rng.next_u64()
                        } else {
                            masked
                        }
                    })
                    .collect();
                let scalar = a.iter().zip(&b).all(|(&x, &y)| x & !y == 0);
                assert_eq!(blocks_subset(&a, &b), scalar, "len={len}");
            }
        }
    }

    #[test]
    fn set_block_bit_places_bits() {
        let mut row = vec![0u64; 3];
        set_block_bit(&mut row, 0);
        set_block_bit(&mut row, 63);
        set_block_bit(&mut row, 64);
        set_block_bit(&mut row, 130);
        assert_eq!(row[0], 1 | (1 << 63));
        assert_eq!(row[1], 1);
        assert_eq!(row[2], 1 << 2);
    }

    #[test]
    fn hub_bitset_tracks_neighbourhoods_across_loads() {
        let g = gen::erdos_renyi(130, 0.1, 3);
        let mut bits = HubBitset::new();
        for v in [0u32, 7, 7, 99, 0] {
            bits.load(&g, v);
            for x in 0..g.n() as u32 {
                assert_eq!(bits.contains(x), g.has_edge(v, x), "owner {v} bit {x}");
            }
        }
        bits.invalidate();
        // retarget to a different graph with the same word count
        let h = gen::star(70);
        bits.load(&h, 0);
        for x in 0..h.n() as u32 {
            assert_eq!(bits.contains(x), h.has_edge(0, x));
        }
    }

    #[test]
    fn residue_domination_matches_induced_subgraph() {
        // killing vertices and re-checking on the mask must agree with
        // materializing the induced residue and running the plain check
        let g = gen::erdos_renyi(40, 0.25, 11);
        let mut rng = Rng::new(11);
        let alive: Vec<bool> = (0..g.n()).map(|_| rng.chance(0.7)).collect();
        let (h, ids) = g.induced(&alive);
        let mut hub = HubBitset::new();
        for (hu, &gu) in ids.iter().enumerate() {
            for (hv, &gv) in ids.iter().enumerate() {
                if hu == hv || !g.has_edge(gu, gv) {
                    continue;
                }
                assert_eq!(
                    residue_dominates(&g, &alive, gu, gv, &mut hub),
                    dominates(&h, hu as u32, hv as u32),
                    "residue pair ({gu},{gv})"
                );
            }
        }
    }

    #[test]
    fn residue_domination_hub_path_matches_merge_path() {
        // a 150-leaf star forces the bitset branch for the hub dominator
        let mut edges: Vec<(u32, u32)> = (1..=150).map(|v| (0u32, v)).collect();
        edges.push((1, 2));
        let g = crate::graph::Graph::from_edges(151, &edges);
        assert!(g.degree(0) >= HUB_DEGREE);
        let mut alive = vec![true; g.n()];
        alive[3] = false;
        let mut hub = HubBitset::new();
        // every leaf is dominated by the hub in the residue
        assert!(residue_dominates(&g, &alive, 5, 0, &mut hub));
        assert!(residue_dominates(&g, &alive, 1, 0, &mut hub));
        // the hub is not dominated by a leaf
        assert!(!residue_dominates(&g, &alive, 0, 1, &mut hub));
    }

    #[test]
    fn bitset_kernel_agrees_with_merge_kernel_on_tombstoned_residues() {
        // the core differential of the tentpole: identical predicate on
        // seeded residues at several tombstone densities, hubs included
        let mut rng = Rng::new(77);
        let graphs = [
            gen::erdos_renyi(90, 0.25, 1),
            gen::barabasi_albert(120, 4, 2),
            gen::complete(18),
            gen::star(100),
        ];
        for g in &graphs {
            for keep in [1.0f64, 0.8, 0.4] {
                let alive: Vec<bool> = (0..g.n()).map(|_| rng.chance(keep)).collect();
                let mut state = KernelState::new();
                for u in 0..g.n() as u32 {
                    if !alive[u as usize] {
                        continue;
                    }
                    state.load_candidate(g, &alive, u);
                    for &v in g.neighbors(u) {
                        if !alive[v as usize] {
                            continue;
                        }
                        let merge = state.residue_dominates(g, &alive, u, v, KernelChoice::Merge);
                        let bits = state.residue_dominates(g, &alive, u, v, KernelChoice::Bitset);
                        assert_eq!(merge, bits, "n={} keep={keep} pair ({u},{v})", g.n());
                    }
                }
            }
        }
    }

    #[test]
    fn kernel_state_reload_survives_alive_changes() {
        // the candidate bitset must not cache across alive flips: load u,
        // kill a neighbour, reload u — the dead neighbour must be gone
        let g = gen::complete(10);
        let mut alive = vec![true; 10];
        let mut state = KernelState::new();
        state.load_candidate(&g, &alive, 0);
        assert!(state.residue_dominates(&g, &alive, 0, 1, KernelChoice::Bitset));
        alive[5] = false;
        state.load_candidate(&g, &alive, 0);
        // still dominated — and the check must not see dead vertex 5
        assert!(state.residue_dominates(&g, &alive, 0, 1, KernelChoice::Bitset));
        let mut hub = HubBitset::new();
        assert!(residue_dominates(&g, &alive, 0, 1, &mut hub));
    }
}
