//! PrunIT domination pruning (S4) and the Strong Collapse baseline (S5).

pub mod domination;
pub mod kernel;
pub mod prunit;
pub mod strong_collapse;

pub use domination::{dominated_pairs_dense, dominates, find_dominator};
pub use kernel::{
    residue_dominates, DominationKernel, HubBitset, KernelChoice, KernelState, HUB_DEGREE,
};
pub use prunit::{prunit, prunit_cancellable, PruneResult};
pub use strong_collapse::{strong_collapse_core, StrongCollapseStats};
