//! PrunIT domination pruning (S4) and the Strong Collapse baseline (S5).

pub mod domination;
pub mod prunit;
pub mod strong_collapse;

pub use domination::{
    dominated_pairs_dense, dominates, find_dominator, HubBitset, HUB_DEGREE, residue_dominates,
};
pub use prunit::{prunit, PruneResult};
pub use strong_collapse::{strong_collapse_core, StrongCollapseStats};
