//! Zero-copy reduction planner: PrunIT (Thm 7), Batagelj–Zaveršnik coring
//! (Thm 2), and component labeling executed **in place on the original
//! CSR** through a reusable [`ReductionWorkspace`].
//!
//! The materializing pipeline pays three full CSR copies before a single
//! boundary column is reduced: one after PrunIT, one after the (k+1)-core,
//! and one per component shard. The planner instead shares a single pair
//! of per-vertex arrays — an `alive` tombstone mask and the residual
//! degree — across all three stages, and compacts to a concrete [`Graph`]
//! exactly once, at emission time (whole-graph for the monolithic path,
//! per shard for the sharded path).
//!
//! # Round-synchronous parallel PrunIT
//!
//! The PrunIT stage is a **frontier sweep**: each round checks every
//! frontier vertex for an admissible dominator against the *round-start*
//! residue, then resolves the collected `(vertex, witness)` candidates in
//! ascending vertex order — a candidate is removed iff its witness is
//! still alive; a candidate whose witness died earlier in the same round
//! is deferred into the next frontier for a re-check. The next frontier
//! is the deferred set plus the alive former neighbours of everything
//! removed.
//!
//! Because the check phase is read-only against the shared `alive`/`deg`
//! arrays, a round's checks commute: [`ReductionWorkspace::set_prune_threads`]
//! partitions the frontier across worker threads, each with its own
//! [`KernelState`], and concatenates the per-worker candidate sets in
//! chunk order. The candidate list — and therefore the residue — is
//! **bit-identical at every thread count**, and identical to the
//! sequential reference `prune::prunit` (differential suites:
//! `rust/tests/parallel_prunit.rs`, `rust/tests/thread_team.rs`).
//!
//! The fan-out runs on a **persistent parking team**
//! ([`crate::util::ThreadTeam`]): the workers are spawned lazily on the
//! first parallel round and then parked on a condvar between rounds, so
//! a multi-round FixedPoint plan pays one spawn per workspace lifetime
//! instead of one per round. The old per-round `std::thread::scope`
//! respawn survives as [`ParallelBackend::Scoped`], the differential and
//! bench reference for the team.
//!
//! Thread policy (`set_prune_threads`): `1` forces inline sweeps, `T ≥ 2`
//! pins `T` threads gated by the static [`PAR_FRONTIER_MIN`] cliff, and
//! `0` enables the **adaptive ramp** — each round projects its
//! sequential check cost from an EWMA of the measured per-check cost
//! (ns/check of previous rounds, recorded alongside the per-round
//! kernel census in the workspace telemetry and surfaced through
//! [`RoundStats`] `par_rounds`) and fans out only with as many threads
//! as the projected work amortizes. The choice is wall-time-only: the
//! candidate set of a round does not depend on how it is chunked.
//!
//! Two further hot-path fixes live here:
//!
//! * **No `Vec::remove` on adjacency lists.** Death is a mask bit plus a
//!   degree decrement; neighbour lists are never edited.
//! * **Adaptive domination kernel.** Every round picks its check kernel
//!   from the measured round-start residue density
//!   (`prune::kernel::choose`): the sorted-merge walk (+ hub bitset for
//!   dominators of original degree ≥ `HUB_DEGREE`) on sparse fringes, the
//!   u64-block subset kernel on dense cores. Both kernels compute the
//!   identical predicate, so the choice — and the
//!   `--domination-kernel merge|bitset` pins exposed through
//!   [`ReductionWorkspace::set_domination_kernel`] — never changes the
//!   residue, only wall time. The per-round choice is recorded in
//!   [`RoundStats`] (`merge_rounds`/`bitset_rounds`) and
//!   [`ReductionWorkspace::kernel_rounds`].
//!
//! On top of the workspace, [`Reduction::FixedPoint`] alternates PrunIT
//! and the (k+1)-core peel until neither removes a vertex. Each stage
//! individually preserves `PD_j` for `j ≥ k` (PrunIT for every dimension,
//! coring for `j ≥ k`), so any finite composition is exact for `j ≥ k` —
//! property-tested against unreduced baselines in `rust/tests/`. The
//! alternation converges because every round but the last removes at
//! least one vertex; rounds are therefore bounded by the removal count.

use crate::complex::Filtration;
use crate::error::Result;
use crate::graph::decompose::Shard;
use crate::graph::Graph;
use crate::homology::PhConfig;
use crate::prune::kernel::{self, DominationKernel, KernelChoice, KernelState};
use crate::util::{CancelToken, TeamSlot, Timer};

use super::pipeline::{Reduction, RoundStats};

/// Frontier length below which a round is swept inline even when
/// [`ReductionWorkspace::set_prune_threads`] pinned more threads: below
/// it, dispatch overhead exceeds the cost of a few hundred domination
/// checks. Purely a performance threshold — the candidate set of a
/// round is the same either way. Adaptive mode (`prune_threads == 0`)
/// replaces this static cliff with a measured ramp.
pub const PAR_FRONTIER_MIN: usize = 512;

/// Minimum frontier chunk handed to one worker; the effective thread
/// count is capped so no worker receives less than this.
const PAR_CHUNK_MIN: usize = 256;

/// Ceiling on the thread count adaptive mode may choose (further capped
/// by `std::thread::available_parallelism`).
pub const PAR_ADAPTIVE_MAX: usize = 8;

/// Assumed cost of waking and joining one team round, in nanoseconds —
/// the dispatch overhead a round's projected work must amortize before
/// adaptive mode fans it out, and the per-thread work quantum of the
/// ramp. Deliberately conservative (a parked-condvar wake is cheaper):
/// mispricing only costs wall time, never correctness.
const PAR_DISPATCH_NS: f64 = 30_000.0;

/// Per-check cost assumed before the first measurement exists (the
/// ballpark of a sparse-residue merge walk), so the very first big
/// frontier still fans out.
const PAR_FALLBACK_CHECK_NS: f64 = 150.0;

/// How many threads a round actually uses for a **pinned** setting of
/// `requested ≥ 1` threads and a frontier of `frontier_len` vertices.
/// The adaptive setting (0) does not come through here — see
/// [`ReductionWorkspace::set_prune_threads`].
fn effective_threads(requested: usize, frontier_len: usize) -> usize {
    let requested = requested.max(1);
    if requested == 1 || frontier_len < PAR_FRONTIER_MIN {
        1
    } else {
        requested.min(frontier_len / PAR_CHUNK_MIN).max(1)
    }
}

/// Which mechanism fans the check phase out. [`ParallelBackend::Team`]
/// (the default) dispatches rounds to the workspace's persistent
/// [`crate::util::ThreadTeam`]; [`ParallelBackend::Scoped`] respawns
/// `std::thread::scope` threads every round, kept as the differential
/// and bench reference the team is measured against. The candidate set
/// — and therefore the residue — is bit-identical either way.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ParallelBackend {
    #[default]
    Team,
    Scoped,
}

/// Raw pointer to the round's per-thread worker slots, shared with the
/// team dispatch: each part index is served by exactly one thread per
/// round, so the slots are accessed disjointly.
struct WorkerPtr(*mut FrontierWorker);
// SAFETY: see above — one part, one thread, disjoint `&mut` per round.
unsafe impl Send for WorkerPtr {}
unsafe impl Sync for WorkerPtr {}

/// Find the frontier vertex `u`'s witness dominator in the residue, or
/// None: the first alive neighbour `v` (ascending CSR order) with
/// residual degree ≥ `u`'s that admissibly dominates `u`, checked under
/// the round's domination kernel. Read-only on everything but the
/// caller's kernel state — safe to run from any number of frontier
/// workers concurrently.
fn find_witness(
    g: &Graph,
    f: &Filtration,
    alive: &[bool],
    deg: &[u32],
    u: u32,
    choice: KernelChoice,
    state: &mut KernelState,
) -> Option<u32> {
    if choice == KernelChoice::Bitset {
        // one candidate-side load per frontier vertex; every dominator
        // probe below reuses the bits
        state.load_candidate(g, alive, u);
    }
    let du = deg[u as usize];
    for &v in g.neighbors(u) {
        if !alive[v as usize] || deg[v as usize] < du {
            continue;
        }
        if f.admissible_removal(u, v) && state.residue_dominates(g, alive, u, v, choice) {
            return Some(v);
        }
    }
    None
}

/// Sweep one frontier chunk against the round-start residue: push each
/// alive vertex's `(vertex, witness)` candidate onto `out` and return
/// the number of vertices checked. The single body behind both the
/// inline and the scoped-thread check phases — keeping it shared is what
/// guarantees the two paths can never diverge.
fn sweep_chunk(
    g: &Graph,
    f: &Filtration,
    alive: &[bool],
    deg: &[u32],
    chunk: &[u32],
    choice: KernelChoice,
    state: &mut KernelState,
    out: &mut Vec<(u32, u32)>,
) -> usize {
    let mut checks = 0usize;
    for &u in chunk {
        if !alive[u as usize] {
            continue;
        }
        checks += 1;
        if let Some(v) = find_witness(g, f, alive, deg, u, choice, state) {
            out.push((u, v));
        }
    }
    checks
}

/// Per-thread scratch for the parallel check phase: a candidate output
/// buffer plus a private kernel state (the bitsets cache one loaded
/// neighbourhood each, so sharing them across threads would both race
/// and thrash).
#[derive(Clone, Debug, Default)]
struct FrontierWorker {
    state: KernelState,
    out: Vec<(u32, u32)>,
    checks: usize,
}

/// Reusable in-place reduction state: one allocation set per worker
/// thread, re-targeted at each graph with [`ReductionWorkspace::plan`].
#[derive(Clone, Debug, Default)]
pub struct ReductionWorkspace {
    /// tombstone mask over original vertex ids
    alive: Vec<bool>,
    /// residual degree (alive neighbours only); stale for dead vertices
    deg: Vec<u32>,
    /// current round's frontier, ascending vertex ids
    frontier: Vec<u32>,
    /// next round's frontier accumulator (sorted at round end)
    next_frontier: Vec<u32>,
    /// membership mask deduplicating `next_frontier` pushes
    in_frontier: Vec<bool>,
    /// this round's `(vertex, witness)` candidates, frontier order
    cands: Vec<(u32, u32)>,
    /// per-thread scratch for parallel check phases
    workers: Vec<FrontierWorker>,
    /// the persistent parking team behind parallel check phases; spawned
    /// lazily on the first fanned-out round and reused across rounds,
    /// passes, and plans. Cloning a workspace clones this as an empty
    /// slot (threads are not clonable state).
    team: TeamSlot,
    /// which fan-out mechanism check phases use; survives `plan`/`reset`
    /// like `prune_threads` — configuration, not per-plan state
    backend: ParallelBackend,
    /// configured PrunIT check-phase thread policy (0 = adaptive, 1 =
    /// forced inline, T ≥ 2 = pinned fan-out); survives `plan`/`reset` —
    /// it is configuration, not per-plan state
    prune_threads: usize,
    /// EWMA of the measured sequential per-check cost in nanoseconds
    /// (0.0 = no measurement yet); drives the adaptive ramp. Survives
    /// re-planning like the team — it is measurement state, and carrying
    /// it across a batch's jobs is exactly what makes the ramp cheap
    check_ns_est: f64,
    /// cached `available_parallelism` cap for adaptive mode (0 = not yet
    /// resolved)
    adaptive_cap: usize,
    /// requested domination-kernel policy; survives `plan`/`reset` like
    /// `prune_threads` — configuration, not per-plan state
    kernel: DominationKernel,
    /// persistence-engine config (`--ph-algorithm` / `--ph-threads`);
    /// survives `plan`/`reset` like `prune_threads` — configuration, not
    /// per-plan state. Downstream PD entry points read it and run the
    /// chunked local phase on this workspace's `team` slot.
    ph: PhConfig,
    /// cooperative cancellation / deadline token, polled at PrunIT round
    /// boundaries and between FixedPoint alternations; survives
    /// `plan`/`reset` like `prune_threads` — the coordinator worker sets
    /// it once per job attempt
    cancel: CancelToken,
    /// fault injection: sleep this long at every frontier-round boundary
    /// (chaos suite only — forces a deadline miss deterministically)
    #[cfg(any(test, feature = "faults"))]
    fault_round_delay: Option<std::time::Duration>,
    /// core-peel stack (scratch for `kcore::peel_residue`)
    peel: Vec<u32>,
    /// domination-kernel state for inline (single-thread) check phases
    kstate: KernelState,
    /// component labels over alive vertices (emit_shards scratch)
    labels: Vec<u32>,
    /// old id -> compacted id scratch
    new_id: Vec<u32>,
    /// BFS stack for component labeling
    stack: Vec<u32>,
    // --- telemetry of the latest plan ---
    rounds: Vec<RoundStats>,
    /// the kernel each frontier round actually ran, in round order
    kernel_log: Vec<KernelChoice>,
    /// frontier rounds run on the merge kernel (latest plan)
    merge_rounds: usize,
    /// frontier rounds run on the u64-block kernel (latest plan)
    bitset_rounds: usize,
    prunit_secs: f64,
    core_secs: f64,
    checks: usize,
    frontier_rounds: usize,
    /// frontier rounds of the latest plan that fanned out (> 1 thread)
    par_frontier_rounds: usize,
    /// threads each frontier round of the latest plan used, round order
    threads_log: Vec<usize>,
    alive_count: usize,
}

impl ReductionWorkspace {
    pub fn new() -> ReductionWorkspace {
        ReductionWorkspace::default()
    }

    /// A workspace with a configured PrunIT check-phase thread policy
    /// (see [`set_prune_threads`](Self::set_prune_threads)).
    pub fn with_prune_threads(threads: usize) -> ReductionWorkspace {
        let mut ws = ReductionWorkspace::default();
        ws.set_prune_threads(threads);
        ws
    }

    /// Configure the PrunIT check-phase thread policy: `0` = adaptive
    /// (per-round thread count from the measured ramp, see module docs),
    /// `1` = forced inline, `T ≥ 2` = pin `T` threads behind the static
    /// [`PAR_FRONTIER_MIN`] gate. The residue is bit-identical at every
    /// setting; only wall time changes.
    pub fn set_prune_threads(&mut self, threads: usize) {
        self.prune_threads = threads;
    }

    /// The configured thread policy, verbatim (0 = adaptive, 1 = inline,
    /// T ≥ 2 = pinned).
    pub fn prune_threads(&self) -> usize {
        self.prune_threads
    }

    /// Select the fan-out mechanism for parallel check phases. The
    /// default [`ParallelBackend::Team`] is the production path;
    /// [`ParallelBackend::Scoped`] is the per-round respawn reference.
    pub fn set_parallel_backend(&mut self, backend: ParallelBackend) {
        self.backend = backend;
    }

    /// The configured fan-out mechanism.
    pub fn parallel_backend(&self) -> ParallelBackend {
        self.backend
    }

    /// Worker threads currently parked in the persistent team (0 until
    /// the first fanned-out round; the dispatching thread is extra).
    pub fn team_workers(&self) -> usize {
        self.team.workers()
    }

    /// A workspace with a pinned (or explicitly `Auto`) domination-kernel
    /// policy — the `--domination-kernel` override.
    pub fn with_domination_kernel(kernel: DominationKernel) -> ReductionWorkspace {
        let mut ws = ReductionWorkspace::default();
        ws.set_domination_kernel(kernel);
        ws
    }

    /// Configure the domination-kernel policy. Both kernels compute the
    /// identical predicate, so the residue is bit-identical at every
    /// setting; only wall time changes.
    pub fn set_domination_kernel(&mut self, kernel: DominationKernel) {
        self.kernel = kernel;
    }

    /// The configured domination-kernel policy.
    pub fn domination_kernel(&self) -> DominationKernel {
        self.kernel
    }

    /// Configure the persistence engine (algorithm, thread budget, chunk
    /// size). Diagrams are bit-identical at every setting; only wall time
    /// changes.
    pub fn set_ph(&mut self, ph: PhConfig) {
        self.ph = ph;
    }

    /// The configured persistence-engine settings.
    pub fn ph(&self) -> PhConfig {
        self.ph
    }

    /// The workspace's persistent team slot, for downstream PD entry
    /// points to run the chunked local phase on — the same parked workers
    /// the PrunIT check phases use, so a job never owns two pools.
    pub(crate) fn ph_team(&mut self) -> &mut TeamSlot {
        &mut self.team
    }

    /// Install a cooperative cancellation / deadline token. It is polled
    /// at every PrunIT frontier-round boundary and between FixedPoint
    /// alternations, and downstream persistence entry points clone it
    /// into the column reduction. Survives re-planning; install
    /// `CancelToken::none()` to clear.
    pub fn set_cancel_token(&mut self, cancel: CancelToken) {
        self.cancel = cancel;
    }

    /// The installed cancellation token (a none token by default).
    pub fn cancel_token(&self) -> &CancelToken {
        &self.cancel
    }

    /// Fault injection (chaos suite only): sleep `delay` at every
    /// frontier-round boundary, turning any graph into a deterministic
    /// deadline miss.
    #[cfg(any(test, feature = "faults"))]
    pub fn set_fault_round_delay(&mut self, delay: Option<std::time::Duration>) {
        self.fault_round_delay = delay;
    }

    /// The kernel each frontier round of the latest plan actually ran, in
    /// round order (`Auto` resolved per round by residue density). Always
    /// `frontier_rounds()` entries long.
    pub fn kernel_rounds(&self) -> &[KernelChoice] {
        &self.kernel_log
    }

    /// Re-target the workspace at `g`: everything alive, residual degrees
    /// = original degrees, telemetry cleared.
    fn reset(&mut self, g: &Graph) {
        let n = g.n();
        self.alive.clear();
        self.alive.resize(n, true);
        self.deg.clear();
        self.deg.extend((0..n as u32).map(|v| g.degree(v) as u32));
        self.frontier.clear();
        self.next_frontier.clear();
        self.in_frontier.clear();
        self.in_frontier.resize(n, false);
        self.cands.clear();
        self.peel.clear();
        self.kstate.invalidate();
        for w in &mut self.workers {
            w.state.invalidate();
            w.out.clear();
            w.checks = 0;
        }
        self.rounds.clear();
        self.kernel_log.clear();
        self.merge_rounds = 0;
        self.bitset_rounds = 0;
        self.prunit_secs = 0.0;
        self.core_secs = 0.0;
        self.checks = 0;
        self.frontier_rounds = 0;
        self.par_frontier_rounds = 0;
        self.threads_log.clear();
        self.alive_count = n;
    }

    /// Run `which` on `(g, f)` targeting `PD_k`, entirely in place. After
    /// this returns, [`compact`](Self::compact) or
    /// [`emit_shards`](Self::emit_shards) materialise the residue — the
    /// only CSR copies the planner ever makes.
    pub fn plan(&mut self, g: &Graph, f: &Filtration, k: usize, which: Reduction) -> Result<()> {
        f.check(g)?;
        self.cancel.check()?;
        self.reset(g);
        let k1 = (k + 1) as u32;
        match which {
            Reduction::None => {}
            Reduction::Coral => {
                let c = self.timed_core(g, k1);
                self.rounds.push(RoundStats {
                    prunit_removed: 0,
                    core_removed: c,
                    merge_rounds: 0,
                    bitset_rounds: 0,
                    par_rounds: 0,
                });
            }
            Reduction::Prunit => {
                let (m0, b0, p0) =
                    (self.merge_rounds, self.bitset_rounds, self.par_frontier_rounds);
                let p = self.timed_prunit(g, f)?;
                self.rounds.push(RoundStats {
                    prunit_removed: p,
                    core_removed: 0,
                    merge_rounds: self.merge_rounds - m0,
                    bitset_rounds: self.bitset_rounds - b0,
                    par_rounds: self.par_frontier_rounds - p0,
                });
            }
            Reduction::Combined => {
                let (m0, b0, p0) =
                    (self.merge_rounds, self.bitset_rounds, self.par_frontier_rounds);
                let p = self.timed_prunit(g, f)?;
                let c = self.timed_core(g, k1);
                self.rounds.push(RoundStats {
                    prunit_removed: p,
                    core_removed: c,
                    merge_rounds: self.merge_rounds - m0,
                    bitset_rounds: self.bitset_rounds - b0,
                    par_rounds: self.par_frontier_rounds - p0,
                });
            }
            Reduction::FixedPoint => loop {
                self.cancel.check()?;
                let (m0, b0, p0) =
                    (self.merge_rounds, self.bitset_rounds, self.par_frontier_rounds);
                let p = self.timed_prunit(g, f)?;
                let c = self.timed_core(g, k1);
                self.rounds.push(RoundStats {
                    prunit_removed: p,
                    core_removed: c,
                    merge_rounds: self.merge_rounds - m0,
                    bitset_rounds: self.bitset_rounds - b0,
                    par_rounds: self.par_frontier_rounds - p0,
                });
                if p + c == 0 {
                    break;
                }
            },
        }
        Ok(())
    }

    // ---------- stage passes ----------

    fn timed_prunit(&mut self, g: &Graph, f: &Filtration) -> Result<usize> {
        let t = Timer::start();
        let r = self.prunit_pass(g, f);
        self.prunit_secs += t.elapsed().as_secs_f64();
        r
    }

    fn timed_core(&mut self, g: &Graph, k1: u32) -> usize {
        let t = Timer::start();
        let removed =
            crate::kcore::peel_residue(g, k1, &mut self.alive, &mut self.deg, &mut self.peel);
        self.alive_count -= removed;
        self.core_secs += t.elapsed().as_secs_f64();
        removed
    }

    /// One PrunIT frontier sweep to its fixed point (see module docs).
    /// Every pass seeds the frontier with all alive vertices in ascending
    /// id order — exactly the schedule `prune::prunit` uses on the
    /// materialized residue — so the planner's removal set is bit-identical
    /// to the sequential reference's even where twin choices depend on
    /// processing order.
    fn prunit_pass(&mut self, g: &Graph, f: &Filtration) -> Result<usize> {
        debug_assert!(self.frontier.is_empty());
        {
            let alive = &self.alive;
            let frontier = &mut self.frontier;
            frontier.extend((0..g.n() as u32).filter(|&v| alive[v as usize]));
        }
        let mut removed_total = 0usize;
        while !self.frontier.is_empty() {
            #[cfg(any(test, feature = "faults"))]
            if let Some(delay) = self.fault_round_delay {
                std::thread::sleep(delay);
            }
            // deadline checkpoint: one poll per frontier round — between
            // rounds the alive/deg arrays are consistent, so unwinding
            // here leaves the workspace reusable (the next plan resets it)
            if let Err(e) = self.cancel.check() {
                self.frontier.clear();
                return Err(e);
            }
            self.frontier_rounds += 1;
            self.collect_candidates(g, f);
            removed_total += self.resolve_round(g);
        }
        Ok(removed_total)
    }

    /// Resolve the domination kernel for the round about to run: pinned
    /// policies resolve immediately; `Auto` measures the round-start
    /// residue density (alive count + residual degree sum — the O(n) scan
    /// is skipped entirely for pinned kernels). Thread-count independent:
    /// the inputs are round-start aggregates, identical however the
    /// frontier is chunked.
    fn round_kernel(&self, g: &Graph) -> KernelChoice {
        if self.kernel != DominationKernel::Auto {
            return kernel::choose(self.kernel, g.n(), 0, 0);
        }
        let degree_sum: usize = self
            .alive
            .iter()
            .zip(&self.deg)
            .filter(|(&a, _)| a)
            .map(|(_, &d)| d as usize)
            .sum();
        kernel::choose(self.kernel, g.n(), self.alive_count, degree_sum)
    }

    /// Thread budget for the round about to run. Pinned policies
    /// (`prune_threads ≥ 1`) go through [`effective_threads`]; the
    /// adaptive policy (0) projects the round's sequential check cost
    /// from the EWMA per-check estimate and ramps up one thread per
    /// dispatch-cost's worth of projected work. Wall-time-only: the
    /// candidate set of a round does not depend on the choice.
    fn round_threads(&mut self) -> usize {
        let len = self.frontier.len();
        if self.prune_threads >= 1 {
            return effective_threads(self.prune_threads, len);
        }
        if self.adaptive_cap == 0 {
            self.adaptive_cap = std::thread::available_parallelism()
                .map_or(1, |p| p.get())
                .min(PAR_ADAPTIVE_MAX);
        }
        if self.adaptive_cap <= 1 || len < 2 * PAR_CHUNK_MIN {
            return 1;
        }
        let per_check = if self.check_ns_est > 0.0 {
            self.check_ns_est
        } else {
            PAR_FALLBACK_CHECK_NS
        };
        let projected_ns = per_check * len as f64;
        if projected_ns < 2.0 * PAR_DISPATCH_NS {
            return 1;
        }
        let by_work = (projected_ns / PAR_DISPATCH_NS) as usize;
        by_work
            .min(self.adaptive_cap)
            .min(len / PAR_CHUNK_MIN)
            .max(1)
    }

    /// Check phase: fill `self.cands` with this round's `(vertex,
    /// witness)` pairs in frontier (ascending) order, reading the
    /// round-start `alive`/`deg` state. Runs inline or fanned out over
    /// the persistent team (or scoped threads, on the reference
    /// backend) — the output is identical every way, because every
    /// check is a pure function of the shared round-start arrays (kernel
    /// choice included) and the frontier chunks are concatenated back in
    /// order.
    fn collect_candidates(&mut self, g: &Graph, f: &Filtration) {
        self.cands.clear();
        let choice = self.round_kernel(g);
        self.kernel_log.push(choice);
        match choice {
            KernelChoice::Merge => self.merge_rounds += 1,
            KernelChoice::Bitset => self.bitset_rounds += 1,
        }
        let threads = self.round_threads();
        self.threads_log.push(threads);
        let checks_before = self.checks;
        let t = Timer::start();
        if threads <= 1 {
            self.checks += sweep_chunk(
                g,
                f,
                &self.alive,
                &self.deg,
                &self.frontier,
                choice,
                &mut self.kstate,
                &mut self.cands,
            );
        } else {
            self.par_frontier_rounds += 1;
            if self.workers.len() < threads {
                self.workers.resize_with(threads, FrontierWorker::default);
            }
            for w in &mut self.workers[..threads] {
                w.out.clear();
                w.checks = 0;
            }
            let chunk = self.frontier.len().div_ceil(threads);
            {
                let alive: &[bool] = &self.alive;
                let deg: &[u32] = &self.deg;
                let frontier: &[u32] = &self.frontier;
                match self.backend {
                    ParallelBackend::Scoped => {
                        let workers = &mut self.workers[..threads];
                        std::thread::scope(|scope| {
                            for (w, slice) in workers.iter_mut().zip(frontier.chunks(chunk)) {
                                scope.spawn(move || {
                                    w.checks = sweep_chunk(
                                        g, f, alive, deg, slice, choice, &mut w.state, &mut w.out,
                                    );
                                });
                            }
                        });
                    }
                    ParallelBackend::Team => {
                        // the dispatching thread sweeps part 0 itself, so
                        // `threads` parts need `threads - 1` team workers
                        let team = self.team.get(threads - 1);
                        let workers = &mut self.workers[..threads];
                        let wptr = WorkerPtr(workers.as_mut_ptr());
                        let len = frontier.len();
                        let body = move |part: usize| {
                            let lo = part * chunk;
                            if lo >= len {
                                return;
                            }
                            let hi = (lo + chunk).min(len);
                            // SAFETY: part indices are distinct per round
                            // (one per thread, `ThreadTeam::run` barrier),
                            // so each slot gets exactly one `&mut`; the
                            // pointer stays valid because `run` does not
                            // return before every part finished.
                            let w = unsafe { &mut *wptr.0.add(part) };
                            w.checks = sweep_chunk(
                                g,
                                f,
                                alive,
                                deg,
                                &frontier[lo..hi],
                                choice,
                                &mut w.state,
                                &mut w.out,
                            );
                        };
                        let worker_panics = team.run(threads, &body);
                        // a panicking check is a poisoned round: escalate
                        // as a panic so the job harness's catch_unwind
                        // isolates it like any other job panic
                        assert_eq!(
                            worker_panics, 0,
                            "{worker_panics} PrunIT team worker part(s) panicked"
                        );
                    }
                }
            }
            for w in &self.workers[..threads] {
                self.cands.extend_from_slice(&w.out);
                self.checks += w.checks;
            }
        }
        // feed the adaptive ramp: fold this round's measured per-check
        // cost (normalised back to sequential ns/check) into the EWMA
        let round_checks = self.checks - checks_before;
        if round_checks > 0 {
            let secs = t.elapsed().as_secs_f64();
            let sample = secs * 1e9 * threads as f64 / round_checks as f64;
            if sample > 0.0 {
                self.check_ns_est = if self.check_ns_est > 0.0 {
                    0.5 * (self.check_ns_est + sample)
                } else {
                    sample
                };
            }
        }
    }

    /// Resolution phase, always sequential and ascending: apply
    /// tombstones for every candidate whose witness is still alive, defer
    /// the rest, and rebuild the frontier (deferred candidates + alive
    /// former neighbours of the removed). Returns the removal count.
    fn resolve_round(&mut self, g: &Graph) -> usize {
        self.next_frontier.clear();
        let mut removed = 0usize;
        for &(u, w) in &self.cands {
            if self.alive[w as usize] {
                self.alive[u as usize] = false;
                self.alive_count -= 1;
                removed += 1;
                for &x in g.neighbors(u) {
                    if self.alive[x as usize] {
                        self.deg[x as usize] -= 1;
                        if !self.in_frontier[x as usize] {
                            self.in_frontier[x as usize] = true;
                            self.next_frontier.push(x);
                        }
                    }
                }
            } else if !self.in_frontier[u as usize] {
                // witness died this round: defer u — it may still have
                // another dominator in the new residue
                self.in_frontier[u as usize] = true;
                self.next_frontier.push(u);
            }
        }
        self.next_frontier.sort_unstable();
        {
            let next = &self.next_frontier;
            let in_frontier = &mut self.in_frontier;
            for &x in next {
                in_frontier[x as usize] = false;
            }
        }
        std::mem::swap(&mut self.frontier, &mut self.next_frontier);
        removed
    }

    // ---------- emission (the single compaction) ----------

    /// Materialise the residue as one compacted `(Graph, Filtration,
    /// new id -> old id)` — the monolithic path's only CSR copy.
    pub fn compact(&mut self, g: &Graph, f: &Filtration) -> (Graph, Filtration, Vec<u32>) {
        let kept: Vec<u32> = (0..g.n() as u32)
            .filter(|&v| self.alive[v as usize])
            .collect();
        self.new_id.clear();
        self.new_id.resize(g.n(), u32::MAX);
        for (new, &old) in kept.iter().enumerate() {
            self.new_id[old as usize] = new as u32;
        }
        // residual degrees are maintained exactly → exact preallocation
        let cap: usize = kept.iter().map(|&v| self.deg[v as usize] as usize).sum();
        let mut offsets = Vec::with_capacity(kept.len() + 1);
        let mut neighbors = Vec::with_capacity(cap);
        offsets.push(0);
        for &old in &kept {
            for &w in g.neighbors(old) {
                if self.alive[w as usize] {
                    neighbors.push(self.new_id[w as usize]);
                }
            }
            offsets.push(neighbors.len());
        }
        let graph = Graph::from_csr_parts(offsets, neighbors);
        let filtration = f.restrict(&kept);
        (graph, filtration, kept)
    }

    /// Label the residue's connected components and materialise one
    /// compacted [`Shard`] per component — the sharded path's only CSR
    /// copies (exactly one per emitted shard, none in between stages).
    /// Component ids are ordered by smallest member, and within a shard
    /// vertex ids ascend with original ids, so mapped neighbour lists
    /// stay sorted — identical output to `decompose_filtered` applied to
    /// the compacted residue.
    pub fn emit_shards(&mut self, g: &Graph, f: &Filtration) -> Vec<Shard> {
        let n = g.n();
        self.labels.clear();
        self.labels.resize(n, u32::MAX);
        debug_assert!(self.stack.is_empty());
        let mut count = 0u32;
        for s in 0..n as u32 {
            if !self.alive[s as usize] || self.labels[s as usize] != u32::MAX {
                continue;
            }
            self.labels[s as usize] = count;
            self.stack.push(s);
            while let Some(v) = self.stack.pop() {
                for &w in g.neighbors(v) {
                    if self.alive[w as usize] && self.labels[w as usize] == u32::MAX {
                        self.labels[w as usize] = count;
                        self.stack.push(w);
                    }
                }
            }
            count += 1;
        }
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); count as usize];
        for v in 0..n as u32 {
            if self.alive[v as usize] {
                members[self.labels[v as usize] as usize].push(v);
            }
        }
        self.new_id.clear();
        self.new_id.resize(n, u32::MAX);
        for part in &members {
            for (i, &v) in part.iter().enumerate() {
                self.new_id[v as usize] = i as u32;
            }
        }
        members
            .into_iter()
            .map(|old_ids| {
                let cap: usize = old_ids
                    .iter()
                    .map(|&v| self.deg[v as usize] as usize)
                    .sum();
                let mut offsets = Vec::with_capacity(old_ids.len() + 1);
                let mut neighbors = Vec::with_capacity(cap);
                offsets.push(0);
                for &v in &old_ids {
                    for &w in g.neighbors(v) {
                        if self.alive[w as usize] {
                            neighbors.push(self.new_id[w as usize]);
                        }
                    }
                    offsets.push(neighbors.len());
                }
                let filtration = f.restrict(&old_ids);
                Shard {
                    graph: Graph::from_csr_parts(offsets, neighbors),
                    filtration,
                    kept_old_ids: old_ids,
                }
            })
            .collect()
    }

    // ---------- telemetry ----------

    /// Alive-vertex count of the residue.
    pub fn alive_count(&self) -> usize {
        self.alive_count
    }

    /// Residual edge count (half the alive degree sum).
    pub fn edges_alive(&self) -> usize {
        let twice: usize = self
            .alive
            .iter()
            .zip(&self.deg)
            .filter(|(&a, _)| a)
            .map(|(_, &d)| d as usize)
            .sum();
        twice / 2
    }

    /// Alive mask over original vertex ids.
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Per-round removal counts of the latest plan.
    pub fn rounds(&self) -> &[RoundStats] {
        &self.rounds
    }

    /// Seconds spent in PrunIT passes (latest plan).
    pub fn prunit_secs(&self) -> f64 {
        self.prunit_secs
    }

    /// Seconds spent in core peels (latest plan).
    pub fn core_secs(&self) -> f64 {
        self.core_secs
    }

    /// Frontier vertices checked for domination (latest plan) — the
    /// work-done proxy reported by `prune::prunit` as `checks`.
    pub fn checks(&self) -> usize {
        self.checks
    }

    /// PrunIT frontier sweep rounds summed over all passes of the latest
    /// plan. Schedule-deterministic: equal at every thread count, and
    /// equal to the sum of `PruneResult::rounds` over the materializing
    /// reference's passes.
    pub fn frontier_rounds(&self) -> usize {
        self.frontier_rounds
    }

    /// Frontier rounds of the latest plan whose check phase fanned out
    /// (> 1 thread). Also aggregated per alternation pass in
    /// [`RoundStats`] `par_rounds`.
    pub fn par_frontier_rounds(&self) -> usize {
        self.par_frontier_rounds
    }

    /// Threads each frontier round of the latest plan used, in round
    /// order (1 = inline). Always `frontier_rounds()` entries long.
    /// Under the adaptive policy this is timing-dependent telemetry —
    /// unlike `checks()`/`frontier_rounds()` it may differ between runs,
    /// while the residue never does.
    pub fn round_thread_log(&self) -> &[usize] {
        &self.threads_log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::homology::persistence_diagrams;
    use crate::prune::kernel::HUB_DEGREE;
    use crate::prune::prunit;
    use crate::reduce::coral_reduce;

    fn ws_residue(g: &Graph, f: &Filtration, k: usize, which: Reduction) -> Vec<u32> {
        let mut ws = ReductionWorkspace::new();
        ws.plan(g, f, k, which).unwrap();
        (0..g.n() as u32).filter(|&v| ws.alive()[v as usize]).collect()
    }

    #[test]
    fn prunit_plan_matches_materializing_prunit() {
        let mut rng = crate::util::Rng::new(12);
        for _ in 0..20 {
            let n = rng.range(4, 60);
            let g = gen::erdos_renyi(n, 0.2, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let kept = ws_residue(&g, &f, 1, Reduction::Prunit);
            let r = prunit(&g, &f).unwrap();
            assert_eq!(kept, r.kept_old_ids, "n={n}");
        }
    }

    #[test]
    fn coral_plan_matches_materializing_core() {
        let mut rng = crate::util::Rng::new(13);
        for _ in 0..20 {
            let n = rng.range(4, 60);
            let g = gen::erdos_renyi(n, 0.15, rng.next_u64());
            let f = Filtration::degree(&g);
            for k in 1..=2 {
                let kept = ws_residue(&g, &f, k, Reduction::Coral);
                let r = coral_reduce(&g, &f, k).unwrap();
                assert_eq!(kept, r.kept_old_ids, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn hub_path_agrees_with_merge_path_on_a_star_of_stars() {
        // hub 0 with 200 leaves (degree ≥ HUB_DEGREE forces the bitset
        // path), plus clique decorations to exercise real subset checks
        let mut edges: Vec<(u32, u32)> = (1..=200).map(|v| (0u32, v)).collect();
        edges.extend([(1, 2), (2, 3), (1, 3), (0, 201), (201, 1)]);
        let g = Graph::from_edges(202, &edges);
        let f = Filtration::degree_superlevel(&g);
        let kept = ws_residue(&g, &f, 1, Reduction::Prunit);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(kept, r.kept_old_ids);
        assert!(g.degree(0) >= HUB_DEGREE);
    }

    #[test]
    fn parallel_frontier_is_bit_identical_and_counts_match() {
        // large enough that round 1 (n ≥ PAR_FRONTIER_MIN) takes the
        // scoped-thread path for every threads > 1 setting
        let g = gen::erdos_renyi(3000, 5.0 / 3000.0, 23);
        let f = Filtration::degree_superlevel(&g);
        let mut seq = ReductionWorkspace::new();
        seq.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        let seq_alive: Vec<bool> = seq.alive().to_vec();
        let r = prunit(&g, &f).unwrap();
        assert_eq!(seq.checks(), r.checks, "planner checks == reference checks");
        assert_eq!(seq.frontier_rounds(), r.rounds);
        for threads in [2usize, 4, 8] {
            let mut par = ReductionWorkspace::with_prune_threads(threads);
            par.plan(&g, &f, 1, Reduction::Prunit).unwrap();
            assert_eq!(par.alive(), &seq_alive[..], "threads={threads}");
            assert_eq!(par.checks(), seq.checks(), "threads={threads}");
            assert_eq!(par.frontier_rounds(), seq.frontier_rounds(), "threads={threads}");
            assert_eq!(par.prune_threads(), threads);
        }
        assert!(g.n() >= PAR_FRONTIER_MIN);
    }

    #[test]
    fn fixed_point_leaves_nothing_to_remove() {
        let mut rng = crate::util::Rng::new(14);
        for _ in 0..10 {
            let n = rng.range(6, 50);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let mut ws = ReductionWorkspace::new();
            ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
            let (h, fh, _) = ws.compact(&g, &f);
            // no admissible dominated vertex, and min degree ≥ 2
            for u in 0..h.n() as u32 {
                assert!(h.degree(u) >= 2, "vertex {u} below core threshold");
                assert!(
                    crate::prune::find_dominator(&h, &fh, u).is_none(),
                    "vertex {u} still prunable"
                );
            }
            // last round removed nothing
            let last = ws.rounds().last().unwrap();
            assert_eq!(last.prunit_removed + last.core_removed, 0);
        }
    }

    #[test]
    fn fixed_point_pd1_exact_on_cycle_with_tail() {
        // cycle 0..6 + pendant path: FixedPoint peels the tail (core) and
        // whatever domination appears, PD_1 must survive untouched
        let mut edges: Vec<(u32, u32)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
        edges.push((0, 6));
        edges.push((6, 7));
        let g = Graph::from_edges(8, &edges);
        let f = Filtration::degree(&g);
        let mut ws = ReductionWorkspace::new();
        ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
        let (h, fh, _) = ws.compact(&g, &f);
        let before = persistence_diagrams(&g, &f, 1);
        let after = persistence_diagrams(&h, &fh, 1);
        assert!(before[1].same_as(&after[1], 1e-12));
    }

    #[test]
    fn emit_shards_equals_decompose_of_compacted_residue() {
        let mut rng = crate::util::Rng::new(15);
        for _ in 0..12 {
            let n = rng.range(6, 50);
            let g = gen::erdos_renyi(n, 0.08, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let mut ws = ReductionWorkspace::new();
            ws.plan(&g, &f, 1, Reduction::Combined).unwrap();
            let shards = ws.emit_shards(&g, &f);
            let (h, fh, kept) = ws.compact(&g, &f);
            let reference = crate::graph::decompose::decompose_filtered(&h, &fh);
            assert_eq!(shards.len(), reference.len());
            for (s, r) in shards.iter().zip(&reference) {
                assert_eq!(s.graph, r.graph);
                assert_eq!(s.filtration, r.filtration);
                // planner ids are original; reference ids go through `kept`
                let via_kept: Vec<u32> =
                    r.kept_old_ids.iter().map(|&m| kept[m as usize]).collect();
                assert_eq!(s.kept_old_ids, via_kept);
            }
        }
    }

    #[test]
    fn workspace_reuse_across_graphs_is_clean() {
        let mut ws = ReductionWorkspace::with_prune_threads(4);
        let specs: [(usize, f64, u64); 4] =
            [(40, 0.2, 1), (7, 0.5, 2), (2000, 0.002, 3), (40, 0.2, 1)];
        let mut first_run: Option<Vec<u32>> = None;
        for (i, &(n, p, seed)) in specs.iter().enumerate() {
            let g = gen::erdos_renyi(n, p, seed);
            let f = Filtration::degree_superlevel(&g);
            ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
            let (_, _, kept) = ws.compact(&g, &f);
            assert_eq!(ws.alive_count(), kept.len());
            if i == 0 {
                first_run = Some(kept);
            } else if i == 3 {
                assert_eq!(kept, first_run.clone().unwrap(), "reuse must be deterministic");
            }
        }
    }

    #[test]
    fn plan_rejects_mismatched_filtration() {
        let g = gen::cycle(5);
        let f = Filtration::constant(3);
        let mut ws = ReductionWorkspace::new();
        assert!(ws.plan(&g, &f, 1, Reduction::Combined).is_err());
    }

    #[test]
    fn telemetry_accounts_for_all_removals() {
        let g = gen::barabasi_albert(300, 2, 5);
        let f = Filtration::degree_superlevel(&g);
        let mut ws = ReductionWorkspace::new();
        ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
        let removed_by_rounds: usize = ws
            .rounds()
            .iter()
            .map(|r| r.prunit_removed + r.core_removed)
            .sum();
        assert_eq!(removed_by_rounds, g.n() - ws.alive_count());
        assert!(ws.rounds().len() <= removed_by_rounds + 1);
        assert!(ws.checks() > 0);
        assert!(ws.frontier_rounds() >= ws.rounds().len());
    }

    #[test]
    fn kernel_choice_is_recorded_per_round() {
        // complete graph: the residue stays dense, so Auto runs the block
        // kernel on (at least) the heavy early rounds
        let g = gen::complete(30);
        let f = Filtration::degree_superlevel(&g);
        let mut ws = ReductionWorkspace::new();
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        assert_eq!(ws.kernel_rounds().len(), ws.frontier_rounds());
        let bitset: usize = ws.rounds().iter().map(|r| r.bitset_rounds).sum();
        let merge: usize = ws.rounds().iter().map(|r| r.merge_rounds).sum();
        assert!(bitset > 0, "dense residue must engage the block kernel");
        assert_eq!(bitset + merge, ws.frontier_rounds());
        assert_eq!(
            ws.kernel_rounds()
                .iter()
                .filter(|&&k| k == KernelChoice::Bitset)
                .count(),
            bitset
        );

        // pinned kernels: identical residue, census all on one side
        let pins = [(DominationKernel::Merge, true), (DominationKernel::Bitset, false)];
        for (pin, want_merge) in pins {
            let mut pinned = ReductionWorkspace::with_domination_kernel(pin);
            pinned.plan(&g, &f, 1, Reduction::Prunit).unwrap();
            assert_eq!(pinned.alive(), ws.alive(), "{}", pin.name());
            assert_eq!(pinned.domination_kernel(), pin);
            let m: usize = pinned.rounds().iter().map(|r| r.merge_rounds).sum();
            let b: usize = pinned.rounds().iter().map(|r| r.bitset_rounds).sum();
            if want_merge {
                assert_eq!((m, b), (pinned.frontier_rounds(), 0));
            } else {
                assert_eq!((m, b), (0, pinned.frontier_rounds()));
            }
        }
    }

    #[test]
    fn sparse_fringe_resolves_auto_to_merge() {
        // avg degree ~2 at n=3000: the crossover needs avg residual
        // degree ≥ words/8 ≈ 5.9, which this residue never approaches
        let g = gen::erdos_renyi(3000, 2.0 / 3000.0, 23);
        let f = Filtration::degree_superlevel(&g);
        let mut ws = ReductionWorkspace::new();
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        assert!(ws.frontier_rounds() > 0);
        assert!(ws.kernel_rounds().iter().all(|&k| k == KernelChoice::Merge));
    }

    #[test]
    fn kernel_config_survives_reset_like_prune_threads() {
        let g = gen::complete(12);
        let f = Filtration::degree_superlevel(&g);
        let mut ws = ReductionWorkspace::with_domination_kernel(DominationKernel::Bitset);
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        assert_eq!(ws.domination_kernel(), DominationKernel::Bitset);
        let m: usize = ws.rounds().iter().map(|r| r.merge_rounds).sum();
        assert_eq!(m, 0, "pin must survive re-planning");
    }

    #[test]
    fn cancelled_token_unwinds_plan_between_rounds() {
        let g = gen::erdos_renyi(200, 0.1, 7);
        let f = Filtration::degree_superlevel(&g);
        let mut ws = ReductionWorkspace::new();
        let t = CancelToken::cancellable();
        ws.set_cancel_token(t.clone());
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap(); // live token: fine
        t.cancel();
        assert!(matches!(
            ws.plan(&g, &f, 1, Reduction::Prunit),
            Err(crate::error::Error::Cancelled)
        ));
        // clearing the token restores normal operation on the same ws
        ws.set_cancel_token(CancelToken::none());
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
    }

    #[test]
    fn fault_round_delay_forces_deadline_miss() {
        let g = gen::erdos_renyi(120, 0.1, 9);
        let f = Filtration::degree_superlevel(&g);
        let mut ws = ReductionWorkspace::new();
        ws.set_fault_round_delay(Some(std::time::Duration::from_millis(40)));
        ws.set_cancel_token(CancelToken::with_deadline(std::time::Duration::from_millis(5)));
        match ws.plan(&g, &f, 1, Reduction::FixedPoint) {
            Err(crate::error::Error::DeadlineExceeded { limit_secs }) => {
                assert!(limit_secs > 0.0);
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        // same workspace, fault cleared, fresh token: plans normally
        ws.set_fault_round_delay(None);
        ws.set_cancel_token(CancelToken::none());
        ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
    }

    #[test]
    fn effective_threads_respects_thresholds() {
        assert_eq!(effective_threads(1, 100_000), 1);
        assert_eq!(effective_threads(8, PAR_FRONTIER_MIN - 1), 1);
        assert_eq!(effective_threads(8, PAR_FRONTIER_MIN), 2);
        assert_eq!(effective_threads(4, 100_000), 4);
    }

    #[test]
    fn scoped_backend_matches_team_backend() {
        let g = gen::erdos_renyi(3000, 5.0 / 3000.0, 23);
        let f = Filtration::degree_superlevel(&g);
        let mut team = ReductionWorkspace::with_prune_threads(4);
        team.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
        assert_eq!(team.parallel_backend(), ParallelBackend::Team);
        assert!(team.team_workers() > 0, "team must have spawned");
        let mut scoped = ReductionWorkspace::with_prune_threads(4);
        scoped.set_parallel_backend(ParallelBackend::Scoped);
        scoped.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
        assert_eq!(scoped.team_workers(), 0, "scoped path must not spawn a team");
        assert_eq!(team.alive(), scoped.alive());
        assert_eq!(team.checks(), scoped.checks());
        assert_eq!(team.frontier_rounds(), scoped.frontier_rounds());
        assert_eq!(team.par_frontier_rounds(), scoped.par_frontier_rounds());
    }

    #[test]
    fn adaptive_policy_is_residue_invariant() {
        let g = gen::erdos_renyi(3000, 5.0 / 3000.0, 29);
        let f = Filtration::degree_superlevel(&g);
        let mut seq = ReductionWorkspace::with_prune_threads(1);
        seq.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
        let mut auto = ReductionWorkspace::with_prune_threads(0);
        assert_eq!(auto.prune_threads(), 0);
        for trial in 0..3 {
            auto.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
            assert_eq!(auto.alive(), seq.alive(), "trial {trial}");
            assert_eq!(auto.checks(), seq.checks(), "trial {trial}");
            assert_eq!(auto.frontier_rounds(), seq.frontier_rounds(), "trial {trial}");
            assert_eq!(auto.round_thread_log().len(), auto.frontier_rounds());
            let par_by_rounds: usize = auto.rounds().iter().map(|r| r.par_rounds).sum();
            assert_eq!(par_by_rounds, auto.par_frontier_rounds(), "trial {trial}");
            assert_eq!(
                auto.round_thread_log().iter().filter(|&&t| t > 1).count(),
                auto.par_frontier_rounds(),
                "trial {trial}"
            );
        }
    }

    #[test]
    fn team_persists_across_plans_and_clones_empty() {
        let g = gen::erdos_renyi(3000, 5.0 / 3000.0, 31);
        let f = Filtration::degree_superlevel(&g);
        let mut ws = ReductionWorkspace::with_prune_threads(4);
        ws.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        let spawned = ws.team_workers();
        assert_eq!(spawned, 3, "4 parts = dispatcher + 3 team workers");
        ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
        assert_eq!(ws.team_workers(), spawned, "replanning must reuse the team");
        let cloned = ws.clone();
        assert_eq!(cloned.team_workers(), 0, "threads are not clonable state");
        // the clone still plans correctly, spawning its own team lazily
        let mut cloned = cloned;
        cloned.plan(&g, &f, 1, Reduction::Prunit).unwrap();
        assert_eq!(cloned.alive(), ws.alive());
    }
}
