//! Zero-copy reduction planner: PrunIT (Thm 7), Batagelj–Zaveršnik coring
//! (Thm 2), and component labeling executed **in place on the original
//! CSR** through a reusable [`ReductionWorkspace`].
//!
//! The materializing pipeline pays three full CSR copies before a single
//! boundary column is reduced: one after PrunIT, one after the (k+1)-core,
//! and one per component shard. The planner instead shares a single pair
//! of per-vertex arrays — an `alive` tombstone mask and the residual
//! degree — across all three stages, and compacts to a concrete [`Graph`]
//! exactly once, at emission time (whole-graph for the monolithic path,
//! per shard for the sharded path).
//!
//! Two further hot-path fixes live here:
//!
//! * **No `Vec::remove` on adjacency lists.** `prune::prunit`'s mutable
//!   view deletes an edge with an O(deg) memmove, O(deg²) on the hubs
//!   that dominate real networks. The planner never edits a neighbour
//!   list — death is a mask bit plus a degree decrement.
//! * **Hybrid domination checks.** Low-degree dominator candidates use
//!   the sorted-merge walk; hub candidates (original degree ≥
//!   [`HUB_DEGREE`]) load a u64-block neighbourhood bitset once and
//!   answer each probe in O(deg(u)).
//!
//! On top of the workspace, [`Reduction::FixedPoint`] alternates PrunIT
//! and the (k+1)-core peel until neither removes a vertex. Each stage
//! individually preserves `PD_j` for `j ≥ k` (PrunIT for every dimension,
//! coring for `j ≥ k`), so any finite composition is exact for `j ≥ k` —
//! property-tested against unreduced baselines in `rust/tests/`. The
//! alternation converges because every round but the last removes at
//! least one vertex; rounds are therefore bounded by the removal count.

use std::collections::VecDeque;

use crate::complex::Filtration;
use crate::error::Result;
use crate::graph::decompose::Shard;
use crate::graph::Graph;
use crate::prune::domination::{HubBitset, HUB_DEGREE};
use crate::util::Timer;

use super::pipeline::{Reduction, RoundStats};

/// Reusable in-place reduction state: one allocation set per worker
/// thread, re-targeted at each graph with [`ReductionWorkspace::plan`].
#[derive(Clone, Debug, Default)]
pub struct ReductionWorkspace {
    /// tombstone mask over original vertex ids
    alive: Vec<bool>,
    /// residual degree (alive neighbours only); stale for dead vertices
    deg: Vec<u32>,
    /// PrunIT worklist bookkeeping
    in_queue: Vec<bool>,
    queue: VecDeque<u32>,
    /// core-peel stack (scratch for `kcore::peel_residue`)
    peel: Vec<u32>,
    /// hub neighbourhood bitset for the hybrid domination check
    hub: HubBitset,
    /// component labels over alive vertices (emit_shards scratch)
    labels: Vec<u32>,
    /// old id -> compacted id scratch
    new_id: Vec<u32>,
    /// BFS stack for component labeling
    stack: Vec<u32>,
    // --- telemetry of the latest plan ---
    rounds: Vec<RoundStats>,
    prunit_secs: f64,
    core_secs: f64,
    checks: usize,
    alive_count: usize,
}

impl ReductionWorkspace {
    pub fn new() -> ReductionWorkspace {
        ReductionWorkspace::default()
    }

    /// Re-target the workspace at `g`: everything alive, residual degrees
    /// = original degrees, telemetry cleared.
    fn reset(&mut self, g: &Graph) {
        let n = g.n();
        self.alive.clear();
        self.alive.resize(n, true);
        self.deg.clear();
        self.deg.extend((0..n as u32).map(|v| g.degree(v) as u32));
        self.in_queue.clear();
        self.in_queue.resize(n, false);
        self.queue.clear();
        self.peel.clear();
        self.hub.invalidate();
        self.rounds.clear();
        self.prunit_secs = 0.0;
        self.core_secs = 0.0;
        self.checks = 0;
        self.alive_count = n;
    }

    /// Run `which` on `(g, f)` targeting `PD_k`, entirely in place. After
    /// this returns, [`compact`](Self::compact) or
    /// [`emit_shards`](Self::emit_shards) materialise the residue — the
    /// only CSR copies the planner ever makes.
    pub fn plan(&mut self, g: &Graph, f: &Filtration, k: usize, which: Reduction) -> Result<()> {
        f.check(g)?;
        self.reset(g);
        let k1 = (k + 1) as u32;
        match which {
            Reduction::None => {}
            Reduction::Coral => {
                let c = self.timed_core(g, k1);
                self.rounds.push(RoundStats {
                    prunit_removed: 0,
                    core_removed: c,
                });
            }
            Reduction::Prunit => {
                let p = self.timed_prunit(g, f);
                self.rounds.push(RoundStats {
                    prunit_removed: p,
                    core_removed: 0,
                });
            }
            Reduction::Combined => {
                let p = self.timed_prunit(g, f);
                let c = self.timed_core(g, k1);
                self.rounds.push(RoundStats {
                    prunit_removed: p,
                    core_removed: c,
                });
            }
            Reduction::FixedPoint => loop {
                let p = self.timed_prunit(g, f);
                let c = self.timed_core(g, k1);
                self.rounds.push(RoundStats {
                    prunit_removed: p,
                    core_removed: c,
                });
                if p + c == 0 {
                    break;
                }
            },
        }
        Ok(())
    }

    // ---------- stage passes ----------

    fn timed_prunit(&mut self, g: &Graph, f: &Filtration) -> usize {
        let (removed, secs) = {
            let t = Timer::start();
            let r = self.prunit_pass(g, f);
            (r, t.elapsed().as_secs_f64())
        };
        self.prunit_secs += secs;
        removed
    }

    fn timed_core(&mut self, g: &Graph, k1: u32) -> usize {
        let t = Timer::start();
        let removed =
            crate::kcore::peel_residue(g, k1, &mut self.alive, &mut self.deg, &mut self.peel);
        self.alive_count -= removed;
        self.core_secs += t.elapsed().as_secs_f64();
        removed
    }

    /// One PrunIT worklist run to its fixed point. Every round seeds the
    /// FIFO with all alive vertices in ascending id order — exactly the
    /// schedule `prune::prunit` uses — so the planner's removal set is
    /// bit-identical to the materializing reference's even where twin
    /// choices depend on processing order. (Seeding only the neighbours
    /// of core-killed vertices would be set-correct but can reorder twin
    /// resolution; the O(n) reseed is noise next to the pass itself.)
    fn prunit_pass(&mut self, g: &Graph, f: &Filtration) -> usize {
        debug_assert!(self.queue.is_empty());
        for v in 0..g.n() as u32 {
            if self.alive[v as usize] {
                self.in_queue[v as usize] = true;
                self.queue.push_back(v);
            }
        }
        let mut removed = 0usize;
        while let Some(u) = self.queue.pop_front() {
            self.in_queue[u as usize] = false;
            if !self.alive[u as usize] {
                continue;
            }
            self.checks += 1;
            let du = self.deg[u as usize];
            let mut dominated = false;
            for &v in g.neighbors(u) {
                if !self.alive[v as usize] || self.deg[v as usize] < du {
                    continue;
                }
                if f.admissible_removal(u, v) && self.dominates(g, u, v) {
                    dominated = true;
                    break;
                }
            }
            if dominated {
                self.alive[u as usize] = false;
                self.alive_count -= 1;
                removed += 1;
                for &w in g.neighbors(u) {
                    if self.alive[w as usize] {
                        self.deg[w as usize] -= 1;
                        if !self.in_queue[w as usize] {
                            self.in_queue[w as usize] = true;
                            self.queue.push_back(w);
                        }
                    }
                }
            }
        }
        removed
    }

    /// Does alive `v` dominate alive `u` in the residue? Caller
    /// guarantees adjacency and `deg[u] ≤ deg[v]`. Hybrid: sorted merge
    /// for low-degree `v`, neighbourhood bitset for hubs.
    fn dominates(&mut self, g: &Graph, u: u32, v: u32) -> bool {
        if g.degree(v) >= HUB_DEGREE {
            self.hub.load(g, v);
            for &x in g.neighbors(u) {
                if x == v || !self.alive[x as usize] {
                    continue;
                }
                if !self.hub.contains(x) {
                    return false;
                }
            }
            true
        } else {
            let nv = g.neighbors(v);
            let mut j = 0usize;
            for &x in g.neighbors(u) {
                if x == v || !self.alive[x as usize] {
                    continue;
                }
                while j < nv.len() && nv[j] < x {
                    j += 1;
                }
                if j == nv.len() || nv[j] != x {
                    return false;
                }
                j += 1;
            }
            true
        }
    }

    // ---------- emission (the single compaction) ----------

    /// Materialise the residue as one compacted `(Graph, Filtration,
    /// new id -> old id)` — the monolithic path's only CSR copy.
    pub fn compact(&mut self, g: &Graph, f: &Filtration) -> (Graph, Filtration, Vec<u32>) {
        let kept: Vec<u32> = (0..g.n() as u32)
            .filter(|&v| self.alive[v as usize])
            .collect();
        self.new_id.clear();
        self.new_id.resize(g.n(), u32::MAX);
        for (new, &old) in kept.iter().enumerate() {
            self.new_id[old as usize] = new as u32;
        }
        // residual degrees are maintained exactly → exact preallocation
        let cap: usize = kept.iter().map(|&v| self.deg[v as usize] as usize).sum();
        let mut offsets = Vec::with_capacity(kept.len() + 1);
        let mut neighbors = Vec::with_capacity(cap);
        offsets.push(0);
        for &old in &kept {
            for &w in g.neighbors(old) {
                if self.alive[w as usize] {
                    neighbors.push(self.new_id[w as usize]);
                }
            }
            offsets.push(neighbors.len());
        }
        let graph = Graph::from_csr_parts(offsets, neighbors);
        let filtration = f.restrict(&kept);
        (graph, filtration, kept)
    }

    /// Label the residue's connected components and materialise one
    /// compacted [`Shard`] per component — the sharded path's only CSR
    /// copies (exactly one per emitted shard, none in between stages).
    /// Component ids are ordered by smallest member, and within a shard
    /// vertex ids ascend with original ids, so mapped neighbour lists
    /// stay sorted — identical output to `decompose_filtered` applied to
    /// the compacted residue.
    pub fn emit_shards(&mut self, g: &Graph, f: &Filtration) -> Vec<Shard> {
        let n = g.n();
        self.labels.clear();
        self.labels.resize(n, u32::MAX);
        debug_assert!(self.stack.is_empty());
        let mut count = 0u32;
        for s in 0..n as u32 {
            if !self.alive[s as usize] || self.labels[s as usize] != u32::MAX {
                continue;
            }
            self.labels[s as usize] = count;
            self.stack.push(s);
            while let Some(v) = self.stack.pop() {
                for &w in g.neighbors(v) {
                    if self.alive[w as usize] && self.labels[w as usize] == u32::MAX {
                        self.labels[w as usize] = count;
                        self.stack.push(w);
                    }
                }
            }
            count += 1;
        }
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); count as usize];
        for v in 0..n as u32 {
            if self.alive[v as usize] {
                members[self.labels[v as usize] as usize].push(v);
            }
        }
        self.new_id.clear();
        self.new_id.resize(n, u32::MAX);
        for part in &members {
            for (i, &v) in part.iter().enumerate() {
                self.new_id[v as usize] = i as u32;
            }
        }
        members
            .into_iter()
            .map(|old_ids| {
                let cap: usize = old_ids
                    .iter()
                    .map(|&v| self.deg[v as usize] as usize)
                    .sum();
                let mut offsets = Vec::with_capacity(old_ids.len() + 1);
                let mut neighbors = Vec::with_capacity(cap);
                offsets.push(0);
                for &v in &old_ids {
                    for &w in g.neighbors(v) {
                        if self.alive[w as usize] {
                            neighbors.push(self.new_id[w as usize]);
                        }
                    }
                    offsets.push(neighbors.len());
                }
                let filtration = f.restrict(&old_ids);
                Shard {
                    graph: Graph::from_csr_parts(offsets, neighbors),
                    filtration,
                    kept_old_ids: old_ids,
                }
            })
            .collect()
    }

    // ---------- telemetry ----------

    /// Alive-vertex count of the residue.
    pub fn alive_count(&self) -> usize {
        self.alive_count
    }

    /// Residual edge count (half the alive degree sum).
    pub fn edges_alive(&self) -> usize {
        let twice: usize = self
            .alive
            .iter()
            .zip(&self.deg)
            .filter(|(&a, _)| a)
            .map(|(_, &d)| d as usize)
            .sum();
        twice / 2
    }

    /// Alive mask over original vertex ids.
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Per-round removal counts of the latest plan.
    pub fn rounds(&self) -> &[RoundStats] {
        &self.rounds
    }

    /// Seconds spent in PrunIT passes (latest plan).
    pub fn prunit_secs(&self) -> f64 {
        self.prunit_secs
    }

    /// Seconds spent in core peels (latest plan).
    pub fn core_secs(&self) -> f64 {
        self.core_secs
    }

    /// PrunIT worklist pops (latest plan) — the work-done proxy reported
    /// by `prune::prunit` as `checks`.
    pub fn checks(&self) -> usize {
        self.checks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::homology::persistence_diagrams;
    use crate::prune::prunit;
    use crate::reduce::coral_reduce;

    fn ws_residue(g: &Graph, f: &Filtration, k: usize, which: Reduction) -> Vec<u32> {
        let mut ws = ReductionWorkspace::new();
        ws.plan(g, f, k, which).unwrap();
        (0..g.n() as u32).filter(|&v| ws.alive()[v as usize]).collect()
    }

    #[test]
    fn prunit_plan_matches_materializing_prunit() {
        let mut rng = crate::util::Rng::new(12);
        for _ in 0..20 {
            let n = rng.range(4, 60);
            let g = gen::erdos_renyi(n, 0.2, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let kept = ws_residue(&g, &f, 1, Reduction::Prunit);
            let r = prunit(&g, &f).unwrap();
            assert_eq!(kept, r.kept_old_ids, "n={n}");
        }
    }

    #[test]
    fn coral_plan_matches_materializing_core() {
        let mut rng = crate::util::Rng::new(13);
        for _ in 0..20 {
            let n = rng.range(4, 60);
            let g = gen::erdos_renyi(n, 0.15, rng.next_u64());
            let f = Filtration::degree(&g);
            for k in 1..=2 {
                let kept = ws_residue(&g, &f, k, Reduction::Coral);
                let r = coral_reduce(&g, &f, k).unwrap();
                assert_eq!(kept, r.kept_old_ids, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn hub_path_agrees_with_merge_path_on_a_star_of_stars() {
        // hub 0 with 200 leaves (degree ≥ HUB_DEGREE forces the bitset
        // path), plus clique decorations to exercise real subset checks
        let mut edges: Vec<(u32, u32)> = (1..=200).map(|v| (0u32, v)).collect();
        edges.extend([(1, 2), (2, 3), (1, 3), (0, 201), (201, 1)]);
        let g = Graph::from_edges(202, &edges);
        let f = Filtration::degree_superlevel(&g);
        let kept = ws_residue(&g, &f, 1, Reduction::Prunit);
        let r = prunit(&g, &f).unwrap();
        assert_eq!(kept, r.kept_old_ids);
        assert!(g.degree(0) as usize >= HUB_DEGREE);
    }

    #[test]
    fn fixed_point_leaves_nothing_to_remove() {
        let mut rng = crate::util::Rng::new(14);
        for _ in 0..10 {
            let n = rng.range(6, 50);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let mut ws = ReductionWorkspace::new();
            ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
            let (h, fh, _) = ws.compact(&g, &f);
            // no admissible dominated vertex, and min degree ≥ 2
            for u in 0..h.n() as u32 {
                assert!(h.degree(u) >= 2, "vertex {u} below core threshold");
                assert!(
                    crate::prune::find_dominator(&h, &fh, u).is_none(),
                    "vertex {u} still prunable"
                );
            }
            // last round removed nothing
            let last = ws.rounds().last().unwrap();
            assert_eq!(last.prunit_removed + last.core_removed, 0);
        }
    }

    #[test]
    fn fixed_point_pd1_exact_on_cycle_with_tail() {
        // cycle 0..6 + pendant path: FixedPoint peels the tail (core) and
        // whatever domination appears, PD_1 must survive untouched
        let mut edges: Vec<(u32, u32)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
        edges.push((0, 6));
        edges.push((6, 7));
        let g = Graph::from_edges(8, &edges);
        let f = Filtration::degree(&g);
        let mut ws = ReductionWorkspace::new();
        ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
        let (h, fh, _) = ws.compact(&g, &f);
        let before = persistence_diagrams(&g, &f, 1);
        let after = persistence_diagrams(&h, &fh, 1);
        assert!(before[1].same_as(&after[1], 1e-12));
    }

    #[test]
    fn emit_shards_equals_decompose_of_compacted_residue() {
        let mut rng = crate::util::Rng::new(15);
        for _ in 0..12 {
            let n = rng.range(6, 50);
            let g = gen::erdos_renyi(n, 0.08, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let mut ws = ReductionWorkspace::new();
            ws.plan(&g, &f, 1, Reduction::Combined).unwrap();
            let shards = ws.emit_shards(&g, &f);
            let (h, fh, kept) = ws.compact(&g, &f);
            let reference = crate::graph::decompose::decompose_filtered(&h, &fh);
            assert_eq!(shards.len(), reference.len());
            for (s, r) in shards.iter().zip(&reference) {
                assert_eq!(s.graph, r.graph);
                assert_eq!(s.filtration, r.filtration);
                // planner ids are original; reference ids go through `kept`
                let via_kept: Vec<u32> =
                    r.kept_old_ids.iter().map(|&m| kept[m as usize]).collect();
                assert_eq!(s.kept_old_ids, via_kept);
            }
        }
    }

    #[test]
    fn workspace_reuse_across_graphs_is_clean() {
        let mut ws = ReductionWorkspace::new();
        let specs: [(usize, f64, u64); 4] =
            [(40, 0.2, 1), (7, 0.5, 2), (120, 0.05, 3), (40, 0.2, 1)];
        let mut first_run: Option<Vec<u32>> = None;
        for (i, &(n, p, seed)) in specs.iter().enumerate() {
            let g = gen::erdos_renyi(n, p, seed);
            let f = Filtration::degree_superlevel(&g);
            ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
            let (_, _, kept) = ws.compact(&g, &f);
            assert_eq!(ws.alive_count(), kept.len());
            if i == 0 {
                first_run = Some(kept);
            } else if i == 3 {
                assert_eq!(kept, first_run.clone().unwrap(), "reuse must be deterministic");
            }
        }
    }

    #[test]
    fn plan_rejects_mismatched_filtration() {
        let g = gen::cycle(5);
        let f = Filtration::constant(3);
        let mut ws = ReductionWorkspace::new();
        assert!(ws.plan(&g, &f, 1, Reduction::Combined).is_err());
    }

    #[test]
    fn telemetry_accounts_for_all_removals() {
        let g = gen::barabasi_albert(300, 2, 5);
        let f = Filtration::degree_superlevel(&g);
        let mut ws = ReductionWorkspace::new();
        ws.plan(&g, &f, 1, Reduction::FixedPoint).unwrap();
        let removed_by_rounds: usize = ws
            .rounds()
            .iter()
            .map(|r| r.prunit_removed + r.core_removed)
            .sum();
        assert_eq!(removed_by_rounds, g.n() - ws.alive_count());
        assert!(ws.rounds().len() <= removed_by_rounds + 1);
        assert!(ws.checks() > 0);
    }
}
