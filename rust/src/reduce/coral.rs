//! CoralTDA (Theorem 2): `PD_j(G, f) = PD_j(G^{k+1}, f)` for all `j ≥ k`.
//!
//! To compute the k-th persistence diagram it suffices to take the
//! (k+1)-core and *restrict* (never recompute — Remark 1) the filtering
//! function to the surviving vertices.

use crate::complex::Filtration;
use crate::error::Result;
use crate::graph::Graph;
use crate::kcore::kcore_subgraph;

/// Result of a CoralTDA reduction targeting `PD_k`.
#[derive(Clone, Debug)]
pub struct CoralResult {
    /// The (k+1)-core subgraph.
    pub graph: Graph,
    /// `new id -> old id` of surviving vertices.
    pub kept_old_ids: Vec<u32>,
    /// Filtration restricted to the core (original values).
    pub filtration: Filtration,
    /// The homology dimension this reduction is exact for (j ≥ k).
    pub k: usize,
}

/// Reduce `(G, f)` to its (k+1)-core for computing `PD_j`, `j ≥ k`.
///
/// Errors with [`crate::error::Error::FiltrationMismatch`] when `f` does
/// not match `g`'s order (the pre-planner `expect` panic is gone).
pub fn coral_reduce(g: &Graph, f: &Filtration, k: usize) -> Result<CoralResult> {
    f.check(g)?;
    let (core, ids) = kcore_subgraph(g, k + 1);
    let filtration = f.restrict(&ids);
    Ok(CoralResult {
        graph: core,
        kept_old_ids: ids,
        filtration,
        k,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::homology::persistence_diagrams;

    #[test]
    fn coral_for_pd1_uses_2core() {
        // BA with m=1 is a tree: its 2-core is empty → PD_1 trivial.
        let g = gen::barabasi_albert(40, 1, 2);
        let f = Filtration::degree(&g);
        let r = coral_reduce(&g, &f, 1).unwrap();
        assert_eq!(r.graph.n(), 0, "trees have empty 2-core");
        let pd = persistence_diagrams(&g, &f, 1);
        assert!(pd[1].is_trivial(), "tree PD_1 must be trivial, matching the empty core");
    }

    #[test]
    fn theorem2_on_cycle_with_tail() {
        // cycle 0..5 plus tail 6-7: 2-core is the cycle alone.
        let mut edges: Vec<(u32, u32)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
        edges.push((0, 6));
        edges.push((6, 7));
        let g = Graph::from_edges(8, &edges);
        let f = Filtration::degree(&g);
        let r = coral_reduce(&g, &f, 1).unwrap();
        assert_eq!(r.graph.n(), 6);
        let before = persistence_diagrams(&g, &f, 1);
        let after = persistence_diagrams(&r.graph, &r.filtration, 1);
        assert!(before[1].same_as(&after[1], 1e-9), "{} vs {}", before[1], after[1]);
    }

    #[test]
    fn restriction_keeps_original_degree_values() {
        // The tail vertex 6 contributes to degree(0)=3 in G; after coral
        // reduction vertex 0 keeps f=3 even though its core degree is 2.
        let mut edges: Vec<(u32, u32)> = (0..6).map(|i| (i, (i + 1) % 6)).collect();
        edges.push((0, 6));
        let g = Graph::from_edges(7, &edges);
        let f = Filtration::degree(&g);
        let r = coral_reduce(&g, &f, 1).unwrap();
        let new0 = r.kept_old_ids.iter().position(|&o| o == 0).unwrap();
        assert_eq!(r.filtration.value(new0 as u32), 3.0, "Remark 1: keep original f");
        assert_eq!(r.graph.degree(new0 as u32), 2);
    }

    #[test]
    fn theorem2_random_graphs_pd_equal_above_k() {
        let mut rng = crate::util::Rng::new(31);
        for _ in 0..8 {
            let n = rng.range(6, 20);
            let g = gen::erdos_renyi(n, 0.4, rng.next_u64());
            let f = Filtration::degree(&g);
            for k in 1..=2usize {
                let r = coral_reduce(&g, &f, k).unwrap();
                let before = persistence_diagrams(&g, &f, 2);
                let after = persistence_diagrams(&r.graph, &r.filtration, 2);
                for j in k..=2 {
                    assert!(
                        before[j].same_as(&after[j], 1e-9),
                        "PD_{j} via {}-core: {} vs {}",
                        k + 1,
                        before[j],
                        after[j]
                    );
                }
            }
        }
    }

    #[test]
    fn empty_graph_reduces_to_empty() {
        let g = Graph::empty(0);
        let f = Filtration::constant(0);
        let r = coral_reduce(&g, &f, 3).unwrap();
        assert_eq!(r.graph.n(), 0);
    }
}
