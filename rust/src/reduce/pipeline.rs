//! The combined reduction pipeline (§5 end):
//! `PD_k(G) = PD_k(G') = PD_k((G')^{k+1})` — PrunIT first (valid in every
//! dimension), then the (k+1)-core of the pruned graph — plus the
//! fixed-point alternation of the two (Choi et al. 2023 show iterating
//! complementary reductions compounds the savings; each stage is exact
//! for `PD_j`, `j ≥ k`, hence so is any finite alternation).
//!
//! The production path runs on the zero-copy [`planner`](super::planner):
//! all stages execute in place on the original CSR and the reduced
//! instance is compacted exactly once — see [`combined_with`]. The old
//! materializing composition survives as
//! [`combined_with_materializing`], the differential reference for tests
//! and the `planner_scaling` bench.

use crate::complex::ComplexWorkspace;
use crate::complex::Filtration;
use crate::error::Result;
use crate::graph::Graph;
use crate::homology::sharded::{all_shard_diagrams_ph, merge_shard_diagrams};
use crate::homology::{pd0, persistence_diagrams_ph, Diagram};
use crate::prune::prunit;
use crate::util::Timer;

use super::coral::coral_reduce;
use super::planner::ReductionWorkspace;

/// Which reduction(s) to apply before PH.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduction {
    /// No reduction — the baseline every paper experiment compares against.
    None,
    /// CoralTDA only (Thm 2; exact for PD_j, j ≥ k).
    Coral,
    /// PrunIT only (Thm 7; exact in every dimension).
    Prunit,
    /// PrunIT then CoralTDA, one round each (§5 end; exact for PD_j, j ≥ k).
    Combined,
    /// Alternate PrunIT and the (k+1)-core to a mutual fixed point
    /// (exact for PD_j, j ≥ k; never keeps more vertices than Combined).
    FixedPoint,
}

impl Reduction {
    pub fn name(&self) -> &'static str {
        match self {
            Reduction::None => "none",
            Reduction::Coral => "coral",
            Reduction::Prunit => "prunit",
            Reduction::Combined => "prunit+coral",
            Reduction::FixedPoint => "fixed-point",
        }
    }
}

/// Removal counts of one PrunIT⇄core round of the planner, plus the
/// domination-kernel and parallelism census of that round's frontier
/// sweeps.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RoundStats {
    pub prunit_removed: usize,
    pub core_removed: usize,
    /// frontier sweep rounds this pass ran on the sorted-merge kernel
    pub merge_rounds: usize,
    /// frontier sweep rounds this pass ran on the u64-block kernel
    pub bitset_rounds: usize,
    /// frontier sweep rounds this pass fanned out over > 1 thread.
    /// Under the adaptive thread policy this is timing-dependent
    /// telemetry (it may differ between runs; the residue never does)
    pub par_rounds: usize,
}

/// Bookkeeping for the paper's reduction-percentage metrics plus planner
/// telemetry (per-stage wall times, per-round removal counts). The report
/// no longer owns the reduced instance — the planner compacts exactly
/// once, into [`Reduced`] on the monolithic path or per-shard on the
/// sharded path.
#[derive(Clone, Debug)]
pub struct ReductionReport {
    pub vertices_before: usize,
    pub edges_before: usize,
    pub vertices_after: usize,
    pub edges_after: usize,
    /// total reduction wall time (stages + compaction, excluding PH)
    pub reduce_secs: f64,
    /// seconds in PrunIT passes
    pub prunit_secs: f64,
    /// seconds in (k+1)-core peels
    pub core_secs: f64,
    /// seconds compacting the residue (whole-graph or per shard)
    pub compact_secs: f64,
    /// removal counts per PrunIT⇄core round (single round for
    /// Coral/Prunit/Combined; one entry per alternation for FixedPoint)
    pub rounds: Vec<RoundStats>,
    /// PrunIT frontier sweep rounds summed over all passes — schedule
    /// telemetry, identical at every `--prune-threads` setting
    pub prunit_rounds: usize,
    pub which: Reduction,
    /// Vertex count per connected component of the reduced graph, filled
    /// by the sharded pipeline ([`pd_sharded`]); empty when the monolithic
    /// path ran.
    pub shard_sizes: Vec<usize>,
    /// Boundary-matrix reduction (PH stage) wall time; 0 until a PD
    /// entry point ran on this report.
    pub ph_secs: f64,
    /// Persistence pairs emitted by the chunked engine's apparent-pair
    /// prepass without any column additions (0 for standard/twist).
    pub ph_apparent_pairs: usize,
    /// Persistence pairs found by full column reduction.
    pub ph_reduced_pairs: usize,
}

impl ReductionReport {
    /// `100·(|V| − |V'|)/|V|` (paper §6).
    pub fn vertex_reduction_pct(&self) -> f64 {
        crate::util::table::reduction_pct(self.vertices_before, self.vertices_after)
    }

    /// `100·(|E| − |E'|)/|E|`.
    pub fn edge_reduction_pct(&self) -> f64 {
        crate::util::table::reduction_pct(self.edges_before, self.edges_after)
    }

    /// Vertices removed by the reduction.
    pub fn removed(&self) -> usize {
        self.vertices_before - self.vertices_after
    }

    /// Number of PrunIT⇄core rounds the planner ran.
    pub fn rounds_run(&self) -> usize {
        self.rounds.len()
    }

    /// Frontier sweep rounds that ran on the sorted-merge kernel, summed
    /// over all PrunIT passes.
    pub fn merge_kernel_rounds(&self) -> usize {
        self.rounds.iter().map(|r| r.merge_rounds).sum()
    }

    /// Frontier sweep rounds that ran on the u64-block kernel, summed
    /// over all PrunIT passes.
    pub fn bitset_kernel_rounds(&self) -> usize {
        self.rounds.iter().map(|r| r.bitset_rounds).sum()
    }

    /// Frontier sweep rounds that fanned out over > 1 thread, summed
    /// over all PrunIT passes (always 0 for the materializing
    /// reference, whose PrunIT is sequential).
    pub fn par_kernel_rounds(&self) -> usize {
        self.rounds.iter().map(|r| r.par_rounds).sum()
    }

    /// Number of shards the reduced graph split into (0 = not sharded).
    pub fn shard_count(&self) -> usize {
        self.shard_sizes.len()
    }

    /// Largest shard order — the quantity that bounds sharded PH cost
    /// (the cubic reduction runs per shard, not on Σnᵢ).
    pub fn largest_shard(&self) -> usize {
        self.shard_sizes.iter().copied().max().unwrap_or(0)
    }
}

/// A reduced instance: the planner's single compaction plus its report.
#[derive(Clone, Debug)]
pub struct Reduced {
    pub graph: Graph,
    /// The filtration restricted to survivors (original values; Rmk 1).
    pub filtration: Filtration,
    /// composition of old-id mappings: `new id -> original id` (ascending)
    pub kept_old_ids: Vec<u32>,
    pub report: ReductionReport,
}

impl Reduced {
    pub fn vertex_reduction_pct(&self) -> f64 {
        self.report.vertex_reduction_pct()
    }

    pub fn edge_reduction_pct(&self) -> f64 {
        self.report.edge_reduction_pct()
    }
}

fn report_from_ws(
    ws: &ReductionWorkspace,
    g: &Graph,
    which: Reduction,
    reduce_secs: f64,
    compact_secs: f64,
) -> ReductionReport {
    ReductionReport {
        vertices_before: g.n(),
        edges_before: g.m(),
        vertices_after: ws.alive_count(),
        edges_after: ws.edges_alive(),
        reduce_secs,
        prunit_secs: ws.prunit_secs(),
        core_secs: ws.core_secs(),
        compact_secs,
        rounds: ws.rounds().to_vec(),
        prunit_rounds: ws.frontier_rounds(),
        which,
        shard_sizes: Vec::new(),
        ph_secs: 0.0,
        ph_apparent_pairs: 0,
        ph_reduced_pairs: 0,
    }
}

/// Apply a reduction targeting `PD_k` on the zero-copy planner, with a
/// fresh workspace. Hot loops (the coordinator pool, the sharded
/// pipeline) should hold one [`ReductionWorkspace`] per worker and call
/// [`combined_with_ws`] instead.
pub fn combined_with(g: &Graph, f: &Filtration, k: usize, which: Reduction) -> Result<Reduced> {
    combined_with_ws(&mut ReductionWorkspace::new(), g, f, k, which)
}

/// [`combined_with`] reusing a caller-held workspace: all stages run in
/// place on `g`'s CSR; the reduced graph is compacted exactly once.
pub fn combined_with_ws(
    ws: &mut ReductionWorkspace,
    g: &Graph,
    f: &Filtration,
    k: usize,
    which: Reduction,
) -> Result<Reduced> {
    let total = Timer::start();
    ws.plan(g, f, k, which)?;
    let ((graph, filtration, kept_old_ids), compact_secs) = Timer::time(|| ws.compact(g, f));
    let report = report_from_ws(ws, g, which, total.elapsed().as_secs_f64(), compact_secs);
    Ok(Reduced {
        graph,
        filtration,
        kept_old_ids,
        report,
    })
}

/// The default full pipeline (PrunIT + CoralTDA) targeting `PD_k`.
pub fn combined(g: &Graph, f: &Filtration, k: usize) -> Result<Reduced> {
    combined_with(g, f, k, Reduction::Combined)
}

/// The pre-planner pipeline: each stage materialises a fresh
/// `Graph`/`Filtration` (`prunit` → `coral_reduce` → compose id maps).
/// Kept as the differential reference the planner is property-tested
/// against, and as the baseline side of `benches/planner_scaling.rs`.
pub fn combined_with_materializing(
    g: &Graph,
    f: &Filtration,
    k: usize,
    which: Reduction,
) -> Result<Reduced> {
    let vertices_before = g.n();
    let edges_before = g.m();
    let mut rounds = Vec::new();
    let mut prunit_rounds = 0usize;
    let total = Timer::start();
    let (graph, filtration, kept) = match which {
        Reduction::None => (g.clone(), f.clone(), (0..g.n() as u32).collect::<Vec<_>>()),
        Reduction::Coral => {
            let r = coral_reduce(g, f, k)?;
            rounds.push(RoundStats {
                prunit_removed: 0,
                core_removed: vertices_before - r.graph.n(),
                merge_rounds: 0,
                bitset_rounds: 0,
                par_rounds: 0,
            });
            (r.graph, r.filtration, r.kept_old_ids)
        }
        Reduction::Prunit => {
            let r = prunit(g, f)?;
            // the materializing reference runs the sequential merge-walk
            // prunit, so every frontier round counts as a merge round
            rounds.push(RoundStats {
                prunit_removed: r.removed,
                core_removed: 0,
                merge_rounds: r.rounds,
                bitset_rounds: 0,
                par_rounds: 0,
            });
            prunit_rounds += r.rounds;
            (r.graph, r.filtration, r.kept_old_ids)
        }
        Reduction::Combined => {
            let p = prunit(g, f)?;
            let c = coral_reduce(&p.graph, &p.filtration, k)?;
            rounds.push(RoundStats {
                prunit_removed: p.removed,
                core_removed: p.graph.n() - c.graph.n(),
                merge_rounds: p.rounds,
                bitset_rounds: 0,
                par_rounds: 0,
            });
            prunit_rounds += p.rounds;
            let ids = c
                .kept_old_ids
                .iter()
                .map(|&mid| p.kept_old_ids[mid as usize])
                .collect();
            (c.graph, c.filtration, ids)
        }
        Reduction::FixedPoint => {
            let mut graph = g.clone();
            let mut filtration = f.clone();
            let mut ids: Vec<u32> = (0..g.n() as u32).collect();
            loop {
                let p = prunit(&graph, &filtration)?;
                let c = coral_reduce(&p.graph, &p.filtration, k)?;
                let round = RoundStats {
                    prunit_removed: p.removed,
                    core_removed: p.graph.n() - c.graph.n(),
                    merge_rounds: p.rounds,
                    bitset_rounds: 0,
                    par_rounds: 0,
                };
                rounds.push(round);
                prunit_rounds += p.rounds;
                ids = c
                    .kept_old_ids
                    .iter()
                    .map(|&mid| ids[p.kept_old_ids[mid as usize] as usize])
                    .collect();
                graph = c.graph;
                filtration = c.filtration;
                if round.prunit_removed + round.core_removed == 0 {
                    break;
                }
            }
            (graph, filtration, ids)
        }
    };
    let report = ReductionReport {
        vertices_before,
        edges_before,
        vertices_after: graph.n(),
        edges_after: graph.m(),
        reduce_secs: total.elapsed().as_secs_f64(),
        prunit_secs: 0.0,
        core_secs: 0.0,
        compact_secs: 0.0,
        rounds,
        prunit_rounds,
        which,
        shard_sizes: Vec::new(),
        ph_secs: 0.0,
        ph_apparent_pairs: 0,
        ph_reduced_pairs: 0,
    };
    Ok(Reduced {
        graph,
        filtration,
        kept_old_ids: kept,
        report,
    })
}

/// End-to-end: reduce then compute diagrams `PD_0..PD_k` on the reduced
/// instance. For `Coral`/`Combined`/`FixedPoint` only `PD_k` (and above)
/// are exact; for `Prunit`/`None` every returned diagram is exact.
pub fn pd_with_reduction(
    g: &Graph,
    f: &Filtration,
    k: usize,
    which: Reduction,
) -> Result<(Vec<Diagram>, ReductionReport)> {
    pd_with_reduction_ws(&mut ReductionWorkspace::new(), g, f, k, which)
}

/// [`pd_with_reduction`] reusing a caller-held planner workspace — the
/// entry point that honours a configured
/// [`ReductionWorkspace::set_prune_threads`] (the CLI's
/// `--prune-threads`) and [`ReductionWorkspace::set_ph`] (the CLI's
/// `--ph-algorithm` / `--ph-threads`; the chunked local phase runs on
/// the workspace's own thread team). The PH stage's wall time and
/// apparent-vs-reduced pair split land in the report.
pub fn pd_with_reduction_ws(
    ws: &mut ReductionWorkspace,
    g: &Graph,
    f: &Filtration,
    k: usize,
    which: Reduction,
) -> Result<(Vec<Diagram>, ReductionReport)> {
    let mut red = combined_with_ws(ws, g, f, k, which)?;
    // the planner's token (a none token unless the coordinator installed
    // a deadline) carries into the cubic PH stage
    let cancel = ws.cancel_token().clone();
    let ph = ws.ph();
    let timer = Timer::start();
    let (diagrams, stats) = persistence_diagrams_ph(
        &mut ComplexWorkspace::new(),
        &red.graph,
        &red.filtration,
        k,
        &ph,
        ws.ph_team(),
        &cancel,
    )?;
    red.report.ph_secs = timer.elapsed().as_secs_f64();
    red.report.ph_apparent_pairs = stats.apparent_pairs;
    red.report.ph_reduced_pairs = stats.reduced_pairs;
    Ok((diagrams, red.report))
}

/// Component-sharded end-to-end pipeline: plan the reduction in place,
/// emit one compacted shard per connected component of the residue (the
/// only CSR copies on this path), run PH per shard on up to `workers`
/// std threads, and merge the diagrams exactly (PDs are additive over
/// disjoint unions — see `homology::sharded`).
///
/// Exactness matches [`pd_with_reduction`]: for
/// `Coral`/`Combined`/`FixedPoint` only `PD_k` (and above) is exact; for
/// `Prunit`/`None` every returned diagram is exact. Sharding itself never
/// changes any diagram. The report records the shard census
/// (`shard_sizes`).
pub fn pd_sharded(
    g: &Graph,
    f: &Filtration,
    k: usize,
    which: Reduction,
    workers: usize,
) -> Result<(Vec<Diagram>, ReductionReport)> {
    pd_sharded_with(&mut ReductionWorkspace::new(), g, f, k, which, workers)
}

/// [`pd_sharded`] reusing a caller-held planner workspace. PD₀-only
/// requests (`k == 0`) skip shard emission entirely and run the
/// union-find elder rule on the compacted residue — no boundary matrix
/// (or shard CSR set) is ever built for them.
pub fn pd_sharded_with(
    ws: &mut ReductionWorkspace,
    g: &Graph,
    f: &Filtration,
    k: usize,
    which: Reduction,
    workers: usize,
) -> Result<(Vec<Diagram>, ReductionReport)> {
    if k == 0 {
        let red = combined_with_ws(ws, g, f, 0, which)?;
        let (diagrams, ph_secs) = Timer::time(|| vec![pd0(&red.graph, &red.filtration)]);
        let mut report = red.report;
        report.ph_secs = ph_secs;
        return Ok((diagrams, report));
    }
    let total = Timer::start();
    ws.plan(g, f, k, which)?;
    let (shards, emit_secs) = Timer::time(|| ws.emit_shards(g, f));
    let mut report = report_from_ws(ws, g, which, total.elapsed().as_secs_f64(), emit_secs);
    report.shard_sizes = shards.iter().map(|s| s.graph.n()).collect();
    let cancel = ws.cancel_token().clone();
    let ph = ws.ph();
    let timer = Timer::start();
    let (per_shard, stats) = all_shard_diagrams_ph(&shards, k, workers, &ph, &cancel)?;
    report.ph_secs = timer.elapsed().as_secs_f64();
    report.ph_apparent_pairs = stats.apparent_pairs;
    report.ph_reduced_pairs = stats.reduced_pairs;
    let diagrams = merge_shard_diagrams(&per_shard, k);
    Ok((diagrams, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::homology::persistence_diagrams;

    const ALL: [Reduction; 5] = [
        Reduction::None,
        Reduction::Coral,
        Reduction::Prunit,
        Reduction::Combined,
        Reduction::FixedPoint,
    ];

    #[test]
    fn combined_identity_statement_holds() {
        // PD_k(G) == PD_k((G')^{k+1}) on random graphs, k = 1 — and the
        // fixed-point alternation keeps the same guarantee.
        let mut rng = crate::util::Rng::new(77);
        for _ in 0..8 {
            let n = rng.range(6, 22);
            let g = gen::erdos_renyi(n, 0.35, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let base = persistence_diagrams(&g, &f, 1);
            for which in [Reduction::Combined, Reduction::FixedPoint] {
                let (red, report) = pd_with_reduction(&g, &f, 1, which).unwrap();
                assert!(
                    base[1].same_as(&red[1], 1e-9),
                    "PD_1 mismatch after {}: {} vs {}",
                    report.which.name(),
                    base[1],
                    red[1]
                );
            }
        }
    }

    #[test]
    fn reduction_percentages_sane() {
        let g = gen::barabasi_albert(120, 2, 5);
        let f = Filtration::degree_superlevel(&g);
        let r = combined(&g, &f, 1).unwrap();
        assert!(r.vertex_reduction_pct() >= 0.0 && r.vertex_reduction_pct() <= 100.0);
        assert!(r.edge_reduction_pct() <= 100.0);
        assert!(r.graph.n() <= g.n());
        assert_eq!(r.report.vertices_after, r.graph.n());
        assert_eq!(r.report.edges_after, r.graph.m());
    }

    #[test]
    fn none_reduction_is_identity() {
        let g = gen::cycle(7);
        let f = Filtration::degree(&g);
        let r = combined_with(&g, &f, 1, Reduction::None).unwrap();
        assert_eq!(r.graph, g);
        assert_eq!(r.vertex_reduction_pct(), 0.0);
        assert_eq!(r.kept_old_ids, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn mapping_composition_points_to_original() {
        let g = gen::barabasi_albert(60, 2, 8);
        let f = Filtration::degree_superlevel(&g);
        for which in [Reduction::Combined, Reduction::FixedPoint] {
            let r = combined_with(&g, &f, 1, which).unwrap();
            for (new, &old) in r.kept_old_ids.iter().enumerate() {
                assert_eq!(
                    r.filtration.value(new as u32),
                    f.value(old),
                    "restricted f must match original values"
                );
            }
        }
    }

    #[test]
    fn reduction_names() {
        assert_eq!(Reduction::Combined.name(), "prunit+coral");
        assert_eq!(Reduction::None.name(), "none");
        assert_eq!(Reduction::FixedPoint.name(), "fixed-point");
    }

    #[test]
    fn mismatched_filtration_is_a_typed_error() {
        let g = gen::cycle(5);
        let f = Filtration::constant(4);
        for which in ALL {
            assert!(
                matches!(
                    combined_with(&g, &f, 1, which),
                    Err(crate::error::Error::FiltrationMismatch { .. })
                ),
                "{} must surface FiltrationMismatch",
                which.name()
            );
        }
        assert!(pd_sharded(&g, &f, 1, Reduction::Combined, 2).is_err());
    }

    #[test]
    fn planner_matches_materializing_pipeline() {
        // the differential property: same reduced instance, same id maps
        let mut rng = crate::util::Rng::new(501);
        for _ in 0..10 {
            let n = rng.range(6, 40);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            for which in ALL {
                let a = combined_with(&g, &f, 1, which).unwrap();
                let b = combined_with_materializing(&g, &f, 1, which).unwrap();
                assert_eq!(a.graph, b.graph, "{}", which.name());
                assert_eq!(a.kept_old_ids, b.kept_old_ids, "{}", which.name());
                assert_eq!(a.filtration, b.filtration, "{}", which.name());
                assert_eq!(
                    a.report.prunit_rounds, b.report.prunit_rounds,
                    "{}: frontier schedule must agree",
                    which.name()
                );
            }
        }
    }

    #[test]
    fn fixed_point_never_keeps_more_than_combined() {
        let mut rng = crate::util::Rng::new(502);
        for _ in 0..10 {
            let n = rng.range(8, 60);
            let g = gen::erdos_renyi(n, 0.2, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let c = combined_with(&g, &f, 1, Reduction::Combined).unwrap();
            let fp = combined_with(&g, &f, 1, Reduction::FixedPoint).unwrap();
            assert!(fp.graph.n() <= c.graph.n());
            assert!(fp.report.removed() >= c.report.removed());
            assert!(fp.report.rounds_run() <= fp.report.removed() + 1);
        }
    }

    #[test]
    fn pd_sharded_matches_monolithic_pipeline() {
        // Full reduction matrix, FixedPoint included: mono and sharded
        // apply the identical reduction to the identical instance, so
        // their diagrams must agree in every computed dimension.
        let mut rng = crate::util::Rng::new(404);
        for _ in 0..6 {
            let n = rng.range(8, 24);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            for which in ALL {
                let (mono, _) = pd_with_reduction(&g, &f, 1, which).unwrap();
                let (shard, report) = pd_sharded(&g, &f, 1, which, 2).unwrap();
                assert_eq!(
                    report.shard_sizes.iter().sum::<usize>(),
                    report.vertices_after
                );
                for k in 0..=1 {
                    assert!(
                        mono[k].same_as(&shard[k], 1e-12),
                        "{} PD_{k}: {} vs {}",
                        which.name(),
                        mono[k],
                        shard[k]
                    );
                }
            }
        }
    }

    #[test]
    fn pd_sharded_coral_pd1_matches_unreduced_baseline() {
        // Theorem 2 through the sharded pipeline: coral's PD_1 equals the
        // unreduced PD_1 (the guarantee is k ≥ 1 only).
        let mut rng = crate::util::Rng::new(405);
        for _ in 0..6 {
            let n = rng.range(8, 22);
            let g = gen::erdos_renyi(n, 0.3, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let base = persistence_diagrams(&g, &f, 1);
            let (coral, _) = pd_sharded(&g, &f, 1, Reduction::Coral, 2).unwrap();
            assert!(
                base[1].same_as(&coral[1], 1e-12),
                "PD_1: {} vs {}",
                base[1],
                coral[1]
            );
        }
    }

    #[test]
    fn shard_report_defaults_empty_on_monolithic_path() {
        let g = gen::cycle(6);
        let f = Filtration::degree(&g);
        let r = combined(&g, &f, 1).unwrap();
        assert_eq!(r.report.shard_count(), 0);
        assert_eq!(r.report.largest_shard(), 0);
        assert_eq!(r.report.rounds_run(), 1);
    }
}
