//! The combined reduction pipeline (§5 end):
//! `PD_k(G) = PD_k(G') = PD_k((G')^{k+1})` — PrunIT first (valid in every
//! dimension), then the (k+1)-core of the pruned graph.

use crate::complex::Filtration;
use crate::graph::decompose::decompose_filtered;
use crate::graph::Graph;
use crate::homology::sharded::{all_shard_diagrams, merge_shard_diagrams};
use crate::homology::{persistence_diagrams, Diagram};
use crate::prune::prunit;
use crate::util::Timer;

use super::coral::coral_reduce;

/// Which reduction(s) to apply before PH.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduction {
    /// No reduction — the baseline every paper experiment compares against.
    None,
    /// CoralTDA only (Thm 2; exact for PD_j, j ≥ k).
    Coral,
    /// PrunIT only (Thm 7; exact in every dimension).
    Prunit,
    /// PrunIT then CoralTDA (§5 end; exact for PD_j, j ≥ k).
    Combined,
}

impl Reduction {
    pub fn name(&self) -> &'static str {
        match self {
            Reduction::None => "none",
            Reduction::Coral => "coral",
            Reduction::Prunit => "prunit",
            Reduction::Combined => "prunit+coral",
        }
    }
}

/// Output of a reduction: reduced instance plus bookkeeping for the
/// paper's reduction-percentage metrics.
#[derive(Clone, Debug)]
pub struct ReductionReport {
    pub graph: Graph,
    pub filtration: Filtration,
    /// composition of old-id mappings: `new id -> original id`
    pub kept_old_ids: Vec<u32>,
    pub vertices_before: usize,
    pub edges_before: usize,
    pub reduce_secs: f64,
    pub which: Reduction,
    /// Vertex count per connected component of the reduced graph, filled
    /// by the sharded pipeline ([`pd_sharded`]); empty when the monolithic
    /// path ran.
    pub shard_sizes: Vec<usize>,
}

impl ReductionReport {
    /// `100·(|V| − |V'|)/|V|` (paper §6).
    pub fn vertex_reduction_pct(&self) -> f64 {
        crate::util::table::reduction_pct(self.vertices_before, self.graph.n())
    }

    /// `100·(|E| − |E'|)/|E|`.
    pub fn edge_reduction_pct(&self) -> f64 {
        crate::util::table::reduction_pct(self.edges_before, self.graph.m())
    }

    /// Number of shards the reduced graph split into (0 = not sharded).
    pub fn shard_count(&self) -> usize {
        self.shard_sizes.len()
    }

    /// Largest shard order — the quantity that bounds sharded PH cost
    /// (the cubic reduction runs per shard, not on Σnᵢ).
    pub fn largest_shard(&self) -> usize {
        self.shard_sizes.iter().copied().max().unwrap_or(0)
    }
}

/// Apply a reduction targeting `PD_k`.
pub fn combined_with(g: &Graph, f: &Filtration, k: usize, which: Reduction) -> ReductionReport {
    let vertices_before = g.n();
    let edges_before = g.m();
    let ((graph, filtration, kept), secs) = Timer::time(|| match which {
        Reduction::None => (g.clone(), f.clone(), (0..g.n() as u32).collect::<Vec<_>>()),
        Reduction::Coral => {
            let r = coral_reduce(g, f, k);
            (r.graph, r.filtration, r.kept_old_ids)
        }
        Reduction::Prunit => {
            let r = prunit(g, f);
            (r.graph, r.filtration, r.kept_old_ids)
        }
        Reduction::Combined => {
            let p = prunit(g, f);
            let c = coral_reduce(&p.graph, &p.filtration, k);
            // compose mappings
            let ids = c
                .kept_old_ids
                .iter()
                .map(|&mid| p.kept_old_ids[mid as usize])
                .collect();
            (c.graph, c.filtration, ids)
        }
    });
    ReductionReport {
        graph,
        filtration,
        kept_old_ids: kept,
        vertices_before,
        edges_before,
        reduce_secs: secs,
        which,
        shard_sizes: Vec::new(),
    }
}

/// The default full pipeline (PrunIT + CoralTDA) targeting `PD_k`.
pub fn combined(g: &Graph, f: &Filtration, k: usize) -> ReductionReport {
    combined_with(g, f, k, Reduction::Combined)
}

/// End-to-end: reduce then compute diagrams `PD_0..PD_k` on the reduced
/// instance. For `Coral`/`Combined` only `PD_k` (and above) are exact;
/// for `Prunit`/`None` every returned diagram is exact.
pub fn pd_with_reduction(
    g: &Graph,
    f: &Filtration,
    k: usize,
    which: Reduction,
) -> (Vec<Diagram>, ReductionReport) {
    let report = combined_with(g, f, k, which);
    let diagrams = persistence_diagrams(&report.graph, &report.filtration, k);
    (diagrams, report)
}

/// Component-sharded end-to-end pipeline: reduce, split the reduced graph
/// into connected components, run PH per shard on up to `workers` std
/// threads, and merge the diagrams exactly (PDs are additive over
/// disjoint unions — see `homology::sharded`).
///
/// Exactness matches [`pd_with_reduction`]: for `Coral`/`Combined` only
/// `PD_k` (and above) is exact; for `Prunit`/`None` every returned
/// diagram is exact. Sharding itself never changes any diagram.
/// The report records the shard census (`shard_sizes`).
pub fn pd_sharded(
    g: &Graph,
    f: &Filtration,
    k: usize,
    which: Reduction,
    workers: usize,
) -> (Vec<Diagram>, ReductionReport) {
    let mut report = combined_with(g, f, k, which);
    let shards = decompose_filtered(&report.graph, &report.filtration);
    report.shard_sizes = shards.iter().map(|s| s.graph.n()).collect();
    let per_shard = all_shard_diagrams(&shards, k, workers);
    let diagrams = merge_shard_diagrams(&per_shard, k);
    (diagrams, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn combined_identity_statement_holds() {
        // PD_k(G) == PD_k((G')^{k+1}) on random graphs, k = 1.
        let mut rng = crate::util::Rng::new(77);
        for _ in 0..8 {
            let n = rng.range(6, 22);
            let g = gen::erdos_renyi(n, 0.35, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let base = persistence_diagrams(&g, &f, 1);
            let (red, report) = pd_with_reduction(&g, &f, 1, Reduction::Combined);
            assert!(
                base[1].same_as(&red[1], 1e-9),
                "PD_1 mismatch after {}: {} vs {}",
                report.which.name(),
                base[1],
                red[1]
            );
        }
    }

    #[test]
    fn reduction_percentages_sane() {
        let g = gen::barabasi_albert(120, 2, 5);
        let f = Filtration::degree_superlevel(&g);
        let r = combined(&g, &f, 1);
        assert!(r.vertex_reduction_pct() >= 0.0 && r.vertex_reduction_pct() <= 100.0);
        assert!(r.edge_reduction_pct() <= 100.0);
        assert!(r.graph.n() <= g.n());
    }

    #[test]
    fn none_reduction_is_identity() {
        let g = gen::cycle(7);
        let f = Filtration::degree(&g);
        let r = combined_with(&g, &f, 1, Reduction::None);
        assert_eq!(r.graph, g);
        assert_eq!(r.vertex_reduction_pct(), 0.0);
        assert_eq!(r.kept_old_ids, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn mapping_composition_points_to_original() {
        let g = gen::barabasi_albert(60, 2, 8);
        let f = Filtration::degree_superlevel(&g);
        let r = combined(&g, &f, 1);
        for (new, &old) in r.kept_old_ids.iter().enumerate() {
            assert_eq!(
                r.filtration.value(new as u32),
                f.value(old),
                "restricted f must match original values"
            );
        }
    }

    #[test]
    fn reduction_names() {
        assert_eq!(Reduction::Combined.name(), "prunit+coral");
        assert_eq!(Reduction::None.name(), "none");
    }

    #[test]
    fn pd_sharded_matches_monolithic_pipeline() {
        // Full reduction matrix, Coral included: mono and sharded apply
        // the identical reduction to the identical instance, so their
        // diagrams must agree in every computed dimension — in particular
        // PD_1, the dimension Coral's (k+1)-core targets.
        let mut rng = crate::util::Rng::new(404);
        for _ in 0..6 {
            let n = rng.range(8, 24);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            for which in [
                Reduction::None,
                Reduction::Coral,
                Reduction::Prunit,
                Reduction::Combined,
            ] {
                let (mono, _) = pd_with_reduction(&g, &f, 1, which);
                let (shard, report) = pd_sharded(&g, &f, 1, which, 2);
                assert_eq!(report.shard_count(), report.graph.components());
                assert_eq!(report.shard_sizes.iter().sum::<usize>(), report.graph.n());
                for k in 0..=1 {
                    assert!(
                        mono[k].same_as(&shard[k], 1e-12),
                        "{} PD_{k}: {} vs {}",
                        which.name(),
                        mono[k],
                        shard[k]
                    );
                }
            }
        }
    }

    #[test]
    fn pd_sharded_coral_pd1_matches_unreduced_baseline() {
        // Theorem 2 through the sharded pipeline: coral's PD_1 equals the
        // unreduced PD_1 (the guarantee is k ≥ 1 only).
        let mut rng = crate::util::Rng::new(405);
        for _ in 0..6 {
            let n = rng.range(8, 22);
            let g = gen::erdos_renyi(n, 0.3, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let base = persistence_diagrams(&g, &f, 1);
            let (coral, _) = pd_sharded(&g, &f, 1, Reduction::Coral, 2);
            assert!(
                base[1].same_as(&coral[1], 1e-12),
                "PD_1: {} vs {}",
                base[1],
                coral[1]
            );
        }
    }

    #[test]
    fn shard_report_defaults_empty_on_monolithic_path() {
        let g = gen::cycle(6);
        let f = Filtration::degree(&g);
        let r = combined(&g, &f, 1);
        assert_eq!(r.shard_count(), 0);
        assert_eq!(r.largest_shard(), 0);
    }
}
