//! The paper's reduction pipeline (S9): CoralTDA (Thm 2), PrunIT (Thm 7),
//! and their composition `PD_k(G) = PD_k((G')^{k+1})` (§5 end).

pub mod coral;
pub mod pipeline;

pub use coral::{coral_reduce, CoralResult};
pub use pipeline::{
    combined, combined_with, pd_sharded, pd_with_reduction, Reduction, ReductionReport,
};
