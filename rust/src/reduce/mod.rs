//! The paper's reduction pipeline (S9): CoralTDA (Thm 2), PrunIT (Thm 7),
//! their composition `PD_k(G) = PD_k((G')^{k+1})` (§5 end), and the
//! zero-copy planner that runs all stages in place on the original CSR
//! (`planner`), including the PrunIT⇄core fixed-point alternation.

pub mod coral;
pub mod pipeline;
pub mod planner;

pub use coral::{coral_reduce, CoralResult};
pub use pipeline::{
    combined, combined_with, combined_with_materializing, combined_with_ws, pd_sharded,
    pd_sharded_with, pd_with_reduction, pd_with_reduction_ws, Reduced, Reduction,
    ReductionReport, RoundStats,
};
pub use planner::{ParallelBackend, ReductionWorkspace, PAR_ADAPTIVE_MAX, PAR_FRONTIER_MIN};
