//! Job and result types for the batch coordinator.

use std::fmt;

use crate::complex::Filtration;
use crate::error::Error;
use crate::graph::Graph;
use crate::homology::{Diagram, PhConfig};
use crate::reduce::{Reduction, ReductionReport};

/// What to compute for one graph.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Highest homology dimension requested (PD_0..PD_max_k).
    pub max_k: usize,
    /// Which reduction to apply first.
    pub reduction: Reduction,
    /// Force component-sharded execution from the first attempt: peak
    /// complex size is bounded by the largest component instead of the
    /// whole graph. Diagrams are unchanged (sharding is an execution
    /// detail); the service's admission controller sets this when it
    /// degrades a job under CPU pressure.
    pub sharded: bool,
    /// Persistence-engine settings (algorithm, thread budget, chunk
    /// size). Diagrams are bit-identical at every setting, so the result
    /// cache deliberately ignores this field.
    pub ph: PhConfig,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            max_k: 1,
            reduction: Reduction::Combined,
            sharded: false,
            ph: PhConfig::default(),
        }
    }
}

/// One unit of work: a graph + filtration + spec.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub graph: Graph,
    pub filtration: Filtration,
    pub spec: JobSpec,
}

impl Job {
    pub fn new(id: u64, graph: Graph, filtration: Filtration, spec: JobSpec) -> Job {
        Job {
            id,
            graph,
            filtration,
            spec,
        }
    }

    /// Convenience: degree-superlevel filtration (always PrunIT-admissible).
    pub fn degree_superlevel(id: u64, graph: Graph, spec: JobSpec) -> Job {
        let filtration = Filtration::degree_superlevel(&graph);
        Job {
            id,
            graph,
            filtration,
            spec,
        }
    }
}

/// How a successful job result was obtained — first try, or after the
/// retry ladder escalated the reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobOutcome {
    /// The requested spec succeeded on the first attempt.
    Success,
    /// A retry succeeded after escalating the reduction (and, on the last
    /// attempt, forcing sharded execution). The diagrams are exact for
    /// `PD_j`, `j ≥ max_k` — stronger exactness the original spec may have
    /// carried (e.g. `Prunit` is exact in every dimension) is traded away
    /// for termination.
    Degraded {
        /// The reduction that actually ran.
        reduction: Reduction,
        /// Whether execution was forced through the component-sharded path.
        sharded: bool,
    },
}

impl JobOutcome {
    /// Whether this outcome is a degraded success.
    pub fn is_degraded(&self) -> bool {
        matches!(self, JobOutcome::Degraded { .. })
    }
}

/// Result of one job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub diagrams: Vec<Diagram>,
    pub reduction: ReductionReport,
    /// seconds spent in PH (excluding reduction, which is in `reduction`)
    pub ph_secs: f64,
    /// total wall seconds for the job on the worker (last attempt only)
    pub total_secs: f64,
    /// worker thread index that executed the job
    pub worker: usize,
    /// attempts consumed (1 = no retries were needed)
    pub attempts: u32,
    /// how the result was obtained (success vs degraded success)
    pub outcome: JobOutcome,
}

/// A job that exhausted its retry budget (or failed permanently): the
/// identity the scheduler routes to the caller and the journal, instead
/// of an anonymous `jobs_failed` increment.
#[derive(Debug)]
pub struct JobFailure {
    pub id: u64,
    /// attempts consumed before giving up
    pub attempts: u32,
    /// the final attempt's error
    pub error: Error,
}

impl fmt::Display for JobFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "job {} failed after {} attempt(s): {}",
            self.id, self.attempts, self.error
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn default_spec_is_combined_pd1() {
        let s = JobSpec::default();
        assert_eq!(s.max_k, 1);
        assert_eq!(s.reduction, Reduction::Combined);
    }

    #[test]
    fn degree_superlevel_constructor() {
        let j = Job::degree_superlevel(7, gen::star(5), JobSpec::default());
        assert_eq!(j.id, 7);
        assert_eq!(j.filtration.value(0), 4.0);
    }
}
