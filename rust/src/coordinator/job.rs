//! Job and result types for the batch coordinator.

use crate::complex::Filtration;
use crate::graph::Graph;
use crate::homology::Diagram;
use crate::reduce::{Reduction, ReductionReport};

/// What to compute for one graph.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Highest homology dimension requested (PD_0..PD_max_k).
    pub max_k: usize,
    /// Which reduction to apply first.
    pub reduction: Reduction,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            max_k: 1,
            reduction: Reduction::Combined,
        }
    }
}

/// One unit of work: a graph + filtration + spec.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub graph: Graph,
    pub filtration: Filtration,
    pub spec: JobSpec,
}

impl Job {
    pub fn new(id: u64, graph: Graph, filtration: Filtration, spec: JobSpec) -> Job {
        Job {
            id,
            graph,
            filtration,
            spec,
        }
    }

    /// Convenience: degree-superlevel filtration (always PrunIT-admissible).
    pub fn degree_superlevel(id: u64, graph: Graph, spec: JobSpec) -> Job {
        let filtration = Filtration::degree_superlevel(&graph);
        Job {
            id,
            graph,
            filtration,
            spec,
        }
    }
}

/// Result of one job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub diagrams: Vec<Diagram>,
    pub reduction: ReductionReport,
    /// seconds spent in PH (excluding reduction, which is in `reduction`)
    pub ph_secs: f64,
    /// total wall seconds for the job on the worker
    pub total_secs: f64,
    /// worker thread index that executed the job
    pub worker: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn default_spec_is_combined_pd1() {
        let s = JobSpec::default();
        assert_eq!(s.max_k, 1);
        assert_eq!(s.reduction, Reduction::Combined);
    }

    #[test]
    fn degree_superlevel_constructor() {
        let j = Job::degree_superlevel(7, gen::star(5), JobSpec::default());
        assert_eq!(j.id, 7);
        assert_eq!(j.filtration.value(0), 4.0);
    }
}
