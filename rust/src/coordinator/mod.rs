//! Batch coordinator (S12): the L3 runtime that schedules reduce+PD jobs
//! across a worker pool — the paper's §6.2 workload ("compute persistence
//! diagrams for *each vertex's* ego network in a 100k+ graph") is exactly
//! a large batch of small independent PH jobs.
//!
//! std-only implementation (tokio is not in the offline registry): a
//! bounded `sync_channel` job queue provides backpressure against the
//! producer, a `Mutex<Receiver>` fans jobs out to `workers` OS threads,
//! and results stream back over an unbounded channel. Metrics are atomic
//! counters suitable for live scraping.

pub mod job;
pub mod metrics;
pub mod pool;

pub use job::{Job, JobResult, JobSpec};
pub use metrics::Metrics;
pub use pool::{Coordinator, WorkerScratch};
