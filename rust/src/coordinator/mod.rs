//! Batch coordinator (S12): the L3 runtime that schedules reduce+PD jobs
//! across a worker pool — the paper's §6.2 workload ("compute persistence
//! diagrams for *each vertex's* ego network in a 100k+ graph") is exactly
//! a large batch of small independent PH jobs.
//!
//! The core layers:
//!
//! * [`scheduler`] — queueing and result streaming: a bounded
//!   `sync_channel` job queue provides backpressure against the producer,
//!   a `Mutex<Receiver>` fans jobs out to `workers` OS threads, and
//!   results stream back over an unbounded channel (std-only; tokio is
//!   not in the offline registry).
//! * [`worker`] — job execution: one [`Job`] in, one [`JobResult`] out,
//!   all allocation through a [`WorkerScratch`] — plus the fault
//!   tolerance harness: per-attempt deadlines ([`crate::util::CancelToken`]),
//!   panic isolation (`catch_unwind` per attempt), and retry with
//!   graceful degradation (each retry escalates the reduction, the last
//!   attempt shards).
//! * [`scratch`] — the size-tiered [`ScratchPool`]: scratches are
//!   bucketed by graph-order tier and checked out per job, so a
//!   100-vertex job never inherits (and re-initialises) the arenas a
//!   multi-million-vertex job grew.
//! * [`journal`] — the persistent batch journal: one flushed JSONL
//!   record per job event, replayed on restart so a killed batch resumes
//!   without recomputing completed jobs.
//! * [`faults`] (tests / `--features faults` only) — deterministic fault
//!   injection scripts driving the chaos suite.
//!
//! On top of the batch core sits the always-on service (`repro serve`):
//! [`serve`] wires [`admission`] (load shedding + degrade-under-pressure),
//! [`cache`] (content-addressed result reuse), the in-flight watchdog
//! ([`worker::InFlightRegistry`]), and a std-only `/healthz` + `/metrics`
//! endpoint around one long-lived scheduler invocation.
//!
//! Metrics are atomic counters suitable for live scraping.

pub mod admission;
pub mod cache;
#[cfg(any(test, feature = "faults"))]
pub mod faults;
pub mod job;
pub mod journal;
pub mod metrics;
pub mod scheduler;
pub mod scratch;
pub mod serve;
pub mod worker;

pub use admission::{AdmissionController, AdmissionDecision, AdmissionPolicy};
pub use cache::{job_key, CacheKey, CacheStats, CachedResult, ResultCache};
#[cfg(any(test, feature = "faults"))]
pub use faults::FaultPlan;
pub use job::{Job, JobFailure, JobOutcome, JobResult, JobSpec};
pub use journal::{Journal, JournalReplay};
pub use metrics::Metrics;
pub use scheduler::{BatchOutcome, Coordinator, ResumeReport};
pub use scratch::{top_tier_min_order, PooledScratch, ScratchPool};
pub use serve::{diagram_digest, install_signal_handlers, ServeOptions, ServeReport};
pub use worker::{degraded_spec, escalate, InFlightRegistry, WorkerScratch};
