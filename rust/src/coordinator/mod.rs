//! Batch coordinator (S12): the L3 runtime that schedules reduce+PD jobs
//! across a worker pool — the paper's §6.2 workload ("compute persistence
//! diagrams for *each vertex's* ego network in a 100k+ graph") is exactly
//! a large batch of small independent PH jobs.
//!
//! Three layers, three modules:
//!
//! * [`scheduler`] — queueing and result streaming: a bounded
//!   `sync_channel` job queue provides backpressure against the producer,
//!   a `Mutex<Receiver>` fans jobs out to `workers` OS threads, and
//!   results stream back over an unbounded channel (std-only; tokio is
//!   not in the offline registry).
//! * [`worker`] — pure job execution: one [`Job`] in, one [`JobResult`]
//!   out, all allocation through a [`WorkerScratch`].
//! * [`scratch`] — the size-tiered [`ScratchPool`]: scratches are
//!   bucketed by graph-order tier and checked out per job, so a
//!   100-vertex job never inherits (and re-initialises) the arenas a
//!   multi-million-vertex job grew.
//!
//! Metrics are atomic counters suitable for live scraping.

pub mod job;
pub mod metrics;
pub mod scheduler;
pub mod scratch;
pub mod worker;

pub use job::{Job, JobResult, JobSpec};
pub use metrics::Metrics;
pub use scheduler::Coordinator;
pub use scratch::{PooledScratch, ScratchPool};
pub use worker::WorkerScratch;
