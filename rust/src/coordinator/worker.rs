//! Worker-side execution: the reusable per-job scratch state, the body
//! that turns one [`Job`] into one [`JobResult`], and the fault-tolerant
//! attempt harness (deadline install, panic isolation, retry with
//! graceful degradation). Pure computation — queueing, backpressure, and
//! result streaming live in [`super::scheduler`], scratch reuse policy
//! in [`super::scratch`].

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::complex::ComplexWorkspace;
use crate::error::{Error, Result};
use crate::homology::persistence_diagrams_ph;
use crate::prune::DominationKernel;
use crate::reduce::{combined_with_ws, pd_sharded_with, Reduction, ReductionWorkspace};
use crate::util::{CancelToken, Rng, Timer};

#[cfg(any(test, feature = "faults"))]
use super::faults::FaultPlan;
use super::job::{Job, JobFailure, JobOutcome, JobResult};
use super::metrics::Metrics;
use super::scratch::ScratchPool;

/// Reusable execution state for one job at a time: complex arenas for PH
/// plus the zero-copy reduction planner's masks/degree arrays. The
/// scheduler's workers check one out of the size-tiered
/// [`super::scratch::ScratchPool`] per job (so arena sizes track job
/// sizes); single-threaded callers can hold one long-lived instance.
#[derive(Debug, Default)]
pub struct WorkerScratch {
    pub complex: ComplexWorkspace,
    pub reduce: ReductionWorkspace,
}

impl WorkerScratch {
    pub fn new() -> WorkerScratch {
        WorkerScratch::default()
    }
}

/// Escalate a reduction one rung on the degradation ladder: anything
/// short of the combined pipeline becomes `Combined`, and `Combined`
/// becomes the `FixedPoint` alternation (the strongest reduction the
/// planner offers, hence the cheapest downstream PH).
pub fn escalate(which: Reduction) -> Reduction {
    match which {
        Reduction::None | Reduction::Coral | Reduction::Prunit => Reduction::Combined,
        Reduction::Combined | Reduction::FixedPoint => Reduction::FixedPoint,
    }
}

/// The spec actually run for a given retry attempt (0-based): the base
/// reduction escalated once per prior failure, plus — on the final
/// attempt of a job that has already failed at least once — forced
/// component-sharded execution, which bounds peak complex size by the
/// largest component instead of the whole graph.
pub fn degraded_spec(base: Reduction, attempt: u32, last: bool) -> (Reduction, bool) {
    let mut which = base;
    for _ in 0..attempt {
        which = escalate(which);
    }
    (which, last && attempt > 0)
}

/// Execute one job: plan + compact the reduction and run PH, both into
/// the caller's scratch. `worker` is the executing thread's index,
/// recorded in the result for telemetry.
///
/// A filtration/graph mismatch surfaces as a typed error instead of the
/// pre-planner panic. Honors whatever [`CancelToken`] is installed in
/// `scratch.reduce` (none by default). The result reports one attempt
/// and [`JobOutcome::Success`]; the retry harness overwrites both.
pub fn execute_job(scratch: &mut WorkerScratch, job: &Job, worker: usize) -> Result<JobResult> {
    execute_attempt(scratch, job, worker, job.spec.reduction, job.spec.sharded)
}

/// One attempt of a job with an explicit (possibly degraded) reduction
/// and an optional forced-sharded execution path.
pub(crate) fn execute_attempt(
    scratch: &mut WorkerScratch,
    job: &Job,
    worker: usize,
    which: Reduction,
    sharded: bool,
) -> Result<JobResult> {
    let total = Timer::start();
    scratch.reduce.set_ph(job.spec.ph);
    if sharded {
        // Forced degraded path: per-component complexes bound peak memory
        // and each shard polls the same token, so deadlines still bite.
        let (diagrams, report) = pd_sharded_with(
            &mut scratch.reduce,
            &job.graph,
            &job.filtration,
            job.spec.max_k,
            which,
            1,
        )?;
        let total_secs = total.elapsed().as_secs_f64();
        let ph_secs = report.ph_secs;
        return Ok(JobResult {
            id: job.id,
            diagrams,
            reduction: report,
            ph_secs,
            total_secs,
            worker,
            attempts: 1,
            outcome: JobOutcome::Success,
        });
    }
    let mut red = combined_with_ws(
        &mut scratch.reduce,
        &job.graph,
        &job.filtration,
        job.spec.max_k,
        which,
    )?;
    let cancel = scratch.reduce.cancel_token().clone();
    let ph_cfg = scratch.reduce.ph();
    let ph = Timer::start();
    let (diagrams, stats) = persistence_diagrams_ph(
        &mut scratch.complex,
        &red.graph,
        &red.filtration,
        job.spec.max_k,
        &ph_cfg,
        scratch.reduce.ph_team(),
        &cancel,
    )?;
    let ph_secs = ph.elapsed().as_secs_f64();
    red.report.ph_secs = ph_secs;
    red.report.ph_apparent_pairs = stats.apparent_pairs;
    red.report.ph_reduced_pairs = stats.reduced_pairs;
    Ok(JobResult {
        id: job.id,
        diagrams,
        reduction: red.report,
        ph_secs,
        total_secs: total.elapsed().as_secs_f64(),
        worker,
        attempts: 1,
        outcome: JobOutcome::Success,
    })
}

/// Per-worker retry policy, derived from the coordinator config once per
/// batch.
#[derive(Clone, Debug)]
pub(crate) struct AttemptPolicy {
    /// retries after the first failure; attempts = `max_retries + 1`
    pub max_retries: usize,
    /// base backoff between attempts, doubled per retry (0 disables)
    pub backoff_ms: u64,
    /// per-attempt wall-clock deadline (≤ 0 disables)
    pub deadline_secs: f64,
    /// seed for the backoff jitter (mixed with job id and attempt)
    pub jitter_seed: u64,
    /// live attempt registry for the service watchdog (None outside serve)
    pub inflight: Option<Arc<InFlightRegistry>>,
    /// scripted faults for the chaos suite
    #[cfg(any(test, feature = "faults"))]
    pub faults: Option<Arc<FaultPlan>>,
}

/// Backoff before re-running a failed attempt (0-based `attempt`): the
/// deterministic exponential nominal (`backoff_ms << attempt`, capped at
/// six doublings) with seeded equal-jitter — half the nominal is kept
/// and the other half drawn uniformly from a [`Rng`] keyed on
/// `(seed, job_id, attempt)`. Concurrent retries decorrelate (no
/// thundering herd back into the queue) while staying fully reproducible
/// for a fixed seed. A zero base disables backoff entirely, which the
/// chaos suite relies on for determinism.
pub fn jittered_backoff_ms(backoff_ms: u64, attempt: u32, seed: u64, job_id: u64) -> u64 {
    if backoff_ms == 0 {
        return 0;
    }
    let nominal = backoff_ms << attempt.min(6);
    let half = nominal / 2;
    let mut rng = Rng::new(
        seed ^ job_id.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ((attempt as u64) << 32),
    );
    half + rng.next_u64() % (nominal - half + 1)
}

/// One live attempt as seen by the watchdog.
#[derive(Debug)]
struct InFlightAttempt {
    job_id: u64,
    started: Instant,
    /// the attempt's own deadline in seconds (≤ 0 = none installed)
    deadline_secs: f64,
    token: CancelToken,
    /// already cancelled by a sweep — never re-reported
    cancelled: bool,
}

/// Live registry of executing attempts, shared between the workers and
/// the service watchdog: each attempt registers its cancel token and
/// deadline on entry and deregisters on exit, so a supervisor thread can
/// cancel attempts that overstay — stuck between cancellation
/// checkpoints past their deadline, or running with no deadline at all.
/// When a registry is installed and no deadline is configured, the
/// attempt harness installs a plain cancellable token instead of the
/// free non-token, so the watchdog always has a handle it can fire.
#[derive(Debug, Default)]
pub struct InFlightRegistry {
    next_ticket: AtomicU64,
    entries: Mutex<HashMap<u64, InFlightAttempt>>,
}

impl InFlightRegistry {
    pub fn new() -> InFlightRegistry {
        InFlightRegistry::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<u64, InFlightAttempt>> {
        // a worker panicking mid-insert leaves the map fully usable
        self.entries.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Register one attempt; returns the ticket to pass to `deregister`.
    pub fn register(&self, job_id: u64, deadline_secs: f64, token: CancelToken) -> u64 {
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        self.lock().insert(
            ticket,
            InFlightAttempt {
                job_id,
                started: Instant::now(),
                deadline_secs,
                token,
                cancelled: false,
            },
        );
        ticket
    }

    /// Drop a finished attempt from the registry.
    pub fn deregister(&self, ticket: u64) {
        self.lock().remove(&ticket);
    }

    /// Attempts currently executing.
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cancel every attempt that has overstayed: past its own deadline
    /// plus `grace_secs`, or — for attempts running without a deadline —
    /// past `default_limit_secs` (≤ 0 disables that default). Returns
    /// the job ids newly cancelled by this sweep; attempts cancelled by
    /// an earlier sweep are not re-reported.
    pub fn cancel_overstayed(&self, default_limit_secs: f64, grace_secs: f64) -> Vec<u64> {
        let now = Instant::now();
        let mut cancelled = Vec::new();
        for entry in self.lock().values_mut() {
            if entry.cancelled {
                continue;
            }
            let limit = if entry.deadline_secs > 0.0 {
                entry.deadline_secs + grace_secs.max(0.0)
            } else {
                default_limit_secs
            };
            if limit <= 0.0 {
                continue;
            }
            if now.duration_since(entry.started).as_secs_f64() > limit {
                entry.token.cancel();
                entry.cancelled = true;
                cancelled.push(entry.job_id);
            }
        }
        cancelled
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Where a worker's per-attempt scratch comes from: checked out of the
/// size-tiered pool per attempt (regular workers), or a pinned
/// long-lived arena owned by the scheduler's dedicated high-tier worker
/// — outsized jobs would otherwise grow-and-drop top-tier arenas on
/// every checkout. A panicking attempt discards a pooled scratch; a
/// pinned one is replaced in place with a fresh default (same rule:
/// unwound arenas are never reused).
pub(crate) enum ScratchSource<'a> {
    Pool(&'a ScratchPool),
    Pinned(&'a mut WorkerScratch),
}

/// Run one job to a final verdict: attempt, and on transient failure
/// back off, escalate the spec one rung, and re-attempt — up to
/// `policy.max_retries` retries. Every attempt gets a freshly
/// configured scratch (from `source`) with a fresh deadline token; a
/// panicking attempt is caught here (the worker thread survives) and
/// its scratch is discarded or reset rather than reused. Permanent
/// errors (e.g. a filtration/graph mismatch) short-circuit the ladder —
/// retrying cannot fix them.
pub(crate) fn run_job_with_retries(
    source: &mut ScratchSource<'_>,
    prune_threads: usize,
    kernel: DominationKernel,
    policy: &AttemptPolicy,
    metrics: &Metrics,
    job: &Job,
    worker: usize,
) -> std::result::Result<JobResult, JobFailure> {
    let attempts_max = (policy.max_retries as u32).saturating_add(1);
    let mut attempt = 0u32;
    loop {
        let last = attempt + 1 >= attempts_max;
        let (which, sharded) = degraded_spec(job.spec.reduction, attempt, last);
        let sharded = sharded || job.spec.sharded;
        // Per-attempt token, created out here so the in-flight registry
        // can hand the watchdog a live handle: with a deadline it
        // self-expires (and can still be cancelled); with a registry but
        // no deadline it must be explicitly cancellable, because
        // `from_secs(0)` is the free non-token nothing can fire.
        let token = if policy.deadline_secs > 0.0 {
            CancelToken::from_secs(policy.deadline_secs)
        } else if policy.inflight.is_some() {
            CancelToken::cancellable()
        } else {
            CancelToken::none()
        };
        let ticket = policy
            .inflight
            .as_ref()
            .map(|reg| reg.register(job.id, policy.deadline_secs, token.clone()));
        // configure + guard one attempt; shared by both scratch sources
        // so they can never diverge. Returns (verdict, panicked).
        let one_attempt = |scratch: &mut WorkerScratch| -> (Result<JobResult>, bool) {
            scratch.reduce.set_prune_threads(prune_threads);
            scratch.reduce.set_domination_kernel(kernel);
            scratch.reduce.set_cancel_token(token.clone());
            #[cfg(any(test, feature = "faults"))]
            scratch.reduce.set_fault_round_delay(
                policy
                    .faults
                    .as_ref()
                    .and_then(|plan| plan.round_delay(job.id)),
            );
            let caught = catch_unwind(AssertUnwindSafe(|| {
                #[cfg(any(test, feature = "faults"))]
                if let Some(plan) = &policy.faults {
                    if plan.should_panic(job.id, attempt) {
                        panic!("injected panic: job {} attempt {}", job.id, attempt);
                    }
                    if let Some(e) = plan.injected_error(job.id, attempt) {
                        return Err(e);
                    }
                }
                execute_attempt(scratch, job, worker, which, sharded)
            }));
            match caught {
                Ok(res) => (res, false),
                Err(payload) => {
                    metrics.jobs_panicked.fetch_add(1, Ordering::Relaxed);
                    (Err(Error::JobPanicked(panic_message(payload))), true)
                }
            }
        };
        let result = match source {
            ScratchSource::Pool(pool) => {
                let mut scratch = pool.checkout(job.graph.n());
                let (res, panicked) = one_attempt(&mut scratch);
                if panicked {
                    // the unwound arenas may be inconsistent — never
                    // re-pool (a clean drop returns it to its tier)
                    scratch.discard();
                }
                res
            }
            ScratchSource::Pinned(scratch) => {
                let (res, panicked) = one_attempt(&mut **scratch);
                if panicked {
                    // same rule, pinned flavour: replace in place
                    **scratch = WorkerScratch::default();
                }
                res
            }
        };
        if let (Some(reg), Some(t)) = (policy.inflight.as_ref(), ticket) {
            reg.deregister(t);
        }
        match result {
            Ok(mut r) => {
                r.attempts = attempt + 1;
                if attempt > 0 {
                    metrics.jobs_degraded.fetch_add(1, Ordering::Relaxed);
                    r.outcome = JobOutcome::Degraded {
                        reduction: which,
                        sharded,
                    };
                }
                return Ok(r);
            }
            Err(e) => {
                if matches!(e, Error::DeadlineExceeded { .. }) {
                    metrics.deadline_misses.fetch_add(1, Ordering::Relaxed);
                }
                if e.is_transient() && !last {
                    metrics.jobs_retried.fetch_add(1, Ordering::Relaxed);
                    if policy.backoff_ms > 0 {
                        std::thread::sleep(Duration::from_millis(jittered_backoff_ms(
                            policy.backoff_ms,
                            attempt,
                            policy.jitter_seed,
                            job.id,
                        )));
                    }
                    attempt += 1;
                    continue;
                }
                return Err(JobFailure {
                    id: job.id,
                    attempts: attempt + 1,
                    error: e,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobSpec;
    use crate::graph::gen;

    #[test]
    fn execute_job_reuses_scratch_cleanly() {
        let mut scratch = WorkerScratch::new();
        let a = Job::degree_superlevel(0, gen::barabasi_albert(50, 2, 1), JobSpec::default());
        let first = execute_job(&mut scratch, &a, 3).unwrap();
        assert_eq!(first.worker, 3);
        assert_eq!(first.diagrams.len(), 2);
        assert_eq!(first.attempts, 1);
        assert_eq!(first.outcome, JobOutcome::Success);
        // same job through the warmed scratch must give identical output
        let again = execute_job(&mut scratch, &a, 3).unwrap();
        for k in 0..first.diagrams.len() {
            assert!(first.diagrams[k].same_as(&again.diagrams[k], 0.0));
        }
    }

    #[test]
    fn execute_job_surfaces_typed_errors() {
        let mut scratch = WorkerScratch::new();
        let bad = Job::new(
            0,
            gen::cycle(5),
            crate::complex::Filtration::constant(3),
            JobSpec::default(),
        );
        assert!(matches!(
            execute_job(&mut scratch, &bad, 0),
            Err(crate::error::Error::FiltrationMismatch { .. })
        ));
    }

    #[test]
    fn degradation_ladder_escalates_and_shards_last() {
        // attempt 0 always runs the requested spec unsharded
        assert_eq!(degraded_spec(Reduction::Prunit, 0, false), (Reduction::Prunit, false));
        assert_eq!(degraded_spec(Reduction::Prunit, 0, true), (Reduction::Prunit, false));
        // each retry escalates one rung
        assert_eq!(degraded_spec(Reduction::None, 1, false), (Reduction::Combined, false));
        assert_eq!(degraded_spec(Reduction::None, 2, false), (Reduction::FixedPoint, false));
        // the last attempt of a failing job is sharded on top
        assert_eq!(degraded_spec(Reduction::Combined, 2, true), (Reduction::FixedPoint, true));
        // FixedPoint saturates
        assert_eq!(escalate(Reduction::FixedPoint), Reduction::FixedPoint);
    }

    #[test]
    fn sharded_attempt_matches_unsharded_diagrams() {
        let mut scratch = WorkerScratch::new();
        let job = Job::degree_superlevel(9, gen::barabasi_albert(60, 2, 4), JobSpec::default());
        let plain = execute_attempt(&mut scratch, &job, 0, Reduction::Combined, false).unwrap();
        let shard = execute_attempt(&mut scratch, &job, 0, Reduction::Combined, true).unwrap();
        assert_eq!(plain.diagrams.len(), shard.diagrams.len());
        for k in 0..plain.diagrams.len() {
            assert!(
                plain.diagrams[k].same_as(&shard.diagrams[k], 0.0),
                "degraded sharded execution must not change PD_{k}"
            );
        }
    }

    fn policy(max_retries: usize, deadline_secs: f64, faults: FaultPlan) -> AttemptPolicy {
        AttemptPolicy {
            max_retries,
            backoff_ms: 0,
            deadline_secs,
            jitter_seed: 0,
            inflight: None,
            faults: Some(Arc::new(faults)),
        }
    }

    #[test]
    fn jittered_backoff_is_deterministic_and_bounded() {
        // zero base disables backoff outright (chaos-suite determinism)
        assert_eq!(jittered_backoff_ms(0, 3, 42, 7), 0);
        // same (base, attempt, seed, job) → same sleep
        assert_eq!(
            jittered_backoff_ms(100, 2, 42, 7),
            jittered_backoff_ms(100, 2, 42, 7)
        );
        // equal-jitter bounds: [nominal/2, nominal], doublings cap at 6
        for attempt in 0..9u32 {
            let nominal = 100u64 << attempt.min(6);
            let v = jittered_backoff_ms(100, attempt, 1, 2);
            assert!(
                v >= nominal / 2 && v <= nominal,
                "attempt={attempt} v={v} nominal={nominal}"
            );
        }
        // different jobs decorrelate: across 16 job ids the draws differ
        let vs: Vec<u64> = (0..16)
            .map(|id| jittered_backoff_ms(1000, 3, 42, id))
            .collect();
        assert!(vs.iter().any(|&v| v != vs[0]), "{vs:?}");
    }

    #[test]
    fn inflight_registry_cancels_overstayers_once() {
        let reg = InFlightRegistry::new();
        let t = CancelToken::cancellable();
        let ticket = reg.register(1, 0.0, t.clone());
        assert_eq!(reg.len(), 1);
        // no default limit → no-deadline attempts are never cancelled
        assert!(reg.cancel_overstayed(0.0, 0.0).is_empty());
        assert!(!t.is_expired());
        // a tiny default limit catches it
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(reg.cancel_overstayed(0.001, 0.0), vec![1]);
        assert!(t.is_expired());
        // idempotent: an already-cancelled entry is not re-reported
        assert!(reg.cancel_overstayed(0.001, 0.0).is_empty());
        reg.deregister(ticket);
        assert!(reg.is_empty());
    }

    #[test]
    fn watchdog_cancel_unwinds_a_stuck_attempt() {
        // no deadline, but a registry installed: the harness hands out a
        // cancellable token, and an external sweep unwinds the attempt
        // at its next checkpoint with Error::Cancelled
        let pool = ScratchPool::new(1);
        let metrics = Metrics::default();
        let reg = Arc::new(InFlightRegistry::new());
        let job = Job::degree_superlevel(
            4,
            gen::erdos_renyi(120, 0.1, 9),
            JobSpec {
                max_k: 1,
                reduction: Reduction::FixedPoint,
                sharded: false,
                ..JobSpec::default()
            },
        );
        // every round sleeps 20ms, so the sweeper always wins the race
        let plan = FaultPlan::new().delay_rounds(4, Duration::from_millis(20));
        let mut p = policy(0, 0.0, plan);
        p.inflight = Some(Arc::clone(&reg));
        let sweeper = {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                for _ in 0..1000 {
                    if !reg.cancel_overstayed(0.001, 0.0).is_empty() {
                        return true;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                false
            })
        };
        let fail = run_job_with_retries(
            &mut ScratchSource::Pool(&pool),
            1,
            DominationKernel::Auto,
            &p,
            &metrics,
            &job,
            0,
        )
        .unwrap_err();
        assert!(sweeper.join().unwrap(), "sweep never saw the attempt");
        assert!(matches!(fail.error, Error::Cancelled), "{:?}", fail.error);
        // the finished attempt deregistered itself
        assert!(reg.is_empty());
    }

    #[test]
    fn spec_sharded_jobs_run_sharded_from_the_first_attempt() {
        let mut scratch = WorkerScratch::new();
        let g = gen::barabasi_albert(60, 2, 4);
        let plain = execute_job(
            &mut scratch,
            &Job::degree_superlevel(0, g.clone(), JobSpec::default()),
            0,
        )
        .unwrap();
        let spec = JobSpec {
            sharded: true,
            ..JobSpec::default()
        };
        let sharded = execute_job(&mut scratch, &Job::degree_superlevel(0, g, spec), 0).unwrap();
        assert!(!sharded.reduction.shard_sizes.is_empty(), "must have sharded");
        for k in 0..plain.diagrams.len() {
            assert!(plain.diagrams[k].same_as(&sharded.diagrams[k], 0.0));
        }
    }

    #[test]
    fn injected_panic_is_caught_and_retried_to_success() {
        let pool = ScratchPool::new(2);
        let metrics = Metrics::default();
        let job = Job::degree_superlevel(5, gen::barabasi_albert(50, 2, 2), JobSpec::default());
        let plan = FaultPlan::new().panic_on(5, 0);
        let r = run_job_with_retries(
            &mut ScratchSource::Pool(&pool),
            1,
            DominationKernel::Auto,
            &policy(2, 0.0, plan),
            &metrics,
            &job,
            0,
        )
        .unwrap();
        assert_eq!(r.attempts, 2);
        assert!(r.outcome.is_degraded());
        assert_eq!(metrics.jobs_panicked(), 1);
        assert_eq!(metrics.jobs_retried(), 1);
        assert_eq!(metrics.jobs_degraded(), 1);
        // the panicked attempt's scratch was discarded, not re-pooled
        assert_eq!(pool.cached(), 1);
    }

    #[test]
    fn exhausted_retries_surface_identity_and_attempts() {
        let pool = ScratchPool::new(2);
        let metrics = Metrics::default();
        let job = Job::degree_superlevel(11, gen::cycle(20), JobSpec::default());
        let plan = FaultPlan::new().error_always(11);
        let fail = run_job_with_retries(
            &mut ScratchSource::Pool(&pool),
            1,
            DominationKernel::Auto,
            &policy(2, 0.0, plan),
            &metrics,
            &job,
            0,
        )
        .unwrap_err();
        assert_eq!(fail.id, 11);
        assert_eq!(fail.attempts, 3, "max_retries=2 → 3 attempts");
        assert!(matches!(fail.error, Error::Injected(_)));
        assert_eq!(metrics.jobs_retried(), 2);
        assert!(fail.to_string().contains("job 11 failed after 3 attempt(s)"));
    }

    #[test]
    fn permanent_errors_do_not_burn_retries() {
        let pool = ScratchPool::new(2);
        let metrics = Metrics::default();
        let bad = Job::new(
            3,
            gen::cycle(5),
            crate::complex::Filtration::constant(3),
            JobSpec::default(),
        );
        let fail = run_job_with_retries(
            &mut ScratchSource::Pool(&pool),
            1,
            DominationKernel::Auto,
            &policy(4, 0.0, FaultPlan::new()),
            &metrics,
            &bad,
            0,
        )
        .unwrap_err();
        assert_eq!(fail.attempts, 1, "structural errors must not be retried");
        assert!(matches!(fail.error, Error::FiltrationMismatch { .. }));
        assert_eq!(metrics.jobs_retried(), 0);
    }

    #[test]
    fn round_delay_plus_deadline_forces_miss_then_recovers() {
        let pool = ScratchPool::new(2);
        let metrics = Metrics::default();
        // FixedPoint alternation polls the token between rounds, so a
        // 50ms injected round delay blows a 5ms deadline deterministically
        let job = Job::degree_superlevel(
            2,
            gen::erdos_renyi(120, 0.1, 9),
            JobSpec {
                max_k: 1,
                reduction: Reduction::FixedPoint,
                sharded: false,
                ..JobSpec::default()
            },
        );
        let plan = FaultPlan::new().delay_rounds(2, Duration::from_millis(50));
        // no retries: the deadline miss is the final verdict
        let fail = run_job_with_retries(
            &mut ScratchSource::Pool(&pool),
            1,
            DominationKernel::Auto,
            &policy(0, 0.005, plan.clone()),
            &metrics,
            &job,
            0,
        )
        .unwrap_err();
        assert!(matches!(fail.error, Error::DeadlineExceeded { .. }));
        assert!(metrics.deadline_misses() >= 1);
        // with no deadline the same faulted job completes (slowly)
        let ok = run_job_with_retries(
            &mut ScratchSource::Pool(&pool),
            1,
            DominationKernel::Auto,
            &policy(0, 0.0, plan),
            &metrics,
            &job,
            0,
        )
        .unwrap();
        assert_eq!(ok.attempts, 1);
    }

    #[test]
    fn pinned_scratch_runs_jobs_and_replaces_itself_on_panic() {
        let metrics = Metrics::default();
        let mut arena = WorkerScratch::new();
        let job = Job::degree_superlevel(8, gen::barabasi_albert(50, 2, 3), JobSpec::default());
        // a panicking attempt must reset the pinned arena, then the
        // retry reuses it: same identity, degraded outcome, nothing pooled
        let plan = FaultPlan::new().panic_on(8, 0);
        let r = run_job_with_retries(
            &mut ScratchSource::Pinned(&mut arena),
            1,
            DominationKernel::Auto,
            &policy(2, 0.0, plan),
            &metrics,
            &job,
            7,
        )
        .unwrap();
        assert_eq!(r.id, 8);
        assert_eq!(r.attempts, 2);
        assert_eq!(r.worker, 7);
        assert_eq!(metrics.jobs_panicked(), 1);
        // the (replaced) arena stays serviceable for the next job, and
        // produces output identical to a fresh pooled run
        let again = run_job_with_retries(
            &mut ScratchSource::Pinned(&mut arena),
            1,
            DominationKernel::Auto,
            &policy(0, 0.0, FaultPlan::new()),
            &metrics,
            &job,
            7,
        )
        .unwrap();
        let pool = ScratchPool::new(1);
        let pooled = run_job_with_retries(
            &mut ScratchSource::Pool(&pool),
            1,
            DominationKernel::Auto,
            &policy(0, 0.0, FaultPlan::new()),
            &metrics,
            &job,
            0,
        )
        .unwrap();
        for k in 0..pooled.diagrams.len() {
            assert!(again.diagrams[k].same_as(&pooled.diagrams[k], 0.0));
        }
    }

    #[test]
    fn degraded_results_stay_correct() {
        // a twice-failed job must produce, on its degraded last attempt,
        // exactly the diagrams a clean run produces in every guaranteed
        // dimension: escalation changes the route, never the answer for
        // PD_j, j ≥ max_k (Thms 2 & 7 compose; dimensions below max_k
        // are best-effort under a stronger core and may differ)
        let pool = ScratchPool::new(2);
        let metrics = Metrics::default();
        let job = Job::degree_superlevel(6, gen::barabasi_albert(70, 3, 5), JobSpec::default());
        let clean = execute_job(&mut WorkerScratch::new(), &job, 0).unwrap();
        let plan = FaultPlan::new().error_on(6, 0).error_on(6, 1);
        let degraded = run_job_with_retries(
            &mut ScratchSource::Pool(&pool),
            1,
            DominationKernel::Auto,
            &policy(2, 0.0, plan),
            &metrics,
            &job,
            0,
        )
        .unwrap();
        assert_eq!(degraded.attempts, 3);
        assert_eq!(
            degraded.outcome,
            JobOutcome::Degraded {
                reduction: Reduction::FixedPoint,
                sharded: true
            }
        );
        for k in job.spec.max_k..clean.diagrams.len() {
            assert!(
                clean.diagrams[k].same_as(&degraded.diagrams[k], 1e-9),
                "degradation changed guaranteed PD_{k}"
            );
        }
    }
}
