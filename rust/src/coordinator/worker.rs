//! Worker-side execution: the reusable per-job scratch state and the
//! body that turns one [`Job`] into one [`JobResult`]. Pure computation —
//! queueing, backpressure, and result streaming live in
//! [`super::scheduler`], scratch reuse policy in [`super::scratch`].

use crate::complex::ComplexWorkspace;
use crate::error::Result;
use crate::homology::persistence_diagrams_with;
use crate::reduce::{combined_with_ws, ReductionWorkspace};
use crate::util::Timer;

use super::job::{Job, JobResult};

/// Reusable execution state for one job at a time: complex arenas for PH
/// plus the zero-copy reduction planner's masks/degree arrays. The
/// scheduler's workers check one out of the size-tiered
/// [`super::scratch::ScratchPool`] per job (so arena sizes track job
/// sizes); single-threaded callers can hold one long-lived instance.
#[derive(Debug, Default)]
pub struct WorkerScratch {
    pub complex: ComplexWorkspace,
    pub reduce: ReductionWorkspace,
}

impl WorkerScratch {
    pub fn new() -> WorkerScratch {
        WorkerScratch::default()
    }
}

/// Execute one job: plan + compact the reduction and run PH, both into
/// the caller's scratch. `worker` is the executing thread's index,
/// recorded in the result for telemetry.
///
/// A filtration/graph mismatch surfaces as a typed error instead of the
/// pre-planner panic.
pub fn execute_job(scratch: &mut WorkerScratch, job: &Job, worker: usize) -> Result<JobResult> {
    let total = Timer::start();
    let red = combined_with_ws(
        &mut scratch.reduce,
        &job.graph,
        &job.filtration,
        job.spec.max_k,
        job.spec.reduction,
    )?;
    let (diagrams, ph_secs) = Timer::time(|| {
        persistence_diagrams_with(
            &mut scratch.complex,
            &red.graph,
            &red.filtration,
            job.spec.max_k,
        )
    });
    Ok(JobResult {
        id: job.id,
        diagrams,
        reduction: red.report,
        ph_secs,
        total_secs: total.elapsed().as_secs_f64(),
        worker,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobSpec;
    use crate::graph::gen;

    #[test]
    fn execute_job_reuses_scratch_cleanly() {
        let mut scratch = WorkerScratch::new();
        let a = Job::degree_superlevel(0, gen::barabasi_albert(50, 2, 1), JobSpec::default());
        let first = execute_job(&mut scratch, &a, 3).unwrap();
        assert_eq!(first.worker, 3);
        assert_eq!(first.diagrams.len(), 2);
        // same job through the warmed scratch must give identical output
        let again = execute_job(&mut scratch, &a, 3).unwrap();
        for k in 0..first.diagrams.len() {
            assert!(first.diagrams[k].same_as(&again.diagrams[k], 0.0));
        }
    }

    #[test]
    fn execute_job_surfaces_typed_errors() {
        let mut scratch = WorkerScratch::new();
        let bad = Job::new(
            0,
            gen::cycle(5),
            crate::complex::Filtration::constant(3),
            JobSpec::default(),
        );
        assert!(matches!(
            execute_job(&mut scratch, &bad, 0),
            Err(crate::error::Error::FiltrationMismatch { .. })
        ));
    }
}
