//! Atomic coordinator metrics (scrape-friendly counters).

use std::sync::atomic::{AtomicU64, Ordering};

/// Live metrics shared between workers and the leader.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    /// microseconds, accumulated
    pub reduce_us: AtomicU64,
    pub ph_us: AtomicU64,
    pub vertices_in: AtomicU64,
    pub vertices_out: AtomicU64,
    pub edges_in: AtomicU64,
    pub edges_out: AtomicU64,
    /// poisoned locks recovered instead of panicking (scratch-pool tiers,
    /// the job queue, the XLA executable cache) — nonzero means some
    /// worker panicked mid-batch but the coordinator kept going
    pub lock_recoveries: AtomicU64,
    /// worker threads that panicked during a batch
    pub workers_panicked: AtomicU64,
    /// job attempts re-run after a transient failure
    pub jobs_retried: AtomicU64,
    /// attempts aborted by `Error::DeadlineExceeded`
    pub deadline_misses: AtomicU64,
    /// job panics caught by the attempt harness (the worker thread
    /// survives; contrast `workers_panicked`, which counts thread deaths)
    pub jobs_panicked: AtomicU64,
    /// jobs that ultimately succeeded with an escalated (degraded) spec
    pub jobs_degraded: AtomicU64,
    /// jobs routed past the pool queue to the dedicated high-tier worker
    /// (order at or above the scheduler's `large_job_order` cutoff)
    pub jobs_routed_large: AtomicU64,
    /// jobs rejected by the service's admission controller with
    /// `Error::Overloaded` (load shedding; never counted as failed)
    pub jobs_shed: AtomicU64,
    /// jobs admitted only after the controller downgraded their spec to
    /// the cheapest shape (FixedPoint + sharded) under CPU pressure
    pub jobs_admission_degraded: AtomicU64,
    /// in-flight attempts cancelled by the service watchdog after
    /// overstaying their deadline
    pub watchdog_cancels: AtomicU64,
    /// persistence pairs resolved by the apparent-pair prepass (free:
    /// no column additions were spent on them)
    pub ph_apparent_pairs: AtomicU64,
    /// persistence pairs that needed actual column reduction
    pub ph_reduced_pairs: AtomicU64,
}

impl Metrics {
    pub fn record(&self, reduce_secs: f64, ph_secs: f64, v_in: usize, v_out: usize, e_in: usize, e_out: usize) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.reduce_us
            .fetch_add((reduce_secs * 1e6) as u64, Ordering::Relaxed);
        self.ph_us.fetch_add((ph_secs * 1e6) as u64, Ordering::Relaxed);
        self.vertices_in.fetch_add(v_in as u64, Ordering::Relaxed);
        self.vertices_out.fetch_add(v_out as u64, Ordering::Relaxed);
        self.edges_in.fetch_add(e_in as u64, Ordering::Relaxed);
        self.edges_out.fetch_add(e_out as u64, Ordering::Relaxed);
    }

    /// Fold one job's persistence-pair split into the counters.
    pub fn record_ph_pairs(&self, apparent: usize, reduced: usize) {
        self.ph_apparent_pairs
            .fetch_add(apparent as u64, Ordering::Relaxed);
        self.ph_reduced_pairs
            .fetch_add(reduced as u64, Ordering::Relaxed);
    }

    pub fn completed(&self) -> u64 {
        self.jobs_completed.load(Ordering::Relaxed)
    }

    /// Jobs whose execution returned an error (also on the summary line).
    pub fn failed(&self) -> u64 {
        self.jobs_failed.load(Ordering::Relaxed)
    }

    /// Aggregate vertex reduction across the batch, percent.
    pub fn vertex_reduction_pct(&self) -> f64 {
        let vin = self.vertices_in.load(Ordering::Relaxed) as f64;
        let vout = self.vertices_out.load(Ordering::Relaxed) as f64;
        if vin == 0.0 {
            0.0
        } else {
            100.0 * (vin - vout) / vin
        }
    }

    /// Poisoned-lock recoveries observed so far.
    pub fn lock_recoveries(&self) -> u64 {
        self.lock_recoveries.load(Ordering::Relaxed)
    }

    /// Worker threads that panicked.
    pub fn workers_panicked(&self) -> u64 {
        self.workers_panicked.load(Ordering::Relaxed)
    }

    /// Attempts re-run after a transient failure.
    pub fn jobs_retried(&self) -> u64 {
        self.jobs_retried.load(Ordering::Relaxed)
    }

    /// Attempts aborted at a cancellation checkpoint by their deadline.
    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses.load(Ordering::Relaxed)
    }

    /// Job panics caught by the attempt harness.
    pub fn jobs_panicked(&self) -> u64 {
        self.jobs_panicked.load(Ordering::Relaxed)
    }

    /// Jobs that succeeded only after spec escalation.
    pub fn jobs_degraded(&self) -> u64 {
        self.jobs_degraded.load(Ordering::Relaxed)
    }

    /// Jobs routed to the dedicated high-tier worker.
    pub fn routed_large(&self) -> u64 {
        self.jobs_routed_large.load(Ordering::Relaxed)
    }

    /// Jobs shed by admission control.
    pub fn jobs_shed(&self) -> u64 {
        self.jobs_shed.load(Ordering::Relaxed)
    }

    /// Jobs degraded at admission (CPU-pressure downgrade).
    pub fn jobs_admission_degraded(&self) -> u64 {
        self.jobs_admission_degraded.load(Ordering::Relaxed)
    }

    /// Stuck attempts cancelled by the watchdog.
    pub fn watchdog_cancels(&self) -> u64 {
        self.watchdog_cancels.load(Ordering::Relaxed)
    }

    /// Human-readable summary line.
    pub fn summary(&self) -> String {
        format!(
            "jobs={} failed={} reduce={:.3}s ph={:.3}s vertex_reduction={:.1}% \
             lock_recoveries={} worker_panics={} retries={} deadline_misses={} \
             degraded={} job_panics={} routed_large={} shed={} \
             admission_degraded={} watchdog_cancels={}",
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.reduce_us.load(Ordering::Relaxed) as f64 / 1e6,
            self.ph_us.load(Ordering::Relaxed) as f64 / 1e6,
            self.vertex_reduction_pct(),
            self.lock_recoveries(),
            self.workers_panicked(),
            self.jobs_retried(),
            self.deadline_misses(),
            self.jobs_degraded(),
            self.jobs_panicked(),
            self.routed_large(),
            self.jobs_shed(),
            self.jobs_admission_degraded(),
            self.watchdog_cancels(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates() {
        let m = Metrics::default();
        m.record(0.5, 1.0, 100, 40, 200, 90);
        m.record(0.5, 1.0, 100, 60, 200, 110);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.vertices_in.load(Ordering::Relaxed), 200);
        assert!((m.vertex_reduction_pct() - 50.0).abs() < 1e-9);
        assert!(m.summary().contains("jobs=2"));
    }

    #[test]
    fn summary_reports_failures() {
        let m = Metrics::default();
        m.record(0.1, 0.1, 10, 5, 20, 9);
        m.jobs_failed.fetch_add(3, Ordering::Relaxed);
        assert_eq!(m.failed(), 3);
        assert!(m.summary().contains("failed=3"), "{}", m.summary());
    }

    #[test]
    fn empty_metrics_no_div_by_zero() {
        let m = Metrics::default();
        assert_eq!(m.vertex_reduction_pct(), 0.0);
    }

    #[test]
    fn summary_reports_recoveries_and_panics() {
        let m = Metrics::default();
        assert!(m.summary().contains("lock_recoveries=0"));
        m.lock_recoveries.fetch_add(2, Ordering::Relaxed);
        m.workers_panicked.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.lock_recoveries(), 2);
        assert_eq!(m.workers_panicked(), 1);
        assert!(m.summary().contains("lock_recoveries=2"), "{}", m.summary());
        assert!(m.summary().contains("worker_panics=1"));
    }

    #[test]
    fn summary_reports_fault_tolerance_counters() {
        let m = Metrics::default();
        assert!(m.summary().contains("retries=0"), "{}", m.summary());
        assert!(m.summary().contains("deadline_misses=0"));
        m.jobs_retried.fetch_add(4, Ordering::Relaxed);
        m.deadline_misses.fetch_add(2, Ordering::Relaxed);
        m.jobs_panicked.fetch_add(1, Ordering::Relaxed);
        m.jobs_degraded.fetch_add(3, Ordering::Relaxed);
        assert_eq!(m.jobs_retried(), 4);
        assert_eq!(m.deadline_misses(), 2);
        assert_eq!(m.jobs_panicked(), 1);
        assert_eq!(m.jobs_degraded(), 3);
        let s = m.summary();
        assert!(s.contains("retries=4"), "{s}");
        assert!(s.contains("deadline_misses=2"), "{s}");
        assert!(s.contains("degraded=3"), "{s}");
        assert!(s.contains("job_panics=1"), "{s}");
    }

    #[test]
    fn summary_reports_service_counters() {
        let m = Metrics::default();
        assert!(m.summary().contains("shed=0"), "{}", m.summary());
        m.jobs_shed.fetch_add(7, Ordering::Relaxed);
        m.jobs_admission_degraded.fetch_add(2, Ordering::Relaxed);
        m.watchdog_cancels.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.jobs_shed(), 7);
        assert_eq!(m.jobs_admission_degraded(), 2);
        assert_eq!(m.watchdog_cancels(), 1);
        let s = m.summary();
        assert!(s.contains("shed=7"), "{s}");
        assert!(s.contains("admission_degraded=2"), "{s}");
        assert!(s.contains("watchdog_cancels=1"), "{s}");
    }

    #[test]
    fn ph_pair_counters_accumulate() {
        let m = Metrics::default();
        m.record_ph_pairs(10, 3);
        m.record_ph_pairs(5, 0);
        assert_eq!(m.ph_apparent_pairs.load(Ordering::Relaxed), 15);
        assert_eq!(m.ph_reduced_pairs.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn summary_reports_large_routing() {
        let m = Metrics::default();
        assert!(m.summary().contains("routed_large=0"), "{}", m.summary());
        m.jobs_routed_large.fetch_add(5, Ordering::Relaxed);
        assert_eq!(m.routed_large(), 5);
        assert!(m.summary().contains("routed_large=5"), "{}", m.summary());
    }
}
