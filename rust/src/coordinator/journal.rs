//! Persistent batch journal: one JSONL record per job event, flushed on
//! every write, so a killed batch can be resumed without recomputing
//! (or double-computing) anything.
//!
//! Three event kinds, hand-rolled JSON (serde is not in the offline
//! registry):
//!
//! ```text
//! {"event":"submitted","id":3,"n":40,"m":76,"max_k":1,"reduction":"prunit+coral"}
//! {"event":"completed","id":3,"attempts":1,"outcome":"success","reduction":"prunit+coral","sharded":false,"total_secs":0.012300}
//! {"event":"failed","id":4,"attempts":3,"error":"injected fault: ..."}
//! ```
//!
//! Resume contract: a job id with a `completed` record is skipped on
//! replay; anything merely `submitted` (the process died mid-flight) or
//! `failed` is re-run. The journal is append-only — a resumed batch
//! appends to the same file, so the full history of a job (including
//! earlier failed incarnations) survives.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

use super::job::{Job, JobFailure, JobOutcome, JobResult};

/// Append-only JSONL writer for batch job events.
#[derive(Debug)]
pub struct Journal {
    path: PathBuf,
    file: File,
}

impl Journal {
    /// Open (creating if absent) a journal at `path` for appending.
    pub fn open(path: impl AsRef<Path>) -> Result<Journal> {
        let path = path.as_ref().to_path_buf();
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| Error::Io(format!("journal {}: {e}", path.display())))?;
        Ok(Journal { path, file })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn write_line(&mut self, line: &str) -> Result<()> {
        // flush per record: a SIGKILL between batches of buffered writes
        // must not lose completed-job records, or resume would recompute
        writeln!(self.file, "{line}")
            .and_then(|_| self.file.flush())
            .map_err(|e| Error::Io(format!("journal {}: {e}", self.path.display())))
    }

    /// Record a job entering the queue.
    pub fn record_submitted(&mut self, job: &Job) -> Result<()> {
        self.write_line(&format!(
            "{{\"event\":\"submitted\",\"id\":{},\"n\":{},\"m\":{},\"max_k\":{},\"reduction\":\"{}\"}}",
            job.id,
            job.graph.n(),
            job.graph.m(),
            job.spec.max_k,
            json_escape(job.spec.reduction.name()),
        ))
    }

    /// Record a job finishing successfully (possibly degraded).
    pub fn record_completed(&mut self, r: &JobResult) -> Result<()> {
        let (outcome, reduction, sharded) = match r.outcome {
            JobOutcome::Success => ("success", r.reduction.which, false),
            JobOutcome::Degraded { reduction, sharded } => ("degraded", reduction, sharded),
        };
        self.write_line(&format!(
            "{{\"event\":\"completed\",\"id\":{},\"attempts\":{},\"outcome\":\"{outcome}\",\
             \"reduction\":\"{}\",\"sharded\":{sharded},\"total_secs\":{:.6}}}",
            r.id,
            r.attempts,
            json_escape(reduction.name()),
            r.total_secs,
        ))
    }

    /// Record a job exhausting its retry budget.
    pub fn record_failed(&mut self, f: &JobFailure) -> Result<()> {
        self.write_line(&format!(
            "{{\"event\":\"failed\",\"id\":{},\"attempts\":{},\"error\":\"{}\"}}",
            f.id,
            f.attempts,
            json_escape(&f.error.to_string()),
        ))
    }

    /// Record a job satisfied from the result cache (no execution). The
    /// record replays as `completed`, so a resume never recomputes it.
    pub fn record_cached(&mut self, id: u64) -> Result<()> {
        self.write_line(&format!(
            "{{\"event\":\"completed\",\"id\":{id},\"attempts\":0,\"outcome\":\"cached\",\
             \"reduction\":\"\",\"sharded\":false,\"total_secs\":0.000000}}"
        ))
    }

    /// Compact the journal at `path` if it has grown past
    /// `threshold_bytes`: rewrite it keeping, per job id, only the lines
    /// that determine replay state — the last `completed` record for
    /// completed ids, the last `failed` record for ids still failed, and
    /// one `submitted` record for orphans — then atomically rename over
    /// the original. Every kept line is verbatim, so nothing the replay
    /// reads changes; history of superseded attempts (and any torn tail)
    /// is dropped. Returns whether compaction ran. A missing file is a
    /// no-op, not an error.
    pub fn compact_if_larger(path: impl AsRef<Path>, threshold_bytes: u64) -> Result<bool> {
        let path = path.as_ref();
        let size = match std::fs::metadata(path) {
            Ok(m) => m.len(),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(false),
            Err(e) => return Err(Error::Io(format!("journal {}: {e}", path.display()))),
        };
        if size <= threshold_bytes {
            return Ok(false);
        }
        let file = File::open(path)
            .map_err(|e| Error::Io(format!("journal {}: {e}", path.display())))?;
        let mut submitted: BTreeMap<u64, String> = BTreeMap::new();
        let mut completed: BTreeMap<u64, String> = BTreeMap::new();
        let mut failed: BTreeMap<u64, String> = BTreeMap::new();
        for line in BufReader::new(file).lines() {
            let line = line.map_err(|e| Error::Io(format!("journal {}: {e}", path.display())))?;
            let (Some(event), Some(id)) = (
                json_str_field(&line, "event"),
                json_u64_field(&line, "id"),
            ) else {
                continue; // malformed (torn tail): dropped by compaction
            };
            match event {
                "submitted" => {
                    submitted.entry(id).or_insert(line);
                }
                "completed" => {
                    completed.insert(id, line);
                    failed.remove(&id);
                }
                "failed" => {
                    if !completed.contains_key(&id) {
                        failed.insert(id, line);
                    }
                }
                _ => {}
            }
        }
        let tmp = PathBuf::from(format!("{}.compact-tmp", path.display()));
        {
            let mut out = File::create(&tmp)
                .map_err(|e| Error::Io(format!("journal {}: {e}", tmp.display())))?;
            let io = |e: std::io::Error| Error::Io(format!("journal {}: {e}", tmp.display()));
            // submitted lines first (only for ids without a terminal
            // record — the orphans), then terminal records
            for (id, line) in &submitted {
                if !completed.contains_key(id) && !failed.contains_key(id) {
                    writeln!(out, "{line}").map_err(io)?;
                }
            }
            for line in completed.values() {
                writeln!(out, "{line}").map_err(io)?;
            }
            for line in failed.values() {
                writeln!(out, "{line}").map_err(io)?;
            }
            out.flush().map_err(io)?;
        }
        std::fs::rename(&tmp, path)
            .map_err(|e| Error::Io(format!("journal {}: {e}", path.display())))?;
        Ok(true)
    }
}

/// The replayed state of a journal: which ids reached which terminal
/// state. Loaded before a resumed batch to decide what to skip.
#[derive(Clone, Debug, Default)]
pub struct JournalReplay {
    /// every id with a `submitted` record
    pub submitted: BTreeSet<u64>,
    /// ids with a `completed` record — skipped on resume
    pub completed: BTreeSet<u64>,
    /// ids whose LAST terminal record is `failed` (a later completed
    /// record, e.g. from a previous resume, clears the failure)
    pub failed: BTreeSet<u64>,
    /// malformed lines skipped (torn final write after a kill is normal)
    pub skipped_lines: usize,
}

impl JournalReplay {
    /// Replay a journal file. A missing file is an empty replay (first
    /// run), not an error.
    pub fn load(path: impl AsRef<Path>) -> Result<JournalReplay> {
        let path = path.as_ref();
        let file = match File::open(path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(JournalReplay::default())
            }
            Err(e) => return Err(Error::Io(format!("journal {}: {e}", path.display()))),
        };
        let mut replay = JournalReplay::default();
        for line in BufReader::new(file).lines() {
            let line = line.map_err(|e| Error::Io(format!("journal {}: {e}", path.display())))?;
            if line.trim().is_empty() {
                continue;
            }
            let (Some(event), Some(id)) = (
                json_str_field(&line, "event"),
                json_u64_field(&line, "id"),
            ) else {
                replay.skipped_lines += 1;
                continue;
            };
            match event {
                "submitted" => {
                    replay.submitted.insert(id);
                }
                "completed" => {
                    replay.completed.insert(id);
                    replay.failed.remove(&id);
                }
                "failed" => {
                    if !replay.completed.contains(&id) {
                        replay.failed.insert(id);
                    }
                }
                _ => replay.skipped_lines += 1,
            }
        }
        Ok(replay)
    }

    /// Whether a job id already completed and can be skipped on resume.
    pub fn is_done(&self, id: u64) -> bool {
        self.completed.contains(&id)
    }

    /// Ids that were submitted but never reached a terminal record — the
    /// in-flight jobs a kill orphaned.
    pub fn orphaned(&self) -> BTreeSet<u64> {
        self.submitted
            .iter()
            .filter(|id| !self.completed.contains(id) && !self.failed.contains(id))
            .copied()
            .collect()
    }
}

/// Minimal JSON string escaping for the fields we write.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Extract a string field's raw value from one flat JSON object line.
/// Only used on fields we write without escapes (event names).
fn json_str_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":\"");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find('"')?;
    Some(&rest[..end])
}

/// Extract an unsigned integer field from one flat JSON object line.
fn json_u64_field(line: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let digits: String = line[start..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobSpec;
    use crate::error::Error;
    use crate::graph::gen;
    use crate::reduce::Reduction;

    fn tmp_path(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("coraltda-journal-{tag}-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sample_result(id: u64, outcome: JobOutcome) -> JobResult {
        JobResult {
            id,
            diagrams: vec![],
            reduction: crate::reduce::ReductionReport {
                vertices_before: 10,
                edges_before: 10,
                vertices_after: 5,
                edges_after: 5,
                reduce_secs: 0.0,
                prunit_secs: 0.0,
                core_secs: 0.0,
                compact_secs: 0.0,
                rounds: vec![],
                prunit_rounds: 0,
                which: Reduction::Combined,
                shard_sizes: vec![],
            },
            ph_secs: 0.0,
            total_secs: 0.25,
            worker: 0,
            attempts: 1,
            outcome,
        }
    }

    #[test]
    fn round_trip_submitted_completed_failed() {
        let path = tmp_path("roundtrip");
        {
            let mut j = Journal::open(&path).unwrap();
            let job = Job::degree_superlevel(1, gen::cycle(6), JobSpec::default());
            j.record_submitted(&job).unwrap();
            j.record_completed(&sample_result(1, JobOutcome::Success))
                .unwrap();
            let job2 = Job::degree_superlevel(2, gen::cycle(6), JobSpec::default());
            j.record_submitted(&job2).unwrap();
            j.record_failed(&JobFailure {
                id: 2,
                attempts: 3,
                error: Error::Injected("scripted \"quoted\" failure".into()),
            })
            .unwrap();
        }
        let replay = JournalReplay::load(&path).unwrap();
        assert_eq!(replay.submitted.len(), 2);
        assert!(replay.is_done(1));
        assert!(!replay.is_done(2));
        assert!(replay.failed.contains(&2));
        assert_eq!(replay.skipped_lines, 0);
        assert!(replay.orphaned().is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_journal_is_empty_replay() {
        let replay = JournalReplay::load("/nonexistent/journal.jsonl".to_string());
        // missing parent dir still maps to NotFound on open
        assert!(replay.unwrap().submitted.is_empty());
    }

    #[test]
    fn torn_final_line_is_skipped_not_fatal() {
        let path = tmp_path("torn");
        {
            let mut j = Journal::open(&path).unwrap();
            let job = Job::degree_superlevel(4, gen::cycle(6), JobSpec::default());
            j.record_submitted(&job).unwrap();
        }
        // simulate a SIGKILL mid-write: a truncated record at the tail
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"event\":\"comple").unwrap();
        }
        let replay = JournalReplay::load(&path).unwrap();
        assert!(replay.submitted.contains(&4));
        assert_eq!(replay.skipped_lines, 1);
        assert_eq!(replay.orphaned(), BTreeSet::from([4]));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resume_clears_earlier_failures_and_finds_orphans() {
        let path = tmp_path("resume");
        {
            let mut j = Journal::open(&path).unwrap();
            for id in [1u64, 2, 3] {
                let job = Job::degree_superlevel(id, gen::cycle(6), JobSpec::default());
                j.record_submitted(&job).unwrap();
            }
            j.record_failed(&JobFailure {
                id: 1,
                attempts: 2,
                error: Error::Cancelled,
            })
            .unwrap();
            // id 2 completes; id 3 stays orphaned (killed in flight)
            j.record_completed(&sample_result(2, JobOutcome::Success))
                .unwrap();
        }
        // second incarnation of the batch: id 1 retried and now succeeds
        {
            let mut j = Journal::open(&path).unwrap();
            j.record_completed(&sample_result(
                1,
                JobOutcome::Degraded {
                    reduction: Reduction::FixedPoint,
                    sharded: true,
                },
            ))
            .unwrap();
        }
        let replay = JournalReplay::load(&path).unwrap();
        assert!(replay.is_done(1), "later completion clears the failure");
        assert!(!replay.failed.contains(&1));
        assert!(replay.is_done(2));
        assert_eq!(replay.orphaned(), BTreeSet::from([3]));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cached_record_replays_as_completed() {
        let path = tmp_path("cached");
        {
            let mut j = Journal::open(&path).unwrap();
            let job = Job::degree_superlevel(9, gen::cycle(6), JobSpec::default());
            j.record_submitted(&job).unwrap();
            j.record_cached(9).unwrap();
        }
        let replay = JournalReplay::load(&path).unwrap();
        assert!(replay.is_done(9));
        assert!(replay.orphaned().is_empty());
        assert_eq!(replay.skipped_lines, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_preserves_replay_state_and_shrinks() {
        let path = tmp_path("compact");
        {
            let mut j = Journal::open(&path).unwrap();
            // a long history: id 1 fails twice then completes, id 2
            // completes, id 3 fails and stays failed, id 4 is orphaned
            for id in [1u64, 2, 3, 4] {
                let job = Job::degree_superlevel(id, gen::cycle(6), JobSpec::default());
                j.record_submitted(&job).unwrap();
            }
            for _ in 0..2 {
                j.record_failed(&JobFailure {
                    id: 1,
                    attempts: 3,
                    error: Error::Cancelled,
                })
                .unwrap();
            }
            j.record_completed(&sample_result(1, JobOutcome::Success))
                .unwrap();
            j.record_completed(&sample_result(2, JobOutcome::Success))
                .unwrap();
            j.record_failed(&JobFailure {
                id: 3,
                attempts: 2,
                error: Error::Cancelled,
            })
            .unwrap();
        }
        // plus a torn tail, which compaction must simply drop
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"event\":\"fail").unwrap();
        }
        let before_replay = JournalReplay::load(&path).unwrap();
        let before_size = std::fs::metadata(&path).unwrap().len();

        // under the threshold: untouched
        assert!(!Journal::compact_if_larger(&path, before_size).unwrap());
        assert_eq!(std::fs::metadata(&path).unwrap().len(), before_size);
        // over the threshold: rewritten smaller
        assert!(Journal::compact_if_larger(&path, 1).unwrap());
        let after_size = std::fs::metadata(&path).unwrap().len();
        assert!(after_size < before_size, "{after_size} !< {before_size}");

        let after = JournalReplay::load(&path).unwrap();
        assert_eq!(after.completed, before_replay.completed);
        assert_eq!(after.failed, before_replay.failed);
        assert_eq!(after.orphaned(), BTreeSet::from([4]));
        assert_eq!(after.skipped_lines, 0, "the torn tail is gone");
        // exactly one line per surviving id: orphan 4, completed 1+2, failed 3
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4, "{text}");
        // a compacted journal still appends normally
        {
            let mut j = Journal::open(&path).unwrap();
            j.record_completed(&sample_result(4, JobOutcome::Success))
                .unwrap();
        }
        let final_replay = JournalReplay::load(&path).unwrap();
        assert!(final_replay.orphaned().is_empty());
        assert_eq!(final_replay.completed.len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_of_missing_journal_is_a_noop() {
        assert!(!Journal::compact_if_larger("/nonexistent/journal.jsonl", 1).unwrap());
    }
}
