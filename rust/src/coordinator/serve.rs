//! Always-on reduction service (`repro serve`): a long-running daemon
//! wrapped around [`super::scheduler::Coordinator::run_core`].
//!
//! Job specs arrive as newline-delimited `key=value` request lines on an
//! input stream (stdin for the CLI); response lines go to an output sink
//! (stdout). Around the core scheduler the service adds the pieces a
//! daemon needs that a batch run does not:
//!
//! * **Admission control + load shedding** — every request passes through
//!   an [`AdmissionController`] *before* it is queued. Over-budget or
//!   over-depth requests are rejected with a typed
//!   [`Error::Overloaded`](crate::error::Error::Overloaded) response
//!   (lowest priority first); CPU-bound backlog degrades requests to the
//!   cheapest exact shape (FixedPoint + sharded) instead of shedding.
//! * **Content-addressed result cache** — a bounded [`ResultCache`] keyed
//!   by the canonical [`job_key`] hash of (graph, filtration, reduction,
//!   max_k). A resubmitted graph is answered from cache without touching
//!   the worker pool; only clean (non-degraded) successes are inserted.
//! * **Watchdog + graceful shutdown** — a supervisor thread sweeps the
//!   [`InFlightRegistry`] and force-cancels attempts that overstay their
//!   deadline, and evicts idle scratch tiers. SIGTERM/SIGINT (or the
//!   in-process shutdown flag) stops intake, drains queued work as shed,
//!   lets in-flight jobs finish, flushes the journal, and returns with
//!   final metrics — exit 0.
//! * **Health/metrics endpoint** — `GET /healthz` and `GET /metrics`
//!   served by a hand-rolled HTTP/1.1 responder over `std::net`
//!   (nothing async, no dependencies).
//!
//! Threading model: a reader thread parses requests, builds graphs, and
//! makes cache/admission decisions (so the pending gauge sees the real
//! backlog, not the bounded scheduler queue); the calling thread runs
//! `run_core`, whose producer iterator pulls admitted jobs off a channel
//! and whose result callbacks run on the same thread — journal and
//! response writes need no locking (`RefCell`, never borrowed twice).

use std::cell::RefCell;
use std::collections::{BTreeSet, HashMap};
use std::io::{BufRead, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::complex::Filtration;
use crate::config::{CoordinatorConfig, ServiceConfig};
use crate::datasets;
use crate::error::{Error, Result};
use crate::homology::{Algorithm, Diagram, PhConfig};
use crate::reduce::Reduction;

use super::admission::{AdmissionController, AdmissionDecision, AdmissionPolicy, DEFAULT_PRIORITY};
use super::cache::{job_key, CacheKey, CachedResult, ResultCache};
#[cfg(any(test, feature = "faults"))]
use super::faults::FaultPlan;
use super::job::{Job, JobFailure, JobOutcome, JobResult, JobSpec};
use super::journal::{Journal, JournalReplay};
use super::metrics::Metrics;
use super::scheduler::Coordinator;
use super::scratch::ScratchPool;
use super::worker::InFlightRegistry;

/// Process-wide shutdown latch set by the Unix signal handler. The serve
/// loop polls it alongside its per-instance flag; in-process tests use
/// only their own [`ServeOptions::shutdown`] flag and never touch this.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    // storing to a static atomic is async-signal-safe; everything else
    // (drain, journal flush, metrics) happens on the serve thread
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install SIGINT/SIGTERM handlers that request a graceful drain. Called
/// by `repro serve` before entering the loop; libc is linked by std, so
/// the raw `signal(2)` binding needs no new dependency.
#[cfg(unix)]
pub fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
}

#[cfg(not(unix))]
pub fn install_signal_handlers() {}

/// Everything `serve` needs beyond the input stream and output sink.
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    pub coordinator: CoordinatorConfig,
    pub service: ServiceConfig,
    /// Persistent JSONL journal; re-serving with the same path skips
    /// requests whose ids already completed (reported `already-done`).
    pub journal_path: Option<PathBuf>,
    /// In-process shutdown flag for tests (signals set the global latch).
    pub shutdown: Option<Arc<AtomicBool>>,
    /// Deterministic fault script threaded into the worker harness.
    #[cfg(any(test, feature = "faults"))]
    pub faults: Option<FaultPlan>,
}

/// Terminal tally of one serve run, returned when the loop drains.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeReport {
    /// requests handed to the worker pool
    pub submitted: usize,
    /// pool jobs that returned diagrams (includes `degraded`)
    pub completed: usize,
    /// completions that ran a degraded spec (retry ladder or admission)
    pub degraded: usize,
    /// pool jobs that exhausted the retry budget
    pub failed: usize,
    /// requests rejected with `Error::Overloaded` (incl. shutdown drain)
    pub shed: usize,
    /// requests answered from the result cache
    pub cache_hits: usize,
    /// requests skipped because the journal already has them completed
    pub already_done: usize,
    /// request lines that failed to parse (service keeps running)
    pub bad_lines: usize,
}

/// One parsed request line.
#[derive(Clone, Debug, PartialEq)]
struct Request {
    id: u64,
    dataset: String,
    instance: usize,
    seed: u64,
    max_k: usize,
    reduction: Reduction,
    priority: u8,
    ph: PhConfig,
}

/// Parse one `key=value`-token request line. `dataset=` is required;
/// everything else falls back to the coordinator config (seed, k,
/// reduction) or [`DEFAULT_PRIORITY`]. `next_id` numbers lines that
/// carry no explicit `id=`.
fn parse_request(line: &str, defaults: &CoordinatorConfig, next_id: u64) -> Result<Request> {
    let mut req = Request {
        id: next_id,
        dataset: String::new(),
        instance: 0,
        seed: defaults.seed,
        max_k: defaults.max_k,
        reduction: crate::cli::parse_reduction(&defaults.reduction)?,
        priority: DEFAULT_PRIORITY,
        ph: PhConfig {
            algorithm: Algorithm::parse(&defaults.ph_algorithm)?,
            threads: defaults.ph_threads,
            chunk_cols: 0,
        },
    };
    for tok in line.split_whitespace() {
        let (key, val) = tok
            .split_once('=')
            .ok_or_else(|| Error::Parse(format!("expected key=value, got {tok:?}")))?;
        let int = |what: &str| -> Result<u64> {
            val.parse()
                .map_err(|_| Error::Parse(format!("{what}: expected integer, got {val:?}")))
        };
        match key {
            "id" => req.id = int("id")?,
            "dataset" => req.dataset = val.to_string(),
            "instance" => req.instance = int("instance")? as usize,
            "seed" => req.seed = int("seed")?,
            "k" => req.max_k = int("k")? as usize,
            "reduction" => req.reduction = crate::cli::parse_reduction(val)?,
            "priority" => req.priority = int("priority")?.min(u8::MAX as u64) as u8,
            "ph_algorithm" => req.ph.algorithm = Algorithm::parse(val)?,
            "ph_threads" => req.ph.threads = int("ph_threads")? as usize,
            other => {
                return Err(Error::Parse(format!("unknown request key {other:?}")));
            }
        }
    }
    if req.dataset.is_empty() {
        return Err(Error::Parse("request needs dataset=NAME".into()));
    }
    Ok(req)
}

/// Order-sensitive FNV-1a digest of a diagram set: two diagram vectors
/// digest equal iff every pair's `f64` bits match. Response lines carry
/// it so a client (and the test suite) can check cached answers are
/// bit-identical to cold computes without shipping the diagrams.
pub fn diagram_digest(diagrams: &[Diagram]) -> u64 {
    fn put(h: &mut u64, x: u64) {
        for byte in x.to_le_bytes() {
            *h ^= byte as u64;
            *h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for d in diagrams {
        put(&mut h, d.all_pairs().len() as u64);
        for &(birth, death) in d.all_pairs() {
            put(&mut h, birth.to_bits());
            put(&mut h, death.to_bits());
        }
    }
    h
}

/// What the reader thread decided about one request.
enum Event {
    /// Admitted (possibly degraded): run it on the pool.
    Run { job: Job, meta: Meta },
    /// Content hash hit: answer without touching the pool.
    CacheHit { id: u64, result: CachedResult },
    /// Rejected by admission control.
    Shed { id: u64, reason: String },
    /// The journal already has this id completed (resume).
    AlreadyDone { id: u64 },
    /// Unparseable request line; the service keeps serving.
    BadLine { line_no: usize, msg: String },
}

/// Book-keeping pinned to an in-flight job until its result comes back.
struct Meta {
    /// content address to insert under on clean success (None: no cache)
    key: Option<CacheKey>,
    /// bytes charged against the admission memory budget
    charged: usize,
    /// spec was downgraded by admission control under CPU pressure
    admission_degraded: bool,
}

/// Shared read-only view handed to the HTTP responder thread.
struct HttpState {
    start: Instant,
    metrics: Arc<Metrics>,
    cache: Arc<ResultCache>,
    admission: Arc<AdmissionController>,
    scratch: Arc<ScratchPool>,
    registry: Arc<InFlightRegistry>,
}

/// Serve until the input stream ends or shutdown is requested. Response
/// lines (one per request, plus `serve:` status lines) go to `out`.
///
/// This is the library entry the CLI and the integration tests share;
/// `repro serve` passes locked stdin and `println!`.
pub fn serve<R, F>(input: R, opts: ServeOptions, mut out: F) -> Result<ServeReport>
where
    R: BufRead + Send + 'static,
    F: FnMut(String),
{
    let service = opts.service.clone();
    let mut coordinator = Coordinator::new(opts.coordinator.clone());
    let registry = Arc::new(InFlightRegistry::new());
    coordinator.set_inflight_registry(Arc::clone(&registry));
    #[cfg(any(test, feature = "faults"))]
    if let Some(plan) = opts.faults.clone() {
        coordinator.set_fault_plan(plan);
    }
    let metrics = coordinator.metrics();
    let scratch = coordinator.scratch_pool();
    let cache_enabled = service.cache_budget_bytes > 0;
    let cache = Arc::new(ResultCache::new(service.cache_budget_bytes));
    let admission = Arc::new(AdmissionController::new(AdmissionPolicy {
        max_pending: service.max_pending,
        shed_pending: service.shed_pending,
        memory_budget_bytes: service.memory_budget_bytes,
        cpu_pressure_secs: service.cpu_pressure_secs,
    }));
    let local_stop = opts.shutdown.clone().unwrap_or_default();
    let stop = {
        let local = Arc::clone(&local_stop);
        move || local.load(Ordering::SeqCst) || SHUTDOWN.load(Ordering::SeqCst)
    };

    // ---- journal: replay (resume), compact, then append -------------
    let mut done: BTreeSet<u64> = BTreeSet::new();
    let journal: RefCell<Option<Journal>> = RefCell::new(match &opts.journal_path {
        Some(path) => {
            let replay = JournalReplay::load(path)?;
            if !replay.completed.is_empty() {
                out(format!(
                    "serve: journal resume — {} id(s) already completed, {} orphaned",
                    replay.completed.len(),
                    replay.orphaned().len()
                ));
            }
            done = replay.completed;
            if opts.coordinator.journal_compact_bytes > 0 {
                Journal::compact_if_larger(path, opts.coordinator.journal_compact_bytes)?;
            }
            Some(Journal::open(path)?)
        }
        None => None,
    });

    // ---- health/metrics endpoint ------------------------------------
    let http_state = Arc::new(HttpState {
        start: Instant::now(),
        metrics: Arc::clone(&metrics),
        cache: Arc::clone(&cache),
        admission: Arc::clone(&admission),
        scratch: Arc::clone(&scratch),
        registry: Arc::clone(&registry),
    });
    let http = if service.http_addr.is_empty() {
        None
    } else {
        let (addr, handle) =
            start_http(&service.http_addr, Arc::clone(&http_state), Arc::clone(&local_stop))?;
        out(format!("serve: http listening on {addr}"));
        Some(handle)
    };

    // ---- watchdog: cancel overstayers, evict idle scratch -----------
    let watchdog = {
        let registry = Arc::clone(&registry);
        let metrics = Arc::clone(&metrics);
        let scratch = Arc::clone(&scratch);
        let stop = stop.clone();
        let poll = Duration::from_millis(service.watchdog_poll_ms.max(1));
        let stuck = service.stuck_job_secs;
        let grace = service.watchdog_grace_secs;
        let idle = service.idle_evict_secs;
        std::thread::spawn(move || {
            let mut last_evict = Instant::now();
            while !stop() {
                std::thread::sleep(poll);
                let cancelled = registry.cancel_overstayed(stuck, grace);
                if !cancelled.is_empty() {
                    metrics
                        .watchdog_cancels
                        .fetch_add(cancelled.len() as u64, Ordering::Relaxed);
                }
                if idle > 0.0 && last_evict.elapsed().as_secs_f64() >= idle {
                    scratch.evict_idle(Duration::from_secs_f64(idle));
                    last_evict = Instant::now();
                }
            }
        })
    };

    // ---- reader: parse, hash, cache-check, admit --------------------
    let (tx, rx) = channel::<Event>();
    {
        let cache = Arc::clone(&cache);
        let admission = Arc::clone(&admission);
        let metrics = Arc::clone(&metrics);
        let defaults = opts.coordinator.clone();
        let stop = stop.clone();
        // detached on purpose: a reader blocked in stdin read() cannot be
        // joined after SIGTERM; it dies with the process (or at EOF)
        std::thread::spawn(move || {
            let mut next_id = 0u64;
            for (line_no, line) in input.lines().enumerate() {
                if stop() {
                    break;
                }
                let Ok(line) = line else { break };
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                let req = match parse_request(line, &defaults, next_id) {
                    Ok(req) => req,
                    Err(e) => {
                        let ev = Event::BadLine { line_no: line_no + 1, msg: e.to_string() };
                        if tx.send(ev).is_err() {
                            break;
                        }
                        continue;
                    }
                };
                next_id = req.id + 1;
                let event = match admit_request(&req, &done, &cache, cache_enabled, &admission) {
                    Ok(ev) => ev,
                    Err(e) => Event::BadLine { line_no: line_no + 1, msg: e.to_string() },
                };
                match &event {
                    Event::Shed { .. } => {
                        metrics.jobs_shed.fetch_add(1, Ordering::Relaxed);
                    }
                    Event::Run { meta, .. } if meta.admission_degraded => {
                        metrics.jobs_admission_degraded.fetch_add(1, Ordering::Relaxed);
                    }
                    _ => {}
                }
                if tx.send(event).is_err() {
                    break;
                }
            }
        });
    }

    // ---- the long-lived run_core call -------------------------------
    // Producer iterator and both result callbacks run on THIS thread
    // (run_core pulls jobs and drains results on its caller), so plain
    // RefCells are sound: borrows never overlap.
    let report = RefCell::new(ServeReport::default());
    let out = RefCell::new(&mut out);
    let emit = |line: String| {
        let mut f = out.borrow_mut();
        (*f)(line);
    };
    let meta_by_id: RefCell<HashMap<u64, Meta>> = RefCell::new(HashMap::new());
    let journal_err: RefCell<Option<Error>> = RefCell::new(None);
    let note_journal = |r: Result<()>| {
        if let Err(e) = r {
            journal_err.borrow_mut().get_or_insert(e);
        }
    };
    // Mid-run compaction: once the file outgrows the threshold, close the
    // append handle (the compactor atomically renames a rewrite over the
    // path, so appending through the old handle would write to the
    // unlinked inode), compact, reopen. In-flight jobs are exactly the
    // orphans compaction preserves, so a crash right after is resumable.
    let compact_threshold = opts.coordinator.journal_compact_bytes;
    let maybe_compact = || {
        let (Some(path), true) = (opts.journal_path.as_ref(), compact_threshold > 0) else {
            return;
        };
        let mut slot = journal.borrow_mut();
        if slot.is_none() {
            return;
        }
        let over = std::fs::metadata(path).map(|m| m.len() > compact_threshold).unwrap_or(false);
        if !over {
            return;
        }
        *slot = None;
        note_journal(
            Journal::compact_if_larger(path, compact_threshold)
                .and_then(|_| Journal::open(path))
                .map(|j| *slot = Some(j)),
        );
    };

    // Answer one non-Run event; shared by the live loop and the
    // shutdown drain (where queued Run events are shed back too).
    let answer = |ev: Event, draining: bool| match ev {
        Event::Run { job, meta } => {
            // only reachable while draining: the job was admitted but
            // the service is shutting down — release and shed it
            debug_assert!(draining);
            admission.release(meta.charged);
            metrics.jobs_shed.fetch_add(1, Ordering::Relaxed);
            report.borrow_mut().shed += 1;
            emit(format!(
                "shed id={} error={}",
                job.id,
                Error::Overloaded("service shutting down".into())
            ));
        }
        Event::CacheHit { id, result } => {
            note_journal(match journal.borrow_mut().as_mut() {
                Some(j) => j.record_cached(id),
                None => Ok(()),
            });
            maybe_compact();
            report.borrow_mut().cache_hits += 1;
            emit(format!(
                "done id={id} status=cached reduction={} pd={:016x}",
                result.reduction.which.name(),
                diagram_digest(&result.diagrams)
            ));
        }
        Event::Shed { id, reason } => {
            report.borrow_mut().shed += 1;
            emit(format!("shed id={id} error={}", Error::Overloaded(reason)));
        }
        Event::AlreadyDone { id } => {
            report.borrow_mut().already_done += 1;
            emit(format!("done id={id} status=already-done"));
        }
        Event::BadLine { line_no, msg } => {
            report.borrow_mut().bad_lines += 1;
            emit(format!("error line={line_no} msg={msg}"));
        }
    };

    let jobs = std::iter::from_fn(|| loop {
        if journal_err.borrow().is_some() {
            return None;
        }
        if stop() {
            // stop intake; queued decisions are answered, admitted-but-
            // unsubmitted jobs are shed (they were never journaled, so
            // the journal shows no orphans for them)
            while let Ok(ev) = rx.try_recv() {
                answer(ev, true);
            }
            return None;
        }
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(Event::Run { job, meta }) => {
                note_journal(match journal.borrow_mut().as_mut() {
                    Some(j) => j.record_submitted(&job),
                    None => Ok(()),
                });
                meta_by_id.borrow_mut().insert(job.id, meta);
                report.borrow_mut().submitted += 1;
                return Some(job);
            }
            Ok(ev) => answer(ev, false),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return None,
        }
    });

    let mut on_result = |r: JobResult| {
        let meta = meta_by_id.borrow_mut().remove(&r.id);
        let mut admission_degraded = false;
        if let Some(m) = &meta {
            admission.release(m.charged);
            admission.observe_job_secs(r.total_secs);
            admission_degraded = m.admission_degraded;
            // cache only clean successes: a retry-degraded result ran a
            // different spec than the one hashed into the key
            if let (JobOutcome::Success, Some(key)) = (r.outcome, m.key) {
                cache.insert(
                    key,
                    CachedResult {
                        diagrams: r.diagrams.clone(),
                        reduction: r.reduction.clone(),
                    },
                );
            }
        }
        note_journal(match journal.borrow_mut().as_mut() {
            Some(j) => j.record_completed(&r),
            None => Ok(()),
        });
        maybe_compact();
        let degraded = admission_degraded || r.outcome.is_degraded();
        {
            let mut rep = report.borrow_mut();
            rep.completed += 1;
            if degraded {
                rep.degraded += 1;
            }
        }
        emit(format!(
            "done id={} status={} reduction={} attempts={} secs={:.4} pd={:016x}",
            r.id,
            if degraded { "degraded" } else { "ok" },
            r.reduction.which.name(),
            r.attempts,
            r.total_secs,
            diagram_digest(&r.diagrams)
        ));
    };
    let mut on_failure = |f: JobFailure| {
        if let Some(m) = meta_by_id.borrow_mut().remove(&f.id) {
            admission.release(m.charged);
        }
        note_journal(match journal.borrow_mut().as_mut() {
            Some(j) => j.record_failed(&f),
            None => Ok(()),
        });
        maybe_compact();
        report.borrow_mut().failed += 1;
        emit(format!(
            "failed id={} attempts={} error={}",
            f.id, f.attempts, f.error
        ));
    };

    let run = coordinator.run_core(jobs, &mut on_result, &mut on_failure, None);

    // stop the watchdog and the endpoint, then report
    local_stop.store(true, Ordering::SeqCst);
    let _ = watchdog.join();
    if let Some(h) = http {
        let _ = h.join();
    }
    run?;
    if let Some(e) = journal_err.into_inner() {
        return Err(e);
    }
    let report = report.into_inner();
    let mut out = out.into_inner();
    out(format!(
        "serve: drained — submitted={} completed={} degraded={} failed={} shed={} \
         cache_hits={} already_done={} bad_lines={}",
        report.submitted,
        report.completed,
        report.degraded,
        report.failed,
        report.shed,
        report.cache_hits,
        report.already_done,
        report.bad_lines
    ));
    out(format!("serve: {}", metrics.summary()));
    out(format!("serve: {}", admission.summary()));
    out(format!("serve: {}", cache.summary()));
    out(format!("serve: {}", scratch.summary()));
    Ok(report)
}

/// Reader-side decision for one parsed request: journal skip, cache
/// lookup, then admission. Returns the event to hand the serve loop.
fn admit_request(
    req: &Request,
    done: &BTreeSet<u64>,
    cache: &ResultCache,
    cache_enabled: bool,
    admission: &AdmissionController,
) -> Result<Event> {
    if done.contains(&req.id) {
        return Ok(Event::AlreadyDone { id: req.id });
    }
    let recipe = datasets::find(&req.dataset)?;
    let g = recipe.make(req.seed, req.instance);
    let f = Filtration::degree_superlevel(&g);
    let key = cache_enabled.then(|| job_key(&g, &f, req.reduction, req.max_k));
    if let Some(key) = &key {
        if let Some(result) = cache.get(key) {
            return Ok(Event::CacheHit { id: req.id, result });
        }
    }
    match admission.admit(g.n(), g.m(), req.priority) {
        AdmissionDecision::Shed { reason } => Ok(Event::Shed { id: req.id, reason }),
        AdmissionDecision::Admit { charged_bytes } => {
            let spec =
                JobSpec { max_k: req.max_k, reduction: req.reduction, sharded: false, ph: req.ph };
            Ok(Event::Run {
                job: Job::new(req.id, g, f, spec),
                meta: Meta { key, charged: charged_bytes, admission_degraded: false },
            })
        }
        AdmissionDecision::Degrade { charged_bytes } => {
            // cheapest exact shape: FixedPoint reduction, sharded from
            // the first attempt. The executed spec differs from the
            // requested one, so the cache key is recomputed for it.
            let spec = JobSpec {
                max_k: req.max_k,
                reduction: Reduction::FixedPoint,
                sharded: true,
                ph: req.ph,
            };
            let key = cache_enabled.then(|| job_key(&g, &f, Reduction::FixedPoint, req.max_k));
            Ok(Event::Run {
                job: Job::new(req.id, g, f, spec),
                meta: Meta { key, charged: charged_bytes, admission_degraded: true },
            })
        }
    }
}

// ---------------------------------------------------------------------
// HTTP endpoint
// ---------------------------------------------------------------------

/// Bind `addr` (port 0 picks a free port; the bound address is returned)
/// and answer `GET /healthz` + `GET /metrics` until `stop` is set.
fn start_http(
    addr: &str,
    state: Arc<HttpState>,
    stop: Arc<AtomicBool>,
) -> Result<(SocketAddr, JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Io(format!("http bind {addr}: {e}")))?;
    let local = listener
        .local_addr()
        .map_err(|e| Error::Io(format!("http addr: {e}")))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::Io(format!("http nonblocking: {e}")))?;
    let handle = std::thread::spawn(move || {
        while !(stop.load(Ordering::SeqCst) || SHUTDOWN.load(Ordering::SeqCst)) {
            match listener.accept() {
                Ok((stream, _)) => handle_conn(stream, &state),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(20)),
            }
        }
    });
    Ok((local, handle))
}

/// Answer one request. Reads a single buffer (requests are one GET
/// line + headers, far under 1 KiB), writes one plaintext response,
/// closes. Any socket error drops the connection; the daemon lives.
fn handle_conn(mut stream: TcpStream, state: &HttpState) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut buf = [0u8; 1024];
    let mut n = 0usize;
    // read until the blank line ending the headers (or the buffer is
    // full): a request split across packets must not 404 on a half line
    while n < buf.len() {
        match stream.read(&mut buf[n..]) {
            Ok(0) | Err(_) => break,
            Ok(m) => {
                n += m;
                if buf[..n].windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
        }
    }
    if n == 0 {
        return;
    }
    let head = String::from_utf8_lossy(&buf[..n]);
    let path = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("/");
    let (status, body) = match path {
        "/healthz" => ("200 OK", "ok\n".to_string()),
        "/metrics" => ("200 OK", render_metrics(state)),
        _ => ("404 Not Found", "not found\n".to_string()),
    };
    let _ = write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

/// Render the scrape body: one `name value` line per counter/gauge,
/// flat and greppable (prometheus exposition style, minus type hints).
fn render_metrics(s: &HttpState) -> String {
    use std::fmt::Write as _;
    let m = &s.metrics;
    let cs = s.cache.stats();
    let mut o = String::with_capacity(1024);
    let _ = writeln!(o, "repro_uptime_seconds {:.3}", s.start.elapsed().as_secs_f64());
    let _ = writeln!(o, "repro_jobs_submitted {}", m.jobs_submitted.load(Ordering::Relaxed));
    let _ = writeln!(o, "repro_jobs_completed {}", m.completed());
    let _ = writeln!(o, "repro_jobs_failed {}", m.failed());
    let _ = writeln!(o, "repro_jobs_retried {}", m.jobs_retried());
    let _ = writeln!(o, "repro_jobs_degraded {}", m.jobs_degraded());
    let _ = writeln!(o, "repro_jobs_shed {}", m.jobs_shed());
    let _ = writeln!(o, "repro_jobs_admission_degraded {}", m.jobs_admission_degraded());
    let _ = writeln!(o, "repro_watchdog_cancels {}", m.watchdog_cancels());
    let _ = writeln!(o, "repro_deadline_misses {}", m.deadline_misses());
    let _ = writeln!(
        o,
        "repro_reduce_seconds_total {:.6}",
        m.reduce_us.load(Ordering::Relaxed) as f64 / 1e6
    );
    let _ = writeln!(
        o,
        "repro_ph_seconds_total {:.6}",
        m.ph_us.load(Ordering::Relaxed) as f64 / 1e6
    );
    let _ = writeln!(
        o,
        "repro_ph_apparent_pairs {}",
        m.ph_apparent_pairs.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        o,
        "repro_ph_reduced_pairs {}",
        m.ph_reduced_pairs.load(Ordering::Relaxed)
    );
    let _ = writeln!(o, "repro_inflight_attempts {}", s.registry.len());
    let _ = writeln!(o, "repro_cache_entries {}", cs.entries);
    let _ = writeln!(o, "repro_cache_bytes {}", cs.bytes);
    let _ = writeln!(o, "repro_cache_hits {}", cs.hits);
    let _ = writeln!(o, "repro_cache_misses {}", cs.misses);
    let _ = writeln!(o, "repro_cache_evictions {}", cs.evictions);
    let _ = writeln!(o, "repro_cache_insertions {}", cs.insertions);
    let _ = writeln!(o, "repro_admission_pending {}", s.admission.pending());
    let _ = writeln!(o, "repro_admission_inflight_bytes {}", s.admission.inflight_bytes());
    let _ = writeln!(o, "repro_admission_backlog_seconds {:.3}", s.admission.backlog_secs());
    let _ = writeln!(o, "repro_scratch_evictions {}", s.scratch.evictions());
    let _ = writeln!(o, "repro_scratch_hits {}", s.scratch.hits());
    let _ = writeln!(o, "repro_scratch_misses {}", s.scratch.misses());
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn opts() -> ServeOptions {
        ServeOptions {
            coordinator: CoordinatorConfig {
                workers: 2,
                max_k: 1,
                reduction: "combined".into(),
                seed: 42,
                prune_threads: 1,
                ..CoordinatorConfig::default()
            },
            service: ServiceConfig {
                http_addr: String::new(),
                idle_evict_secs: 0.0,
                stuck_job_secs: 0.0,
                ..ServiceConfig::default()
            },
            ..ServeOptions::default()
        }
    }

    fn run_lines(input: &str, opts: ServeOptions) -> (ServeReport, Vec<String>) {
        let mut lines = Vec::new();
        let report = serve(Cursor::new(input.to_string()), opts, |l| lines.push(l)).unwrap();
        (report, lines)
    }

    #[test]
    fn request_line_parses_with_defaults_and_overrides() {
        let cfg = CoordinatorConfig::default();
        let r = parse_request("dataset=DHFR", &cfg, 7).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.seed, cfg.seed);
        assert_eq!(r.max_k, cfg.max_k);
        assert_eq!(r.priority, DEFAULT_PRIORITY);
        assert_eq!(r.ph, PhConfig::default());
        let r = parse_request(
            "id=3 dataset=DHFR instance=1 seed=9 k=0 reduction=prunit priority=8 \
             ph_algorithm=chunked ph_threads=4",
            &cfg,
            0,
        )
        .unwrap();
        assert_eq!(
            r,
            Request {
                id: 3,
                dataset: "DHFR".into(),
                instance: 1,
                seed: 9,
                max_k: 0,
                reduction: Reduction::Prunit,
                priority: 8,
                ph: PhConfig { algorithm: Algorithm::Chunked, threads: 4, chunk_cols: 0 },
            }
        );
        assert!(parse_request("k=1", &cfg, 0).is_err()); // no dataset
        assert!(parse_request("dataset=DHFR k=soon", &cfg, 0).is_err());
        assert!(parse_request("dataset=DHFR frobnicate=1", &cfg, 0).is_err());
        assert!(parse_request("dataset=DHFR ph_algorithm=nope", &cfg, 0).is_err());
    }

    #[test]
    fn digest_is_bit_sensitive_and_deterministic() {
        let a = Diagram::new(0, vec![(0.0, 1.0), (2.0, f64::INFINITY)]);
        let b = Diagram::new(0, vec![(0.0, 1.5), (2.0, f64::INFINITY)]);
        assert_ne!(
            diagram_digest(std::slice::from_ref(&a)),
            diagram_digest(std::slice::from_ref(&b))
        );
        assert_eq!(
            diagram_digest(std::slice::from_ref(&a)),
            diagram_digest(std::slice::from_ref(&a))
        );
    }

    #[test]
    fn serve_answers_a_stream_of_requests() {
        let input = "id=0 dataset=DHFR instance=0\n\
                     # a comment and a blank line are skipped\n\
                     \n\
                     id=2 dataset=DHFR instance=1\n";
        let (report, lines) = run_lines(input, opts());
        assert_eq!(report.submitted, 2);
        assert_eq!(report.completed, 2);
        assert_eq!(report.failed + report.shed + report.bad_lines, 0);
        assert!(lines.iter().any(|l| l.contains("id=0 status=ok")));
        assert!(lines.iter().any(|l| l.contains("id=2 status=ok")));
        assert!(lines.iter().any(|l| l.starts_with("serve: drained")));
    }

    /// Feed request lines one at a time, waiting for each response —
    /// the resubmission is only sent after the cold compute finished,
    /// so the cache-hit path is exercised deterministically.
    #[test]
    fn resubmitted_graph_is_served_from_cache_bit_identically() {
        struct ChanReader {
            rx: std::sync::mpsc::Receiver<Vec<u8>>,
            buf: Vec<u8>,
            pos: usize,
        }
        impl std::io::Read for ChanReader {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.pos >= self.buf.len() {
                    match self.rx.recv() {
                        Ok(b) => {
                            self.buf = b;
                            self.pos = 0;
                        }
                        Err(_) => return Ok(0), // sender dropped: EOF
                    }
                }
                let n = (self.buf.len() - self.pos).min(out.len());
                out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
                self.pos += n;
                Ok(n)
            }
        }
        let (in_tx, in_rx) = channel::<Vec<u8>>();
        let (out_tx, out_rx) = channel::<String>();
        let handle = std::thread::spawn(move || {
            let input = ChanReader { rx: in_rx, buf: Vec::new(), pos: 0 };
            let reader = std::io::BufReader::new(input);
            serve(reader, opts(), move |l| {
                let _ = out_tx.send(l);
            })
            .unwrap()
        });
        let wait_for = |needle: &str| loop {
            let line = out_rx
                .recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|_| panic!("timed out waiting for {needle:?}"));
            if line.contains(needle) {
                return line;
            }
        };
        in_tx.send(b"id=0 dataset=DHFR\n".to_vec()).unwrap();
        let cold = wait_for("id=0 status=ok");
        in_tx.send(b"id=1 dataset=DHFR\n".to_vec()).unwrap();
        let hit = wait_for("id=1 status=cached");
        drop(in_tx);
        let report = handle.join().unwrap();
        assert_eq!(report.cache_hits, 1);
        assert_eq!(report.completed, 1);
        let digest = |line: &str| line.split("pd=").nth(1).unwrap().to_string();
        assert_eq!(digest(&cold), digest(&hit), "cached PDs must be bit-identical");
    }

    #[test]
    fn malformed_lines_get_error_responses_but_service_keeps_going() {
        let input = "dataset=NO_SUCH_DATASET\n\
                     what even is this\n\
                     id=5 dataset=DHFR\n";
        let (report, lines) = run_lines(input, opts());
        assert_eq!(report.bad_lines, 2);
        assert_eq!(report.completed, 1);
        assert!(lines.iter().any(|l| l.starts_with("error line=1")));
        assert!(lines.iter().any(|l| l.starts_with("error line=2")));
        assert!(lines.iter().any(|l| l.contains("id=5 status=ok")));
    }

    #[test]
    fn zero_max_pending_sheds_everything_with_overloaded() {
        let mut o = opts();
        o.service.max_pending = 0;
        o.service.shed_pending = 0;
        let (report, lines) = run_lines("id=0 dataset=DHFR\n", o);
        assert_eq!(report.shed, 1);
        assert_eq!(report.submitted, 0);
        assert!(lines.iter().any(|l| l.starts_with("shed id=0") && l.contains("overloaded:")));
    }

    #[test]
    fn journal_resume_skips_completed_ids() {
        let mut path = std::env::temp_dir();
        path.push(format!("coral-serve-resume-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut o = opts();
        o.journal_path = Some(path.clone());
        let (first, _) = run_lines("id=0 dataset=DHFR\nid=1 dataset=DHFR instance=1\n", o.clone());
        assert_eq!(first.completed, 2);
        // resubmit the same ids: both skip, no recompute, no duplicates
        let (second, lines) = run_lines("id=0 dataset=DHFR\nid=1 dataset=DHFR instance=1\n", o);
        assert_eq!(second.already_done, 2);
        assert_eq!(second.submitted, 0);
        assert!(lines.iter().any(|l| l.contains("status=already-done")));
        let replay = JournalReplay::load(&path).unwrap();
        assert_eq!(replay.completed.len(), 2);
        assert!(replay.orphaned().is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn journal_compacts_mid_run_once_past_threshold() {
        let mut path = std::env::temp_dir();
        path.push(format!("coral-serve-compact-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut o = opts();
        o.journal_path = Some(path.clone());
        // 1-byte threshold: every terminal record trips compaction, so the
        // rewrite + handle-reopen path runs several times in one serve
        o.coordinator.journal_compact_bytes = 1;
        let input = "id=0 dataset=DHFR\nid=1 dataset=DHFR instance=1\nid=2 dataset=DHFR instance=2\n";
        let (report, _) = run_lines(input, o);
        assert_eq!(report.completed, 3);
        // the compacted journal still replays every completion (nothing
        // recomputes on resume) and holds exactly one record per id
        let replay = JournalReplay::load(&path).unwrap();
        assert_eq!(replay.completed.len(), 3);
        assert!(replay.orphaned().is_empty());
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3, "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn chunked_requests_answer_identically_to_twist() {
        let input = "id=0 dataset=DHFR ph_algorithm=twist\n\
                     id=1 dataset=DHFR ph_algorithm=chunked ph_threads=4\n";
        // cache off: the second request must recompute with the chunked
        // engine, not answer from the twist result's content hash
        let mut o = opts();
        o.service.cache_budget_bytes = 0;
        let (report, lines) = run_lines(input, o);
        assert_eq!(report.completed, 2);
        let digest = |needle: &str| {
            lines
                .iter()
                .find(|l| l.contains(needle))
                .unwrap_or_else(|| panic!("missing {needle:?} in {lines:?}"))
                .split("pd=")
                .nth(1)
                .unwrap()
                .to_string()
        };
        assert_eq!(digest("id=0 "), digest("id=1 "), "chunked must be bit-identical");
    }

    #[test]
    fn shutdown_flag_drains_and_sheds_queued_work() {
        // shutdown pre-set: intake stops immediately; nothing is lost,
        // the loop exits cleanly with a report (no hang)
        let stopper = Arc::new(AtomicBool::new(true));
        let mut o = opts();
        o.shutdown = Some(Arc::clone(&stopper));
        let (report, lines) = run_lines("id=0 dataset=DHFR\n", o);
        assert_eq!(report.completed, 0);
        assert!(lines.iter().any(|l| l.starts_with("serve: drained")));
    }

    #[test]
    fn healthz_and_metrics_answer_over_tcp() {
        let state = Arc::new(HttpState {
            start: Instant::now(),
            metrics: Arc::new(Metrics::default()),
            cache: Arc::new(ResultCache::new(1024)),
            admission: Arc::new(AdmissionController::new(AdmissionPolicy::default())),
            scratch: Arc::new(ScratchPool::new(2)),
            registry: Arc::new(InFlightRegistry::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let (addr, handle) = start_http("127.0.0.1:0", state, Arc::clone(&stop)).unwrap();
        let get = |path: &str| {
            let mut s = TcpStream::connect(addr).unwrap();
            write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut body = String::new();
            s.read_to_string(&mut body).unwrap();
            body
        };
        let health = get("/healthz");
        assert!(health.starts_with("HTTP/1.1 200 OK"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");
        let metrics = get("/metrics");
        assert!(metrics.contains("repro_jobs_completed 0"), "{metrics}");
        assert!(metrics.contains("repro_cache_entries 0"), "{metrics}");
        assert!(metrics.contains("repro_reduce_seconds_total 0.000000"), "{metrics}");
        assert!(metrics.contains("repro_ph_apparent_pairs 0"), "{metrics}");
        assert!(get("/nope").starts_with("HTTP/1.1 404"));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }
}
