//! Size-tiered scratch pool: reusable [`WorkerScratch`] arenas bucketed
//! by graph-order tier.
//!
//! The batch workload mixes jobs of wildly different orders (ego networks
//! of a hundred vertices next to multi-million-vertex networks). A
//! per-thread scratch that once served a huge job keeps huge arrays; a
//! small job checking it out then pays cache pollution and O(big-n)
//! re-initialisation for an O(small-n) plan. Tiering fixes the mismatch:
//! scratches live in buckets of geometrically growing order ranges
//! (factor [`TIER_GROWTH`] between tiers, starting at
//! [`TIER_BASE_ORDER`]), a job checks out from the tier matching its own
//! order, and the guard returns the scratch to that same tier on drop —
//! so arenas stay within a small constant factor of the jobs they serve.
//!
//! Each tier holds at most `max_per_tier` scratches (the scheduler sizes
//! this to its worker count); surplus check-ins are dropped, bounding
//! pool memory at `TIER_COUNT × max_per_tier` arena sets.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::worker::WorkerScratch;

/// Number of size tiers. The last tier is unbounded above.
pub const TIER_COUNT: usize = 8;

/// Upper order bound of tier 0.
pub const TIER_BASE_ORDER: usize = 256;

/// Order growth factor between consecutive tiers.
pub const TIER_GROWTH: usize = 4;

/// The smallest graph order that lands in the pool's top (unbounded)
/// tier: one past the upper bound of tier `TIER_COUNT - 2`. The
/// scheduler uses this as the default `large_job_order` routing cutoff —
/// jobs at or above it bypass the pool for the dedicated high-tier
/// worker, so one outsized graph can't evict the arenas every other tier
/// is reusing.
pub fn top_tier_min_order() -> usize {
    let mut cap = TIER_BASE_ORDER;
    for _ in 0..TIER_COUNT - 2 {
        cap = cap.saturating_mul(TIER_GROWTH);
    }
    cap + 1
}

/// Map a graph order to its pool tier: tier 0 covers orders up to
/// [`TIER_BASE_ORDER`], each further tier covers [`TIER_GROWTH`]× more,
/// and the last tier is unbounded.
pub fn tier_of(order: usize) -> usize {
    let mut tier = 0usize;
    let mut cap = TIER_BASE_ORDER;
    while tier + 1 < TIER_COUNT && order > cap {
        tier += 1;
        cap = cap.saturating_mul(TIER_GROWTH);
    }
    tier
}

/// Upper order bound of a tier (the top tier reports `usize::MAX`).
pub fn tier_cap(tier: usize) -> usize {
    if tier + 1 >= TIER_COUNT {
        return usize::MAX;
    }
    let mut cap = TIER_BASE_ORDER;
    for _ in 0..tier {
        cap = cap.saturating_mul(TIER_GROWTH);
    }
    cap
}

/// Estimated peak working-set bytes a job of this shape charges against
/// the service's memory budget. The planner's tombstone arrays, the
/// filtration copy, and the complex arenas scale with the *tier cap* the
/// scratch will grow to (arenas are reused across jobs, so the pool pays
/// tier-cap bytes even for a job at the bottom of its tier), at roughly
/// 96 B per vertex; boundary columns and CSR copies add ~48 B per edge.
/// Deliberately coarse — admission control needs an upper bound that's
/// stable across jobs of one tier, not an allocator audit.
pub fn estimate_job_bytes(order: usize, edges: usize) -> usize {
    let tier_order = match tier_cap(tier_of(order)) {
        usize::MAX => order, // top tier is unbounded: charge actual order
        cap => cap,
    };
    tier_order.saturating_mul(96).saturating_add(edges.saturating_mul(48))
}

/// A bounded, size-tiered pool of [`WorkerScratch`] shared by the
/// scheduler's workers. All operations are lock-per-tier; tiers never
/// block each other.
#[derive(Debug)]
pub struct ScratchPool {
    /// Each cached scratch carries its check-in instant, so a quiet
    /// daemon can evict arenas that have sat idle past a window.
    tiers: Vec<Mutex<Vec<(WorkerScratch, Instant)>>>,
    max_per_tier: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    /// arenas dropped by [`ScratchPool::evict_idle`]
    evictions: AtomicU64,
    /// tier locks found poisoned and recovered (a worker panicked while
    /// holding one; the guarded Vec is valid regardless, so we reuse it)
    poison_recovered: AtomicU64,
    /// optional sink mirroring recoveries into the coordinator's metrics
    metrics: Option<Arc<Metrics>>,
}

impl ScratchPool {
    /// A pool retaining at most `max_per_tier` scratches per tier
    /// (clamped to ≥ 1).
    pub fn new(max_per_tier: usize) -> ScratchPool {
        ScratchPool::with_metrics(max_per_tier, None)
    }

    /// [`ScratchPool::new`] with a metrics sink: poisoned-lock recoveries
    /// are mirrored into `Metrics::lock_recoveries`.
    pub fn with_metrics(max_per_tier: usize, metrics: Option<Arc<Metrics>>) -> ScratchPool {
        ScratchPool {
            tiers: (0..TIER_COUNT).map(|_| Mutex::new(Vec::new())).collect(),
            max_per_tier: max_per_tier.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            poison_recovered: AtomicU64::new(0),
            metrics,
        }
    }

    /// Lock one tier, recovering from poisoning: a panic in a worker that
    /// held the lock leaves the guarded `Vec<WorkerScratch>` fully valid
    /// (scratches are plain arenas, re-targeted on every checkout), so
    /// the pool keeps serving instead of cascading the panic into every
    /// subsequent job.
    fn lock_tier(&self, tier: usize) -> MutexGuard<'_, Vec<(WorkerScratch, Instant)>> {
        self.tiers[tier].lock().unwrap_or_else(|e| {
            self.poison_recovered.fetch_add(1, Ordering::Relaxed);
            if let Some(m) = &self.metrics {
                m.lock_recoveries.fetch_add(1, Ordering::Relaxed);
            }
            e.into_inner()
        })
    }

    /// Check out a scratch sized for a graph of `order` vertices: reuse
    /// one from the matching tier, or allocate fresh when the tier is
    /// empty. The returned guard checks the scratch back in on drop.
    pub fn checkout(&self, order: usize) -> PooledScratch<'_> {
        let tier = tier_of(order);
        let reused = self.lock_tier(tier).pop();
        let scratch = match reused {
            Some((s, _)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                s
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                WorkerScratch::default()
            }
        };
        PooledScratch {
            pool: self,
            tier,
            scratch: Some(scratch),
        }
    }

    fn check_in(&self, tier: usize, scratch: WorkerScratch) {
        let mut bucket = self.lock_tier(tier);
        if bucket.len() < self.max_per_tier {
            bucket.push((scratch, Instant::now()));
        }
        // else: drop the scratch — the pool is bounded per tier
    }

    /// Drop every cached scratch idle for longer than `window`, returning
    /// how many were evicted. A long-lived daemon calls this from its
    /// watchdog so steady-state memory shrinks back down after a traffic
    /// spike grew the upper tiers; a one-shot batch never needs to.
    pub fn evict_idle(&self, window: Duration) -> usize {
        let now = Instant::now();
        let mut evicted = 0usize;
        for tier in 0..TIER_COUNT {
            let mut bucket = self.lock_tier(tier);
            let before = bucket.len();
            bucket.retain(|(_, stamp)| now.duration_since(*stamp) <= window);
            evicted += before - bucket.len();
        }
        if evicted > 0 {
            self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        }
        evicted
    }

    /// Checkouts served from a tier's cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Checkouts that had to allocate a fresh scratch.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Scratches currently cached across all tiers.
    pub fn cached(&self) -> usize {
        (0..TIER_COUNT).map(|t| self.lock_tier(t).len()).sum()
    }

    /// Tier locks found poisoned and recovered.
    pub fn poison_recoveries(&self) -> u64 {
        self.poison_recovered.load(Ordering::Relaxed)
    }

    /// Arenas dropped by idle eviction so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// One-line reuse summary for batch drivers.
    pub fn summary(&self) -> String {
        format!(
            "scratch_pool: cached={} hits={} misses={} evictions={} poison_recovered={}",
            self.cached(),
            self.hits(),
            self.misses(),
            self.evictions(),
            self.poison_recoveries()
        )
    }

    /// Raw tier lock for poisoning tests: lets a test thread take a tier
    /// guard and panic while holding it.
    #[cfg(test)]
    pub(crate) fn tier_lock_for_test(&self, tier: usize) -> &Mutex<Vec<(WorkerScratch, Instant)>> {
        &self.tiers[tier]
    }
}

/// RAII checkout of one [`WorkerScratch`]: derefs to the scratch and
/// returns it to its tier when dropped.
pub struct PooledScratch<'a> {
    pool: &'a ScratchPool,
    tier: usize,
    scratch: Option<WorkerScratch>,
}

impl PooledScratch<'_> {
    /// The tier this scratch was checked out from (and returns to).
    pub fn tier(&self) -> usize {
        self.tier
    }

    /// Consume the checkout WITHOUT returning the scratch to its tier.
    /// Used after a caught job panic: the unwound arenas may hold
    /// arbitrary intermediate state, so re-pooling them would hand a
    /// possibly-inconsistent scratch to an innocent later job.
    pub fn discard(mut self) {
        self.scratch = None;
        // Drop sees no scratch and skips check_in.
    }
}

impl Deref for PooledScratch<'_> {
    type Target = WorkerScratch;

    fn deref(&self) -> &WorkerScratch {
        self.scratch.as_ref().expect("scratch present until drop")
    }
}

impl DerefMut for PooledScratch<'_> {
    fn deref_mut(&mut self) -> &mut WorkerScratch {
        self.scratch.as_mut().expect("scratch present until drop")
    }
}

impl Drop for PooledScratch<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.scratch.take() {
            self.pool.check_in(self.tier, s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_boundaries_are_geometric() {
        assert_eq!(tier_of(0), 0);
        assert_eq!(tier_of(TIER_BASE_ORDER), 0);
        assert_eq!(tier_of(TIER_BASE_ORDER + 1), 1);
        assert_eq!(tier_of(TIER_BASE_ORDER * TIER_GROWTH), 1);
        assert_eq!(tier_of(TIER_BASE_ORDER * TIER_GROWTH + 1), 2);
        // far past the last boundary everything lands in the top tier
        assert_eq!(tier_of(usize::MAX), TIER_COUNT - 1);
    }

    #[test]
    fn top_tier_min_order_is_the_first_top_tier_order() {
        let boundary = top_tier_min_order();
        assert_eq!(tier_of(boundary), TIER_COUNT - 1);
        assert_eq!(tier_of(boundary - 1), TIER_COUNT - 2);
        // 256 · 4^6 + 1 with the current tiering constants
        assert_eq!(boundary, 1_048_577);
    }

    #[test]
    fn checkout_reuses_within_a_tier_only() {
        let pool = ScratchPool::new(4);
        {
            let _small = pool.checkout(100);
            let _big = pool.checkout(2_000_000);
        } // both returned
        assert_eq!(pool.cached(), 2);
        assert_eq!(pool.misses(), 2);
        // a small job must NOT receive the big job's scratch
        let small = pool.checkout(80);
        assert_eq!(small.tier(), tier_of(80));
        assert_eq!(pool.hits(), 1);
        drop(small);
        let big = pool.checkout(1_900_000);
        assert_eq!(big.tier(), tier_of(1_900_000));
        assert_ne!(big.tier(), tier_of(80));
        assert_eq!(pool.hits(), 2);
    }

    #[test]
    fn pool_is_bounded_per_tier() {
        let pool = ScratchPool::new(2);
        {
            let a = pool.checkout(10);
            let b = pool.checkout(10);
            let c = pool.checkout(10);
            drop(a);
            drop(b);
            drop(c); // third check-in of tier 0 is dropped
        }
        assert_eq!(pool.cached(), 2);
        assert_eq!(pool.misses(), 3);
    }

    #[test]
    fn scratch_state_survives_the_round_trip() {
        let pool = ScratchPool::new(1);
        {
            let mut s = pool.checkout(50);
            s.reduce.set_prune_threads(4);
        }
        let s = pool.checkout(50);
        // configuration is per-checkout state: the scheduler re-applies
        // its prune_threads on every checkout, so whatever persisted here
        // is simply whatever the last user set
        assert_eq!(s.reduce.prune_threads(), 4);
        assert!(pool.summary().contains("hits=1"));
    }

    #[test]
    fn tier_caps_align_with_tier_of() {
        assert_eq!(tier_cap(0), TIER_BASE_ORDER);
        assert_eq!(tier_cap(1), TIER_BASE_ORDER * TIER_GROWTH);
        assert_eq!(tier_cap(TIER_COUNT - 1), usize::MAX);
        for tier in 0..TIER_COUNT - 1 {
            assert_eq!(tier_of(tier_cap(tier)), tier);
            assert_eq!(tier_of(tier_cap(tier) + 1), tier + 1);
        }
    }

    #[test]
    fn job_byte_estimate_is_tier_stable_and_monotone() {
        // every order within one tier charges the same vertex bytes
        assert_eq!(estimate_job_bytes(10, 0), estimate_job_bytes(200, 0));
        assert_eq!(estimate_job_bytes(10, 0), TIER_BASE_ORDER * 96);
        // edges add on top, and bigger tiers charge more
        assert!(estimate_job_bytes(10, 100) > estimate_job_bytes(10, 0));
        assert!(estimate_job_bytes(5_000, 0) > estimate_job_bytes(200, 0));
        // the unbounded top tier charges actual order, not usize::MAX
        let top = estimate_job_bytes(2_000_000, 0);
        assert_eq!(top, 2_000_000 * 96);
    }

    #[test]
    fn idle_eviction_drops_stale_arenas_and_counts() {
        let pool = ScratchPool::new(4);
        {
            let _a = pool.checkout(10);
            let _b = pool.checkout(2_000_000);
        }
        assert_eq!(pool.cached(), 2);
        // a generous window evicts nothing
        assert_eq!(pool.evict_idle(Duration::from_secs(3600)), 0);
        assert_eq!(pool.cached(), 2);
        // a zero window evicts everything that isn't checked out
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(pool.evict_idle(Duration::ZERO), 2);
        assert_eq!(pool.cached(), 0);
        assert_eq!(pool.evictions(), 2);
        assert!(pool.summary().contains("evictions=2"), "{}", pool.summary());
        // the pool keeps serving after eviction (fresh allocation)
        let s = pool.checkout(10);
        assert_eq!(s.tier(), 0);
    }

    #[test]
    fn poisoned_tier_lock_recovers_and_counts() {
        let metrics = Arc::new(Metrics::default());
        let pool = ScratchPool::with_metrics(2, Some(Arc::clone(&metrics)));
        {
            let _warm = pool.checkout(50);
        } // tier 0 now caches one scratch
        // poison tier 0: panic while holding its lock
        let poisoner = std::thread::scope(|scope| {
            scope
                .spawn(|| {
                    let _guard = pool.tier_lock_for_test(0).lock().unwrap();
                    panic!("poison tier 0");
                })
                .join()
        });
        assert!(poisoner.is_err(), "the poisoning thread must panic");
        // the pool keeps serving: the cached scratch is still reusable
        let s = pool.checkout(50);
        assert_eq!(s.tier(), 0);
        drop(s);
        assert_eq!(pool.hits(), 1);
        assert!(pool.poison_recoveries() >= 1);
        assert!(metrics.lock_recoveries() >= 1);
        assert!(pool.summary().contains("poison_recovered="), "{}", pool.summary());
    }
}
