//! Content-addressed result cache: canonical graph hash → persistence
//! diagrams.
//!
//! The service workload (millions of users resubmitting overlapping
//! ego-nets and snapshots of slowly-mutating networks) repeats inputs
//! constantly. A job's PDs are a pure function of `(graph, filtration,
//! reduction, max_k)` — thread counts, kernels, and scheduling are all
//! proven bit-invariant by the differential suites — so the cache key is
//! exactly that tuple, hashed canonically:
//!
//! * graph: order + the sorted normalized `u < v` edge list (the CSR is
//!   already simple, sorted, and deduplicated, so iteration order is
//!   canonical by construction);
//! * filtration: direction tag + the raw `f64` bit patterns per vertex;
//! * spec: reduction name + `max_k`.
//!
//! Two independent 64-bit FNV-1a streams form a 128-bit key, making an
//! accidental collision across a service lifetime implausible (~2⁻⁶⁴ at
//! a billion distinct entries). Entries are LRU-evicted against a byte
//! budget estimated from diagram payload sizes; hit / miss / eviction /
//! insertion counters are exported on the `/metrics` endpoint.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::complex::{Direction, Filtration};
use crate::graph::Graph;
use crate::homology::Diagram;
use crate::reduce::{Reduction, ReductionReport};

/// 128-bit content address of one job's input tuple.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey(pub u128);

/// One 64-bit FNV-1a stream over `u64` items.
#[derive(Clone, Copy)]
struct Fnv(u64);

impl Fnv {
    const PRIME: u64 = 0x0000_0100_0000_01B3;

    fn new(offset: u64) -> Fnv {
        Fnv(offset)
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        for byte in x.to_le_bytes() {
            self.0 ^= byte as u64;
            self.0 = self.0.wrapping_mul(Fnv::PRIME);
        }
    }
}

/// Canonical content hash of one job input. Deterministic across runs,
/// processes, and machines (no pointer or HashMap iteration order leaks
/// in — everything hashed is already canonically ordered).
pub fn job_key(g: &Graph, f: &Filtration, reduction: Reduction, max_k: usize) -> CacheKey {
    // two independent streams: different offsets AND a per-item mix on
    // the second, so the halves never collide in tandem
    let mut a = Fnv::new(0xCBF2_9CE4_8422_2325);
    let mut b = Fnv::new(0x6C62_272E_07BB_0142);
    let mut put = |x: u64| {
        a.write_u64(x);
        b.write_u64(x.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17));
    };
    put(g.n() as u64);
    put(g.m() as u64);
    for (u, v) in g.edges() {
        put(((u as u64) << 32) | v as u64);
    }
    put(match f.direction() {
        Direction::Sublevel => 1,
        Direction::Superlevel => 2,
    });
    for &x in f.values() {
        put(x.to_bits());
    }
    for byte in reduction.name().bytes() {
        put(byte as u64);
    }
    put(max_k as u64);
    CacheKey(((a.0 as u128) << 64) | b.0 as u128)
}

/// What the cache stores per key: the diagrams plus the reduction report
/// of the cold run, so a hit can synthesize a full [`super::JobResult`].
#[derive(Clone, Debug)]
pub struct CachedResult {
    pub diagrams: Vec<Diagram>,
    pub reduction: ReductionReport,
}

impl CachedResult {
    /// Estimated heap footprint, charged against the byte budget. The
    /// diagram payload dominates; report vectors are charged per element.
    pub fn byte_size(&self) -> usize {
        let diagrams: usize = self
            .diagrams
            .iter()
            .map(|d| d.all_pairs().len() * 16 + 48)
            .sum();
        diagrams
            + self.reduction.rounds.len() * 64
            + self.reduction.shard_sizes.len() * 8
            + 256 // struct + map-entry overhead
    }
}

#[derive(Debug)]
struct Entry {
    result: CachedResult,
    bytes: usize,
    stamp: u64,
}

#[derive(Debug, Default)]
struct Inner {
    map: HashMap<u128, Entry>,
    /// recency index: stamp → key, oldest first
    recency: BTreeMap<u64, u128>,
    clock: u64,
    bytes: usize,
}

/// Point-in-time cache statistics (exported on `/metrics`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub entries: usize,
    pub bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub insertions: u64,
}

/// Bounded, thread-safe, content-addressed LRU result cache.
#[derive(Debug)]
pub struct ResultCache {
    budget: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    insertions: AtomicU64,
}

impl ResultCache {
    /// A cache holding at most `budget_bytes` of estimated payload
    /// (clamped to ≥ 1 so a zero budget degenerates to "cache nothing"
    /// rather than dividing the service's logic).
    pub fn new(budget_bytes: usize) -> ResultCache {
        ResultCache {
            budget: budget_bytes.max(1),
            inner: Mutex::new(Inner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
        }
    }

    /// Lock the interior, recovering from poisoning (the guarded maps
    /// stay structurally valid through a panic elsewhere — same policy as
    /// the scratch pool).
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Look a key up, refreshing its recency. Returns a clone — the
    /// cache stays the owner so eviction never invalidates a caller.
    pub fn get(&self, key: &CacheKey) -> Option<CachedResult> {
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        let Some(entry) = inner.map.get_mut(&key.0) else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        let old = entry.stamp;
        entry.stamp = clock;
        let result = entry.result.clone();
        inner.recency.remove(&old);
        inner.recency.insert(clock, key.0);
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(result)
    }

    /// Insert (or refresh) a result, evicting least-recently-used entries
    /// until the byte budget holds. A result larger than the whole budget
    /// is not cached at all.
    pub fn insert(&self, key: CacheKey, result: CachedResult) {
        let bytes = result.byte_size();
        if bytes > self.budget {
            return;
        }
        let mut inner = self.lock();
        inner.clock += 1;
        let stamp = inner.clock;
        if let Some(old) = inner.map.remove(&key.0) {
            inner.recency.remove(&old.stamp);
            inner.bytes -= old.bytes;
        }
        inner.map.insert(
            key.0,
            Entry {
                result,
                bytes,
                stamp,
            },
        );
        inner.recency.insert(stamp, key.0);
        inner.bytes += bytes;
        self.insertions.fetch_add(1, Ordering::Relaxed);
        while inner.bytes > self.budget {
            let Some((&oldest, &victim)) = inner.recency.iter().next() else {
                break;
            };
            inner.recency.remove(&oldest);
            if let Some(e) = inner.map.remove(&victim) {
                inner.bytes -= e.bytes;
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.lock();
        CacheStats {
            entries: inner.map.len(),
            bytes: inner.bytes,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
        }
    }

    /// One-line summary for the service's final report.
    pub fn summary(&self) -> String {
        let s = self.stats();
        format!(
            "result_cache: entries={} bytes={} hits={} misses={} evictions={} insertions={}",
            s.entries, s.bytes, s.hits, s.misses, s.evictions, s.insertions
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn sample(id_rounds: usize) -> CachedResult {
        CachedResult {
            diagrams: vec![Diagram::new(0, vec![(0.0, 1.0); 8])],
            reduction: ReductionReport {
                vertices_before: 10,
                edges_before: 10,
                vertices_after: 5,
                edges_after: 5,
                reduce_secs: 0.0,
                prunit_secs: 0.0,
                core_secs: 0.0,
                compact_secs: 0.0,
                rounds: vec![],
                prunit_rounds: id_rounds,
                which: Reduction::Combined,
                shard_sizes: vec![],
            },
        }
    }

    #[test]
    fn key_is_deterministic_and_content_addressed() {
        let g1 = gen::barabasi_albert(60, 2, 7);
        let g2 = gen::barabasi_albert(60, 2, 7); // same recipe → same graph
        let f1 = Filtration::degree_superlevel(&g1);
        let f2 = Filtration::degree_superlevel(&g2);
        let k1 = job_key(&g1, &f1, Reduction::Combined, 1);
        let k2 = job_key(&g2, &f2, Reduction::Combined, 1);
        assert_eq!(k1, k2, "identical content must share one address");
        // every component of the tuple perturbs the key
        assert_ne!(k1, job_key(&g1, &f1, Reduction::FixedPoint, 1));
        assert_ne!(k1, job_key(&g1, &f1, Reduction::Combined, 2));
        assert_ne!(
            k1,
            job_key(&g1, &Filtration::degree(&g1), Reduction::Combined, 1),
            "direction flip must change the key"
        );
        let other = gen::barabasi_albert(60, 2, 8);
        assert_ne!(
            k1,
            job_key(&other, &Filtration::degree_superlevel(&other), Reduction::Combined, 1)
        );
    }

    #[test]
    fn get_insert_round_trip_and_counters() {
        let cache = ResultCache::new(1 << 20);
        let g = gen::cycle(12);
        let f = Filtration::degree_superlevel(&g);
        let key = job_key(&g, &f, Reduction::Combined, 1);
        assert!(cache.get(&key).is_none());
        cache.insert(key, sample(1));
        let hit = cache.get(&key).expect("inserted entry must hit");
        assert_eq!(hit.reduction.prunit_rounds, 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
        assert_eq!(s.entries, 1);
        assert!(s.bytes > 0);
        assert!(cache.summary().contains("hits=1"));
    }

    #[test]
    fn lru_eviction_respects_byte_budget_and_order() {
        let unit = sample(0).byte_size();
        // room for exactly 3 entries
        let cache = ResultCache::new(unit * 3 + unit / 2);
        let keys: Vec<CacheKey> = (0..5u64)
            .map(|i| {
                let g = gen::cycle(10 + i as usize);
                let f = Filtration::degree_superlevel(&g);
                job_key(&g, &f, Reduction::Combined, 1)
            })
            .collect();
        for (i, k) in keys.iter().enumerate() {
            cache.insert(*k, sample(i));
        }
        let s = cache.stats();
        assert!(s.bytes <= cache.budget(), "budget must hold after inserts");
        assert_eq!(s.entries, 3);
        assert_eq!(s.evictions, 2);
        // the two oldest are gone, the three newest survive
        assert!(cache.get(&keys[0]).is_none());
        assert!(cache.get(&keys[1]).is_none());
        for k in &keys[2..] {
            assert!(cache.get(k).is_some());
        }
    }

    #[test]
    fn touching_an_entry_saves_it_from_eviction() {
        let unit = sample(0).byte_size();
        let cache = ResultCache::new(unit * 2 + unit / 2);
        let key = |i: usize| {
            let g = gen::cycle(10 + i);
            let f = Filtration::degree_superlevel(&g);
            job_key(&g, &f, Reduction::Combined, 1)
        };
        cache.insert(key(0), sample(0));
        cache.insert(key(1), sample(1));
        assert!(cache.get(&key(0)).is_some()); // refresh 0 → 1 is now LRU
        cache.insert(key(2), sample(2));
        assert!(cache.get(&key(0)).is_some(), "refreshed entry survives");
        assert!(cache.get(&key(1)).is_none(), "stale entry evicted");
    }

    #[test]
    fn oversized_entry_is_not_cached() {
        let cache = ResultCache::new(8);
        let g = gen::cycle(6);
        let f = Filtration::degree_superlevel(&g);
        let key = job_key(&g, &f, Reduction::Combined, 1);
        cache.insert(key, sample(0));
        assert!(cache.get(&key).is_none());
        assert_eq!(cache.stats().insertions, 0);
    }
}
