//! The batch scheduler: bounded job queue (backpressure) + result
//! stream. Job execution lives in [`super::worker`], scratch reuse in
//! [`super::scratch`] — this module only moves jobs and results.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};

use crate::config::CoordinatorConfig;
use crate::error::{Error, Result};
use crate::prune::DominationKernel;

use super::job::{Job, JobResult};
use super::metrics::Metrics;
use super::scratch::ScratchPool;
use super::worker::{execute_job, WorkerScratch};

/// The batch coordinator: owns config, metrics, and the size-tiered
/// scratch pool; `run` executes a batch.
pub struct Coordinator {
    config: CoordinatorConfig,
    metrics: Arc<Metrics>,
    scratch: Arc<ScratchPool>,
}

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        let metrics = Arc::new(Metrics::default());
        // every worker can hold one scratch per tier in flight, so the
        // pool never needs to cache more than `workers` per tier; wire
        // the metrics in so pool-level lock recoveries are visible on
        // the coordinator's summary line
        let scratch = Arc::new(ScratchPool::with_metrics(
            config.workers.max(1),
            Some(Arc::clone(&metrics)),
        ));
        Coordinator {
            config,
            metrics,
            scratch,
        }
    }

    pub fn with_defaults() -> Coordinator {
        Coordinator::new(CoordinatorConfig::default())
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// The shared scratch pool (stats: hits/misses/cached).
    pub fn scratch_pool(&self) -> Arc<ScratchPool> {
        Arc::clone(&self.scratch)
    }

    /// Execute one job inline (public for testing and for single-threaded
    /// callers). Allocates fresh scratch; the pool's worker threads check
    /// tiered scratch out of [`Coordinator::scratch_pool`] instead.
    pub fn execute(job: &Job, worker: usize) -> Result<JobResult> {
        Coordinator::execute_with(&mut WorkerScratch::new(), job, worker)
    }

    /// Execute one job into a caller-held scratch — see
    /// [`super::worker::execute_job`].
    pub fn execute_with(
        scratch: &mut WorkerScratch,
        job: &Job,
        worker: usize,
    ) -> Result<JobResult> {
        execute_job(scratch, job, worker)
    }

    /// Run a batch of jobs from an iterator, streaming results to `sink`
    /// as they complete (out of order). The job queue is bounded at
    /// `queue_depth`, so a slow pool backpressures the producer iterator.
    /// Each worker checks a size-tiered scratch out of the shared pool
    /// per job and configures it with the scheduler's `prune_threads`.
    pub fn run_streaming<I, F>(&self, jobs: I, mut sink: F) -> Result<usize>
    where
        I: Iterator<Item = Job>,
        F: FnMut(JobResult),
    {
        let workers = self.config.workers.max(1);
        let prune_threads = self.config.prune_threads.max(1);
        let kernel = DominationKernel::parse(&self.config.domination_kernel)?;
        let (job_tx, job_rx): (SyncSender<Job>, Receiver<Job>) =
            sync_channel(self.config.queue_depth.max(1));
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = std::sync::mpsc::channel::<Result<JobResult>>();

        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let job_rx = Arc::clone(&job_rx);
                let res_tx = res_tx.clone();
                let metrics = Arc::clone(&self.metrics);
                let pool = Arc::clone(&self.scratch);
                std::thread::spawn(move || loop {
                    let job = {
                        // a peer panicking mid-recv leaves the Receiver
                        // fully usable — recover instead of cascading
                        let guard = job_rx.lock().unwrap_or_else(|e| {
                            metrics.lock_recoveries.fetch_add(1, Ordering::Relaxed);
                            e.into_inner()
                        });
                        guard.recv()
                    };
                    let Ok(job) = job else { break };
                    let (v_in, e_in) = (job.graph.n(), job.graph.m());
                    let mut scratch = pool.checkout(job.graph.n());
                    scratch.reduce.set_prune_threads(prune_threads);
                    scratch.reduce.set_domination_kernel(kernel);
                    let result = execute_job(&mut scratch, &job, w);
                    drop(scratch); // back to its tier
                    match &result {
                        Ok(r) => metrics.record(
                            r.reduction.reduce_secs,
                            r.ph_secs,
                            v_in,
                            r.reduction.vertices_after,
                            e_in,
                            r.reduction.edges_after,
                        ),
                        Err(_) => {
                            metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    if res_tx.send(result).is_err() {
                        break;
                    }
                })
            })
            .collect();
        drop(res_tx);

        // Producer on the current thread; consume results opportunistically
        // to keep the result channel drained. A failed job surfaces as the
        // batch's error after the pool drains — remaining jobs still run.
        let mut submitted = 0usize;
        let mut received = 0usize;
        let mut first_err: Option<Error> = None;
        let mut consume = |r: Result<JobResult>, first_err: &mut Option<Error>| match r {
            Ok(r) => sink(r),
            Err(e) => {
                if first_err.is_none() {
                    *first_err = Some(e);
                }
            }
        };
        for job in jobs {
            self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
            job_tx
                .send(job)
                .map_err(|_| Error::Coordinator("all workers exited early".into()))?;
            submitted += 1;
            while let Ok(r) = res_rx.try_recv() {
                received += 1;
                consume(r, &mut first_err);
            }
        }
        drop(job_tx);
        while let Ok(r) = res_rx.recv() {
            received += 1;
            consume(r, &mut first_err);
        }
        // A panicking worker must not abort the batch: surviving workers
        // have already drained the queue by this point. Count the panics,
        // and only error if jobs were actually lost (a worker died between
        // receiving a job and sending its result) with nothing else to
        // report.
        let mut panicked = 0u64;
        for h in handles {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        if panicked > 0 {
            self.metrics
                .workers_panicked
                .fetch_add(panicked, Ordering::Relaxed);
            if first_err.is_none() && received < submitted {
                first_err = Some(Error::Coordinator(format!(
                    "{panicked} worker(s) panicked; {} job(s) produced no result",
                    submitted - received
                )));
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        debug_assert!(panicked > 0 || submitted == received);
        Ok(received)
    }

    /// Run a batch and collect results sorted by job id.
    pub fn run(&self, jobs: Vec<Job>) -> Result<Vec<JobResult>> {
        let mut out = Vec::with_capacity(jobs.len());
        self.run_streaming(jobs.into_iter(), |r| out.push(r))?;
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobSpec;
    use crate::graph::gen;
    use crate::reduce::Reduction;

    fn cfg(workers: usize, depth: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            workers,
            queue_depth: depth,
            max_k: 1,
            reduction: "prunit+coral".into(),
            seed: 1,
            prune_threads: 1,
            domination_kernel: "auto".into(),
        }
    }

    fn jobs(n: usize) -> Vec<Job> {
        (0..n)
            .map(|i| {
                Job::degree_superlevel(
                    i as u64,
                    gen::barabasi_albert(40 + i, 2, i as u64),
                    JobSpec::default(),
                )
            })
            .collect()
    }

    #[test]
    fn runs_all_jobs_and_sorts() {
        let c = Coordinator::new(cfg(3, 4));
        let res = c.run(jobs(20)).unwrap();
        assert_eq!(res.len(), 20);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.diagrams.len(), 2);
        }
        assert_eq!(c.metrics().completed(), 20);
    }

    #[test]
    fn single_worker_small_queue_backpressure() {
        let c = Coordinator::new(cfg(1, 1));
        let res = c.run(jobs(8)).unwrap();
        assert_eq!(res.len(), 8);
    }

    #[test]
    fn results_match_inline_execution() {
        let c = Coordinator::new(cfg(2, 2));
        let js = jobs(6);
        let inline: Vec<JobResult> = js
            .iter()
            .map(|j| Coordinator::execute(j, 0).unwrap())
            .collect();
        let pooled = c.run(js).unwrap();
        for (a, b) in inline.iter().zip(&pooled) {
            assert_eq!(a.id, b.id);
            for k in 0..a.diagrams.len() {
                assert!(a.diagrams[k].same_as(&b.diagrams[k], 1e-12));
            }
        }
    }

    #[test]
    fn scratch_pool_reuses_across_a_batch() {
        // 20 same-tier jobs on 3 workers: at most `workers` fresh
        // allocations in that tier, everything else a cache hit
        let c = Coordinator::new(cfg(3, 4));
        c.run(jobs(20)).unwrap();
        let pool = c.scratch_pool();
        assert_eq!(pool.hits() + pool.misses(), 20);
        assert!(pool.misses() <= 3, "misses={}", pool.misses());
        assert!(pool.cached() >= 1);
    }

    #[test]
    fn parallel_prunit_config_matches_sequential_results() {
        // the batch outcome is thread-count invariant by construction
        let seq = Coordinator::new(cfg(2, 2));
        let mut par_cfg = cfg(2, 2);
        par_cfg.prune_threads = 4;
        let par = Coordinator::new(par_cfg);
        let a = seq.run(jobs(6)).unwrap();
        let b = par.run(jobs(6)).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.reduction.vertices_after, y.reduction.vertices_after);
            assert_eq!(x.reduction.prunit_rounds, y.reduction.prunit_rounds);
            for k in 0..x.diagrams.len() {
                assert!(x.diagrams[k].same_as(&y.diagrams[k], 0.0));
            }
        }
    }

    #[test]
    fn reduction_spec_respected() {
        let c = Coordinator::new(cfg(2, 4));
        let g = gen::star(30);
        let job = Job::degree_superlevel(
            0,
            g,
            JobSpec {
                max_k: 0,
                reduction: Reduction::Prunit,
            },
        );
        let res = c.run(vec![job]).unwrap();
        assert_eq!(res[0].reduction.which, Reduction::Prunit);
        assert!(res[0].reduction.vertex_reduction_pct() > 80.0);
    }

    #[test]
    fn streaming_sink_sees_everything() {
        let c = Coordinator::new(cfg(2, 2));
        let mut seen = 0usize;
        let n = c
            .run_streaming(jobs(12).into_iter(), |_r| seen += 1)
            .unwrap();
        assert_eq!(n, 12);
        assert_eq!(seen, 12);
    }

    #[test]
    fn empty_batch_is_fine() {
        let c = Coordinator::new(cfg(2, 2));
        assert_eq!(c.run(vec![]).unwrap().len(), 0);
    }

    #[test]
    fn mismatched_filtration_job_fails_batch_with_typed_error() {
        let c = Coordinator::new(cfg(2, 2));
        let bad = Job::new(
            0,
            gen::cycle(5),
            crate::complex::Filtration::constant(3),
            JobSpec::default(),
        );
        let err = c.run(vec![bad]).unwrap_err();
        assert!(matches!(
            err,
            crate::error::Error::FiltrationMismatch { .. }
        ));
        assert_eq!(c.metrics().failed(), 1);
    }

    #[test]
    fn poisoned_scratch_tier_does_not_abort_the_batch() {
        let c = Coordinator::new(cfg(2, 2));
        let pool = c.scratch_pool();
        // poison tier 0 — every `jobs()` graph is small enough to land
        // there — by panicking while holding its lock
        let poisoner = std::thread::scope(|scope| {
            scope
                .spawn(|| {
                    let _guard = pool.tier_lock_for_test(0).lock().unwrap();
                    panic!("poison tier 0");
                })
                .join()
        });
        assert!(poisoner.is_err(), "the poisoning thread must panic");
        // the batch still runs to completion on the recovered pool
        let res = c.run(jobs(8)).unwrap();
        assert_eq!(res.len(), 8);
        assert!(pool.poison_recoveries() >= 1);
        assert!(c.metrics().lock_recoveries() >= 1);
        assert!(c.metrics().summary().contains("lock_recoveries="));
    }

    #[test]
    fn domination_kernel_config_is_threaded_and_invariant() {
        let a = Coordinator::new(cfg(2, 2)).run(jobs(6)).unwrap();
        for pin in ["merge", "bitset"] {
            let mut pinned = cfg(2, 2);
            pinned.domination_kernel = pin.into();
            let b = Coordinator::new(pinned).run(jobs(6)).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(
                    x.reduction.vertices_after, y.reduction.vertices_after,
                    "kernel={pin}"
                );
                assert_eq!(x.reduction.prunit_rounds, y.reduction.prunit_rounds);
                for k in 0..x.diagrams.len() {
                    assert!(x.diagrams[k].same_as(&y.diagrams[k], 0.0), "kernel={pin}");
                }
            }
        }
        // a bogus kernel value is a typed error before any worker spawns
        let mut bad = cfg(1, 1);
        bad.domination_kernel = "simd".into();
        assert!(matches!(
            Coordinator::new(bad).run(jobs(1)),
            Err(crate::error::Error::Parse(_))
        ));
    }

    #[test]
    fn fixed_point_jobs_run_through_the_pool() {
        let c = Coordinator::new(cfg(2, 2));
        let g = gen::barabasi_albert(60, 2, 3);
        let job = Job::degree_superlevel(
            0,
            g,
            JobSpec {
                max_k: 1,
                reduction: Reduction::FixedPoint,
            },
        );
        let res = c.run(vec![job]).unwrap();
        assert_eq!(res[0].reduction.which, Reduction::FixedPoint);
        assert!(res[0].reduction.rounds_run() >= 1);
    }
}
