//! The batch scheduler: bounded job queue (backpressure) + result
//! stream. Job execution and the retry/degradation harness live in
//! [`super::worker`], scratch reuse in [`super::scratch`], journaling in
//! [`super::journal`] — this module only moves jobs, results, and
//! failures.

use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};

use crate::config::CoordinatorConfig;
use crate::error::{Error, Result};
use crate::prune::DominationKernel;

#[cfg(any(test, feature = "faults"))]
use super::faults::FaultPlan;
use super::job::{Job, JobFailure, JobResult};
use super::journal::{Journal, JournalReplay};
use super::metrics::Metrics;
use super::scratch::{top_tier_min_order, ScratchPool};
use super::worker::{
    execute_job, run_job_with_retries, AttemptPolicy, InFlightRegistry, ScratchSource,
    WorkerScratch,
};

/// Everything a fault-tolerant batch produced: successful results
/// (sorted by id) plus the identity, attempt count, and final error of
/// every job that exhausted its retry budget.
#[derive(Debug)]
pub struct BatchOutcome {
    pub results: Vec<JobResult>,
    pub failures: Vec<JobFailure>,
}

/// What [`Coordinator::run_resumable`] learned from the journal before
/// running: how many jobs were skipped as already terminal, and the ids
/// of jobs a previous incarnation submitted but never finished
/// (orphans). Orphans are re-enqueued under their original identity —
/// they appear here so callers can announce the recompute.
#[derive(Debug, Default)]
pub struct ResumeReport {
    pub skipped: usize,
    pub orphaned: Vec<u64>,
}

/// Fold one terminal job verdict into the shared metrics (used by both
/// the pool workers and the dedicated high-tier worker).
fn note_result(
    metrics: &Metrics,
    v_in: usize,
    e_in: usize,
    result: &std::result::Result<JobResult, JobFailure>,
) {
    match result {
        Ok(r) => {
            metrics.record(
                r.reduction.reduce_secs,
                r.ph_secs,
                v_in,
                r.reduction.vertices_after,
                e_in,
                r.reduction.edges_after,
            );
            metrics.record_ph_pairs(r.reduction.ph_apparent_pairs, r.reduction.ph_reduced_pairs);
        }
        Err(_) => {
            metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// The batch coordinator: owns config, metrics, and the size-tiered
/// scratch pool; `run` executes a batch.
pub struct Coordinator {
    config: CoordinatorConfig,
    metrics: Arc<Metrics>,
    scratch: Arc<ScratchPool>,
    /// live attempt registry, installed by the serve watchdog so it can
    /// cancel attempts that overstay their deadline
    inflight: Option<Arc<InFlightRegistry>>,
    /// scripted faults injected into every batch (chaos tests only)
    #[cfg(any(test, feature = "faults"))]
    faults: Option<Arc<FaultPlan>>,
}

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        let metrics = Arc::new(Metrics::default());
        // every worker can hold one scratch per tier in flight, so the
        // pool never needs to cache more than `workers` per tier; wire
        // the metrics in so pool-level lock recoveries are visible on
        // the coordinator's summary line
        let scratch = Arc::new(ScratchPool::with_metrics(
            config.workers.max(1),
            Some(Arc::clone(&metrics)),
        ));
        Coordinator {
            config,
            metrics,
            scratch,
            inflight: None,
            #[cfg(any(test, feature = "faults"))]
            faults: None,
        }
    }

    pub fn with_defaults() -> Coordinator {
        Coordinator::new(CoordinatorConfig::default())
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// The shared scratch pool (stats: hits/misses/cached).
    pub fn scratch_pool(&self) -> Arc<ScratchPool> {
        Arc::clone(&self.scratch)
    }

    /// Install a scripted fault plan for the next batches (chaos tests).
    #[cfg(any(test, feature = "faults"))]
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.faults = Some(Arc::new(plan));
    }

    /// Install a live attempt registry: every attempt of every
    /// subsequent batch registers its cancel token on entry, so a
    /// supervisor thread (the serve watchdog) can cancel attempts that
    /// overstay their deadline.
    pub fn set_inflight_registry(&mut self, registry: Arc<InFlightRegistry>) {
        self.inflight = Some(registry);
    }

    /// Execute one job inline (public for testing and for single-threaded
    /// callers). Allocates fresh scratch; the pool's worker threads check
    /// tiered scratch out of [`Coordinator::scratch_pool`] instead.
    pub fn execute(job: &Job, worker: usize) -> Result<JobResult> {
        Coordinator::execute_with(&mut WorkerScratch::new(), job, worker)
    }

    /// Execute one job into a caller-held scratch — see
    /// [`super::worker::execute_job`].
    pub fn execute_with(
        scratch: &mut WorkerScratch,
        job: &Job,
        worker: usize,
    ) -> Result<JobResult> {
        execute_job(scratch, job, worker)
    }

    /// The shared engine behind every batch entry point: a bounded
    /// `sync_channel` job queue (backpressure against the producer), a
    /// `Mutex<Receiver>` fanning jobs out to `workers` threads, and each
    /// job run through the retry/degradation harness
    /// ([`super::worker::run_job_with_retries`]) — so a failed, timed-out,
    /// or panicking job consumes its retry budget and then surfaces as a
    /// [`JobFailure`] instead of poisoning the batch. Journal records
    /// (submitted/completed/failed) are written on the calling thread.
    ///
    /// Jobs at or above the routing cutoff (`large_job_order`, default:
    /// the first order past the scratch pool's top tier) bypass the pool
    /// queue entirely: a dedicated high-tier worker holds one pinned
    /// [`WorkerScratch`] for the whole batch, so outsized graphs reuse a
    /// single warm arena instead of churning top-tier pool entries that
    /// evict everything else.
    ///
    /// Returns the number of jobs that reached a terminal state. An `Err`
    /// means the batch infrastructure itself failed (bad config, journal
    /// I/O, lost workers) — per-job failures go to `on_failure`.
    pub(crate) fn run_core<I>(
        &self,
        jobs: I,
        on_result: &mut dyn FnMut(JobResult),
        on_failure: &mut dyn FnMut(JobFailure),
        mut journal: Option<&mut Journal>,
    ) -> Result<usize>
    where
        I: Iterator<Item = Job>,
    {
        let workers = self.config.workers.max(1);
        // 0 = adaptive ramp, 1 = inline, T>=2 = pinned (see
        // `ReductionWorkspace::set_prune_threads`); threaded through as-is
        let prune_threads = self.config.prune_threads;
        let large_cutoff = if self.config.large_job_order == 0 {
            top_tier_min_order()
        } else {
            self.config.large_job_order
        };
        let kernel = DominationKernel::parse(&self.config.domination_kernel)?;
        let policy = AttemptPolicy {
            max_retries: self.config.max_retries,
            backoff_ms: self.config.retry_backoff_ms,
            deadline_secs: self.config.job_deadline_secs,
            jitter_seed: self.config.retry_jitter_seed,
            inflight: self.inflight.clone(),
            #[cfg(any(test, feature = "faults"))]
            faults: self.faults.clone(),
        };
        let (job_tx, job_rx): (SyncSender<Job>, Receiver<Job>) =
            sync_channel(self.config.queue_depth.max(1));
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) =
            std::sync::mpsc::channel::<std::result::Result<JobResult, JobFailure>>();

        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let job_rx = Arc::clone(&job_rx);
                let res_tx = res_tx.clone();
                let metrics = Arc::clone(&self.metrics);
                let pool = Arc::clone(&self.scratch);
                let policy = policy.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        // a peer panicking mid-recv leaves the Receiver
                        // fully usable — recover instead of cascading
                        let guard = job_rx.lock().unwrap_or_else(|e| {
                            metrics.lock_recoveries.fetch_add(1, Ordering::Relaxed);
                            e.into_inner()
                        });
                        guard.recv()
                    };
                    let Ok(job) = job else { break };
                    let (v_in, e_in) = (job.graph.n(), job.graph.m());
                    let result = run_job_with_retries(
                        &mut ScratchSource::Pool(&pool),
                        prune_threads,
                        kernel,
                        &policy,
                        &metrics,
                        &job,
                        w,
                    );
                    note_result(&metrics, v_in, e_in, &result);
                    if res_tx.send(result).is_err() {
                        break;
                    }
                })
            })
            .collect();

        // The high-tier lane: outsized jobs go down their own bounded
        // channel to one dedicated worker (index `workers`, one past the
        // pool) that owns its receiver outright — no Mutex — and keeps a
        // single pinned arena alive across the whole batch.
        let (big_tx, big_rx): (SyncSender<Job>, Receiver<Job>) =
            sync_channel(self.config.queue_depth.max(1));
        let big_handle = {
            let res_tx = res_tx.clone();
            let metrics = Arc::clone(&self.metrics);
            let policy = policy.clone();
            std::thread::spawn(move || {
                let mut arena = WorkerScratch::new();
                while let Ok(job) = big_rx.recv() {
                    let (v_in, e_in) = (job.graph.n(), job.graph.m());
                    let result = run_job_with_retries(
                        &mut ScratchSource::Pinned(&mut arena),
                        prune_threads,
                        kernel,
                        &policy,
                        &metrics,
                        &job,
                        workers,
                    );
                    note_result(&metrics, v_in, e_in, &result);
                    if res_tx.send(result).is_err() {
                        break;
                    }
                }
            })
        };
        drop(res_tx);

        // Producer on the current thread; consume results opportunistically
        // to keep the result channel drained. Journal writes stay on this
        // thread so the file needs no locking.
        let mut submitted = 0usize;
        let mut received = 0usize;
        let mut journal_err: Option<Error> = None;
        let mut submit_err: Option<Error> = None;
        let mut handle = |r: std::result::Result<JobResult, JobFailure>,
                          journal: &mut Option<&mut Journal>,
                          journal_err: &mut Option<Error>| {
            match r {
                Ok(res) => {
                    if let Some(j) = journal.as_deref_mut() {
                        if let Err(e) = j.record_completed(&res) {
                            journal_err.get_or_insert(e);
                        }
                    }
                    on_result(res);
                }
                Err(fail) => {
                    if let Some(j) = journal.as_deref_mut() {
                        if let Err(e) = j.record_failed(&fail) {
                            journal_err.get_or_insert(e);
                        }
                    }
                    on_failure(fail);
                }
            }
        };
        for job in jobs {
            if let Some(j) = journal.as_deref_mut() {
                // journal the submission BEFORE the job can run: a job
                // killed in flight must be visible as orphaned on replay
                if let Err(e) = j.record_submitted(&job) {
                    submit_err = Some(e);
                    break;
                }
            }
            let route_large = job.graph.n() >= large_cutoff;
            let sent = if route_large {
                big_tx.send(job).is_ok()
            } else {
                job_tx.send(job).is_ok()
            };
            if !sent {
                submit_err = Some(Error::Coordinator("all workers exited early".into()));
                break;
            }
            if route_large {
                self.metrics
                    .jobs_routed_large
                    .fetch_add(1, Ordering::Relaxed);
            }
            self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
            submitted += 1;
            while let Ok(r) = res_rx.try_recv() {
                received += 1;
                handle(r, &mut journal, &mut journal_err);
            }
        }
        drop(job_tx);
        drop(big_tx);
        while let Ok(r) = res_rx.recv() {
            received += 1;
            handle(r, &mut journal, &mut journal_err);
        }
        let mut panicked = 0u64;
        for h in handles.into_iter().chain(std::iter::once(big_handle)) {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        if panicked > 0 {
            self.metrics
                .workers_panicked
                .fetch_add(panicked, Ordering::Relaxed);
        }
        if let Some(e) = submit_err {
            return Err(e);
        }
        if let Some(e) = journal_err {
            return Err(e);
        }
        // Every submitted job must come back as exactly one result or
        // failure. The attempt harness catches job panics, so worker
        // threads no longer die with their jobs — any imbalance here is a
        // scheduler bug, and the old escape hatch
        // (`debug_assert!(panicked > 0 || ...)`) would have hidden it.
        assert!(
            submitted == received,
            "scheduler lost {} job(s): submitted={submitted} received={received} \
             worker_threads_died={panicked}",
            submitted - received,
        );
        Ok(received)
    }

    /// Run a batch of jobs from an iterator, streaming results to `sink`
    /// as they complete (out of order). The job queue is bounded at
    /// `queue_depth`, so a slow pool backpressures the producer iterator.
    /// Each worker checks a size-tiered scratch out of the shared pool
    /// per job; failed or timed-out jobs are retried with escalating
    /// reductions up to `max_retries` times. A job that still fails
    /// surfaces as the batch's error after everything else ran — use
    /// [`Coordinator::run_with_failures`] to keep partial results.
    pub fn run_streaming<I, F>(&self, jobs: I, mut sink: F) -> Result<usize>
    where
        I: Iterator<Item = Job>,
        F: FnMut(JobResult),
    {
        let mut first_fail: Option<JobFailure> = None;
        let received = self.run_core(
            jobs,
            &mut |r| sink(r),
            &mut |f| {
                if first_fail.is_none() {
                    first_fail = Some(f);
                }
            },
            None,
        )?;
        if let Some(f) = first_fail {
            return Err(f.error);
        }
        Ok(received)
    }

    /// Run a batch and collect results sorted by job id.
    pub fn run(&self, jobs: Vec<Job>) -> Result<Vec<JobResult>> {
        let mut out = Vec::with_capacity(jobs.len());
        self.run_streaming(jobs.into_iter(), |r| out.push(r))?;
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Run a batch keeping partial results: failed jobs are returned by
    /// identity in [`BatchOutcome::failures`] instead of aborting the
    /// batch. With a journal, every submission/completion/failure is
    /// persisted as it happens.
    pub fn run_with_failures(
        &self,
        jobs: Vec<Job>,
        mut journal: Option<&mut Journal>,
    ) -> Result<BatchOutcome> {
        let mut results = Vec::with_capacity(jobs.len());
        let mut failures = Vec::new();
        self.run_core(
            jobs.into_iter(),
            &mut |r| results.push(r),
            &mut |f| failures.push(f),
            journal.as_deref_mut(),
        )?;
        results.sort_by_key(|r| r.id);
        failures.sort_by_key(|f| f.id);
        Ok(BatchOutcome { results, failures })
    }

    /// [`Coordinator::run_with_failures`] against a persistent journal at
    /// `path`: replay it first, skip jobs already completed by an earlier
    /// incarnation of this batch, and append this run's records to the
    /// same file. Returns the outcome plus a [`ResumeReport`] with the
    /// skip count and the ids of orphaned jobs (submitted by the earlier
    /// incarnation, never finished) that this run re-executes.
    pub fn run_resumable(
        &self,
        jobs: Vec<Job>,
        path: impl AsRef<Path>,
    ) -> Result<(BatchOutcome, ResumeReport)> {
        let replay = JournalReplay::load(&path)?;
        // an always-on service resumes the same journal indefinitely:
        // compact superseded history once the file outgrows the
        // configured threshold (0 disables), before appending to it
        if self.config.journal_compact_bytes > 0 {
            Journal::compact_if_larger(&path, self.config.journal_compact_bytes)?;
        }
        let mut journal = Journal::open(&path)?;
        let before = jobs.len();
        let orphan_ids = replay.orphaned();
        let todo: Vec<Job> = jobs.into_iter().filter(|j| !replay.is_done(j.id)).collect();
        let report = ResumeReport {
            skipped: before - todo.len(),
            orphaned: todo
                .iter()
                .map(|j| j.id)
                .filter(|id| orphan_ids.contains(id))
                .collect(),
        };
        let outcome = self.run_with_failures(todo, Some(&mut journal))?;
        Ok((outcome, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobSpec;
    use crate::graph::gen;
    use crate::reduce::Reduction;
    use std::time::Duration;

    fn cfg(workers: usize, depth: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            workers,
            queue_depth: depth,
            max_k: 1,
            reduction: "prunit+coral".into(),
            seed: 1,
            prune_threads: 1,
            domination_kernel: "auto".into(),
            job_deadline_secs: 0.0,
            max_retries: 2,
            retry_backoff_ms: 0,
            retry_jitter_seed: 0,
            large_job_order: 0,
            journal_compact_bytes: 0,
            ph_algorithm: "twist".into(),
            ph_threads: 1,
        }
    }

    fn jobs(n: usize) -> Vec<Job> {
        (0..n)
            .map(|i| {
                Job::degree_superlevel(
                    i as u64,
                    gen::barabasi_albert(40 + i, 2, i as u64),
                    JobSpec::default(),
                )
            })
            .collect()
    }

    fn tmp_journal(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!(
            "coraltda-sched-{tag}-{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn runs_all_jobs_and_sorts() {
        let c = Coordinator::new(cfg(3, 4));
        let res = c.run(jobs(20)).unwrap();
        assert_eq!(res.len(), 20);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.diagrams.len(), 2);
            assert_eq!(r.attempts, 1);
        }
        assert_eq!(c.metrics().completed(), 20);
        assert_eq!(c.metrics().jobs_retried(), 0);
    }

    #[test]
    fn single_worker_small_queue_backpressure() {
        let c = Coordinator::new(cfg(1, 1));
        let res = c.run(jobs(8)).unwrap();
        assert_eq!(res.len(), 8);
    }

    #[test]
    fn bounded_queue_backpressures_the_producer() {
        // queue_depth=1, workers=1: when the iterator yields job i, at
        // most 3 earlier jobs can be unaccounted for (one queued, one in
        // the worker, one completed-but-undrained is impossible since
        // metrics.record precedes the result send). Slow the worker down
        // with an injected per-round delay to make any backpressure bug
        // (e.g. an unbounded queue) actually observable.
        let mut c = Coordinator::new(cfg(1, 1));
        let mut plan = FaultPlan::new();
        for id in 0..10u64 {
            plan = plan.delay_rounds(id, Duration::from_millis(2));
        }
        c.set_fault_plan(plan);
        let metrics = c.metrics();
        let pulled = std::cell::Cell::new(0usize);
        let producer = (0..10u64).map(|i| {
            pulled.set(pulled.get() + 1);
            let in_flight = pulled.get() - metrics.completed() as usize;
            assert!(
                in_flight <= 3,
                "bounded queue must throttle the producer: in_flight={in_flight}"
            );
            Job::degree_superlevel(i, gen::barabasi_albert(40, 2, i), JobSpec::default())
        });
        let n = c.run_streaming(producer, |_r| {}).unwrap();
        assert_eq!(n, 10);
        assert_eq!(pulled.get(), 10);
    }

    #[test]
    fn results_match_inline_execution() {
        let c = Coordinator::new(cfg(2, 2));
        let js = jobs(6);
        let inline: Vec<JobResult> = js
            .iter()
            .map(|j| Coordinator::execute(j, 0).unwrap())
            .collect();
        let pooled = c.run(js).unwrap();
        for (a, b) in inline.iter().zip(&pooled) {
            assert_eq!(a.id, b.id);
            for k in 0..a.diagrams.len() {
                assert!(a.diagrams[k].same_as(&b.diagrams[k], 1e-12));
            }
        }
    }

    #[test]
    fn scratch_pool_reuses_across_a_batch() {
        // 20 same-tier jobs on 3 workers: at most `workers` fresh
        // allocations in that tier, everything else a cache hit
        let c = Coordinator::new(cfg(3, 4));
        c.run(jobs(20)).unwrap();
        let pool = c.scratch_pool();
        assert_eq!(pool.hits() + pool.misses(), 20);
        assert!(pool.misses() <= 3, "misses={}", pool.misses());
        assert!(pool.cached() >= 1);
    }

    #[test]
    fn parallel_prunit_config_matches_sequential_results() {
        // the batch outcome is thread-count invariant by construction
        let seq = Coordinator::new(cfg(2, 2));
        let mut par_cfg = cfg(2, 2);
        par_cfg.prune_threads = 4;
        let par = Coordinator::new(par_cfg);
        let a = seq.run(jobs(6)).unwrap();
        let b = par.run(jobs(6)).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.reduction.vertices_after, y.reduction.vertices_after);
            assert_eq!(x.reduction.prunit_rounds, y.reduction.prunit_rounds);
            for k in 0..x.diagrams.len() {
                assert!(x.diagrams[k].same_as(&y.diagrams[k], 0.0));
            }
        }
    }

    #[test]
    fn reduction_spec_respected() {
        let c = Coordinator::new(cfg(2, 4));
        let g = gen::star(30);
        let job = Job::degree_superlevel(
            0,
            g,
            JobSpec {
                max_k: 0,
                reduction: Reduction::Prunit,
                sharded: false,
                ..JobSpec::default()
            },
        );
        let res = c.run(vec![job]).unwrap();
        assert_eq!(res[0].reduction.which, Reduction::Prunit);
        assert!(res[0].reduction.vertex_reduction_pct() > 80.0);
    }

    #[test]
    fn streaming_sink_sees_everything() {
        let c = Coordinator::new(cfg(2, 2));
        let mut seen = 0usize;
        let n = c
            .run_streaming(jobs(12).into_iter(), |_r| seen += 1)
            .unwrap();
        assert_eq!(n, 12);
        assert_eq!(seen, 12);
    }

    #[test]
    fn empty_batch_is_fine() {
        let c = Coordinator::new(cfg(2, 2));
        assert_eq!(c.run(vec![]).unwrap().len(), 0);
    }

    #[test]
    fn mismatched_filtration_job_fails_batch_with_typed_error() {
        let c = Coordinator::new(cfg(2, 2));
        let bad = Job::new(
            0,
            gen::cycle(5),
            crate::complex::Filtration::constant(3),
            JobSpec::default(),
        );
        let err = c.run(vec![bad]).unwrap_err();
        assert!(matches!(
            err,
            crate::error::Error::FiltrationMismatch { .. }
        ));
        assert_eq!(c.metrics().failed(), 1);
        // structural errors are permanent: the retry budget is untouched
        assert_eq!(c.metrics().jobs_retried(), 0);
    }

    #[test]
    fn injected_faults_retry_to_success_in_a_batch() {
        let mut c = Coordinator::new(cfg(2, 2));
        c.set_fault_plan(FaultPlan::new().panic_on(3, 0).error_on(5, 0));
        let res = c.run(jobs(8)).unwrap();
        assert_eq!(res.len(), 8);
        let m = c.metrics();
        assert_eq!(m.completed(), 8);
        assert_eq!(m.failed(), 0);
        assert_eq!(m.jobs_retried(), 2);
        assert_eq!(m.jobs_panicked(), 1);
        assert_eq!(m.jobs_degraded(), 2);
        assert_eq!(
            m.workers_panicked(),
            0,
            "a job panic must not kill its worker thread"
        );
        let r3 = res.iter().find(|r| r.id == 3).unwrap();
        assert_eq!(r3.attempts, 2);
        assert!(r3.outcome.is_degraded());
        let summary = m.summary();
        assert!(summary.contains("retries=2"), "{summary}");
        assert!(summary.contains("job_panics=1"), "{summary}");
    }

    #[test]
    fn run_with_failures_surfaces_failed_job_identity() {
        let mut c = Coordinator::new(cfg(2, 2));
        c.set_fault_plan(FaultPlan::new().error_always(4));
        let out = c.run_with_failures(jobs(8), None).unwrap();
        assert_eq!(out.results.len(), 7);
        assert!(out.results.iter().all(|r| r.id != 4));
        assert_eq!(out.failures.len(), 1);
        assert_eq!(out.failures[0].id, 4);
        assert_eq!(out.failures[0].attempts, 3, "max_retries=2 → 3 attempts");
        assert!(matches!(
            out.failures[0].error,
            crate::error::Error::Injected(_)
        ));
        assert_eq!(c.metrics().failed(), 1);
        assert_eq!(c.metrics().completed(), 7);
    }

    #[test]
    fn deadline_misses_are_counted_and_surfaced() {
        let mut config = cfg(1, 2);
        config.job_deadline_secs = 0.005;
        config.max_retries = 1;
        let mut c = Coordinator::new(config);
        // every PrunIT round of job 0 sleeps 40ms — both attempts blow
        // the 5ms deadline at their first round checkpoint
        c.set_fault_plan(FaultPlan::new().delay_rounds(0, Duration::from_millis(40)));
        let out = c.run_with_failures(jobs(1), None).unwrap();
        assert!(out.results.is_empty());
        assert_eq!(out.failures.len(), 1);
        assert_eq!(out.failures[0].id, 0);
        assert_eq!(out.failures[0].attempts, 2);
        assert!(matches!(
            out.failures[0].error,
            crate::error::Error::DeadlineExceeded { .. }
        ));
        let m = c.metrics();
        assert_eq!(m.deadline_misses(), 2);
        assert_eq!(m.jobs_retried(), 1);
        assert!(m.summary().contains("deadline_misses=2"), "{}", m.summary());
    }

    #[test]
    fn journaled_batch_resumes_without_recompute() {
        let path = tmp_journal("resume");
        {
            let mut c = Coordinator::new(cfg(2, 2));
            c.set_fault_plan(FaultPlan::new().error_always(2));
            let (out, resume) = c.run_resumable(jobs(6), &path).unwrap();
            assert_eq!(resume.skipped, 0);
            assert!(resume.orphaned.is_empty());
            assert_eq!(out.results.len(), 5);
            assert_eq!(out.failures.len(), 1);
            assert_eq!(out.failures[0].id, 2);
        }
        // resume with the fault gone: completed ids are skipped, only
        // the failed id re-runs — no duplicates, no recompute
        {
            let c = Coordinator::new(cfg(2, 2));
            let (out, resume) = c.run_resumable(jobs(6), &path).unwrap();
            assert_eq!(resume.skipped, 5);
            assert!(resume.orphaned.is_empty(), "failed ids are terminal, not orphaned");
            assert_eq!(out.results.len(), 1);
            assert_eq!(out.results[0].id, 2);
            assert!(out.failures.is_empty());
        }
        let replay = JournalReplay::load(&path).unwrap();
        assert_eq!(replay.completed.len(), 6);
        assert!(replay.failed.is_empty(), "resume cleared the failure");
        assert!(replay.orphaned().is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn poisoned_scratch_tier_does_not_abort_the_batch() {
        let c = Coordinator::new(cfg(2, 2));
        let pool = c.scratch_pool();
        // poison tier 0 — every `jobs()` graph is small enough to land
        // there — by panicking while holding its lock
        let poisoner = std::thread::scope(|scope| {
            scope
                .spawn(|| {
                    let _guard = pool.tier_lock_for_test(0).lock().unwrap();
                    panic!("poison tier 0");
                })
                .join()
        });
        assert!(poisoner.is_err(), "the poisoning thread must panic");
        // the batch still runs to completion on the recovered pool
        let res = c.run(jobs(8)).unwrap();
        assert_eq!(res.len(), 8);
        assert!(pool.poison_recoveries() >= 1);
        assert!(c.metrics().lock_recoveries() >= 1);
        assert!(c.metrics().summary().contains("lock_recoveries="));
    }

    #[test]
    fn domination_kernel_config_is_threaded_and_invariant() {
        let a = Coordinator::new(cfg(2, 2)).run(jobs(6)).unwrap();
        for pin in ["merge", "bitset"] {
            let mut pinned = cfg(2, 2);
            pinned.domination_kernel = pin.into();
            let b = Coordinator::new(pinned).run(jobs(6)).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(
                    x.reduction.vertices_after, y.reduction.vertices_after,
                    "kernel={pin}"
                );
                assert_eq!(x.reduction.prunit_rounds, y.reduction.prunit_rounds);
                for k in 0..x.diagrams.len() {
                    assert!(x.diagrams[k].same_as(&y.diagrams[k], 0.0), "kernel={pin}");
                }
            }
        }
        // a bogus kernel value is a typed error before any worker spawns
        let mut bad = cfg(1, 1);
        bad.domination_kernel = "simd".into();
        assert!(matches!(
            Coordinator::new(bad).run(jobs(1)),
            Err(crate::error::Error::Parse(_))
        ));
    }

    #[test]
    fn fixed_point_jobs_run_through_the_pool() {
        let c = Coordinator::new(cfg(2, 2));
        let g = gen::barabasi_albert(60, 2, 3);
        let job = Job::degree_superlevel(
            0,
            g,
            JobSpec {
                max_k: 1,
                reduction: Reduction::FixedPoint,
                sharded: false,
                ..JobSpec::default()
            },
        );
        let res = c.run(vec![job]).unwrap();
        assert_eq!(res[0].reduction.which, Reduction::FixedPoint);
        assert!(res[0].reduction.rounds_run() >= 1);
    }

    #[test]
    fn outsized_jobs_route_to_the_dedicated_high_tier_worker() {
        let mut config = cfg(2, 2);
        // lower the cutoff so the two largest jobs (50 and 51 vertices)
        // count as outsized
        config.large_job_order = 50;
        let c = Coordinator::new(config);
        let res = c.run(jobs(12)).unwrap();
        assert_eq!(res.len(), 12);
        assert_eq!(c.metrics().routed_large(), 2);
        // routed jobs never touch the scratch pool...
        let pool = c.scratch_pool();
        assert_eq!(pool.hits() + pool.misses(), 10);
        // ...and run on the dedicated worker, one index past the pool
        for r in &res {
            if r.id >= 10 {
                assert_eq!(r.worker, 2, "id={}", r.id);
            } else {
                assert!(r.worker < 2, "id={}", r.id);
            }
        }
        // routing is an execution detail: diagrams match inline execution
        let inline = Coordinator::execute(&jobs(12)[11], 0).unwrap();
        let routed = res.iter().find(|r| r.id == 11).unwrap();
        for k in 0..inline.diagrams.len() {
            assert!(inline.diagrams[k].same_as(&routed.diagrams[k], 0.0));
        }
        assert!(c.metrics().summary().contains("routed_large=2"));
    }

    #[test]
    fn default_cutoff_is_the_top_pool_tier() {
        // large_job_order=0 resolves to the first order past the pool's
        // top tier — every job in this batch is far below it
        let c = Coordinator::new(cfg(2, 2));
        c.run(jobs(4)).unwrap();
        assert_eq!(c.metrics().routed_large(), 0);
        assert!(top_tier_min_order() > 1_000_000);
    }

    #[test]
    fn adaptive_prune_threads_config_matches_sequential_results() {
        // prune_threads=0 (adaptive ramp) must be wall-time-only: the
        // batch outcome is identical to the sequential run
        let seq = Coordinator::new(cfg(2, 2));
        let mut auto_cfg = cfg(2, 2);
        auto_cfg.prune_threads = 0;
        let auto = Coordinator::new(auto_cfg);
        let a = seq.run(jobs(6)).unwrap();
        let b = auto.run(jobs(6)).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.reduction.vertices_after, y.reduction.vertices_after);
            assert_eq!(x.reduction.prunit_rounds, y.reduction.prunit_rounds);
            for k in 0..x.diagrams.len() {
                assert!(x.diagrams[k].same_as(&y.diagrams[k], 0.0));
            }
        }
    }

    #[test]
    fn orphaned_jobs_are_reported_and_rerun() {
        let path = tmp_journal("orphan");
        let c = Coordinator::new(cfg(2, 2));
        let (out, resume) = c.run_resumable(jobs(2), &path).unwrap();
        assert_eq!(out.results.len(), 2);
        assert!(resume.orphaned.is_empty());
        // simulate an incarnation killed mid-flight: id 2 journaled as
        // submitted but with no terminal record
        {
            let mut j = Journal::open(&path).unwrap();
            j.record_submitted(&jobs(3)[2]).unwrap();
        }
        let (out, resume) = c.run_resumable(jobs(4), &path).unwrap();
        assert_eq!(resume.skipped, 2);
        assert_eq!(resume.orphaned, vec![2]);
        let ids: Vec<u64> = out.results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3], "the orphan re-ran alongside the new job");
        let replay = JournalReplay::load(&path).unwrap();
        assert!(replay.orphaned().is_empty(), "resume cleared the orphan");
        let _ = std::fs::remove_file(&path);
    }
}
