//! Admission control and load shedding for the always-on service.
//!
//! `repro batch` is protected from overload only by its bounded job
//! queue: the producer blocks. A daemon cannot block its ingest loop —
//! sockets would time out, the journal would starve, and one oversized
//! graph would wedge everything behind it. The admission controller
//! instead decides *before* a job enters the queue, in order:
//!
//! 1. **Memory** — the job's estimated working set
//!    ([`super::scratch::estimate_job_bytes`], tier-rounded against the
//!    `ScratchPool` accounting) is charged against a budget shared by
//!    every admitted-but-unfinished job. A job that cannot fit — alone
//!    or alongside the in-flight set — is **shed** with
//!    [`crate::error::Error::Overloaded`]: degrading the spec does not
//!    shrink the arenas, so memory pressure is never degradable.
//! 2. **Queue depth** — pending jobs at or past `max_pending` shed
//!    unconditionally; from `shed_pending` up, a linear priority ramp
//!    sheds lowest-priority work first (the required priority rises from
//!    0 at `shed_pending` to [`MAX_PRIORITY`] at `max_pending`).
//! 3. **CPU** — when the estimated backlog (pending × observed mean job
//!    seconds) exceeds `cpu_pressure_secs`, the job is **admitted
//!    degraded**: the service forces the cheapest exact spec
//!    (`FixedPoint` reduction + sharded execution) instead of rejecting,
//!    because CPU pressure clears on its own — memory pressure does not.
//!
//! Shedding is not failure: a shed job was never executed and the client
//! is told to resubmit later. `Error::Overloaded` is deliberately not
//! transient (see `Error::is_transient`) so the retry ladder never
//! re-enters the queue the controller just protected.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::scratch::estimate_job_bytes;

/// Priority ceiling of the shed ramp (priorities are `0..=MAX_PRIORITY`,
/// higher = keep longer under load).
pub const MAX_PRIORITY: u8 = 10;

/// Default priority for job specs that don't state one: the middle of
/// the ramp, so explicit low-priority bulk work sheds before it and
/// explicit high-priority probes outlive it.
pub const DEFAULT_PRIORITY: u8 = MAX_PRIORITY / 2;

/// Tunable admission thresholds (the `service.*` config keys).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionPolicy {
    /// Hard cap on admitted-but-unfinished jobs; at or past it everything
    /// sheds regardless of priority.
    pub max_pending: usize,
    /// Pending count where the priority shed ramp starts.
    pub shed_pending: usize,
    /// Byte budget for the estimated working sets of all in-flight jobs.
    pub memory_budget_bytes: usize,
    /// Estimated backlog seconds past which new jobs are admitted only
    /// with the degraded (FixedPoint + sharded) spec. `0` disables the
    /// CPU check.
    pub cpu_pressure_secs: f64,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy {
            max_pending: 256,
            shed_pending: 128,
            memory_budget_bytes: 2 << 30, // 2 GiB of estimated working set
            cpu_pressure_secs: 30.0,
        }
    }
}

/// What the controller decided for one offered job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Run as requested. `charged_bytes` was charged to the memory
    /// budget; pass it back to [`AdmissionController::release`] when the
    /// job finishes (success *or* failure).
    Admit { charged_bytes: usize },
    /// Run, but with the spec forced to FixedPoint + sharded (CPU
    /// pressure). Same release contract as `Admit`.
    Degrade { charged_bytes: usize },
    /// Rejected before execution; nothing was charged. The reason is the
    /// `Error::Overloaded` payload.
    Shed { reason: String },
}

/// Shared admission state: lock-free counters so the ingest thread never
/// blocks on the workers.
#[derive(Debug)]
pub struct AdmissionController {
    policy: AdmissionPolicy,
    pending: AtomicUsize,
    inflight_bytes: AtomicUsize,
    /// mean-job-cost observation stream (microseconds / count)
    observed_us: AtomicU64,
    observed_jobs: AtomicU64,
}

impl AdmissionController {
    pub fn new(policy: AdmissionPolicy) -> AdmissionController {
        AdmissionController {
            policy,
            pending: AtomicUsize::new(0),
            inflight_bytes: AtomicUsize::new(0),
            observed_us: AtomicU64::new(0),
            observed_jobs: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> &AdmissionPolicy {
        &self.policy
    }

    /// Jobs admitted and not yet released.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Relaxed)
    }

    /// Estimated bytes charged by admitted-but-unfinished jobs.
    pub fn inflight_bytes(&self) -> usize {
        self.inflight_bytes.load(Ordering::Relaxed)
    }

    /// Mean observed job seconds (0 until the first completion).
    pub fn mean_job_secs(&self) -> f64 {
        let jobs = self.observed_jobs.load(Ordering::Relaxed);
        if jobs == 0 {
            return 0.0;
        }
        self.observed_us.load(Ordering::Relaxed) as f64 / 1e6 / jobs as f64
    }

    /// Estimated seconds of queued work: pending × mean job cost.
    pub fn backlog_secs(&self) -> f64 {
        self.pending() as f64 * self.mean_job_secs()
    }

    /// Feed one completed job's wall seconds into the cost model.
    pub fn observe_job_secs(&self, secs: f64) {
        self.observed_us
            .fetch_add((secs.max(0.0) * 1e6) as u64, Ordering::Relaxed);
        self.observed_jobs.fetch_add(1, Ordering::Relaxed);
    }

    /// The priority a job must meet to be admitted at `pending` depth:
    /// 0 below `shed_pending`, rising linearly to [`MAX_PRIORITY`] + 1
    /// (shed everything) at `max_pending`.
    fn required_priority(&self, pending: usize) -> u32 {
        let p = &self.policy;
        if pending < p.shed_pending {
            return 0;
        }
        if pending >= p.max_pending {
            return MAX_PRIORITY as u32 + 1;
        }
        let span = (p.max_pending - p.shed_pending).max(1);
        // bites at 1 from shed_pending, tops out at MAX_PRIORITY just
        // below max_pending — so max-priority work is only ever shed by
        // the hard cap above
        (1 + ((pending - p.shed_pending) * MAX_PRIORITY as usize) / span) as u32
    }

    /// Decide one offered job of `order` vertices / `edges` edges at
    /// `priority`. On `Admit`/`Degrade` the memory charge is already
    /// applied — the caller owes a matching [`release`](Self::release).
    pub fn admit(&self, order: usize, edges: usize, priority: u8) -> AdmissionDecision {
        let p = &self.policy;
        let bytes = estimate_job_bytes(order, edges);
        if bytes > p.memory_budget_bytes {
            return AdmissionDecision::Shed {
                reason: format!(
                    "job working set ~{bytes}B exceeds the service memory budget \
                     {}B even when run alone",
                    p.memory_budget_bytes
                ),
            };
        }
        let pending = self.pending();
        let inflight = self.inflight_bytes();
        if inflight + bytes > p.memory_budget_bytes {
            return AdmissionDecision::Shed {
                reason: format!(
                    "memory budget: {inflight}B in flight + ~{bytes}B would exceed {}B",
                    p.memory_budget_bytes
                ),
            };
        }
        let required = self.required_priority(pending);
        if (priority.min(MAX_PRIORITY) as u32) < required {
            return AdmissionDecision::Shed {
                reason: format!(
                    "queue depth {pending}: priority {priority} below the shed \
                     threshold {required}"
                ),
            };
        }
        self.pending.fetch_add(1, Ordering::Relaxed);
        self.inflight_bytes.fetch_add(bytes, Ordering::Relaxed);
        if p.cpu_pressure_secs > 0.0 && self.backlog_secs() > p.cpu_pressure_secs {
            AdmissionDecision::Degrade {
                charged_bytes: bytes,
            }
        } else {
            AdmissionDecision::Admit {
                charged_bytes: bytes,
            }
        }
    }

    /// Release one admitted job's charge (call exactly once per
    /// `Admit`/`Degrade`, whatever the job's outcome).
    pub fn release(&self, charged_bytes: usize) {
        self.pending.fetch_sub(1, Ordering::Relaxed);
        self.inflight_bytes.fetch_sub(charged_bytes, Ordering::Relaxed);
    }

    /// One-line summary for the final service report.
    pub fn summary(&self) -> String {
        format!(
            "admission: pending={} inflight_bytes={} backlog_secs={:.3} mean_job_secs={:.4}",
            self.pending(),
            self.inflight_bytes(),
            self.backlog_secs(),
            self.mean_job_secs()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_pending: usize, shed_pending: usize, mem: usize, cpu: f64) -> AdmissionPolicy {
        AdmissionPolicy {
            max_pending,
            shed_pending,
            memory_budget_bytes: mem,
            cpu_pressure_secs: cpu,
        }
    }

    fn charged(d: &AdmissionDecision) -> usize {
        match d {
            AdmissionDecision::Admit { charged_bytes }
            | AdmissionDecision::Degrade { charged_bytes } => *charged_bytes,
            AdmissionDecision::Shed { .. } => panic!("expected an admit, got {d:?}"),
        }
    }

    #[test]
    fn admits_when_idle_and_charges_the_budget() {
        let c = AdmissionController::new(AdmissionPolicy::default());
        let d = c.admit(100, 200, DEFAULT_PRIORITY);
        let bytes = charged(&d);
        assert_eq!(bytes, estimate_job_bytes(100, 200));
        assert_eq!(c.pending(), 1);
        assert_eq!(c.inflight_bytes(), bytes);
        c.release(bytes);
        assert_eq!(c.pending(), 0);
        assert_eq!(c.inflight_bytes(), 0);
    }

    #[test]
    fn oversized_job_is_shed_even_when_idle() {
        let c = AdmissionController::new(policy(16, 8, 1 << 16, 0.0));
        match c.admit(5_000_000, 0, MAX_PRIORITY) {
            AdmissionDecision::Shed { reason } => {
                assert!(reason.contains("even when run alone"), "{reason}")
            }
            other => panic!("oversized job must shed, got {other:?}"),
        }
        assert_eq!(c.pending(), 0, "a shed job charges nothing");
    }

    #[test]
    fn memory_pressure_sheds_rather_than_degrades() {
        // budget fits ~2 tier-0 jobs
        let c = AdmissionController::new(policy(64, 32, estimate_job_bytes(10, 0) * 2, 0.0));
        let a = c.admit(10, 0, MAX_PRIORITY);
        let b = c.admit(10, 0, MAX_PRIORITY);
        charged(&a);
        charged(&b);
        match c.admit(10, 0, MAX_PRIORITY) {
            AdmissionDecision::Shed { reason } => assert!(reason.contains("memory budget")),
            other => panic!("memory-bound job must shed, not {other:?}"),
        }
        // releasing one readmits
        c.release(charged(&a));
        charged(&c.admit(10, 0, 0));
    }

    #[test]
    fn queue_ramp_sheds_lowest_priority_first() {
        let c = AdmissionController::new(policy(8, 4, usize::MAX, 0.0));
        // fill to the ramp start
        for _ in 0..4 {
            charged(&c.admit(10, 0, 0));
        }
        // at pending=4 the ramp bites: priority 0 sheds, high priority passes
        assert!(matches!(
            c.admit(10, 0, 0),
            AdmissionDecision::Shed { .. }
        ));
        charged(&c.admit(10, 0, MAX_PRIORITY));
        // required priority grows with depth until the hard cap sheds all
        while c.pending() < 8 {
            charged(&c.admit(10, 0, MAX_PRIORITY));
        }
        assert!(matches!(
            c.admit(10, 0, MAX_PRIORITY),
            AdmissionDecision::Shed { .. }
        ));
    }

    #[test]
    fn required_priority_ramp_is_monotone() {
        let c = AdmissionController::new(policy(100, 50, usize::MAX, 0.0));
        let mut last = 0;
        for pending in 0..110 {
            let req = c.required_priority(pending);
            assert!(req >= last, "ramp must be monotone");
            last = req;
        }
        assert_eq!(c.required_priority(0), 0);
        assert_eq!(c.required_priority(49), 0);
        assert!(c.required_priority(50) >= 1);
        assert_eq!(c.required_priority(100), MAX_PRIORITY as u32 + 1);
    }

    #[test]
    fn cpu_pressure_degrades_instead_of_shedding() {
        let c = AdmissionController::new(policy(1000, 900, usize::MAX, 1.0));
        // teach the cost model that jobs are slow: mean 1 s
        c.observe_job_secs(1.0);
        charged(&c.admit(10, 0, DEFAULT_PRIORITY)); // backlog now 1 s — at the limit
        let d = c.admit(10, 0, DEFAULT_PRIORITY); // backlog 2 s > 1 s
        assert!(
            matches!(d, AdmissionDecision::Degrade { .. }),
            "CPU pressure must degrade, got {d:?}"
        );
        assert_eq!(c.pending(), 2, "degraded jobs are admitted");
    }

    #[test]
    fn backlog_estimate_tracks_observations() {
        let c = AdmissionController::new(AdmissionPolicy::default());
        assert_eq!(c.backlog_secs(), 0.0);
        c.observe_job_secs(0.5);
        c.observe_job_secs(1.5);
        assert!((c.mean_job_secs() - 1.0).abs() < 1e-6);
        charged(&c.admit(10, 0, DEFAULT_PRIORITY));
        assert!((c.backlog_secs() - 1.0).abs() < 1e-6);
        assert!(c.summary().contains("pending=1"), "{}", c.summary());
    }
}
