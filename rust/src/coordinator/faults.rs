//! Deterministic fault injection for the chaos suite. Compiled only
//! under `cfg(test)` or the `faults` cargo feature — release builds
//! carry zero injection branches.
//!
//! A [`FaultPlan`] is a static script keyed on `(job id, attempt)`: it
//! can panic an attempt, fail it with a typed [`Error::Injected`], or
//! slow every PrunIT/fixed-point round of a job by a fixed delay (which,
//! combined with a short deadline, deterministically forces
//! `Error::DeadlineExceeded` at a round checkpoint). Because triggers
//! are exact-match on ids and attempts, a chaos test's outcome is fully
//! reproducible: no randomness, no timing races in the trigger logic.

use std::time::Duration;

use crate::error::Error;

/// Sentinel attempt index meaning "every attempt".
const ANY_ATTEMPT: u32 = u32::MAX;

/// A deterministic script of faults to inject into a batch.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// (job id, attempt) pairs whose attempt panics mid-execution.
    panics: Vec<(u64, u32)>,
    /// (job id, attempt) pairs whose attempt fails with `Error::Injected`.
    errors: Vec<(u64, u32)>,
    /// per-round delays installed into the planner for a job id.
    delays: Vec<(u64, Duration)>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Panic the given attempt (0-based) of job `id`.
    pub fn panic_on(mut self, id: u64, attempt: u32) -> FaultPlan {
        self.panics.push((id, attempt));
        self
    }

    /// Panic every attempt of job `id` — the job can only fail.
    pub fn panic_always(mut self, id: u64) -> FaultPlan {
        self.panics.push((id, ANY_ATTEMPT));
        self
    }

    /// Fail the given attempt (0-based) of job `id` with a typed
    /// transient error.
    pub fn error_on(mut self, id: u64, attempt: u32) -> FaultPlan {
        self.errors.push((id, attempt));
        self
    }

    /// Fail every attempt of job `id`.
    pub fn error_always(mut self, id: u64) -> FaultPlan {
        self.errors.push((id, ANY_ATTEMPT));
        self
    }

    /// Sleep `delay` at every PrunIT frontier round / fixed-point
    /// alternation of job `id` (all attempts). With a deadline shorter
    /// than one delay this forces a deterministic deadline miss.
    pub fn delay_rounds(mut self, id: u64, delay: Duration) -> FaultPlan {
        self.delays.push((id, delay));
        self
    }

    fn matches(list: &[(u64, u32)], id: u64, attempt: u32) -> bool {
        list.iter()
            .any(|&(j, a)| j == id && (a == attempt || a == ANY_ATTEMPT))
    }

    /// Should this attempt panic?
    pub fn should_panic(&self, id: u64, attempt: u32) -> bool {
        FaultPlan::matches(&self.panics, id, attempt)
    }

    /// The injected error for this attempt, if scripted.
    pub fn injected_error(&self, id: u64, attempt: u32) -> Option<Error> {
        if FaultPlan::matches(&self.errors, id, attempt) {
            Some(Error::Injected(format!(
                "scripted failure: job {id} attempt {attempt}"
            )))
        } else {
            None
        }
    }

    /// The per-round delay scripted for this job, if any.
    pub fn round_delay(&self, id: u64) -> Option<Duration> {
        self.delays
            .iter()
            .find(|&&(j, _)| j == id)
            .map(|&(_, d)| d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triggers_are_exact_match() {
        let plan = FaultPlan::new()
            .panic_on(3, 0)
            .error_on(5, 1)
            .delay_rounds(7, Duration::from_millis(10));
        assert!(plan.should_panic(3, 0));
        assert!(!plan.should_panic(3, 1), "retry of job 3 must run clean");
        assert!(!plan.should_panic(4, 0));
        assert!(plan.injected_error(5, 1).is_some());
        assert!(plan.injected_error(5, 0).is_none());
        assert_eq!(plan.round_delay(7), Some(Duration::from_millis(10)));
        assert_eq!(plan.round_delay(3), None);
    }

    #[test]
    fn always_variants_hit_every_attempt() {
        let plan = FaultPlan::new().panic_always(1).error_always(2);
        for attempt in 0..8 {
            assert!(plan.should_panic(1, attempt));
            assert!(plan.injected_error(2, attempt).is_some());
        }
    }

    #[test]
    fn injected_error_is_transient() {
        let plan = FaultPlan::new().error_on(0, 0);
        let e = plan.injected_error(0, 0).unwrap();
        assert!(e.is_transient(), "injected faults must enter the retry ladder");
    }
}
