//! # coral-prunit
//!
//! A production-grade reproduction of **"Reduction Algorithms for
//! Persistence Diagrams of Networks: CoralTDA and PrunIT"** (Akcora,
//! Kantarcioglu, Gel, Coskunuzer — NeurIPS 2022) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper proves two *exact* graph reductions for persistent homology
//! over clique-complex filtrations:
//!
//! * **CoralTDA** (Theorem 2): `PD_k(G, f) = PD_k(G^{k+1}, f)` — the
//!   (k+1)-core suffices for the k-th persistence diagram.
//! * **PrunIT** (Theorem 7): removing a vertex `u` dominated by `v`
//!   (`N[u] ⊆ N[v]`) with `f(u) ≥ f(v)` preserves *every* `PD_k`.
//!
//! This crate contains the complete system: the graph substrate and
//! generators, k-core decomposition, domination pruning (sparse CPU path
//! and a dense XLA path executing the AOT-compiled Pallas kernel, gated
//! behind the `xla` feature), clique-complex filtrations stored in the
//! **columnar `FlatComplex`** (vertex arena + boundary CSR resolved at
//! construction; the AoS path survives in `homology::legacy` as the
//! differential-test reference), a Z/2 persistent-homology engine that
//! reduces the boundary CSR in place (the expensive computation the
//! paper reduces), the combined reduction pipeline, a **component-sharded
//! parallel pipeline** (`reduce::pd_sharded` — PDs are additive over
//! disjoint unions, so per-component PH is exact and turns the cubic
//! monolithic reduction into independent parallel jobs), a batch
//! coordinator, and one bench driver per paper table/figure. See the
//! top-level `README.md` for build instructions and the experiment
//! index.
//!
//! ## Quickstart
//!
//! ```no_run
//! use coral_prunit::prelude::*;
//!
//! let g = gen::barabasi_albert(200, 3, 42);
//! let f = Filtration::degree(&g);
//! // Reduce (PrunIT + coral on the in-place planner; one compaction),
//! // then compute PD_1 — provably equal to the unreduced diagram.
//! let reduced = reduce::combined(&g, &f, 1).unwrap();
//! let pd = homology::persistence_diagrams(&reduced.graph, &reduced.filtration, 1);
//! println!("PD_1 has {} off-diagonal points", pd[1].points().len());
//! ```

pub mod bench;
pub mod cli;
pub mod complex;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod error;
pub mod graph;
pub mod homology;
pub mod kcore;
pub mod prune;
pub mod reduce;
pub mod runtime;
pub mod testutil;
pub mod util;

pub use error::{Error, Result};

/// Convenience re-exports for downstream users and examples.
pub mod prelude {
    pub use crate::complex::filtration::{Direction, Filtration};
    pub use crate::graph::gen;
    pub use crate::graph::Graph;
    pub use crate::homology::{self, Diagram};
    pub use crate::kcore;
    pub use crate::prune;
    pub use crate::reduce;
    pub use crate::{Error, Result};
}
