//! Crate-wide error type. `anyhow` is reserved for binaries; the library
//! surfaces a typed error so downstream callers can match on failure modes.

use std::fmt;

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Error cases surfaced by the coral-prunit library.
#[derive(Debug)]
pub enum Error {
    /// A vertex id out of range for the graph it was used with.
    VertexOutOfRange { vertex: usize, order: usize },
    /// Filtration length does not match graph order.
    FiltrationMismatch { filtration: usize, order: usize },
    /// Graph too large for every exported XLA size bucket.
    NoBucket { order: usize, largest: usize },
    /// artifacts/ directory missing or artifact file unreadable.
    ArtifactMissing(String),
    /// PJRT / XLA failure (compile or execute).
    Xla(String),
    /// A simplex was added to a complex without one of its codim-1 faces
    /// (boundary construction requires face-closed input in build order).
    FaceMissing { simplex: String, face: String },
    /// The same simplex was added to a complex builder more than once.
    DuplicateSimplex { simplex: String },
    /// Config file syntax or schema error.
    Config(String),
    /// Dataset / experiment identifier not in the registry.
    UnknownDataset(String),
    /// Malformed edge-list input.
    Parse(String),
    /// Coordinator channel failure (worker panicked or receiver dropped).
    Coordinator(String),
    /// I/O error with context.
    Io(String),
    /// A job ran past its configured deadline and unwound at a
    /// cancellation checkpoint.
    DeadlineExceeded { limit_secs: f64 },
    /// A cancellation token was tripped explicitly.
    Cancelled,
    /// A job panicked; `catch_unwind` isolation converted the payload.
    JobPanicked(String),
    /// A deterministic fault-injection plan raised this error on purpose
    /// (test / chaos-suite only).
    Injected(String),
    /// The service's admission controller shed this job before execution:
    /// queue, deadline-pressure, or memory budget would be exceeded and
    /// the job's priority did not clear the load-shedding threshold.
    /// Permanent from the service's point of view — the *client* may
    /// resubmit later, but retrying inside the service would re-enter the
    /// same overloaded queue it was just protected from.
    Overloaded(String),
}

impl Error {
    /// Whether a retry could plausibly succeed. Structural errors
    /// (mismatched filtration, out-of-range vertex, bad config) are
    /// permanent: retrying burns attempts on a deterministic failure.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            Error::DeadlineExceeded { .. }
                | Error::Cancelled
                | Error::JobPanicked(_)
                | Error::Injected(_)
                | Error::Io(_)
                | Error::Xla(_)
                | Error::Coordinator(_)
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::VertexOutOfRange { vertex, order } => {
                write!(f, "vertex {vertex} out of range for graph of order {order}")
            }
            Error::FiltrationMismatch { filtration, order } => write!(
                f,
                "filtration has {filtration} values but graph has {order} vertices"
            ),
            Error::NoBucket { order, largest } => write!(
                f,
                "graph order {order} exceeds the largest XLA bucket {largest}; \
                 use the sparse path"
            ),
            Error::ArtifactMissing(p) => write!(f, "missing AOT artifact: {p} (run `make artifacts`)"),
            Error::Xla(msg) => write!(f, "xla/pjrt error: {msg}"),
            Error::FaceMissing { simplex, face } => write!(
                f,
                "face {face} of simplex {simplex} missing from complex — build order violated"
            ),
            Error::DuplicateSimplex { simplex } => {
                write!(f, "simplex {simplex} pushed to the complex builder twice")
            }
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::UnknownDataset(name) => write!(f, "unknown dataset/experiment: {name}"),
            Error::Parse(msg) => write!(f, "parse error: {msg}"),
            Error::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            Error::Io(msg) => write!(f, "io error: {msg}"),
            Error::DeadlineExceeded { limit_secs } => {
                write!(f, "job exceeded its {limit_secs}s deadline")
            }
            Error::Cancelled => write!(f, "job cancelled"),
            Error::JobPanicked(msg) => write!(f, "job panicked: {msg}"),
            Error::Injected(msg) => write!(f, "injected fault: {msg}"),
            Error::Overloaded(msg) => write!(f, "overloaded: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}
