//! Columnar **FlatComplex** — the production simplex storage (§Perf).
//!
//! The legacy [`CliqueComplex`](super::clique::CliqueComplex) is an
//! array-of-structs: one heap `Vec<u32>` per simplex, and boundary
//! construction re-derives every face through a `HashMap<&[u32], usize>`.
//! At the sharded-pipeline scale (thousands of small PH jobs per batch)
//! that allocation churn dominates the wall time. This module replaces it
//! with a structure-of-arrays layout:
//!
//! * one contiguous **vertex arena** + CSR offsets (simplex `i`'s tuple is
//!   a slice of the arena),
//! * parallel arrays for filtration keys and dimensions,
//! * a **boundary CSR** (`bnd_rows`/`bnd_offsets`) with face *positions*
//!   resolved during construction — so [`crate::homology::reduction::reduce`]
//!   consumes columns straight from the arena with no per-column `Vec` and
//!   no post-hoc hashing.
//!
//! Face resolution exploits a structural fact of ordered clique expansion:
//! within each dimension the DFS emits tuples in strictly increasing
//! lexicographic order, so every face of a d-simplex can be located in the
//! (d−1)-pool by a strided binary search — `O(d · log n_{d-1})` integer
//! comparisons, zero hashing, zero allocation (one reusable face buffer).
//!
//! [`ComplexWorkspace`] keeps every scratch buffer (expansion candidate
//! pools, per-dimension tuple pools, the sort/permutation vectors) alive
//! across builds, which is what the sharded pipeline and the coordinator
//! worker threads reuse per shard/job.

use crate::complex::filtration::Filtration;
use crate::error::{Error, Result};
use crate::graph::core::sorted_intersection_into;
use crate::graph::Graph;
use crate::util::sortable_f64;

/// A filtered flag complex in columnar (structure-of-arrays) layout,
/// simplices in filtration order (key, dim, lexicographic tuple) with the
/// Z/2 boundary resolved to column positions.
#[derive(Clone, Debug)]
pub struct FlatComplex {
    /// Vertex arena: tuple of simplex `i` is `verts[offsets[i]..offsets[i+1]]`.
    verts: Vec<u32>,
    /// CSR offsets into `verts`, length `len() + 1`.
    offsets: Vec<u32>,
    /// Filtration key per simplex (ascending in the sort order).
    keys: Vec<f64>,
    /// Dimension per simplex.
    dims: Vec<u32>,
    /// Boundary arena: positions of the codim-1 faces of simplex `i`,
    /// ascending, at `bnd_rows[bnd_offsets[i]..bnd_offsets[i+1]]`.
    /// Dim-0 simplices have empty columns.
    bnd_rows: Vec<u32>,
    /// CSR offsets into `bnd_rows`, length `len() + 1`.
    bnd_offsets: Vec<u32>,
    /// Max dimension present (0 for the empty complex).
    max_dim: usize,
}

impl Default for FlatComplex {
    /// The empty complex. Hand-written so the CSR invariant
    /// (`offsets.len() == len() + 1`, first offset 0) holds even for the
    /// default value.
    fn default() -> FlatComplex {
        FlatComplex {
            verts: Vec::new(),
            offsets: vec![0],
            keys: Vec::new(),
            dims: Vec::new(),
            bnd_rows: Vec::new(),
            bnd_offsets: vec![0],
            max_dim: 0,
        }
    }
}

impl FlatComplex {
    /// Build the clique complex of `g` up to `max_dim`-simplices, filtered
    /// by the vertex function. To compute `PD_k` you need `max_dim = k+1`.
    /// Allocates fresh scratch; batch callers should hold a
    /// [`ComplexWorkspace`] and use [`ComplexWorkspace::build_clique`].
    pub fn build(g: &Graph, f: &Filtration, max_dim: usize) -> FlatComplex {
        ComplexWorkspace::new().build_clique(g, f, max_dim)
    }

    /// Number of simplices.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Max dimension present.
    pub fn dim(&self) -> usize {
        self.max_dim
    }

    /// Dimension of simplex `i`.
    #[inline]
    pub fn dim_of(&self, i: usize) -> usize {
        self.dims[i] as usize
    }

    /// Filtration key of simplex `i`.
    #[inline]
    pub fn key_of(&self, i: usize) -> f64 {
        self.keys[i]
    }

    /// All filtration keys, in filtration order.
    pub fn keys(&self) -> &[f64] {
        &self.keys
    }

    /// Vertex tuple of simplex `i` (strictly increasing).
    #[inline]
    pub fn vertices_of(&self, i: usize) -> &[u32] {
        &self.verts[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Boundary column of simplex `i`: positions of its codim-1 faces,
    /// ascending. Every entry is `< i` (faces precede cofaces).
    #[inline]
    pub fn boundary_of(&self, i: usize) -> &[u32] {
        &self.bnd_rows[self.bnd_offsets[i] as usize..self.bnd_offsets[i + 1] as usize]
    }

    /// Number of simplices per dimension.
    pub fn counts_by_dim(&self) -> Vec<usize> {
        let mut counts = vec![0usize; if self.keys.is_empty() { 0 } else { self.max_dim + 1 }];
        for &d in &self.dims {
            counts[d as usize] += 1;
        }
        counts
    }
}

/// Incremental builder over per-dimension columnar pools. Push simplices
/// as strictly increasing vertex tuples (each simplex exactly once, faces
/// included for every coface), then [`finish`](FlatComplexBuilder::finish).
/// Missing faces surface as [`Error::FaceMissing`] instead of a panic.
/// The pools and scratch retain capacity across `finish` calls, so one
/// builder amortises allocation over many complexes — on the in-order
/// (clique/power expansion) path; the unsorted-push fallback allocates
/// fresh pool storage for each permuted dimension, and an erroring
/// `finish` leaves the scratch cold (both are off the hot path).
#[derive(Debug, Default)]
pub struct FlatComplexBuilder {
    /// Per-dimension tuple pools, stride `d + 1`.
    pool_verts: Vec<Vec<u32>>,
    /// Per-dimension keys, parallel to the tuples.
    pool_keys: Vec<Vec<f64>>,
    // finish() scratch, reused across builds
    order: Vec<u32>,
    pos: Vec<u32>,
    sortkeys: Vec<u64>,
    dim_global: Vec<u32>,
    face: Vec<u32>,
}

/// Tuple of global simplex `g` inside the per-dim pools.
fn tuple_of<'a>(
    pool_verts: &'a [Vec<u32>],
    dim_global: &[u32],
    base: &[usize],
    g: usize,
) -> &'a [u32] {
    let d = dim_global[g] as usize;
    let l = g - base[d];
    &pool_verts[d][l * (d + 1)..(l + 1) * (d + 1)]
}

/// Strided lower-bound search for `needle` in a lex-sorted tuple pool.
fn find_tuple(pool: &[u32], stride: usize, needle: &[u32]) -> Option<usize> {
    debug_assert_eq!(needle.len(), stride);
    let count = pool.len() / stride;
    let (mut lo, mut hi) = (0usize, count);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if &pool[mid * stride..(mid + 1) * stride] < needle {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    if lo < count && &pool[lo * stride..(lo + 1) * stride] == needle {
        Some(lo)
    } else {
        None
    }
}

/// Render a vertex tuple as `[a,b,c]` — the format shared by
/// [`Error::FaceMissing`] / [`Error::DuplicateSimplex`] in both the flat
/// and the legacy engine (matches `Simplex`'s `Display`).
pub(crate) fn fmt_tuple(t: &[u32]) -> String {
    let mut s = String::from("[");
    for (i, v) in t.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&v.to_string());
    }
    s.push(']');
    s
}

impl FlatComplexBuilder {
    pub fn new() -> FlatComplexBuilder {
        FlatComplexBuilder::default()
    }

    /// Number of simplices pushed so far.
    pub fn len(&self) -> usize {
        self.pool_keys.iter().map(|p| p.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.pool_keys.iter().all(|p| p.is_empty())
    }

    /// Drop all pushed simplices (capacity retained). A successful
    /// [`finish`](FlatComplexBuilder::finish) drains the pools itself; call
    /// this to reuse a builder after `finish` returned an error.
    pub fn clear(&mut self) {
        for p in &mut self.pool_verts {
            p.clear();
        }
        for p in &mut self.pool_keys {
            p.clear();
        }
    }

    /// Append a simplex as a strictly increasing vertex tuple. The tuple
    /// bytes are copied into the dimension's arena — no per-simplex heap
    /// allocation beyond amortised arena growth.
    #[inline]
    pub fn push(&mut self, tuple: &[u32], key: f64) {
        debug_assert!(
            !tuple.is_empty() && tuple.windows(2).all(|w| w[0] < w[1]),
            "tuple must be strictly increasing"
        );
        let d = tuple.len() - 1;
        while self.pool_verts.len() <= d {
            self.pool_verts.push(Vec::new());
            self.pool_keys.push(Vec::new());
        }
        self.pool_verts[d].extend_from_slice(tuple);
        self.pool_keys[d].push(key);
    }

    /// Sort into filtration order, resolve every boundary column, and emit
    /// the columnar complex. Errors with [`Error::FaceMissing`] if a pushed
    /// simplex has a codim-1 face that was never pushed (a build-order /
    /// closure violation — formerly a panic in `BoundaryMatrix::build`).
    /// The builder's pools are drained (capacity retained) for reuse.
    pub fn finish(&mut self) -> Result<FlatComplex> {
        let ndims = self.pool_verts.len();
        let mut counts = vec![0usize; ndims];
        let mut base = vec![0usize; ndims + 1];
        for d in 0..ndims {
            counts[d] = self.pool_keys[d].len();
            base[d + 1] = base[d] + counts[d];
        }
        let n = base[ndims];
        // u32 indices cap the arena (and with it every offset/position
        // array — arena_len bounds them all, each simplex holding ≥ 1
        // vertex). Fail loudly rather than wrap: a complex this size must
        // be sharded before building.
        let arena_len: usize = (0..ndims).map(|d| counts[d] * (d + 1)).sum();
        assert!(
            arena_len <= u32::MAX as usize,
            "complex exceeds the u32 arena-index space ({arena_len} vertex slots); \
             shard the graph before building"
        );

        // Canonical per-dim lexicographic order. Ordered clique expansion
        // already emits it (DFS over ascending candidates), so the sort
        // below is a no-op check on the hot path; the permutation branch
        // serves builder users pushing in arbitrary order. Adjacent equal
        // tuples — a simplex pushed twice — are a build violation and
        // surface as a typed error, like missing faces.
        for d in 0..ndims {
            let stride = d + 1;
            let cnt = counts[d];
            let mut sorted = true;
            {
                let pv = &self.pool_verts[d];
                for i in 1..cnt {
                    let prev = &pv[(i - 1) * stride..i * stride];
                    let cur = &pv[i * stride..(i + 1) * stride];
                    if prev > cur {
                        sorted = false;
                        break;
                    }
                    if prev == cur {
                        return Err(Error::DuplicateSimplex {
                            simplex: fmt_tuple(cur),
                        });
                    }
                }
            }
            if sorted {
                continue;
            }
            let mut perm: Vec<u32> = (0..cnt as u32).collect();
            {
                let pv = &self.pool_verts[d];
                perm.sort_unstable_by(|&x, &y| {
                    let (x, y) = (x as usize, y as usize);
                    pv[x * stride..(x + 1) * stride].cmp(&pv[y * stride..(y + 1) * stride])
                });
            }
            let (new_v, new_k) = {
                let pv = &self.pool_verts[d];
                let pk = &self.pool_keys[d];
                let mut nv = Vec::with_capacity(pv.len());
                let mut nk = Vec::with_capacity(cnt);
                for &x in &perm {
                    let x = x as usize;
                    nv.extend_from_slice(&pv[x * stride..(x + 1) * stride]);
                    nk.push(pk[x]);
                }
                (nv, nk)
            };
            // the sort fallback must also reject duplicates, now adjacent
            for i in 1..cnt {
                let prev = &new_v[(i - 1) * stride..i * stride];
                let cur = &new_v[i * stride..(i + 1) * stride];
                if prev == cur {
                    return Err(Error::DuplicateSimplex {
                        simplex: fmt_tuple(cur),
                    });
                }
            }
            self.pool_verts[d] = new_v;
            self.pool_keys[d] = new_k;
        }

        // Global filtration order: (key, dim, lex tuple). §Perf: integer
        // key transform avoids partial_cmp in the hot sort.
        self.sortkeys.clear();
        self.dim_global.clear();
        for d in 0..ndims {
            for l in 0..counts[d] {
                self.sortkeys.push(sortable_f64(self.pool_keys[d][l]));
                self.dim_global.push(d as u32);
            }
        }
        let mut order = std::mem::take(&mut self.order);
        order.clear();
        order.extend(0..n as u32);
        {
            let sortkeys = &self.sortkeys;
            let dim_global = &self.dim_global;
            let pool_verts = &self.pool_verts;
            let base = &base;
            order.sort_unstable_by(|&a, &b| {
                let (a, b) = (a as usize, b as usize);
                sortkeys[a]
                    .cmp(&sortkeys[b])
                    .then(dim_global[a].cmp(&dim_global[b]))
                    .then_with(|| {
                        tuple_of(pool_verts, dim_global, base, a)
                            .cmp(tuple_of(pool_verts, dim_global, base, b))
                    })
            });
        }
        let mut pos = std::mem::take(&mut self.pos);
        pos.clear();
        pos.resize(n, 0);
        for (j, &g) in order.iter().enumerate() {
            pos[g as usize] = j as u32;
        }

        // Emit the columnar arrays in filtration order.
        let mut verts: Vec<u32> = Vec::with_capacity(arena_len);
        let mut offsets: Vec<u32> = Vec::with_capacity(n + 1);
        let mut keys: Vec<f64> = Vec::with_capacity(n);
        let mut dims: Vec<u32> = Vec::with_capacity(n);
        offsets.push(0);
        for &gid in &order {
            let g = gid as usize;
            let d = self.dim_global[g] as usize;
            let l = g - base[d];
            verts.extend_from_slice(&self.pool_verts[d][l * (d + 1)..(l + 1) * (d + 1)]);
            offsets.push(verts.len() as u32);
            keys.push(self.pool_keys[d][l]);
            dims.push(d as u32);
        }

        // Resolve boundary columns: each face is found in the (d−1)-pool by
        // strided binary search (the pools are lex-sorted), then mapped to
        // its filtration position. No HashMap, no per-face allocation.
        let bnd_len: usize = (1..ndims).map(|d| counts[d] * (d + 1)).sum();
        let mut bnd_rows: Vec<u32> = Vec::with_capacity(bnd_len);
        let mut bnd_offsets: Vec<u32> = Vec::with_capacity(n + 1);
        bnd_offsets.push(0);
        let mut face = std::mem::take(&mut self.face);
        for &gid in &order {
            let g = gid as usize;
            let d = self.dim_global[g] as usize;
            if d > 0 {
                let t = tuple_of(&self.pool_verts, &self.dim_global, &base, g);
                let col_start = bnd_rows.len();
                for drop in 0..=d {
                    face.clear();
                    face.extend(
                        t.iter()
                            .enumerate()
                            .filter_map(|(i, &v)| if i == drop { None } else { Some(v) }),
                    );
                    let fl = match find_tuple(&self.pool_verts[d - 1], d, &face) {
                        Some(fl) => fl,
                        None => {
                            return Err(Error::FaceMissing {
                                simplex: fmt_tuple(t),
                                face: fmt_tuple(&face),
                            })
                        }
                    };
                    bnd_rows.push(pos[base[d - 1] + fl]);
                }
                bnd_rows[col_start..].sort_unstable();
            }
            bnd_offsets.push(bnd_rows.len() as u32);
        }

        // Drain pools (retain capacity) and hand scratch back for reuse.
        for p in &mut self.pool_verts {
            p.clear();
        }
        for p in &mut self.pool_keys {
            p.clear();
        }
        self.sortkeys.clear();
        self.dim_global.clear();
        self.order = order;
        self.pos = pos;
        self.face = face;

        let max_dim = dims.iter().copied().max().unwrap_or(0) as usize;
        Ok(FlatComplex {
            verts,
            offsets,
            keys,
            dims,
            bnd_rows,
            bnd_offsets,
            max_dim,
        })
    }
}

/// Reusable build state for the clique-expansion hot path: the tuple pools
/// (inside the builder) plus the DFS candidate buffers. One workspace per
/// worker thread amortises every allocation over a whole batch of shards.
#[derive(Debug, Default)]
pub struct ComplexWorkspace {
    builder: FlatComplexBuilder,
    clique: Vec<u32>,
    cand: Vec<u32>,
    pool: Vec<Vec<u32>>,
}

impl ComplexWorkspace {
    pub fn new() -> ComplexWorkspace {
        ComplexWorkspace::default()
    }

    /// Build the filtered clique complex of `g` up to `max_dim`-simplices,
    /// reusing this workspace's arenas. Equivalent to
    /// [`FlatComplex::build`].
    pub fn build_clique(&mut self, g: &Graph, f: &Filtration, max_dim: usize) -> FlatComplex {
        f.check(g).expect("filtration must match graph");

        // dim 0
        for v in 0..g.n() as u32 {
            self.builder.push(&[v], f.key(v));
        }

        // dims >= 1 by ordered expansion: each clique is discovered exactly
        // once as its ascending vertex tuple, per dimension in lex order.
        if self.pool.len() < max_dim + 2 {
            self.pool.resize_with(max_dim + 2, Vec::new);
        }
        if max_dim > 0 {
            for v in 0..g.n() as u32 {
                self.clique.clear();
                self.clique.push(v);
                self.cand.clear();
                self.cand
                    .extend(g.neighbors(v).iter().copied().filter(|&w| w > v));
                expand_flat(
                    g,
                    f,
                    max_dim,
                    &mut self.clique,
                    &self.cand,
                    f.key(v),
                    &mut self.builder,
                    &mut self.pool,
                );
            }
        }

        match self.builder.finish() {
            Ok(c) => c,
            // Ordered clique expansion emits every face of every clique.
            Err(e) => unreachable!("clique expansion is face-closed: {e}"),
        }
    }
}

/// Recursive ordered clique expansion into the columnar builder. `clique`
/// is the current ascending tuple, `cand` the common later neighbours,
/// `key` the running max, `pool` the per-depth candidate buffers
/// (allocation-free inner loop).
#[allow(clippy::too_many_arguments)]
fn expand_flat(
    g: &Graph,
    f: &Filtration,
    max_dim: usize,
    clique: &mut Vec<u32>,
    cand: &[u32],
    key: f64,
    b: &mut FlatComplexBuilder,
    pool: &mut Vec<Vec<u32>>,
) {
    let depth = clique.len();
    for (i, &w) in cand.iter().enumerate() {
        clique.push(w);
        let k = key.max(f.key(w));
        b.push(&clique[..], k);
        if clique.len() <= max_dim {
            // candidates after w that stay adjacent to the whole clique
            let mut next = std::mem::take(&mut pool[depth]);
            sorted_intersection_into(&cand[i + 1..], g.neighbors(w), &mut next);
            if !next.is_empty() {
                expand_flat(g, f, max_dim, clique, &next, k, b, pool);
            }
            pool[depth] = next;
        }
        clique.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn boundary_valid(c: &FlatComplex) {
        for i in 0..c.len() {
            let col = c.boundary_of(i);
            if c.dim_of(i) == 0 {
                assert!(col.is_empty());
                continue;
            }
            assert_eq!(col.len(), c.dim_of(i) + 1);
            for w in col.windows(2) {
                assert!(w[0] < w[1], "column rows must be strictly ascending");
            }
            for &r in col {
                let r = r as usize;
                assert!(r < i, "face must precede coface");
                assert_eq!(c.dim_of(r) + 1, c.dim_of(i));
                // every face tuple is a subset of the coface tuple
                assert!(crate::graph::core::sorted_is_subset(
                    c.vertices_of(r),
                    c.vertices_of(i)
                ));
            }
        }
    }

    #[test]
    fn triangle_complex() {
        let g = gen::complete(3);
        let f = Filtration::constant(3);
        let c = FlatComplex::build(&g, &f, 2);
        assert_eq!(c.counts_by_dim(), vec![3, 3, 1]);
        boundary_valid(&c);
    }

    #[test]
    fn k4_counts() {
        let g = gen::complete(4);
        let c = FlatComplex::build(&g, &Filtration::constant(4), 3);
        assert_eq!(c.counts_by_dim(), vec![4, 6, 4, 1]);
        boundary_valid(&c);
    }

    #[test]
    fn dim_cap_respected() {
        let g = gen::complete(6);
        let c = FlatComplex::build(&g, &Filtration::constant(6), 2);
        assert_eq!(c.dim(), 2);
        assert_eq!(c.counts_by_dim(), vec![6, 15, 20]);
    }

    #[test]
    fn simplex_key_is_max_vertex_key() {
        let g = gen::complete(3);
        let f = Filtration::sublevel(vec![1.0, 5.0, 3.0]);
        let c = FlatComplex::build(&g, &f, 2);
        let tri = (0..c.len()).find(|&i| c.dim_of(i) == 2).unwrap();
        assert_eq!(c.key_of(tri), 5.0);
        boundary_valid(&c);
    }

    #[test]
    fn superlevel_ordering_reverses() {
        let g = gen::path(3); // 0-1-2, degrees 1,2,1
        let f = Filtration::degree_superlevel(&g);
        let c = FlatComplex::build(&g, &f, 1);
        // vertex 1 (degree 2) must enter first under superlevel
        assert_eq!(c.vertices_of(0), &[1]);
        boundary_valid(&c);
    }

    #[test]
    fn empty_graph_complex() {
        let g = Graph::empty(0);
        let c = FlatComplex::build(&g, &Filtration::constant(0), 2);
        assert!(c.is_empty());
        assert_eq!(c.dim(), 0);
        assert_eq!(c.counts_by_dim(), Vec::<usize>::new());
    }

    #[test]
    fn max_dim_zero_is_vertices_only() {
        let g = gen::complete(5);
        let c = FlatComplex::build(&g, &Filtration::constant(5), 0);
        assert_eq!(c.counts_by_dim(), vec![5]);
        boundary_valid(&c);
    }

    #[test]
    fn builder_missing_face_is_typed_error() {
        // triangle [0,1,2] with edge [1,2] never pushed
        let mut b = FlatComplexBuilder::new();
        for v in 0..3u32 {
            b.push(&[v], 0.0);
        }
        b.push(&[0, 1], 0.0);
        b.push(&[0, 2], 0.0);
        b.push(&[0, 1, 2], 0.0);
        match b.finish() {
            Err(Error::FaceMissing { simplex, face }) => {
                assert_eq!(simplex, "[0,1,2]");
                assert_eq!(face, "[1,2]");
            }
            other => panic!("expected FaceMissing, got {other:?}"),
        }
    }

    #[test]
    fn builder_duplicate_simplex_is_typed_error() {
        // sorted push order: duplicate caught by the adjacency scan
        let mut b = FlatComplexBuilder::new();
        b.push(&[0], 0.0);
        b.push(&[1], 0.0);
        b.push(&[0, 1], 0.0);
        b.push(&[0, 1], 0.0);
        match b.finish() {
            Err(Error::DuplicateSimplex { simplex }) => assert_eq!(simplex, "[0,1]"),
            other => panic!("expected DuplicateSimplex, got {other:?}"),
        }
        // unsorted push order: duplicate caught after the fallback sort
        b.clear();
        b.push(&[1], 0.0);
        b.push(&[0], 0.0);
        b.push(&[1], 0.0);
        match b.finish() {
            Err(Error::DuplicateSimplex { simplex }) => assert_eq!(simplex, "[1]"),
            other => panic!("expected DuplicateSimplex, got {other:?}"),
        }
    }

    #[test]
    fn builder_reuses_pools_across_finishes() {
        let mut b = FlatComplexBuilder::new();
        b.push(&[0], 0.0);
        b.push(&[1], 0.0);
        b.push(&[0, 1], 1.0);
        let c = b.finish().unwrap();
        assert_eq!(c.len(), 3);
        assert_eq!(c.boundary_of(2), &[0, 1]);
        // pools drained: a second build sees only its own pushes
        b.push(&[4], 2.0);
        let c2 = b.finish().unwrap();
        assert_eq!(c2.len(), 1);
        assert_eq!(c2.vertices_of(0), &[4]);
    }

    #[test]
    fn builder_accepts_unsorted_push_order() {
        // same complex pushed in scrambled order must normalise
        let mut b = FlatComplexBuilder::new();
        b.push(&[0, 1, 2], 1.0);
        b.push(&[2], 0.0);
        b.push(&[0, 2], 0.0);
        b.push(&[0], 0.0);
        b.push(&[1, 2], 1.0);
        b.push(&[1], 1.0);
        b.push(&[0, 1], 1.0);
        let c = b.finish().unwrap();
        let direct = FlatComplex::build(
            &gen::complete(3),
            &Filtration::sublevel(vec![0.0, 1.0, 0.0]),
            2,
        );
        assert_eq!(c.len(), direct.len());
        for i in 0..c.len() {
            assert_eq!(c.vertices_of(i), direct.vertices_of(i), "position {i}");
            assert_eq!(c.key_of(i), direct.key_of(i));
            assert_eq!(c.boundary_of(i), direct.boundary_of(i));
        }
    }

    #[test]
    fn workspace_reuse_is_equivalent_to_fresh_builds() {
        let mut ws = ComplexWorkspace::new();
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..6 {
            let n = rng.range(3, 16);
            let g = gen::erdos_renyi(n, 0.4, rng.next_u64());
            let f = Filtration::degree(&g);
            let a = ws.build_clique(&g, &f, 3);
            let b = FlatComplex::build(&g, &f, 3);
            assert_eq!(a.len(), b.len());
            for i in 0..a.len() {
                assert_eq!(a.vertices_of(i), b.vertices_of(i));
                assert_eq!(a.key_of(i), b.key_of(i));
                assert_eq!(a.boundary_of(i), b.boundary_of(i));
            }
            boundary_valid(&a);
        }
    }
}
