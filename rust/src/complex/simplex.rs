//! Simplices: sorted vertex tuples. A k-clique in the graph induces a
//! (k−1)-simplex in the clique complex (paper §4.1).

/// A simplex as a strictly increasing vertex tuple.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Simplex {
    vertices: Vec<u32>,
}

impl Simplex {
    /// Construct from vertices (sorted + deduped defensively).
    pub fn new(mut vertices: Vec<u32>) -> Simplex {
        vertices.sort_unstable();
        vertices.dedup();
        Simplex { vertices }
    }

    /// Construct from an already strictly-increasing tuple (hot path).
    #[inline]
    pub fn from_sorted(vertices: Vec<u32>) -> Simplex {
        debug_assert!(vertices.windows(2).all(|w| w[0] < w[1]));
        Simplex { vertices }
    }

    /// Dimension = |vertices| − 1.
    #[inline]
    pub fn dim(&self) -> usize {
        self.vertices.len() - 1
    }

    #[inline]
    pub fn vertices(&self) -> &[u32] {
        &self.vertices
    }

    /// The i-th codimension-1 face (drop vertex i).
    pub fn face(&self, i: usize) -> Simplex {
        let mut v = self.vertices.clone();
        v.remove(i);
        Simplex { vertices: v }
    }

    /// All codimension-1 faces (boundary support over Z/2).
    pub fn faces(&self) -> Vec<Simplex> {
        (0..self.vertices.len()).map(|i| self.face(i)).collect()
    }

    /// Does this simplex contain vertex `v`?
    pub fn contains(&self, v: u32) -> bool {
        self.vertices.binary_search(&v).is_ok()
    }
}

impl std::fmt::Display for Simplex {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, v) in self.vertices.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{v}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_sorts_and_dedups() {
        let s = Simplex::new(vec![3, 1, 2, 1]);
        assert_eq!(s.vertices(), &[1, 2, 3]);
        assert_eq!(s.dim(), 2);
    }

    #[test]
    fn faces_of_triangle() {
        let s = Simplex::from_sorted(vec![0, 1, 2]);
        let fs = s.faces();
        assert_eq!(fs.len(), 3);
        assert!(fs.contains(&Simplex::from_sorted(vec![1, 2])));
        assert!(fs.contains(&Simplex::from_sorted(vec![0, 2])));
        assert!(fs.contains(&Simplex::from_sorted(vec![0, 1])));
    }

    #[test]
    fn vertex_simplex_has_empty_faceset_dim() {
        let s = Simplex::from_sorted(vec![7]);
        assert_eq!(s.dim(), 0);
        assert_eq!(s.faces().len(), 1); // the empty simplex, dropped by PH
    }

    #[test]
    fn contains_and_display() {
        let s = Simplex::from_sorted(vec![2, 5, 9]);
        assert!(s.contains(5));
        assert!(!s.contains(4));
        assert_eq!(s.to_string(), "[2,5,9]");
    }
}
