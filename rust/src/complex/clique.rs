//! Filtered clique (flag) complexes (§3): enumerate all cliques of the
//! graph up to a dimension cap and order them by sublevel filtration value
//! (max vertex key, then dimension, then lexicographic tuple — which
//! guarantees every face precedes its cofaces).
//!
//! [`CliqueComplex`] is the **legacy AoS representation** (one `Vec<u32>`
//! per simplex). Production code uses the columnar
//! [`FlatComplex`](super::flat::FlatComplex); this type is retained as the
//! reference implementation for the differential property suite
//! (`rust/tests/flat_vs_legacy.rs`), the legacy engine
//! ([`crate::homology::legacy`]), and the `flat_complex` layout bench.
//! [`count_cliques`] remains the production clique counter (Fig 7).

use super::filtration::Filtration;
use super::simplex::Simplex;
use crate::graph::core::sorted_intersection_into;
use crate::graph::Graph;

/// One simplex in a filtered complex.
#[derive(Clone, Debug, PartialEq)]
pub struct FilteredSimplex {
    pub simplex: Simplex,
    /// Ascending sort key (sublevel-normalised; see [`Filtration::key`]).
    pub key: f64,
}

/// A filtered flag complex, simplices in filtration order.
#[derive(Clone, Debug, Default)]
pub struct CliqueComplex {
    /// Simplices sorted by (key, dim, lexicographic vertices).
    pub simplices: Vec<FilteredSimplex>,
}

impl CliqueComplex {
    /// Build the clique complex of `g` up to `max_dim`-simplices, filtered
    /// by the vertex function. To compute `PD_k` you need `max_dim = k+1`.
    pub fn build(g: &Graph, f: &Filtration, max_dim: usize) -> CliqueComplex {
        f.check(g).expect("filtration must match graph");
        let mut simplices: Vec<FilteredSimplex> = Vec::new();

        // dim 0
        for v in 0..g.n() as u32 {
            simplices.push(FilteredSimplex {
                simplex: Simplex::from_sorted(vec![v]),
                key: f.key(v),
            });
        }

        // dims >= 1 by ordered expansion: a clique is discovered exactly
        // once as its ascending vertex tuple. §Perf: candidate buffers are
        // pooled per recursion depth — no allocation in the inner loop.
        let mut stack_clique: Vec<u32> = Vec::new();
        let mut pool: Vec<Vec<u32>> = vec![Vec::new(); max_dim + 2];
        let mut cand: Vec<u32> = Vec::new();
        for v in 0..(if max_dim == 0 { 0 } else { g.n() }) as u32 {
            stack_clique.clear();
            stack_clique.push(v);
            cand.clear();
            cand.extend(g.neighbors(v).iter().copied().filter(|&w| w > v));
            expand(
                g,
                f,
                max_dim,
                &mut stack_clique,
                &cand,
                f.key(v),
                &mut simplices,
                &mut pool,
            );
        }

        // §Perf: integer key transform avoids partial_cmp in the hot sort.
        simplices.sort_unstable_by(|a, b| {
            crate::util::sortable_f64(a.key)
                .cmp(&crate::util::sortable_f64(b.key))
                .then(a.simplex.dim().cmp(&b.simplex.dim()))
                .then(a.simplex.vertices().cmp(b.simplex.vertices()))
        });
        CliqueComplex { simplices }
    }

    /// Number of simplices per dimension.
    pub fn counts_by_dim(&self) -> Vec<usize> {
        let mut counts = Vec::new();
        for s in &self.simplices {
            let d = s.simplex.dim();
            if counts.len() <= d {
                counts.resize(d + 1, 0);
            }
            counts[d] += 1;
        }
        counts
    }

    pub fn len(&self) -> usize {
        self.simplices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.simplices.is_empty()
    }

    /// Max dimension present.
    pub fn dim(&self) -> usize {
        self.simplices
            .iter()
            .map(|s| s.simplex.dim())
            .max()
            .unwrap_or(0)
    }
}

/// Recursive ordered clique expansion. `clique` is the current ascending
/// tuple, `cand` the common later neighbours, `key` the running max,
/// `pool` the per-depth candidate buffers (allocation-free inner loop).
#[allow(clippy::too_many_arguments)]
fn expand(
    g: &Graph,
    f: &Filtration,
    max_dim: usize,
    clique: &mut Vec<u32>,
    cand: &[u32],
    key: f64,
    out: &mut Vec<FilteredSimplex>,
    pool: &mut Vec<Vec<u32>>,
) {
    let depth = clique.len();
    for (i, &w) in cand.iter().enumerate() {
        clique.push(w);
        let k = key.max(f.key(w));
        out.push(FilteredSimplex {
            simplex: Simplex::from_sorted(clique.clone()),
            key: k,
        });
        if clique.len() <= max_dim {
            // candidates after w that stay adjacent to the whole clique
            let mut next = std::mem::take(&mut pool[depth]);
            sorted_intersection_into(&cand[i + 1..], g.neighbors(w), &mut next);
            if !next.is_empty() {
                expand(g, f, max_dim, clique, &next, k, out, pool);
            }
            pool[depth] = next;
        }
        clique.pop();
    }
}

/// Count cliques of each size 1..=max_size without materialising them
/// (Fig 7's simplex-count reduction metric). §Perf: candidate buffers are
/// pooled per recursion depth, the same scheme as `expand` — no
/// allocation in the inner loop.
pub fn count_cliques(g: &Graph, max_size: usize) -> Vec<usize> {
    let mut counts = vec![0usize; max_size.max(1)];
    if max_size == 0 {
        return counts;
    }
    counts[0] = g.n();
    fn rec(
        g: &Graph,
        depth: usize,
        cand: &[u32],
        max_size: usize,
        counts: &mut [usize],
        pool: &mut Vec<Vec<u32>>,
    ) {
        for (i, &w) in cand.iter().enumerate() {
            counts[depth] += 1;
            if depth + 1 < max_size {
                let mut next = std::mem::take(&mut pool[depth]);
                sorted_intersection_into(&cand[i + 1..], g.neighbors(w), &mut next);
                if !next.is_empty() {
                    rec(g, depth + 1, &next, max_size, counts, pool);
                }
                pool[depth] = next;
            }
        }
    }
    let mut pool: Vec<Vec<u32>> = vec![Vec::new(); max_size + 1];
    let mut root_cand: Vec<u32> = Vec::new();
    for v in 0..g.n() as u32 {
        root_cand.clear();
        root_cand.extend(g.neighbors(v).iter().copied().filter(|&w| w > v));
        if !root_cand.is_empty() && max_size >= 2 {
            rec(g, 1, &root_cand, max_size, &mut counts, &mut pool);
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn keys_valid(c: &CliqueComplex) {
        // faces precede cofaces in the sorted order
        let pos: std::collections::HashMap<&[u32], usize> = c
            .simplices
            .iter()
            .enumerate()
            .map(|(i, s)| (s.simplex.vertices(), i))
            .collect();
        for (i, s) in c.simplices.iter().enumerate() {
            if s.simplex.dim() == 0 {
                continue;
            }
            for face in s.simplex.faces() {
                let j = pos[face.vertices()];
                assert!(j < i, "face {face} must precede {}", s.simplex);
            }
        }
    }

    #[test]
    fn triangle_complex() {
        let g = gen::complete(3);
        let f = Filtration::constant(3);
        let c = CliqueComplex::build(&g, &f, 2);
        assert_eq!(c.counts_by_dim(), vec![3, 3, 1]);
        keys_valid(&c);
    }

    #[test]
    fn k4_counts() {
        let g = gen::complete(4);
        let c = CliqueComplex::build(&g, &Filtration::constant(4), 3);
        assert_eq!(c.counts_by_dim(), vec![4, 6, 4, 1]);
        keys_valid(&c);
    }

    #[test]
    fn dim_cap_respected() {
        let g = gen::complete(6);
        let c = CliqueComplex::build(&g, &Filtration::constant(6), 2);
        assert_eq!(c.dim(), 2);
        // C(6,1), C(6,2), C(6,3)
        assert_eq!(c.counts_by_dim(), vec![6, 15, 20]);
    }

    #[test]
    fn octahedron_has_no_tetrahedra() {
        let g = gen::octahedron();
        let c = CliqueComplex::build(&g, &Filtration::constant(6), 3);
        assert_eq!(c.counts_by_dim(), vec![6, 12, 8]); // S² triangulation
    }

    #[test]
    fn simplex_key_is_max_vertex_key() {
        let g = gen::complete(3);
        let f = Filtration::sublevel(vec![1.0, 5.0, 3.0]);
        let c = CliqueComplex::build(&g, &f, 2);
        let tri = c
            .simplices
            .iter()
            .find(|s| s.simplex.dim() == 2)
            .unwrap();
        assert_eq!(tri.key, 5.0);
        keys_valid(&c);
    }

    #[test]
    fn superlevel_ordering_reverses() {
        let g = gen::path(3); // 0-1-2, degrees 1,2,1
        let f = Filtration::degree_superlevel(&g);
        let c = CliqueComplex::build(&g, &f, 1);
        // vertex 1 (degree 2) must enter first under superlevel
        assert_eq!(c.simplices[0].simplex.vertices(), &[1]);
        keys_valid(&c);
    }

    #[test]
    fn count_cliques_matches_materialised() {
        for seed in 0..5 {
            let g = gen::erdos_renyi(30, 0.3, seed);
            let c = CliqueComplex::build(&g, &Filtration::constant(30), 3);
            let counted = count_cliques(&g, 4);
            let built = c.counts_by_dim();
            for d in 0..4 {
                assert_eq!(
                    counted.get(d).copied().unwrap_or(0),
                    built.get(d).copied().unwrap_or(0),
                    "dim {d} mismatch (seed {seed})"
                );
            }
        }
    }

    #[test]
    fn empty_graph_complex() {
        let g = crate::graph::Graph::empty(0);
        let c = CliqueComplex::build(&g, &Filtration::constant(0), 2);
        assert!(c.is_empty());
        assert_eq!(count_cliques(&g, 3), vec![0, 0, 0]);
    }
}
