//! Clique complexes and filtrations (S6/S7): the simplicial machinery the
//! paper's persistence diagrams are defined over (§3).

pub mod clique;
pub mod filtration;
pub mod power;
pub mod simplex;

pub use clique::{count_cliques, CliqueComplex};
pub use filtration::{Direction, Filtration};
pub use simplex::Simplex;
