//! Clique complexes and filtrations (S6/S7): the simplicial machinery the
//! paper's persistence diagrams are defined over (§3).
//!
//! The production representation is the columnar [`FlatComplex`]
//! (`flat.rs`): vertex arena + CSR offsets + boundary columns resolved at
//! construction. The AoS [`CliqueComplex`] (`clique.rs`) is retained as
//! the reference implementation for differential tests and the
//! `flat_complex` bench.

pub mod clique;
pub mod filtration;
pub mod flat;
pub mod power;
pub mod simplex;

pub use clique::{count_cliques, CliqueComplex};
pub use filtration::{Direction, Filtration};
pub use flat::{ComplexWorkspace, FlatComplex, FlatComplexBuilder};
pub use simplex::Simplex;
