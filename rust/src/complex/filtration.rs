//! Vertex filtering functions and sublevel/superlevel directions (§3).
//!
//! A filtration is a function `f : V → ℝ` plus a direction. Sublevel
//! filtrations include vertex `v` once the threshold passes `f(v)` from
//! below; superlevel from above. Internally everything is normalised to
//! "ascending order of a sort key": the key is `f` for sublevel and `−f`
//! for superlevel, so the PH engine only ever sees sublevel semantics —
//! exactly the trick Remark 8 uses (`f(u) ≤ f(v)` superlevel admissibility
//! equals sublevel admissibility on `−f`).

use crate::error::{Error, Result};
use crate::graph::Graph;

/// Filtration direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Include vertices with `f(v) ≤ α` as α grows (paper default).
    Sublevel,
    /// Include vertices with `f(v) ≥ α` as α decreases.
    Superlevel,
}

/// A filtering function on the vertices of a graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Filtration {
    values: Vec<f64>,
    direction: Direction,
}

impl Filtration {
    /// From explicit values (sublevel).
    pub fn sublevel(values: Vec<f64>) -> Filtration {
        Filtration {
            values,
            direction: Direction::Sublevel,
        }
    }

    /// From explicit values (superlevel).
    pub fn superlevel(values: Vec<f64>) -> Filtration {
        Filtration {
            values,
            direction: Direction::Superlevel,
        }
    }

    /// The paper's most common choice: vertex degree, sublevel.
    pub fn degree(g: &Graph) -> Filtration {
        Filtration::sublevel(g.degrees().iter().map(|&d| d as f64).collect())
    }

    /// Degree function with superlevel direction (paper Fig 5a). Under
    /// superlevel + degree, *every* dominated vertex is admissible
    /// (Remark 8: `deg(u) ≤ deg(v)` whenever v dominates u).
    pub fn degree_superlevel(g: &Graph) -> Filtration {
        Filtration::superlevel(g.degrees().iter().map(|&d| d as f64).collect())
    }

    /// Constant filtration — turns PH into plain homology (Betti numbers).
    pub fn constant(n: usize) -> Filtration {
        Filtration::sublevel(vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn direction(&self) -> Direction {
        self.direction
    }

    /// Raw f value of a vertex.
    #[inline]
    pub fn value(&self, v: u32) -> f64 {
        self.values[v as usize]
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Ascending sort key: `f` for sublevel, `−f` for superlevel. The PH
    /// engine and diagrams operate in key space; `display_value` maps back.
    #[inline]
    pub fn key(&self, v: u32) -> f64 {
        match self.direction {
            Direction::Sublevel => self.values[v as usize],
            Direction::Superlevel => -self.values[v as usize],
        }
    }

    /// Map a key back to the user-facing filtration value.
    #[inline]
    pub fn display_value(&self, key: f64) -> f64 {
        match self.direction {
            Direction::Sublevel => key,
            Direction::Superlevel => -key,
        }
    }

    /// PrunIT admissibility (Thm 7 / Rmk 8): may `u` (dominated by `v`) be
    /// removed? Sublevel: `f(u) ≥ f(v)`; superlevel: `f(u) ≤ f(v)`.
    /// Both reduce to `key(u) ≥ key(v)`.
    #[inline]
    pub fn admissible_removal(&self, u: u32, v: u32) -> bool {
        self.key(u) >= self.key(v)
    }

    /// Restrict to a surviving vertex set (`new id -> old id`), keeping the
    /// ORIGINAL values (paper Remark 1: f is restricted, never recomputed).
    pub fn restrict(&self, old_ids: &[u32]) -> Filtration {
        Filtration {
            values: old_ids.iter().map(|&v| self.values[v as usize]).collect(),
            direction: self.direction,
        }
    }

    /// Validate the filtration matches a graph.
    pub fn check(&self, g: &Graph) -> Result<()> {
        if self.values.len() == g.n() {
            Ok(())
        } else {
            Err(Error::FiltrationMismatch {
                filtration: self.values.len(),
                order: g.n(),
            })
        }
    }

    /// As f32 key values — marshalling format for the XLA domination
    /// artifact (which implements sublevel semantics on keys).
    pub fn keys_f32(&self) -> Vec<f32> {
        (0..self.values.len() as u32)
            .map(|v| self.key(v) as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn degree_filtration_values() {
        let g = gen::star(4);
        let f = Filtration::degree(&g);
        assert_eq!(f.values(), &[3.0, 1.0, 1.0, 1.0]);
        assert_eq!(f.direction(), Direction::Sublevel);
    }

    #[test]
    fn superlevel_key_negates() {
        let f = Filtration::superlevel(vec![1.0, 5.0]);
        assert_eq!(f.key(0), -1.0);
        assert_eq!(f.display_value(f.key(1)), 5.0);
    }

    #[test]
    fn admissibility_directions() {
        // sublevel: u removable iff f(u) >= f(v)
        let sub = Filtration::sublevel(vec![2.0, 1.0]);
        assert!(sub.admissible_removal(0, 1));
        assert!(!sub.admissible_removal(1, 0));
        // superlevel: u removable iff f(u) <= f(v)
        let sup = Filtration::superlevel(vec![2.0, 1.0]);
        assert!(!sup.admissible_removal(0, 1));
        assert!(sup.admissible_removal(1, 0));
        // ties are admissible both ways in both directions
        let tie = Filtration::sublevel(vec![3.0, 3.0]);
        assert!(tie.admissible_removal(0, 1) && tie.admissible_removal(1, 0));
    }

    #[test]
    fn degree_superlevel_always_admits_dominated() {
        // v dominates u ⇒ deg(u) ≤ deg(v) ⇒ superlevel-admissible (Rmk 8).
        let g = gen::star(5);
        let f = Filtration::degree_superlevel(&g);
        for leaf in 1..5u32 {
            assert!(f.admissible_removal(leaf, 0));
        }
    }

    #[test]
    fn restrict_keeps_original_values() {
        let f = Filtration::sublevel(vec![10.0, 20.0, 30.0, 40.0]);
        let r = f.restrict(&[1, 3]);
        assert_eq!(r.values(), &[20.0, 40.0]);
    }

    #[test]
    fn check_mismatch() {
        let g = gen::path(3);
        assert!(Filtration::constant(3).check(&g).is_ok());
        assert!(Filtration::constant(2).check(&g).is_err());
    }
}
