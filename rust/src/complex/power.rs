//! Power filtration (§5, Thm 10): `G^n` adds edges between all vertex
//! pairs at graph distance ≤ n; the filtration is the nested sequence of
//! clique complexes `Ĝ⁰ ⊂ Ĝ¹ ⊂ …`. Equivalent formulation (used here):
//! a Vietoris–Rips-style flag filtration over shortest-path distances —
//! a simplex's key is the max pairwise distance of its vertices, vertices
//! enter at key 0.
//!
//! Power filtrations explode combinatorially (a connected graph's
//! diameter-power is complete), so this module is deliberately scoped to
//! the small graphs of the paper's power-filtration results: the PrunIT
//! extension (Thm 10) and the CoralTDA counterexample on cycles (Rmk 11).
//! The complex is emitted through [`FlatComplexBuilder`] into the same
//! columnar layout the production clique path uses.

use super::flat::{FlatComplex, FlatComplexBuilder};
use crate::graph::Graph;

/// All-pairs shortest-path distances via BFS from every vertex.
/// `usize::MAX` marks unreachable pairs.
pub fn distance_matrix(g: &Graph) -> Vec<Vec<usize>> {
    (0..g.n() as u32).map(|v| g.bfs_distances(v)).collect()
}

/// Build the power filtration of `g` as a filtered flag complex, capped at
/// `max_dim`-simplices and power ≤ `max_power`.
pub fn power_complex(g: &Graph, max_dim: usize, max_power: usize) -> FlatComplex {
    let dist = distance_matrix(g);
    let n = g.n();
    // Threshold graph at max_power, as sorted adjacency lists.
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for u in 0..n {
        for v in (u + 1)..n {
            let d = dist[u][v];
            if d != usize::MAX && d >= 1 && d <= max_power {
                adj[u].push(v as u32);
                adj[v].push(u as u32);
            }
        }
    }
    for l in adj.iter_mut() {
        l.sort_unstable();
    }

    let mut builder = FlatComplexBuilder::new();
    for v in 0..n as u32 {
        builder.push(&[v], 0.0);
    }

    // Ordered clique expansion over the threshold graph, tracking the max
    // pairwise distance incrementally.
    fn expand(
        adj: &[Vec<u32>],
        dist: &[Vec<usize>],
        max_dim: usize,
        clique: &mut Vec<u32>,
        cand: &[u32],
        key: usize,
        out: &mut FlatComplexBuilder,
    ) {
        for (i, &w) in cand.iter().enumerate() {
            let mut k = key;
            for &m in clique.iter() {
                k = k.max(dist[m as usize][w as usize]);
            }
            clique.push(w);
            out.push(&clique[..], k as f64);
            if clique.len() <= max_dim {
                let next: Vec<u32> = cand[i + 1..]
                    .iter()
                    .copied()
                    .filter(|&x| adj[w as usize].binary_search(&x).is_ok())
                    .collect();
                if !next.is_empty() {
                    expand(adj, dist, max_dim, clique, &next, k, out);
                }
            }
            clique.pop();
        }
    }

    let mut clique = Vec::new();
    for v in 0..n as u32 {
        clique.clear();
        clique.push(v);
        let cand: Vec<u32> = adj[v as usize]
            .iter()
            .copied()
            .filter(|&w| w > v)
            .collect();
        expand(&adj, &dist, max_dim, &mut clique, &cand, 0, &mut builder);
    }

    match builder.finish() {
        Ok(c) => c,
        // Flag expansion over the threshold graph emits every face.
        Err(e) => unreachable!("power-flag expansion is face-closed: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn find(c: &FlatComplex, tuple: &[u32]) -> usize {
        (0..c.len())
            .find(|&i| c.vertices_of(i) == tuple)
            .unwrap_or_else(|| panic!("tuple {tuple:?} not in complex"))
    }

    #[test]
    fn distances_on_cycle() {
        let g = gen::cycle(6);
        let d = distance_matrix(&g);
        assert_eq!(d[0][3], 3);
        assert_eq!(d[0][5], 1);
        assert_eq!(d[2][2], 0);
    }

    #[test]
    fn unreachable_is_max() {
        let g = crate::graph::Graph::from_edges(3, &[(0, 1)]);
        let d = distance_matrix(&g);
        assert_eq!(d[0][2], usize::MAX);
    }

    #[test]
    fn power_one_equals_clique_complex_counts() {
        let g = gen::erdos_renyi(18, 0.25, 3);
        let pc = power_complex(&g, 2, 1);
        let cc = FlatComplex::build(
            &g,
            &super::super::filtration::Filtration::constant(g.n()),
            2,
        );
        assert_eq!(pc.counts_by_dim(), cc.counts_by_dim());
    }

    #[test]
    fn c5_power2_is_complete() {
        // C5 squared is K5 → power-2 complex has C(5,3) triangles.
        let g = gen::cycle(5);
        let pc = power_complex(&g, 2, 2);
        assert_eq!(pc.counts_by_dim(), vec![5, 10, 10]);
    }

    #[test]
    fn keys_are_max_pairwise_distance() {
        let g = gen::path(4); // 0-1-2-3
        let pc = power_complex(&g, 2, 3);
        assert_eq!(pc.key_of(find(&pc, &[0, 1, 2])), 2.0);
        assert_eq!(pc.key_of(find(&pc, &[0, 1, 3])), 3.0);
    }

    #[test]
    fn faces_precede_cofaces() {
        let g = gen::cycle(7);
        let pc = power_complex(&g, 3, 3);
        for i in 0..pc.len() {
            let col = pc.boundary_of(i);
            if pc.dim_of(i) == 0 {
                assert!(col.is_empty());
            } else {
                assert_eq!(col.len(), pc.dim_of(i) + 1);
            }
            for &r in col {
                assert!((r as usize) < i, "face {r} must precede coface {i}");
            }
        }
    }
}
