//! Property-test mini-framework (S16) — proptest is not in the offline
//! registry, so the crate ships its own: seeded case generation with
//! per-case reproduction seeds in failure messages.

pub mod graph_gen;
pub mod propcheck;

pub use graph_gen::{random_filtration, random_graph_case, GraphCase};
pub use propcheck::forall;
