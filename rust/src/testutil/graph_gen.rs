//! Random test-case generators shared by the theorem property suites.

use crate::complex::{Direction, Filtration};
use crate::graph::{gen, Graph};
use crate::util::Rng;

/// A generated test case: a graph plus a human-readable description for
/// failure messages.
#[derive(Clone, Debug)]
pub struct GraphCase {
    pub graph: Graph,
    pub desc: String,
}

/// Sample a graph from a diverse family mix (ER sparse/dense, BA, WS,
/// geometric, planted communities, deterministic families).
pub fn random_graph_case(rng: &mut Rng, max_n: usize) -> GraphCase {
    let n = rng.range(3, max_n.max(4));
    let family = rng.below(8);
    let seed = rng.next_u64();
    let (graph, desc) = match family {
        0 => (
            gen::erdos_renyi(n, 0.15, seed),
            format!("ER(n={n}, p=0.15, seed={seed})"),
        ),
        1 => (
            gen::erdos_renyi(n, 0.45, seed),
            format!("ER(n={n}, p=0.45, seed={seed})"),
        ),
        2 => {
            let m = rng.range(1, 3);
            (
                gen::barabasi_albert(n, m, seed),
                format!("BA(n={n}, m={m}, seed={seed})"),
            )
        }
        3 => {
            let nn = n.max(6);
            (
                gen::watts_strogatz(nn, 4, 0.2, seed),
                format!("WS(n={nn}, k=4, beta=0.2, seed={seed})"),
            )
        }
        4 => (
            gen::random_geometric(n, 0.35, seed),
            format!("RGG(n={n}, r=0.35, seed={seed})"),
        ),
        5 => (
            gen::planted_partition(n, 2.max(n / 6), 0.5, 0.05, seed),
            format!("PP(n={n}, seed={seed})"),
        ),
        6 => (gen::cycle(n), format!("C{n}")),
        _ => {
            let m = rng.range(1, 2);
            (
                gen::powerlaw_cluster(n, m, 0.7, seed),
                format!("PLC(n={n}, m={m}, seed={seed})"),
            )
        }
    };
    GraphCase { graph, desc }
}

/// Sample a filtration for a graph: degree or random-integer values (ties
/// are important for theorem edge cases), sublevel or superlevel.
pub fn random_filtration(rng: &mut Rng, g: &Graph) -> Filtration {
    let dir = if rng.chance(0.5) {
        Direction::Sublevel
    } else {
        Direction::Superlevel
    };
    let values: Vec<f64> = match rng.below(3) {
        0 => g.degrees().iter().map(|&d| d as f64).collect(),
        1 => (0..g.n()).map(|_| rng.below(4) as f64).collect(),
        _ => (0..g.n()).map(|_| rng.f64() * 10.0).collect(),
    };
    match dir {
        Direction::Sublevel => Filtration::sublevel(values),
        Direction::Superlevel => Filtration::superlevel(values),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_valid_graphs() {
        let mut rng = Rng::new(1);
        for _ in 0..30 {
            let case = random_graph_case(&mut rng, 25);
            assert!(case.graph.n() >= 1);
            assert!(!case.desc.is_empty());
            let f = random_filtration(&mut rng, &case.graph);
            assert!(f.check(&case.graph).is_ok());
        }
    }

    #[test]
    fn family_mix_has_variety() {
        let mut rng = Rng::new(2);
        let mut prefixes = std::collections::BTreeSet::new();
        for _ in 0..60 {
            let case = random_graph_case(&mut rng, 20);
            prefixes.insert(case.desc.chars().take(2).collect::<String>());
        }
        assert!(prefixes.len() >= 4, "want diverse families, got {prefixes:?}");
    }
}
