//! `forall`: run a property over `cases` seeded random cases; failures
//! report the case index and reproduction seed.

use crate::util::Rng;

/// Run `prop` for `cases` cases derived deterministically from `seed`.
/// The property receives a fresh RNG per case and returns `Err(reason)`
/// to signal failure.
///
/// Panics with the failing case's seed so
/// `forall("name", 1, failing_seed, prop)` reproduces it exactly.
pub fn forall<F>(name: &str, cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(reason) = prop(&mut rng) {
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (repro: forall(\"{name}\", 1, {case_seed}, ..)): {reason}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0usize);
        forall("count", 25, 1, |_rng| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        assert_eq!(counter.get(), 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_name() {
        forall("fails", 10, 2, |rng| {
            if rng.below(3) == 0 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn case_seeds_are_deterministic() {
        let mut seen_a = Vec::new();
        forall("det", 5, 3, |rng| {
            seen_a.push(rng.next_u64());
            Ok(())
        });
        let mut seen_b = Vec::new();
        forall("det", 5, 3, |rng| {
            seen_b.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }
}
