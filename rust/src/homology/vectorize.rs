//! Diagram vectorizations — the fixed-length features that downstream
//! graph-ML pipelines (the paper's §1 motivation: classification, link
//! prediction, anomaly detection) consume. Implements the standard
//! summaries: persistence statistics, Betti curves, persistence
//! landscapes, and persistence images.

use super::diagram::Diagram;

/// Scalar summary statistics of a diagram.
#[derive(Clone, Debug, PartialEq)]
pub struct DiagramStats {
    pub points: usize,
    pub essential: usize,
    pub total_persistence: f64,
    pub max_persistence: f64,
    pub mean_birth: f64,
    pub mean_death: f64,
}

/// Compute summary statistics (finite points only for death-derived
/// values; essential classes counted separately).
pub fn stats(d: &Diagram) -> DiagramStats {
    let pts = d.points();
    let finite: Vec<(f64, f64)> = pts.iter().copied().filter(|p| p.1.is_finite()).collect();
    let n = pts.len();
    DiagramStats {
        points: n,
        essential: d.essential().len(),
        total_persistence: d.total_persistence(),
        max_persistence: finite
            .iter()
            .map(|&(b, dd)| dd - b)
            .fold(0.0, f64::max),
        mean_birth: if n == 0 {
            0.0
        } else {
            pts.iter().map(|p| p.0).sum::<f64>() / n as f64
        },
        mean_death: if finite.is_empty() {
            0.0
        } else {
            finite.iter().map(|p| p.1).sum::<f64>() / finite.len() as f64
        },
    }
}

/// Betti curve: β(t) sampled at `bins` points across `[lo, hi]` — the
/// number of classes alive at each threshold. Essential classes count as
/// alive from birth onward.
pub fn betti_curve(d: &Diagram, lo: f64, hi: f64, bins: usize) -> Vec<f64> {
    assert!(bins >= 1 && hi >= lo);
    let mut curve = vec![0.0; bins];
    for (i, slot) in curve.iter_mut().enumerate() {
        let t = if bins == 1 {
            lo
        } else {
            lo + (hi - lo) * i as f64 / (bins - 1) as f64
        };
        *slot = d
            .all_pairs()
            .iter()
            .filter(|&&(b, dd)| b <= t && t < dd)
            .count() as f64;
    }
    curve
}

/// Persistence landscape: the k-th landscape λ_k sampled at `bins` points
/// over `[lo, hi]`. λ_k(t) = k-th largest value of the tent functions
/// Λ_p(t) = max(0, min(t − b, d − t)).
pub fn landscape(d: &Diagram, k: usize, lo: f64, hi: f64, bins: usize) -> Vec<f64> {
    assert!(bins >= 1 && hi >= lo);
    let finite: Vec<(f64, f64)> = d
        .points()
        .into_iter()
        .filter(|p| p.1.is_finite())
        .collect();
    let mut out = vec![0.0; bins];
    let mut tents: Vec<f64> = Vec::with_capacity(finite.len());
    for (i, slot) in out.iter_mut().enumerate() {
        let t = if bins == 1 {
            lo
        } else {
            lo + (hi - lo) * i as f64 / (bins - 1) as f64
        };
        tents.clear();
        tents.extend(
            finite
                .iter()
                .map(|&(b, dd)| (t - b).min(dd - t).max(0.0))
                .filter(|&v| v > 0.0),
        );
        tents.sort_by(|a, b| b.partial_cmp(a).unwrap());
        *slot = tents.get(k).copied().unwrap_or(0.0);
    }
    out
}

/// Persistence image: a `res × res` grid over (birth, persistence) space
/// with Gaussian bumps of bandwidth `sigma`, weighted by persistence.
pub fn persistence_image(d: &Diagram, res: usize, sigma: f64) -> Vec<f64> {
    assert!(res >= 1 && sigma > 0.0);
    let finite: Vec<(f64, f64)> = d
        .points()
        .into_iter()
        .filter(|p| p.1.is_finite())
        .map(|(b, dd)| (b, dd - b)) // (birth, persistence)
        .collect();
    let mut img = vec![0.0; res * res];
    if finite.is_empty() {
        return img;
    }
    let (mut blo, mut bhi) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut plo, mut phi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(b, p) in &finite {
        blo = blo.min(b);
        bhi = bhi.max(b);
        plo = plo.min(p);
        phi = phi.max(p);
    }
    let bspan = (bhi - blo).max(1e-9);
    let pspan = (phi - plo).max(1e-9);
    for iy in 0..res {
        for ix in 0..res {
            let gb = blo + bspan * ix as f64 / (res - 1).max(1) as f64;
            let gp = plo + pspan * iy as f64 / (res - 1).max(1) as f64;
            let mut acc = 0.0;
            for &(b, p) in &finite {
                let d2 = (gb - b) * (gb - b) + (gp - p) * (gp - p);
                // persistence-weighted Gaussian
                acc += p * (-d2 / (2.0 * sigma * sigma)).exp();
            }
            img[iy * res + ix] = acc;
        }
    }
    img
}

/// Concatenated feature vector for classification: stats + Betti curve.
pub fn feature_vector(diagrams: &[Diagram], lo: f64, hi: f64, bins: usize) -> Vec<f64> {
    let mut out = Vec::new();
    for d in diagrams {
        let s = stats(d);
        out.extend_from_slice(&[
            s.points as f64,
            s.essential as f64,
            s.total_persistence,
            s.max_persistence,
            s.mean_birth,
            s.mean_death,
        ]);
        out.extend(betti_curve(d, lo, hi, bins));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Diagram {
        Diagram::new(1, vec![(0.0, 2.0), (1.0, 4.0), (0.5, f64::INFINITY)])
    }

    #[test]
    fn stats_basic() {
        let s = stats(&demo());
        assert_eq!(s.points, 3);
        assert_eq!(s.essential, 1);
        assert!((s.total_persistence - 5.0).abs() < 1e-12);
        assert!((s.max_persistence - 3.0).abs() < 1e-12);
    }

    #[test]
    fn betti_curve_counts_alive() {
        let c = betti_curve(&demo(), 0.0, 4.0, 5); // t = 0,1,2,3,4
        // t=0: (0,2) alive → 1; t=1: (0,2),(1,4),(0.5,∞) → 3;
        // t=2: (1,4),(0.5,∞) → 2; t=3: same → 2; t=4: (0.5,∞) → 1
        assert_eq!(c, vec![1.0, 3.0, 2.0, 2.0, 1.0]);
    }

    #[test]
    fn landscape_tent_peak() {
        let d = Diagram::new(1, vec![(0.0, 2.0)]);
        let l0 = landscape(&d, 0, 0.0, 2.0, 5); // t = 0, .5, 1, 1.5, 2
        assert_eq!(l0, vec![0.0, 0.5, 1.0, 0.5, 0.0]);
        let l1 = landscape(&d, 1, 0.0, 2.0, 5);
        assert!(l1.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn landscape_order_statistic() {
        let d = Diagram::new(1, vec![(0.0, 2.0), (0.0, 2.0)]);
        let l1 = landscape(&d, 1, 0.0, 2.0, 3);
        assert_eq!(l1[1], 1.0, "second copy fills λ_1");
    }

    #[test]
    fn image_mass_positive_and_empty_is_zero() {
        let img = persistence_image(&demo(), 8, 0.5);
        assert_eq!(img.len(), 64);
        assert!(img.iter().sum::<f64>() > 0.0);
        let empty = persistence_image(&Diagram::new(0, vec![]), 8, 0.5);
        assert!(empty.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn feature_vector_shape() {
        let ds = vec![demo(), Diagram::new(0, vec![])];
        let v = feature_vector(&ds, 0.0, 4.0, 10);
        assert_eq!(v.len(), 2 * (6 + 10));
    }

    #[test]
    fn vectorizations_invariant_under_reduction() {
        // End-to-end: features from reduced and unreduced graphs agree —
        // the property that makes the paper's reductions safe for ML.
        use crate::complex::Filtration;
        use crate::graph::gen;
        let g = gen::powerlaw_cluster(60, 3, 0.6, 5);
        let f = Filtration::degree_superlevel(&g);
        let base = crate::homology::persistence_diagrams(&g, &f, 1);
        let r = crate::reduce::combined(&g, &f, 1).unwrap();
        let red = crate::homology::persistence_diagrams(&r.graph, &r.filtration, 1);
        let fa = feature_vector(&base[1..], -20.0, 0.0, 16);
        let fb = feature_vector(&red[1..], -20.0, 0.0, 16);
        for (a, b) in fa.iter().zip(&fb) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
