//! PD₀ fast path via union-find with the elder rule — the workload of the
//! paper's §6.2 OGB experiment (0-dimensional persistence of ego networks)
//! runs through this instead of matrix reduction.

use super::diagram::Diagram;
use crate::complex::Filtration;
use crate::graph::Graph;

struct Dsu {
    parent: Vec<u32>,
    /// birth key of the component's oldest member
    birth: Vec<f64>,
}

impl Dsu {
    fn new(births: Vec<f64>) -> Dsu {
        Dsu {
            parent: (0..births.len() as u32).collect(),
            birth: births,
        }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }
}

/// Compute PD₀ in O(m α(n)) after sorting.
pub fn pd0(g: &Graph, f: &Filtration) -> Diagram {
    f.check(g).expect("filtration must match graph");
    let n = g.n();
    let births: Vec<f64> = (0..n as u32).map(|v| f.key(v)).collect();
    let mut dsu = Dsu::new(births);

    // Edges in ascending key order (key(edge) = max endpoint key).
    // §Perf: sort on an order-preserving u64 transform of the f64 key —
    // integer comparisons beat partial_cmp on the 600k-edge workloads of
    // the large-network benches (see EXPERIMENTS.md §Perf).
    use crate::util::sortable_f64 as sortable;
    let mut edges: Vec<(u64, u32, u32)> = Vec::with_capacity(g.m());
    edges.extend(
        g.edges()
            .map(|(u, v)| (sortable(f.key(u).max(f.key(v))), u, v)),
    );
    edges.sort_unstable_by_key(|e| e.0);

    let mut pairs: Vec<(f64, f64)> = Vec::new();
    for (_, u, v) in edges {
        let key = f.key(u).max(f.key(v));
        let ru = dsu.find(u);
        let rv = dsu.find(v);
        if ru == rv {
            continue;
        }
        // Elder rule: the younger component (larger birth key) dies.
        let (elder, younger) = if dsu.birth[ru as usize] <= dsu.birth[rv as usize] {
            (ru, rv)
        } else {
            (rv, ru)
        };
        pairs.push((dsu.birth[younger as usize], key));
        dsu.parent[younger as usize] = elder;
    }

    // Surviving roots are essential components.
    for v in 0..n as u32 {
        if dsu.find(v) == v {
            pairs.push((dsu.birth[v as usize], f64::INFINITY));
        }
    }
    Diagram::new(0, pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complex::FlatComplex;
    use crate::homology::reduction::{diagrams_of_complex, Algorithm};
    use crate::graph::gen;
    use crate::util::Rng;

    #[test]
    fn single_component_path() {
        let g = gen::path(5);
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        let d = pd0(&g, &f);
        assert_eq!(d.betti(), 1);
        // vertices 1..4 each die immediately when their edge arrives
        assert_eq!(d.points().len(), 1); // only the essential point off-diagonal
    }

    #[test]
    fn merge_records_younger_death() {
        // two stars joined late: components born at 0 and 1, bridge at 5.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (2, 3), (1, 2)]);
        let f = Filtration::sublevel(vec![0.0, 0.0, 1.0, 1.0]);
        // edge keys: (0,1)→0, (2,3)→1, (1,2)→1
        let d = pd0(&g, &f);
        let pts = d.points();
        assert_eq!(d.betti(), 1);
        assert!(pts.contains(&(0.0, f64::INFINITY)));
        // component born at 1 is absorbed at key 1 → zero persistence,
        // filtered from points
        assert_eq!(pts.len(), 1);
    }

    #[test]
    fn isolated_vertices_are_essential() {
        let g = crate::graph::Graph::empty(3);
        let f = Filtration::sublevel(vec![5.0, 6.0, 7.0]);
        let d = pd0(&g, &f);
        assert_eq!(d.betti(), 3);
    }

    #[test]
    fn matches_matrix_reduction_on_random_graphs() {
        let mut rng = Rng::new(17);
        for _ in 0..25 {
            let n = rng.range(2, 40);
            let g = gen::erdos_renyi(n, 0.08, rng.next_u64());
            let vals: Vec<f64> = (0..n).map(|_| rng.below(6) as f64).collect();
            let f = Filtration::sublevel(vals);
            let fast = pd0(&g, &f);
            let c = FlatComplex::build(&g, &f, 1);
            let slow = &diagrams_of_complex(&c, 0, Algorithm::Twist)[0];
            assert!(
                fast.same_as(slow, 1e-12),
                "uf {fast} vs matrix {slow} (n={n})"
            );
        }
    }

    #[test]
    fn superlevel_direction_respected() {
        let g = gen::path(3);
        let f = Filtration::degree_superlevel(&g); // degrees 1,2,1
        let d = pd0(&g, &f);
        // center (deg 2, key −2) enters first; endpoints merge in at key −1
        assert_eq!(d.betti(), 1);
        assert_eq!(d.essential(), vec![-2.0]);
    }
}
