//! Legacy AoS persistence engine — the reference implementation the
//! columnar [`FlatComplex`](crate::complex::FlatComplex) engine replaced.
//!
//! Retained deliberately: the differential property suite
//! (`rust/tests/flat_vs_legacy.rs`) and the `flat_complex` bench compare
//! the production engine against this one, so every layout-level
//! optimisation stays *measured against* and *equal to* a known-good
//! baseline. Its two costs are exactly what the flat engine deleted:
//!
//! * [`BoundaryMatrix::build`] re-derives every face of every simplex
//!   through a `HashMap<&[u32], usize>` over per-simplex `Vec`s;
//! * [`reduce`] clones the entire column set before reducing.
//!
//! Do not wire this into production paths — use
//! [`crate::homology::reduction`].

use std::collections::HashMap;

use super::diagram::Diagram;
use super::reduction::{Algorithm, DenseColumn, ReductionResult};
use crate::complex::clique::CliqueComplex;
use crate::error::{Error, Result};

/// Sparse boundary matrix in filtration order (AoS layout).
pub struct BoundaryMatrix {
    /// columns[j] = sorted row indices of ∂(simplex_j); dim-0 columns empty.
    pub columns: Vec<Vec<u32>>,
    /// Simplex dimension per column.
    pub dims: Vec<usize>,
    /// Filtration key per column.
    pub keys: Vec<f64>,
}

impl BoundaryMatrix {
    /// Build from a filtered complex (simplices already in filtration
    /// order with faces preceding cofaces). A face absent from the
    /// complex surfaces as [`Error::FaceMissing`].
    pub fn build(c: &CliqueComplex) -> Result<BoundaryMatrix> {
        let n = c.simplices.len();
        // same u32 row-index cap the flat engine asserts in `finish`
        assert!(
            n <= u32::MAX as usize,
            "complex exceeds the u32 row-index space ({n} simplices)"
        );
        let mut index: HashMap<&[u32], usize> = HashMap::with_capacity(n);
        for (i, s) in c.simplices.iter().enumerate() {
            index.insert(s.simplex.vertices(), i);
        }
        let mut columns = Vec::with_capacity(n);
        let mut dims = Vec::with_capacity(n);
        let mut keys = Vec::with_capacity(n);
        let mut face_buf: Vec<u32> = Vec::new();
        for s in &c.simplices {
            let verts = s.simplex.vertices();
            let d = s.simplex.dim();
            dims.push(d);
            keys.push(s.key);
            if d == 0 {
                columns.push(Vec::new());
                continue;
            }
            let mut col = Vec::with_capacity(verts.len());
            for drop in 0..verts.len() {
                face_buf.clear();
                face_buf.extend(verts.iter().enumerate().filter_map(|(i, &v)| {
                    if i == drop {
                        None
                    } else {
                        Some(v)
                    }
                }));
                let row = *index.get(face_buf.as_slice()).ok_or_else(|| Error::FaceMissing {
                    simplex: crate::complex::flat::fmt_tuple(verts),
                    face: crate::complex::flat::fmt_tuple(&face_buf),
                })?;
                col.push(row as u32);
            }
            col.sort_unstable();
            columns.push(col);
        }
        Ok(BoundaryMatrix { columns, dims, keys })
    }

    pub fn max_dim(&self) -> usize {
        self.dims.iter().copied().max().unwrap_or(0)
    }
}

/// Run the legacy reduction and extract index pairs. Clones the full
/// column set up front — the allocation the flat engine's lazy
/// working-column scheme removed.
pub fn reduce(matrix: &BoundaryMatrix, algorithm: Algorithm) -> ReductionResult {
    let n = matrix.columns.len();
    let mut cols: Vec<Vec<u32>> = matrix.columns.clone();
    // pivot_of_row[r] = column whose low is r.
    let mut pivot_of_row: Vec<Option<usize>> = vec![None; n];
    let mut dense = DenseColumn::new(n);

    let mut process = |j: usize, cols: &mut Vec<Vec<u32>>, pivot_of_row: &mut Vec<Option<usize>>| {
        let Some(&start_low) = cols[j].last() else { return };
        let start_low = start_low as usize;
        // Fast path: unique low already — no dense round-trip needed.
        if pivot_of_row[start_low].is_none() {
            pivot_of_row[start_low] = Some(j);
            return;
        }
        dense.load(&cols[j]);
        let mut low = start_low;
        loop {
            match pivot_of_row[low] {
                Some(jp) => {
                    dense.xor(&cols[jp]);
                    // the shared low always cancels; next low is strictly
                    // below it
                    match (low > 0).then(|| dense.low_at_or_below(low - 1)).flatten() {
                        Some(l) => low = l,
                        None => {
                            // column reduced to zero
                            cols[j].clear();
                            return;
                        }
                    }
                }
                None => {
                    pivot_of_row[low] = Some(j);
                    dense.drain_into(low, &mut cols[j]);
                    return;
                }
            }
        }
    };

    match algorithm {
        Algorithm::Standard => {
            for j in 0..n {
                process(j, &mut cols, &mut pivot_of_row);
            }
        }
        Algorithm::Twist => {
            let max_dim = matrix.max_dim();
            let mut cleared = vec![false; n];
            for d in (1..=max_dim).rev() {
                for j in 0..n {
                    if matrix.dims[j] != d || cleared[j] {
                        continue;
                    }
                    process(j, &mut cols, &mut pivot_of_row);
                    if let Some(&low) = cols[j].last() {
                        // The paired creator column reduces to zero — clear.
                        let low = low as usize;
                        cleared[low] = true;
                        cols[low].clear();
                    }
                }
            }
        }
    }

    let mut pairs = Vec::new();
    let mut is_negative = vec![false; n];
    for (row, &column) in pivot_of_row.iter().enumerate() {
        if let Some(j) = column {
            pairs.push((row, j));
            is_negative[j] = true;
        }
    }
    let mut paired_birth = vec![false; n];
    for &(b, _) in &pairs {
        paired_birth[b] = true;
    }
    let essential = (0..n)
        .filter(|&i| !paired_birth[i] && !is_negative[i])
        .collect();
    ReductionResult { pairs, essential }
}

/// Persistence diagrams PD_0..PD_max_k through the legacy AoS pipeline.
pub fn diagrams_of_complex(
    c: &CliqueComplex,
    max_k: usize,
    algorithm: Algorithm,
) -> Result<Vec<Diagram>> {
    let matrix = BoundaryMatrix::build(c)?;
    let red = reduce(&matrix, algorithm);
    let mut per_dim: Vec<Vec<(f64, f64)>> = vec![Vec::new(); max_k + 1];
    for &(b, d) in &red.pairs {
        let k = matrix.dims[b];
        if k <= max_k {
            per_dim[k].push((matrix.keys[b], matrix.keys[d]));
        }
    }
    for &i in &red.essential {
        let k = matrix.dims[i];
        if k <= max_k {
            per_dim[k].push((matrix.keys[i], f64::INFINITY));
        }
    }
    Ok(per_dim
        .into_iter()
        .enumerate()
        .map(|(k, pairs)| Diagram::new(k, pairs))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complex::clique::FilteredSimplex;
    use crate::complex::{Filtration, Simplex};
    use crate::graph::gen;

    #[test]
    fn known_spaces_through_legacy_pipeline() {
        let g = gen::octahedron();
        let c = CliqueComplex::build(&g, &Filtration::constant(6), 3);
        let pds = diagrams_of_complex(&c, 2, Algorithm::Twist).unwrap();
        assert_eq!(pds[0].betti(), 1);
        assert_eq!(pds[1].betti(), 0);
        assert_eq!(pds[2].betti(), 1);
    }

    #[test]
    fn missing_face_is_typed_error_not_panic() {
        // triangle [0,1,2] whose edge [1,2] was never added
        let mk = |v: Vec<u32>, key: f64| FilteredSimplex {
            simplex: Simplex::from_sorted(v),
            key,
        };
        let c = CliqueComplex {
            simplices: vec![
                mk(vec![0], 0.0),
                mk(vec![1], 0.0),
                mk(vec![2], 0.0),
                mk(vec![0, 1], 0.0),
                mk(vec![0, 2], 0.0),
                mk(vec![0, 1, 2], 0.0),
            ],
        };
        match BoundaryMatrix::build(&c) {
            Err(Error::FaceMissing { simplex, face }) => {
                assert_eq!(simplex, "[0,1,2]");
                assert_eq!(face, "[1,2]");
            }
            Ok(_) => panic!("expected FaceMissing error"),
            Err(other) => panic!("wrong error variant: {other}"),
        }
    }

    #[test]
    fn standard_equals_twist_through_legacy_path() {
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..6 {
            let n = rng.range(4, 18);
            let g = gen::erdos_renyi(n, 0.35, rng.next_u64());
            let f = Filtration::degree(&g);
            let c = CliqueComplex::build(&g, &f, 3);
            let a = diagrams_of_complex(&c, 2, Algorithm::Standard).unwrap();
            let b = diagrams_of_complex(&c, 2, Algorithm::Twist).unwrap();
            for k in 0..=2 {
                assert!(a[k].same_as(&b[k], 1e-12));
            }
        }
    }
}
