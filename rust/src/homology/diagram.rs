//! Persistence diagrams: multisets of (birth, death) pairs per homology
//! dimension (§3). Values live in *key space* (sublevel-normalised; see
//! [`crate::complex::Filtration::key`]); `death = +∞` marks essential
//! classes.

/// A single persistence diagram `PD_k`.
#[derive(Clone, Debug, Default)]
pub struct Diagram {
    dim: usize,
    pairs: Vec<(f64, f64)>,
}

impl Diagram {
    pub fn new(dim: usize, mut pairs: Vec<(f64, f64)>) -> Diagram {
        pairs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Diagram { dim, pairs }
    }

    /// Homology dimension k of this PD_k.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// All pairs, including zero-persistence ones, sorted.
    pub fn all_pairs(&self) -> &[(f64, f64)] {
        &self.pairs
    }

    /// Off-diagonal points (birth ≠ death) — what the paper's diagrams
    /// contain; homotopy-equivalence arguments preserve exactly these.
    pub fn points(&self) -> Vec<(f64, f64)> {
        self.pairs.iter().copied().filter(|&(b, d)| b != d).collect()
    }

    /// Essential (infinite) classes.
    pub fn essential(&self) -> Vec<f64> {
        self.pairs
            .iter()
            .filter(|&&(_, d)| d.is_infinite())
            .map(|&(b, _)| b)
            .collect()
    }

    /// Betti number of the final space = number of essential classes.
    pub fn betti(&self) -> usize {
        self.essential().len()
    }

    /// Total (finite) persistence Σ (d − b).
    pub fn total_persistence(&self) -> f64 {
        self.pairs
            .iter()
            .filter(|&&(_, d)| d.is_finite())
            .map(|&(b, d)| d - b)
            .sum()
    }

    /// Multiset equality of off-diagonal points up to `tol` per coordinate.
    /// This is the equality the paper's theorems assert (diagrams agree up
    /// to zero-persistence pairs).
    pub fn same_as(&self, other: &Diagram, tol: f64) -> bool {
        let a = self.points();
        let b = other.points();
        if a.len() != b.len() {
            return false;
        }
        a.iter().zip(b.iter()).all(|(&(b1, d1), &(b2, d2))| {
            (b1 - b2).abs() <= tol
                && ((d1.is_infinite() && d2.is_infinite()) || (d1 - d2).abs() <= tol)
        })
    }

    pub fn is_trivial(&self) -> bool {
        self.points().is_empty()
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

impl std::fmt::Display for Diagram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PD_{} {{", self.dim)?;
        for (i, (b, d)) in self.points().iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            if d.is_infinite() {
                write!(f, "({b:.3},∞)")?;
            } else {
                write!(f, "({b:.3},{d:.3})")?;
            }
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn points_filter_diagonal() {
        let d = Diagram::new(0, vec![(1.0, 1.0), (0.0, 2.0), (0.5, f64::INFINITY)]);
        assert_eq!(d.points().len(), 2);
        assert_eq!(d.len(), 3);
    }

    #[test]
    fn essential_and_betti() {
        let d = Diagram::new(1, vec![(0.0, f64::INFINITY), (1.0, 3.0)]);
        assert_eq!(d.betti(), 1);
        assert_eq!(d.essential(), vec![0.0]);
    }

    #[test]
    fn same_as_is_multiset_equality() {
        let a = Diagram::new(0, vec![(0.0, 1.0), (2.0, 3.0)]);
        let b = Diagram::new(0, vec![(2.0, 3.0), (0.0, 1.0)]);
        assert!(a.same_as(&b, 1e-9));
        let c = Diagram::new(0, vec![(0.0, 1.0), (2.0, 4.0)]);
        assert!(!a.same_as(&c, 1e-9));
    }

    #[test]
    fn same_as_ignores_zero_persistence() {
        let a = Diagram::new(0, vec![(0.0, 1.0), (5.0, 5.0)]);
        let b = Diagram::new(0, vec![(0.0, 1.0)]);
        assert!(a.same_as(&b, 1e-9));
    }

    #[test]
    fn infinite_deaths_compare_equal() {
        let a = Diagram::new(1, vec![(1.0, f64::INFINITY)]);
        let b = Diagram::new(1, vec![(1.0, f64::INFINITY)]);
        assert!(a.same_as(&b, 1e-9));
        let c = Diagram::new(1, vec![(1.0, 9.0)]);
        assert!(!a.same_as(&c, 1e-9));
    }

    #[test]
    fn total_persistence_sums_finite() {
        let d = Diagram::new(0, vec![(0.0, 2.0), (1.0, f64::INFINITY), (3.0, 4.5)]);
        assert!((d.total_persistence() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn display_renders() {
        let d = Diagram::new(1, vec![(0.0, f64::INFINITY)]);
        assert_eq!(format!("{d}"), "PD_1 {(0.000,∞)}");
    }
}
