//! Bottleneck distance between persistence diagrams — used by the test
//! suite as a robust diagram comparator and by downstream ML users of the
//! library.
//!
//! Implementation: binary search over candidate ε (the classic reduction)
//! with a Hopcroft–Karp-style feasibility check on the ε-threshold
//! bipartite graph, where every point may also match its diagonal
//! projection. Diagrams in this crate are small (thousands of points at
//! most), so the O(E·√V) matching is more than fast enough.

use super::diagram::Diagram;

const INF_MISMATCH: f64 = f64::INFINITY;

/// L∞ distance between two points, treating +∞ coordinates exactly.
fn dist_inf(a: (f64, f64), b: (f64, f64)) -> f64 {
    let dd = match (a.1.is_infinite(), b.1.is_infinite()) {
        (true, true) => 0.0,
        (false, false) => (a.1 - b.1).abs(),
        _ => return INF_MISMATCH,
    };
    (a.0 - b.0).abs().max(dd)
}

/// Distance from a (finite) point to the diagonal.
fn diag_dist(p: (f64, f64)) -> f64 {
    if p.1.is_infinite() {
        INF_MISMATCH
    } else {
        (p.1 - p.0) / 2.0
    }
}

/// Bottleneck distance between two diagrams (must be same homology dim to
/// be meaningful; not enforced).
pub fn bottleneck(a: &Diagram, b: &Diagram) -> f64 {
    let pa = a.points();
    let pb = b.points();
    if pa.is_empty() && pb.is_empty() {
        return 0.0;
    }
    // Infinite points must match among themselves; count mismatch = ∞.
    let inf_a = pa.iter().filter(|p| p.1.is_infinite()).count();
    let inf_b = pb.iter().filter(|p| p.1.is_infinite()).count();
    if inf_a != inf_b {
        return f64::INFINITY;
    }

    // Candidate ε values: all pairwise distances + diagonal distances.
    let mut cands: Vec<f64> = Vec::new();
    for &x in &pa {
        for &y in &pb {
            let d = dist_inf(x, y);
            if d.is_finite() {
                cands.push(d);
            }
        }
        let d = diag_dist(x);
        if d.is_finite() {
            cands.push(d);
        }
    }
    for &y in &pb {
        let d = diag_dist(y);
        if d.is_finite() {
            cands.push(d);
        }
    }
    cands.push(0.0);
    cands.sort_by(|x, y| x.partial_cmp(y).unwrap());
    cands.dedup();

    // Binary search the smallest feasible ε.
    let mut lo = 0usize;
    let mut hi = cands.len() - 1;
    if !feasible(&pa, &pb, cands[hi]) {
        return f64::INFINITY;
    }
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(&pa, &pb, cands[mid]) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    cands[lo]
}

/// Is there a perfect matching at threshold ε (points may use diagonal)?
fn feasible(pa: &[(f64, f64)], pb: &[(f64, f64)], eps: f64) -> bool {
    let eps = eps + 1e-12;
    let na = pa.len();
    let nb = pb.len();
    // Left nodes: points of A. Right: points of B. A point of A whose
    // diagonal distance ≤ ε may stay unmatched; similarly for B — the
    // standard trick: check max matching among "must-match" nodes.
    // Build adjacency restricted to pairs within ε.
    let adj: Vec<Vec<usize>> = pa
        .iter()
        .map(|&x| {
            (0..nb)
                .filter(|&j| dist_inf(x, pb[j]) <= eps)
                .collect::<Vec<_>>()
        })
        .collect();
    let a_must: Vec<bool> = pa.iter().map(|&x| diag_dist(x) > eps).collect();
    let b_must: Vec<bool> = pb.iter().map(|&y| diag_dist(y) > eps).collect();

    // Greedy + augmenting paths (Kuhn's algorithm) for must-match lefts,
    // then verify every must-match right is covered.
    let mut match_b: Vec<Option<usize>> = vec![None; nb];
    let mut match_a: Vec<Option<usize>> = vec![None; na];

    fn try_augment(
        u: usize,
        adj: &[Vec<usize>],
        match_a: &mut [Option<usize>],
        match_b: &mut [Option<usize>],
        seen: &mut [bool],
    ) -> bool {
        for &v in &adj[u] {
            if seen[v] {
                continue;
            }
            seen[v] = true;
            let free = match match_b[v] {
                None => true,
                Some(u2) => try_augment(u2, adj, match_a, match_b, seen),
            };
            if free {
                match_b[v] = Some(u);
                match_a[u] = Some(v);
                return true;
            }
        }
        false
    }

    for u in 0..na {
        if a_must[u] && match_a[u].is_none() {
            let mut seen = vec![false; nb];
            if !try_augment(u, &adj, &mut match_a, &mut match_b, &mut seen) {
                return false;
            }
        }
    }
    // Every must-match right must be matched; try augmenting from
    // optional lefts to free them up.
    for v in 0..nb {
        if b_must[v] && match_b[v].is_none() {
            // find any left adjacent to v that can route there
            let mut done = false;
            for u in 0..na {
                if match_a[u].is_none() && adj[u].contains(&v) {
                    let mut seen = vec![false; nb];
                    if try_augment(u, &adj, &mut match_a, &mut match_b, &mut seen)
                        && match_b[v].is_some()
                    {
                        done = true;
                        break;
                    }
                }
            }
            if !done && match_b[v].is_none() {
                return false;
            }
        }
    }
    true
}

/// 1-Wasserstein distance (L∞ ground metric) via the Hungarian algorithm
/// on the augmented matching problem: each point may match a point of the
/// other diagram or its own diagonal projection. Diagrams with different
/// essential-class counts are at distance +∞.
pub fn wasserstein1(a: &Diagram, b: &Diagram) -> f64 {
    let pa = a.points();
    let pb = b.points();
    let inf_a = pa.iter().filter(|p| p.1.is_infinite()).count();
    let inf_b = pb.iter().filter(|p| p.1.is_infinite()).count();
    if inf_a != inf_b {
        return f64::INFINITY;
    }
    // Split: essentials match among themselves (sorted births — optimal
    // for 1-d transport); finite points go through the assignment solver.
    let mut ess_a: Vec<f64> = pa.iter().filter(|p| p.1.is_infinite()).map(|p| p.0).collect();
    let mut ess_b: Vec<f64> = pb.iter().filter(|p| p.1.is_infinite()).map(|p| p.0).collect();
    ess_a.sort_by(|x, y| x.partial_cmp(y).unwrap());
    ess_b.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let ess_cost: f64 = ess_a
        .iter()
        .zip(&ess_b)
        .map(|(x, y)| (x - y).abs())
        .sum();

    let fa: Vec<(f64, f64)> = pa.into_iter().filter(|p| p.1.is_finite()).collect();
    let fb: Vec<(f64, f64)> = pb.into_iter().filter(|p| p.1.is_finite()).collect();
    // Augmented square cost matrix: n+m rows/cols; point↔point, point↔its
    // diagonal, diagonal↔diagonal (0).
    let (n, m) = (fa.len(), fb.len());
    let size = n + m;
    if size == 0 {
        return ess_cost;
    }
    let mut cost = vec![vec![0.0f64; size]; size];
    for i in 0..size {
        for j in 0..size {
            cost[i][j] = match (i < n, j < m) {
                (true, true) => dist_inf(fa[i], fb[j]),
                (true, false) => diag_dist(fa[i]),
                (false, true) => diag_dist(fb[j]),
                (false, false) => 0.0,
            };
        }
    }
    ess_cost + hungarian(&cost)
}

/// O(n³) Hungarian algorithm (Jonker-style potentials) for square cost
/// matrices; returns the minimal assignment cost.
fn hungarian(cost: &[Vec<f64>]) -> f64 {
    let n = cost.len();
    if n == 0 {
        return 0.0;
    }
    const INF: f64 = f64::INFINITY;
    // 1-indexed potentials
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut total = 0.0;
    for j in 1..=n {
        if p[j] > 0 {
            total += cost[p[j] - 1][j - 1];
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_diagrams_distance_zero() {
        let a = Diagram::new(1, vec![(0.0, 3.0), (1.0, 2.0)]);
        let b = Diagram::new(1, vec![(1.0, 2.0), (0.0, 3.0)]);
        assert_eq!(bottleneck(&a, &b), 0.0);
    }

    #[test]
    fn single_point_shift() {
        let a = Diagram::new(1, vec![(0.0, 4.0)]);
        let b = Diagram::new(1, vec![(0.5, 4.0)]);
        assert!((bottleneck(&a, &b) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn point_vs_empty_uses_diagonal() {
        let a = Diagram::new(1, vec![(0.0, 2.0)]);
        let b = Diagram::new(1, vec![]);
        assert!((bottleneck(&a, &b) - 1.0).abs() < 1e-9); // (2−0)/2
    }

    #[test]
    fn infinite_count_mismatch_is_infinite() {
        let a = Diagram::new(0, vec![(0.0, f64::INFINITY)]);
        let b = Diagram::new(0, vec![]);
        assert!(bottleneck(&a, &b).is_infinite());
    }

    #[test]
    fn infinite_points_compare_by_birth() {
        let a = Diagram::new(0, vec![(0.0, f64::INFINITY)]);
        let b = Diagram::new(0, vec![(0.75, f64::INFINITY)]);
        assert!((bottleneck(&a, &b) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn symmetric() {
        let a = Diagram::new(1, vec![(0.0, 3.0), (2.0, 6.0)]);
        let b = Diagram::new(1, vec![(0.5, 3.5)]);
        assert!((bottleneck(&a, &b) - bottleneck(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn wasserstein_identical_is_zero() {
        let a = Diagram::new(1, vec![(0.0, 3.0), (2.0, 6.0), (1.0, f64::INFINITY)]);
        let b = Diagram::new(1, vec![(2.0, 6.0), (1.0, f64::INFINITY), (0.0, 3.0)]);
        assert!(wasserstein1(&a, &b).abs() < 1e-12);
    }

    #[test]
    fn wasserstein_sums_shifts() {
        let a = Diagram::new(1, vec![(0.0, 4.0), (10.0, 14.0)]);
        let b = Diagram::new(1, vec![(0.5, 4.0), (10.0, 14.5)]);
        assert!((wasserstein1(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn wasserstein_unmatched_goes_to_diagonal() {
        let a = Diagram::new(1, vec![(0.0, 2.0), (5.0, 5.4)]);
        let b = Diagram::new(1, vec![(0.0, 2.0)]);
        // (5, 5.4) pays its diagonal distance 0.2
        assert!((wasserstein1(&a, &b) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn wasserstein_dominates_bottleneck() {
        let a = Diagram::new(1, vec![(0.0, 3.0), (1.0, 5.0)]);
        let b = Diagram::new(1, vec![(0.2, 3.0), (1.0, 4.5)]);
        assert!(wasserstein1(&a, &b) >= bottleneck(&a, &b) - 1e-12);
    }

    #[test]
    fn wasserstein_essential_mismatch_infinite() {
        let a = Diagram::new(0, vec![(0.0, f64::INFINITY)]);
        let b = Diagram::new(0, vec![]);
        assert!(wasserstein1(&a, &b).is_infinite());
    }

    #[test]
    fn hungarian_small_matrix() {
        let cost = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        assert!((hungarian(&cost) - 5.0).abs() < 1e-12); // 1 + 2 + 2
    }
}
