//! Persistent homology engine (S8) — the computation whose cost the
//! paper's reductions attack. Z/2 clique-complex persistence over the
//! columnar [`FlatComplex`](crate::complex::FlatComplex), with a
//! union-find fast path for PD₀ and a twist-optimised matrix reduction
//! for higher dimensions. The pre-columnar AoS engine survives in
//! [`legacy`] as the differential-testing baseline.

pub mod diagram;
pub mod distance;
pub mod legacy;
pub mod reduction;
pub mod sharded;
pub mod union_find;
pub mod vectorize;

pub use diagram::Diagram;
pub use distance::{bottleneck, wasserstein1};
pub use reduction::{
    diagrams_of_complex, diagrams_of_complex_cancellable, diagrams_of_complex_with, reduce,
    reduce_cancellable, reduce_with, Algorithm, PhConfig, PhStats, ReductionResult,
};
pub use sharded::{
    merge_shard_diagrams, persistence_diagrams_sharded, persistence_diagrams_sharded_with,
};
pub use union_find::pd0;

use crate::complex::{ComplexWorkspace, Filtration};
use crate::error::Result;
use crate::graph::Graph;
use crate::util::team::TeamSlot;
use crate::util::CancelToken;

/// Persistence diagrams `PD_0 .. PD_max_k` of `(G, f)` over the clique-
/// complex sublevel/superlevel filtration (§3). Uses the union-find fast
/// path when only PD₀ is requested.
pub fn persistence_diagrams(g: &Graph, f: &Filtration, max_k: usize) -> Vec<Diagram> {
    persistence_diagrams_with(&mut ComplexWorkspace::new(), g, f, max_k)
}

/// [`persistence_diagrams`] reusing a caller-held [`ComplexWorkspace`] —
/// the batch entry point: shard workers and coordinator threads hold one
/// workspace each, so complex construction allocates once per thread, not
/// once per job.
pub fn persistence_diagrams_with(
    ws: &mut ComplexWorkspace,
    g: &Graph,
    f: &Filtration,
    max_k: usize,
) -> Vec<Diagram> {
    persistence_diagrams_cancellable(ws, g, f, max_k, &CancelToken::none())
        .expect("persistence with a none token cannot be cancelled")
}

/// [`persistence_diagrams_with`] with cooperative cancellation: polls the
/// token before and after complex construction and threads it into the
/// column reduction, so a job past its deadline unwinds with
/// `Error::DeadlineExceeded` instead of finishing the cubic loop.
pub fn persistence_diagrams_cancellable(
    ws: &mut ComplexWorkspace,
    g: &Graph,
    f: &Filtration,
    max_k: usize,
    cancel: &CancelToken,
) -> Result<Vec<Diagram>> {
    persistence_diagrams_ph(
        ws,
        g,
        f,
        max_k,
        &PhConfig::default(),
        &mut TeamSlot::default(),
        cancel,
    )
    .map(|(d, _)| d)
}

/// [`persistence_diagrams_cancellable`] with the full persistence-engine
/// config: `ph` picks the reduction algorithm and (for
/// [`Algorithm::Chunked`]) the thread budget, `team` is the caller's
/// persistent thread team for the chunked local phase. Returns the
/// apparent-vs-reduced pair split alongside the diagrams. PD₀-only
/// requests still take the union-find elder-rule path — no boundary
/// matrix is built.
#[allow(clippy::too_many_arguments)]
pub fn persistence_diagrams_ph(
    ws: &mut ComplexWorkspace,
    g: &Graph,
    f: &Filtration,
    max_k: usize,
    ph: &PhConfig,
    team: &mut TeamSlot,
    cancel: &CancelToken,
) -> Result<(Vec<Diagram>, PhStats)> {
    cancel.check()?;
    if max_k == 0 {
        return Ok((vec![pd0(g, f)], PhStats::default()));
    }
    let complex = ws.build_clique(g, f, max_k + 1);
    cancel.check()?;
    diagrams_of_complex_with(&complex, max_k, ph, team, cancel)
}

/// Betti numbers β₀..β_max_k of the clique complex of `G` (constant
/// filtration → essential classes = homology ranks). Figure 2 / Figure 10
/// count these as "numbers of topological features".
pub fn betti_numbers(g: &Graph, max_k: usize) -> Vec<usize> {
    let f = Filtration::constant(g.n());
    persistence_diagrams(g, &f, max_k)
        .iter()
        .map(|d| d.betti())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complex::FlatComplex;
    use crate::graph::gen;

    #[test]
    fn betti_of_known_spaces() {
        assert_eq!(betti_numbers(&gen::cycle(7), 2), vec![1, 1, 0]);
        assert_eq!(betti_numbers(&gen::complete(5), 2), vec![1, 0, 0]);
        assert_eq!(betti_numbers(&gen::octahedron(), 2), vec![1, 0, 1]);
        assert_eq!(betti_numbers(&gen::star(6), 1), vec![1, 0]);
        assert_eq!(betti_numbers(&crate::graph::Graph::empty(4), 1), vec![4, 0]);
    }

    #[test]
    fn grid_loops_all_filled_none() {
        // 3x3 grid: 4 squares, no triangles → β₁ = 4.
        assert_eq!(betti_numbers(&gen::grid(3, 3), 1), vec![1, 4]);
    }

    #[test]
    fn pd0_fast_path_used_and_correct() {
        let g = gen::barabasi_albert(60, 2, 3);
        let f = Filtration::degree(&g);
        let fast = persistence_diagrams(&g, &f, 0);
        let complex = FlatComplex::build(&g, &f, 1);
        let slow = diagrams_of_complex(&complex, 0, Algorithm::Standard);
        assert!(fast[0].same_as(&slow[0], 1e-12));
    }

    #[test]
    fn diagram_count_matches_request() {
        let g = gen::cycle(5);
        let f = Filtration::degree(&g);
        assert_eq!(persistence_diagrams(&g, &f, 2).len(), 3);
    }

    #[test]
    fn workspace_variant_matches_fresh_path() {
        let mut ws = ComplexWorkspace::new();
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..5 {
            let n = rng.range(4, 20);
            let g = gen::erdos_renyi(n, 0.3, rng.next_u64());
            let f = Filtration::degree_superlevel(&g);
            let a = persistence_diagrams_with(&mut ws, &g, &f, 2);
            let b = persistence_diagrams(&g, &f, 2);
            for k in 0..=2 {
                assert!(a[k].same_as(&b[k], 0.0));
            }
        }
    }
}
