//! Z/2 boundary-matrix reduction — the O(n³) computation the paper's
//! algorithms exist to shrink (§1: "the complexity of the standard PH
//! algorithm is cubic in the number of simplices").
//!
//! The reducers consume a [`FlatComplex`]'s boundary CSR **in place**:
//! unreduced columns are read straight from the arena, and per-column
//! storage materialises only for columns the reduction actually rewrites
//! (the legacy engine cloned the whole column set up front — see
//! [`super::legacy`]). Two strategies over the same layout:
//!
//! Three strategies over the same layout:
//!
//! * `standard` — textbook left-to-right reduction [59].
//! * `twist` — Chen–Kerber clearing: process dimensions top-down and clear
//!   columns of paired (creator) simplices, skipping their reduction
//!   entirely. Property-tested equal to `standard`.
//! * `chunked` — PHAT-style chunk parallelism on top of twist, preceded by
//!   an apparent-pair prepass (Ripser-style shortcut). Diagrams are
//!   bit-identical to `twist` at every thread count and chunk size: with
//!   Z/2 left-to-right column additions the final pivot assignment is
//!   unique, so any legal completion — and both the prepass and the
//!   chunked schedule only ever add columns from the left — lands on the
//!   same pairing. See [`reduce_with`].

use super::diagram::Diagram;
use crate::complex::flat::FlatComplex;
use crate::error::{Error, Result};
use crate::util::team::TeamSlot;
use crate::util::CancelToken;

/// Cancellation-poll granularity: one deadline check per this many
/// processed columns. 1024 columns is far above the cost of an atomic
/// load + `Instant::now()`, so the overhead is unmeasurable, while a
/// runaway cubic reduction still observes its deadline within
/// milliseconds.
pub(crate) const CANCEL_CHECK_COLS: usize = 1024;

/// Which reduction algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Standard,
    Twist,
    /// Apparent-pair prepass + chunk-parallel twist + sequential global
    /// sweep. Bit-identical to [`Algorithm::Twist`]; thread count and
    /// chunk size come from [`PhConfig`].
    Chunked,
}

impl Algorithm {
    /// Parse a `--ph-algorithm` / config / request-line value.
    pub fn parse(s: &str) -> Result<Algorithm> {
        match s {
            "standard" => Ok(Algorithm::Standard),
            "twist" => Ok(Algorithm::Twist),
            "chunked" => Ok(Algorithm::Chunked),
            other => Err(Error::Config(format!(
                "unknown PH algorithm {other:?} (expected standard|twist|chunked)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Standard => "standard",
            Algorithm::Twist => "twist",
            Algorithm::Chunked => "chunked",
        }
    }
}

/// Persistence-engine knobs threaded from CLI/config/serve request lines
/// down to [`reduce_with`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PhConfig {
    pub algorithm: Algorithm,
    /// Worker threads for the chunked local phase; `0` = auto (available
    /// parallelism), `1` = sequential. Ignored by standard/twist.
    pub threads: usize,
    /// Columns per chunk in the local phase; `0` = auto (scaled so each
    /// thread sees several chunks per dimension).
    pub chunk_cols: usize,
}

impl Default for PhConfig {
    fn default() -> Self {
        PhConfig {
            algorithm: Algorithm::Twist,
            threads: 1,
            chunk_cols: 0,
        }
    }
}

impl PhConfig {
    /// Effective thread count (`0` resolves to available parallelism).
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1)
        } else {
            self.threads
        }
    }
}

/// How the pairs of a reduction were found — apparent-pair shortcut vs
/// full column reduction (standard/twist report everything as reduced).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhStats {
    /// Pairs emitted by the apparent-pair prepass without any column
    /// additions.
    pub apparent_pairs: usize,
    /// Pairs found by actual column reduction.
    pub reduced_pairs: usize,
}

/// Dense Z/2 working column: a reusable bitset for the reduction chain.
///
/// §Perf: reducing a column may add hundreds of pivot columns; with
/// sorted-vector columns each addition is an O(|target| + |other|) merge
/// that reallocates. XOR-ing into a bitset makes each addition
/// O(|other|) bit flips, and the new low is found by scanning downward
/// from the old low (which always cancels). Measured 2.2× end-to-end on
/// the reduction hot path (see EXPERIMENTS.md §Perf).
pub(crate) struct DenseColumn {
    words: Vec<u64>,
}

impl DenseColumn {
    pub(crate) fn new(rows: usize) -> DenseColumn {
        DenseColumn {
            words: vec![0; rows.div_ceil(64)],
        }
    }

    /// Load a sparse column (clears previous contents cheaply by
    /// re-zeroing only the words it may have touched — callers guarantee
    /// `drain_into` ran first).
    pub(crate) fn load(&mut self, col: &[u32]) {
        for &r in col {
            self.words[(r >> 6) as usize] ^= 1u64 << (r & 63);
        }
    }

    /// XOR a sparse column in.
    #[inline]
    pub(crate) fn xor(&mut self, col: &[u32]) {
        for &r in col {
            self.words[(r >> 6) as usize] ^= 1u64 << (r & 63);
        }
    }

    /// Highest set bit at or below `from`, if any.
    pub(crate) fn low_at_or_below(&self, from: usize) -> Option<usize> {
        let mut w = from >> 6;
        let mut mask = if (from & 63) == 63 {
            u64::MAX
        } else {
            (1u64 << ((from & 63) + 1)) - 1
        };
        loop {
            let bits = self.words[w] & mask;
            if bits != 0 {
                return Some((w << 6) + 63 - bits.leading_zeros() as usize);
            }
            if w == 0 {
                return None;
            }
            w -= 1;
            mask = u64::MAX;
        }
    }

    /// Extract set bits ≤ `max_row` into `out` (ascending) and zero them.
    pub(crate) fn drain_into(&mut self, max_row: usize, out: &mut Vec<u32>) {
        out.clear();
        let top = (max_row >> 6) + 1;
        for w in 0..top.min(self.words.len()) {
            let mut bits = self.words[w];
            self.words[w] = 0;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                out.push(((w << 6) + b) as u32);
                bits &= bits - 1;
            }
        }
    }
}

/// Output of a reduction: persistence pairs as (birth col, death col)
/// index pairs plus the essential (unpaired positive) columns.
pub struct ReductionResult {
    /// (birth simplex index, death simplex index); class dim = dim of birth.
    pub pairs: Vec<(usize, usize)>,
    /// Unpaired positive simplex indices (infinite classes).
    pub essential: Vec<usize>,
    /// Shortcut-vs-reduction split of `pairs`.
    pub stats: PhStats,
}

/// Current view of column `j`: the reduced form if the reduction rewrote
/// it, otherwise the original CSR slice straight from the complex arena.
#[inline]
fn col<'a>(c: &'a FlatComplex, work: &'a [Vec<u32>], touched: &[bool], j: usize) -> &'a [u32] {
    if touched[j] {
        &work[j]
    } else {
        c.boundary_of(j)
    }
}

/// Reduce column `j` against the pivots found so far.
fn process(
    j: usize,
    c: &FlatComplex,
    work: &mut [Vec<u32>],
    touched: &mut [bool],
    pivot_of_row: &mut [Option<usize>],
    dense: &mut DenseColumn,
) {
    let Some(&start_low) = col(c, work, touched, j).last() else {
        return; // structurally empty (dim-0) column
    };
    let start_low = start_low as usize;
    // Fast path: unique low already — the CSR slice stays the column's
    // reduced form; no dense round-trip, no storage.
    if pivot_of_row[start_low].is_none() {
        pivot_of_row[start_low] = Some(j);
        return;
    }
    dense.load(col(c, work, touched, j));
    let mut low = start_low;
    loop {
        match pivot_of_row[low] {
            Some(jp) => {
                dense.xor(col(c, work, touched, jp));
                // the shared low always cancels; next low is strictly
                // below it
                match (low > 0).then(|| dense.low_at_or_below(low - 1)).flatten() {
                    Some(l) => low = l,
                    None => {
                        // column reduced to zero
                        work[j].clear();
                        touched[j] = true;
                        return;
                    }
                }
            }
            None => {
                pivot_of_row[low] = Some(j);
                let out = &mut work[j];
                dense.drain_into(low, out);
                touched[j] = true;
                return;
            }
        }
    }
}

/// Run the reduction and extract index pairs. Columns are consumed from
/// the complex's boundary CSR; nothing is cloned up front.
pub fn reduce(c: &FlatComplex, algorithm: Algorithm) -> ReductionResult {
    reduce_cancellable(c, algorithm, &CancelToken::none())
        .expect("reduction with a none token cannot be cancelled")
}

/// [`reduce`] with cooperative cancellation: polls `cancel` every
/// [`CANCEL_CHECK_COLS`] processed columns and unwinds with
/// `Error::DeadlineExceeded` / `Error::Cancelled` instead of running the
/// cubic loop to completion.
pub fn reduce_cancellable(
    c: &FlatComplex,
    algorithm: Algorithm,
    cancel: &CancelToken,
) -> Result<ReductionResult> {
    let ph = PhConfig {
        algorithm,
        threads: 1,
        chunk_cols: 0,
    };
    reduce_with(c, &ph, &mut TeamSlot::default(), cancel)
}

/// [`reduce_cancellable`] with the full engine config: the chunked
/// algorithm runs its local phase on `team` (the caller's persistent
/// thread team — no pool is spawned here unless `ph` asks for more
/// workers than the slot already holds).
pub fn reduce_with(
    c: &FlatComplex,
    ph: &PhConfig,
    team: &mut TeamSlot,
    cancel: &CancelToken,
) -> Result<ReductionResult> {
    let n = c.len();
    // Lazily materialised reduced columns: work[j] is meaningful only
    // when touched[j]; untouched columns read from the arena.
    let mut work: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut touched = vec![false; n];
    // pivot_of_row[r] = column whose low is r.
    let mut pivot_of_row: Vec<Option<usize>> = vec![None; n];
    let mut apparent = 0usize;

    match ph.algorithm {
        Algorithm::Standard => {
            let mut dense = DenseColumn::new(n);
            let mut since_check = 0usize;
            for j in 0..n {
                since_check += 1;
                if since_check >= CANCEL_CHECK_COLS {
                    since_check = 0;
                    cancel.check()?;
                }
                process(j, c, &mut work, &mut touched, &mut pivot_of_row, &mut dense);
            }
        }
        Algorithm::Twist => {
            let mut dense = DenseColumn::new(n);
            let mut since_check = 0usize;
            let max_dim = c.dim();
            let mut cleared = vec![false; n];
            for d in (1..=max_dim).rev() {
                for j in 0..n {
                    if c.dim_of(j) != d || cleared[j] {
                        continue;
                    }
                    since_check += 1;
                    if since_check >= CANCEL_CHECK_COLS {
                        since_check = 0;
                        cancel.check()?;
                    }
                    process(j, c, &mut work, &mut touched, &mut pivot_of_row, &mut dense);
                    if let Some(&low) = col(c, &work, &touched, j).last() {
                        // The paired creator column reduces to zero — clear.
                        let low = low as usize;
                        cleared[low] = true;
                        work[low].clear();
                        touched[low] = true;
                    }
                }
            }
        }
        Algorithm::Chunked => {
            apparent = reduce_chunked(
                c,
                ph,
                team,
                cancel,
                &mut work,
                &mut touched,
                &mut pivot_of_row,
            )?;
        }
    }

    let mut pairs = Vec::new();
    let mut is_negative = vec![false; n];
    for (row, &column) in pivot_of_row.iter().enumerate() {
        if let Some(j) = column {
            pairs.push((row, j));
            is_negative[j] = true;
        }
    }
    let mut paired_birth = vec![false; n];
    for &(b, _) in &pairs {
        paired_birth[b] = true;
    }
    let essential = (0..n)
        .filter(|&i| !paired_birth[i] && !is_negative[i])
        .collect();
    let stats = PhStats {
        apparent_pairs: apparent,
        reduced_pairs: pairs.len() - apparent,
    };
    Ok(ReductionResult {
        pairs,
        essential,
        stats,
    })
}

/// Shared mutable column state handed to team workers by raw pointer.
/// Chunks partition the columns, each part writes only `work[j]` /
/// `touched[j]` for its own chunk's `j`, and cross-chunk reads target
/// only apparent columns (never touched), so slots never alias.
struct ColsPtr {
    work: *mut Vec<u32>,
    touched: *mut bool,
}

unsafe impl Send for ColsPtr {}
unsafe impl Sync for ColsPtr {}

/// Current view of column `j` through the raw-pointer window.
///
/// # Safety
/// `j` must be a column of the caller's own chunk or an apparent column
/// (whose `touched[j]` is never written by anyone).
unsafe fn col_at<'a>(c: &'a FlatComplex, p: &ColsPtr, j: usize) -> &'a [u32] {
    unsafe {
        if *p.touched.add(j) {
            &(*p.work.add(j))[..]
        } else {
            c.boundary_of(j)
        }
    }
}

/// Local (in-chunk) reduction of column `j`: additions may come from
/// apparent pivots (global, read-only during the local phase) and pivots
/// claimed earlier within the same chunk — both strictly left of `j`.
/// A low owned by neither is claimed tentatively into `local_pivot`; the
/// sequential global sweep settles cross-chunk conflicts.
#[allow(clippy::too_many_arguments)]
fn local_reduce(
    c: &FlatComplex,
    p: &ColsPtr,
    pivot_of_row: &[Option<usize>],
    local_pivot: &mut [u32],
    claimed: &mut Vec<u32>,
    j: usize,
    wj: &mut Vec<u32>,
    tj: &mut bool,
    dense: &mut DenseColumn,
) {
    debug_assert!(!*tj, "chunk columns start untouched");
    let cur = c.boundary_of(j);
    let Some(&start_low) = cur.last() else {
        return;
    };
    let mut low = start_low as usize;
    // Fast path: unclaimed low — the CSR slice stays the current form.
    if pivot_of_row[low].is_none() && local_pivot[low] == u32::MAX {
        local_pivot[low] = j as u32;
        claimed.push(low as u32);
        return;
    }
    dense.load(cur);
    loop {
        let owner = pivot_of_row[low].or_else(|| {
            let lp = local_pivot[low];
            (lp != u32::MAX).then_some(lp as usize)
        });
        match owner {
            Some(jp) => {
                // SAFETY: jp owns a pivot, so it is apparent (untouched,
                // read from the arena) or a column of this same chunk
                // (written only by this thread).
                dense.xor(unsafe { col_at(c, p, jp) });
                match (low > 0).then(|| dense.low_at_or_below(low - 1)).flatten() {
                    Some(l) => low = l,
                    None => {
                        // zeroed: final in every legal completion —
                        // drop it from the addition pool for good
                        wj.clear();
                        *tj = true;
                        return;
                    }
                }
            }
            None => {
                local_pivot[low] = j as u32;
                claimed.push(low as u32);
                dense.drain_into(low, wj);
                *tj = true;
                return;
            }
        }
    }
}

/// Chunked reduction: apparent-pair prepass, then per dimension
/// (top-down, preserving twist clearing) a chunk-parallel local phase on
/// the thread team followed by a sequential global sweep. Returns the
/// number of pairs emitted by the prepass.
fn reduce_chunked(
    c: &FlatComplex,
    ph: &PhConfig,
    team: &mut TeamSlot,
    cancel: &CancelToken,
    work: &mut [Vec<u32>],
    touched: &mut [bool],
    pivot_of_row: &mut [Option<usize>],
) -> Result<usize> {
    let n = c.len();

    // --- Apparent-pair prepass ------------------------------------------
    // oldest_cofacet[r] = oldest column whose boundary contains row r;
    // one CSR pass, first write wins.
    let mut oldest_cofacet: Vec<u32> = vec![u32::MAX; n];
    for j in 0..n {
        for &r in c.boundary_of(j) {
            if oldest_cofacet[r as usize] == u32::MAX {
                oldest_cofacet[r as usize] = j as u32;
            }
        }
    }
    cancel.check()?;
    // (σ, τ) is apparent when σ is the last entry of ∂τ and τ is the
    // oldest cofacet of σ. Then no column left of τ even contains row σ
    // (it would be an older cofacet), so τ reduces with zero additions —
    // its raw CSR slice is its final form — and pivot_of_row[σ] = τ in
    // every legal reduction. Emit the pair and clear the creator column
    // exactly as twist would when it reached τ.
    let mut cleared = vec![false; n];
    let mut apparent_death = vec![false; n];
    let mut apparent = 0usize;
    for j in 0..n {
        let Some(&low) = c.boundary_of(j).last() else {
            continue;
        };
        if oldest_cofacet[low as usize] == j as u32 {
            pivot_of_row[low as usize] = Some(j);
            cleared[low as usize] = true;
            apparent_death[j] = true;
            apparent += 1;
        }
    }
    drop(oldest_cofacet);
    cancel.check()?;

    // --- Chunk-parallel twist over the surviving columns ----------------
    let threads = ph.resolved_threads().max(1);
    let max_dim = c.dim();
    let mut cols: Vec<u32> = Vec::new();
    let mut dense = DenseColumn::new(n);
    let mut since_check = 0usize;
    for d in (1..=max_dim).rev() {
        // Column compression: the pool for this dimension is only the
        // still-live columns — cleared creators and apparent deaths are
        // never revisited.
        cols.clear();
        cols.extend(
            (0..n)
                .filter(|&j| c.dim_of(j) == d && !cleared[j] && !apparent_death[j])
                .map(|j| j as u32),
        );
        if cols.is_empty() {
            continue;
        }
        let chunk = if ph.chunk_cols > 0 {
            ph.chunk_cols
        } else {
            // several chunks per thread so strides stay load-balanced
            (cols.len() / (threads * 8)).max(64)
        };
        let nchunks = cols.len().div_ceil(chunk);
        let parts = threads.min(nchunks);
        if parts > 1 {
            let tm = team.get(parts - 1);
            let wptr = ColsPtr {
                work: work.as_mut_ptr(),
                touched: touched.as_mut_ptr(),
            };
            let pivot_ro: &[Option<usize>] = pivot_of_row;
            let cols_ref: &[u32] = &cols;
            let body = move |part: usize| {
                let mut dense = DenseColumn::new(n);
                let mut local_pivot: Vec<u32> = vec![u32::MAX; n];
                let mut claimed: Vec<u32> = Vec::new();
                let mut ci = part;
                while ci < nchunks {
                    // deadline polling at chunk boundaries; the global
                    // sweep's checkpoint turns expiry into the error
                    if cancel.is_expired() {
                        return;
                    }
                    for &r in &claimed {
                        local_pivot[r as usize] = u32::MAX;
                    }
                    claimed.clear();
                    let lo = ci * chunk;
                    let hi = (lo + chunk).min(cols_ref.len());
                    for &j32 in &cols_ref[lo..hi] {
                        let j = j32 as usize;
                        // SAFETY: chunks partition `cols`, each part owns
                        // its chunks' columns exclusively; `run` does not
                        // return before every part finished.
                        let (wj, tj) =
                            unsafe { (&mut *wptr.work.add(j), &mut *wptr.touched.add(j)) };
                        local_reduce(
                            c,
                            &wptr,
                            pivot_ro,
                            &mut local_pivot,
                            &mut claimed,
                            j,
                            wj,
                            tj,
                            &mut dense,
                        );
                    }
                    ci += parts;
                }
            };
            let worker_panics = tm.run(parts, &body);
            assert_eq!(
                worker_panics, 0,
                "{worker_panics} chunked-reduction team worker part(s) panicked"
            );
            cancel.check()?;
        }
        // Global sweep, sequential and ascending: most columns now carry
        // a unique low and claim it on the fast path; the few whose
        // pivots crossed chunk boundaries keep reducing here. Clearing
        // is applied exactly as in twist.
        for &j32 in &cols {
            let j = j32 as usize;
            since_check += 1;
            if since_check >= CANCEL_CHECK_COLS {
                since_check = 0;
                cancel.check()?;
            }
            process(j, c, work, touched, pivot_of_row, &mut dense);
            if let Some(&low) = col(c, work, touched, j).last() {
                let low = low as usize;
                cleared[low] = true;
                work[low].clear();
                touched[low] = true;
            }
        }
    }
    Ok(apparent)
}

/// Persistence diagrams PD_0..PD_max_k from a filtered complex.
///
/// The complex must contain simplices up to dimension `max_k + 1`,
/// otherwise deaths of k-classes are missed and PD_k is wrong.
pub fn diagrams_of_complex(c: &FlatComplex, max_k: usize, algorithm: Algorithm) -> Vec<Diagram> {
    diagrams_of_complex_cancellable(c, max_k, algorithm, &CancelToken::none())
        .expect("reduction with a none token cannot be cancelled")
}

/// [`diagrams_of_complex`] with cooperative cancellation threaded into
/// the column reduction.
pub fn diagrams_of_complex_cancellable(
    c: &FlatComplex,
    max_k: usize,
    algorithm: Algorithm,
    cancel: &CancelToken,
) -> Result<Vec<Diagram>> {
    let ph = PhConfig {
        algorithm,
        threads: 1,
        chunk_cols: 0,
    };
    diagrams_of_complex_with(c, max_k, &ph, &mut TeamSlot::default(), cancel).map(|(d, _)| d)
}

/// [`diagrams_of_complex_cancellable`] with the full engine config and
/// the caller's thread team; also returns the shortcut/reduction split.
pub fn diagrams_of_complex_with(
    c: &FlatComplex,
    max_k: usize,
    ph: &PhConfig,
    team: &mut TeamSlot,
    cancel: &CancelToken,
) -> Result<(Vec<Diagram>, PhStats)> {
    let red = reduce_with(c, ph, team, cancel)?;
    let mut per_dim: Vec<Vec<(f64, f64)>> = vec![Vec::new(); max_k + 1];
    for &(b, d) in &red.pairs {
        let k = c.dim_of(b);
        if k <= max_k {
            per_dim[k].push((c.key_of(b), c.key_of(d)));
        }
    }
    for &i in &red.essential {
        let k = c.dim_of(i);
        if k <= max_k {
            per_dim[k].push((c.key_of(i), f64::INFINITY));
        }
    }
    let diagrams = per_dim
        .into_iter()
        .enumerate()
        .map(|(k, pairs)| Diagram::new(k, pairs))
        .collect();
    Ok((diagrams, red.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complex::{Filtration, FlatComplex};
    use crate::graph::gen;

    fn diagrams(
        g: &crate::graph::Graph,
        f: &Filtration,
        max_k: usize,
        alg: Algorithm,
    ) -> Vec<Diagram> {
        let c = FlatComplex::build(g, f, max_k + 1);
        diagrams_of_complex(&c, max_k, alg)
    }

    #[test]
    fn circle_has_one_loop() {
        let g = gen::cycle(8);
        let f = Filtration::constant(8);
        for alg in [Algorithm::Standard, Algorithm::Twist] {
            let pds = diagrams(&g, &f, 1, alg);
            assert_eq!(pds[0].betti(), 1, "one component");
            assert_eq!(pds[1].betti(), 1, "one essential loop");
        }
    }

    #[test]
    fn complete_graph_is_contractible() {
        let g = gen::complete(5);
        let f = Filtration::constant(5);
        let pds = diagrams(&g, &f, 2, Algorithm::Twist);
        assert_eq!(pds[0].betti(), 1);
        assert_eq!(pds[1].betti(), 0);
        assert_eq!(pds[2].betti(), 0);
    }

    #[test]
    fn octahedron_is_a_sphere() {
        let g = gen::octahedron();
        let f = Filtration::constant(6);
        let pds = diagrams(&g, &f, 2, Algorithm::Twist);
        assert_eq!(pds[0].betti(), 1);
        assert_eq!(pds[1].betti(), 0);
        assert_eq!(pds[2].betti(), 1, "β₂(S²) = 1");
    }

    #[test]
    fn disjoint_union_is_additive() {
        // two 4-cycles, disjoint
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push((i, (i + 1) % 4));
            edges.push((4 + i, 4 + (i + 1) % 4));
        }
        let g = crate::graph::Graph::from_edges(8, &edges);
        let pds = diagrams(&g, &Filtration::constant(8), 1, Algorithm::Twist);
        assert_eq!(pds[0].betti(), 2);
        assert_eq!(pds[1].betti(), 2);
    }

    #[test]
    fn two_triangles_sharing_edge_no_loop() {
        // 0-1-2 and 1-2-3 triangles: the square with a diagonal; both
        // 1-cycles are filled by 2-simplices.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (1, 3), (2, 3)]);
        let pds = diagrams(&g, &Filtration::constant(4), 1, Algorithm::Twist);
        assert_eq!(pds[1].betti(), 0);
    }

    #[test]
    fn sublevel_degree_on_paper_figure1_loop() {
        // A 4-cycle with degree filtration: the loop is born when the last
        // of its vertices arrives, never dies (no triangles).
        let g = gen::cycle(4);
        let f = Filtration::degree(&g);
        let pds = diagrams(&g, &f, 1, Algorithm::Twist);
        assert_eq!(pds[1].betti(), 1);
        assert_eq!(pds[1].essential(), vec![2.0]); // all degrees are 2
    }

    #[test]
    fn birth_death_values_on_tadpole() {
        // triangle 0-1-2 plus pendant 3 on vertex 0, f = vertex id.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (0, 3)]);
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0, 3.0]);
        let pds = diagrams(&g, &f, 1, Algorithm::Twist);
        // components: 0 born at 0 (essential); 1 born at 1 dies at 1 (edge
        // 0-1); 2 born at 2 dies at 2; 3 born at 3 dies at 3.
        assert_eq!(pds[0].betti(), 1);
        assert!(pds[0].points().iter().all(|&(_, d)| d.is_infinite()));
        // loop 0-1-2 born at key 2 (edge 1-2 arrives), filled at 2 (triangle).
        assert!(pds[1].is_trivial());
    }

    #[test]
    fn standard_equals_twist_on_random_graphs() {
        let mut rng = crate::util::Rng::new(42);
        for _ in 0..15 {
            let n = rng.range(4, 24);
            let g = gen::erdos_renyi(n, 0.35, rng.next_u64());
            let vals: Vec<f64> = (0..n).map(|_| (rng.below(5)) as f64).collect();
            let f = Filtration::sublevel(vals);
            let a = diagrams(&g, &f, 2, Algorithm::Standard);
            let b = diagrams(&g, &f, 2, Algorithm::Twist);
            for k in 0..=2 {
                assert!(
                    a[k].same_as(&b[k], 1e-12),
                    "PD_{k} mismatch: {} vs {}",
                    a[k],
                    b[k]
                );
            }
        }
    }

    #[test]
    fn essential_plus_pairs_counts_match_simplices() {
        // Euler-type sanity: every simplex is either a birth (paired or
        // essential) or a death, exactly once.
        let g = gen::erdos_renyi(16, 0.4, 7);
        let f = Filtration::degree(&g);
        let c = FlatComplex::build(&g, &f, 3);
        let r = reduce(&c, Algorithm::Twist);
        assert_eq!(2 * r.pairs.len() + r.essential.len(), c.len());
    }

    #[test]
    fn pre_cancelled_token_aborts_reduction() {
        // Enough columns to cross the CANCEL_CHECK_COLS checkpoint.
        let g = gen::erdos_renyi(120, 0.25, 11);
        let f = Filtration::degree(&g);
        let c = FlatComplex::build(&g, &f, 2);
        assert!(c.len() > CANCEL_CHECK_COLS, "need a checkpoint to fire");
        let t = crate::util::CancelToken::cancellable();
        t.cancel();
        for alg in [Algorithm::Standard, Algorithm::Twist] {
            match reduce_cancellable(&c, alg, &t) {
                Err(crate::error::Error::Cancelled) => {}
                other => panic!("expected Cancelled, got {:?}", other.map(|_| ())),
            }
        }
        // A none token reduces normally on the same complex.
        assert!(reduce_cancellable(&c, Algorithm::Twist, &CancelToken::none()).is_ok());
    }

    #[test]
    fn untouched_columns_read_from_arena() {
        // A path graph's edge columns all have unique lows — the fast path
        // must leave every column untouched and still pair correctly.
        let g = gen::path(6);
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let c = FlatComplex::build(&g, &f, 2);
        let r = reduce(&c, Algorithm::Standard);
        assert_eq!(r.pairs.len(), 5, "five edges kill five components");
        assert_eq!(r.essential.len(), 1);
    }
}
