//! Z/2 boundary-matrix reduction — the O(n³) computation the paper's
//! algorithms exist to shrink (§1: "the complexity of the standard PH
//! algorithm is cubic in the number of simplices").
//!
//! The reducers consume a [`FlatComplex`]'s boundary CSR **in place**:
//! unreduced columns are read straight from the arena, and per-column
//! storage materialises only for columns the reduction actually rewrites
//! (the legacy engine cloned the whole column set up front — see
//! [`super::legacy`]). Two strategies over the same layout:
//!
//! * `standard` — textbook left-to-right reduction [59].
//! * `twist` — Chen–Kerber clearing: process dimensions top-down and clear
//!   columns of paired (creator) simplices, skipping their reduction
//!   entirely. The production path; property-tested equal to `standard`.

use super::diagram::Diagram;
use crate::complex::flat::FlatComplex;
use crate::error::Result;
use crate::util::CancelToken;

/// Cancellation-poll granularity: one deadline check per this many
/// processed columns. 1024 columns is far above the cost of an atomic
/// load + `Instant::now()`, so the overhead is unmeasurable, while a
/// runaway cubic reduction still observes its deadline within
/// milliseconds.
pub(crate) const CANCEL_CHECK_COLS: usize = 1024;

/// Which reduction algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Standard,
    Twist,
}

/// Dense Z/2 working column: a reusable bitset for the reduction chain.
///
/// §Perf: reducing a column may add hundreds of pivot columns; with
/// sorted-vector columns each addition is an O(|target| + |other|) merge
/// that reallocates. XOR-ing into a bitset makes each addition
/// O(|other|) bit flips, and the new low is found by scanning downward
/// from the old low (which always cancels). Measured 2.2× end-to-end on
/// the reduction hot path (see EXPERIMENTS.md §Perf).
pub(crate) struct DenseColumn {
    words: Vec<u64>,
}

impl DenseColumn {
    pub(crate) fn new(rows: usize) -> DenseColumn {
        DenseColumn {
            words: vec![0; rows.div_ceil(64)],
        }
    }

    /// Load a sparse column (clears previous contents cheaply by
    /// re-zeroing only the words it may have touched — callers guarantee
    /// `drain_into` ran first).
    pub(crate) fn load(&mut self, col: &[u32]) {
        for &r in col {
            self.words[(r >> 6) as usize] ^= 1u64 << (r & 63);
        }
    }

    /// XOR a sparse column in.
    #[inline]
    pub(crate) fn xor(&mut self, col: &[u32]) {
        for &r in col {
            self.words[(r >> 6) as usize] ^= 1u64 << (r & 63);
        }
    }

    /// Highest set bit at or below `from`, if any.
    pub(crate) fn low_at_or_below(&self, from: usize) -> Option<usize> {
        let mut w = from >> 6;
        let mut mask = if (from & 63) == 63 {
            u64::MAX
        } else {
            (1u64 << ((from & 63) + 1)) - 1
        };
        loop {
            let bits = self.words[w] & mask;
            if bits != 0 {
                return Some((w << 6) + 63 - bits.leading_zeros() as usize);
            }
            if w == 0 {
                return None;
            }
            w -= 1;
            mask = u64::MAX;
        }
    }

    /// Extract set bits ≤ `max_row` into `out` (ascending) and zero them.
    pub(crate) fn drain_into(&mut self, max_row: usize, out: &mut Vec<u32>) {
        out.clear();
        let top = (max_row >> 6) + 1;
        for w in 0..top.min(self.words.len()) {
            let mut bits = self.words[w];
            self.words[w] = 0;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                out.push(((w << 6) + b) as u32);
                bits &= bits - 1;
            }
        }
    }
}

/// Output of a reduction: persistence pairs as (birth col, death col)
/// index pairs plus the essential (unpaired positive) columns.
pub struct ReductionResult {
    /// (birth simplex index, death simplex index); class dim = dim of birth.
    pub pairs: Vec<(usize, usize)>,
    /// Unpaired positive simplex indices (infinite classes).
    pub essential: Vec<usize>,
}

/// Current view of column `j`: the reduced form if the reduction rewrote
/// it, otherwise the original CSR slice straight from the complex arena.
#[inline]
fn col<'a>(c: &'a FlatComplex, work: &'a [Vec<u32>], touched: &[bool], j: usize) -> &'a [u32] {
    if touched[j] {
        &work[j]
    } else {
        c.boundary_of(j)
    }
}

/// Reduce column `j` against the pivots found so far.
fn process(
    j: usize,
    c: &FlatComplex,
    work: &mut [Vec<u32>],
    touched: &mut [bool],
    pivot_of_row: &mut [Option<usize>],
    dense: &mut DenseColumn,
) {
    let Some(&start_low) = col(c, work, touched, j).last() else {
        return; // structurally empty (dim-0) column
    };
    let start_low = start_low as usize;
    // Fast path: unique low already — the CSR slice stays the column's
    // reduced form; no dense round-trip, no storage.
    if pivot_of_row[start_low].is_none() {
        pivot_of_row[start_low] = Some(j);
        return;
    }
    dense.load(col(c, work, touched, j));
    let mut low = start_low;
    loop {
        match pivot_of_row[low] {
            Some(jp) => {
                dense.xor(col(c, work, touched, jp));
                // the shared low always cancels; next low is strictly
                // below it
                match (low > 0).then(|| dense.low_at_or_below(low - 1)).flatten() {
                    Some(l) => low = l,
                    None => {
                        // column reduced to zero
                        work[j].clear();
                        touched[j] = true;
                        return;
                    }
                }
            }
            None => {
                pivot_of_row[low] = Some(j);
                let out = &mut work[j];
                dense.drain_into(low, out);
                touched[j] = true;
                return;
            }
        }
    }
}

/// Run the reduction and extract index pairs. Columns are consumed from
/// the complex's boundary CSR; nothing is cloned up front.
pub fn reduce(c: &FlatComplex, algorithm: Algorithm) -> ReductionResult {
    reduce_cancellable(c, algorithm, &CancelToken::none())
        .expect("reduction with a none token cannot be cancelled")
}

/// [`reduce`] with cooperative cancellation: polls `cancel` every
/// [`CANCEL_CHECK_COLS`] processed columns and unwinds with
/// `Error::DeadlineExceeded` / `Error::Cancelled` instead of running the
/// cubic loop to completion.
pub fn reduce_cancellable(
    c: &FlatComplex,
    algorithm: Algorithm,
    cancel: &CancelToken,
) -> Result<ReductionResult> {
    let n = c.len();
    // Lazily materialised reduced columns: work[j] is meaningful only
    // when touched[j]; untouched columns read from the arena.
    let mut work: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut touched = vec![false; n];
    // pivot_of_row[r] = column whose low is r.
    let mut pivot_of_row: Vec<Option<usize>> = vec![None; n];
    let mut dense = DenseColumn::new(n);
    let mut since_check = 0usize;

    match algorithm {
        Algorithm::Standard => {
            for j in 0..n {
                since_check += 1;
                if since_check >= CANCEL_CHECK_COLS {
                    since_check = 0;
                    cancel.check()?;
                }
                process(j, c, &mut work, &mut touched, &mut pivot_of_row, &mut dense);
            }
        }
        Algorithm::Twist => {
            let max_dim = c.dim();
            let mut cleared = vec![false; n];
            for d in (1..=max_dim).rev() {
                for j in 0..n {
                    if c.dim_of(j) != d || cleared[j] {
                        continue;
                    }
                    since_check += 1;
                    if since_check >= CANCEL_CHECK_COLS {
                        since_check = 0;
                        cancel.check()?;
                    }
                    process(j, c, &mut work, &mut touched, &mut pivot_of_row, &mut dense);
                    if let Some(&low) = col(c, &work, &touched, j).last() {
                        // The paired creator column reduces to zero — clear.
                        let low = low as usize;
                        cleared[low] = true;
                        work[low].clear();
                        touched[low] = true;
                    }
                }
            }
        }
    }

    let mut pairs = Vec::new();
    let mut is_negative = vec![false; n];
    for (row, &column) in pivot_of_row.iter().enumerate() {
        if let Some(j) = column {
            pairs.push((row, j));
            is_negative[j] = true;
        }
    }
    let mut paired_birth = vec![false; n];
    for &(b, _) in &pairs {
        paired_birth[b] = true;
    }
    let essential = (0..n)
        .filter(|&i| !paired_birth[i] && !is_negative[i])
        .collect();
    Ok(ReductionResult { pairs, essential })
}

/// Persistence diagrams PD_0..PD_max_k from a filtered complex.
///
/// The complex must contain simplices up to dimension `max_k + 1`,
/// otherwise deaths of k-classes are missed and PD_k is wrong.
pub fn diagrams_of_complex(c: &FlatComplex, max_k: usize, algorithm: Algorithm) -> Vec<Diagram> {
    diagrams_of_complex_cancellable(c, max_k, algorithm, &CancelToken::none())
        .expect("reduction with a none token cannot be cancelled")
}

/// [`diagrams_of_complex`] with cooperative cancellation threaded into
/// the column reduction.
pub fn diagrams_of_complex_cancellable(
    c: &FlatComplex,
    max_k: usize,
    algorithm: Algorithm,
    cancel: &CancelToken,
) -> Result<Vec<Diagram>> {
    let red = reduce_cancellable(c, algorithm, cancel)?;
    let mut per_dim: Vec<Vec<(f64, f64)>> = vec![Vec::new(); max_k + 1];
    for &(b, d) in &red.pairs {
        let k = c.dim_of(b);
        if k <= max_k {
            per_dim[k].push((c.key_of(b), c.key_of(d)));
        }
    }
    for &i in &red.essential {
        let k = c.dim_of(i);
        if k <= max_k {
            per_dim[k].push((c.key_of(i), f64::INFINITY));
        }
    }
    Ok(per_dim
        .into_iter()
        .enumerate()
        .map(|(k, pairs)| Diagram::new(k, pairs))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complex::{Filtration, FlatComplex};
    use crate::graph::gen;

    fn diagrams(
        g: &crate::graph::Graph,
        f: &Filtration,
        max_k: usize,
        alg: Algorithm,
    ) -> Vec<Diagram> {
        let c = FlatComplex::build(g, f, max_k + 1);
        diagrams_of_complex(&c, max_k, alg)
    }

    #[test]
    fn circle_has_one_loop() {
        let g = gen::cycle(8);
        let f = Filtration::constant(8);
        for alg in [Algorithm::Standard, Algorithm::Twist] {
            let pds = diagrams(&g, &f, 1, alg);
            assert_eq!(pds[0].betti(), 1, "one component");
            assert_eq!(pds[1].betti(), 1, "one essential loop");
        }
    }

    #[test]
    fn complete_graph_is_contractible() {
        let g = gen::complete(5);
        let f = Filtration::constant(5);
        let pds = diagrams(&g, &f, 2, Algorithm::Twist);
        assert_eq!(pds[0].betti(), 1);
        assert_eq!(pds[1].betti(), 0);
        assert_eq!(pds[2].betti(), 0);
    }

    #[test]
    fn octahedron_is_a_sphere() {
        let g = gen::octahedron();
        let f = Filtration::constant(6);
        let pds = diagrams(&g, &f, 2, Algorithm::Twist);
        assert_eq!(pds[0].betti(), 1);
        assert_eq!(pds[1].betti(), 0);
        assert_eq!(pds[2].betti(), 1, "β₂(S²) = 1");
    }

    #[test]
    fn disjoint_union_is_additive() {
        // two 4-cycles, disjoint
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push((i, (i + 1) % 4));
            edges.push((4 + i, 4 + (i + 1) % 4));
        }
        let g = crate::graph::Graph::from_edges(8, &edges);
        let pds = diagrams(&g, &Filtration::constant(8), 1, Algorithm::Twist);
        assert_eq!(pds[0].betti(), 2);
        assert_eq!(pds[1].betti(), 2);
    }

    #[test]
    fn two_triangles_sharing_edge_no_loop() {
        // 0-1-2 and 1-2-3 triangles: the square with a diagonal; both
        // 1-cycles are filled by 2-simplices.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (1, 3), (2, 3)]);
        let pds = diagrams(&g, &Filtration::constant(4), 1, Algorithm::Twist);
        assert_eq!(pds[1].betti(), 0);
    }

    #[test]
    fn sublevel_degree_on_paper_figure1_loop() {
        // A 4-cycle with degree filtration: the loop is born when the last
        // of its vertices arrives, never dies (no triangles).
        let g = gen::cycle(4);
        let f = Filtration::degree(&g);
        let pds = diagrams(&g, &f, 1, Algorithm::Twist);
        assert_eq!(pds[1].betti(), 1);
        assert_eq!(pds[1].essential(), vec![2.0]); // all degrees are 2
    }

    #[test]
    fn birth_death_values_on_tadpole() {
        // triangle 0-1-2 plus pendant 3 on vertex 0, f = vertex id.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (0, 3)]);
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0, 3.0]);
        let pds = diagrams(&g, &f, 1, Algorithm::Twist);
        // components: 0 born at 0 (essential); 1 born at 1 dies at 1 (edge
        // 0-1); 2 born at 2 dies at 2; 3 born at 3 dies at 3.
        assert_eq!(pds[0].betti(), 1);
        assert!(pds[0].points().iter().all(|&(_, d)| d.is_infinite()));
        // loop 0-1-2 born at key 2 (edge 1-2 arrives), filled at 2 (triangle).
        assert!(pds[1].is_trivial());
    }

    #[test]
    fn standard_equals_twist_on_random_graphs() {
        let mut rng = crate::util::Rng::new(42);
        for _ in 0..15 {
            let n = rng.range(4, 24);
            let g = gen::erdos_renyi(n, 0.35, rng.next_u64());
            let vals: Vec<f64> = (0..n).map(|_| (rng.below(5)) as f64).collect();
            let f = Filtration::sublevel(vals);
            let a = diagrams(&g, &f, 2, Algorithm::Standard);
            let b = diagrams(&g, &f, 2, Algorithm::Twist);
            for k in 0..=2 {
                assert!(
                    a[k].same_as(&b[k], 1e-12),
                    "PD_{k} mismatch: {} vs {}",
                    a[k],
                    b[k]
                );
            }
        }
    }

    #[test]
    fn essential_plus_pairs_counts_match_simplices() {
        // Euler-type sanity: every simplex is either a birth (paired or
        // essential) or a death, exactly once.
        let g = gen::erdos_renyi(16, 0.4, 7);
        let f = Filtration::degree(&g);
        let c = FlatComplex::build(&g, &f, 3);
        let r = reduce(&c, Algorithm::Twist);
        assert_eq!(2 * r.pairs.len() + r.essential.len(), c.len());
    }

    #[test]
    fn pre_cancelled_token_aborts_reduction() {
        // Enough columns to cross the CANCEL_CHECK_COLS checkpoint.
        let g = gen::erdos_renyi(120, 0.25, 11);
        let f = Filtration::degree(&g);
        let c = FlatComplex::build(&g, &f, 2);
        assert!(c.len() > CANCEL_CHECK_COLS, "need a checkpoint to fire");
        let t = crate::util::CancelToken::cancellable();
        t.cancel();
        for alg in [Algorithm::Standard, Algorithm::Twist] {
            match reduce_cancellable(&c, alg, &t) {
                Err(crate::error::Error::Cancelled) => {}
                other => panic!("expected Cancelled, got {:?}", other.map(|_| ())),
            }
        }
        // A none token reduces normally on the same complex.
        assert!(reduce_cancellable(&c, Algorithm::Twist, &CancelToken::none()).is_ok());
    }

    #[test]
    fn untouched_columns_read_from_arena() {
        // A path graph's edge columns all have unique lows — the fast path
        // must leave every column untouched and still pair correctly.
        let g = gen::path(6);
        let f = Filtration::sublevel(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let c = FlatComplex::build(&g, &f, 2);
        let r = reduce(&c, Algorithm::Standard);
        assert_eq!(r.pairs.len(), 5, "five edges kill five components");
        assert_eq!(r.essential.len(), 1);
    }
}
