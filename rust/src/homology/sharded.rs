//! Component-sharded persistence: run the reduce→PH path once per
//! connected component and merge the diagrams exactly.
//!
//! Soundness: the clique complex of a disjoint union is the disjoint
//! union of the clique complexes, its boundary matrix is block-diagonal
//! in every filtration order, and column reduction never mixes blocks —
//! so for every `k`, `PD_k(G₁ ⊔ … ⊔ G_c)` is the multiset union of the
//! per-component `PD_k`s. (For `PD_0` each component contributes exactly
//! one essential class, which is what the union yields.) The merge below
//! is therefore plain concatenation followed by the canonical sort.
//!
//! Cost: the monolithic boundary-matrix reduction is cubic in total
//! simplices, `O((Σ nᵢ)³)`; sharding pays `Σ O(nᵢ³)` and the shards run
//! in parallel on std threads — the same worker-pool shape as
//! `coordinator::scheduler`, specialised to pre-materialised shards (an
//! atomic work index replaces the bounded job queue because there is no
//! producer to backpressure, and per-thread `ComplexWorkspace`s replace
//! the size-tiered `coordinator::scratch` pool because every shard of
//! one batch shares a fate — see that module for the mixed-size case).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::complex::{ComplexWorkspace, Filtration};
use crate::error::Result;
use crate::graph::decompose::{decompose_filtered, Shard};
use crate::graph::Graph;
use crate::reduce::planner::ReductionWorkspace;
use crate::reduce::Reduction;
use crate::util::CancelToken;

use crate::util::team::TeamSlot;

use super::diagram::Diagram;
use super::reduction::{PhConfig, PhStats};
use super::{pd0, persistence_diagrams_ph};

/// Diagrams `PD_0..PD_max_k` of a single shard. Singleton shards (the
/// isolated-vertex fringe that PrunIT and coral leave behind in bulk)
/// short-circuit to their one essential component instead of building a
/// complex.
pub fn shard_diagrams(shard: &Shard, max_k: usize) -> Vec<Diagram> {
    shard_diagrams_with(&mut ComplexWorkspace::new(), shard, max_k)
}

/// [`shard_diagrams`] reusing a caller-held [`ComplexWorkspace`]. The
/// sharded pipeline runs thousands of small PH jobs per batch; building
/// each shard's complex into the same per-thread arenas removes the
/// per-shard allocation churn.
pub fn shard_diagrams_with(
    ws: &mut ComplexWorkspace,
    shard: &Shard,
    max_k: usize,
) -> Vec<Diagram> {
    shard_diagrams_cancellable(ws, shard, max_k, &CancelToken::none())
        .expect("shard persistence with a none token cannot be cancelled")
}

/// [`shard_diagrams_with`] with cooperative cancellation threaded into
/// the per-shard persistence computation. The singleton fast path never
/// polls: it is O(1).
pub fn shard_diagrams_cancellable(
    ws: &mut ComplexWorkspace,
    shard: &Shard,
    max_k: usize,
    cancel: &CancelToken,
) -> Result<Vec<Diagram>> {
    shard_diagrams_ph(
        ws,
        shard,
        max_k,
        &PhConfig::default(),
        &mut TeamSlot::default(),
        cancel,
    )
    .map(|(d, _)| d)
}

/// [`shard_diagrams_cancellable`] with the full persistence-engine
/// config: `ph` picks the algorithm, `team` hosts the chunked local
/// phase. Returns the apparent-vs-reduced pair split alongside the
/// diagrams (all-zero on the singleton fast path).
pub fn shard_diagrams_ph(
    ws: &mut ComplexWorkspace,
    shard: &Shard,
    max_k: usize,
    ph: &PhConfig,
    team: &mut TeamSlot,
    cancel: &CancelToken,
) -> Result<(Vec<Diagram>, PhStats)> {
    if shard.graph.n() == 1 {
        let mut out = Vec::with_capacity(max_k + 1);
        out.push(Diagram::new(
            0,
            vec![(shard.filtration.key(0), f64::INFINITY)],
        ));
        for k in 1..=max_k {
            out.push(Diagram::new(k, Vec::new()));
        }
        return Ok((out, PhStats::default()));
    }
    persistence_diagrams_ph(ws, &shard.graph, &shard.filtration, max_k, ph, team, cancel)
}

/// Per-shard diagrams for a whole shard set, computed on up to `workers`
/// std threads. Shards are dispatched largest-first (LPT scheduling): PH
/// cost is superlinear in shard order, so starting the big shards first
/// keeps the makespan near `max(largest shard, total/workers)` even on
/// skewed shard sets. Deterministic: results land in shard order
/// regardless of scheduling, and each shard's computation is itself
/// deterministic.
pub fn all_shard_diagrams(shards: &[Shard], max_k: usize, workers: usize) -> Vec<Vec<Diagram>> {
    all_shard_diagrams_cancellable(shards, max_k, workers, &CancelToken::none())
        .expect("shard persistence with a none token cannot be cancelled")
}

/// [`all_shard_diagrams`] with cooperative cancellation: the token is
/// shared by every worker thread, so one shard hitting the deadline stops
/// the whole dispatch (remaining shards observe expiry before starting)
/// and the first error is returned after the scope joins.
pub fn all_shard_diagrams_cancellable(
    shards: &[Shard],
    max_k: usize,
    workers: usize,
    cancel: &CancelToken,
) -> Result<Vec<Vec<Diagram>>> {
    all_shard_diagrams_ph(shards, max_k, workers, &PhConfig::default(), cancel).map(|(d, _)| d)
}

/// [`all_shard_diagrams_cancellable`] with the full persistence-engine
/// config. `ph.threads` is the budget for the *whole* shard set: it is
/// split across the shard workers (`inner = max(1, threads / workers)`)
/// so chunked inner parallelism never oversubscribes the machine on top
/// of the outer fan-out. Each worker thread holds its own lazily-spawned
/// team slot. Returns the summed apparent-vs-reduced pair split.
pub fn all_shard_diagrams_ph(
    shards: &[Shard],
    max_k: usize,
    workers: usize,
    ph: &PhConfig,
    cancel: &CancelToken,
) -> Result<(Vec<Vec<Diagram>>, PhStats)> {
    let workers = workers.max(1).min(shards.len().max(1));
    let inner = PhConfig {
        threads: (ph.resolved_threads() / workers).max(1),
        ..*ph
    };
    if workers == 1 {
        let mut ws = ComplexWorkspace::new();
        let mut team = TeamSlot::default();
        let mut out = Vec::with_capacity(shards.len());
        let mut stats = PhStats::default();
        for s in shards {
            let (pds, st) = shard_diagrams_ph(&mut ws, s, max_k, &inner, &mut team, cancel)?;
            stats.apparent_pairs += st.apparent_pairs;
            stats.reduced_pairs += st.reduced_pairs;
            out.push(pds);
        }
        return Ok((out, stats));
    }
    let mut order: Vec<usize> = (0..shards.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(shards[i].graph.n()));
    let next = AtomicUsize::new(0);
    let mut out: Vec<Vec<Diagram>> = vec![Vec::new(); shards.len()];
    let mut stats = PhStats::default();
    let mut first_err = None;
    std::thread::scope(|scope| {
        let (tx, rx) = mpsc::channel::<(usize, Result<(Vec<Diagram>, PhStats)>)>();
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let order = &order;
            let inner = &inner;
            scope.spawn(move || {
                // one complex workspace + team slot per worker thread:
                // every shard on this thread builds into the same arenas
                // and fans its chunked local phase out on the same team
                let mut ws = ComplexWorkspace::new();
                let mut team = TeamSlot::default();
                loop {
                    let slot = next.fetch_add(1, Ordering::Relaxed);
                    if slot >= order.len() {
                        break;
                    }
                    let i = order[slot];
                    let res =
                        shard_diagrams_ph(&mut ws, &shards[i], max_k, inner, &mut team, cancel);
                    let errored = res.is_err();
                    if tx.send((i, res)).is_err() || errored {
                        // receiver gone, or this shard failed (deadline /
                        // cancellation): stop claiming work
                        break;
                    }
                }
            });
        }
        drop(tx);
        for (i, pds) in rx {
            match pds {
                Ok((pds, st)) => {
                    stats.apparent_pairs += st.apparent_pairs;
                    stats.reduced_pairs += st.reduced_pairs;
                    out[i] = pds;
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
    });
    match first_err {
        Some(e) => Err(e),
        None => Ok((out, stats)),
    }
}

/// Exact merge of per-shard diagrams: multiset union per dimension
/// (`Diagram::new` restores the canonical sorted order). An empty shard
/// set yields empty diagrams — the PDs of the empty graph.
pub fn merge_shard_diagrams(parts: &[Vec<Diagram>], max_k: usize) -> Vec<Diagram> {
    (0..=max_k)
        .map(|k| {
            let mut pairs: Vec<(f64, f64)> = Vec::new();
            for p in parts {
                if let Some(d) = p.get(k) {
                    pairs.extend_from_slice(d.all_pairs());
                }
            }
            Diagram::new(k, pairs)
        })
        .collect()
}

/// Drop-in sharded replacement for [`persistence_diagrams`]: split into
/// components, compute per-shard PDs on `workers` threads, merge exactly.
/// Equal to the monolithic result in every dimension (property-tested in
/// `rust/tests/`).
pub fn persistence_diagrams_sharded(
    g: &Graph,
    f: &Filtration,
    max_k: usize,
    workers: usize,
) -> Vec<Diagram> {
    let shards = decompose_filtered(g, f);
    let per = all_shard_diagrams(&shards, max_k, workers);
    merge_shard_diagrams(&per, max_k)
}

/// [`persistence_diagrams_sharded`] reusing a caller-held planner
/// workspace for the component labeling + shard emission (an identity
/// plan: nothing is reduced, but the labeling buffers and per-shard CSR
/// assembly run through the same in-place machinery as `pd_sharded`,
/// one compaction per shard). Batch drivers check a
/// `coordinator::WorkerScratch` (a [`ReductionWorkspace`] paired with a
/// [`ComplexWorkspace`]) out of the coordinator's size-tiered scratch
/// pool per job.
///
/// Errors with `Error::FiltrationMismatch` (like every planner entry
/// point) when `f` does not match `g`'s order.
pub fn persistence_diagrams_sharded_with(
    rws: &mut ReductionWorkspace,
    g: &Graph,
    f: &Filtration,
    max_k: usize,
    workers: usize,
) -> crate::error::Result<Vec<Diagram>> {
    if max_k == 0 {
        // PD₀-only: the union-find elder rule over the whole graph is the
        // exact answer — skip the plan, the shard emission, and every
        // boundary matrix.
        f.check(g)?;
        return Ok(vec![pd0(g, f)]);
    }
    rws.plan(g, f, 0, Reduction::None)?;
    let shards = rws.emit_shards(g, f);
    let cancel = rws.cancel_token().clone();
    let ph = rws.ph();
    let (per, _) = all_shard_diagrams_ph(&shards, max_k, workers, &ph, &cancel)?;
    Ok(merge_shard_diagrams(&per, max_k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::decompose::disjoint_union;
    use crate::graph::gen;
    use crate::homology::persistence_diagrams;

    #[test]
    fn merge_is_additive_on_known_spaces() {
        // octahedron ⊔ C8: betti = (2, 1, 1)
        let g = disjoint_union(&[gen::octahedron(), gen::cycle(8)]);
        let f = Filtration::constant(g.n());
        let pds = persistence_diagrams_sharded(&g, &f, 2, 2);
        assert_eq!(pds[0].betti(), 2);
        assert_eq!(pds[1].betti(), 1);
        assert_eq!(pds[2].betti(), 1);
    }

    #[test]
    fn workspace_variant_matches_plain_sharded() {
        let g = disjoint_union(&[gen::cycle(6), gen::erdos_renyi(14, 0.3, 9), Graph::empty(2)]);
        let f = Filtration::degree_superlevel(&g);
        let plain = persistence_diagrams_sharded(&g, &f, 2, 2);
        let mut rws = ReductionWorkspace::new();
        // run twice through the same workspace: reuse must be clean
        for _ in 0..2 {
            let via_ws = persistence_diagrams_sharded_with(&mut rws, &g, &f, 2, 2).unwrap();
            for k in 0..=2 {
                assert!(plain[k].same_as(&via_ws[k], 0.0), "k={k}");
            }
        }
        // mismatched filtration is the typed error, not a panic
        let bad = Filtration::constant(1);
        assert!(persistence_diagrams_sharded_with(&mut rws, &g, &bad, 1, 1).is_err());
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let g = disjoint_union(&[
            gen::erdos_renyi(15, 0.3, 1),
            gen::cycle(9),
            gen::complete(5),
            Graph::empty(4),
        ]);
        let f = Filtration::degree_superlevel(&g);
        let seq = persistence_diagrams_sharded(&g, &f, 2, 1);
        for workers in [2usize, 4, 16] {
            let par = persistence_diagrams_sharded(&g, &f, 2, workers);
            for k in 0..=2 {
                assert!(seq[k].same_as(&par[k], 0.0), "workers={workers} k={k}");
            }
        }
    }

    #[test]
    fn matches_monolithic_engine() {
        let g = disjoint_union(&[gen::cycle(6), gen::star(5), gen::grid(3, 3)]);
        let f = Filtration::degree(&g);
        let mono = persistence_diagrams(&g, &f, 2);
        let shard = persistence_diagrams_sharded(&g, &f, 2, 3);
        for k in 0..=2 {
            assert!(
                mono[k].same_as(&shard[k], 1e-12),
                "PD_{k}: {} vs {}",
                mono[k],
                shard[k]
            );
        }
    }

    #[test]
    fn singleton_fast_path_is_exact() {
        let g = Graph::empty(3);
        let f = Filtration::superlevel(vec![1.0, 2.0, 3.0]);
        let mono = persistence_diagrams(&g, &f, 1);
        let shard = persistence_diagrams_sharded(&g, &f, 1, 2);
        for k in 0..=1 {
            assert!(mono[k].same_as(&shard[k], 0.0));
        }
        assert_eq!(shard[0].betti(), 3);
        assert_eq!(shard[0].essential(), vec![-3.0, -2.0, -1.0]);
    }

    #[test]
    fn empty_graph_gives_empty_diagrams() {
        let pds = persistence_diagrams_sharded(&Graph::empty(0), &Filtration::constant(0), 2, 4);
        assert_eq!(pds.len(), 3);
        assert!(pds.iter().all(|d| d.is_empty()));
    }

    #[test]
    fn pd0_only_request_uses_union_find_per_shard() {
        let g = disjoint_union(&[gen::path(7), gen::cycle(4)]);
        let f = Filtration::degree(&g);
        let mono = persistence_diagrams(&g, &f, 0);
        let shard = persistence_diagrams_sharded(&g, &f, 0, 2);
        assert_eq!(shard.len(), 1);
        assert!(mono[0].same_as(&shard[0], 1e-12));
    }

    #[test]
    fn workers_capped_by_shard_count() {
        // more workers than shards must not deadlock or drop results
        let g = gen::cycle(5);
        let f = Filtration::degree(&g);
        let pds = persistence_diagrams_sharded(&g, &f, 1, 64);
        assert_eq!(pds[1].betti(), 1);
    }
}
