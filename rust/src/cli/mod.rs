//! Hand-rolled CLI (clap is not in the offline registry): the `repro`
//! binary's subcommands, each a thin driver over the library.

use std::collections::BTreeMap;

use crate::complex::{CliqueComplex, ComplexWorkspace, Filtration};
use crate::config::{Config, CoordinatorConfig, ServiceConfig};
use crate::coordinator::{Coordinator, Job, JobSpec, ResumeReport, ServeOptions};
use crate::datasets;
use crate::error::{Error, Result};
use crate::homology::{legacy, persistence_diagrams, persistence_diagrams_ph, Algorithm, PhConfig};
use crate::prune::DominationKernel;
use crate::reduce::{
    combined_with_ws, pd_sharded_with, pd_with_reduction_ws, Reduction, ReductionWorkspace,
};
use crate::runtime::XlaRuntime;
use crate::util::team::TeamSlot;
use crate::util::{CancelToken, Table};

/// Parsed command line: subcommand + `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let val = if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    it.next().cloned().unwrap()
                } else {
                    "true".to_string()
                };
                args.flags.insert(key.to_string(), val);
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("--{key}: expected integer, got {v:?}"))),
        }
    }

    pub fn flag_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("--{key}: expected integer, got {v:?}"))),
        }
    }

    pub fn flag_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("--{key}: expected number, got {v:?}"))),
        }
    }
}

/// Parse a `--reduction` flag value.
pub fn parse_reduction(s: &str) -> Result<Reduction> {
    match s {
        "none" => Ok(Reduction::None),
        "coral" => Ok(Reduction::Coral),
        "prunit" => Ok(Reduction::Prunit),
        "combined" | "prunit+coral" => Ok(Reduction::Combined),
        "fixed-point" | "fixedpoint" | "fp" => Ok(Reduction::FixedPoint),
        other => Err(Error::Parse(format!(
            "--reduction must be none|coral|prunit|combined|fixed-point, got {other:?}"
        ))),
    }
}

pub const USAGE: &str = "\
repro — CoralTDA + PrunIT reduction framework (NeurIPS 2022 reproduction)

USAGE:
  repro <command> [flags]

COMMANDS:
  info                         registry, artifact buckets, PJRT platform
  reduce   --dataset NAME      reduction stats for a dataset
           [--k K] [--seed S]
           [--reduction none|coral|prunit|combined|fixed-point]
           [--prune-threads T]       parallel PrunIT frontier checks
                                     (bit-identical at any T; default 1;
                                     0 = adaptive per-round ramp)
           [--domination-kernel auto|merge|bitset]
                                     pin the residue-domination kernel
                                     (auto picks per round by density)
  pd       --dataset NAME      persistence diagrams of instance 0
           [--k K] [--seed S] [--instance I]
           [--reduction none|coral|prunit|combined|fixed-point]
                                     fixed-point alternates PrunIT and the
                                     (k+1)-core on the in-place planner
           [--prune-threads T]       parallel PrunIT frontier checks
                                     (0 = adaptive, 1 = inline)
           [--domination-kernel auto|merge|bitset]
           [--shard] [--workers W]   component-sharded parallel PH
           [--ph-algorithm standard|twist|chunked]
                                     boundary-matrix reduction engine;
                                     chunked adds the apparent-pair
                                     prepass + chunk-parallel reduction
                                     (diagrams bit-identical everywhere)
           [--ph-threads T]          threads for the chunked engine
                                     (default 1; 0 = all cores)
           [--engine flat|legacy]    columnar engine (default) or the
                                     AoS reference engine (cross-check)
  batch    --dataset NAME      run the batch coordinator over all instances
           [--config FILE] [--workers W] [--k K] [--seed S]
           [--prune-threads T]       per-job PrunIT threads (default 1:
                                     the worker pool owns the cores;
                                     0 = adaptive per-round ramp)
           [--domination-kernel auto|merge|bitset]
           [--ph-algorithm standard|twist|chunked]
                                     per-job persistence engine
           [--ph-threads T]          per-job PH threads (default 1: the
                                     worker pool owns the cores)
           [--large-job-order N]     route jobs with >= N vertices to the
                                     dedicated high-tier worker (0 =
                                     first order past the top scratch
                                     tier, the default)
           [--job-deadline-secs S]   per-job wall deadline (0 disables);
                                     a miss enters the retry ladder
           [--max-retries N]         retries per job, each escalating the
                                     reduction (default 2)
           [--retry-backoff-ms MS]   base backoff, doubled per retry
           [--journal PATH]          persistent JSONL job journal; re-run
                                     with the same path to resume a killed
                                     batch, skipping completed jobs and
                                     re-running orphans (reported as
                                     `ORPHANED <id>` on stderr; exit code
                                     1 if any job still fails)
  serve                        always-on reduction service: newline-
                               delimited `key=value` requests on stdin
                               (`id= dataset= instance= seed= k=
                               reduction= priority= ph_algorithm=
                               ph_threads=`), one response line
                               per request on stdout; SIGTERM/SIGINT
                               drains in-flight work and exits 0
           [--config FILE]           reads [coordinator] + [service] keys
           [--http ADDR]             /healthz + /metrics endpoint
                                     (e.g. 127.0.0.1:9100; port 0 = auto)
           [--journal PATH]          persistent journal; resuming skips
                                     completed ids (`already-done`) and
                                     compacts past journal_compact_bytes
           [--workers W] [--k K] [--prune-threads T]
           [--domination-kernel auto|merge|bitset]
           [--ph-algorithm standard|twist|chunked] [--ph-threads T]
           [--job-deadline-secs S] [--max-retries N]
           [--retry-backoff-ms MS]
           [--max-pending N]         admission: hard queue cap
           [--shed-pending N]        admission: priority ramp start
           [--memory-budget-bytes B] admission: working-set budget
           [--cpu-pressure-secs S]   admission: degrade threshold
           [--cache-budget-bytes B]  result cache size (0 disables)
           [--idle-evict-secs S]     scratch idle eviction window
           [--stuck-job-secs S]      watchdog force-cancel limit
           [--watchdog-poll-ms MS]   watchdog sweep period
  dense-check --dataset NAME   cross-check XLA dense PrunIT vs sparse path
           [--seed S]          (needs the `xla` build feature + artifacts)
  help                         this text

Datasets: see `repro info`. Experiments (paper tables/figures) live in
`cargo bench` targets; see README.md for the index.
";

/// Entry: dispatch a parsed command, returning the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "info" => cmd_info(),
        "reduce" => cmd_reduce(&args),
        "pd" => cmd_pd(&args),
        "batch" => cmd_batch(&args),
        "serve" => cmd_serve(&args),
        "dense-check" => cmd_dense_check(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            Ok(2)
        }
    }
}

fn dataset_flag(args: &Args) -> Result<datasets::Recipe> {
    let name = args
        .flag("dataset")
        .ok_or_else(|| Error::Parse("--dataset is required".into()))?;
    datasets::find(name)
}

fn cmd_info() -> Result<i32> {
    let mut t = Table::new(
        "dataset registry (synthetic stand-ins; README.md §Datasets)",
        &["name", "kind", "n", "instances", "scale_down", "family"],
    );
    let groups: [(&str, Vec<datasets::Recipe>); 4] = [
        ("kernel", datasets::kernel_datasets()),
        ("node", datasets::node_datasets()),
        ("ogb", datasets::ogb_like()),
        ("large", datasets::large_networks()),
    ];
    for (kind, recipes) in groups {
        for r in recipes {
            t.row(&[
                r.name.to_string(),
                kind.to_string(),
                r.n.to_string(),
                r.instances.to_string(),
                format!("{}x", r.scale_down),
                format!("{:?}", r.family),
            ]);
        }
    }
    t.emit(None);
    match XlaRuntime::from_default() {
        Ok(rt) => println!(
            "xla runtime: platform={} buckets={:?}",
            rt.platform(),
            rt.buckets()
        ),
        Err(e) => println!("xla runtime unavailable: {e}"),
    }
    Ok(0)
}

fn cmd_reduce(args: &Args) -> Result<i32> {
    let recipe = dataset_flag(args)?;
    let k = args.flag_usize("k", 1)?;
    let seed = args.flag_u64("seed", 42)?;
    let prune_threads = args.flag_usize("prune-threads", 1)?;
    let kernel = DominationKernel::parse(args.flag("domination-kernel").unwrap_or("auto"))?;
    let which = parse_reduction(args.flag("reduction").unwrap_or("combined"))?;
    let mut t = Table::new(
        &format!("{} reduction on {} (k={k})", which.name(), recipe.name),
        &["instance", "|V|", "|V'|", "V-red", "|E|", "|E'|", "E-red", "rounds", "secs"],
    );
    let mut ws = ReductionWorkspace::with_prune_threads(prune_threads);
    ws.set_domination_kernel(kernel);
    for i in 0..recipe.instances {
        let g = recipe.make(seed, i);
        let f = Filtration::degree_superlevel(&g);
        let r = combined_with_ws(&mut ws, &g, &f, k, which)?;
        t.row(&[
            i.to_string(),
            r.report.vertices_before.to_string(),
            r.graph.n().to_string(),
            format!("{:.1}%", r.vertex_reduction_pct()),
            r.report.edges_before.to_string(),
            r.graph.m().to_string(),
            format!("{:.1}%", r.edge_reduction_pct()),
            r.report.rounds_run().to_string(),
            format!("{:.4}", r.report.reduce_secs),
        ]);
    }
    t.emit(None);
    Ok(0)
}

fn cmd_pd(args: &Args) -> Result<i32> {
    let recipe = dataset_flag(args)?;
    let k = args.flag_usize("k", 1)?;
    let seed = args.flag_u64("seed", 42)?;
    let idx = args.flag_usize("instance", 0)?;
    let which = parse_reduction(args.flag("reduction").unwrap_or("none"))?;
    let shard = args.flag("shard").map(|v| v != "false").unwrap_or(false);
    let engine = args.flag("engine").unwrap_or("flat");
    if engine != "flat" && engine != "legacy" {
        return Err(Error::Parse(format!(
            "--engine must be flat|legacy, got {engine:?}"
        )));
    }
    if engine == "legacy" && shard {
        return Err(Error::Parse(
            "--engine legacy is the monolithic reference path; drop --shard".into(),
        ));
    }
    let default_workers = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(2);
    let workers = args.flag_usize("workers", default_workers)?;
    let prune_threads = args.flag_usize("prune-threads", 1)?;
    let kernel = DominationKernel::parse(args.flag("domination-kernel").unwrap_or("auto"))?;
    let ph = PhConfig {
        algorithm: Algorithm::parse(args.flag("ph-algorithm").unwrap_or("twist"))?,
        threads: args.flag_usize("ph-threads", 1)?,
        chunk_cols: 0,
    };
    let g = recipe.make(seed, idx);
    let f = Filtration::degree_superlevel(&g);
    println!(
        "{} instance {idx}: n={} m={}",
        recipe.name,
        g.n(),
        g.m()
    );
    let mut rws = ReductionWorkspace::with_prune_threads(prune_threads);
    rws.set_domination_kernel(kernel);
    rws.set_ph(ph);
    let pds = if engine == "legacy" {
        let red = combined_with_ws(&mut rws, &g, &f, k, which)?;
        let c = CliqueComplex::build(&red.graph, &red.filtration, k + 1);
        let pds = legacy::diagrams_of_complex(&c, k, Algorithm::Twist)?;
        println!(
            "legacy engine: reduction={} {}->{} vertices, {} simplices (AoS)",
            red.report.which.name(),
            red.report.vertices_before,
            red.graph.n(),
            c.len(),
        );
        pds
    } else if shard {
        let (pds, report) = pd_sharded_with(&mut rws, &g, &f, k, which, workers)?;
        println!(
            "sharded: reduction={} {}->{} vertices in {} round(s), {} shards (largest {}), {workers} workers",
            report.which.name(),
            report.vertices_before,
            report.vertices_after,
            report.rounds_run().max(1),
            report.shard_count(),
            report.largest_shard(),
        );
        pds
    } else if which != Reduction::None {
        let (pds, report) = pd_with_reduction_ws(&mut rws, &g, &f, k, which)?;
        println!(
            "reduced: {} {}->{} vertices ({:.1}%) in {} round(s), {} frontier round(s) \
             [prunit {:.4}s x{prune_threads}t, core {:.4}s, compact {:.4}s]",
            report.which.name(),
            report.vertices_before,
            report.vertices_after,
            report.vertex_reduction_pct(),
            report.rounds_run(),
            report.prunit_rounds,
            report.prunit_secs,
            report.core_secs,
            report.compact_secs,
        );
        pds
    } else {
        let mut team = TeamSlot::default();
        let (pds, _) = persistence_diagrams_ph(
            &mut ComplexWorkspace::new(),
            &g,
            &f,
            k,
            &ph,
            &mut team,
            &CancelToken::none(),
        )?;
        pds
    };
    for d in &pds {
        println!("  {d}");
    }
    Ok(0)
}

fn cmd_batch(args: &Args) -> Result<i32> {
    let recipe = dataset_flag(args)?;
    let seed = args.flag_u64("seed", 42)?;
    let mut cfg = match args.flag("config") {
        Some(path) => CoordinatorConfig::from_config(&Config::load(path)?)?,
        None => CoordinatorConfig::default(),
    };
    if let Some(w) = args.flag("workers") {
        cfg.workers = w
            .parse()
            .map_err(|_| Error::Parse(format!("--workers: {w:?}")))?;
    }
    cfg.max_k = args.flag_usize("k", cfg.max_k)?;
    cfg.prune_threads = args.flag_usize("prune-threads", cfg.prune_threads)?;
    if let Some(kern) = args.flag("domination-kernel") {
        cfg.domination_kernel = kern.to_string();
    }
    cfg.job_deadline_secs = args.flag_f64("job-deadline-secs", cfg.job_deadline_secs)?;
    cfg.max_retries = args.flag_usize("max-retries", cfg.max_retries)?;
    cfg.retry_backoff_ms = args.flag_u64("retry-backoff-ms", cfg.retry_backoff_ms)?;
    cfg.large_job_order = args.flag_usize("large-job-order", cfg.large_job_order)?;
    if let Some(alg) = args.flag("ph-algorithm") {
        cfg.ph_algorithm = alg.to_string();
    }
    cfg.ph_threads = args.flag_usize("ph-threads", cfg.ph_threads)?;
    // validate up front so a bad value fails before any worker spawns
    DominationKernel::parse(&cfg.domination_kernel)?;
    let reduction = parse_reduction(&cfg.reduction.clone())?;
    let ph = PhConfig {
        algorithm: Algorithm::parse(&cfg.ph_algorithm)?,
        threads: cfg.ph_threads,
        chunk_cols: 0,
    };
    let coordinator = Coordinator::new(cfg.clone());
    let jobs: Vec<Job> = (0..recipe.instances)
        .map(|i| {
            Job::degree_superlevel(
                i as u64,
                recipe.make(seed, i),
                JobSpec {
                    max_k: cfg.max_k,
                    reduction,
                    sharded: false,
                    ph,
                },
            )
        })
        .collect();
    let t0 = std::time::Instant::now();
    let (outcome, resume) = match args.flag("journal") {
        Some(path) => coordinator.run_resumable(jobs, path)?,
        None => (
            coordinator.run_with_failures(jobs, None)?,
            ResumeReport::default(),
        ),
    };
    let secs = t0.elapsed().as_secs_f64();
    let prune_desc = match cfg.prune_threads {
        0 => "adaptive".to_string(),
        t => t.to_string(),
    };
    println!(
        "{}: {} jobs in {:.3}s ({:.1} jobs/s, {} workers, {} prune thread(s)/job)",
        recipe.name,
        outcome.results.len(),
        secs,
        outcome.results.len() as f64 / secs.max(1e-12),
        cfg.workers,
        prune_desc,
    );
    if resume.skipped > 0 {
        println!(
            "journal: skipped {} job(s) already completed by an earlier run",
            resume.skipped
        );
    }
    // orphans go to stderr: a monitoring wrapper tailing the journal can
    // pick up exactly which ids a killed incarnation left in flight
    for id in &resume.orphaned {
        eprintln!("ORPHANED {id}");
    }
    if !resume.orphaned.is_empty() {
        println!(
            "journal: re-ran {} orphaned job(s) left in flight by a killed run",
            resume.orphaned.len()
        );
    }
    let degraded = outcome
        .results
        .iter()
        .filter(|r| r.outcome.is_degraded())
        .count();
    if degraded > 0 {
        println!("degraded: {degraded} job(s) succeeded only after spec escalation");
    }
    println!("{}", coordinator.metrics().summary());
    println!("{}", coordinator.scratch_pool().summary());
    if !outcome.failures.is_empty() {
        for f in &outcome.failures {
            eprintln!("FAILED: {f}");
        }
        eprintln!(
            "batch: {} of {} job(s) failed after retries",
            outcome.failures.len(),
            outcome.results.len() + outcome.failures.len(),
        );
        return Ok(1);
    }
    Ok(0)
}

fn cmd_serve(args: &Args) -> Result<i32> {
    let file_cfg = match args.flag("config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    let mut cfg = CoordinatorConfig::from_config(&file_cfg)?;
    let mut svc = ServiceConfig::from_config(&file_cfg)?;
    if let Some(w) = args.flag("workers") {
        cfg.workers = w
            .parse()
            .map_err(|_| Error::Parse(format!("--workers: {w:?}")))?;
    }
    cfg.max_k = args.flag_usize("k", cfg.max_k)?;
    cfg.prune_threads = args.flag_usize("prune-threads", cfg.prune_threads)?;
    if let Some(kern) = args.flag("domination-kernel") {
        cfg.domination_kernel = kern.to_string();
    }
    cfg.job_deadline_secs = args.flag_f64("job-deadline-secs", cfg.job_deadline_secs)?;
    cfg.max_retries = args.flag_usize("max-retries", cfg.max_retries)?;
    cfg.retry_backoff_ms = args.flag_u64("retry-backoff-ms", cfg.retry_backoff_ms)?;
    if let Some(alg) = args.flag("ph-algorithm") {
        cfg.ph_algorithm = alg.to_string();
    }
    cfg.ph_threads = args.flag_usize("ph-threads", cfg.ph_threads)?;
    if let Some(addr) = args.flag("http") {
        svc.http_addr = addr.to_string();
    }
    svc.max_pending = args.flag_usize("max-pending", svc.max_pending)?;
    svc.shed_pending = args.flag_usize("shed-pending", svc.shed_pending)?;
    svc.memory_budget_bytes = args.flag_usize("memory-budget-bytes", svc.memory_budget_bytes)?;
    svc.cpu_pressure_secs = args.flag_f64("cpu-pressure-secs", svc.cpu_pressure_secs)?;
    svc.cache_budget_bytes = args.flag_usize("cache-budget-bytes", svc.cache_budget_bytes)?;
    svc.idle_evict_secs = args.flag_f64("idle-evict-secs", svc.idle_evict_secs)?;
    svc.stuck_job_secs = args.flag_f64("stuck-job-secs", svc.stuck_job_secs)?;
    svc.watchdog_poll_ms = args.flag_u64("watchdog-poll-ms", svc.watchdog_poll_ms)?;
    // validate up front so a bad config fails before any thread spawns
    DominationKernel::parse(&cfg.domination_kernel)?;
    parse_reduction(&cfg.reduction)?;
    Algorithm::parse(&cfg.ph_algorithm)?;
    crate::coordinator::install_signal_handlers();
    let opts = ServeOptions {
        coordinator: cfg,
        service: svc,
        journal_path: args.flag("journal").map(std::path::PathBuf::from),
        shutdown: None,
        #[cfg(any(test, feature = "faults"))]
        faults: None,
    };
    // Stdin (not StdinLock: the reader thread needs a Send handle);
    // responses and the final drain summary go straight to stdout.
    let input = std::io::BufReader::new(std::io::stdin());
    crate::coordinator::serve::serve(input, opts, |line| println!("{line}"))?;
    Ok(0)
}

fn cmd_dense_check(args: &Args) -> Result<i32> {
    let recipe = dataset_flag(args)?;
    let seed = args.flag_u64("seed", 42)?;
    let rt = XlaRuntime::from_default()?;
    let mut checked = 0usize;
    for i in 0..recipe.instances {
        let g = recipe.make(seed, i);
        if g.n() > rt.max_order() {
            println!("instance {i}: n={} exceeds dense buckets, skipped", g.n());
            continue;
        }
        let f = Filtration::degree_superlevel(&g);
        let dense = crate::runtime::prunit_dense(&rt, &g, &f)?;
        let sparse = crate::prune::prunit(&g, &f)?;
        let pd_dense = persistence_diagrams(&dense.graph, &dense.filtration, 1);
        let pd_sparse = persistence_diagrams(&sparse.graph, &sparse.filtration, 1);
        for k in 0..=1 {
            if !pd_dense[k].same_as(&pd_sparse[k], 1e-9) {
                return Err(Error::Xla(format!(
                    "instance {i}: dense/sparse PD_{k} disagree"
                )));
            }
        }
        println!(
            "instance {i}: n={} dense→{} sparse→{} PDs agree ✓",
            g.n(),
            dense.graph.n(),
            sparse.graph.n()
        );
        checked += 1;
    }
    println!("dense-check: {checked} instances verified");
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&argv("reduce --dataset TWITTER --k 2 pos")).unwrap();
        assert_eq!(a.command, "reduce");
        assert_eq!(a.flag("dataset"), Some("TWITTER"));
        assert_eq!(a.flag_usize("k", 0).unwrap(), 2);
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn boolean_flags_default_true() {
        let a = Args::parse(&argv("cmd --verbose --k 3")).unwrap();
        assert_eq!(a.flag("verbose"), Some("true"));
    }

    #[test]
    fn reduction_parse() {
        assert_eq!(parse_reduction("coral").unwrap(), Reduction::Coral);
        assert_eq!(
            parse_reduction("prunit+coral").unwrap(),
            Reduction::Combined
        );
        assert_eq!(
            parse_reduction("fixed-point").unwrap(),
            Reduction::FixedPoint
        );
        assert_eq!(parse_reduction("fp").unwrap(), Reduction::FixedPoint);
        assert!(parse_reduction("bogus").is_err());
    }

    #[test]
    fn unknown_command_is_exit_2() {
        assert_eq!(run(&argv("frobnicate")).unwrap(), 2);
    }

    #[test]
    fn help_runs() {
        assert_eq!(run(&argv("help")).unwrap(), 0);
    }

    #[test]
    fn missing_dataset_flag_errors() {
        assert!(run(&argv("reduce")).is_err());
    }

    #[test]
    fn bad_flag_value_errors() {
        let a = Args::parse(&argv("reduce --k abc")).unwrap();
        assert!(a.flag_usize("k", 0).is_err());
    }

    #[test]
    fn pd_sharded_path_runs_end_to_end() {
        // DHFR instance 0 is a ~40-vertex molecule graph: cheap, and the
        // sharded pipeline must accept boolean `--shard` + `--workers`.
        assert_eq!(
            run(&argv("pd --dataset DHFR --shard --workers 2 --k 1")).unwrap(),
            0
        );
    }

    #[test]
    fn pd_reduction_flag_runs() {
        assert_eq!(
            run(&argv("pd --dataset DHFR --reduction combined --k 1")).unwrap(),
            0
        );
    }

    #[test]
    fn pd_fixed_point_reduction_runs() {
        assert_eq!(
            run(&argv("pd --dataset DHFR --reduction fixed-point --k 1")).unwrap(),
            0
        );
        assert_eq!(
            run(&argv("pd --dataset DHFR --reduction fixed-point --shard --workers 2 --k 1"))
                .unwrap(),
            0
        );
    }

    #[test]
    fn pd_prune_threads_flag_runs() {
        assert_eq!(
            run(&argv(
                "pd --dataset DHFR --reduction combined --prune-threads 4 --k 1"
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run(&argv("reduce --dataset DHFR --prune-threads 2 --k 1")).unwrap(),
            0
        );
        // non-integer thread counts are a parse error
        assert!(run(&argv("pd --dataset DHFR --prune-threads lots")).is_err());
        // 0 = adaptive: valid everywhere a thread count is accepted
        assert_eq!(
            run(&argv("reduce --dataset DHFR --prune-threads 0 --k 1")).unwrap(),
            0
        );
    }

    #[test]
    fn batch_adaptive_threads_and_routing_flags_run() {
        assert_eq!(
            run(&argv(
                "batch --dataset DHFR --workers 2 --prune-threads 0 --large-job-order 64"
            ))
            .unwrap(),
            0
        );
        assert!(run(&argv("batch --dataset DHFR --large-job-order many")).is_err());
    }

    #[test]
    fn domination_kernel_flag_runs_and_validates() {
        assert_eq!(
            run(&argv(
                "pd --dataset DHFR --reduction combined --domination-kernel bitset --k 1"
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run(&argv("reduce --dataset DHFR --domination-kernel merge --k 1")).unwrap(),
            0
        );
        // unknown kernel names are a parse error, not a silent fallback
        assert!(run(&argv("pd --dataset DHFR --domination-kernel simd")).is_err());
    }

    #[test]
    fn ph_algorithm_flag_runs_and_validates() {
        assert_eq!(
            run(&argv("pd --dataset DHFR --ph-algorithm chunked --ph-threads 2 --k 1")).unwrap(),
            0
        );
        assert_eq!(
            run(&argv(
                "pd --dataset DHFR --reduction combined --ph-algorithm standard --k 1"
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run(&argv(
                "batch --dataset DHFR --workers 2 --ph-algorithm chunked --ph-threads 2"
            ))
            .unwrap(),
            0
        );
        // unknown engine names are a parse error, not a silent fallback
        assert!(run(&argv("pd --dataset DHFR --ph-algorithm fast")).is_err());
        assert!(run(&argv("batch --dataset DHFR --ph-algorithm fast")).is_err());
        assert!(run(&argv("pd --dataset DHFR --ph-threads lots")).is_err());
    }

    #[test]
    fn pd_legacy_engine_runs() {
        assert_eq!(
            run(&argv("pd --dataset DHFR --engine legacy --k 1")).unwrap(),
            0
        );
    }

    #[test]
    fn pd_engine_flag_validated() {
        assert!(run(&argv("pd --dataset DHFR --engine bogus")).is_err());
        assert!(run(&argv("pd --dataset DHFR --engine legacy --shard")).is_err());
    }

    #[test]
    fn flag_f64_parses_and_rejects() {
        let a = Args::parse(&argv("batch --job-deadline-secs 1.5")).unwrap();
        assert_eq!(a.flag_f64("job-deadline-secs", 0.0).unwrap(), 1.5);
        assert_eq!(a.flag_f64("missing", 2.5).unwrap(), 2.5);
        let bad = Args::parse(&argv("batch --job-deadline-secs soon")).unwrap();
        assert!(bad.flag_f64("job-deadline-secs", 0.0).is_err());
    }

    #[test]
    fn batch_fault_tolerance_flags_run() {
        assert_eq!(
            run(&argv(
                "batch --dataset DHFR --workers 2 --max-retries 1 \
                 --retry-backoff-ms 1 --job-deadline-secs 30"
            ))
            .unwrap(),
            0
        );
        assert!(run(&argv("batch --dataset DHFR --job-deadline-secs soon")).is_err());
    }

    #[test]
    fn batch_journal_flag_resumes_without_recompute() {
        let mut p = std::env::temp_dir();
        p.push(format!("coraltda-cli-journal-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&p);
        let cmd = format!("batch --dataset DHFR --workers 2 --journal {}", p.display());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let replay = crate::coordinator::JournalReplay::load(&p).unwrap();
        let completed_first = replay.completed.len();
        assert!(completed_first > 0);
        // second invocation replays the journal and skips everything
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let replay = crate::coordinator::JournalReplay::load(&p).unwrap();
        assert_eq!(
            replay.completed.len(),
            completed_first,
            "resume must not re-run (or duplicate) completed jobs"
        );
        assert!(replay.orphaned().is_empty());
        let _ = std::fs::remove_file(&p);
    }
}
