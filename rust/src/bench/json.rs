//! Machine-readable bench output: a dependency-free JSON emitter for the
//! planner perf trajectory (`BENCH_planner.json`). One record per
//! (bench, graph, pipeline, stage) measurement; CI runs the quick bench
//! profile and uploads the file as an artifact so reduce wall-times are
//! comparable across PRs.

use std::fmt::Write as _;
use std::fs;
use std::io;

/// One measurement row of a bench run.
#[derive(Clone, Debug)]
pub struct JsonRecord {
    /// bench driver name, e.g. `planner_scaling`
    pub bench: String,
    /// workload label, e.g. `ER(20000,5/n)`
    pub graph: String,
    /// `in-place` (planner) or `materializing` (reference pipeline)
    pub pipeline: String,
    /// reduction variant name (`Reduction::name`)
    pub reduction: String,
    /// measured stage, e.g. `reduce`
    pub stage: String,
    /// domination-kernel policy the run was pinned to (`auto`, `merge`,
    /// or `bitset`) — lets CI compare the forced-kernel bench legs
    pub kernel: String,
    /// median wall seconds of the stage
    pub wall_secs: f64,
    /// vertices removed per PrunIT⇄core round (prunit + core per entry)
    pub removed_per_round: Vec<usize>,
    /// residue order after the reduction
    pub vertices_after: usize,
}

fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn push_json_f64(out: &mut String, x: f64) {
    if x.is_finite() {
        // Rust's shortest-roundtrip Display for f64 is valid JSON except
        // that integral values print without a fractional part — fine.
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null");
    }
}

/// Serialise records as a pretty-enough JSON array.
pub fn to_json(records: &[JsonRecord]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("  {");
        out.push_str("\"bench\": ");
        push_json_str(&mut out, &r.bench);
        out.push_str(", \"graph\": ");
        push_json_str(&mut out, &r.graph);
        out.push_str(", \"pipeline\": ");
        push_json_str(&mut out, &r.pipeline);
        out.push_str(", \"reduction\": ");
        push_json_str(&mut out, &r.reduction);
        out.push_str(", \"stage\": ");
        push_json_str(&mut out, &r.stage);
        out.push_str(", \"kernel\": ");
        push_json_str(&mut out, &r.kernel);
        out.push_str(", \"wall_secs\": ");
        push_json_f64(&mut out, r.wall_secs);
        out.push_str(", \"removed_per_round\": [");
        for (j, c) in r.removed_per_round.iter().enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "{c}");
        }
        out.push_str("], \"vertices_after\": ");
        let _ = write!(out, "{}", r.vertices_after);
        out.push('}');
        if i + 1 < records.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

/// Write records to `path`. Cargo runs bench binaries with the working
/// directory set to the PACKAGE root (`rust/`), not the invocation cwd,
/// so a relative path here lands next to `rust/Cargo.toml` — the same
/// place `bench_results.tsv` accumulates; CI uploads
/// `rust/BENCH_planner.json`.
pub fn write_records(path: &str, records: &[JsonRecord]) -> io::Result<()> {
    fs::write(path, to_json(records))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_and_escaping() {
        let rec = JsonRecord {
            bench: "planner_scaling".into(),
            graph: "ER(\"n\",5/n)".into(),
            pipeline: "in-place".into(),
            reduction: "fixed-point".into(),
            stage: "reduce".into(),
            kernel: "auto".into(),
            wall_secs: 0.125,
            removed_per_round: vec![10, 3, 0],
            vertices_after: 42,
        };
        let s = to_json(std::slice::from_ref(&rec));
        assert!(s.starts_with("[\n"));
        assert!(s.contains("\\\"n\\\""), "quotes escaped: {s}");
        assert!(s.contains("\"kernel\": \"auto\""));
        assert!(s.contains("\"wall_secs\": 0.125"));
        assert!(s.contains("\"removed_per_round\": [10, 3, 0]"));
        assert!(s.contains("\"vertices_after\": 42"));
        assert!(s.trim_end().ends_with(']'));
    }

    #[test]
    fn non_finite_times_become_null() {
        let rec = JsonRecord {
            bench: "b".into(),
            graph: "g".into(),
            pipeline: "p".into(),
            reduction: "r".into(),
            stage: "s".into(),
            kernel: "merge".into(),
            wall_secs: f64::NAN,
            removed_per_round: vec![],
            vertices_after: 0,
        };
        let s = to_json(&[rec]);
        assert!(s.contains("\"wall_secs\": null"));
        assert!(s.contains("\"removed_per_round\": []"));
    }

    #[test]
    fn empty_record_list_is_valid_json_array() {
        assert_eq!(to_json(&[]), "[\n]\n");
    }
}
